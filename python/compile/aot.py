"""AOT lowering: JAX entry points → HLO **text** artifacts + manifest.

HLO text (not serialized ``HloModuleProto``) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and aot_recipe.md).

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile target);
also importable for the pytest lowering smoke tests.

Exports (batch ``B``, toy GRU config — see ``model.LatentConfig``):
  post_drift_fwd     (params[P], zin[B, dz+1+dc])          → (B, dz)
  post_drift_vjp     (params, zin, ct[B, dz])              → (dzin, dparams)
  prior_drift_fwd    (params, zin[B, dz+1])                → (B, dz)
  decoder_fwd        (params, z[B, dz])                    → (B, dx)
  diffusion_fwd      (params, z[B, dz])                    → (B, dz)
  elbo_euler_step    (params, z, l[B], t[], dt[], ctx, dw) → (z', l')

The manifest (``manifest.txt``) is line-oriented ``key=value`` (hand
parseable from Rust without a JSON dependency): a ``cfg`` line with the
model dimensions and one ``entry`` line per artifact.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text (return_tuple=True so the
    Rust side unwraps with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def entries(cfg: M.LatentConfig, batch: int):
    """(name, fn, example-arg shapes) for every exported entry point."""
    p = M.n_params(cfg)
    dz, dc, dx = cfg.latent_dim, cfg.context_dim, cfg.obs_dim

    def post_fwd(params, zin):
        return (M.post_drift_fwd(cfg, params, zin),)

    def post_vjp(params, zin, ct):
        _, pull = jax.vjp(lambda pp, zz: M.post_drift_fwd(cfg, pp, zz), params, zin)
        dp, dzin = pull(ct)
        return (dzin, dp)

    def prior_fwd(params, zin):
        return (M.prior_drift_fwd(cfg, params, zin),)

    def dec_fwd(params, z):
        return (M.decoder_fwd(cfg, params, z),)

    def diff_fwd(params, z):
        return (M.diffusion_fwd(cfg, params, z),)

    def step(params, z, l, t, dt, ctx, dw):
        zn, ln = M.elbo_euler_step(cfg, params, z, l, t, dt, ctx, dw)
        return (zn, ln)

    return [
        ("post_drift_fwd", post_fwd, [[p], [batch, dz + 1 + dc]]),
        ("post_drift_vjp", post_vjp, [[p], [batch, dz + 1 + dc], [batch, dz]]),
        ("prior_drift_fwd", prior_fwd, [[p], [batch, dz + 1]]),
        ("decoder_fwd", dec_fwd, [[p], [batch, dz]]),
        ("diffusion_fwd", diff_fwd, [[p], [batch, dz]]),
        (
            "elbo_euler_step",
            step,
            [[p], [batch, dz], [batch], [], [], [batch, dc], [batch, dz]],
        ),
    ]


def lower_entry(fn, shapes):
    specs = [_spec(s) for s in shapes]
    return jax.jit(fn).lower(*specs)


def export_all(out_dir: str, cfg: M.LatentConfig, batch: int) -> list:
    """Lower every entry point, write ``<name>.hlo.txt`` + manifest.
    Returns the manifest lines."""
    os.makedirs(out_dir, exist_ok=True)
    p = M.n_params(cfg)
    lines = [
        "format=sdegrad-artifacts-v1",
        (
            f"cfg obs_dim={cfg.obs_dim} latent_dim={cfg.latent_dim} "
            f"context_dim={cfg.context_dim} hidden={cfg.hidden} "
            f"diff_hidden={cfg.diff_hidden} enc_hidden={cfg.enc_hidden} "
            f"n_params={p} batch={batch} "
            f"sigma_floor={cfg.sigma_floor} sigma_scale={cfg.sigma_scale}"
        ),
    ]
    for name, fn, shapes in entries(cfg, batch):
        text = to_hlo_text(lower_entry(fn, shapes))
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        shape_str = ";".join("x".join(str(d) for d in s) if s else "scalar" for s in shapes)
        lines.append(f"entry {name} file={fname} inputs={shape_str}")
        print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  wrote manifest.txt ({len(lines)} lines)")
    return lines


@functools.lru_cache(maxsize=1)
def default_cfg() -> M.LatentConfig:
    return M.LatentConfig()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()
    cfg = default_cfg()
    print(f"AOT-lowering latent SDE entry points (n_params={M.n_params(cfg)}) ...")
    export_all(args.out, cfg, args.batch)


if __name__ == "__main__":
    main()
