"""L2: the latent-SDE compute graph in JAX, calling the L1 Pallas kernels.

Build-time only — these functions are lowered once by ``aot.py`` to HLO
text and executed from the Rust runtime (``rust/src/runtime``); Python
never runs on the training path.

Parameter layout
----------------
All entry points take one flat f32 parameter vector whose layout matches
the Rust model byte-for-byte (``rust/src/latent/model.rs``): per
``Linear``, the weight matrix is stored row-major ``(out, in)`` followed by
the bias; modules in order prior-drift MLP, posterior-drift MLP, per-dim
diffusion nets, decoder, encoder, q-head, ``p(z0)`` mean, ``p(z0)``
logvar. This lets the Rust side hand its live parameter vector (cast to
f32) straight to a compiled artifact, and is verified end-to-end by the
``runtime::consistency`` Rust test.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from compile.kernels.fused_mlp import euler_logqp_step, fused_mlp


@dataclass(frozen=True)
class LatentConfig:
    """Mirror of the Rust ``LatentSdeConfig`` (GRU-encoder, per-dim σ)."""

    obs_dim: int = 1
    latent_dim: int = 4
    context_dim: int = 1
    hidden: int = 100
    diff_hidden: int = 16
    enc_hidden: int = 100
    sigma_floor: float = 1e-3
    sigma_scale: float = 1.0

    @property
    def post_in(self) -> int:
        return self.latent_dim + 1 + self.context_dim

    @property
    def prior_in(self) -> int:
        return self.latent_dim + 1


def _linear_size(i, o):
    return i * o + o


@dataclass(frozen=True)
class Layout:
    """Offsets of each module inside the flat parameter vector."""

    cfg: LatentConfig
    prior: int
    post: int
    diff: int
    dec: int
    enc: int
    q_head: int
    pz0_mean: int
    pz0_logvar: int
    total: int


def layout(cfg: LatentConfig) -> Layout:
    """Compute module offsets, mirroring Rust ``ParamBuilder`` order."""
    dz, dx, dc = cfg.latent_dim, cfg.obs_dim, cfg.context_dim
    off = 0
    prior = off
    off += _linear_size(dz + 1, cfg.hidden) + _linear_size(cfg.hidden, dz)
    post = off
    off += _linear_size(dz + 1 + dc, cfg.hidden) + _linear_size(cfg.hidden, dz)
    diff = off
    off += dz * (_linear_size(1, cfg.diff_hidden) + _linear_size(cfg.diff_hidden, 1))
    dec = off
    off += _linear_size(dz, cfg.hidden) + _linear_size(cfg.hidden, dx)
    enc = off
    # GRU cell: 3 input-side (dx→H) + 3 hidden-side (H→H) linears, then the
    # ctx head (H→dc).
    hd = cfg.enc_hidden
    off += 3 * _linear_size(dx, hd) + 3 * _linear_size(hd, hd) + _linear_size(hd, dc)
    q_head = off
    off += _linear_size(hd, 2 * dz)
    pz0_mean = off
    off += dz
    pz0_logvar = off
    off += dz
    return Layout(cfg, prior, post, diff, dec, enc, q_head, pz0_mean, pz0_logvar, off)


def _unpack_linear(flat, off, i, o):
    """Rust Linear stores W row-major (o, i) then bias (o,). Returns
    (W_in_major (i, o), b) ready for ``x @ W + b``."""
    w = flat[off : off + i * o].reshape(o, i).T
    b = flat[off + i * o : off + i * o + o]
    return w, b


def _unpack_mlp(flat, off, sizes):
    """Unpack consecutive Linear layers of an MLP with the given sizes."""
    out = []
    for i, o in zip(sizes[:-1], sizes[1:]):
        out.append(_unpack_linear(flat, off, i, o))
        off += _linear_size(i, o)
    return out, off


def post_drift_fwd(cfg: LatentConfig, params, zin):
    """Posterior drift ``h_φ`` for a batch of ``[z, t, ctx]`` rows.

    Args:
      params: flat ``(P,)`` parameter vector.
      zin: ``(B, dz+1+dc)``.

    Returns:
      ``(B, dz)`` drift, via the fused Pallas MLP kernel.
    """
    lay = layout(cfg)
    w1, b1 = _unpack_linear(params, lay.post, cfg.post_in, cfg.hidden)
    w2, b2 = _unpack_linear(
        params, lay.post + _linear_size(cfg.post_in, cfg.hidden), cfg.hidden, cfg.latent_dim
    )
    return fused_mlp(zin, w1, b1, w2, b2, hidden_act="softplus", out_act="none")


def prior_drift_fwd(cfg: LatentConfig, params, zin):
    """Prior drift ``h_θ`` for a batch of ``[z, t]`` rows → ``(B, dz)``."""
    lay = layout(cfg)
    w1, b1 = _unpack_linear(params, lay.prior, cfg.prior_in, cfg.hidden)
    w2, b2 = _unpack_linear(
        params, lay.prior + _linear_size(cfg.prior_in, cfg.hidden), cfg.hidden, cfg.latent_dim
    )
    return fused_mlp(zin, w1, b1, w2, b2, hidden_act="softplus", out_act="none")


def decoder_fwd(cfg: LatentConfig, params, z):
    """Decoder ``z → x̂`` for a batch → ``(B, dx)``."""
    lay = layout(cfg)
    w1, b1 = _unpack_linear(params, lay.dec, cfg.latent_dim, cfg.hidden)
    w2, b2 = _unpack_linear(
        params, lay.dec + _linear_size(cfg.latent_dim, cfg.hidden), cfg.hidden, cfg.obs_dim
    )
    return fused_mlp(z, w1, b1, w2, b2, hidden_act="softplus", out_act="none")


def diffusion_fwd(cfg: LatentConfig, params, z):
    """Per-dimension diffusion ``σ_i = floor + scale·sigmoid(net_i(z_i))``.

    The dz tiny nets are evaluated as one batched einsum (they are too
    small to tile individually).

    Args:
      z: ``(B, dz)``.

    Returns:
      ``(B, dz)`` positive diffusion values.
    """
    lay = layout(cfg)
    dz, dh = cfg.latent_dim, cfg.diff_hidden
    per = _linear_size(1, dh) + _linear_size(dh, 1)
    w1s, b1s, w2s, b2s = [], [], [], []
    for i in range(dz):
        off = lay.diff + i * per
        w1, b1 = _unpack_linear(params, off, 1, dh)  # (1, dh), (dh,)
        w2, b2 = _unpack_linear(params, off + _linear_size(1, dh), dh, 1)  # (dh,1),(1,)
        w1s.append(w1[0])
        b1s.append(b1)
        w2s.append(w2[:, 0])
        b2s.append(b2[0])
    w1s = jnp.stack(w1s)  # (dz, dh)
    b1s = jnp.stack(b1s)  # (dz, dh)
    w2s = jnp.stack(w2s)  # (dz, dh)
    b2s = jnp.stack(b2s)  # (dz,)
    # h[b,i,k] = softplus(z[b,i]·w1s[i,k] + b1s[i,k])
    h = jax.nn.softplus(z[:, :, None] * w1s[None] + b1s[None])
    pre = jnp.einsum("bik,ik->bi", h, w2s) + b2s[None]
    return cfg.sigma_floor + cfg.sigma_scale * jax.nn.sigmoid(pre)


def elbo_drift(cfg: LatentConfig, params, z, t, ctx):
    """Posterior drift, diffusion and ``|u|²`` for a batch (§5).

    Returns ``(h_φ (B,dz), σ (B,dz), |u|² (B,))``.
    """
    b = z.shape[0]
    tcol = jnp.full((b, 1), t, jnp.float32)
    zin_post = jnp.concatenate([z, tcol, ctx], axis=1)
    zin_prior = jnp.concatenate([z, tcol], axis=1)
    h_post = post_drift_fwd(cfg, params, zin_post)
    h_prior = prior_drift_fwd(cfg, params, zin_prior)
    sigma = diffusion_fwd(cfg, params, z)
    u = (h_post - h_prior) / sigma
    return h_post, sigma, jnp.sum(u * u, axis=1)


def elbo_euler_step(cfg: LatentConfig, params, z, l, t, dt, ctx, dw):
    """One fused Euler–Maruyama step of the KL-augmented posterior for a
    batch of trajectories — the training hot-step artifact.

    Args:
      z: ``(B, dz)``; l: ``(B,)``; t, dt: scalars; ctx: ``(B, dc)``;
      dw: ``(B, dz)`` Brownian increments.

    Returns:
      ``(z', l')``.
    """
    h_post, sigma, u2 = elbo_drift(cfg, params, z, t, ctx)
    return euler_logqp_step(z, h_post, sigma, dw, u2, l, dt)


def n_params(cfg: LatentConfig) -> int:
    """Total flat parameter count (must equal Rust ``model.n_params``)."""
    return layout(cfg).total
