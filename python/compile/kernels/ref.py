"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest asserts
``fused_mlp == mlp_ref`` and ``euler_logqp_step == euler_logqp_ref`` over
a hypothesis-driven sweep of shapes and activations (the CORE L1 signal).
"""

import jax
import jax.numpy as jnp

_ACTS = {
    "none": lambda x: x,
    "tanh": jnp.tanh,
    "softplus": jax.nn.softplus,
    "sigmoid": jax.nn.sigmoid,
    "relu": lambda x: jnp.maximum(x, 0.0),
}


def mlp_ref(x, w1, b1, w2, b2, *, hidden_act="softplus", out_act="none"):
    """Reference 1-hidden-layer MLP: out_act(act(x@W1+b1)@W2+b2)."""
    x = x.astype(jnp.float32)
    h = _ACTS[hidden_act](x @ w1.astype(jnp.float32) + b1.astype(jnp.float32))
    y = h @ w2.astype(jnp.float32) + b2.astype(jnp.float32)
    return _ACTS[out_act](y)


def euler_logqp_ref(z, f, g, dw, u_sq_sum, l, dt):
    """Reference fused Euler–Maruyama + running-KL update."""
    dt = jnp.asarray(dt, jnp.float32)
    z_next = z.astype(jnp.float32) + f.astype(jnp.float32) * dt + g.astype(
        jnp.float32
    ) * dw.astype(jnp.float32)
    l_next = l.astype(jnp.float32) + 0.5 * u_sq_sum.astype(jnp.float32) * dt
    return z_next, l_next
