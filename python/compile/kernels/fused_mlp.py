"""L1 Pallas kernel: fused batched MLP forward.

The hot spot of latent-SDE training is evaluating small drift MLPs for a
batch of trajectories at every solver step. This kernel fuses the whole
1-hidden-layer MLP — ``out_act(act(x @ W1 + b1) @ W2 + b2)`` — into a
single Pallas call tiled over the batch dimension:

* the batch is cut into ``block_b``-row tiles via ``BlockSpec`` (the
  HBM→VMEM schedule a CUDA implementation would express with threadblocks);
* both weight matrices live fully in VMEM for every tile (they are tiny:
  the paper's largest drift net is (dz+1+dc)×100×dz), so each tile performs
  two MXU matmuls with no re-fetch;
* bias add and both activations are fused elementwise on the tile.

TPU mapping notes (DESIGN.md §Hardware-Adaptation): on a real TPU the
natural tile is ``block_b = 128`` (MXU systolic width) with bf16 inputs and
f32 accumulation; VMEM footprint per tile is
``4·(block_b·(D+H+O) + D·H + H·O + H + O)`` bytes — ≈ 0.27 MiB for the
toy config (B=128, D=7, H=100, O=4), far under the ~16 MiB VMEM budget, so
occupancy is bounded by the grid, not memory. On this CPU image Pallas
must run with ``interpret=True`` (the CPU PJRT client cannot execute
Mosaic custom-calls), which is also what lets the lowered HLO run from the
Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {
    "none": lambda x: x,
    "tanh": jnp.tanh,
    "softplus": jax.nn.softplus,
    "sigmoid": jax.nn.sigmoid,
    "relu": lambda x: jnp.maximum(x, 0.0),
}

# Derivatives f'(pre) for the backward pass (pre-activation argument).
_ACT_GRADS = {
    "none": lambda p: jnp.ones_like(p),
    "tanh": lambda p: 1.0 - jnp.tanh(p) ** 2,
    "softplus": jax.nn.sigmoid,
    "sigmoid": lambda p: jax.nn.sigmoid(p) * (1.0 - jax.nn.sigmoid(p)),
    "relu": lambda p: (p > 0).astype(jnp.float32),
}


def _kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref, *, hidden_act, out_act):
    """One batch tile: two fused matmuls + activations."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = _ACTS[hidden_act](h)
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    o_ref[...] = _ACTS[out_act](y)


@functools.partial(
    jax.jit, static_argnames=("hidden_act", "out_act", "block_b", "interpret")
)
def fused_mlp(
    x,
    w1,
    b1,
    w2,
    b2,
    *,
    hidden_act="softplus",
    out_act="none",
    block_b=128,
    interpret=True,
):
    """Fused 1-hidden-layer MLP over a batch.

    Args:
      x: ``(B, D)`` input batch.
      w1: ``(D, H)`` first-layer weights (input-major).
      b1: ``(H,)`` bias.
      w2: ``(H, O)`` second-layer weights.
      b2: ``(O,)`` bias.
      hidden_act / out_act: names in ``{"none","tanh","softplus","sigmoid","relu"}``.
      block_b: batch tile size.
      interpret: keep True on CPU (see module docstring).

    Returns:
      ``(B, O)`` outputs, float32.
    """
    if x.ndim != 2:
        raise ValueError(f"x must be rank-2, got {x.shape}")
    b, d = x.shape
    h = w1.shape[1]
    o = w2.shape[1]
    if w1.shape[0] != d or w2.shape[0] != h or b1.shape != (h,) or b2.shape != (o,):
        raise ValueError(
            f"shape mismatch: x{x.shape} w1{w1.shape} b1{b1.shape} w2{w2.shape} b2{b2.shape}"
        )
    return _fused_mlp_ad(
        x.astype(jnp.float32),
        w1.astype(jnp.float32),
        b1.astype(jnp.float32),
        w2.astype(jnp.float32),
        b2.astype(jnp.float32),
        hidden_act,
        out_act,
        block_b,
        interpret,
    )


def _pallas_forward(x, w1, b1, w2, b2, hidden_act, out_act, block_b, interpret):
    b, d = x.shape
    h = w1.shape[1]
    o = w2.shape[1]
    block = min(block_b, b)
    grid = (pl.cdiv(b, block),)
    kernel = functools.partial(_kernel, hidden_act=hidden_act, out_act=out_act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, o), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block, o), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, o), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2)


# `pallas_call` has no reverse-mode rule, so the fused kernel carries a
# custom VJP whose backward is the analytic MLP pullback in plain jnp —
# XLA fuses it on its own, and the lowered HLO stays loadable from Rust.
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fused_mlp_ad(x, w1, b1, w2, b2, hidden_act, out_act, block_b, interpret):
    return _pallas_forward(x, w1, b1, w2, b2, hidden_act, out_act, block_b, interpret)


def _fused_mlp_fwd(x, w1, b1, w2, b2, hidden_act, out_act, block_b, interpret):
    y = _pallas_forward(x, w1, b1, w2, b2, hidden_act, out_act, block_b, interpret)
    return y, (x, w1, b1, w2, b2)


def _fused_mlp_bwd(hidden_act, out_act, block_b, interpret, res, ct):
    del block_b, interpret
    x, w1, b1, w2, b2 = res
    h_pre = x @ w1 + b1
    h = _ACTS[hidden_act](h_pre)
    y_pre = h @ w2 + b2
    g = ct * _ACT_GRADS[out_act](y_pre)
    dw2 = h.T @ g
    db2 = jnp.sum(g, axis=0)
    dh = (g @ w2.T) * _ACT_GRADS[hidden_act](h_pre)
    dw1 = x.T @ dh
    db1 = jnp.sum(dh, axis=0)
    dx = dh @ w1.T
    return dx, dw1, db1, dw2, db2


_fused_mlp_ad.defvjp(_fused_mlp_fwd, _fused_mlp_bwd)


def _step_kernel(z_ref, f_ref, g_ref, dw_ref, u2_ref, l_ref, dt_ref, zo_ref, lo_ref):
    """Fused Euler–Maruyama update tile with running-KL accumulation."""
    dt = dt_ref[0]
    zo_ref[...] = z_ref[...] + f_ref[...] * dt + g_ref[...] * dw_ref[...]
    lo_ref[...] = l_ref[...] + 0.5 * u2_ref[...] * dt


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def euler_logqp_step(z, f, g, dw, u_sq_sum, l, dt, *, block_b=128, interpret=True):
    """Fused Euler–Maruyama step of the KL-augmented latent state (§5).

    ``z' = z + f·dt + g ⊙ dw``, ``ℓ' = ℓ + ½|u|²·dt`` — one elementwise
    Pallas kernel over the batch, avoiding four separate HBM round-trips.

    Args:
      z: ``(B, dz)`` latent states.
      f: ``(B, dz)`` drift at (z, t).
      g: ``(B, dz)`` diagonal diffusion at (z, t).
      dw: ``(B, dz)`` Brownian increments.
      u_sq_sum: ``(B,)`` precomputed ``|u|²`` per batch element.
      l: ``(B,)`` running KL.
      dt: scalar array, step size.

    Returns:
      ``(z', l')``.
    """
    b, dz = z.shape
    block = min(block_b, b)
    grid = (pl.cdiv(b, block),)
    return pl.pallas_call(
        _step_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, dz), lambda i: (i, 0)),
            pl.BlockSpec((block, dz), lambda i: (i, 0)),
            pl.BlockSpec((block, dz), lambda i: (i, 0)),
            pl.BlockSpec((block, dz), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, dz), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, dz), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=interpret,
    )(
        z.astype(jnp.float32),
        f.astype(jnp.float32),
        g.astype(jnp.float32),
        dw.astype(jnp.float32),
        u_sq_sum.astype(jnp.float32),
        l.astype(jnp.float32),
        jnp.asarray(dt, jnp.float32).reshape((1,)),
    )
