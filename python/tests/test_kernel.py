"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core kernel signal: a hypothesis sweep over shapes and
activation pairs asserts the fused kernel matches ``ref.py`` to f32
tolerance, including ragged batch tiles (B not a multiple of block_b).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.fused_mlp import euler_logqp_step, fused_mlp
from compile.kernels.ref import euler_logqp_ref, mlp_ref

ACTS = ["none", "tanh", "softplus", "sigmoid", "relu"]


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 70),
    d=st.integers(1, 24),
    h=st.integers(1, 32),
    o=st.integers(1, 16),
    hidden_act=st.sampled_from(ACTS),
    out_act=st.sampled_from(ACTS),
    seed=st.integers(0, 2**16),
)
def test_fused_mlp_matches_ref(b, d, h, o, hidden_act, out_act, seed):
    x = _rand(seed, b, d)
    w1 = _rand(seed + 1, d, h) * 0.5
    b1 = _rand(seed + 2, h) * 0.1
    w2 = _rand(seed + 3, h, o) * 0.5
    b2 = _rand(seed + 4, o) * 0.1
    got = fused_mlp(x, w1, b1, w2, b2, hidden_act=hidden_act, out_act=out_act)
    want = mlp_ref(x, w1, b1, w2, b2, hidden_act=hidden_act, out_act=out_act)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_b", [1, 3, 16, 128])
def test_fused_mlp_block_sizes(block_b):
    # B=37 is deliberately not a multiple of any tile size.
    x = _rand(0, 37, 5)
    w1 = _rand(1, 5, 11)
    b1 = _rand(2, 11)
    w2 = _rand(3, 11, 4)
    b2 = _rand(4, 4)
    got = fused_mlp(x, w1, b1, w2, b2, block_b=block_b)
    want = mlp_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_mlp_rejects_bad_shapes():
    x = _rand(0, 8, 5)
    w1 = _rand(1, 6, 11)  # wrong in_dim
    b1 = _rand(2, 11)
    w2 = _rand(3, 11, 4)
    b2 = _rand(4, 4)
    with pytest.raises(ValueError):
        fused_mlp(x, w1, b1, w2, b2)


def test_fused_mlp_paper_drift_shape():
    # The paper's toy posterior drift: (dz+1+dc)=6 → 100 → 4, softplus.
    x = _rand(7, 32, 6)
    w1 = _rand(8, 6, 100)
    b1 = _rand(9, 100)
    w2 = _rand(10, 100, 4)
    b2 = _rand(11, 4)
    got = fused_mlp(x, w1, b1, w2, b2, hidden_act="softplus")
    want = mlp_ref(x, w1, b1, w2, b2, hidden_act="softplus")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 64),
    dz=st.integers(1, 8),
    seed=st.integers(0, 2**16),
    dt=st.floats(1e-4, 0.5),
)
def test_euler_step_matches_ref(b, dz, seed, dt):
    z = _rand(seed, b, dz)
    f = _rand(seed + 1, b, dz)
    g = jnp.abs(_rand(seed + 2, b, dz)) + 0.1
    dw = _rand(seed + 3, b, dz) * np.sqrt(dt)
    u2 = jnp.abs(_rand(seed + 4, b))
    l = _rand(seed + 5, b)
    zn, ln = euler_logqp_step(z, f, g, dw, u2, l, dt)
    zr, lr = euler_logqp_ref(z, f, g, dw, u2, l, dt)
    np.testing.assert_allclose(np.asarray(zn), np.asarray(zr), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ln), np.asarray(lr), rtol=1e-5, atol=1e-6)


def test_euler_step_kl_monotone():
    # ℓ accumulates ½|u|²·dt ≥ 0: l' ≥ l.
    b, dz = 16, 4
    z = _rand(0, b, dz)
    f = _rand(1, b, dz)
    g = jnp.ones((b, dz))
    dw = jnp.zeros((b, dz))
    u2 = jnp.abs(_rand(2, b))
    l = jnp.zeros(b)
    _, ln = euler_logqp_step(z, f, g, dw, u2, l, 0.1)
    assert np.all(np.asarray(ln) >= 0.0)
