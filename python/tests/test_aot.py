"""AOT lowering smoke tests: every entry point lowers to parseable HLO
text, and the manifest matches what was written."""

import os

import pytest

from compile import aot
from compile import model as M

SMALL = M.LatentConfig(
    obs_dim=1, latent_dim=2, context_dim=1, hidden=6, diff_hidden=3, enc_hidden=5
)


@pytest.mark.parametrize("name_fn_shapes", aot.entries(SMALL, batch=4), ids=lambda e: e[0])
def test_entry_lowers_to_hlo_text(name_fn_shapes):
    name, fn, shapes = name_fn_shapes
    text = aot.to_hlo_text(aot.lower_entry(fn, shapes))
    assert "ENTRY" in text, f"{name}: no ENTRY in HLO text"
    assert "HloModule" in text
    # return_tuple=True: the root is a tuple.
    assert "tuple" in text.lower()


def test_export_all_writes_files(tmp_path):
    out = str(tmp_path / "artifacts")
    lines = aot.export_all(out, SMALL, batch=4)
    assert lines[0].startswith("format=")
    entry_lines = [l for l in lines if l.startswith("entry ")]
    assert len(entry_lines) == len(aot.entries(SMALL, 4))
    for line in entry_lines:
        fname = [tok.split("=", 1)[1] for tok in line.split() if tok.startswith("file=")][0]
        path = os.path.join(out, fname)
        assert os.path.exists(path), f"missing artifact {fname}"
        assert os.path.getsize(path) > 100
    assert os.path.exists(os.path.join(out, "manifest.txt"))


def test_manifest_cfg_line_contains_dims():
    lines = aot.export_all.__wrapped__ if hasattr(aot.export_all, "__wrapped__") else None
    # Build the cfg line without writing: check the format via a tmp export
    # is covered above; here just assert n_params consistency.
    assert M.n_params(SMALL) == M.layout(SMALL).total
