"""L2 correctness: parameter layout, graph outputs vs plain-jnp references,
and VJPs vs jax autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.LatentConfig(
    obs_dim=2, latent_dim=3, context_dim=2, hidden=8, diff_hidden=4, enc_hidden=6
)


def _params(seed=0, cfg=CFG):
    n = M.n_params(cfg)
    return jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype=jnp.float32) * 0.3


def _manual_mlp(params, off, sizes, x, hidden_act, out_act):
    """Reference MLP straight from the flat layout."""
    h = x
    acts = {"softplus": jax.nn.softplus, "none": lambda v: v, "sigmoid": jax.nn.sigmoid}
    n_layers = len(sizes) - 1
    for li, (i, o) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = params[off : off + i * o].reshape(o, i).T
        b = params[off + i * o : off + i * o + o]
        off += i * o + o
        h = h @ w + b
        h = acts[out_act](h) if li == n_layers - 1 else acts[hidden_act](h)
    return h


def test_layout_total_is_consistent():
    lay = M.layout(CFG)
    assert lay.total == M.n_params(CFG)
    assert lay.prior < lay.post < lay.diff < lay.dec < lay.enc < lay.q_head
    assert lay.pz0_logvar + CFG.latent_dim == lay.total


def test_post_drift_matches_manual_unpack():
    params = _params(1)
    lay = M.layout(CFG)
    zin = jax.random.normal(jax.random.PRNGKey(2), (5, CFG.post_in), dtype=jnp.float32)
    got = M.post_drift_fwd(CFG, params, zin)
    want = _manual_mlp(
        params, lay.post, [CFG.post_in, CFG.hidden, CFG.latent_dim], zin, "softplus", "none"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_decoder_matches_manual_unpack():
    params = _params(3)
    lay = M.layout(CFG)
    z = jax.random.normal(jax.random.PRNGKey(4), (7, CFG.latent_dim), dtype=jnp.float32)
    got = M.decoder_fwd(CFG, params, z)
    want = _manual_mlp(
        params, lay.dec, [CFG.latent_dim, CFG.hidden, CFG.obs_dim], z, "softplus", "none"
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_diffusion_positive_and_bounded():
    params = _params(5)
    z = jax.random.normal(jax.random.PRNGKey(6), (9, CFG.latent_dim), dtype=jnp.float32)
    sig = np.asarray(M.diffusion_fwd(CFG, params, z))
    assert np.all(sig > 0)
    assert np.all(sig < CFG.sigma_floor + CFG.sigma_scale + 1e-6)


def test_diffusion_matches_manual_per_dim():
    params = _params(7)
    lay = M.layout(CFG)
    z = jax.random.normal(jax.random.PRNGKey(8), (4, CFG.latent_dim), dtype=jnp.float32)
    got = np.asarray(M.diffusion_fwd(CFG, params, z))
    per = (1 * CFG.diff_hidden + CFG.diff_hidden) + (CFG.diff_hidden * 1 + 1)
    for i in range(CFG.latent_dim):
        want_i = _manual_mlp(
            params,
            lay.diff + i * per,
            [1, CFG.diff_hidden, 1],
            z[:, i : i + 1],
            "softplus",
            "sigmoid",
        )
        want_i = CFG.sigma_floor + CFG.sigma_scale * np.asarray(want_i)[:, 0]
        np.testing.assert_allclose(got[:, i], want_i, rtol=1e-5, atol=1e-5)


def test_elbo_drift_u_square_definition():
    params = _params(9)
    b = 6
    z = jax.random.normal(jax.random.PRNGKey(10), (b, CFG.latent_dim), dtype=jnp.float32)
    ctx = jax.random.normal(jax.random.PRNGKey(11), (b, CFG.context_dim), dtype=jnp.float32)
    t = jnp.float32(0.4)
    h_post, sigma, u2 = M.elbo_drift(CFG, params, z, t, ctx)
    tcol = jnp.full((b, 1), t)
    h_prior = M.prior_drift_fwd(CFG, params, jnp.concatenate([z, tcol], axis=1))
    u = (np.asarray(h_post) - np.asarray(h_prior)) / np.asarray(sigma)
    np.testing.assert_allclose(np.asarray(u2), (u * u).sum(axis=1), rtol=1e-4, atol=1e-5)
    assert np.all(np.asarray(u2) >= 0)


def test_post_drift_vjp_matches_jax_grad():
    params = _params(12)
    zin = jax.random.normal(jax.random.PRNGKey(13), (4, CFG.post_in), dtype=jnp.float32)
    ct = jax.random.normal(jax.random.PRNGKey(14), (4, CFG.latent_dim), dtype=jnp.float32)

    def scalar_loss(pp, zz):
        return jnp.sum(M.post_drift_fwd(CFG, pp, zz) * ct)

    gp, gz = jax.grad(scalar_loss, argnums=(0, 1))(params, zin)
    _, pull = jax.vjp(lambda pp, zz: M.post_drift_fwd(CFG, pp, zz), params, zin)
    dp, dz = pull(ct)
    np.testing.assert_allclose(np.asarray(dp), np.asarray(gp), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(gz), rtol=1e-4, atol=1e-5)


def test_elbo_euler_step_consistency():
    params = _params(15)
    b = 5
    key = jax.random.PRNGKey(16)
    z = jax.random.normal(key, (b, CFG.latent_dim), dtype=jnp.float32)
    l = jnp.zeros(b)
    ctx = jnp.zeros((b, CFG.context_dim), dtype=jnp.float32)
    dw = jax.random.normal(jax.random.PRNGKey(17), (b, CFG.latent_dim)) * 0.1
    t, dt = jnp.float32(0.0), jnp.float32(0.05)
    zn, ln = M.elbo_euler_step(CFG, params, z, l, t, dt, ctx, dw)
    h_post, sigma, u2 = M.elbo_drift(CFG, params, z, t, ctx)
    want_z = np.asarray(z) + np.asarray(h_post) * 0.05 + np.asarray(sigma) * np.asarray(dw)
    want_l = 0.5 * np.asarray(u2) * 0.05
    np.testing.assert_allclose(np.asarray(zn), want_z, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ln), want_l, rtol=1e-4, atol=1e-6)


@pytest.mark.parametrize("batch", [1, 32])
def test_shapes_all_entries(batch):
    params = _params(18)
    dz, dc, dx = CFG.latent_dim, CFG.context_dim, CFG.obs_dim
    zin = jnp.zeros((batch, CFG.post_in))
    assert M.post_drift_fwd(CFG, params, zin).shape == (batch, dz)
    assert M.prior_drift_fwd(CFG, params, jnp.zeros((batch, dz + 1))).shape == (batch, dz)
    assert M.decoder_fwd(CFG, params, jnp.zeros((batch, dz))).shape == (batch, dx)
    assert M.diffusion_fwd(CFG, params, jnp.zeros((batch, dz))).shape == (batch, dz)
