//! Bench: regenerate Table 2 (future-frame test MSE on synthetic mocap:
//! latent SDE vs latent ODE vs constant baselines). Training-heavy: quick
//! by default; SDEGRAD_FULL=1 for the paper-scale protocol.
fn main() {
    let full = std::env::var("SDEGRAD_FULL").is_ok();
    sdegrad::coordinator::repro::table2::run(!full);
}
