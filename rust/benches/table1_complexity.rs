//! Bench: regenerate Table 1 (gradient-method complexity sweep).
//! Full sweep by default; set SDEGRAD_QUICK=1 for the short version.
fn main() {
    let quick = std::env::var("SDEGRAD_QUICK").is_ok();
    sdegrad::coordinator::repro::table1::run(quick);
}
