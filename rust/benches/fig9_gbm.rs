//! Bench: regenerate Figure 9 (latent SDE on geometric Brownian motion).
//! Training-heavy: quick by default; SDEGRAD_FULL=1 for paper scale.
fn main() {
    let full = std::env::var("SDEGRAD_FULL").is_ok();
    sdegrad::coordinator::repro::latent_figs::run_gbm(!full);
}
