//! Bench: regenerate the convergence-order verification table (empirical
//! strong/weak/gradient orders vs analytic oracles, with bootstrap CIs).
fn main() {
    let quick = std::env::var("SDEGRAD_QUICK").is_ok();
    sdegrad::coordinator::repro::convergence::run(quick);
}
