//! Bench: regenerate Figures 6/8 (latent SDE on the stochastic Lorenz
//! attractor). Training-heavy: quick by default; SDEGRAD_FULL=1 for the
//! paper-scale run.
fn main() {
    let full = std::env::var("SDEGRAD_FULL").is_ok();
    sdegrad::coordinator::repro::latent_figs::run_lorenz(!full);
}
