//! Bench: regenerate Figures 5 and 7 (numerical studies, Examples 1–3:
//! error vs step size, MSE vs NFE under adaptive stepping, time vs error).
fn main() {
    let quick = std::env::var("SDEGRAD_QUICK").is_ok();
    sdegrad::coordinator::repro::fig5::run(quick);
}
