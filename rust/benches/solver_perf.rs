//! Perf bench: solver-step throughput across schemes, plus the latent-SDE
//! drift-evaluation hot path — pure-Rust NN vs the AOT-compiled XLA
//! artifact (batched) when artifacts are present.

use sdegrad::api::{solve_batch, solve_batch_per_path, SdeProblem, SolveOptions};
use sdegrad::latent::{LatentSdeConfig, LatentSdeModel, PosteriorSde};
use sdegrad::metrics::timer::bench;
use sdegrad::metrics::CsvWriter;
use sdegrad::metrics::Stopwatch;
use sdegrad::prng::PrngKey;
use sdegrad::sde::problems::{sample_experiment_setup, Example1};
use sdegrad::sde::{ReplicatedSde, Sde};
use sdegrad::solvers::Method;

fn main() {
    println!("=== Solver & drift-eval throughput ======================================");
    let mut csv = CsvWriter::create(
        "bench_out/solver_perf.csv",
        &["bench", "variant", "value_us"],
    )
    .expect("csv");

    // 1. Scheme throughput on the 10-d replicated GBM.
    let dim = 10;
    let sde = ReplicatedSde::new(Example1, dim);
    let key = PrngKey::from_seed(3);
    let (theta, x0) = sample_experiment_setup(key, dim, 2);
    let n_steps = 1000;
    let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    println!("{:<26} {:>14}", "scheme (1000 steps, d=10)", "µs/solve");
    for method in [Method::EulerMaruyama, Method::MilsteinIto, Method::Heun] {
        let mut run = 0u64;
        let stats = bench(3, 30, || {
            run += 1;
            let sol = prob
                .clone()
                .key(key.fold_in(run))
                .solve(&SolveOptions::fixed(method, n_steps));
            sol.final_state()[0]
        });
        let us = stats.mean() * 1e6;
        println!("{:<26} {:>14.1}", method.name(), us);
        csv.row(&["scheme_solve".into(), method.name().into(), format!("{us}")]).ok();
    }

    // 2. Latent drift evaluation: Rust NN per-row vs XLA artifact batched.
    let artifacts_ok = std::path::Path::new("artifacts/manifest.txt").exists();
    if artifacts_ok {
        let mut reg = sdegrad::runtime::ArtifactRegistry::open("artifacts").expect("registry");
        let m = &reg.manifest;
        let cfg = LatentSdeConfig {
            obs_dim: m.cfg_usize("obs_dim").unwrap(),
            latent_dim: m.cfg_usize("latent_dim").unwrap(),
            context_dim: m.cfg_usize("context_dim").unwrap(),
            hidden: m.cfg_usize("hidden").unwrap(),
            diff_hidden: m.cfg_usize("diff_hidden").unwrap(),
            enc_hidden: m.cfg_usize("enc_hidden").unwrap(),
            ..Default::default()
        };
        let batch = m.cfg_usize("batch").unwrap();
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(4));
        let params_f32: Vec<f32> = params.iter().map(|&v| v as f32).collect();
        let d_in = cfg.latent_dim + 1 + cfg.context_dim;
        let mut zin = vec![0.0f64; batch * d_in];
        PrngKey::from_seed(5).fill_normal(0, &mut zin);
        let zin_f32: Vec<f32> = zin.iter().map(|&v| v as f32).collect();

        let exe = reg.get("post_drift_fwd").expect("compile");
        let s_xla = bench(5, 50, || exe.call_f32(&[&params_f32, &zin_f32]).unwrap()[0][0] as f64);
        let mut cache = model.post_drift.cache();
        let mut sink = vec![0.0f64; cfg.latent_dim];
        let s_rust = bench(5, 50, || {
            let mut acc = 0.0;
            for b in 0..batch {
                model.post_drift.forward(&params, &zin[b * d_in..(b + 1) * d_in], &mut cache, &mut sink);
                acc += sink[0];
            }
            acc
        });
        let (xla_us, rust_us) = (s_xla.mean() * 1e6, s_rust.mean() * 1e6);
        println!("\ndrift eval, batch {batch} (hidden {}):", cfg.hidden);
        println!("  XLA artifact (PJRT):  {xla_us:>10.1} µs/batch");
        println!("  Rust NN (per row):    {rust_us:>10.1} µs/batch");
        csv.row(&["drift_eval".into(), "xla_batched".into(), format!("{xla_us}")]).ok();
        csv.row(&["drift_eval".into(), "rust_nn".into(), format!("{rust_us}")]).ok();
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the XLA comparison)");
    }

    // 3. Full augmented posterior step cost (the latent training hot loop).
    let model = LatentSdeModel::new(LatentSdeConfig {
        obs_dim: 3,
        latent_dim: 4,
        context_dim: 1,
        hidden: 100,
        diff_hidden: 16,
        enc_hidden: 100,
        ..Default::default()
    });
    let params = model.init_params(PrngKey::from_seed(6));
    let post = PosteriorSde::new(&model);
    let mut theta_full = params[..post.sde_param_len()].to_vec();
    theta_full.push(0.3); // ctx
    let aug = post.state_dim();
    let y0 = vec![0.1; aug];
    let post_prob = SdeProblem::new(&post, &y0, (0.0, 0.1)).params(&theta_full);
    let mut run = 0u64;
    let stats = bench(3, 30, || {
        run += 1;
        let sol = post_prob
            .clone()
            .key(PrngKey::from_seed(100 + run))
            .solve(&SolveOptions::fixed(Method::Heun, 50));
        sol.final_state()[0]
    });
    let per_step_us = stats.mean() * 1e6 / 50.0;
    println!("\nlatent posterior Heun step (dz=4, hidden=100): {per_step_us:.2} µs/step");
    csv.row(&["latent_step".into(), "heun_hidden100".into(), format!("{per_step_us}")]).ok();

    // 4. Multi-path throughput: solve_batch chunks N independent
    // replicates across threads and runs the batched SoA kernel per chunk.
    // Compare against the pre-0.3 thread-per-path engine and a sequential
    // loop — all three must agree bit-for-bit (only throughput differs).
    // The dedicated sweep lives in `sdegrad bench throughput`.
    let n_paths = 64;
    let batch_prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta);
    let opts = SolveOptions::fixed(Method::MilsteinIto, n_steps);
    let root = PrngKey::from_seed(77);
    // Warm-up + measure.
    let replicates = batch_prob.replicates(root, n_paths);
    let _ = solve_batch(&replicates, &opts);
    let sw = Stopwatch::new();
    let sols = solve_batch(&replicates, &opts);
    let t_batch = sw.elapsed_s();
    let sw = Stopwatch::new();
    let per_path = solve_batch_per_path(&replicates, &opts);
    let t_per_path = sw.elapsed_s();
    let sw = Stopwatch::new();
    let seq: Vec<_> = replicates.iter().map(|pr| pr.solve(&opts)).collect();
    let t_seq = sw.elapsed_s();
    assert_eq!(sols.len(), seq.len());
    for ((a, b), c) in sols.iter().zip(&per_path).zip(&seq) {
        assert_eq!(a.states, b.states, "batched engine diverged from per-path engine");
        assert_eq!(a.states, c.states, "solve_batch diverged from sequential");
    }
    println!(
        "\nsolve_batch: {n_paths} paths × {n_steps} steps — batched {:.1} ms vs \
         per-path {:.1} ms vs sequential {:.1} ms ({:.1}x vs seq)",
        t_batch * 1e3,
        t_per_path * 1e3,
        t_seq * 1e3,
        t_seq / t_batch.max(1e-12)
    );
    csv.row(&["solve_batch".into(), "batched_ms".into(), format!("{}", t_batch * 1e3)]).ok();
    csv.row(&["solve_batch".into(), "per_path_ms".into(), format!("{}", t_per_path * 1e3)])
        .ok();
    csv.row(&["solve_batch".into(), "sequential_ms".into(), format!("{}", t_seq * 1e3)]).ok();
    csv.flush().ok();
    println!("(CSV: bench_out/solver_perf.csv)");
}
