//! Perf bench: Brownian noise sources (paper §4's cost model).
//!
//! * virtual-tree query cost vs tolerance — should grow ~log(1/ε);
//! * stored-path query cost vs number of cached points — ~log n;
//! * memory footprints side by side;
//! * end-to-end: a fixed-grid solve driven by each source.

use sdegrad::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
use sdegrad::metrics::timer::bench;
use sdegrad::metrics::CsvWriter;
use sdegrad::prng::PrngKey;

fn main() {
    println!("=== Brownian source microbenchmarks =====================================");
    let dim = 4;
    let key = PrngKey::from_seed(1);
    let mut csv = CsvWriter::create(
        "bench_out/brownian_perf.csv",
        &["source", "param", "ns_per_query", "memory_floats"],
    )
    .expect("csv");

    println!("{:<18} {:>12} {:>16} {:>14}", "source", "ε / points", "ns/query", "mem (floats)");
    for &tol in &[1e-3, 1e-6, 1e-9, 1e-12] {
        let mut tree = VirtualBrownianTree::new(key, dim, 0.0, 1.0, tol);
        let mut out = vec![0.0; dim];
        let mut q = 0u64;
        let stats = bench(50, 2000, || {
            // Query pseudo-random times so every call walks the tree.
            let t = ((q as f64 * 0.618_033_988_749_894_8) % 1.0).max(1e-9);
            q += 1;
            tree.sample_into(t, &mut out);
            out[0]
        });
        let ns = stats.mean() * 1e9;
        println!("{:<18} {:>12.0e} {:>16.0} {:>14}", "virtual_tree", tol, ns, tree.memory_footprint());
        csv.row(&[
            "virtual_tree".into(),
            format!("{tol}"),
            format!("{ns}"),
            tree.memory_footprint().to_string(),
        ])
        .ok();
    }

    for &points in &[100usize, 1000, 10000, 100000] {
        let mut path = BrownianPath::new(key, dim, 0.0, 1.0);
        // Pre-populate the cache.
        let mut out = vec![0.0; dim];
        for i in 0..points {
            path.sample_into((i + 1) as f64 / (points + 1) as f64, &mut out);
        }
        let mut q = 0u64;
        let stats = bench(50, 2000, || {
            let t = ((q as f64 * 0.618_033_988_749_894_8) % 1.0).max(1e-9);
            q += 1;
            path.sample_into(t, &mut out);
            out[0]
        });
        let ns = stats.mean() * 1e9;
        println!("{:<18} {:>12} {:>16.0} {:>14}", "stored_path", points, ns, path.memory_footprint());
        csv.row(&[
            "stored_path".into(),
            points.to_string(),
            format!("{ns}"),
            path.memory_footprint().to_string(),
        ])
        .ok();
    }
    csv.flush().ok();
    println!("(CSV: bench_out/brownian_perf.csv)");
}
