//! Bench: regenerate Figure 2 (Itô vs Stratonovich backward reconstruction).
fn main() {
    let quick = std::env::var("SDEGRAD_QUICK").is_ok();
    sdegrad::coordinator::repro::fig2::run(quick);
}
