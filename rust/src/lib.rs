//! # sdegrad
//!
//! A Rust + JAX/Pallas reproduction of **"Scalable Gradients for Stochastic
//! Differential Equations"** (Li, Wong, Chen, Duvenaud — AISTATS 2020):
//! the stochastic adjoint sensitivity method, the virtual Brownian tree,
//! and gradient-based variational inference for latent SDEs, packaged as a
//! trainable framework with a coordinator, data pipeline, and benchmark
//! harness for every table and figure in the paper.
//!
//! ## The API: problem → solve → sensitivity
//!
//! Everything goes through [`api::SdeProblem`] — define *what* once, then
//! choose *how* per call:
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! // 10-d replicated geometric Brownian motion (§7.1).
//! let sde = ReplicatedSde::new(Example1, 10);
//! let (theta, z0) = (vec![0.5; 20], vec![1.0; 10]);
//!
//! let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
//!     .params(&theta)
//!     .key(PrngKey::from_seed(7));
//!
//! // Forward solve with any scheme / step control / save spec...
//! let sol = prob.solve(&SolveOptions::fixed(Method::MilsteinIto, 1000));
//! println!("z_T = {:?}", sol.final_state());
//!
//! // ...and gradients with any estimator, at the same Brownian path.
//! let g = prob
//!     .sensitivity_sum(
//!         &SensAlg::StochasticAdjoint(AdjointConfig::default()),
//!         StepControl::Steps(1000),
//!     )
//!     .unwrap();
//! println!("∂L/∂θ = {:?}", g.dtheta);
//! ```
//!
//! Swap `SensAlg::StochasticAdjoint(..)` for `SensAlg::backprop(..)`,
//! `SensAlg::ForwardPathwise`, or `SensAlg::Antithetic { .. }` to change
//! the estimator; set `.noise(NoiseSpec::VirtualTree { tol })` for the
//! paper's O(1)-memory noise source — every estimator, taped ones
//! included, honors the spec. (The pre-0.2 deprecated free functions
//! were removed in 0.3; CHANGES.md has the migration table.)
//!
//! ## Constant-memory gradients: checkpointed backprop
//!
//! The backprop-through-the-solver baseline no longer has to hold the
//! whole trajectory: [`adjoint::Checkpointing`] picks a recursive
//! checkpoint schedule (`Tape` | `Sqrt` | `Log` |
//! `Budget { max_live_steps }`) and the backward pass re-integrates one
//! segment at a time from stored checkpoints, replaying the *same*
//! noise (a stored path caches its queried times; the virtual tree is a
//! pure function of `(key, t)`). Gradients are **exact-f64-identical**
//! to the full tape for every schedule — only memory and recompute move
//! (n = solver steps, per path):
//!
//! | schedule | peak live memory | backward-pass recompute |
//! |---|---|---|
//! | `Tape` | O(n) | none |
//! | `Sqrt` | O(√n) | ≈ 1 extra forward pass |
//! | `Log` | O(log n) | O(n log n) coefficient evals |
//! | `Budget { max_live_steps: m }` | ≤ ≈ m steps' worth | cheapest plan that fits m |
//!
//! Reach for checkpointed backprop when you want *the
//! backprop-through-the-solver estimator exactly* (its variance
//! properties, or a pin against the tape) at horizons the tape cannot
//! hold; the stochastic adjoint remains the O(1)-memory choice when a
//! different (continuous-adjoint) estimator is acceptable.
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! let sde = ReplicatedSde::new(Example1, 10);
//! let prob = SdeProblem::new(&sde, &vec![1.0; 10], (0.0, 1.0))
//!     .params(&vec![0.5; 20])
//!     .noise(NoiseSpec::VirtualTree { tol: 1e-8 });
//! // 10⁵ solver steps under a ~100-live-step cap — far beyond what the
//! // full tape could hold at this horizon-per-byte budget.
//! let g = prob
//!     .sensitivity_sum(
//!         &SensAlg::Backprop {
//!             method: Method::MilsteinIto,
//!             checkpointing: Checkpointing::Budget { max_live_steps: 100 },
//!         },
//!         StepControl::Steps(100_000),
//!     )
//!     .unwrap();
//! // Observability: peak live tape bytes + recompute cost of the plan.
//! println!("peak {} B, recompute {} NFE",
//!     g.stats.peak_tape_bytes, g.stats.recompute_nfe);
//! ```
//!
//! `tests/checkpoint_backprop.rs` pins the bit-identical claim across
//! schemes (Euler–Maruyama / Milstein / Heun), schedules, noise specs,
//! and batch layouts, plus the O(√n) memory-scaling ladder.
//!
//! ## Batched Monte Carlo: the SoA execution engine
//!
//! Multi-path workloads go through [`api::solve_batch`] /
//! [`api::sensitivity_batch`], which run on a **batched
//! structure-of-arrays engine**: the batch is chunked across the
//! persistent work-stealing pool ([`runtime::scoped_map`]) and each
//! chunk's paths advance *together* through batched
//! solver steps, batched Brownian sampling
//! ([`brownian::BatchBrownian::fill_increments`]), and a batched
//! augmented adjoint — over contiguous `[B×d]` buffers with zero heap
//! allocation per step. For `nn`-backed SDEs the per-step MLP passes
//! become blocked matrix–matrix products ([`nn::Mlp::forward_batch`]).
//! Results are bit-identical to per-path sequential execution for any
//! batch size and thread count (`tests/batch_engine.rs`), and
//! `sdegrad bench throughput` measures the speedup (paths/sec and
//! grad-paths/sec, scalar vs batched engine → `BENCH_throughput.json`).
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! let sde = ReplicatedSde::new(Example1, 10);
//! let prob = SdeProblem::new(&sde, &vec![1.0; 10], (0.0, 1.0))
//!     .params(&vec![0.5; 20]);
//! // 4096 paths, batched per chunk across threads, one call:
//! let sols = solve_batch(
//!     &prob.replicates(PrngKey::from_seed(7), 4096),
//!     &SolveOptions::fixed(Method::MilsteinIto, 1000),
//! );
//! let mean: f64 =
//!     sols.iter().map(|s| s.final_state()[0]).sum::<f64>() / sols.len() as f64;
//! # let _ = mean;
//! ```
//!
//! Custom systems opt in with one line each — `impl BatchSde for MySde {}`
//! (and `impl BatchSdeVjp for MySde {}` for gradients) — inheriting
//! loop-based batch kernels that can be overridden with hand-batched ones
//! where structure allows (see [`sde::batch`]).
//!
//! ## Execution model: one pool, one knob, zero bit drift
//!
//! All CPU fan-out in the crate — batched solves and gradients, the
//! minibatch ELBO engine, serving's worker sizing — runs on **one
//! process-wide persistent work-stealing pool** ([`runtime::pool`]).
//! Workers spawn lazily up to the configured width, park between calls,
//! and are *reused* across calls: steady-state training pays zero thread
//! spawns per iteration (`sdegrad bench throughput` reports the per-call
//! dispatch overhead in its `executor` row). The caller participates in
//! its own job, so nested fan-outs cannot deadlock. Scheduling decides
//! only *who* computes each chunk, never *what*: task `i` always computes
//! result `i`, so results are bit-identical for any pool width and any
//! steal interleaving (`tests/executor.rs`). Task panics are contained:
//! a panicking closure cannot kill a worker or hang the caller — the
//! payload is re-thrown on the calling thread once the job has fully
//! retired, and the pool keeps serving (see "Panic containment" in
//! [`runtime::pool`]).
//!
//! ### Execution config
//!
//! [`runtime::ExecConfig`] is the one value that carries the execution
//! knobs — `tier` (kernel tier), `threads` (worker-count override), and
//! `tree_cache` (Brownian-tree node-cache capacity) — through every
//! layer: [`api::SolveOptions`]`::exec`, [`latent::ElboConfig`]`::exec`,
//! the trainer's `TrainConfig::exec`, and serving's
//! `BatcherConfig`/`ServeConfig::exec`. The worker count keeps **one
//! precedence** everywhere: an explicit `ExecConfig::threads` (the
//! `--workers`/`--threads N` flags) > `SDEGRAD_THREADS` env var >
//! `std::thread::available_parallelism` — programmatically,
//! [`runtime::set_worker_count`] / [`runtime::worker_count`]. The pre-0.2
//! per-struct fields and `_tier` entry points
//! ([`api::sensitivity_batch_tier`] and friends) remain one release as
//! `#[deprecated]` delegating shims, pinned bit-identical to the base
//! names in `tests/exec_config.rs`.
//!
//! Two allocation-recycling layers ride on the same hot path, both
//! observationally identical to fresh allocation (leases re-zero before
//! handout): a per-thread buffer arena ([`runtime::arena`]) for `[B×d]`
//! state staging, and a per-thread [`solvers::batch::Workspace`] pool.
//! The virtual Brownian tree adds a bounded **ancestor-node cache**
//! (`SdeProblem::tree_cache(capacity)`, default on): monotone sweeps
//! resume descent from the deepest cached ancestor instead of the root,
//! amortizing bridge draws to O(1) per step on dyadic grids — with
//! *bit-identical* draws for every capacity, since a cached node stores
//! exactly what a fresh root descent would recompute.
//!
//! ## Kernel tiers: exact (default) vs fast
//!
//! The batched engine has two kernel tiers, selected per call by
//! [`sde::KernelTier`]:
//!
//! * **`Exact`** (the default everywhere) keeps the bit-identical-to-
//!   scalar contract above — every float op in the same order as the
//!   per-path engine. This is the oracle tier; nothing about it changed
//!   when the fast tier was added (`tests/fast_tier.rs` pins this).
//! * **`Fast`** is an opt-in throughput tier: fused drift+diffusion
//!   steps, flat elementwise kernels for structured systems
//!   ([`sde::ReplicatedSde`], [`sde::ou::OrnsteinUhlenbeck`]), and
//!   blocked, reassociation-friendly matrix–matrix kernels for the
//!   `nn` forward/VJP passes. It trades the bit-identity contract for
//!   speed and is instead validated against the exact tier to tight
//!   *relative tolerance* on solves, adjoint gradients, and ELBO steps
//!   (`tests/fast_tier.rs`; `bench throughput` re-validates to
//!   [`coordinator::bench::FAST_RTOL`] before timing any fast row).
//!
//! Select it with an [`runtime::ExecConfig`] — e.g.
//! `SolveOptions::fixed(..).tier(KernelTier::Fast)` (shorthand for
//! `exec.tier`), `ElboConfig::default().tier(..)`, or `--tier fast` on
//! the `train` / `serve` / `bench serve` CLIs. The
//! serving byte-determinism contract is *per tier*: the batcher and its
//! scalar oracle run the same tier, so batching with strangers still
//! cannot change your answer — but `--tier fast` bytes are not `--tier
//! exact` bytes. `sdegrad bench throughput` reports paired exact/fast
//! rows (`gbm_d10` vs `gbm_d10_fast`, …) so the speedup is a measured
//! number, not a promise.
//!
//! ## Latent-SDE training on the batch engine
//!
//! The headline application (§6): gradient-based stochastic variational
//! inference for latent SDEs. [`coordinator::train_latent_sde`] runs
//! minibatch Adam where each iteration's M sequences × S posterior
//! samples form **one batched ELBO-gradient call**
//! ([`latent::elbo_step_batch`]): a batched encoder pass
//! ([`nn::GruCell::forward_batch`] / [`nn::Mlp::forward_batch`]), one
//! batched piecewise forward solve per chunk with each path's encoder
//! context riding in its parameter tail, the batched augmented stochastic
//! adjoint ([`adjoint::batch`]), and batched encoder/decoder backprop —
//! chunks fanned across the persistent work-stealing pool. Per-path keys are
//! `key.fold_in(sequence).fold_in(sample)` and gradients reduce in path
//! order, so results are bit-identical to a sequential scalar
//! [`latent::elbo_step`] loop for any batch size, chunk layout, and
//! worker count (`tests/trainer_batch.rs`); the scalar path remains as
//! that oracle. Training resumes exactly from a
//! [`coordinator::TrainState`] checkpoint (params + Adam moments +
//! counters; `sdegrad train --state/--resume`), and CI gates both the
//! trainer (`training-smoke` job: loss must decrease) and the engine's
//! throughput (`sdegrad bench compare` vs the committed
//! `BENCH_baseline.json`, >25% regression fails).
//!
//! ## Serving a trained latent SDE
//!
//! `sdegrad serve --state ckpt.bin --dataset gbm --port 7878` turns a
//! checkpoint (either format: bare params or full `TrainState`) into an
//! HTTP inference service ([`serve`]) with **dynamic micro-batching onto
//! the batched SoA engine**, scaled horizontally across `--shards N`
//! dispatcher shards: a rendezvous hash of (model fingerprint, endpoint)
//! routes each request to its home shard ([`serve::Router`]) — the
//! routing key is coarser than the batching-compatibility key, so
//! sharding never splits a groupable batch — and each shard's dispatcher
//! drains its own bounded queue (up to `--max-batch`, waiting at most
//! `--max-wait-us`) and runs each compatible group as ONE batched engine
//! call.
//!
//! | endpoint | engine call | answer |
//! |---|---|---|
//! | `GET /healthz` | — | loaded models + fingerprints |
//! | `GET /metrics` | — | per-shard queue depth, batch-occupancy histogram, shed/cache/engine counters |
//! | `POST /v1/simulate` | [`latent::sample_prior_paths_batch`] prior fleet | prior latent path + decoded obs |
//! | `POST /v1/reconstruct` | batched encoder + posterior solve + decoder | posterior path + reconstruction |
//! | `POST /v1/elbo` | [`latent::elbo_value_multi_batch`] | S-sample ELBO estimate |
//!
//! **Admission control:** each shard's queue carries a cell budget
//! (`--queue-cells`); a request that would push a non-empty queue over
//! budget is shed immediately with `429` (`overloaded`, `Retry-After`)
//! instead of queuing unboundedly. Long `/v1/simulate` responses past
//! `--stream-threshold` bytes stream back `Transfer-Encoding: chunked` —
//! framing is transport, never content.
//!
//! **Determinism contract:** every request carries a `seed`, and every
//! 200 response body is a pure function of (canonical request, model
//! fingerprint) — bit-identical to a per-request scalar engine call for
//! any arrival order, batch layout (`--max-batch` 1 vs 16), worker
//! count, **shard count (1/2/4)**, queue state, response framing, and
//! cache state (`tests/serve.rs`). Shedding changes *which* requests get
//! a 429, never a success byte. This is the serving-side payoff of the
//! engine's bit-identical-batching guarantee: batching with strangers
//! cannot change your answer. Knobs: `--workers` (HTTP threads),
//! `--shards` (dispatcher shards), `--max-batch`/`--max-wait-us`
//! (batcher), `--queue-cells` (admission budget), `--stream-threshold`
//! (chunked streaming), `--cache` (LRU entries, keyed on fingerprint +
//! canonical request bytes; 0 disables), `--bind` (loopback-only by
//! default — pass `0.0.0.0` to expose). `sdegrad bench serve`
//! load-tests a synthetic model in-process: closed-loop req/sec +
//! p50/p99 per endpoint, then an open-loop traffic simulator with
//! heavy-tail request sizes, bursty arrivals, and a deliberate overload
//! episode (`serve_p99_ms` + `shed_rate`, gated lower-is-better by
//! `sdegrad bench compare`) → `BENCH_serve.json`.
//!
//! ## Observability: spans, metrics registry, Chrome-trace export
//!
//! Every hot layer is instrumented through the std-only [`obs`]
//! subsystem — the solver step loops, the checkpointed adjoint's
//! forward/replay/backward segments, the ELBO phases
//! (encode / posterior solve / decode / backward / encoder BPTT), the
//! trainer's per-iteration phase breakdown, the work-stealing pool's
//! dispatch/steal/park events, and the serve request lifecycle
//! (parse → queue wait → batch assembly → engine call → serialize).
//!
//! * **Spans** are RAII regions entered with the [`obs::span!`] macro
//!   (`let _span = obs::span!("adjoint.backward");`), gated by a
//!   process-wide flag ([`obs::set_enabled`]). Disabled — the default —
//!   a span site costs one relaxed atomic load + branch.
//! * **Registry** metrics ([`obs::counter`] / [`obs::gauge`] /
//!   [`obs::hist`]) are always-on named integer atomics: bridge-call and
//!   tree-cache hit/miss counters, pool spawn/dispatch/steal/park
//!   counters, `peak_tape_bytes`/`recompute_nfe` gauges, per-shard
//!   queue-wait and engine-time histograms (power-of-two buckets,
//!   [`obs::bucket_index`]).
//!
//! | exporter | trigger | format |
//! |---|---|---|
//! | Chrome trace | `--trace-out trace.json` on `train`/`bench`/`serve` | trace-event JSON (`chrome://tracing`, Perfetto) |
//! | registry dump | `GET /metrics` (`"registry"` key) or [`obs::dump_json`] | strict JSON, sorted names |
//!
//! **Determinism contract:** instrumentation never touches the `f64`
//! path — spans and registry metrics are integer-only side channels, so
//! tracing (on or off) never changes a result byte. `tests/obs.rs` pins
//! solve/gradient/ELBO bits with tracing enabled vs disabled, the
//! well-nestedness of exported begin/end pairs per thread, counter
//! monotonicity under concurrent batched calls, and the histogram
//! bucket boundaries; `bench throughput` reports the measured
//! enabled-vs-disabled overhead as its `tracing` row.
//!
//! ## Verified convergence orders
//!
//! The [`convergence`] subsystem turns the paper's §5 convergence claims
//! into measurements: dt-ladder runners drive the API across halving step
//! sizes against analytic oracles ([`sde::ExactSolution`] — closed-form
//! strong solutions and pathwise gradients consuming the *same* Brownian
//! path as the solver) and fit empirical strong/weak/gradient orders by
//! log-log regression with paired-bootstrap confidence intervals.
//! `sdegrad repro convergence` prints the table;
//! `cargo test --release --test convergence` pins measured orders to the
//! nominal ones ([`solvers::Method::strong_order`]) under seeded paths.
//!
//! ## Architecture (see DESIGN.md)
//!
//! * L3 (this crate) — [`api`] over solvers, adjoint, Brownian sources,
//!   NN/optim, latent-SDE training, coordinator. Python never runs at
//!   train time.
//! * L2/L1 (python/compile) — JAX compute graph + Pallas kernel,
//!   AOT-lowered to HLO text under `artifacts/`, executed via [`runtime`]
//!   (PJRT CPU; `xla` cargo feature).

pub mod adjoint;
pub mod api;
pub mod brownian;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod latent;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod optim;
pub mod prng;
pub mod runtime;
pub mod sde;
pub mod serve;
pub mod solvers;
pub mod testing;

/// Convenience re-exports: the problem–solver–solution API plus the core
/// trait/config vocabulary it is spoken in.
pub mod prelude {
    pub use crate::adjoint::{AdjointConfig, Checkpointing, NoiseMode};
    pub use crate::api::{
        sensitivity_batch, solve_batch, GradStats, Gradients, NoiseSpec, ProblemError, SaveAt,
        SdeProblem, SdeSolution, SensAlg, SolveOptions, StepControl,
    };
    #[allow(deprecated)]
    pub use crate::api::sensitivity_batch_tier;
    pub use crate::brownian::{BatchBrownian, BrownianMotion, BrownianPath, VirtualBrownianTree};
    pub use crate::prng::PrngKey;
    pub use crate::runtime::ExecConfig;
    pub use crate::sde::{
        BatchSde, BatchSdeVjp, Calculus, ExactSolution, KernelTier, ReplicatedSde, Sde, SdeVjp,
    };
    pub use crate::solvers::{AdaptiveConfig, Method, SolveStats};
}

/// Crate version string (exposed for CLI `--version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
