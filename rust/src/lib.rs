//! # sdegrad
//!
//! A Rust + JAX/Pallas reproduction of **"Scalable Gradients for Stochastic
//! Differential Equations"** (Li, Wong, Chen, Duvenaud — AISTATS 2020):
//! the stochastic adjoint sensitivity method, the virtual Brownian tree,
//! and gradient-based variational inference for latent SDEs, packaged as a
//! trainable framework with a coordinator, data pipeline, and benchmark
//! harness for every table and figure in the paper.
//!
//! ## The API: problem → solve → sensitivity
//!
//! Everything goes through [`api::SdeProblem`] — define *what* once, then
//! choose *how* per call:
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! // 10-d replicated geometric Brownian motion (§7.1).
//! let sde = ReplicatedSde::new(Example1, 10);
//! let (theta, z0) = (vec![0.5; 20], vec![1.0; 10]);
//!
//! let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
//!     .params(&theta)
//!     .key(PrngKey::from_seed(7));
//!
//! // Forward solve with any scheme / step control / save spec...
//! let sol = prob.solve(&SolveOptions::fixed(Method::MilsteinIto, 1000));
//! println!("z_T = {:?}", sol.final_state());
//!
//! // ...and gradients with any estimator, at the same Brownian path.
//! let g = prob
//!     .sensitivity_sum(
//!         &SensAlg::StochasticAdjoint(AdjointConfig::default()),
//!         StepControl::Steps(1000),
//!     )
//!     .unwrap();
//! println!("∂L/∂θ = {:?}", g.dtheta);
//! ```
//!
//! Swap `SensAlg::StochasticAdjoint(..)` for `SensAlg::Backprop { .. }`,
//! `SensAlg::ForwardPathwise`, or `SensAlg::Antithetic { .. }` to change
//! the estimator; set `.noise(NoiseSpec::VirtualTree { tol })` for the
//! paper's O(1)-memory noise source; use [`api::solve_batch`] /
//! [`api::sensitivity_batch`] for thread-parallel multi-path throughput.
//! The pre-0.2 free functions (`integrate_grid`,
//! `stochastic_adjoint_gradients`, …) remain as `#[deprecated]` shims
//! with bit-identical results.
//!
//! ## Verified convergence orders
//!
//! The [`convergence`] subsystem turns the paper's §5 convergence claims
//! into measurements: dt-ladder runners drive the API across halving step
//! sizes against analytic oracles ([`sde::ExactSolution`] — closed-form
//! strong solutions and pathwise gradients consuming the *same* Brownian
//! path as the solver) and fit empirical strong/weak/gradient orders by
//! log-log regression with paired-bootstrap confidence intervals.
//! `sdegrad repro convergence` prints the table;
//! `cargo test --release --test convergence` pins measured orders to the
//! nominal ones ([`solvers::Method::strong_order`]) under seeded paths.
//!
//! ## Architecture (see DESIGN.md)
//!
//! * L3 (this crate) — [`api`] over solvers, adjoint, Brownian sources,
//!   NN/optim, latent-SDE training, coordinator. Python never runs at
//!   train time.
//! * L2/L1 (python/compile) — JAX compute graph + Pallas kernel,
//!   AOT-lowered to HLO text under `artifacts/`, executed via [`runtime`]
//!   (PJRT CPU; `xla` cargo feature).

pub mod adjoint;
pub mod api;
pub mod brownian;
pub mod convergence;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod latent;
pub mod metrics;
pub mod nn;
pub mod optim;
pub mod prng;
pub mod runtime;
pub mod sde;
pub mod solvers;
pub mod testing;

/// Convenience re-exports: the problem–solver–solution API plus the core
/// trait/config vocabulary it is spoken in.
pub mod prelude {
    pub use crate::adjoint::{AdjointConfig, NoiseMode};
    pub use crate::api::{
        sensitivity_batch, solve_batch, GradStats, Gradients, NoiseSpec, ProblemError, SaveAt,
        SdeProblem, SdeSolution, SensAlg, SolveOptions, StepControl,
    };
    pub use crate::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
    pub use crate::prng::PrngKey;
    pub use crate::sde::{Calculus, ExactSolution, ReplicatedSde, Sde, SdeVjp};
    pub use crate::solvers::{AdaptiveConfig, Method, SolveStats};
}

/// Crate version string (exposed for CLI `--version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
