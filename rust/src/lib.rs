//! # sdegrad
//!
//! A Rust + JAX/Pallas reproduction of **"Scalable Gradients for Stochastic
//! Differential Equations"** (Li, Wong, Chen, Duvenaud — AISTATS 2020):
//! the stochastic adjoint sensitivity method, the virtual Brownian tree,
//! and gradient-based variational inference for latent SDEs, packaged as a
//! trainable framework with a coordinator, data pipeline, and benchmark
//! harness for every table and figure in the paper.
//!
//! Architecture (see DESIGN.md):
//! * L3 (this crate) — solvers, adjoint, Brownian sources, NN/optim,
//!   latent-SDE training, coordinator. Python never runs at train time.
//! * L2/L1 (python/compile) — JAX compute graph + Pallas kernel, AOT-lowered
//!   to HLO text under `artifacts/`, executed via [`runtime`] (PJRT CPU).

pub mod adjoint;
pub mod brownian;
pub mod coordinator;
pub mod data;
pub mod latent;
pub mod metrics;
pub mod nn;
pub mod optim;
pub mod prng;
pub mod runtime;
pub mod sde;
pub mod solvers;
pub mod testing;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::adjoint::{
        stochastic_adjoint_gradients, AdjointConfig, GradientOutput, NoiseMode,
    };
    pub use crate::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
    pub use crate::prng::PrngKey;
    pub use crate::sde::{Calculus, ForwardFunc, ReplicatedSde, Sde, SdeFunc, SdeVjp};
    pub use crate::solvers::{integrate_adaptive, integrate_grid, uniform_grid, AdaptiveConfig, Method};
}

/// Crate version string (exposed for CLI `--version`).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
