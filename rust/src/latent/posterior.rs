//! The augmented posterior system `(z, ℓ)` as an [`Sde`]/[`SdeVjp`].
//!
//! Forward state: `y = [z (dz) | ℓ (1)]` where `ℓ_t = ∫₀ᵗ ½|u|² ds` is the
//! running path-KL (§5: "augment the forward SDE with an extra scalar
//! variable whose drift is ½|u|² and whose diffusion is zero").
//!
//! Parameter vector seen by the adjoint: `[model params (N) | ctx (dc)]` —
//! the per-interval context produced by the recognition network is treated
//! as a constant parameter block for the duration of an interval, so the
//! stochastic adjoint's `a_θ` yields `∂L/∂ctx` in the tail, which the
//! trainer then backpropagates through the encoder. This is exactly the
//! "treat inputs as zero-dynamics state" trick of §3.3 applied to the
//! context.
//!
//! Backward dynamics (Eq. 18): the `a_z` adjoint receives an extra drift
//! term `a_ℓ · ∂(½|u|²)/∂z` and the parameter adjoints receive
//! `a_ℓ · ∂(½|u|²)/∂θ`; `a_ℓ` itself is constant. All of this emerges
//! automatically from implementing the drift VJP of the augmented system —
//! no special-casing in the adjoint driver.

use std::cell::RefCell;

use super::model::{DiffusionMode, LatentSdeModel};
use crate::adjoint::batch::BatchAugmentedOps;
use crate::nn::{MlpBatchCache, MlpCache};
use crate::runtime::ExecConfig;
use crate::sde::{BatchSde, BatchSdeVjp, Calculus, KernelTier, Sde, SdeVjp};

/// Scratch buffers + forward caches (interior-mutable: the `Sde` trait is
/// `&self`, and each `PosteriorSde` is used by one solver at a time).
struct Scratch {
    post_in: Vec<f64>,
    prior_in: Vec<f64>,
    post_cache: MlpCache,
    prior_cache: MlpCache,
    diff_caches: Vec<MlpCache>,
    h_post: Vec<f64>,
    h_prior: Vec<f64>,
    sig: Vec<f64>,
    u: Vec<f64>,
    vjp_vec: Vec<f64>,
    dx_post: Vec<f64>,
    dx_prior: Vec<f64>,
}

/// Batched scratch: `[B×·]` net inputs/outputs and batch MLP caches,
/// (re)allocated only when the batch size changes.
struct BatchScratch {
    batch: usize,
    post_in: Vec<f64>,
    prior_in: Vec<f64>,
    post_cache: MlpBatchCache,
    prior_cache: MlpBatchCache,
    diff_caches: Vec<MlpBatchCache>,
    diff_in: Vec<f64>,
    diff_out: Vec<f64>,
    h_post: Vec<f64>,
    h_prior: Vec<f64>,
    sig: Vec<f64>,
    u: Vec<f64>,
}

/// The latent posterior SDE with running-KL augmentation.
pub struct PosteriorSde<'a> {
    model: &'a LatentSdeModel,
    /// Length of the SDE-relevant prefix of the flat parameter vector
    /// (prior drift | posterior drift | diffusion nets — everything the
    /// path dynamics can depend on). Decoder/encoder/q-head/p(z0) params
    /// sit *after* this prefix and can never receive path-adjoint
    /// gradients, so the adjoint runs over `sde_len + dc` parameters
    /// instead of `n_params + dc` — a large constant-factor win in the
    /// O(p)-per-step quadrature (EXPERIMENTS.md §Perf).
    sde_len: usize,
    scratch: RefCell<Scratch>,
    batch_scratch: RefCell<Option<BatchScratch>>,
}

impl<'a> PosteriorSde<'a> {
    pub fn new(model: &'a LatentSdeModel) -> Self {
        let dz = model.cfg.latent_dim;
        let dc = model.cfg.context_dim;
        // The decoder is allocated immediately after the diffusion nets
        // (see LatentSdeModel::new), so its first weight offset bounds the
        // SDE-relevant region.
        let sde_len = model.decoder.layers[0].w_off;
        let scratch = Scratch {
            post_in: vec![0.0; dz + 1 + dc],
            prior_in: vec![0.0; dz + 1],
            post_cache: model.post_drift.cache(),
            prior_cache: model.prior_drift.cache(),
            diff_caches: model.diffusion.iter().map(|m| m.cache()).collect(),
            h_post: vec![0.0; dz],
            h_prior: vec![0.0; dz],
            sig: vec![0.0; dz],
            u: vec![0.0; dz],
            vjp_vec: vec![0.0; dz],
            dx_post: vec![0.0; dz + 1 + dc],
            dx_prior: vec![0.0; dz + 1],
        };
        PosteriorSde {
            model,
            sde_len,
            scratch: RefCell::new(scratch),
            batch_scratch: RefCell::new(None),
        }
    }

    /// Get (allocating or resizing on demand) the batched scratch for a
    /// batch of `bsz` paths.
    fn ensure_batch_scratch(&self, bsz: usize) -> std::cell::RefMut<'_, BatchScratch> {
        let dz = self.dz();
        let dc = self.model.cfg.context_dim;
        let mut cell = self.batch_scratch.borrow_mut();
        let stale = match cell.as_ref() {
            Some(sc) => sc.batch != bsz,
            None => true,
        };
        if stale {
            *cell = Some(BatchScratch {
                batch: bsz,
                post_in: vec![0.0; bsz * (dz + 1 + dc)],
                prior_in: vec![0.0; bsz * (dz + 1)],
                post_cache: self.model.post_drift.batch_cache(bsz),
                prior_cache: self.model.prior_drift.batch_cache(bsz),
                diff_caches: self.model.diffusion.iter().map(|m| m.batch_cache(bsz)).collect(),
                diff_in: vec![0.0; bsz],
                diff_out: vec![0.0; bsz],
                h_post: vec![0.0; bsz * dz],
                h_prior: vec![0.0; bsz * dz],
                sig: vec![0.0; bsz * dz],
                u: vec![0.0; bsz * dz],
            });
        }
        std::cell::RefMut::map(cell, |o| o.as_mut().expect("just ensured"))
    }

    /// Batched σ into `sc.sig` (`[B×dz]`): per dimension, one `[B×1]`
    /// forward through that dimension's net — weight rows hot across all
    /// B paths. With `fast == false`, values per `(b, i)` cell match the
    /// scalar `eval_sigma`; with `fast == true` the nets run through
    /// [`crate::nn::Mlp::forward_batch_fast`] (reassociated dots, equal to
    /// exact only to relative tolerance).
    fn eval_sigma_batch(
        &self,
        params: &[f64],
        y: &[f64],
        aug: usize,
        sc: &mut BatchScratch,
        fast: bool,
    ) {
        let dz = self.dz();
        let bsz = sc.batch;
        match self.model.cfg.diffusion {
            DiffusionMode::Off => sc.sig.fill(0.0),
            DiffusionMode::PerDimNets { floor, scale } => {
                for i in 0..dz {
                    for b in 0..bsz {
                        sc.diff_in[b] = y[b * aug + i];
                    }
                    let BatchScratch { diff_in, diff_out, diff_caches, .. } = sc;
                    if fast {
                        self.model.diffusion[i].forward_batch_fast(
                            params,
                            diff_in,
                            &mut diff_caches[i],
                            diff_out,
                        );
                    } else {
                        self.model.diffusion[i].forward_batch(
                            params,
                            diff_in,
                            &mut diff_caches[i],
                            diff_out,
                        );
                    }
                    for b in 0..bsz {
                        sc.sig[b * dz + i] = floor + scale * sc.diff_out[b];
                    }
                }
            }
        }
    }

    /// Length of the SDE-relevant parameter prefix (excludes context).
    pub fn sde_param_len(&self) -> usize {
        self.sde_len
    }

    #[inline]
    fn dz(&self) -> usize {
        self.model.cfg.latent_dim
    }

    #[inline]
    fn n_model(&self) -> usize {
        self.sde_len
    }

    /// Split the full parameter vector into (model params, context).
    #[inline]
    fn split_theta<'t>(&self, theta: &'t [f64]) -> (&'t [f64], &'t [f64]) {
        theta.split_at(self.n_model())
    }

    /// Forward evaluation of h_φ, h_θ, σ, u into the scratch (σ only when
    /// diffusing; u only when `with_u`).
    fn eval_nets(&self, t: f64, z: &[f64], theta: &[f64], sc: &mut Scratch, with_u: bool) {
        let dz = self.dz();
        let (params, ctx) = self.split_theta(theta);
        sc.post_in[..dz].copy_from_slice(z);
        sc.post_in[dz] = t;
        sc.post_in[dz + 1..].copy_from_slice(ctx);
        {
            let Scratch { post_in, post_cache, h_post, .. } = sc;
            self.model.post_drift.forward(params, post_in, post_cache, h_post);
        }
        if with_u {
            sc.prior_in[..dz].copy_from_slice(z);
            sc.prior_in[dz] = t;
            {
                let Scratch { prior_in, prior_cache, h_prior, .. } = sc;
                self.model.prior_drift.forward(params, prior_in, prior_cache, h_prior);
            }
            self.eval_sigma(params, z, sc);
            for i in 0..dz {
                sc.u[i] = (sc.h_post[i] - sc.h_prior[i]) / sc.sig[i];
            }
        }
    }

    fn eval_sigma(&self, params: &[f64], z: &[f64], sc: &mut Scratch) {
        let dz = self.dz();
        match self.model.cfg.diffusion {
            DiffusionMode::Off => sc.sig[..dz].fill(0.0),
            DiffusionMode::PerDimNets { floor, scale } => {
                for i in 0..dz {
                    let mut out = [0.0];
                    self.model.diffusion[i].forward(
                        params,
                        &z[i..i + 1],
                        &mut sc.diff_caches[i],
                        &mut out,
                    );
                    sc.sig[i] = floor + scale * out[0];
                }
            }
        }
    }

    fn diffusing(&self) -> bool {
        !matches!(self.model.cfg.diffusion, DiffusionMode::Off)
    }

    fn diff_scale(&self) -> f64 {
        match self.model.cfg.diffusion {
            DiffusionMode::PerDimNets { scale, .. } => scale,
            DiffusionMode::Off => 0.0,
        }
    }

    /// Batched drift core shared by the shared-context and per-path-context
    /// entry points: `ctx` holds one context row broadcast to every path
    /// (`ctx_stride == 0`) or B per-path rows (`ctx_stride == dc`). With
    /// `fast == false`, per `(b, i)` cell the floats equal the scalar
    /// [`Sde::drift`] with `θ_b = [params | ctx_b]`; with `fast == true`
    /// the drift nets run through the fast-tier MLP kernels.
    fn drift_batch_rows(
        &self,
        t: f64,
        y: &[f64],
        params: &[f64],
        ctx: &[f64],
        ctx_stride: usize,
        out: &mut [f64],
        fast: bool,
    ) {
        let dz = self.dz();
        let aug = dz + 1;
        let bsz = y.len() / aug;
        let dc = self.model.cfg.context_dim;
        let with_u = self.diffusing();
        let mut sc = self.ensure_batch_scratch(bsz);
        let sc = &mut *sc;

        let din = dz + 1 + dc;
        for b in 0..bsz {
            let row = &mut sc.post_in[b * din..(b + 1) * din];
            row[..dz].copy_from_slice(&y[b * aug..b * aug + dz]);
            row[dz] = t;
            row[dz + 1..].copy_from_slice(&ctx[b * ctx_stride..b * ctx_stride + dc]);
        }
        {
            let BatchScratch { post_in, post_cache, h_post, .. } = sc;
            if fast {
                self.model.post_drift.forward_batch_fast(params, post_in, post_cache, h_post);
            } else {
                self.model.post_drift.forward_batch(params, post_in, post_cache, h_post);
            }
        }
        if with_u {
            for b in 0..bsz {
                let row = &mut sc.prior_in[b * (dz + 1)..(b + 1) * (dz + 1)];
                row[..dz].copy_from_slice(&y[b * aug..b * aug + dz]);
                row[dz] = t;
            }
            {
                let BatchScratch { prior_in, prior_cache, h_prior, .. } = sc;
                if fast {
                    self.model
                        .prior_drift
                        .forward_batch_fast(params, prior_in, prior_cache, h_prior);
                } else {
                    self.model.prior_drift.forward_batch(params, prior_in, prior_cache, h_prior);
                }
            }
            self.eval_sigma_batch(params, y, aug, sc, fast);
            for i in 0..bsz * dz {
                sc.u[i] = (sc.h_post[i] - sc.h_prior[i]) / sc.sig[i];
            }
        }
        for b in 0..bsz {
            out[b * aug..b * aug + dz].copy_from_slice(&sc.h_post[b * dz..(b + 1) * dz]);
            out[b * aug + dz] = if with_u {
                0.5 * sc.u[b * dz..(b + 1) * dz].iter().map(|v| v * v).sum::<f64>()
            } else {
                0.0
            };
        }
    }

    /// Batched drift with **per-path context rows** (`ctx: [B×dc]`): path
    /// `b` is evaluated under `θ_b = [params | ctx_b]`. This is the
    /// minibatch trainer's kernel — different paths belong to different
    /// sequences, each with its own encoder context.
    pub(crate) fn drift_batch_ctx(
        &self,
        t: f64,
        y: &[f64],
        params: &[f64],
        ctx: &[f64],
        out: &mut [f64],
        tier: KernelTier,
    ) {
        let bsz = y.len() / (self.dz() + 1);
        debug_assert_eq!(ctx.len(), bsz * self.model.cfg.context_dim);
        self.drift_batch_rows(
            t,
            y,
            params,
            ctx,
            self.model.cfg.context_dim,
            out,
            tier == KernelTier::Fast,
        );
    }

    /// Batched diffusion from the model-parameter prefix alone (σ never
    /// reads the context).
    pub(crate) fn diffusion_batch_params(
        &self,
        _t: f64,
        y: &[f64],
        params: &[f64],
        out: &mut [f64],
        tier: KernelTier,
    ) {
        let dz = self.dz();
        let aug = dz + 1;
        let bsz = y.len() / aug;
        let mut sc = self.ensure_batch_scratch(bsz);
        let sc = &mut *sc;
        self.eval_sigma_batch(params, y, aug, sc, tier == KernelTier::Fast);
        for b in 0..bsz {
            out[b * aug..b * aug + dz].copy_from_slice(&sc.sig[b * dz..(b + 1) * dz]);
            out[b * aug + dz] = 0.0;
        }
    }
}

impl<'a> Sde for PosteriorSde<'a> {
    fn state_dim(&self) -> usize {
        self.dz() + 1
    }

    fn param_dim(&self) -> usize {
        self.n_model() + self.model.cfg.context_dim
    }

    fn calculus(&self) -> Calculus {
        // Native Stratonovich by convention (see latent/mod.rs docs).
        Calculus::Stratonovich
    }

    fn drift(&self, t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let dz = self.dz();
        let sc = &mut *self.scratch.borrow_mut();
        let with_u = self.diffusing();
        self.eval_nets(t, &y[..dz], theta, sc, with_u);
        out[..dz].copy_from_slice(&sc.h_post);
        out[dz] = if with_u {
            0.5 * sc.u.iter().map(|v| v * v).sum::<f64>()
        } else {
            0.0
        };
    }

    fn diffusion(&self, _t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let dz = self.dz();
        let (params, _) = self.split_theta(theta);
        let sc = &mut *self.scratch.borrow_mut();
        self.eval_sigma(params, &y[..dz], sc);
        out[..dz].copy_from_slice(&sc.sig);
        out[dz] = 0.0;
    }

    fn diffusion_dz_diag(&self, _t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let dz = self.dz();
        let (params, _) = self.split_theta(theta);
        out[dz] = 0.0;
        match self.model.cfg.diffusion {
            DiffusionMode::Off => out[..dz].fill(0.0),
            DiffusionMode::PerDimNets { scale, .. } => {
                let sc = &mut *self.scratch.borrow_mut();
                for i in 0..dz {
                    let mut o = [0.0];
                    self.model.diffusion[i].forward(
                        params,
                        &y[i..i + 1],
                        &mut sc.diff_caches[i],
                        &mut o,
                    );
                    let mut dx = [0.0];
                    // Parameter grads of this probe are discarded (cold
                    // path: only Milstein forward stepping uses this).
                    let mut dp = vec![0.0; params.len()];
                    self.model.diffusion[i]
                        .vjp(params, &mut sc.diff_caches[i], &[scale], &mut dx, &mut dp);
                    out[i] = dx[0];
                }
            }
        }
    }
}

impl<'a> SdeVjp for PosteriorSde<'a> {
    fn drift_vjp(
        &self,
        t: f64,
        y: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        let dz = self.dz();
        let (params, _) = self.split_theta(theta);
        let n_model = self.n_model();
        let a_l = a[dz];
        let with_u = self.diffusing();

        let sc = &mut *self.scratch.borrow_mut();
        self.eval_nets(t, &y[..dz], theta, sc, with_u);

        // --- Posterior drift: weight v1 = a_z + a_ℓ·u/σ. ---
        for i in 0..dz {
            sc.vjp_vec[i] = a[i]
                + if with_u { a_l * sc.u[i] / sc.sig[i] } else { 0.0 };
        }
        sc.dx_post.fill(0.0);
        {
            let Scratch { post_cache, dx_post, vjp_vec, .. } = sc;
            self.model.post_drift.vjp(
                params,
                post_cache,
                &vjp_vec[..dz],
                dx_post,
                &mut out_theta[..n_model],
            );
        }
        for i in 0..dz {
            out_z[i] += sc.dx_post[i];
        }
        // ctx gradient: input slots dz+1.. of the posterior drift.
        let dc = self.model.cfg.context_dim;
        for c in 0..dc {
            out_theta[n_model + c] += sc.dx_post[dz + 1 + c];
        }

        if with_u {
            // --- Prior drift: weight v2 = −a_ℓ·u/σ. ---
            for i in 0..dz {
                sc.vjp_vec[i] = -a_l * sc.u[i] / sc.sig[i];
            }
            sc.dx_prior.fill(0.0);
            {
                let Scratch { prior_cache, dx_prior, vjp_vec, .. } = sc;
                self.model.prior_drift.vjp(
                    params,
                    prior_cache,
                    &vjp_vec[..dz],
                    dx_prior,
                    &mut out_theta[..n_model],
                );
            }
            for i in 0..dz {
                out_z[i] += sc.dx_prior[i];
            }
            // --- σ-dependence of ½|u|²: ∂/∂σ_i = −u_i²/σ_i. ---
            let scale = self.diff_scale();
            for i in 0..dz {
                let w = a_l * (-sc.u[i] * sc.u[i] / sc.sig[i]) * scale;
                if w == 0.0 {
                    continue;
                }
                let mut dx = [0.0];
                // σ nets were forward-evaluated inside eval_nets.
                self.model.diffusion[i].vjp(
                    params,
                    &mut sc.diff_caches[i],
                    &[w],
                    &mut dx,
                    &mut out_theta[..n_model],
                );
                out_z[i] += dx[0];
            }
        }
        // ℓ never influences the drift: out_z[dz] += 0.
    }

    fn diffusion_vjp(
        &self,
        _t: f64,
        y: &[f64],
        theta: &[f64],
        a: &[f64],
        out_z: &mut [f64],
        out_theta: &mut [f64],
    ) {
        if !self.diffusing() {
            return;
        }
        let dz = self.dz();
        let (params, _) = self.split_theta(theta);
        let n_model = self.n_model();
        let scale = self.diff_scale();
        let sc = &mut *self.scratch.borrow_mut();
        self.eval_sigma(params, &y[..dz], sc);
        for i in 0..dz {
            let w = a[i] * scale;
            if w == 0.0 {
                continue;
            }
            let mut dx = [0.0];
            self.model.diffusion[i].vjp(
                params,
                &mut sc.diff_caches[i],
                &[w],
                &mut dx,
                &mut out_theta[..n_model],
            );
            out_z[i] += dx[0];
        }
        // ℓ-row of the diffusion is 0.
    }
}

/// Hand-batched forward evaluation: the MLP passes become blocked
/// `[B×in]·[in×out]` matrix–matrix products via
/// [`crate::nn::Mlp::forward_batch`], reusing one batch cache arena —
/// this is the latent-SDE hot path the batch engine exists for. Per-path
/// values are bit-identical to the scalar [`Sde`] impl (same per-cell
/// accumulation order throughout).
impl<'a> BatchSde for PosteriorSde<'a> {
    fn drift_batch(&self, t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let (params, ctx) = self.split_theta(theta);
        // One shared context row, broadcast to every path (stride 0).
        self.drift_batch_rows(t, y, params, ctx, 0, out, false);
    }

    fn diffusion_batch(&self, t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let (params, _) = self.split_theta(theta);
        self.diffusion_batch_params(t, y, params, out, KernelTier::Exact);
    }

    fn drift_batch_fast(&self, t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let (params, ctx) = self.split_theta(theta);
        self.drift_batch_rows(t, y, params, ctx, 0, out, true);
    }

    fn diffusion_batch_fast(&self, t: f64, y: &[f64], theta: &[f64], out: &mut [f64]) {
        let (params, _) = self.split_theta(theta);
        self.diffusion_batch_params(t, y, params, out, KernelTier::Fast);
    }
}

// VJPs ride the loop-based defaults (the scalar VJPs already reuse the
// per-instance scratch); the solve-side forward passes above are where
// batching pays in the latent workload (B ELBO samples per step).
impl<'a> BatchSdeVjp for PosteriorSde<'a> {}

/// Batched forward view of the posterior with **per-path context rows**
/// (`[B×dc]`): the minibatch trainer's forward kernel, where each path in
/// the batch belongs to a (possibly different) sequence whose encoder
/// context rides in its parameter tail. Implements
/// [`crate::solvers::BatchSdeFunc`] directly in the posterior's native
/// Stratonovich calculus (the trainer steps with Heun, so no conversion
/// arises); path `b`'s floats equal a scalar
/// [`crate::sde::ForwardFunc`] solve with `θ_b = [params | ctx_b]`.
pub(crate) struct CtxBatchForwardFunc<'a, 'm> {
    sde: &'a PosteriorSde<'m>,
    params: &'a [f64],
    ctx: &'a [f64],
    batch: usize,
    tier: KernelTier,
    nfe_f: u64,
    nfe_g: u64,
}

impl<'a, 'm> CtxBatchForwardFunc<'a, 'm> {
    /// `exec.tier == Fast` routes the drift/diffusion net evaluations
    /// through the fast-tier MLP kernels (tolerance-equal to exact, not
    /// bit-equal); the other [`ExecConfig`] knobs do not apply at this
    /// level (threads and tree caching belong to the callers).
    pub(crate) fn new(
        sde: &'a PosteriorSde<'m>,
        params: &'a [f64],
        ctx: &'a [f64],
        batch: usize,
        exec: ExecConfig,
    ) -> Self {
        assert_eq!(params.len(), sde.sde_param_len(), "CtxBatchForwardFunc: params length");
        assert_eq!(
            ctx.len(),
            batch * sde.model.cfg.context_dim,
            "CtxBatchForwardFunc: ctx rows mismatch"
        );
        CtxBatchForwardFunc { sde, params, ctx, batch, tier: exec.tier, nfe_f: 0, nfe_g: 0 }
    }

    /// Deprecated spelling of [`CtxBatchForwardFunc::new`] from before
    /// [`ExecConfig`] unified the execution knobs.
    #[deprecated(
        since = "0.2.0",
        note = "use `CtxBatchForwardFunc::new` with `ExecConfig::new().tier(tier)`"
    )]
    #[allow(dead_code)]
    pub(crate) fn new_tier(
        sde: &'a PosteriorSde<'m>,
        params: &'a [f64],
        ctx: &'a [f64],
        batch: usize,
        tier: KernelTier,
    ) -> Self {
        Self::new(sde, params, ctx, batch, ExecConfig::new().tier(tier))
    }
}

impl<'a, 'm> crate::solvers::BatchSdeFunc for CtxBatchForwardFunc<'a, 'm> {
    fn dim(&self) -> usize {
        self.sde.state_dim()
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn calculus(&self) -> Calculus {
        Calculus::Stratonovich
    }
    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_f += 1;
        self.sde.drift_batch_ctx(t, y, self.params, self.ctx, out, self.tier);
    }
    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_g += 1;
        self.sde.diffusion_batch_params(t, y, self.params, out, self.tier);
    }
    fn nfe_drift(&self) -> u64 {
        self.nfe_f
    }
    fn nfe_diffusion(&self) -> u64 {
        self.nfe_g
    }
}

/// [`BatchAugmentedOps`] over the posterior with per-path context rows:
/// the batched augmented backward dynamics of the latent trainer's
/// stochastic adjoint. Coefficient evaluations (`b̃`, `σ`) are
/// hand-batched — blocked MLP passes with each path's own context — while
/// the VJPs ride the scalar kernels row-per-row under the path's
/// `θ_b = [params | ctx_b]`, exactly the call sequence of the scalar
/// [`crate::adjoint::AdjointOps`], so per-path floats match the scalar
/// backward solver bit for bit (pinned in the module tests and
/// `tests/trainer_batch.rs`).
pub(crate) struct CtxAdjointOps<'a, 'm> {
    sde: &'a PosteriorSde<'m>,
    /// One full parameter vector `[params | ctx_b]`; the dc-wide tail is
    /// rewritten per row before each scalar VJP call (dc is tiny compared
    /// to re-copying all of θ per row per stage).
    theta_row: Vec<f64>,
    /// Current interval's context rows `[B×dc]`.
    ctx: Vec<f64>,
    n_model: usize,
    d: usize,
    batch: usize,
    neg_a: Vec<f64>,
    weighted_a: Vec<f64>,
    /// Row-level scratch for the Stratonovich drift VJP (len d).
    vjp_scratch: Vec<f64>,
    /// Discard buffers for the two one-sided diffusion VJP calls.
    scratch_z: Vec<f64>,
    scratch_p: Vec<f64>,
    /// Tier for the *batched coefficient evaluations* (`b̃`, `σ`). The
    /// row-wise scalar VJP calls are tier-agnostic (scalar kernels have no
    /// fast variant), so fast-tier backward passes differ from exact only
    /// through the coefficient floats.
    tier: KernelTier,
    nfe_drift: u64,
    nfe_diffusion: u64,
}

impl<'a, 'm> CtxAdjointOps<'a, 'm> {
    /// `exec.tier` selects the tier for the batched coefficient
    /// evaluations (see the `tier` field); the other [`ExecConfig`] knobs
    /// do not apply at this level.
    pub(crate) fn new(
        sde: &'a PosteriorSde<'m>,
        params: &[f64],
        batch: usize,
        exec: ExecConfig,
    ) -> Self {
        let tier = exec.tier;
        let n_model = sde.sde_param_len();
        assert_eq!(params.len(), n_model, "CtxAdjointOps: params length");
        assert!(batch > 0, "CtxAdjointOps: empty batch");
        let d = sde.state_dim();
        let dc = sde.model.cfg.context_dim;
        let p = n_model + dc;
        let mut theta_row = vec![0.0; p];
        theta_row[..n_model].copy_from_slice(params);
        CtxAdjointOps {
            sde,
            theta_row,
            ctx: vec![0.0; batch * dc],
            n_model,
            d,
            batch,
            neg_a: vec![0.0; batch * d],
            weighted_a: vec![0.0; batch * d],
            vjp_scratch: vec![0.0; d],
            scratch_z: vec![0.0; d],
            scratch_p: vec![0.0; p],
            tier,
            nfe_drift: 0,
            nfe_diffusion: 0,
        }
    }

    /// Deprecated spelling of [`CtxAdjointOps::new`] from before
    /// [`ExecConfig`] unified the execution knobs.
    #[deprecated(
        since = "0.2.0",
        note = "use `CtxAdjointOps::new` with `ExecConfig::new().tier(tier)`"
    )]
    #[allow(dead_code)]
    pub(crate) fn new_tier(
        sde: &'a PosteriorSde<'m>,
        params: &[f64],
        batch: usize,
        tier: KernelTier,
    ) -> Self {
        Self::new(sde, params, batch, ExecConfig::new().tier(tier))
    }

    /// Swap in the next interval's context rows (`[B×dc]`).
    pub(crate) fn set_ctx(&mut self, ctx: &[f64]) {
        assert_eq!(ctx.len(), self.ctx.len(), "set_ctx: rows mismatch");
        self.ctx.copy_from_slice(ctx);
    }
}

impl<'a, 'm> BatchAugmentedOps for CtxAdjointOps<'a, 'm> {
    fn state_dim(&self) -> usize {
        self.d
    }
    fn param_dim(&self) -> usize {
        self.theta_row.len()
    }
    fn batch(&self) -> usize {
        self.batch
    }

    fn eval_drift(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        b_out: &mut [f64],
        fa_out: &mut [f64],
        fth_out: &mut [f64],
    ) {
        self.nfe_drift += 1;
        // b̃ is the native-Stratonovich drift — hand-batched per-ctx pass.
        let params = &self.theta_row[..self.n_model];
        self.sde.drift_batch_ctx(t, z, params, &self.ctx, b_out, self.tier);
        for (n, v) in self.neg_a.iter_mut().zip(a) {
            *n = -v;
        }
        fa_out.fill(0.0);
        fth_out.fill(0.0);
        let d = self.d;
        let p = self.theta_row.len();
        let dc = p - self.n_model;
        for b in 0..self.batch {
            self.theta_row[self.n_model..].copy_from_slice(&self.ctx[b * dc..(b + 1) * dc]);
            self.sde.drift_vjp_stratonovich(
                t,
                &z[b * d..(b + 1) * d],
                &self.theta_row,
                &self.neg_a[b * d..(b + 1) * d],
                &mut fa_out[b * d..(b + 1) * d],
                &mut fth_out[b * p..(b + 1) * p],
                &mut self.vjp_scratch,
            );
        }
    }

    fn eval_diffusion(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        dw: &[f64],
        s_out: &mut [f64],
        ga_out: &mut [f64],
        gth_out: &mut [f64],
    ) {
        self.nfe_diffusion += 1;
        self.sde.diffusion_batch_params(t, z, &self.theta_row[..self.n_model], s_out, self.tier);
        for i in 0..self.batch * self.d {
            self.neg_a[i] = -a[i];
            self.weighted_a[i] = -a[i] * dw[i];
        }
        ga_out.fill(0.0);
        gth_out.fill(0.0);
        let d = self.d;
        let p = self.theta_row.len();
        let dc = p - self.n_model;
        for b in 0..self.batch {
            self.theta_row[self.n_model..].copy_from_slice(&self.ctx[b * dc..(b + 1) * dc]);
            // z-VJP with −a (unweighted); θ-VJP with −a⊙ΔW. Side outputs
            // land in scratch and are discarded — the scalar AdjointOps'
            // two-call structure, row by row.
            self.scratch_p.fill(0.0);
            self.sde.diffusion_vjp(
                t,
                &z[b * d..(b + 1) * d],
                &self.theta_row,
                &self.neg_a[b * d..(b + 1) * d],
                &mut ga_out[b * d..(b + 1) * d],
                &mut self.scratch_p,
            );
            self.scratch_z.fill(0.0);
            self.sde.diffusion_vjp(
                t,
                &z[b * d..(b + 1) * d],
                &self.theta_row,
                &self.weighted_a[b * d..(b + 1) * d],
                &mut self.scratch_z,
                &mut gth_out[b * p..(b + 1) * p],
            );
        }
    }

    fn nfe(&self) -> (u64, u64) {
        (self.nfe_drift, self.nfe_diffusion)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latent::model::{LatentSdeConfig, LatentSdeModel};
    use crate::prng::PrngKey;

    fn tiny_model() -> LatentSdeModel {
        LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            ..Default::default()
        })
    }

    fn theta_full(model: &LatentSdeModel, seed: u64) -> Vec<f64> {
        let params = model.init_params(PrngKey::from_seed(seed));
        let sde_len = model.decoder.layers[0].w_off;
        let mut th = params[..sde_len].to_vec();
        let mut ctx = vec![0.0; model.cfg.context_dim];
        PrngKey::from_seed(seed + 1).fill_normal(0, &mut ctx);
        th.extend_from_slice(&ctx);
        th
    }

    #[test]
    fn drift_has_kl_row_and_it_is_nonnegative() {
        let model = tiny_model();
        let th = theta_full(&model, 1);
        let sys = PosteriorSde::new(&model);
        let y = [0.2, -0.5, 0.9, 0.0];
        let mut out = [0.0; 4];
        sys.drift(0.3, &y, &th, &mut out);
        assert!(out[3] >= 0.0, "½|u|² must be ≥ 0, got {}", out[3]);
    }

    #[test]
    fn drift_vjp_matches_finite_difference() {
        let model = tiny_model();
        let th = theta_full(&model, 2);
        let sys = PosteriorSde::new(&model);
        let y = [0.2, -0.5, 0.9, 0.1];
        let a = [0.7, -1.2, 0.4, 0.9];
        let t = 0.25;

        let mut vz = vec![0.0; 4];
        let mut vth = vec![0.0; th.len()];
        sys.drift_vjp(t, &y, &th, &a, &mut vz, &mut vth);

        let f = |yy: &[f64], tt: &[f64]| -> f64 {
            let mut out = [0.0; 4];
            sys.drift(t, yy, tt, &mut out);
            out.iter().zip(&a).map(|(o, ai)| o * ai).sum()
        };
        let eps = 1e-6;
        for i in 0..4 {
            let mut yp = y;
            yp[i] += eps;
            let hi = f(&yp, &th);
            yp[i] -= 2.0 * eps;
            let lo = f(&yp, &th);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - vz[i]).abs() < 2e-5 * fd.abs().max(1.0),
                "z[{i}]: fd {fd} vs {}",
                vz[i]
            );
        }
        // Sample parameter coordinates across all regions (model + ctx).
        let n = th.len();
        let probes: Vec<usize> = (0..n).step_by((n / 60).max(1)).chain([n - 1, n - 2]).collect();
        for j in probes {
            let mut tp = th.clone();
            tp[j] += eps;
            let hi = f(&y, &tp);
            tp[j] -= 2.0 * eps;
            let lo = f(&y, &tp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - vth[j]).abs() < 2e-5 * fd.abs().max(1.0),
                "θ[{j}]: fd {fd} vs {}",
                vth[j]
            );
        }
    }

    #[test]
    fn diffusion_vjp_matches_finite_difference() {
        let model = tiny_model();
        let th = theta_full(&model, 3);
        let sys = PosteriorSde::new(&model);
        let y = [0.2, -0.5, 0.9, 0.1];
        let a = [1.0, 0.5, -0.8, 0.3];
        let mut vz = vec![0.0; 4];
        let mut vth = vec![0.0; th.len()];
        sys.diffusion_vjp(0.0, &y, &th, &a, &mut vz, &mut vth);

        let f = |yy: &[f64], tt: &[f64]| -> f64 {
            let mut out = [0.0; 4];
            sys.diffusion(0.0, yy, tt, &mut out);
            out.iter().zip(&a).map(|(o, ai)| o * ai).sum()
        };
        let eps = 1e-6;
        for i in 0..4 {
            let mut yp = y;
            yp[i] += eps;
            let hi = f(&yp, &th);
            yp[i] -= 2.0 * eps;
            let lo = f(&yp, &th);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - vz[i]).abs() < 1e-6, "z[{i}]: fd {fd} vs {}", vz[i]);
        }
        let n = th.len();
        for j in (0..n).step_by((n / 40).max(1)) {
            let mut tp = th.clone();
            tp[j] += eps;
            let hi = f(&y, &tp);
            tp[j] -= 2.0 * eps;
            let lo = f(&y, &tp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - vth[j]).abs() < 1e-6, "θ[{j}]: fd {fd} vs {}", vth[j]);
        }
    }

    #[test]
    fn diffusion_dz_diag_matches_fd() {
        let model = tiny_model();
        let th = theta_full(&model, 4);
        let sys = PosteriorSde::new(&model);
        let y = [0.2, -0.5, 0.9, 0.1];
        let mut diag = [0.0; 4];
        sys.diffusion_dz_diag(0.0, &y, &th, &mut diag);
        let eps = 1e-6;
        for i in 0..3 {
            let mut yp = y;
            yp[i] += eps;
            let mut hi = [0.0; 4];
            sys.diffusion(0.0, &yp, &th, &mut hi);
            yp[i] -= 2.0 * eps;
            let mut lo = [0.0; 4];
            sys.diffusion(0.0, &yp, &th, &mut lo);
            let fd = (hi[i] - lo[i]) / (2.0 * eps);
            assert!((fd - diag[i]).abs() < 1e-6, "diag[{i}]");
        }
        assert_eq!(diag[3], 0.0);
    }

    /// The hand-batched MLP-backed kernels must equal the scalar `Sde`
    /// impl row-for-row, exactly.
    #[test]
    fn batched_drift_and_diffusion_match_scalar_rows_exactly() {
        use crate::sde::BatchSde;
        let model = tiny_model();
        let th = theta_full(&model, 6);
        let sys = PosteriorSde::new(&model);
        let aug = sys.state_dim();
        let bsz = 4;
        let mut y = vec![0.0; bsz * aug];
        PrngKey::from_seed(7).fill_normal(0, &mut y);
        let t = 0.2;

        let mut drift_b = vec![0.0; bsz * aug];
        sys.drift_batch(t, &y, &th, &mut drift_b);
        let mut diff_b = vec![0.0; bsz * aug];
        sys.diffusion_batch(t, &y, &th, &mut diff_b);

        for b in 0..bsz {
            let row = &y[b * aug..(b + 1) * aug];
            let mut out = vec![0.0; aug];
            sys.drift(t, row, &th, &mut out);
            assert_eq!(&drift_b[b * aug..(b + 1) * aug], &out[..], "drift row {b}");
            sys.diffusion(t, row, &th, &mut out);
            assert_eq!(&diff_b[b * aug..(b + 1) * aug], &out[..], "diffusion row {b}");
        }
    }

    /// Per-path-context kernels (the minibatch trainer's forward and
    /// backward evaluation bundles) must equal the scalar path with
    /// `θ_b = [params | ctx_b]` row-for-row, exactly.
    #[test]
    fn ctx_batched_kernels_match_scalar_rows_exactly() {
        use crate::adjoint::AdjointOps;
        use crate::solvers::BatchSdeFunc;

        let model = tiny_model();
        let all = model.init_params(PrngKey::from_seed(8));
        let sys = PosteriorSde::new(&model);
        let n_model = sys.sde_param_len();
        let params = &all[..n_model];
        let dc = model.cfg.context_dim;
        let aug = sys.state_dim();
        let p = n_model + dc;
        let bsz = 3;
        let t = 0.15;

        let key = PrngKey::from_seed(9);
        let mut ctx = vec![0.0; bsz * dc];
        key.fill_normal(0, &mut ctx);
        let mut y = vec![0.0; bsz * aug];
        key.fill_normal(100, &mut y);
        let mut a = vec![0.0; bsz * aug];
        key.fill_normal(200, &mut a);
        let mut dw = vec![0.0; bsz * aug];
        key.fill_normal(300, &mut dw);
        for v in dw.iter_mut() {
            *v *= 0.05;
        }

        // Forward func.
        let mut fwd = CtxBatchForwardFunc::new(&sys, params, &ctx, bsz, ExecConfig::default());
        let mut drift_b = vec![0.0; bsz * aug];
        fwd.drift(t, &y, &mut drift_b);
        let mut diff_b = vec![0.0; bsz * aug];
        fwd.diffusion(t, &y, &mut diff_b);

        // Adjoint ops.
        let mut ops = CtxAdjointOps::new(&sys, params, bsz, ExecConfig::default());
        ops.set_ctx(&ctx);
        let mut b_out = vec![0.0; bsz * aug];
        let mut fa = vec![0.0; bsz * aug];
        let mut fth = vec![0.0; bsz * p];
        ops.eval_drift(t, &y, &a, &mut b_out, &mut fa, &mut fth);
        let mut s_out = vec![0.0; bsz * aug];
        let mut ga = vec![0.0; bsz * aug];
        let mut gth = vec![0.0; bsz * p];
        ops.eval_diffusion(t, &y, &a, &dw, &mut s_out, &mut ga, &mut gth);

        for b in 0..bsz {
            let mut th = params.to_vec();
            th.extend_from_slice(&ctx[b * dc..(b + 1) * dc]);
            let yr = &y[b * aug..(b + 1) * aug];
            let ar = &a[b * aug..(b + 1) * aug];
            let mut row = vec![0.0; aug];
            sys.drift(t, yr, &th, &mut row);
            assert_eq!(&drift_b[b * aug..(b + 1) * aug], &row[..], "fwd drift row {b}");
            sys.diffusion(t, yr, &th, &mut row);
            assert_eq!(&diff_b[b * aug..(b + 1) * aug], &row[..], "fwd diffusion row {b}");

            let mut sops = AdjointOps::new(&sys, &th);
            let mut sb = vec![0.0; aug];
            let mut sfa = vec![0.0; aug];
            let mut sfth = vec![0.0; p];
            sops.eval_drift(t, yr, ar, &mut sb, &mut sfa, &mut sfth);
            assert_eq!(&b_out[b * aug..(b + 1) * aug], &sb[..], "adj b row {b}");
            assert_eq!(&fa[b * aug..(b + 1) * aug], &sfa[..], "adj fa row {b}");
            assert_eq!(&fth[b * p..(b + 1) * p], &sfth[..], "adj fth row {b}");

            let mut ss = vec![0.0; aug];
            let mut sga = vec![0.0; aug];
            let mut sgth = vec![0.0; p];
            let dwr = &dw[b * aug..(b + 1) * aug];
            sops.eval_diffusion(t, yr, ar, dwr, &mut ss, &mut sga, &mut sgth);
            assert_eq!(&s_out[b * aug..(b + 1) * aug], &ss[..], "adj σ row {b}");
            assert_eq!(&ga[b * aug..(b + 1) * aug], &sga[..], "adj ga row {b}");
            assert_eq!(&gth[b * p..(b + 1) * p], &sgth[..], "adj gth row {b}");
        }
    }

    /// Fast-tier batched kernels reassociate the MLP dot products, so
    /// they match the exact batched kernels only to relative tolerance.
    #[test]
    fn fast_batched_kernels_match_exact_to_tolerance() {
        use crate::sde::BatchSde;
        let model = tiny_model();
        let th = theta_full(&model, 11);
        let sys = PosteriorSde::new(&model);
        let aug = sys.state_dim();
        let bsz = 4;
        let mut y = vec![0.0; bsz * aug];
        PrngKey::from_seed(12).fill_normal(0, &mut y);
        let t = 0.2;

        let mut drift_exact = vec![0.0; bsz * aug];
        sys.drift_batch(t, &y, &th, &mut drift_exact);
        let mut diff_exact = vec![0.0; bsz * aug];
        sys.diffusion_batch(t, &y, &th, &mut diff_exact);

        let mut drift_fast = vec![0.0; bsz * aug];
        sys.drift_batch_fast(t, &y, &th, &mut drift_fast);
        let mut diff_fast = vec![0.0; bsz * aug];
        sys.diffusion_batch_fast(t, &y, &th, &mut diff_fast);

        for i in 0..bsz * aug {
            let scale = drift_exact[i].abs().max(1.0);
            assert!(
                (drift_exact[i] - drift_fast[i]).abs() <= 1e-10 * scale,
                "drift[{i}]: {} vs {}",
                drift_exact[i],
                drift_fast[i]
            );
            let scale = diff_exact[i].abs().max(1.0);
            assert!(
                (diff_exact[i] - diff_fast[i]).abs() <= 1e-10 * scale,
                "diffusion[{i}]: {} vs {}",
                diff_exact[i],
                diff_fast[i]
            );
        }
    }

    #[test]
    fn ode_mode_zero_diffusion_zero_kl() {
        let model = LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            enc_hidden: 6,
            diffusion: DiffusionMode::Off,
            ..Default::default()
        });
        let th = theta_full(&model, 5);
        let sys = PosteriorSde::new(&model);
        let y = [0.2, -0.5, 0.9, 0.0];
        let mut out = [0.0; 4];
        sys.drift(0.1, &y, &th, &mut out);
        assert_eq!(out[3], 0.0, "ODE mode must have zero KL drift");
        sys.diffusion(0.1, &y, &th, &mut out);
        assert_eq!(out, [0.0; 4]);
    }
}
