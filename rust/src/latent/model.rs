//! Latent-SDE architecture (App. 9.9 for the toy datasets, App. 9.11 for
//! mocap).
//!
//! All weights live in one flat parameter vector. Layout (offsets recorded
//! at construction):
//! `[prior_drift | post_drift | diffusion nets | decoder | encoder |
//!   q-heads | p(z0) mean | p(z0) logvar]`.

use crate::nn::{Activation, GruCell, Linear, Mlp, ParamBuilder};
use crate::nn::params::Init;
use crate::prng::PrngKey;

/// Diffusion configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DiffusionMode {
    /// Per-dimension nets `σ_i = floor + scale·sigmoid(net_i(z_i))`
    /// (App. 9.9.2/9.11: "multiple small neural networks, each for a
    /// single dimension", sigmoid applied at the end).
    PerDimNets { floor: f64, scale: f64 },
    /// σ ≡ 0: the latent ODE baseline of Table 2.
    Off,
}

/// Recognition-network flavor.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EncoderKind {
    /// GRU over the observations, run in reverse time (App. 9.9): emits a
    /// context vector at every observation and `q(z_0)` at the start.
    GruBackward,
    /// MLP over the first `n_frames` observations (App. 9.11, mocap):
    /// emits one static context vector and `q(z_0)`.
    FirstFramesMlp { n_frames: usize },
}

/// Hyperparameters of the latent SDE model.
#[derive(Clone, Copy, Debug)]
pub struct LatentSdeConfig {
    pub obs_dim: usize,
    pub latent_dim: usize,
    pub context_dim: usize,
    /// Hidden width of drift/decoder MLPs (paper: 100 for toys).
    pub hidden: usize,
    /// Hidden width of the per-dim diffusion nets.
    pub diff_hidden: usize,
    /// GRU hidden size (paper: 100 for toys).
    pub enc_hidden: usize,
    pub encoder: EncoderKind,
    pub diffusion: DiffusionMode,
    /// Fixed Gaussian observation noise std (paper: 0.01 for toys).
    pub obs_noise_std: f64,
}

impl Default for LatentSdeConfig {
    fn default() -> Self {
        LatentSdeConfig {
            obs_dim: 1,
            latent_dim: 4,
            context_dim: 1,
            hidden: 100,
            diff_hidden: 16,
            enc_hidden: 100,
            encoder: EncoderKind::GruBackward,
            diffusion: DiffusionMode::PerDimNets { floor: 1e-3, scale: 1.0 },
            obs_noise_std: 0.01,
        }
    }
}

/// Encoder networks (either flavor shares the two q-heads).
#[derive(Clone, Debug)]
pub enum Encoder {
    Gru { cell: GruCell, ctx_head: Linear },
    Mlp { net: Mlp, n_frames: usize },
}

/// The full latent SDE model: layer descriptors + parameter layout.
#[derive(Clone, Debug)]
pub struct LatentSdeModel {
    pub cfg: LatentSdeConfig,
    /// Prior drift `h_θ([z, t]) → R^dz`.
    pub prior_drift: Mlp,
    /// Posterior drift `h_φ([z, t, ctx]) → R^dz`.
    pub post_drift: Mlp,
    /// Per-dimension diffusion nets `[z_i] → R` (sigmoid output). Empty in
    /// ODE mode.
    pub diffusion: Vec<Mlp>,
    /// Decoder `z → x̂`.
    pub decoder: Mlp,
    pub encoder: Encoder,
    /// Head producing `(μ_0, logvar_0)` of `q(z_0)` from the encoder state.
    pub q_head: Linear,
    /// Learnable `p(z_0) = N(pz0_mean, exp(pz0_logvar))`.
    pub pz0_mean_off: usize,
    pub pz0_logvar_off: usize,
    /// Total trainable parameter count.
    pub n_params: usize,
}

impl LatentSdeModel {
    pub fn new(cfg: LatentSdeConfig) -> Self {
        let mut pb = ParamBuilder::new();
        let dz = cfg.latent_dim;
        let dx = cfg.obs_dim;
        let dc = cfg.context_dim;

        let prior_drift = Mlp::new(
            &mut pb,
            &[dz + 1, cfg.hidden, dz],
            Activation::Softplus,
            Activation::Identity,
        );
        let post_drift = Mlp::new(
            &mut pb,
            &[dz + 1 + dc, cfg.hidden, dz],
            Activation::Softplus,
            Activation::Identity,
        );
        let diffusion = match cfg.diffusion {
            DiffusionMode::PerDimNets { .. } => (0..dz)
                .map(|_| {
                    Mlp::new(&mut pb, &[1, cfg.diff_hidden, 1], Activation::Softplus, Activation::Sigmoid)
                })
                .collect(),
            DiffusionMode::Off => Vec::new(),
        };
        let decoder =
            Mlp::new(&mut pb, &[dz, cfg.hidden, dx], Activation::Softplus, Activation::Identity);

        let (encoder, enc_out_dim) = match cfg.encoder {
            EncoderKind::GruBackward => {
                let cell = GruCell::new(&mut pb, dx, cfg.enc_hidden);
                let ctx_head = Linear::new(&mut pb, cfg.enc_hidden, dc);
                (Encoder::Gru { cell, ctx_head }, cfg.enc_hidden)
            }
            EncoderKind::FirstFramesMlp { n_frames } => {
                let net = Mlp::new(
                    &mut pb,
                    &[dx * n_frames, cfg.enc_hidden, cfg.enc_hidden + dc],
                    Activation::Softplus,
                    Activation::Identity,
                );
                (Encoder::Mlp { net, n_frames }, cfg.enc_hidden)
            }
        };
        let q_head = Linear::new(&mut pb, enc_out_dim, 2 * dz);
        let pz0_mean_off = pb.alloc(dz, Init::Zeros);
        let pz0_logvar_off = pb.alloc(dz, Init::Zeros);

        let n_params = pb.len();
        let model = LatentSdeModel {
            cfg,
            prior_drift,
            post_drift,
            diffusion,
            decoder,
            encoder,
            q_head,
            pz0_mean_off,
            pz0_logvar_off,
            n_params,
        };
        // Keep the builder around only for init; callers use init_params.
        model.check_consistency(&pb);
        model
    }

    fn check_consistency(&self, pb: &ParamBuilder) {
        assert_eq!(self.n_params, pb.len());
    }

    /// Initialize a fresh parameter vector.
    pub fn init_params(&self, key: PrngKey) -> Vec<f64> {
        // Rebuild the builder deterministically to get the init specs.
        // (Cheap: layout is a pure function of cfg.)
        let fresh = LatentSdeModel::builder_for(self.cfg);
        fresh.init(key)
    }

    fn builder_for(cfg: LatentSdeConfig) -> ParamBuilder {
        let mut pb = ParamBuilder::new();
        let dz = cfg.latent_dim;
        let dx = cfg.obs_dim;
        let dc = cfg.context_dim;
        Mlp::new(&mut pb, &[dz + 1, cfg.hidden, dz], Activation::Softplus, Activation::Identity);
        Mlp::new(
            &mut pb,
            &[dz + 1 + dc, cfg.hidden, dz],
            Activation::Softplus,
            Activation::Identity,
        );
        if let DiffusionMode::PerDimNets { .. } = cfg.diffusion {
            for _ in 0..dz {
                Mlp::new(&mut pb, &[1, cfg.diff_hidden, 1], Activation::Softplus, Activation::Sigmoid);
            }
        }
        Mlp::new(&mut pb, &[dz, cfg.hidden, dx], Activation::Softplus, Activation::Identity);
        match cfg.encoder {
            EncoderKind::GruBackward => {
                GruCell::new(&mut pb, dx, cfg.enc_hidden);
                Linear::new(&mut pb, cfg.enc_hidden, dc);
                Linear::new(&mut pb, cfg.enc_hidden, 2 * dz);
            }
            EncoderKind::FirstFramesMlp { n_frames } => {
                Mlp::new(
                    &mut pb,
                    &[dx * n_frames, cfg.enc_hidden, cfg.enc_hidden + dc],
                    Activation::Softplus,
                    Activation::Identity,
                );
                Linear::new(&mut pb, cfg.enc_hidden, 2 * dz);
            }
        }
        pb.alloc(dz, Init::Zeros);
        pb.alloc(dz, Init::Zeros);
        pb
    }

    /// Evaluate the diffusion vector `σ(z)` (and optionally `∂σ_i/∂z_i`)
    /// at `z`, honoring the mode. `dsig` may be empty to skip derivatives.
    pub fn diffusion_eval(
        &self,
        params: &[f64],
        z: &[f64],
        sig: &mut [f64],
        mut dsig: Option<&mut [f64]>,
    ) {
        match self.cfg.diffusion {
            DiffusionMode::Off => {
                sig.fill(0.0);
                if let Some(d) = dsig.as_deref_mut() {
                    d.fill(0.0);
                }
            }
            DiffusionMode::PerDimNets { floor, scale } => {
                for i in 0..self.cfg.latent_dim {
                    let net = &self.diffusion[i];
                    let mut cache = net.cache();
                    let mut out = [0.0];
                    net.forward(params, &z[i..i + 1], &mut cache, &mut out);
                    sig[i] = floor + scale * out[0];
                    if let Some(d) = dsig.as_deref_mut() {
                        let mut dx = [0.0];
                        let mut dummy = vec![0.0; 0];
                        // dσ_i/dz_i = scale · d(net)/dz_i. Use a throwaway
                        // param-grad buffer (not accumulated here).
                        let mut dp = vec![0.0; 0];
                        let _ = (&mut dummy, &mut dp);
                        let mut dp_full = vec![0.0; params.len()];
                        net.vjp(params, &mut cache, &[scale], &mut dx, &mut dp_full);
                        d[i] = dx[0];
                    }
                }
            }
        }
    }

    /// Number of frames the encoder consumes before prediction starts
    /// (mocap protocol: condition on the first 3 frames).
    pub fn encoder_warmup_frames(&self) -> usize {
        match self.encoder {
            Encoder::Gru { .. } => 0,
            Encoder::Mlp { n_frames, .. } => n_frames,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_is_deterministic_and_complete() {
        let cfg = LatentSdeConfig { obs_dim: 3, latent_dim: 4, ..Default::default() };
        let m1 = LatentSdeModel::new(cfg);
        let m2 = LatentSdeModel::new(cfg);
        assert_eq!(m1.n_params, m2.n_params);
        let p1 = m1.init_params(PrngKey::from_seed(1));
        let p2 = m2.init_params(PrngKey::from_seed(1));
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), m1.n_params);
    }

    #[test]
    fn ode_mode_has_fewer_params() {
        let sde = LatentSdeModel::new(LatentSdeConfig::default());
        let ode = LatentSdeModel::new(LatentSdeConfig {
            diffusion: DiffusionMode::Off,
            ..Default::default()
        });
        assert!(ode.n_params < sde.n_params);
        assert!(ode.diffusion.is_empty());
    }

    #[test]
    fn diffusion_bounded_and_positive() {
        let cfg = LatentSdeConfig::default();
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(2));
        let z = [0.5, -1.0, 2.0, 0.0];
        let mut sig = [0.0; 4];
        model.diffusion_eval(&params, &z, &mut sig, None);
        for (i, &s) in sig.iter().enumerate() {
            assert!(s > 0.0 && s < 1.1, "σ[{i}] = {s} out of (0, 1.1)");
        }
    }

    #[test]
    fn diffusion_derivative_matches_fd() {
        let model = LatentSdeModel::new(LatentSdeConfig::default());
        let params = model.init_params(PrngKey::from_seed(3));
        let z = [0.3, -0.5, 1.2, 0.1];
        let mut sig = [0.0; 4];
        let mut dsig = [0.0; 4];
        model.diffusion_eval(&params, &z, &mut sig, Some(&mut dsig));
        let eps = 1e-6;
        for i in 0..4 {
            let mut zp = z;
            zp[i] += eps;
            let mut hi = [0.0; 4];
            model.diffusion_eval(&params, &zp, &mut hi, None);
            zp[i] -= 2.0 * eps;
            let mut lo = [0.0; 4];
            model.diffusion_eval(&params, &zp, &mut lo, None);
            let fd = (hi[i] - lo[i]) / (2.0 * eps);
            assert!((fd - dsig[i]).abs() < 1e-6, "dσ[{i}]: fd {fd} vs {}", dsig[i]);
        }
    }

    #[test]
    fn mocap_architecture_param_count_order() {
        // App. 9.11: mocap model ~11.6k params with 6-dim latent, 50-dim
        // obs, 3-dim context. Our exact count differs (architectural
        // details), but should be the same order of magnitude.
        let cfg = LatentSdeConfig {
            obs_dim: 50,
            latent_dim: 6,
            context_dim: 3,
            hidden: 30,
            diff_hidden: 8,
            enc_hidden: 30,
            encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
            ..Default::default()
        };
        let model = LatentSdeModel::new(cfg);
        assert!(
            model.n_params > 4000 && model.n_params < 40000,
            "param count {} not in expected range",
            model.n_params
        );
    }
}
