//! One ELBO training step for a latent SDE (Eq. 10) with gradients via the
//! stochastic adjoint.
//!
//! Loss for one sequence `x_{t_0..t_{K-1}}`:
//!
//! ```text
//! L = − Σ_k log N(x_k | dec(z_k), s²I)          (reconstruction)
//!     + β · ( ℓ_T + KL(q(z_0) ‖ p(z_0)) )       (path KL + initial KL)
//! ```
//!
//! where `ℓ_T = ∫ ½|u|² dt` accumulates in the forward solve (see
//! [`super::posterior`]), `β` is the KL weight (annealed per §7.3), and
//! `q(z_0)` comes from the recognition network.
//!
//! Gradient flow, in one pass over the sequence:
//! 1. encoder forward (contexts per interval + `q(z_0)`), reparameterized
//!    sample `z_0 = μ₀ + e^{½lv₀}·ε`;
//! 2. piecewise forward SDE solve (Heun) recording `(z, ℓ)` at obs times;
//! 3. backward: interval-by-interval stochastic adjoint with the context
//!    in the parameter tail; decoder VJPs injected at each observation;
//! 4. `∂L/∂z_0` → reparameterization + Gaussian-KL grads → `q`-head;
//!    `∂L/∂ctx_k` → encoder BPTT; decoder grads accumulated in step 3.
//!
//! The result is a single flat gradient aligned with
//! [`LatentSdeModel::init_params`]'s layout — ready for
//! [`crate::optim::Adam`].

use super::model::{Encoder, LatentSdeModel};
use super::posterior::{CtxAdjointOps, CtxBatchForwardFunc, PosteriorSde};
use crate::adjoint::batch::BatchBackwardSolver;
use crate::adjoint::BackwardSolver;
use crate::api::SdeProblem;
use crate::brownian::{BatchBrownian, BrownianPath};
use crate::nn::gru::{GruBatchCache, GruStepCache};
use crate::nn::MlpBatchCache;
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;
use crate::sde::KernelTier;
use crate::solvers::{batch_grid_core, uniform_grid, BatchForwardFunc, Method, SolveStats};

/// Per-step ELBO configuration.
#[derive(Clone, Copy, Debug)]
pub struct ElboConfig {
    /// Solver sub-steps per observation interval (§7.3 uses 1/5 of the
    /// smallest gap, i.e. 5 sub-steps).
    pub substeps: usize,
    /// KL weight β (validated over {1, 0.1, 0.01, 0.001} in the paper).
    pub kl_weight: f64,
    /// Execution configuration ([`crate::runtime::ExecConfig`]).
    /// `exec.tier` selects the kernel tier for the batched net
    /// evaluations (encoder, drift / diffusion nets, decoder): `Exact`
    /// (the default) keeps the bit-identical-to-scalar contract; `Fast`
    /// routes through the reassociated fast kernels, equal to exact only
    /// to relative tolerance. The scalar [`elbo_step`] ignores the tier —
    /// the fast tier is a property of batched sweeps.
    pub exec: ExecConfig,
}

impl Default for ElboConfig {
    fn default() -> Self {
        ElboConfig { substeps: 5, kl_weight: 1.0, exec: ExecConfig::default() }
    }
}

impl ElboConfig {
    /// Select the kernel tier (shorthand for setting `exec.tier`).
    pub fn tier(mut self, tier: KernelTier) -> Self {
        self.exec.tier = tier;
        self
    }

    /// Replace the whole execution configuration.
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }
}

/// Outputs of one ELBO step.
#[derive(Clone, Debug)]
pub struct ElboOutput {
    /// Total loss (negative ELBO, up to the constant β-weighting choice).
    pub loss: f64,
    /// Σ log p(x_k | z_k).
    pub log_px: f64,
    /// Path KL `ℓ_T`.
    pub kl_path: f64,
    /// `KL(q(z_0) ‖ p(z_0))`.
    pub kl_z0: f64,
    /// Mean squared reconstruction error per observed value.
    pub recon_mse: f64,
    /// Flat gradient (length `model.n_params`).
    pub grad: Vec<f64>,
    /// Latent states at observation times, row-major `(K, dz)` (useful for
    /// diagnostics/visualization).
    pub z_obs: Vec<f64>,
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
}

/// Gaussian log-density `log N(x | mean, std²I)` summed over dims.
fn gaussian_logpdf(x: &[f64], mean: &[f64], std: f64) -> f64 {
    let var = std * std;
    let log_norm = -0.5 * (2.0 * std::f64::consts::PI * var).ln();
    x.iter()
        .zip(mean)
        .map(|(xi, mi)| {
            let d = xi - mi;
            log_norm - 0.5 * d * d / var
        })
        .sum()
}

/// Encoder forward results.
struct EncodeResult {
    /// Context per interval k=1..K-1 (row-major `(K-1, dc)`); interval k
    /// spans `[t_{k-1}, t_k]`.
    ctx: Vec<f64>,
    mu0: Vec<f64>,
    logvar0: Vec<f64>,
    /// GRU step caches (reverse order as processed) or the MLP cache input.
    gru_caches: Vec<GruStepCache>,
    mlp_input: Vec<f64>,
    /// Encoder hidden state fed to the q-head.
    q_in: Vec<f64>,
}

fn encode(model: &LatentSdeModel, params: &[f64], obs: &[f64], n_obs: usize) -> EncodeResult {
    let dx = model.cfg.obs_dim;
    let dz = model.cfg.latent_dim;
    let dc = model.cfg.context_dim;
    match &model.encoder {
        Encoder::Gru { cell, ctx_head } => {
            // Process observations in reverse: step s handles obs K-1-s.
            let mut h = vec![0.0; model.cfg.enc_hidden];
            let mut caches = Vec::with_capacity(n_obs);
            let mut hs = Vec::with_capacity(n_obs); // hidden after each step
            for s in 0..n_obs {
                let k = n_obs - 1 - s;
                let x = &obs[k * dx..(k + 1) * dx];
                let mut cache = GruStepCache::default();
                let mut h_next = vec![0.0; model.cfg.enc_hidden];
                cell.forward(params, x, &h, &mut cache, &mut h_next);
                caches.push(cache);
                h = h_next;
                hs.push(h.clone());
            }
            // ctx_k (interval [t_{k-1}, t_k]) from h after step s = K-1-k,
            // i.e. after processing observations k..K-1 ("the future").
            let mut ctx = vec![0.0; (n_obs - 1) * dc];
            for k in 1..n_obs {
                let s = n_obs - 1 - k;
                ctx_head.forward(params, &hs[s], &mut ctx[(k - 1) * dc..k * dc]);
            }
            // q(z0) from the full pass.
            let q_in = hs[n_obs - 1].clone();
            let mut q_out = vec![0.0; 2 * dz];
            model.q_head.forward(params, &q_in, &mut q_out);
            EncodeResult {
                ctx,
                mu0: q_out[..dz].to_vec(),
                logvar0: q_out[dz..].to_vec(),
                gru_caches: caches,
                mlp_input: Vec::new(),
                q_in,
            }
        }
        Encoder::Mlp { net, n_frames } => {
            let n_frames = (*n_frames).min(n_obs);
            let mut input = vec![0.0; dx * n_frames];
            input.copy_from_slice(&obs[..dx * n_frames]);
            let mut cache = net.cache();
            let mut out = vec![0.0; model.cfg.enc_hidden + dc];
            net.forward(params, &input, &mut cache, &mut out);
            let q_in = out[..model.cfg.enc_hidden].to_vec();
            let ctx_static = &out[model.cfg.enc_hidden..];
            let mut ctx = vec![0.0; (n_obs - 1) * dc];
            for k in 0..n_obs - 1 {
                ctx[k * dc..(k + 1) * dc].copy_from_slice(ctx_static);
            }
            let mut q_out = vec![0.0; 2 * dz];
            model.q_head.forward(params, &q_in, &mut q_out);
            EncodeResult {
                ctx,
                mu0: q_out[..dz].to_vec(),
                logvar0: q_out[dz..].to_vec(),
                gru_caches: Vec::new(),
                mlp_input: input,
                q_in,
            }
        }
    }
}

/// One ELBO evaluation with full gradients for a single sequence.
///
/// `times` are the observation times (ascending, length K ≥ 2); `obs` is
/// row-major `(K, obs_dim)`. `key` drives the reparameterization sample
/// and the Brownian path.
pub fn elbo_step(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs: &[f64],
    key: PrngKey,
    cfg: &ElboConfig,
) -> ElboOutput {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();
    assert!(n_obs >= 2, "elbo_step: need at least two observations");
    assert_eq!(obs.len(), n_obs * dx, "elbo_step: obs layout mismatch");
    let s_obs = model.cfg.obs_noise_std;
    let beta = cfg.kl_weight;

    // ---- 1. Encode. --------------------------------------------------
    let enc = encode(model, params, obs, n_obs);

    // Reparameterized z0.
    let (k_eps, k_bm) = key.split();
    let mut eps = vec![0.0; dz];
    k_eps.fill_normal(0, &mut eps);
    let mut z0 = vec![0.0; dz];
    for i in 0..dz {
        z0[i] = enc.mu0[i] + (0.5 * enc.logvar0[i]).exp() * eps[i];
    }

    // ---- 2. Forward solve with running KL. ---------------------------
    // Piecewise solve through the problem API: one shared Brownian source
    // across intervals, the encoder context swapped into the parameter
    // tail per interval, and the (z, ℓ) state saved at each obs time.
    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();
    let aug = dz + 1;
    let mut theta_full = vec![0.0; n_sde + dc];
    theta_full[..n_sde].copy_from_slice(&params[..n_sde]);

    let mut y0_aug = vec![0.0; aug];
    y0_aug[..dz].copy_from_slice(&z0);
    let mut sol = SdeProblem::new(&sde, &y0_aug, (times[0], times[n_obs - 1]))
        .params(&theta_full)
        .key(k_bm)
        .solve_intervals(times, cfg.substeps, Method::Heun, |k, th| {
            th[n_sde..].copy_from_slice(&enc.ctx[k * dc..(k + 1) * dc]);
        });
    let forward_stats = sol.stats;
    let y_obs = std::mem::take(&mut sol.states); // (z, l) at each obs time
    let kl_path = y_obs[(n_obs - 1) * aug + dz];

    // ---- 3. Reconstruction terms. ------------------------------------
    let mut dec_cache = model.decoder.cache();
    let mut xhat = vec![0.0; dx];
    let mut log_px = 0.0;
    let mut sq_err = 0.0;
    for k in 0..n_obs {
        let z_k = &y_obs[k * aug..k * aug + dz];
        model.decoder.forward(params, z_k, &mut dec_cache, &mut xhat);
        let x_k = &obs[k * dx..(k + 1) * dx];
        log_px += gaussian_logpdf(x_k, &xhat, s_obs);
        sq_err += x_k.iter().zip(&xhat).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
    }
    let recon_mse = sq_err / (n_obs * dx) as f64;

    // KL(q(z0) || p(z0)) with learnable Gaussian prior.
    let mu_p = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
    let lv_p = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
    let mut kl_z0 = 0.0;
    for i in 0..dz {
        let var_q = enc.logvar0[i].exp();
        let var_p = lv_p[i].exp();
        let dmu = enc.mu0[i] - mu_p[i];
        kl_z0 += 0.5 * (lv_p[i] - enc.logvar0[i] + (var_q + dmu * dmu) / var_p - 1.0);
    }

    let loss = -log_px + beta * (kl_path + kl_z0);

    // ---- 4. Backward pass. -------------------------------------------
    let mut grad = vec![0.0; model.n_params];
    let mut dctx = vec![0.0; (n_obs - 1) * dc];
    let mut backward_stats = SolveStats::default();

    // Adjoint state: a = [a_z (dz), a_ℓ].
    let mut a = vec![0.0; aug];
    a[dz] = beta; // ∂loss/∂ℓ_T

    // Decoder VJP helper: adds ∂(−log p(x_k|z_k))/∂z into `a_z` and the
    // decoder parameter grads into `grad`.
    let add_obs_grad = |k: usize,
                            a: &mut [f64],
                            grad: &mut [f64],
                            dec_cache: &mut crate::nn::MlpCache,
                            y_obs: &[f64]| {
        let z_k = &y_obs[k * aug..k * aug + dz];
        let mut xh = vec![0.0; dx];
        model.decoder.forward(params, z_k, dec_cache, &mut xh);
        let x_k = &obs[k * dx..(k + 1) * dx];
        // d(−log N)/dx̂ = (x̂ − x)/s².
        let inv_var = 1.0 / (s_obs * s_obs);
        let dxh: Vec<f64> = xh.iter().zip(x_k).map(|(h, x)| (h - x) * inv_var).collect();
        let mut dz_buf = vec![0.0; dz];
        model.decoder.vjp(params, dec_cache, &dxh, &mut dz_buf, grad);
        for i in 0..dz {
            a[i] += dz_buf[i];
        }
    };

    add_obs_grad(n_obs - 1, &mut a, &mut grad, &mut dec_cache, &y_obs);

    let mut yb = y_obs[(n_obs - 1) * aug..].to_vec();
    let mut ath_full = vec![0.0; n_sde + dc];
    // One solver for all intervals: scratch buffers are O(n_params) and
    // re-allocating them per interval dominated allocation traffic
    // (EXPERIMENTS.md §Perf).
    let mut solver = BackwardSolver::new(&sde, &theta_full);
    for k in (1..n_obs).rev() {
        theta_full[n_sde..].copy_from_slice(&enc.ctx[(k - 1) * dc..k * dc]);
        solver.set_theta(&theta_full);
        let grid = uniform_grid(times[k], times[k - 1], cfg.substeps); // descending
        ath_full.fill(0.0);
        // Replay the forward pass's realized path via the solution's
        // noise handle.
        solver.solve_interval(
            &grid,
            &mut yb,
            &mut a,
            &mut ath_full,
            &mut sol.noise,
            &mut backward_stats,
        );
        for (g, a) in grad[..n_sde].iter_mut().zip(&ath_full[..n_sde]) {
            *g += a;
        }
        dctx[(k - 1) * dc..k * dc].copy_from_slice(&ath_full[n_sde..]);
        // Inject the observation gradient at t_{k-1} and re-anchor the
        // path reconstruction at the stored forward state.
        add_obs_grad(k - 1, &mut a, &mut grad, &mut dec_cache, &y_obs);
        yb.copy_from_slice(&y_obs[(k - 1) * aug..k * aug]);
    }

    // ---- 5. z0 / q(z0) / p(z0) gradients. ------------------------------
    // Reparameterization: z0 = μ0 + e^{½lv0}·ε.
    let mut dmu0 = vec![0.0; dz];
    let mut dlv0 = vec![0.0; dz];
    for i in 0..dz {
        dmu0[i] = a[i];
        dlv0[i] = a[i] * eps[i] * 0.5 * (0.5 * enc.logvar0[i]).exp();
    }
    // KL(q||p) gradients (weighted by β).
    for i in 0..dz {
        let var_q = enc.logvar0[i].exp();
        let var_p = lv_p[i].exp();
        let dmu = enc.mu0[i] - mu_p[i];
        dmu0[i] += beta * dmu / var_p;
        dlv0[i] += beta * 0.5 * (var_q / var_p - 1.0);
        grad[model.pz0_mean_off + i] += beta * (-dmu / var_p);
        grad[model.pz0_logvar_off + i] +=
            beta * 0.5 * (1.0 - (var_q + dmu * dmu) / var_p);
    }

    // ---- 6. Encoder backward. ------------------------------------------
    // q-head VJP.
    let dq_out: Vec<f64> = dmu0.iter().chain(dlv0.iter()).copied().collect();
    let mut dq_in = vec![0.0; enc.q_in.len()];
    model.q_head.vjp(params, &enc.q_in, &dq_out, &mut dq_in, &mut grad);

    match &model.encoder {
        Encoder::Gru { cell, ctx_head } => {
            // BPTT over the reverse-order GRU. Hidden after step s was used
            // by ctx_head for interval k = K-1-s (s ≤ K-2) and by the
            // q-head at s = K-1.
            let hd = model.cfg.enc_hidden;
            let mut dh = vec![0.0; hd];
            for s in (0..n_obs).rev() {
                if s == n_obs - 1 {
                    for i in 0..hd {
                        dh[i] += dq_in[i];
                    }
                } else {
                    let k = n_obs - 1 - s;
                    let h_s = &enc.gru_caches[s + 1].h; // h after step s == input h of step s+1
                    ctx_head.vjp(
                        params,
                        h_s,
                        &dctx[(k - 1) * dc..k * dc],
                        &mut dh,
                        &mut grad,
                    );
                }
                let mut dh_prev = vec![0.0; hd];
                let mut dx_sink = vec![0.0; dx];
                cell.vjp(params, &enc.gru_caches[s], &dh, &mut dx_sink, &mut dh_prev, &mut grad);
                dh = dh_prev;
            }
        }
        Encoder::Mlp { net, .. } => {
            // Static context: sum interval gradients.
            let mut dout = vec![0.0; model.cfg.enc_hidden + dc];
            dout[..model.cfg.enc_hidden].copy_from_slice(&dq_in);
            for k in 0..n_obs - 1 {
                for c in 0..dc {
                    dout[model.cfg.enc_hidden + c] += dctx[k * dc + c];
                }
            }
            let mut cache = net.cache();
            let mut out = vec![0.0; model.cfg.enc_hidden + dc];
            net.forward(params, &enc.mlp_input, &mut cache, &mut out);
            let mut dx_sink = vec![0.0; enc.mlp_input.len()];
            net.vjp(params, &mut cache, &dout, &mut dx_sink, &mut grad);
        }
    }

    let z_obs: Vec<f64> = (0..n_obs)
        .flat_map(|k| y_obs[k * aug..k * aug + dz].to_vec())
        .collect();

    ElboOutput {
        loss,
        log_px,
        kl_path,
        kl_z0,
        recon_mse,
        grad,
        z_obs,
        forward_stats,
        backward_stats,
    }
}

/// Multi-sample ELBO estimate.
#[derive(Clone, Debug)]
pub struct MultiElboOutput {
    /// Mean loss over samples (the S-sample Monte Carlo ELBO estimate).
    pub loss: f64,
    /// Mean `Σ log p(x_k | z_k)` over samples.
    pub log_px: f64,
    /// Mean path KL over samples.
    pub kl_path: f64,
    /// `KL(q(z_0) ‖ p(z_0))` — shared by all samples (one encoding).
    pub kl_z0: f64,
    /// Mean squared reconstruction error per observed value, over samples.
    pub recon_mse: f64,
    /// Per-sample losses (length `n_samples`).
    pub per_sample_loss: Vec<f64>,
    /// Per-sample forward solve statistics.
    pub forward_stats: SolveStats,
}

/// S-sample ELBO *estimate* (loss components only — no gradients) on the
/// batched SoA engine: one encoder pass, S reparameterized `z_0` draws on
/// independent Brownian streams, and a **single batched piecewise solve**
/// advancing all S posterior paths together per interval (batched MLP
/// forward per stage instead of S scalar net passes).
///
/// Sample `s` uses `key.fold_in(s)` split into (ε-draw, Brownian) keys —
/// independent of `n_samples`, so sample `s`'s loss is the same float in
/// an S-sample call as in an (S+1)-sample call (pinned by tests). The
/// single-sample *training* step (with gradients) remains
/// [`elbo_step`]; this estimator is the cheap way to tighten evaluation
/// ELBOs (validation curves, model comparison) by averaging S samples.
pub fn elbo_value_multi(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs: &[f64],
    key: PrngKey,
    cfg: &ElboConfig,
    n_samples: usize,
) -> MultiElboOutput {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();
    assert!(n_obs >= 2, "elbo_value_multi: need at least two observations");
    assert_eq!(obs.len(), n_obs * dx, "elbo_value_multi: obs layout mismatch");
    assert!(n_samples > 0, "elbo_value_multi: need at least one sample");
    let s_obs = model.cfg.obs_noise_std;
    let beta = cfg.kl_weight;
    let bsz = n_samples;

    // ---- 1. Encode once; S reparameterized z0 draws. -----------------
    // One-row batched encode: bit-identical to the scalar `encode` in the
    // exact tier (pinned row-identity), and the only way the fast tier
    // keeps this estimator float-equal to its R-request batched twin
    // (`elbo_value_multi_batch`) — both then run the same fast kernels.
    let enc = encode_batch(model, params, &[obs], n_obs, cfg.exec.tier == KernelTier::Fast);
    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();
    let aug = dz + 1;

    let mut y = vec![0.0; bsz * aug];
    let mut eps = vec![0.0; dz];
    let mut bm_sources = Vec::with_capacity(bsz);
    for s in 0..bsz {
        let (k_eps, k_bm) = key.fold_in(s as u64).split();
        k_eps.fill_normal(0, &mut eps);
        for i in 0..dz {
            y[s * aug + i] = enc.mu0[i] + (0.5 * enc.logvar0[i]).exp() * eps[i];
        }
        bm_sources.push(BrownianPath::new(k_bm, aug, times[0], times[n_obs - 1]));
    }
    let mut bm = BatchBrownian::new(bm_sources);

    // ---- 2. Batched piecewise forward solve with running KL. ---------
    let mut theta_full = vec![0.0; n_sde + dc];
    theta_full[..n_sde].copy_from_slice(&params[..n_sde]);
    let mut y_obs = vec![0.0; n_obs * bsz * aug];
    y_obs[..bsz * aug].copy_from_slice(&y);
    let mut forward_stats = SolveStats::default();
    let mut y_next = vec![0.0; bsz * aug];
    for k in 1..n_obs {
        theta_full[n_sde..].copy_from_slice(&enc.ctx[(k - 1) * dc..k * dc]);
        let grid = uniform_grid(times[k - 1], times[k], cfg.substeps.max(1));
        let mut sys = BatchForwardFunc::for_method_tier(
            &sde,
            &theta_full,
            bsz,
            Method::Heun,
            cfg.exec.tier,
        );
        let st = batch_grid_core(&mut sys, Method::Heun, &y, &grid, &mut bm, &mut y_next);
        forward_stats.steps += st.steps;
        forward_stats.nfe_drift += st.nfe_drift;
        forward_stats.nfe_diffusion += st.nfe_diffusion;
        y.copy_from_slice(&y_next);
        y_obs[k * bsz * aug..(k + 1) * bsz * aug].copy_from_slice(&y);
    }

    // ---- 3. Batched decoding + per-sample loss components. -----------
    let mut dec_cache = model.decoder.batch_cache(bsz);
    let mut z_in = vec![0.0; bsz * dz];
    let mut xhat = vec![0.0; bsz * dx];
    let mut log_px_s = vec![0.0; bsz];
    let mut sq_err_s = vec![0.0; bsz];
    for k in 0..n_obs {
        for s in 0..bsz {
            z_in[s * dz..(s + 1) * dz]
                .copy_from_slice(&y_obs[(k * bsz + s) * aug..(k * bsz + s) * aug + dz]);
        }
        if cfg.exec.tier == KernelTier::Fast {
            model.decoder.forward_batch_fast(params, &z_in, &mut dec_cache, &mut xhat);
        } else {
            model.decoder.forward_batch(params, &z_in, &mut dec_cache, &mut xhat);
        }
        let x_k = &obs[k * dx..(k + 1) * dx];
        for s in 0..bsz {
            let xh = &xhat[s * dx..(s + 1) * dx];
            log_px_s[s] += gaussian_logpdf(x_k, xh, s_obs);
            sq_err_s[s] += x_k.iter().zip(xh).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        }
    }

    // KL(q(z0) || p(z0)) — one encoding, shared across samples.
    let mu_p = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
    let lv_p = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
    let mut kl_z0 = 0.0;
    for i in 0..dz {
        let var_q = enc.logvar0[i].exp();
        let var_p = lv_p[i].exp();
        let dmu = enc.mu0[i] - mu_p[i];
        kl_z0 += 0.5 * (lv_p[i] - enc.logvar0[i] + (var_q + dmu * dmu) / var_p - 1.0);
    }

    let mut per_sample_loss = vec![0.0; bsz];
    let (mut loss, mut log_px, mut kl_path, mut recon_mse) = (0.0, 0.0, 0.0, 0.0);
    for s in 0..bsz {
        let kl_s = y_obs[((n_obs - 1) * bsz + s) * aug + dz];
        let l = -log_px_s[s] + beta * (kl_s + kl_z0);
        per_sample_loss[s] = l;
        loss += l;
        log_px += log_px_s[s];
        kl_path += kl_s;
        recon_mse += sq_err_s[s] / (n_obs * dx) as f64;
    }
    let inv = 1.0 / bsz as f64;
    MultiElboOutput {
        loss: loss * inv,
        log_px: log_px * inv,
        kl_path: kl_path * inv,
        kl_z0,
        recon_mse: recon_mse * inv,
        per_sample_loss,
        forward_stats,
    }
}

/// Batched posterior reconstruction for the serving subsystem: R
/// sequences (one per request, each with its own observations and key)
/// advance together through **one batched engine call** — a batched
/// encoder pass ([`encode_batch`]), per-path reparameterized z₀ draws,
/// and a single batched piecewise forward solve with each request's
/// encoder context riding in its parameter-tail row
/// ([`CtxBatchForwardFunc`]). Returns each request's latent trajectory
/// `(K, dz)` (KL row stripped).
///
/// Request `r`'s floats are **bit-identical** to
/// `sample_posterior_path(model, params, times, rows[r], substeps,
/// keys[r])` for any batch composition: the same key split
/// (`key.split()` → ε-draw, Brownian), the same per-row encoder floats
/// (`encode_batch` is pinned row-identical to the scalar encoder), and
/// the same per-row solver floats (the ctx-batch kernels are pinned
/// row-identical to the scalar solve in `latent/posterior.rs` and
/// `tests/trainer_batch.rs`). Pinned again directly in the module tests.
pub fn sample_posterior_paths_batch(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    rows: &[&[f64]],
    substeps: usize,
    keys: &[PrngKey],
) -> Vec<Vec<f64>> {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();
    let aug = dz + 1;
    let c_n = rows.len();
    assert!(n_obs >= 2, "sample_posterior_paths_batch: need at least two observations");
    assert_eq!(rows.len(), keys.len(), "sample_posterior_paths_batch: one key per request");
    for obs in rows {
        assert_eq!(obs.len(), n_obs * dx, "sample_posterior_paths_batch: obs layout mismatch");
    }
    if c_n == 0 {
        return Vec::new();
    }

    let enc = encode_batch(model, params, rows, n_obs, false);
    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();

    let mut y = vec![0.0; c_n * aug];
    let mut eps = vec![0.0; dz];
    let mut bm_sources = Vec::with_capacity(c_n);
    for c in 0..c_n {
        let (k_eps, k_bm) = keys[c].split();
        k_eps.fill_normal(0, &mut eps);
        for i in 0..dz {
            y[c * aug + i] =
                enc.mu0[c * dz + i] + (0.5 * enc.logvar0[c * dz + i]).exp() * eps[i];
        }
        bm_sources.push(BrownianPath::new(k_bm, aug, times[0], times[n_obs - 1]));
    }
    let mut bm = BatchBrownian::new(bm_sources);

    let mut out = vec![vec![0.0; n_obs * dz]; c_n];
    for c in 0..c_n {
        out[c][..dz].copy_from_slice(&y[c * aug..c * aug + dz]);
    }
    let mut y_next = vec![0.0; c_n * aug];
    for k in 1..n_obs {
        let ctx_k = &enc.ctx[(k - 1) * c_n * dc..k * c_n * dc];
        let grid = uniform_grid(times[k - 1], times[k], substeps.max(1));
        let mut sys =
            CtxBatchForwardFunc::new(&sde, &params[..n_sde], ctx_k, c_n, ExecConfig::default());
        batch_grid_core(&mut sys, Method::Heun, &y, &grid, &mut bm, &mut y_next);
        y.copy_from_slice(&y_next);
        for c in 0..c_n {
            out[c][k * dz..(k + 1) * dz].copy_from_slice(&y[c * aug..c * aug + dz]);
        }
    }
    out
}

/// Batched multi-sequence ELBO scoring for the serving subsystem: R
/// requests × S samples = one batched engine call. Each request is
/// encoded in the batched encoder pass; its S posterior sample paths
/// (keys `keys[r].fold_in(s)`, the same derivation as
/// [`elbo_value_multi`]) advance together with all other requests'
/// paths through a single batched piecewise solve with per-path context
/// rows. Returns one [`MultiElboOutput`] per request.
///
/// Request `r`'s loss fields and `per_sample_loss` are **bit-identical**
/// to `elbo_value_multi(model, params, times, rows[r], keys[r], cfg,
/// n_samples)` for any batch composition: the shared-context and
/// per-path-context drift kernels run the same row core
/// (`latent/posterior.rs`), so broadcasting one context over S rows and
/// carrying R·S per-path context rows produce the same per-row floats.
/// (`forward_stats` covers the whole batched solve rather than one
/// request and is *not* part of the equality contract.) Pinned in the
/// module tests.
pub fn elbo_value_multi_batch(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    rows: &[&[f64]],
    keys: &[PrngKey],
    cfg: &ElboConfig,
    n_samples: usize,
) -> Vec<MultiElboOutput> {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();
    let aug = dz + 1;
    let r_n = rows.len();
    let s_n = n_samples;
    assert!(n_obs >= 2, "elbo_value_multi_batch: need at least two observations");
    assert!(s_n > 0, "elbo_value_multi_batch: need at least one sample");
    assert_eq!(rows.len(), keys.len(), "elbo_value_multi_batch: one key per request");
    for obs in rows {
        assert_eq!(obs.len(), n_obs * dx, "elbo_value_multi_batch: obs layout mismatch");
    }
    if r_n == 0 {
        return Vec::new();
    }
    let p_n = r_n * s_n;
    let s_obs = model.cfg.obs_noise_std;
    let beta = cfg.kl_weight;

    // ---- 1. Batched encode (R rows); P = R·S reparameterized z0s. ----
    let enc = encode_batch(model, params, rows, n_obs, cfg.exec.tier == KernelTier::Fast);
    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();

    let mut y = vec![0.0; p_n * aug];
    let mut eps = vec![0.0; dz];
    let mut bm_sources = Vec::with_capacity(p_n);
    for r in 0..r_n {
        for s in 0..s_n {
            let p = r * s_n + s;
            let (k_eps, k_bm) = keys[r].fold_in(s as u64).split();
            k_eps.fill_normal(0, &mut eps);
            for i in 0..dz {
                y[p * aug + i] =
                    enc.mu0[r * dz + i] + (0.5 * enc.logvar0[r * dz + i]).exp() * eps[i];
            }
            bm_sources.push(BrownianPath::new(k_bm, aug, times[0], times[n_obs - 1]));
        }
    }
    let mut bm = BatchBrownian::new(bm_sources);

    // ---- 2. One batched piecewise solve over all P paths, each under
    // its request's context row. --------------------------------------
    let mut y_obs = vec![0.0; n_obs * p_n * aug];
    y_obs[..p_n * aug].copy_from_slice(&y);
    let mut forward_stats = SolveStats::default();
    let mut y_next = vec![0.0; p_n * aug];
    let mut ctx_p = vec![0.0; p_n * dc];
    for k in 1..n_obs {
        for r in 0..r_n {
            let src = &enc.ctx[((k - 1) * r_n + r) * dc..((k - 1) * r_n + r + 1) * dc];
            for s in 0..s_n {
                ctx_p[(r * s_n + s) * dc..(r * s_n + s + 1) * dc].copy_from_slice(src);
            }
        }
        let grid = uniform_grid(times[k - 1], times[k], cfg.substeps.max(1));
        let mut sys = CtxBatchForwardFunc::new(&sde, &params[..n_sde], &ctx_p, p_n, cfg.exec);
        let st = batch_grid_core(&mut sys, Method::Heun, &y, &grid, &mut bm, &mut y_next);
        forward_stats.steps += st.steps;
        forward_stats.nfe_drift += st.nfe_drift;
        forward_stats.nfe_diffusion += st.nfe_diffusion;
        y.copy_from_slice(&y_next);
        y_obs[k * p_n * aug..(k + 1) * p_n * aug].copy_from_slice(&y);
    }

    // ---- 3. Batched decoding + per-path loss components. -------------
    let mut dec_cache = model.decoder.batch_cache(p_n);
    let mut z_in = vec![0.0; p_n * dz];
    let mut xhat = vec![0.0; p_n * dx];
    let mut log_px_p = vec![0.0; p_n];
    let mut sq_err_p = vec![0.0; p_n];
    for k in 0..n_obs {
        for p in 0..p_n {
            z_in[p * dz..(p + 1) * dz]
                .copy_from_slice(&y_obs[(k * p_n + p) * aug..(k * p_n + p) * aug + dz]);
        }
        if cfg.exec.tier == KernelTier::Fast {
            model.decoder.forward_batch_fast(params, &z_in, &mut dec_cache, &mut xhat);
        } else {
            model.decoder.forward_batch(params, &z_in, &mut dec_cache, &mut xhat);
        }
        for r in 0..r_n {
            let x_k = &rows[r][k * dx..(k + 1) * dx];
            for s in 0..s_n {
                let p = r * s_n + s;
                let xh = &xhat[p * dx..(p + 1) * dx];
                log_px_p[p] += gaussian_logpdf(x_k, xh, s_obs);
                sq_err_p[p] +=
                    x_k.iter().zip(xh).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
            }
        }
    }

    // ---- 4. Per-request reduction (the scalar estimator's loop). -----
    let mu_p = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
    let lv_p = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
    let inv = 1.0 / s_n as f64;
    (0..r_n)
        .map(|r| {
            let mut kl_z0 = 0.0;
            for i in 0..dz {
                let var_q = enc.logvar0[r * dz + i].exp();
                let var_p = lv_p[i].exp();
                let dmu = enc.mu0[r * dz + i] - mu_p[i];
                kl_z0 += 0.5
                    * (lv_p[i] - enc.logvar0[r * dz + i] + (var_q + dmu * dmu) / var_p - 1.0);
            }
            let mut per_sample_loss = vec![0.0; s_n];
            let (mut loss, mut log_px, mut kl_path, mut recon_mse) = (0.0, 0.0, 0.0, 0.0);
            for s in 0..s_n {
                let p = r * s_n + s;
                let kl_s = y_obs[((n_obs - 1) * p_n + p) * aug + dz];
                let l = -log_px_p[p] + beta * (kl_s + kl_z0);
                per_sample_loss[s] = l;
                loss += l;
                log_px += log_px_p[p];
                kl_path += kl_s;
                recon_mse += sq_err_p[p] / (n_obs * dx) as f64;
            }
            MultiElboOutput {
                loss: loss * inv,
                log_px: log_px * inv,
                kl_path: kl_path * inv,
                kl_z0,
                recon_mse: recon_mse * inv,
                per_sample_loss,
                forward_stats,
            }
        })
        .collect()
}

/// Output of [`elbo_step_batch`]: minibatch totals plus per-path
/// diagnostics. All scalar fields are **sums over paths** (divide by
/// [`BatchElboOutput::n_paths`] for minibatch means — the trainer owns
/// the scaling so the unreduced floats stay bit-comparable to a scalar
/// loop).
#[derive(Clone, Debug)]
pub struct BatchElboOutput {
    /// Σ over paths of the per-path loss.
    pub loss: f64,
    pub log_px: f64,
    pub kl_path: f64,
    pub kl_z0: f64,
    pub recon_mse: f64,
    /// Σ over paths of the per-path flat gradient, reduced in path order —
    /// bit-identical to summing sequential [`elbo_step`] gradients.
    pub grad: Vec<f64>,
    /// Per-path losses; path `m·S + s` is sample `s` of sequence `m`.
    pub per_path_loss: Vec<f64>,
    /// Total paths = sequences × samples.
    pub n_paths: usize,
    /// Per-path solve statistics (uniform across paths).
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
}

/// Batched encoder results for one chunk of paths (rows are paths).
struct BatchEncode {
    /// Context rows, interval-major: interval `k`'s rows at
    /// `[(k·C + c)·dc ..]`.
    ctx: Vec<f64>,
    mu0: Vec<f64>,
    logvar0: Vec<f64>,
    /// Encoder hidden rows fed to the q-head (`[C×eh]`).
    q_in: Vec<f64>,
    /// GRU step caches in processing order (reverse time), or empty.
    gru_caches: Vec<GruBatchCache>,
    /// Hidden rows after each GRU step (`hs[s]: [C×hd]`), or empty.
    hs: Vec<Vec<f64>>,
    /// The MLP-encoder input rows, or empty.
    mlp_input: Vec<f64>,
}

/// Batched q-head pass over C encoder-state rows: `(μ₀, logvar₀)` rows,
/// de-interleaved from the head's `[C×2dz]` output.
fn q_head_batch(
    model: &LatentSdeModel,
    params: &[f64],
    q_in: &[f64],
    c_n: usize,
    fast: bool,
) -> (Vec<f64>, Vec<f64>) {
    let dz = model.cfg.latent_dim;
    let mut q_out = vec![0.0; c_n * 2 * dz];
    if fast {
        model.q_head.forward_batch_fast(params, q_in, &mut q_out);
    } else {
        model.q_head.forward_batch(params, q_in, &mut q_out);
    }
    let mut mu0 = vec![0.0; c_n * dz];
    let mut logvar0 = vec![0.0; c_n * dz];
    for c in 0..c_n {
        mu0[c * dz..(c + 1) * dz].copy_from_slice(&q_out[c * 2 * dz..c * 2 * dz + dz]);
        logvar0[c * dz..(c + 1) * dz].copy_from_slice(&q_out[c * 2 * dz + dz..(c + 1) * 2 * dz]);
    }
    (mu0, logvar0)
}

/// Batched encoder forward over C paths (`rows[c]` is path c's sequence).
/// With `fast == false`, row-for-row bit-identical to the scalar
/// [`encode`]; with `fast == true` the GRU/MLP/head passes run through
/// the fast-tier nn kernels (tolerance-equal only).
fn encode_batch(
    model: &LatentSdeModel,
    params: &[f64],
    rows: &[&[f64]],
    n_obs: usize,
    fast: bool,
) -> BatchEncode {
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let c_n = rows.len();
    match &model.encoder {
        Encoder::Gru { cell, ctx_head } => {
            let hd = model.cfg.enc_hidden;
            let mut h = vec![0.0; c_n * hd];
            let mut h_next = vec![0.0; c_n * hd];
            let mut x = vec![0.0; c_n * dx];
            let mut caches = Vec::with_capacity(n_obs);
            let mut hs = Vec::with_capacity(n_obs);
            for s in 0..n_obs {
                let k = n_obs - 1 - s;
                for (c, seq) in rows.iter().enumerate() {
                    x[c * dx..(c + 1) * dx].copy_from_slice(&seq[k * dx..(k + 1) * dx]);
                }
                let mut cache = cell.batch_cache(c_n);
                if fast {
                    cell.forward_batch_fast(params, &x, &h, &mut cache, &mut h_next);
                } else {
                    cell.forward_batch(params, &x, &h, &mut cache, &mut h_next);
                }
                caches.push(cache);
                h.copy_from_slice(&h_next);
                hs.push(h.clone());
            }
            let mut ctx = vec![0.0; (n_obs - 1) * c_n * dc];
            for k in 1..n_obs {
                let s = n_obs - 1 - k;
                let ctx_k = &mut ctx[(k - 1) * c_n * dc..k * c_n * dc];
                if fast {
                    ctx_head.forward_batch_fast(params, &hs[s], ctx_k);
                } else {
                    ctx_head.forward_batch(params, &hs[s], ctx_k);
                }
            }
            let q_in = hs[n_obs - 1].clone();
            let (mu0, logvar0) = q_head_batch(model, params, &q_in, c_n, fast);
            BatchEncode { ctx, mu0, logvar0, q_in, gru_caches: caches, hs, mlp_input: Vec::new() }
        }
        Encoder::Mlp { net, n_frames } => {
            let eh = model.cfg.enc_hidden;
            let n_frames = (*n_frames).min(n_obs);
            let din = dx * n_frames;
            let mut input = vec![0.0; c_n * din];
            for (c, seq) in rows.iter().enumerate() {
                input[c * din..(c + 1) * din].copy_from_slice(&seq[..din]);
            }
            let mut cache = net.batch_cache(c_n);
            let mut out = vec![0.0; c_n * (eh + dc)];
            if fast {
                net.forward_batch_fast(params, &input, &mut cache, &mut out);
            } else {
                net.forward_batch(params, &input, &mut cache, &mut out);
            }
            let mut q_in = vec![0.0; c_n * eh];
            let mut ctx = vec![0.0; (n_obs - 1) * c_n * dc];
            for c in 0..c_n {
                q_in[c * eh..(c + 1) * eh].copy_from_slice(&out[c * (eh + dc)..c * (eh + dc) + eh]);
                let ctx_static = &out[c * (eh + dc) + eh..(c + 1) * (eh + dc)];
                for k in 0..n_obs - 1 {
                    ctx[(k * c_n + c) * dc..(k * c_n + c + 1) * dc].copy_from_slice(ctx_static);
                }
            }
            let (mu0, logvar0) = q_head_batch(model, params, &q_in, c_n, fast);
            BatchEncode {
                ctx,
                mu0,
                logvar0,
                q_in,
                gru_caches: Vec::new(),
                hs: Vec::new(),
                mlp_input: input,
            }
        }
    }
}

/// Per-chunk results: per-path rows only — the caller performs the
/// path-ordered reduction so chunk layout never changes a float.
struct ChunkOut {
    /// Per-path flat gradients, `[C × n_params]`.
    grads: Vec<f64>,
    loss: Vec<f64>,
    log_px: Vec<f64>,
    kl_path: Vec<f64>,
    kl_z0: Vec<f64>,
    mse: Vec<f64>,
    forward_stats: SolveStats,
    backward_stats: SolveStats,
}

/// Batched decoder observation-gradient injection at obs time `k`: adds
/// `∂(−log p(x_k|z_k))/∂z` into the `a_z` rows and the decoder parameter
/// gradients into each path's gradient block. Mirrors the scalar
/// `add_obs_grad` float-for-float per row.
#[allow(clippy::too_many_arguments)]
fn add_obs_grad_batch(
    model: &LatentSdeModel,
    params: &[f64],
    rows: &[&[f64]],
    y_obs: &[f64],
    k: usize,
    aug: usize,
    inv_var: f64,
    dec_cache: &mut MlpBatchCache,
    z_in: &mut [f64],
    xhat: &mut [f64],
    dxh: &mut [f64],
    dz_buf: &mut [f64],
    a: &mut [f64],
    grads: &mut [f64],
    fast: bool,
) {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let c_n = rows.len();
    for c in 0..c_n {
        z_in[c * dz..(c + 1) * dz]
            .copy_from_slice(&y_obs[(k * c_n + c) * aug..(k * c_n + c) * aug + dz]);
    }
    if fast {
        model.decoder.forward_batch_fast(params, z_in, dec_cache, xhat);
    } else {
        model.decoder.forward_batch(params, z_in, dec_cache, xhat);
    }
    for c in 0..c_n {
        let x_k = &rows[c][k * dx..(k + 1) * dx];
        for i in 0..dx {
            // d(−log N)/dx̂ = (x̂ − x)/s².
            dxh[c * dx + i] = (xhat[c * dx + i] - x_k[i]) * inv_var;
        }
    }
    dz_buf.fill(0.0);
    if fast {
        model.decoder.vjp_batch_fast(params, dec_cache, dxh, dz_buf, grads, model.n_params);
    } else {
        model.decoder.vjp_batch(params, dec_cache, dxh, dz_buf, grads, model.n_params);
    }
    for c in 0..c_n {
        for i in 0..dz {
            a[c * aug + i] += dz_buf[c * dz + i];
        }
    }
}

/// One chunk of the batched ELBO step: paths `p0..p1` of the flattened
/// (sequence-major) path list advance together through batched encoder,
/// forward solve, augmented adjoint, and encoder BPTT kernels.
#[allow(clippy::too_many_arguments)]
fn elbo_chunk(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs_seqs: &[&[f64]],
    keys: &[PrngKey],
    cfg: &ElboConfig,
    n_samples: usize,
    p0: usize,
    p1: usize,
) -> ChunkOut {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();
    let aug = dz + 1;
    let s_obs = model.cfg.obs_noise_std;
    let beta = cfg.kl_weight;
    let c_n = p1 - p0;
    let rows: Vec<&[f64]> = (0..c_n).map(|c| obs_seqs[(p0 + c) / n_samples]).collect();

    // ---- 1. Batched encode + per-path reparameterized z0. ------------
    let span_encode = crate::obs::span!("elbo.encode");
    let fast = cfg.exec.tier == KernelTier::Fast;
    let enc = encode_batch(model, params, &rows, n_obs, fast);
    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();

    let mut y = vec![0.0; c_n * aug];
    let mut eps = vec![0.0; c_n * dz];
    let mut bm_sources = Vec::with_capacity(c_n);
    for c in 0..c_n {
        let p = p0 + c;
        let (k_eps, k_bm) = keys[p / n_samples].fold_in((p % n_samples) as u64).split();
        k_eps.fill_normal(0, &mut eps[c * dz..(c + 1) * dz]);
        for i in 0..dz {
            y[c * aug + i] =
                enc.mu0[c * dz + i] + (0.5 * enc.logvar0[c * dz + i]).exp() * eps[c * dz + i];
        }
        bm_sources.push(BrownianPath::new(k_bm, aug, times[0], times[n_obs - 1]));
    }
    let mut bm = BatchBrownian::new(bm_sources);
    drop(span_encode);

    // ---- 2. Batched piecewise forward solve with running KL. ---------
    let span_solve = crate::obs::span!("elbo.posterior_solve");
    let mut y_obs = vec![0.0; n_obs * c_n * aug];
    y_obs[..c_n * aug].copy_from_slice(&y);
    let mut forward_stats = SolveStats::default();
    let mut y_next = vec![0.0; c_n * aug];
    for k in 1..n_obs {
        let ctx_k = &enc.ctx[(k - 1) * c_n * dc..k * c_n * dc];
        let grid = uniform_grid(times[k - 1], times[k], cfg.substeps.max(1));
        let mut sys = CtxBatchForwardFunc::new(&sde, &params[..n_sde], ctx_k, c_n, cfg.exec);
        let st = batch_grid_core(&mut sys, Method::Heun, &y, &grid, &mut bm, &mut y_next);
        forward_stats.steps += st.steps;
        forward_stats.nfe_drift += st.nfe_drift;
        forward_stats.nfe_diffusion += st.nfe_diffusion;
        y.copy_from_slice(&y_next);
        y_obs[k * c_n * aug..(k + 1) * c_n * aug].copy_from_slice(&y);
    }
    drop(span_solve);

    // ---- 3. Batched decoding + per-path loss components. -------------
    let span_decode = crate::obs::span!("elbo.decode");
    let mut dec_cache = model.decoder.batch_cache(c_n);
    let mut z_in = vec![0.0; c_n * dz];
    let mut xhat = vec![0.0; c_n * dx];
    let mut log_px = vec![0.0; c_n];
    let mut sq_err = vec![0.0; c_n];
    for k in 0..n_obs {
        for c in 0..c_n {
            z_in[c * dz..(c + 1) * dz]
                .copy_from_slice(&y_obs[(k * c_n + c) * aug..(k * c_n + c) * aug + dz]);
        }
        if fast {
            model.decoder.forward_batch_fast(params, &z_in, &mut dec_cache, &mut xhat);
        } else {
            model.decoder.forward_batch(params, &z_in, &mut dec_cache, &mut xhat);
        }
        for c in 0..c_n {
            let x_k = &rows[c][k * dx..(k + 1) * dx];
            let xh = &xhat[c * dx..(c + 1) * dx];
            log_px[c] += gaussian_logpdf(x_k, xh, s_obs);
            sq_err[c] += x_k.iter().zip(xh).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
        }
    }

    let mu_p = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
    let lv_p = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
    let mut kl_z0 = vec![0.0; c_n];
    for c in 0..c_n {
        for i in 0..dz {
            let var_q = enc.logvar0[c * dz + i].exp();
            let var_p = lv_p[i].exp();
            let dmu = enc.mu0[c * dz + i] - mu_p[i];
            kl_z0[c] +=
                0.5 * (lv_p[i] - enc.logvar0[c * dz + i] + (var_q + dmu * dmu) / var_p - 1.0);
        }
    }
    let mut kl_path = vec![0.0; c_n];
    let mut loss = vec![0.0; c_n];
    let mut mse = vec![0.0; c_n];
    for c in 0..c_n {
        kl_path[c] = y_obs[((n_obs - 1) * c_n + c) * aug + dz];
        loss[c] = -log_px[c] + beta * (kl_path[c] + kl_z0[c]);
        mse[c] = sq_err[c] / (n_obs * dx) as f64;
    }
    drop(span_decode);

    // ---- 4. Batched backward pass. -----------------------------------
    let span_backward = crate::obs::span!("elbo.backward");
    let n_params = model.n_params;
    let mut grads = vec![0.0; c_n * n_params];
    let mut dctx = vec![0.0; (n_obs - 1) * c_n * dc];
    let mut backward_stats = SolveStats::default();
    let mut a = vec![0.0; c_n * aug];
    for c in 0..c_n {
        a[c * aug + dz] = beta; // ∂loss/∂ℓ_T per path
    }
    let inv_var = 1.0 / (s_obs * s_obs);
    let mut dxh = vec![0.0; c_n * dx];
    let mut dz_buf = vec![0.0; c_n * dz];

    add_obs_grad_batch(
        model, params, &rows, &y_obs, n_obs - 1, aug, inv_var, &mut dec_cache, &mut z_in,
        &mut xhat, &mut dxh, &mut dz_buf, &mut a, &mut grads, fast,
    );

    let mut yb = y_obs[(n_obs - 1) * c_n * aug..].to_vec();
    let p_aug = n_sde + dc;
    let mut ath = vec![0.0; c_n * p_aug];
    // One batched solver for all intervals: scratch is O(B·p) and
    // reallocating per interval would dominate allocation traffic, as in
    // the scalar path.
    let mut solver =
        BatchBackwardSolver::new(CtxAdjointOps::new(&sde, &params[..n_sde], c_n, cfg.exec));
    for k in (1..n_obs).rev() {
        solver.ops_mut().set_ctx(&enc.ctx[(k - 1) * c_n * dc..k * c_n * dc]);
        let grid = uniform_grid(times[k], times[k - 1], cfg.substeps); // descending
        ath.fill(0.0);
        // Replay the forward pass's realized paths through the same
        // per-path Brownian sources.
        solver.solve_interval(&grid, &mut yb, &mut a, &mut ath, &mut bm, &mut backward_stats);
        for c in 0..c_n {
            let g = &mut grads[c * n_params..(c + 1) * n_params];
            for (gi, ai) in g[..n_sde].iter_mut().zip(&ath[c * p_aug..c * p_aug + n_sde]) {
                *gi += ai;
            }
            dctx[((k - 1) * c_n + c) * dc..((k - 1) * c_n + c + 1) * dc]
                .copy_from_slice(&ath[c * p_aug + n_sde..(c + 1) * p_aug]);
        }
        add_obs_grad_batch(
            model, params, &rows, &y_obs, k - 1, aug, inv_var, &mut dec_cache, &mut z_in,
            &mut xhat, &mut dxh, &mut dz_buf, &mut a, &mut grads, fast,
        );
        yb.copy_from_slice(&y_obs[(k - 1) * c_n * aug..k * c_n * aug]);
    }

    // ---- 5. z0 / q(z0) / p(z0) gradients per path. ---------------------
    let mut dmu0 = vec![0.0; c_n * dz];
    let mut dlv0 = vec![0.0; c_n * dz];
    for c in 0..c_n {
        let g = &mut grads[c * n_params..(c + 1) * n_params];
        for i in 0..dz {
            dmu0[c * dz + i] = a[c * aug + i];
            dlv0[c * dz + i] =
                a[c * aug + i] * eps[c * dz + i] * 0.5 * (0.5 * enc.logvar0[c * dz + i]).exp();
        }
        for i in 0..dz {
            let var_q = enc.logvar0[c * dz + i].exp();
            let var_p = lv_p[i].exp();
            let dmu = enc.mu0[c * dz + i] - mu_p[i];
            dmu0[c * dz + i] += beta * dmu / var_p;
            dlv0[c * dz + i] += beta * 0.5 * (var_q / var_p - 1.0);
            g[model.pz0_mean_off + i] += beta * (-dmu / var_p);
            g[model.pz0_logvar_off + i] += beta * 0.5 * (1.0 - (var_q + dmu * dmu) / var_p);
        }
    }
    drop(span_backward);

    // ---- 6. Batched encoder backward. ----------------------------------
    let span_bptt = crate::obs::span!("elbo.encoder_bptt");
    let eh = enc.q_in.len() / c_n;
    let mut dq_out = vec![0.0; c_n * 2 * dz];
    for c in 0..c_n {
        dq_out[c * 2 * dz..c * 2 * dz + dz].copy_from_slice(&dmu0[c * dz..(c + 1) * dz]);
        dq_out[c * 2 * dz + dz..(c + 1) * 2 * dz].copy_from_slice(&dlv0[c * dz..(c + 1) * dz]);
    }
    let mut dq_in = vec![0.0; c_n * eh];
    if fast {
        model.q_head.vjp_batch_fast(params, &enc.q_in, &dq_out, &mut dq_in, &mut grads, n_params);
    } else {
        model.q_head.vjp_batch(params, &enc.q_in, &dq_out, &mut dq_in, &mut grads, n_params);
    }

    match &model.encoder {
        Encoder::Gru { cell, ctx_head } => {
            let hd = model.cfg.enc_hidden;
            let mut dh = vec![0.0; c_n * hd];
            let mut dh_prev = vec![0.0; c_n * hd];
            let mut dx_sink = vec![0.0; c_n * dx];
            for s in (0..n_obs).rev() {
                if s == n_obs - 1 {
                    for (d, q) in dh.iter_mut().zip(&dq_in) {
                        *d += q;
                    }
                } else {
                    let k = n_obs - 1 - s;
                    let dctx_k = &dctx[(k - 1) * c_n * dc..k * c_n * dc];
                    if fast {
                        ctx_head.vjp_batch_fast(
                            params, &enc.hs[s], dctx_k, &mut dh, &mut grads, n_params,
                        );
                    } else {
                        ctx_head.vjp_batch(
                            params, &enc.hs[s], dctx_k, &mut dh, &mut grads, n_params,
                        );
                    }
                }
                dh_prev.fill(0.0);
                dx_sink.fill(0.0);
                if fast {
                    cell.vjp_batch_fast(
                        params,
                        &enc.gru_caches[s],
                        &dh,
                        &mut dx_sink,
                        &mut dh_prev,
                        &mut grads,
                        n_params,
                    );
                } else {
                    cell.vjp_batch(
                        params,
                        &enc.gru_caches[s],
                        &dh,
                        &mut dx_sink,
                        &mut dh_prev,
                        &mut grads,
                        n_params,
                    );
                }
                dh.copy_from_slice(&dh_prev);
            }
        }
        Encoder::Mlp { net, .. } => {
            let mut dout = vec![0.0; c_n * (eh + dc)];
            for c in 0..c_n {
                dout[c * (eh + dc)..c * (eh + dc) + eh]
                    .copy_from_slice(&dq_in[c * eh..(c + 1) * eh]);
                for k in 0..n_obs - 1 {
                    for j in 0..dc {
                        dout[c * (eh + dc) + eh + j] += dctx[(k * c_n + c) * dc + j];
                    }
                }
            }
            let mut cache = net.batch_cache(c_n);
            let mut out = vec![0.0; c_n * (eh + dc)];
            let mut dx_sink = vec![0.0; enc.mlp_input.len()];
            if fast {
                net.forward_batch_fast(params, &enc.mlp_input, &mut cache, &mut out);
                net.vjp_batch_fast(params, &mut cache, &dout, &mut dx_sink, &mut grads, n_params);
            } else {
                net.forward_batch(params, &enc.mlp_input, &mut cache, &mut out);
                net.vjp_batch(params, &mut cache, &dout, &mut dx_sink, &mut grads, n_params);
            }
        }
    }
    drop(span_bptt);

    ChunkOut { grads, loss, log_px, kl_path, kl_z0, mse, forward_stats, backward_stats }
}

/// One minibatch ELBO step with full gradients on the **batched SoA
/// engine**: S posterior samples × M sequences advance together — batched
/// encoder passes ([`crate::nn::GruCell::forward_batch`]), one batched
/// piecewise forward solve per chunk with per-path encoder context, the
/// batched augmented stochastic adjoint
/// ([`crate::adjoint::batch`]), and batched encoder/decoder backprop —
/// fanned across the persistent work-stealing pool
/// ([`crate::runtime::scoped_map`]) in path chunks.
///
/// Path `m·S + s` uses `keys[m].fold_in(s)`, and every per-path float is
/// computed independently of the batch around it, so the result is
/// **bit-identical** (exact f64) to the sequential scalar loop
///
/// ```ignore
/// for m in 0..M { for s in 0..S {
///     elbo_step(model, params, times, obs_seqs[m], keys[m].fold_in(s), cfg)
/// } }
/// ```
///
/// with gradients summed in path order — for any chunk layout and any
/// `n_workers` (pinned by `tests/trainer_batch.rs`). [`elbo_step`] remains
/// the scalar reference oracle.
#[allow(clippy::too_many_arguments)]
pub fn elbo_step_batch(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs_seqs: &[&[f64]],
    keys: &[PrngKey],
    cfg: &ElboConfig,
    n_samples: usize,
    n_workers: usize,
) -> BatchElboOutput {
    let _span = crate::obs::span!("elbo.step");
    let n_obs = times.len();
    let dx = model.cfg.obs_dim;
    assert!(n_obs >= 2, "elbo_step_batch: need at least two observations");
    assert!(!obs_seqs.is_empty(), "elbo_step_batch: empty minibatch");
    assert_eq!(obs_seqs.len(), keys.len(), "elbo_step_batch: one key per sequence");
    assert!(n_samples > 0, "elbo_step_batch: need at least one sample");
    for obs in obs_seqs {
        assert_eq!(obs.len(), n_obs * dx, "elbo_step_batch: obs layout mismatch");
    }
    let b_total = obs_seqs.len() * n_samples;
    let workers = n_workers.clamp(1, b_total);
    // Bigger chunks keep the batched kernels hotter; the cap bounds
    // per-chunk scratch. Chunk layout never changes a float: every path's
    // numbers are computed independently and reduced in path order below.
    let chunk = b_total.div_ceil(workers).clamp(1, 16);
    let n_chunks = b_total.div_ceil(chunk);

    let run_chunk = |ci: usize| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(b_total);
        elbo_chunk(model, params, times, obs_seqs, keys, cfg, n_samples, lo, hi)
    };
    // Chunks fan out on the persistent pool (capped at this call's
    // `workers` budget); the reduction below is path-ordered, so the
    // schedule never changes a float.
    let chunk_outs: Vec<ChunkOut> = crate::runtime::scoped_map(n_chunks, workers, run_chunk);

    // Path-ordered reduction — bit-identical to a sequential per-path
    // accumulation regardless of chunk layout or worker count.
    let n_params = model.n_params;
    let mut grad = vec![0.0; n_params];
    let (mut loss, mut log_px, mut kl_path, mut kl_z0, mut mse) = (0.0, 0.0, 0.0, 0.0, 0.0);
    let mut per_path_loss = Vec::with_capacity(b_total);
    for co in &chunk_outs {
        for c in 0..co.loss.len() {
            for (g, og) in grad.iter_mut().zip(&co.grads[c * n_params..(c + 1) * n_params]) {
                *g += og;
            }
            loss += co.loss[c];
            log_px += co.log_px[c];
            kl_path += co.kl_path[c];
            kl_z0 += co.kl_z0[c];
            mse += co.mse[c];
            per_path_loss.push(co.loss[c]);
        }
    }
    BatchElboOutput {
        loss,
        log_px,
        kl_path,
        kl_z0,
        recon_mse: mse,
        grad,
        per_path_loss,
        n_paths: b_total,
        forward_stats: chunk_outs[0].forward_stats,
        backward_stats: chunk_outs[0].backward_stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latent::model::{DiffusionMode, EncoderKind, LatentSdeConfig, LatentSdeModel};

    fn tiny_cfg() -> LatentSdeConfig {
        LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            obs_noise_std: 0.1,
            ..Default::default()
        }
    }

    fn toy_sequence(n_obs: usize, dx: usize, seed: u64) -> (Vec<f64>, Vec<f64>) {
        let times: Vec<f64> = (0..n_obs).map(|k| 0.1 * k as f64).collect();
        let mut obs = vec![0.0; n_obs * dx];
        PrngKey::from_seed(seed).fill_normal(0, &mut obs);
        for v in obs.iter_mut() {
            *v *= 0.3;
        }
        (times, obs)
    }

    #[test]
    fn elbo_components_are_finite_and_signed() {
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(1));
        let (times, obs) = toy_sequence(5, 2, 2);
        let out =
            elbo_step(&model, &params, &times, &obs, PrngKey::from_seed(3), &ElboConfig::default());
        assert!(out.loss.is_finite());
        assert!(out.kl_path >= 0.0, "path KL must be ≥ 0: {}", out.kl_path);
        assert!(out.kl_z0 >= 0.0, "z0 KL must be ≥ 0: {}", out.kl_z0);
        assert!(out.grad.iter().all(|g| g.is_finite()));
        assert!(out.grad.iter().any(|g| g.abs() > 0.0), "gradient identically zero");
    }

    /// The central correctness test of the whole latent-SDE stack: the
    /// assembled gradient must match finite differences of the full loss
    /// (same key → same ε and Brownian path → deterministic loss).
    ///
    /// Note the adjoint gradient equals the FD gradient only in the h→0
    /// limit (it differentiates the continuous system, not the discrete
    /// solver), so we use a moderate tolerance and many substeps.
    #[test]
    fn full_gradient_matches_finite_difference() {
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(10));
        let (times, obs) = toy_sequence(4, 2, 11);
        let key = PrngKey::from_seed(12);
        let cfg = ElboConfig { substeps: 40, kl_weight: 0.7, ..ElboConfig::default() };

        let out = elbo_step(&model, &params, &times, &obs, key, &cfg);
        let loss_at = |p: &[f64]| elbo_step(&model, p, &times, &obs, key, &cfg).loss;

        let n = params.len();
        let eps = 1e-5;
        let mut checked = 0;
        let mut max_rel: f64 = 0.0;
        for j in (0..n).step_by((n / 50).max(1)) {
            let mut pp = params.clone();
            pp[j] += eps;
            let hi = loss_at(&pp);
            pp[j] -= 2.0 * eps;
            let lo = loss_at(&pp);
            let fd = (hi - lo) / (2.0 * eps);
            let g = out.grad[j];
            let denom = fd.abs().max(g.abs()).max(1e-2);
            let rel = (fd - g).abs() / denom;
            max_rel = max_rel.max(rel);
            assert!(
                rel < 0.05,
                "param {j}: fd {fd:.6} vs adjoint {g:.6} (rel {rel:.4})"
            );
            checked += 1;
        }
        assert!(checked > 30, "too few parameters probed");
    }

    #[test]
    fn ode_mode_gradient_matches_finite_difference() {
        let model = LatentSdeModel::new(LatentSdeConfig {
            diffusion: DiffusionMode::Off,
            ..tiny_cfg()
        });
        let params = model.init_params(PrngKey::from_seed(20));
        let (times, obs) = toy_sequence(4, 2, 21);
        let key = PrngKey::from_seed(22);
        let cfg = ElboConfig { substeps: 30, kl_weight: 0.5, ..ElboConfig::default() };
        let out = elbo_step(&model, &params, &times, &obs, key, &cfg);
        assert_eq!(out.kl_path, 0.0, "ODE mode has no path KL");

        let loss_at = |p: &[f64]| elbo_step(&model, p, &times, &obs, key, &cfg).loss;
        let n = params.len();
        let eps = 1e-5;
        for j in (0..n).step_by((n / 40).max(1)) {
            let mut pp = params.clone();
            pp[j] += eps;
            let hi = loss_at(&pp);
            pp[j] -= 2.0 * eps;
            let lo = loss_at(&pp);
            let fd = (hi - lo) / (2.0 * eps);
            let g = out.grad[j];
            let denom = fd.abs().max(g.abs()).max(1e-2);
            assert!(
                (fd - g).abs() / denom < 0.05,
                "param {j}: fd {fd:.6} vs adjoint {g:.6}"
            );
        }
    }

    #[test]
    fn mlp_encoder_gradient_matches_finite_difference() {
        let model = LatentSdeModel::new(LatentSdeConfig {
            encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
            ..tiny_cfg()
        });
        let params = model.init_params(PrngKey::from_seed(30));
        let (times, obs) = toy_sequence(5, 2, 31);
        let key = PrngKey::from_seed(32);
        let cfg = ElboConfig { substeps: 30, kl_weight: 1.0, ..ElboConfig::default() };
        let out = elbo_step(&model, &params, &times, &obs, key, &cfg);
        let loss_at = |p: &[f64]| elbo_step(&model, p, &times, &obs, key, &cfg).loss;
        let n = params.len();
        let eps = 1e-5;
        for j in (0..n).step_by((n / 40).max(1)) {
            let mut pp = params.clone();
            pp[j] += eps;
            let hi = loss_at(&pp);
            pp[j] -= 2.0 * eps;
            let lo = loss_at(&pp);
            let fd = (hi - lo) / (2.0 * eps);
            let g = out.grad[j];
            let denom = fd.abs().max(g.abs()).max(1e-2);
            assert!(
                (fd - g).abs() / denom < 0.05,
                "param {j}: fd {fd:.6} vs adjoint {g:.6}"
            );
        }
    }

    /// Sample s's loss must not depend on how many other samples ride in
    /// the batch (per-sample keys are `key.fold_in(s)`, and the batched
    /// kernel computes each path's floats independently).
    #[test]
    fn multi_sample_elbo_is_batch_size_independent() {
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(50));
        let (times, obs) = toy_sequence(5, 2, 51);
        let key = PrngKey::from_seed(52);
        let cfg = ElboConfig { substeps: 6, kl_weight: 0.8, ..ElboConfig::default() };

        let one = elbo_value_multi(&model, &params, &times, &obs, key, &cfg, 1);
        let four = elbo_value_multi(&model, &params, &times, &obs, key, &cfg, 4);
        assert_eq!(one.per_sample_loss[0], four.per_sample_loss[0]);
        assert!(four.per_sample_loss.windows(2).any(|w| w[0] != w[1]), "samples must differ");
        assert!(four.loss.is_finite());
        assert!(four.kl_path >= 0.0);
        let mean: f64 =
            four.per_sample_loss.iter().sum::<f64>() / four.per_sample_loss.len() as f64;
        assert!((four.loss - mean).abs() < 1e-12);
    }

    /// The batched minibatch step must equal a sequential scalar loop
    /// float-for-float (the full batch-size × worker-count matrix lives
    /// in `tests/trainer_batch.rs`).
    #[test]
    fn elbo_step_batch_matches_scalar_loop_exactly() {
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(60));
        let (times, obs_a) = toy_sequence(5, 2, 61);
        let (_, obs_b) = toy_sequence(5, 2, 62);
        let key = PrngKey::from_seed(63);
        let cfg = ElboConfig { substeps: 3, kl_weight: 0.7, ..ElboConfig::default() };
        let keys = [key.fold_in(0), key.fold_in(1)];
        let obs_seqs: Vec<&[f64]> = vec![&obs_a, &obs_b];
        let n_samples = 2;

        let out = elbo_step_batch(&model, &params, &times, &obs_seqs, &keys, &cfg, n_samples, 1);

        let mut grad_ref = vec![0.0; model.n_params];
        let mut loss_ref = 0.0;
        let mut per_path = Vec::new();
        for (m, obs) in obs_seqs.iter().enumerate() {
            for s in 0..n_samples {
                let o = elbo_step(&model, &params, &times, obs, keys[m].fold_in(s as u64), &cfg);
                for (g, og) in grad_ref.iter_mut().zip(&o.grad) {
                    *g += og;
                }
                loss_ref += o.loss;
                per_path.push(o.loss);
            }
        }
        assert_eq!(out.grad, grad_ref, "batched gradient != scalar loop");
        assert_eq!(out.loss, loss_ref);
        assert_eq!(out.per_path_loss, per_path);
        assert_eq!(out.n_paths, 4);
    }

    /// The serving batcher's one-call reconstruction rollout must be
    /// bit-identical to per-request scalar `sample_posterior_path` calls,
    /// for any batch composition, under both encoder flavors.
    #[test]
    fn batched_posterior_paths_bit_identical_to_scalar() {
        use crate::latent::sample::sample_posterior_path;
        for cfg in [
            tiny_cfg(),
            LatentSdeConfig {
                encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
                ..tiny_cfg()
            },
            LatentSdeConfig { diffusion: DiffusionMode::Off, ..tiny_cfg() },
        ] {
            let model = LatentSdeModel::new(cfg);
            let params = model.init_params(PrngKey::from_seed(50));
            let n_obs = 5;
            let seqs: Vec<Vec<f64>> =
                (0..4).map(|r| toy_sequence(n_obs, 2, 60 + r).1).collect();
            let times = toy_sequence(n_obs, 2, 60).0;
            let rows: Vec<&[f64]> = seqs.iter().map(|s| s.as_slice()).collect();
            let keys: Vec<PrngKey> = (0..4).map(|r| PrngKey::from_seed(70 + r)).collect();

            let batch =
                sample_posterior_paths_batch(&model, &params, &times, &rows, 3, &keys);
            for r in 0..rows.len() {
                let scalar =
                    sample_posterior_path(&model, &params, &times, rows[r], 3, keys[r]);
                assert_eq!(batch[r], scalar, "request {r} diverged from scalar call");
            }
            // Batch composition must not matter.
            let sub = sample_posterior_paths_batch(
                &model,
                &params,
                &times,
                &rows[1..3],
                3,
                &keys[1..3],
            );
            assert_eq!(sub[0], batch[1]);
            assert_eq!(sub[1], batch[2]);
        }
    }

    /// The serving batcher's one-call multi-request scorer must be
    /// bit-identical (loss fields + per-sample losses) to per-request
    /// `elbo_value_multi` calls, for any batch composition.
    #[test]
    fn batched_multi_request_elbo_bit_identical_to_scalar() {
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(80));
        let n_obs = 5;
        let seqs: Vec<Vec<f64>> = (0..3).map(|r| toy_sequence(n_obs, 2, 90 + r).1).collect();
        let times = toy_sequence(n_obs, 2, 90).0;
        let rows: Vec<&[f64]> = seqs.iter().map(|s| s.as_slice()).collect();
        let keys: Vec<PrngKey> = (0..3).map(|r| PrngKey::from_seed(95 + r)).collect();
        let cfg = ElboConfig { substeps: 3, kl_weight: 0.4, ..ElboConfig::default() };

        for n_samples in [1, 3] {
            let batch =
                elbo_value_multi_batch(&model, &params, &times, &rows, &keys, &cfg, n_samples);
            assert_eq!(batch.len(), rows.len());
            for r in 0..rows.len() {
                let scalar = elbo_value_multi(
                    &model, &params, &times, rows[r], keys[r], &cfg, n_samples,
                );
                assert_eq!(batch[r].loss, scalar.loss, "loss, request {r}");
                assert_eq!(batch[r].log_px, scalar.log_px, "log_px, request {r}");
                assert_eq!(batch[r].kl_path, scalar.kl_path, "kl_path, request {r}");
                assert_eq!(batch[r].kl_z0, scalar.kl_z0, "kl_z0, request {r}");
                assert_eq!(batch[r].recon_mse, scalar.recon_mse, "mse, request {r}");
                assert_eq!(
                    batch[r].per_sample_loss, scalar.per_sample_loss,
                    "per-sample losses, request {r}"
                );
            }
            // Batch composition must not matter.
            let solo = elbo_value_multi_batch(
                &model,
                &params,
                &times,
                &rows[2..3],
                &keys[2..3],
                &cfg,
                n_samples,
            );
            assert_eq!(solo[0].loss, batch[2].loss);
            assert_eq!(solo[0].per_sample_loss, batch[2].per_sample_loss);
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_noise() {
        // A few Adam steps with a FIXED key must reduce the deterministic
        // loss — end-to-end sanity of gradient direction.
        use crate::optim::Adam;
        let model = LatentSdeModel::new(tiny_cfg());
        let mut params = model.init_params(PrngKey::from_seed(40));
        let (times, obs) = toy_sequence(5, 2, 41);
        let key = PrngKey::from_seed(42);
        let cfg = ElboConfig { substeps: 8, kl_weight: 0.1, ..ElboConfig::default() };
        let mut adam = Adam::new(params.len(), 2e-3);
        let first = elbo_step(&model, &params, &times, &obs, key, &cfg).loss;
        let mut last = first;
        for _ in 0..30 {
            let out = elbo_step(&model, &params, &times, &obs, key, &cfg);
            last = out.loss;
            adam.step(&mut params, &out.grad, 1.0);
        }
        assert!(
            last < first - 1.0,
            "loss did not decrease: first {first}, last {last}"
        );
    }
}
