//! Latent stochastic differential equations (paper §5).
//!
//! A variational autoencoder whose decoder is an SDE solve: the prior over
//! latent paths is `dZ̃ = h_θ(Z̃,t) dt + σ(Z̃,t) ∘ dW`, the approximate
//! posterior is `dZ = h_φ(Z,t,ctx) dt + σ(Z,t) ∘ dW` with the *same*
//! diffusion, and the path-space KL is `∫ ½|u|² dt` with
//! `σ_i u_i = h_φ,i − h_θ,i` (Girsanov; App. 9.5).
//!
//! **Calculus convention.** The model is defined natively in *Stratonovich*
//! form. Because prior and posterior share σ, their Itô↔Stratonovich drift
//! corrections are identical and cancel in `u`, so the KL term — and hence
//! the ELBO — is the same in either reading; defining the model in
//! Stratonovich form lets the stochastic adjoint run with first-order VJPs
//! only (no second derivatives of the diffusion nets). DESIGN.md §6.
//!
//! Module map:
//! * [`model`] — architecture (App. 9.9/9.11): prior/posterior drift MLPs,
//!   per-dimension diffusion nets with sigmoid output, decoder, GRU or
//!   first-frames-MLP encoder, learnable `p(z_0)`/`q(z_0)`.
//! * [`posterior`] — the augmented `(z, ℓ)` system (state + running KL) as
//!   an [`crate::sde::SdeVjp`], with the per-interval context appended to
//!   the parameter vector so the adjoint also yields `∂L/∂ctx`.
//! * [`elbo`] — one training step: encode → sample z₀ → piecewise forward
//!   solve with the running-KL augmentation → decoder likelihoods →
//!   interval-by-interval stochastic adjoint → encoder/decoder backprop →
//!   one flat gradient. Setting `DiffusionMode::Off` recovers the latent
//!   ODE baseline of Table 2 (zero diffusion, zero path-KL, ODE adjoint).
//!   [`elbo_step_batch`] is the **batched minibatch engine** the trainer
//!   runs on: S posterior samples × M sequences advance together through
//!   batched encoder/solver/adjoint kernels (per-path encoder context in
//!   the parameter tail), bit-identical to a sequential [`elbo_step`]
//!   loop. [`elbo_value_multi`] computes S-sample ELBO estimates (values
//!   only) on the same engine; [`elbo_value_multi_batch`] and
//!   [`sample_posterior_paths_batch`] are its multi-request forms — the
//!   one-engine-call kernels behind the `sdegrad serve` micro-batcher,
//!   each request bit-identical to its per-request scalar call.
//! * [`sample`] — prior/posterior path sampling for Figures 6/8/9, plus
//!   the batched prior fleet [`sample_prior_paths_batch`] for serving.

pub mod elbo;
pub mod model;
pub mod posterior;
pub mod sample;

pub use elbo::{
    elbo_step, elbo_step_batch, elbo_value_multi, elbo_value_multi_batch,
    sample_posterior_paths_batch, BatchElboOutput, ElboConfig, ElboOutput, MultiElboOutput,
};
pub use model::{DiffusionMode, EncoderKind, LatentSdeConfig, LatentSdeModel};
pub use posterior::PosteriorSde;
pub use sample::{decode_path, sample_posterior_path, sample_prior_path, sample_prior_paths_batch};
