//! Path sampling from trained latent SDEs (Figures 6, 8, 9).
//!
//! * [`sample_prior_path`] — draw `z_0 ~ p(z_0)` and integrate the *prior*
//!   SDE `dZ = h_θ dt + σ ∘ dW` (rows 2–3 of Figs 8/9: samples with
//!   independent or shared initial latent state).
//! * [`sample_posterior_path`] — encode a data sequence and integrate the
//!   posterior SDE (row 1: reconstructions).
//! * [`decode_path`] — map a latent trajectory through the decoder.

use super::model::{Encoder, LatentSdeModel};
use super::posterior::PosteriorSde;
use crate::api::SdeProblem;
use crate::brownian::{BatchBrownian, BrownianPath};
use crate::nn::gru::GruStepCache;
use crate::prng::PrngKey;
use crate::sde::{BatchSde, Calculus, Sde};
use crate::solvers::{batch_grid_core, uniform_grid, BatchForwardFunc, Method};

/// The prior latent SDE `dZ = h_θ(z,t) dt + σ(z) ∘ dW` as an [`Sde`]
/// (no adjoint needed for sampling).
struct PriorSde<'a> {
    model: &'a LatentSdeModel,
}

impl<'a> Sde for PriorSde<'a> {
    fn state_dim(&self) -> usize {
        self.model.cfg.latent_dim
    }
    fn param_dim(&self) -> usize {
        self.model.n_params
    }
    fn calculus(&self) -> Calculus {
        Calculus::Stratonovich
    }
    fn drift(&self, t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let dz = self.model.cfg.latent_dim;
        let mut input = vec![0.0; dz + 1];
        input[..dz].copy_from_slice(z);
        input[dz] = t;
        let mut cache = self.model.prior_drift.cache();
        self.model.prior_drift.forward(theta, &input, &mut cache, out);
    }
    fn diffusion(&self, _t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        self.model.diffusion_eval(theta, z, out, None);
    }
    fn diffusion_dz_diag(&self, _t: f64, z: &[f64], theta: &[f64], out: &mut [f64]) {
        let dz = self.model.cfg.latent_dim;
        let mut sig = vec![0.0; dz];
        self.model.diffusion_eval(theta, z, &mut sig, Some(out));
    }
}

// Loop-based batch kernels (row-per-row over the scalar impl — the
// bit-identity-by-construction default), which is what lets the serving
// batcher advance many simulation requests together per solver step.
impl<'a> BatchSde for PriorSde<'a> {}

/// Sample a latent path from the prior on the grid `times` (with
/// `substeps` solver steps per interval, integrated **piecewise** so the
/// returned rows sit exactly at the requested times — `times` only needs
/// to be strictly ascending, not uniformly spaced; the serving
/// `/v1/simulate` endpoint accepts arbitrary grids). If `z0_override` is
/// given it is used instead of sampling from `p(z_0)` (Fig 8 row 3:
/// shared initial state). Returns the latent trajectory row-major
/// `(len(times), dz)`.
pub fn sample_prior_path(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    substeps: usize,
    key: PrngKey,
    z0_override: Option<&[f64]>,
) -> Vec<f64> {
    let dz = model.cfg.latent_dim;
    let (k0, kw) = key.split();
    let mut z0 = vec![0.0; dz];
    match z0_override {
        Some(z) => z0.copy_from_slice(z),
        None => {
            let mu = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
            let lv = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
            let mut eps = vec![0.0; dz];
            k0.fill_normal(0, &mut eps);
            for i in 0..dz {
                z0[i] = mu[i] + (0.5 * lv[i]).exp() * eps[i];
            }
        }
    }
    let sde = PriorSde { model };
    let sol = SdeProblem::new(&sde, &z0, (times[0], *times.last().unwrap()))
        .params(params)
        .key(kw)
        .solve_intervals(times, substeps.max(1), Method::Heun, |_, _| {});
    sol.states
}

/// Batched prior sampling for the serving subsystem: R independent prior
/// paths (one per request key) advance **together** through one batched
/// piecewise solve — per interval, a single batched solver call over the
/// `[R×dz]` state block ([`BatchForwardFunc`] over [`PriorSde`]'s batch
/// kernels, one Brownian source per path) — so the rows sit exactly at
/// the requested times for any strictly-ascending grid.
///
/// Request `r`'s floats are **bit-identical** to
/// `sample_prior_path(model, params, times, substeps, keys[r], None)`
/// for any batch composition (the batch engine computes each path's
/// floats independently — `tests/batch_engine.rs`; pinned again here in
/// the module tests), which is what makes cross-request dynamic batching
/// safe: an answer cannot depend on which strangers' requests shared the
/// batch.
pub fn sample_prior_paths_batch(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    substeps: usize,
    keys: &[PrngKey],
) -> Vec<Vec<f64>> {
    let dz = model.cfg.latent_dim;
    let n_obs = times.len();
    assert!(n_obs >= 2, "sample_prior_paths_batch: need at least two times");
    let bsz = keys.len();
    if bsz == 0 {
        return Vec::new();
    }
    let sde = PriorSde { model };

    // Same per-request derivation as the scalar path: key → (z0 draw, W).
    let mu = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
    let lv = &params[model.pz0_logvar_off..model.pz0_logvar_off + dz];
    let mut y = vec![0.0; bsz * dz];
    let mut eps = vec![0.0; dz];
    let mut bm_sources = Vec::with_capacity(bsz);
    for (r, key) in keys.iter().enumerate() {
        let (k0, kw) = key.split();
        k0.fill_normal(0, &mut eps);
        for i in 0..dz {
            y[r * dz + i] = mu[i] + (0.5 * lv[i]).exp() * eps[i];
        }
        bm_sources.push(BrownianPath::new(kw, dz, times[0], times[n_obs - 1]));
    }
    let mut bm = BatchBrownian::new(bm_sources);

    let mut out = vec![vec![0.0; n_obs * dz]; bsz];
    for r in 0..bsz {
        out[r][..dz].copy_from_slice(&y[r * dz..(r + 1) * dz]);
    }
    let mut y_next = vec![0.0; bsz * dz];
    for k in 1..n_obs {
        let grid = uniform_grid(times[k - 1], times[k], substeps.max(1));
        let mut sys = BatchForwardFunc::for_method(&sde, params, bsz, Method::Heun);
        batch_grid_core(&mut sys, Method::Heun, &y, &grid, &mut bm, &mut y_next);
        y.copy_from_slice(&y_next);
        for r in 0..bsz {
            out[r][k * dz..(k + 1) * dz].copy_from_slice(&y[r * dz..(r + 1) * dz]);
        }
    }
    out
}

/// Encode a sequence and sample a posterior latent path at the observation
/// times. Returns the latent trajectory `(K, dz)` (KL row stripped).
pub fn sample_posterior_path(
    model: &LatentSdeModel,
    params: &[f64],
    times: &[f64],
    obs: &[f64],
    substeps: usize,
    key: PrngKey,
) -> Vec<f64> {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let dc = model.cfg.context_dim;
    let n_obs = times.len();

    // Encoder forward (same logic as elbo::encode, reconstructed here to
    // keep that function private and this one allocation-simple).
    let (ctx, mu0, logvar0) = encode_for_sampling(model, params, obs, n_obs, dx, dz, dc);

    let (k_eps, k_bm) = key.split();
    let mut eps = vec![0.0; dz];
    k_eps.fill_normal(0, &mut eps);
    let mut z0 = vec![0.0; dz];
    for i in 0..dz {
        z0[i] = mu0[i] + (0.5 * logvar0[i]).exp() * eps[i];
    }

    let sde = PosteriorSde::new(model);
    let n_sde = sde.sde_param_len();
    let aug = dz + 1;
    let mut theta_full = vec![0.0; n_sde + dc];
    theta_full[..n_sde].copy_from_slice(&params[..n_sde]);

    // Piecewise posterior solve: one shared Brownian source, per-interval
    // encoder context in the parameter tail.
    let mut y0 = vec![0.0; aug];
    y0[..dz].copy_from_slice(&z0);
    let sol = SdeProblem::new(&sde, &y0, (times[0], times[n_obs - 1]))
        .params(&theta_full)
        .key(k_bm)
        .solve_intervals(times, substeps, Method::Heun, |k, th| {
            th[n_sde..].copy_from_slice(&ctx[k * dc..(k + 1) * dc]);
        });
    let mut out = vec![0.0; n_obs * dz];
    for k in 0..n_obs {
        out[k * dz..(k + 1) * dz].copy_from_slice(&sol.state(k)[..dz]);
    }
    out
}

fn encode_for_sampling(
    model: &LatentSdeModel,
    params: &[f64],
    obs: &[f64],
    n_obs: usize,
    dx: usize,
    dz: usize,
    dc: usize,
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    match &model.encoder {
        Encoder::Gru { cell, ctx_head } => {
            let hd = model.cfg.enc_hidden;
            let mut h = vec![0.0; hd];
            let mut hs = Vec::with_capacity(n_obs);
            for s in 0..n_obs {
                let k = n_obs - 1 - s;
                let mut cache = GruStepCache::default();
                let mut h_next = vec![0.0; hd];
                cell.forward(params, &obs[k * dx..(k + 1) * dx], &h, &mut cache, &mut h_next);
                h = h_next;
                hs.push(h.clone());
            }
            let mut ctx = vec![0.0; (n_obs - 1) * dc];
            for k in 1..n_obs {
                let s = n_obs - 1 - k;
                ctx_head.forward(params, &hs[s], &mut ctx[(k - 1) * dc..k * dc]);
            }
            let mut q_out = vec![0.0; 2 * dz];
            model.q_head.forward(params, &hs[n_obs - 1], &mut q_out);
            (ctx, q_out[..dz].to_vec(), q_out[dz..].to_vec())
        }
        Encoder::Mlp { net, n_frames } => {
            let nf = (*n_frames).min(n_obs);
            let mut cache = net.cache();
            let mut out = vec![0.0; model.cfg.enc_hidden + dc];
            net.forward(params, &obs[..dx * nf], &mut cache, &mut out);
            let mut ctx = vec![0.0; (n_obs - 1) * dc];
            for k in 0..n_obs - 1 {
                ctx[k * dc..(k + 1) * dc].copy_from_slice(&out[model.cfg.enc_hidden..]);
            }
            let mut q_out = vec![0.0; 2 * dz];
            model.q_head.forward(params, &out[..model.cfg.enc_hidden], &mut q_out);
            (ctx, q_out[..dz].to_vec(), q_out[dz..].to_vec())
        }
    }
}

/// Decode a latent trajectory `(K, dz)` into observation space `(K, dx)`.
pub fn decode_path(model: &LatentSdeModel, params: &[f64], latents: &[f64]) -> Vec<f64> {
    let dz = model.cfg.latent_dim;
    let dx = model.cfg.obs_dim;
    let k_total = latents.len() / dz;
    let mut cache = model.decoder.cache();
    let mut out = vec![0.0; k_total * dx];
    let mut xhat = vec![0.0; dx];
    for k in 0..k_total {
        model
            .decoder
            .forward(params, &latents[k * dz..(k + 1) * dz], &mut cache, &mut xhat);
        out[k * dx..(k + 1) * dx].copy_from_slice(&xhat);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latent::model::{DiffusionMode, EncoderKind, LatentSdeConfig};

    fn model() -> LatentSdeModel {
        LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            ..Default::default()
        })
    }

    #[test]
    fn prior_samples_have_correct_shape_and_vary() {
        let m = model();
        let params = m.init_params(PrngKey::from_seed(1));
        let times: Vec<f64> = (0..6).map(|k| 0.1 * k as f64).collect();
        let a = sample_prior_path(&m, &params, &times, 4, PrngKey::from_seed(2), None);
        let b = sample_prior_path(&m, &params, &times, 4, PrngKey::from_seed(3), None);
        assert_eq!(a.len(), 6 * 3);
        let diff: f64 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-6, "prior samples identical across keys");
    }

    #[test]
    fn shared_z0_prior_samples_still_diverge_under_sde() {
        // With a shared initial state, path noise must still create spread
        // (Fig 8 row 3) — unless diffusion is off.
        let m = model();
        let params = m.init_params(PrngKey::from_seed(4));
        let times: Vec<f64> = (0..6).map(|k| 0.1 * k as f64).collect();
        let z0 = [0.1, -0.2, 0.3];
        let a = sample_prior_path(&m, &params, &times, 4, PrngKey::from_seed(5), Some(&z0));
        let b = sample_prior_path(&m, &params, &times, 4, PrngKey::from_seed(6), Some(&z0));
        assert_eq!(&a[..3], &z0);
        assert_eq!(&b[..3], &z0);
        let end_diff: f64 = a[15..].iter().zip(&b[15..]).map(|(x, y)| (x - y).abs()).sum();
        assert!(end_diff > 1e-8, "SDE prior should diverge from shared z0");

        let ode = LatentSdeModel::new(LatentSdeConfig {
            diffusion: DiffusionMode::Off,
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            enc_hidden: 6,
            ..Default::default()
        });
        let p_ode = ode.init_params(PrngKey::from_seed(7));
        let c = sample_prior_path(&ode, &p_ode, &times, 4, PrngKey::from_seed(8), Some(&z0));
        let d = sample_prior_path(&ode, &p_ode, &times, 4, PrngKey::from_seed(9), Some(&z0));
        assert_eq!(c, d, "ODE prior with shared z0 must be deterministic");
    }

    #[test]
    fn posterior_path_and_decode_shapes() {
        let m = model();
        let params = m.init_params(PrngKey::from_seed(10));
        let times: Vec<f64> = (0..5).map(|k| 0.1 * k as f64).collect();
        let mut obs = vec![0.0; 5 * 2];
        PrngKey::from_seed(11).fill_normal(0, &mut obs);
        let lat = sample_posterior_path(&m, &params, &times, &obs, 4, PrngKey::from_seed(12));
        assert_eq!(lat.len(), 5 * 3);
        let dec = decode_path(&m, &params, &lat);
        assert_eq!(dec.len(), 5 * 2);
        assert!(dec.iter().all(|v| v.is_finite()));
    }

    /// The serving batcher's one-call prior sampler must be bit-identical
    /// to per-request scalar calls, for any batch composition — including
    /// non-uniformly spaced time grids (the piecewise solve puts every
    /// returned row exactly at its requested time).
    #[test]
    fn batched_prior_sampling_is_bit_identical_to_scalar() {
        let m = model();
        let params = m.init_params(PrngKey::from_seed(20));
        let uniform: Vec<f64> = (0..7).map(|k| 0.15 * k as f64).collect();
        let irregular = vec![0.0, 0.05, 0.3, 0.35, 0.9];
        for times in [&uniform, &irregular] {
            let keys: Vec<PrngKey> = (0..5).map(|i| PrngKey::from_seed(100 + i)).collect();
            let batch = sample_prior_paths_batch(&m, &params, times, 3, &keys);
            assert_eq!(batch.len(), keys.len());
            for (r, key) in keys.iter().enumerate() {
                let scalar = sample_prior_path(&m, &params, times, 3, *key, None);
                assert_eq!(batch[r], scalar, "request {r} diverged from scalar call");
            }
            // Batch composition must not matter: the same key in a
            // different fleet yields the same floats.
            let sub = sample_prior_paths_batch(&m, &params, times, 3, &keys[2..4]);
            assert_eq!(sub[0], batch[2]);
            assert_eq!(sub[1], batch[3]);
        }
    }

    /// On a non-uniform grid the prior sampler must respect the interval
    /// structure: an ODE-mode (deterministic) solve over a *prefix* of
    /// the grid reproduces the same rows, which fails if rows are
    /// subsampled from one uniform grid over the whole span.
    #[test]
    fn prior_sampling_rows_sit_at_their_requested_times() {
        let ode = LatentSdeModel::new(LatentSdeConfig {
            diffusion: DiffusionMode::Off,
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            enc_hidden: 6,
            ..Default::default()
        });
        let params = ode.init_params(PrngKey::from_seed(30));
        let z0 = [0.2, -0.1, 0.4];
        let full = vec![0.0, 0.05, 0.3, 0.35, 0.9];
        let prefix = &full[..3];
        let a = sample_prior_path(&ode, &params, &full, 4, PrngKey::from_seed(31), Some(&z0));
        let b = sample_prior_path(&ode, &params, prefix, 4, PrngKey::from_seed(31), Some(&z0));
        assert_eq!(&a[..3 * 3], &b[..], "prefix rows must agree with the full-grid rows");
    }

    #[test]
    fn mlp_encoder_sampling_works() {
        let m = LatentSdeModel::new(LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
            ..Default::default()
        });
        let params = m.init_params(PrngKey::from_seed(13));
        let times: Vec<f64> = (0..5).map(|k| 0.1 * k as f64).collect();
        let mut obs = vec![0.0; 5 * 2];
        PrngKey::from_seed(14).fill_normal(0, &mut obs);
        let lat = sample_posterior_path(&m, &params, &times, &obs, 4, PrngKey::from_seed(15));
        assert_eq!(lat.len(), 5 * 3);
    }
}
