//! Fixed-grid integration driver.
//!
//! Works on any monotone time grid — ascending (forward solve) or
//! descending (backward solve). Brownian increments are queried from the
//! noise source as signed differences `W(t_{k+1}) − W(t_k)`, so the same
//! sample path drives both passes.

use super::methods::{Method, Stepper};
use crate::brownian::BrownianMotion;
use crate::sde::SdeFunc;

/// Counters reported by a solve (Fig 5b plots gradient error vs NFE).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolveStats {
    /// Steps taken (accepted steps for adaptive solves).
    pub steps: u64,
    /// Rejected step attempts (adaptive only).
    pub rejected: u64,
    /// Drift evaluations.
    pub nfe_drift: u64,
    /// Diffusion evaluations.
    pub nfe_diffusion: u64,
}

impl SolveStats {
    /// Total function evaluations (the paper's NFE metric counts drift and
    /// diffusion evaluations together; Table 1's unit is "cost of
    /// evaluating the drift and diffusion functions once each").
    pub fn nfe(&self) -> u64 {
        self.nfe_drift + self.nfe_diffusion
    }
}

/// Build a uniform grid of `n_steps + 1` points from `t0` to `t1`
/// (descending if `t1 < t0`).
pub fn uniform_grid(t0: f64, t1: f64, n_steps: usize) -> Vec<f64> {
    assert!(n_steps > 0, "uniform_grid: need at least one step");
    let h = (t1 - t0) / n_steps as f64;
    let mut ts: Vec<f64> = (0..=n_steps).map(|k| t0 + h * k as f64).collect();
    // Pin the endpoint exactly (avoids off-by-ulp Brownian queries).
    ts[n_steps] = t1;
    ts
}

/// Fixed-grid integration core behind [`crate::api::SdeProblem::solve`].
pub(crate) fn grid_core<S: SdeFunc, B: BrownianMotion>(
    sys: &mut S,
    method: Method,
    y0: &[f64],
    times: &[f64],
    bm: &mut B,
    y_out: &mut [f64],
) -> SolveStats {
    let d = sys.dim();
    assert_eq!(y0.len(), d, "integrate_grid: y0 length mismatch");
    assert_eq!(y_out.len(), d, "integrate_grid: y_out length mismatch");
    assert!(times.len() >= 2, "integrate_grid: need at least two time points");
    debug_assert_eq!(bm.dim(), d, "integrate_grid: Brownian dim mismatch");

    let mut stepper = Stepper::new(method, d);
    let mut y = y0.to_vec();
    let mut ynext = vec![0.0; d];
    let mut dw = vec![0.0; d];
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];

    let f0 = sys.nfe_drift();
    let g0 = sys.nfe_diffusion();
    let mut steps = 0u64;

    bm.sample_into(times[0], &mut wa);
    for k in 0..times.len() - 1 {
        let (t, tn) = (times[k], times[k + 1]);
        let h = tn - t;
        bm.sample_into(tn, &mut wb);
        for i in 0..d {
            dw[i] = wb[i] - wa[i];
        }
        stepper.step(sys, t, h, &y, &dw, &mut ynext);
        std::mem::swap(&mut y, &mut ynext);
        std::mem::swap(&mut wa, &mut wb);
        steps += 1;
    }
    y_out.copy_from_slice(&y);
    SolveStats {
        steps,
        rejected: 0,
        nfe_drift: sys.nfe_drift() - f0,
        nfe_diffusion: sys.nfe_diffusion() - g0,
    }
}

/// Trajectory-saving fixed-grid core behind
/// [`crate::api::SdeProblem::solve`] with `SaveAt::Dense` (returns the
/// trajectory as a flat row-major `(times.len(), d)` matrix).
pub(crate) fn grid_saving_core<S: SdeFunc, B: BrownianMotion>(
    sys: &mut S,
    method: Method,
    y0: &[f64],
    times: &[f64],
    bm: &mut B,
) -> (Vec<f64>, SolveStats) {
    let d = sys.dim();
    let mut traj = vec![0.0; times.len() * d];
    traj[..d].copy_from_slice(y0);

    let mut stepper = Stepper::new(method, d);
    let mut y = y0.to_vec();
    let mut ynext = vec![0.0; d];
    let mut dw = vec![0.0; d];
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];

    let f0 = sys.nfe_drift();
    let g0 = sys.nfe_diffusion();

    bm.sample_into(times[0], &mut wa);
    for k in 0..times.len() - 1 {
        let (t, tn) = (times[k], times[k + 1]);
        bm.sample_into(tn, &mut wb);
        for i in 0..d {
            dw[i] = wb[i] - wa[i];
        }
        stepper.step(sys, t, tn - t, &y, &dw, &mut ynext);
        std::mem::swap(&mut y, &mut ynext);
        std::mem::swap(&mut wa, &mut wb);
        traj[(k + 1) * d..(k + 2) * d].copy_from_slice(&y);
    }
    let stats = SolveStats {
        steps: (times.len() - 1) as u64,
        rejected: 0,
        nfe_drift: sys.nfe_drift() - f0,
        nfe_diffusion: sys.nfe_diffusion() - g0,
    };
    (traj, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::{BrownianPath, VirtualBrownianTree};
    use crate::prng::PrngKey;
    use crate::sde::problems::Example1;
    use crate::sde::{ForwardFunc, ReplicatedSde, ScalarSde};

    #[test]
    fn uniform_grid_endpoints() {
        let g = uniform_grid(0.0, 1.0, 10);
        assert_eq!(g.len(), 11);
        assert_eq!(g[0], 0.0);
        assert_eq!(g[10], 1.0);
        let gb = uniform_grid(1.0, 0.0, 4);
        assert!(gb.windows(2).all(|w| w[1] < w[0]), "descending grid");
    }

    /// Strong convergence of Euler–Maruyama on GBM: error vs the closed
    /// form at matched Brownian paths should shrink ~h^0.5.
    #[test]
    fn euler_strong_convergence_on_gbm() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.5, 0.8];
        let x0 = [1.0];
        let t1 = 1.0;
        let n_paths = 200;

        let mut errs = Vec::new();
        for &n_steps in &[8usize, 64, 512] {
            let mut total = 0.0;
            for path in 0..n_paths {
                let key = PrngKey::from_seed(1000 + path);
                let mut bm = BrownianPath::new(key, 1, 0.0, t1);
                let mut sys = ForwardFunc::new(&sde, &theta);
                let grid = uniform_grid(0.0, t1, n_steps);
                let mut y = [0.0];
                grid_core(&mut sys, Method::EulerMaruyama, &x0, &grid, &mut bm, &mut y);
                let w_t = bm.sample(t1)[0];
                let exact = sde.problem().analytic_solution(t1, x0[0], &theta, w_t);
                total += (y[0] - exact).abs();
            }
            errs.push(total / n_paths as f64);
        }
        // Each 8x refinement should shrink the error by ~sqrt(8) ≈ 2.8;
        // require at least 2x to be robust to noise.
        assert!(errs[0] / errs[1] > 2.0, "errors: {errs:?}");
        assert!(errs[1] / errs[2] > 2.0, "errors: {errs:?}");
    }

    /// Milstein (Itô) achieves strong order 1.0 on GBM: 8x refinement
    /// should shrink error ~8x; require ≥4x.
    #[test]
    fn milstein_strong_convergence_on_gbm() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.5, 0.8];
        let x0 = [1.0];
        let t1 = 1.0;
        let n_paths = 200;

        let mut errs = Vec::new();
        for &n_steps in &[8usize, 64, 512] {
            let mut total = 0.0;
            for path in 0..n_paths {
                let key = PrngKey::from_seed(5000 + path);
                let mut bm = BrownianPath::new(key, 1, 0.0, t1);
                let mut sys = ForwardFunc::new(&sde, &theta);
                let grid = uniform_grid(0.0, t1, n_steps);
                let mut y = [0.0];
                grid_core(&mut sys, Method::MilsteinIto, &x0, &grid, &mut bm, &mut y);
                let w_t = bm.sample(t1)[0];
                let exact = sde.problem().analytic_solution(t1, x0[0], &theta, w_t);
                total += (y[0] - exact).abs();
            }
            errs.push(total / n_paths as f64);
        }
        assert!(errs[0] / errs[1] > 4.0, "errors: {errs:?}");
        assert!(errs[1] / errs[2] > 4.0, "errors: {errs:?}");
    }

    /// Heun must converge to the *Stratonovich* solution: integrating the
    /// Itô-GBM coefficients with Heun converges to
    /// x0·exp(αt + βW_t) instead (drift uncorrected).
    #[test]
    fn heun_targets_stratonovich_solution() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.5, 0.8];
        let x0 = [1.0];
        let t1 = 1.0;
        let n_paths = 300;
        let n_steps = 512;

        let mut err_strat = 0.0;
        let mut err_ito = 0.0;
        for path in 0..n_paths {
            let key = PrngKey::from_seed(9000 + path);
            let mut bm = BrownianPath::new(key, 1, 0.0, t1);
            let mut sys = ForwardFunc::new(&sde, &theta);
            let grid = uniform_grid(0.0, t1, n_steps);
            let mut y = [0.0];
            grid_core(&mut sys, Method::Heun, &x0, &grid, &mut bm, &mut y);
            let w_t = bm.sample(t1)[0];
            let strat = x0[0] * (theta[0] * t1 + theta[1] * w_t).exp();
            let ito = sde.problem().analytic_solution(t1, x0[0], &theta, w_t);
            err_strat += (y[0] - strat).abs();
            err_ito += (y[0] - ito).abs();
        }
        assert!(
            err_strat < 0.1 * err_ito,
            "Heun should match Stratonovich solution: strat_err={} ito_err={}",
            err_strat / n_paths as f64,
            err_ito / n_paths as f64
        );
    }

    /// The virtual Brownian tree and the stored path must be interchangeable
    /// noise sources (same trait, same law); a solve driven by the tree
    /// converges to that tree's own closed-form endpoint.
    #[test]
    fn tree_driven_solve_matches_closed_form() {
        let sde = ReplicatedSde::new(Example1, 2);
        let theta = [0.5, 0.3, 0.7, 0.4];
        let x0 = [1.0, 2.0];
        let t1 = 1.0;
        let key = PrngKey::from_seed(31);
        let mut bm = VirtualBrownianTree::new(key, 2, 0.0, t1, 1e-10);
        let mut sys = ForwardFunc::new(&sde, &theta);
        let grid = uniform_grid(0.0, t1, 4096);
        let mut y = [0.0; 2];
        grid_core(&mut sys, Method::MilsteinIto, &x0, &grid, &mut bm, &mut y);
        let w = bm.sample(t1);
        for i in 0..2 {
            let exact =
                sde.problem().analytic_solution(t1, x0[i], &theta[2 * i..2 * i + 2], w[i]);
            assert!(
                (y[i] - exact).abs() < 0.02 * exact.abs().max(1.0),
                "dim {i}: numeric {} vs exact {exact}",
                y[i]
            );
        }
    }

    #[test]
    fn saving_records_full_trajectory() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.5, 0.8];
        let key = PrngKey::from_seed(7);
        let mut bm = BrownianPath::new(key, 1, 0.0, 1.0);
        let mut sys = ForwardFunc::new(&sde, &theta);
        let grid = uniform_grid(0.0, 1.0, 16);
        let (traj, stats) = grid_saving_core(&mut sys, Method::EulerMaruyama, &[1.0], &grid, &mut bm);
        assert_eq!(traj.len(), 17);
        assert_eq!(traj[0], 1.0);
        assert_eq!(stats.steps, 16);
        assert_eq!(stats.nfe_drift, 16);
        // Terminal state must match the non-saving driver on the same path.
        let mut bm2 = BrownianPath::new(key, 1, 0.0, 1.0);
        let mut sys2 = ForwardFunc::new(&sde, &theta);
        let mut y = [0.0];
        grid_core(&mut sys2, Method::EulerMaruyama, &[1.0], &grid, &mut bm2, &mut y);
        assert_eq!(y[0], traj[16]);
    }
}
