//! Single-step integration schemes.
//!
//! Every step is *signed*: `h = t_next − t` may be negative (backward
//! integration) and `dw = W(t_next) − W(t)` is the matching signed Brownian
//! increment. In Stratonovich form the backward dynamics are the forward
//! dynamics with negated coefficients (Theorem 2.1b), which after the sign
//! flip of `h` and `dw` reduces to *the same update formula* — so one
//! stepper serves both passes. (For Itô/Euler–Maruyama the backward pass is
//! deliberately available but *wrong* — that asymmetry is Figure 2.)

use crate::sde::{Calculus, SdeFunc};

/// Available stepping schemes.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Method {
    /// Euler–Maruyama. Interprets the system as Itô. Strong order 0.5
    /// (1.0 for additive noise).
    EulerMaruyama,
    /// Stratonovich Heun (trapezoid predictor-corrector). Strong order 1.0
    /// under commutative noise — which App. 9.4 proves holds for the
    /// adjoint system of any diagonal-noise SDE.
    Heun,
    /// Milstein, Itô form: adds `½ g g' (ΔW² − h)`. Strong order 1.0,
    /// diagonal noise. Requires `diffusion_dy_diag`.
    MilsteinIto,
    /// Milstein, Stratonovich form: adds `½ g g' ΔW²`. Strong order 1.0,
    /// diagonal noise. Requires `diffusion_dy_diag`.
    MilsteinStrat,
}

impl Method {
    /// Calculus in which this scheme interprets (drift, diffusion).
    pub fn calculus(&self) -> Calculus {
        match self {
            Method::EulerMaruyama | Method::MilsteinIto => Calculus::Ito,
            Method::Heun | Method::MilsteinStrat => Calculus::Stratonovich,
        }
    }

    /// Strong order under diagonal (commutative) noise.
    pub fn strong_order(&self) -> f64 {
        match self {
            Method::EulerMaruyama => 0.5,
            _ => 1.0,
        }
    }

    /// Parse from CLI/bench strings.
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "euler" | "euler_maruyama" | "em" => Some(Method::EulerMaruyama),
            "heun" | "stratonovich_heun" => Some(Method::Heun),
            "milstein" | "milstein_ito" => Some(Method::MilsteinIto),
            "milstein_strat" => Some(Method::MilsteinStrat),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::EulerMaruyama => "euler_maruyama",
            Method::Heun => "heun",
            Method::MilsteinIto => "milstein_ito",
            Method::MilsteinStrat => "milstein_strat",
        }
    }
}

/// Reusable scratch buffers for allocation-free stepping (the solver hot
/// loop is the L3 hot path; see DESIGN.md §Perf).
pub struct Stepper {
    method: Method,
    f0: Vec<f64>,
    g0: Vec<f64>,
    f1: Vec<f64>,
    g1: Vec<f64>,
    ytmp: Vec<f64>,
    gp: Vec<f64>,
}

impl Stepper {
    pub fn new(method: Method, dim: usize) -> Self {
        Stepper {
            method,
            f0: vec![0.0; dim],
            g0: vec![0.0; dim],
            f1: vec![0.0; dim],
            g1: vec![0.0; dim],
            ytmp: vec![0.0; dim],
            gp: vec![0.0; dim],
        }
    }

    pub fn method(&self) -> Method {
        self.method
    }

    /// Resize scratch (e.g. when reused across systems).
    pub fn resize(&mut self, dim: usize) {
        for buf in [&mut self.f0, &mut self.g0, &mut self.f1, &mut self.g1, &mut self.ytmp, &mut self.gp]
        {
            buf.resize(dim, 0.0);
        }
    }

    /// Advance `y` at time `t` by a signed step `h` with signed Brownian
    /// increment `dw` (`dw.len() == y.len()`, diagonal noise). Writes the
    /// new state into `out` (may not alias `y`).
    pub fn step<S: SdeFunc>(
        &mut self,
        sys: &mut S,
        t: f64,
        h: f64,
        y: &[f64],
        dw: &[f64],
        out: &mut [f64],
    ) {
        let d = y.len();
        debug_assert_eq!(dw.len(), d);
        debug_assert_eq!(out.len(), d);
        debug_assert!(self.f0.len() >= d, "Stepper scratch too small; call resize()");
        match self.method {
            Method::EulerMaruyama => {
                sys.drift(t, y, &mut self.f0[..d]);
                sys.diffusion(t, y, &mut self.g0[..d]);
                for i in 0..d {
                    out[i] = y[i] + self.f0[i] * h + self.g0[i] * dw[i];
                }
            }
            Method::Heun => {
                sys.drift(t, y, &mut self.f0[..d]);
                sys.diffusion(t, y, &mut self.g0[..d]);
                for i in 0..d {
                    self.ytmp[i] = y[i] + self.f0[i] * h + self.g0[i] * dw[i];
                }
                let t1 = t + h;
                sys.drift(t1, &self.ytmp[..d], &mut self.f1[..d]);
                sys.diffusion(t1, &self.ytmp[..d], &mut self.g1[..d]);
                for i in 0..d {
                    out[i] = y[i]
                        + 0.5 * (self.f0[i] + self.f1[i]) * h
                        + 0.5 * (self.g0[i] + self.g1[i]) * dw[i];
                }
            }
            Method::MilsteinIto | Method::MilsteinStrat => {
                assert!(
                    sys.has_diffusion_jacobian(),
                    "Milstein requires diffusion_dy_diag; use Heun instead"
                );
                sys.drift(t, y, &mut self.f0[..d]);
                sys.diffusion(t, y, &mut self.g0[..d]);
                sys.diffusion_dy_diag(t, y, &mut self.gp[..d]);
                let ito = self.method == Method::MilsteinIto;
                for i in 0..d {
                    let corr = if ito { dw[i] * dw[i] - h } else { dw[i] * dw[i] };
                    out[i] = y[i]
                        + self.f0[i] * h
                        + self.g0[i] * dw[i]
                        + 0.5 * self.g0[i] * self.gp[i] * corr;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sde::{Calculus, SdeFunc};

    /// dY = a·Y dt + b·Y ∘ dW (declared Stratonovich for Heun tests; the
    /// Milstein-Itô test reinterprets the same coefficients as Itô).
    struct LinearSys {
        a: f64,
        b: f64,
        nfe_f: u64,
        nfe_g: u64,
    }

    impl SdeFunc for LinearSys {
        fn dim(&self) -> usize {
            1
        }
        fn calculus(&self) -> Calculus {
            Calculus::Stratonovich
        }
        fn drift(&mut self, _t: f64, y: &[f64], out: &mut [f64]) {
            self.nfe_f += 1;
            out[0] = self.a * y[0];
        }
        fn diffusion(&mut self, _t: f64, y: &[f64], out: &mut [f64]) {
            self.nfe_g += 1;
            out[0] = self.b * y[0];
        }
        fn has_diffusion_jacobian(&self) -> bool {
            true
        }
        fn diffusion_dy_diag(&mut self, _t: f64, _y: &[f64], out: &mut [f64]) {
            out[0] = self.b;
        }
        fn nfe_drift(&self) -> u64 {
            self.nfe_f
        }
        fn nfe_diffusion(&self) -> u64 {
            self.nfe_g
        }
    }

    fn sys() -> LinearSys {
        LinearSys { a: 0.5, b: 0.3, nfe_f: 0, nfe_g: 0 }
    }

    #[test]
    fn euler_step_formula() {
        let mut s = sys();
        let mut st = Stepper::new(Method::EulerMaruyama, 1);
        let mut out = [0.0];
        st.step(&mut s, 0.0, 0.1, &[2.0], &[0.05], &mut out);
        // y + a*y*h + b*y*dw = 2 + 0.5*2*0.1 + 0.3*2*0.05
        assert!((out[0] - (2.0 + 0.1 + 0.03)).abs() < 1e-14);
    }

    #[test]
    fn milstein_ito_step_formula() {
        let mut s = sys();
        let mut st = Stepper::new(Method::MilsteinIto, 1);
        let mut out = [0.0];
        let (h, dw) = (0.1, 0.05);
        st.step(&mut s, 0.0, h, &[2.0], &[dw], &mut out);
        let expect = 2.0 + 0.5 * 2.0 * h + 0.3 * 2.0 * dw + 0.5 * (0.3 * 2.0) * 0.3 * (dw * dw - h);
        assert!((out[0] - expect).abs() < 1e-14);
    }

    #[test]
    fn milstein_strat_step_formula() {
        let mut s = sys();
        let mut st = Stepper::new(Method::MilsteinStrat, 1);
        let mut out = [0.0];
        let (h, dw) = (0.1, 0.05);
        st.step(&mut s, 0.0, h, &[2.0], &[dw], &mut out);
        let expect = 2.0 + 0.5 * 2.0 * h + 0.3 * 2.0 * dw + 0.5 * (0.3 * 2.0) * 0.3 * (dw * dw);
        assert!((out[0] - expect).abs() < 1e-14);
    }

    #[test]
    fn heun_matches_strat_milstein_to_second_order() {
        // For 1-d linear diffusion, Heun's corrector reproduces the
        // Stratonovich-Milstein ΔW² term up to O(ΔW³): the difference over
        // a single small step must be o(ΔW²).
        let (h, dw) = (1e-4, 1e-3);
        let mut s1 = sys();
        let mut s2 = sys();
        let mut heun = Stepper::new(Method::Heun, 1);
        let mut mil = Stepper::new(Method::MilsteinStrat, 1);
        let mut a = [0.0];
        let mut b = [0.0];
        heun.step(&mut s1, 0.0, h, &[1.0], &[dw], &mut a);
        mil.step(&mut s2, 0.0, h, &[1.0], &[dw], &mut b);
        // Residual terms are O(h·ΔW) ≈ 1.6e-8 here; require < 5e-8.
        assert!((a[0] - b[0]).abs() < 5e-8, "diff {}", (a[0] - b[0]).abs());
    }

    #[test]
    fn heun_backward_step_inverts_forward_step_exactly_for_additive_noise() {
        // Additive noise: dY = a·Y dt + c dW. Heun forward then backward
        // with the same increments must return ~exactly (trapezoid is
        // symmetric in (t, t+h) up to the nonlinearity of the drift).
        struct Additive;
        impl SdeFunc for Additive {
            fn dim(&self) -> usize {
                1
            }
            fn calculus(&self) -> Calculus {
                Calculus::Stratonovich
            }
            fn drift(&mut self, _t: f64, y: &[f64], out: &mut [f64]) {
                out[0] = 0.5 * y[0];
            }
            fn diffusion(&mut self, _t: f64, _y: &[f64], out: &mut [f64]) {
                out[0] = 0.7;
            }
            fn nfe_drift(&self) -> u64 {
                0
            }
            fn nfe_diffusion(&self) -> u64 {
                0
            }
        }
        let mut s = Additive;
        let mut st = Stepper::new(Method::Heun, 1);
        let y0 = [1.3];
        let (h, dw) = (1e-3, 0.02);
        let mut fwd = [0.0];
        st.step(&mut s, 0.0, h, &y0, &[dw], &mut fwd);
        let mut back = [0.0];
        st.step(&mut s, h, -h, &fwd, &[-dw], &mut back);
        assert!((back[0] - y0[0]).abs() < 1e-9, "reconstruction error {}", (back[0] - y0[0]).abs());
    }

    #[test]
    fn nfe_counts() {
        let mut s = sys();
        let mut st = Stepper::new(Method::Heun, 1);
        let mut out = [0.0];
        st.step(&mut s, 0.0, 0.1, &[1.0], &[0.0], &mut out);
        assert_eq!(s.nfe_drift(), 2); // predictor + corrector
        assert_eq!(s.nfe_diffusion(), 2);
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::EulerMaruyama, Method::Heun, Method::MilsteinIto, Method::MilsteinStrat] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }
}
