//! Adaptive time-stepping with step-doubling error estimation and a PI
//! step-size controller (§3.4; Burrage–Burrage 2004, Ilie–Jackson–Enright
//! 2015).
//!
//! Error estimate: advance one full step of size `h` and two half steps of
//! size `h/2` *driven by the same Brownian path* (the half-step midpoint
//! value comes from the noise source's bridge interpolation, so accepted
//! and rejected attempts all see one consistent sample path). The scaled
//! difference between the two candidates estimates the local error; the PI
//! controller turns it into the next step size.
//!
//! This is exactly the machinery that makes the virtual Brownian tree
//! valuable: adaptive solves query the path at unpredictable times, which a
//! stored-increment implementation cannot answer without bridging anyway.

use super::methods::{Method, Stepper};
use super::grid::SolveStats;
use crate::brownian::BrownianMotion;
use crate::sde::SdeFunc;

/// Adaptive-solve configuration (Fig 5b varies `atol` with `rtol = 0`).
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveConfig {
    pub atol: f64,
    pub rtol: f64,
    /// Initial step size (signed direction is inferred from the horizon).
    pub h0: f64,
    /// Smallest |h| allowed before the solve aborts with an error flag.
    pub h_min: f64,
    /// Largest |h| allowed.
    pub h_max: f64,
    /// Safety factor in the controller (0.9 classic).
    pub safety: f64,
    /// PI proportional exponent (on the current error).
    pub k_i: f64,
    /// PI integral exponent (on the previous error).
    pub k_p: f64,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        AdaptiveConfig {
            atol: 1e-3,
            rtol: 0.0,
            h0: 1e-2,
            h_min: 1e-10,
            h_max: 0.5,
            safety: 0.9,
            // Exponents scaled for local strong error ~ h^{1.5}
            // (order-1.0 schemes): classic PI pair (0.3/0.4)/1.5.
            k_i: 0.7 / 1.5,
            k_p: 0.4 / 1.5,
        }
    }
}

/// Result of an adaptive solve.
#[derive(Clone, Debug)]
pub struct AdaptiveResult {
    pub y: Vec<f64>,
    pub stats: SolveStats,
    /// True if the controller hit `h_min` (accuracy not certified).
    pub hit_h_min: bool,
}

/// Adaptive-stepping core behind [`crate::api::SdeProblem::solve`] with
/// `StepControl::Adaptive` (integrates from `t0` to `t1`, either
/// direction).
pub(crate) fn adaptive_core<S: SdeFunc, B: BrownianMotion>(
    sys: &mut S,
    method: Method,
    y0: &[f64],
    t0: f64,
    t1: f64,
    bm: &mut B,
    cfg: &AdaptiveConfig,
) -> AdaptiveResult {
    let d = sys.dim();
    assert_eq!(y0.len(), d);
    assert!(t0 != t1, "integrate_adaptive: empty horizon");
    let dir = (t1 - t0).signum();

    let mut stepper = Stepper::new(method, d);
    let mut y = y0.to_vec();
    let mut y_full = vec![0.0; d];
    let mut y_half = vec![0.0; d];
    let mut y_half2 = vec![0.0; d];
    let mut w_t = vec![0.0; d];
    let mut w_mid = vec![0.0; d];
    let mut w_next = vec![0.0; d];
    let mut dw_full = vec![0.0; d];
    let mut dw_a = vec![0.0; d];
    let mut dw_b = vec![0.0; d];

    let nf0 = sys.nfe_drift();
    let ng0 = sys.nfe_diffusion();
    let mut steps = 0u64;
    let mut rejected = 0u64;
    let mut hit_h_min = false;

    let mut t = t0;
    let mut h = cfg.h0.abs().clamp(cfg.h_min, cfg.h_max) * dir;
    let mut err_prev: f64 = 1.0;

    bm.sample_into(t, &mut w_t);
    while (t1 - t) * dir > 0.0 {
        // Clip the final step to land exactly on t1.
        if (t + h - t1) * dir > 0.0 {
            h = t1 - t;
        }
        let t_mid = t + 0.5 * h;
        let t_next = t + h;
        bm.sample_into(t_mid, &mut w_mid);
        bm.sample_into(t_next, &mut w_next);
        for i in 0..d {
            dw_full[i] = w_next[i] - w_t[i];
            dw_a[i] = w_mid[i] - w_t[i];
            dw_b[i] = w_next[i] - w_mid[i];
        }
        // One full step vs two half steps on the same noise.
        stepper.step(sys, t, h, &y, &dw_full, &mut y_full);
        stepper.step(sys, t, 0.5 * h, &y, &dw_a, &mut y_half);
        stepper.step(sys, t_mid, 0.5 * h, &y_half, &dw_b, &mut y_half2);

        // Scaled RMS error.
        let mut acc = 0.0;
        for i in 0..d {
            let scale = cfg.atol + cfg.rtol * y[i].abs().max(y_half2[i].abs());
            let e = (y_full[i] - y_half2[i]) / scale;
            acc += e * e;
        }
        let err = (acc / d as f64).sqrt().max(1e-12);

        if err <= 1.0 {
            // Accept the more accurate two-half-step candidate.
            t = t_next;
            y.copy_from_slice(&y_half2);
            w_t.copy_from_slice(&w_next);
            steps += 1;
            err_prev = err;
        } else {
            rejected += 1;
        }

        // PI update, clamped.
        let mut factor = cfg.safety * err.powf(-cfg.k_i) * err_prev.powf(cfg.k_p);
        factor = factor.clamp(0.2, 5.0);
        let mut h_new = (h.abs() * factor).clamp(cfg.h_min, cfg.h_max);
        if h_new <= cfg.h_min && err > 1.0 {
            // Cannot refine further: accept under protest and move on.
            hit_h_min = true;
            t = t_next;
            y.copy_from_slice(&y_half2);
            w_t.copy_from_slice(&w_next);
            steps += 1;
            h_new = cfg.h_min;
        }
        h = h_new * dir;
    }

    AdaptiveResult {
        y,
        stats: SolveStats {
            steps,
            rejected,
            nfe_drift: sys.nfe_drift() - nf0,
            nfe_diffusion: sys.nfe_diffusion() - ng0,
        },
        hit_h_min,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::BrownianPath;
    use crate::prng::PrngKey;
    use crate::sde::problems::Example1;
    use crate::sde::{ForwardFunc, ReplicatedSde, ScalarSde};

    fn solve_gbm(atol: f64, seed: u64) -> (f64, f64, SolveStats) {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.5, 0.6];
        let mut bm = BrownianPath::new(PrngKey::from_seed(seed), 1, 0.0, 1.0);
        let mut sys = ForwardFunc::new(&sde, &theta);
        let cfg = AdaptiveConfig { atol, rtol: 0.0, ..Default::default() };
        let res = adaptive_core(&mut sys, Method::MilsteinIto, &[1.0], 0.0, 1.0, &mut bm, &cfg);
        let w = bm.sample(1.0)[0];
        let exact = sde.problem().analytic_solution(1.0, 1.0, &theta, w);
        (res.y[0], exact, res.stats)
    }

    #[test]
    fn tighter_tolerance_reduces_error_and_increases_nfe() {
        let n = 24;
        let mut err_loose = 0.0;
        let mut err_tight = 0.0;
        let mut nfe_loose = 0u64;
        let mut nfe_tight = 0u64;
        for s in 0..n {
            let (y, exact, st) = solve_gbm(1e-2, 100 + s);
            err_loose += (y - exact).abs();
            nfe_loose += st.nfe();
            let (y, exact, st) = solve_gbm(1e-5, 100 + s);
            err_tight += (y - exact).abs();
            nfe_tight += st.nfe();
        }
        assert!(
            err_tight < err_loose,
            "tight {err_tight} should beat loose {err_loose}"
        );
        assert!(nfe_tight > nfe_loose, "tight tol must cost more NFE");
        let mean_tight = err_tight / n as f64;
        assert!(mean_tight < 2e-3, "tight error too large: {mean_tight}");
    }

    #[test]
    fn final_time_is_hit_exactly() {
        let (y, exact, _) = solve_gbm(1e-4, 7);
        // If the final step overshot, the comparison against the exact
        // solution at t=1 would be systematically off.
        assert!((y - exact).abs() < 5e-2, "y={y} exact={exact}");
    }

    #[test]
    fn rejections_happen_under_tight_tolerances() {
        let mut any_rejection = false;
        for s in 0..10 {
            let (_, _, st) = solve_gbm(1e-6, 500 + s);
            if st.rejected > 0 {
                any_rejection = true;
            }
            assert!(st.steps > 10, "tight tol should need many steps");
        }
        assert!(any_rejection, "controller never rejected a step across seeds");
    }

    #[test]
    fn backward_adaptive_runs() {
        // Backward adaptive integration (t0=1 → t1=0) of an additive-noise
        // system retraces approximately the forward path end state.
        use crate::sde::ou::OrnsteinUhlenbeck;
        use crate::solvers::grid::{grid_core, uniform_grid};
        let ou = OrnsteinUhlenbeck::new(2);
        let theta = [1.0, 0.5, 0.4];
        let key = PrngKey::from_seed(11);
        let mut bm = BrownianPath::new(key, 2, 0.0, 1.0);
        let mut sys = ForwardFunc::new(&ou, &theta);
        let grid = uniform_grid(0.0, 1.0, 2048);
        let y0 = [0.2, -0.1];
        let mut y1 = [0.0; 2];
        grid_core(&mut sys, Method::Heun, &y0, &grid, &mut bm, &mut y1);

        let mut sys_b = ForwardFunc::new(&ou, &theta);
        let cfg = AdaptiveConfig { atol: 1e-6, rtol: 0.0, h0: 1e-3, ..Default::default() };
        let res = adaptive_core(&mut sys_b, Method::Heun, &y1, 1.0, 0.0, &mut bm, &cfg);
        for i in 0..2 {
            assert!(
                (res.y[i] - y0[i]).abs() < 1e-2,
                "backward reconstruction dim {i}: {} vs {}",
                res.y[i],
                y0[i]
            );
        }
    }
}
