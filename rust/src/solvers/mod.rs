//! Numerical SDE solvers (§3.2–§3.4).
//!
//! * [`methods`] — single-step schemes: Euler–Maruyama (Itô), Heun
//!   (Stratonovich trapezoid; strong order 1.0 under commutative noise),
//!   and Milstein in both calculi (diagonal noise).
//! * [`grid`] — fixed-grid driver. Steps are *signed*: the same machinery
//!   integrates forward (ascending grid) and backward (descending grid),
//!   which is exactly the symmetry Theorem 2.1(b) buys us in Stratonovich
//!   form (Fig 2).
//! * [`adaptive`] — adaptive time-stepping with step-doubling error
//!   estimation and a PI controller (Burrage–Burrage/Ilie et al., §3.4),
//!   made possible by Brownian sources that answer bridge-consistent
//!   queries at arbitrary times.
//! * [`batch`] — the batched SoA drivers: the same schemes advancing B
//!   paths per step over `[B×d]` buffers with a preallocated
//!   [`Workspace`] (zero heap allocation per step), bit-identical per
//!   path to the scalar drivers.
//!
//! Scalar solvers consume a [`crate::sde::SdeFunc`] (flat diagonal-noise
//! system) and a [`crate::brownian::BrownianMotion`]; batched solvers a
//! [`BatchSdeFunc`] and a [`crate::brownian::BatchBrownian`].

pub mod adaptive;
pub mod batch;
pub mod grid;
pub mod methods;

pub use adaptive::{AdaptiveConfig, AdaptiveResult};
pub use batch::{BatchForwardFunc, BatchSdeFunc, BatchStepper, Workspace};
pub use grid::{uniform_grid, SolveStats};
pub use methods::{Method, Stepper};

pub(crate) use adaptive::adaptive_core;
pub(crate) use batch::{batch_grid_core, batch_grid_saving_core};
pub(crate) use grid::{grid_core, grid_saving_core};
