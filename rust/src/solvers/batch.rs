//! Batched fixed-grid integration: advance B Brownian paths per solver
//! step over contiguous `[B×d]` state buffers.
//!
//! Mirrors the scalar pipeline one level up:
//!
//! | scalar                       | batched                                  |
//! |------------------------------|------------------------------------------|
//! | [`crate::sde::SdeFunc`]      | [`BatchSdeFunc`]                         |
//! | [`crate::sde::ForwardFunc`]  | [`BatchForwardFunc`]                     |
//! | [`super::methods::Stepper`]  | [`BatchStepper`] over a [`Workspace`]    |
//! | [`super::grid::grid_core`]   | [`batch_grid_core`]                      |
//!
//! Every per-path float is computed by the *same expression in the same
//! order* as the scalar engine, so a batch of B paths equals B scalar
//! solves exactly (`tests/batch_engine.rs` pins this bit-for-bit). The
//! payoff is architectural: one virtual call per *stage* instead of per
//! *path*, coefficients and weight rows hot in cache across all B paths,
//! and zero heap allocation per step — the [`Workspace`] is sized once
//! and recycled across solves through a per-thread pool (persistent pool
//! workers re-lease the same warm buffers; see [`crate::runtime`]).
//!
//! NFE accounting stays in per-path units: one batched drift call counts
//! as one drift evaluation (it is one evaluation *per path*), so the
//! returned [`SolveStats`] apply to each path and match the scalar
//! engine's numbers.

use super::grid::SolveStats;
use super::methods::Method;
use crate::brownian::{BatchBrownian, BrownianMotion};
use crate::sde::{BatchSde, Calculus, KernelTier};

/// A flat batched diagonal-noise system as seen by the batched
/// integrators: all buffers are row-major `[B×d]`.
pub trait BatchSdeFunc {
    /// Per-path state dimension d.
    fn dim(&self) -> usize;
    /// Batch size B.
    fn batch(&self) -> usize;
    /// Calculus in which `drift`/`diffusion` are expressed.
    fn calculus(&self) -> Calculus;
    /// Drift of every path into `out`.
    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]);
    /// Diagonal diffusion of every path into `out`.
    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]);
    /// Whether [`BatchSdeFunc::diffusion_dy_diag`] is available.
    fn has_diffusion_jacobian(&self) -> bool {
        false
    }
    /// `∂g_i/∂y_i` of every path into `out`.
    fn diffusion_dy_diag(&mut self, _t: f64, _y: &[f64], _out: &mut [f64]) {
        unimplemented!("diffusion_dy_diag not provided by this batched system")
    }
    /// Drift **and** diffusion of every path — the first stage of every
    /// explicit scheme. Default: drift then diffusion, in that order, so
    /// the exact tier's float sequence is untouched. Fast-tier systems
    /// override with one fused sweep over the state buffer.
    fn drift_and_diffusion(&mut self, t: f64, y: &[f64], f_out: &mut [f64], g_out: &mut [f64]) {
        self.drift(t, y, f_out);
        self.diffusion(t, y, g_out);
    }
    /// Drift evaluations performed, in per-path units (one batched call =
    /// one evaluation).
    fn nfe_drift(&self) -> u64;
    /// Diffusion evaluations performed, per-path units.
    fn nfe_diffusion(&self) -> u64;
}

/// Batched forward solve of a [`BatchSde`] at fixed parameters, with the
/// same target-calculus conversion as [`crate::sde::ForwardFunc`]: the
/// drift is corrected by `±½σσ'` when the scheme's calculus differs from
/// the SDE's native one, elementwise over the `[B×d]` buffers.
pub struct BatchForwardFunc<'a, S: BatchSde + ?Sized> {
    sde: &'a S,
    theta: &'a [f64],
    target: Calculus,
    batch: usize,
    tier: KernelTier,
    sig: Vec<f64>,
    dsig: Vec<f64>,
    nfe_f: u64,
    nfe_g: u64,
}

impl<'a, S: BatchSde + ?Sized> BatchForwardFunc<'a, S> {
    /// Expose the coefficients converted for `method`'s calculus, on the
    /// exact (bit-identical) kernel tier.
    pub fn for_method(sde: &'a S, theta: &'a [f64], batch: usize, method: Method) -> Self {
        Self::in_calculus_tier(sde, theta, batch, method.calculus(), KernelTier::Exact)
    }

    /// Like [`Self::for_method`] with an explicit kernel tier.
    pub fn for_method_tier(
        sde: &'a S,
        theta: &'a [f64],
        batch: usize,
        method: Method,
        tier: KernelTier,
    ) -> Self {
        Self::in_calculus_tier(sde, theta, batch, method.calculus(), tier)
    }

    /// Expose the coefficients in an explicit target calculus on the
    /// exact tier.
    pub fn in_calculus(sde: &'a S, theta: &'a [f64], batch: usize, target: Calculus) -> Self {
        Self::in_calculus_tier(sde, theta, batch, target, KernelTier::Exact)
    }

    /// Expose the coefficients in an explicit target calculus and tier.
    pub fn in_calculus_tier(
        sde: &'a S,
        theta: &'a [f64],
        batch: usize,
        target: Calculus,
        tier: KernelTier,
    ) -> Self {
        assert_eq!(
            theta.len(),
            sde.param_dim(),
            "BatchForwardFunc: theta length {} != param_dim {}",
            theta.len(),
            sde.param_dim()
        );
        assert!(batch > 0, "BatchForwardFunc: empty batch");
        let n = batch * sde.state_dim();
        BatchForwardFunc {
            sde,
            theta,
            target,
            batch,
            tier,
            sig: vec![0.0; n],
            dsig: vec![0.0; n],
            nfe_f: 0,
            nfe_g: 0,
        }
    }
}

impl<'a, S: BatchSde + ?Sized> BatchSdeFunc for BatchForwardFunc<'a, S> {
    fn dim(&self) -> usize {
        self.sde.state_dim()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn calculus(&self) -> Calculus {
        self.target
    }

    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_f += 1;
        match self.tier {
            KernelTier::Exact => self.sde.drift_batch(t, y, self.theta, out),
            KernelTier::Fast => self.sde.drift_batch_fast(t, y, self.theta, out),
        }
        let native = self.sde.calculus();
        if native != self.target {
            match self.tier {
                KernelTier::Exact => {
                    self.sde.diffusion_batch(t, y, self.theta, &mut self.sig);
                    self.sde.diffusion_dz_diag_batch(t, y, self.theta, &mut self.dsig);
                }
                KernelTier::Fast => {
                    self.sde.diffusion_batch_fast(t, y, self.theta, &mut self.sig);
                    self.sde.diffusion_dz_diag_batch_fast(t, y, self.theta, &mut self.dsig);
                }
            }
            let sign = match (native, self.target) {
                (Calculus::Ito, Calculus::Stratonovich) => -0.5,
                (Calculus::Stratonovich, Calculus::Ito) => 0.5,
                _ => unreachable!(),
            };
            for ((o, s), ds) in out.iter_mut().zip(&self.sig).zip(&self.dsig) {
                *o += sign * s * ds;
            }
        }
    }

    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_g += 1;
        match self.tier {
            KernelTier::Exact => self.sde.diffusion_batch(t, y, self.theta, out),
            KernelTier::Fast => self.sde.diffusion_batch_fast(t, y, self.theta, out),
        }
    }

    fn has_diffusion_jacobian(&self) -> bool {
        true
    }

    fn diffusion_dy_diag(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        match self.tier {
            KernelTier::Exact => self.sde.diffusion_dz_diag_batch(t, y, self.theta, out),
            KernelTier::Fast => self.sde.diffusion_dz_diag_batch_fast(t, y, self.theta, out),
        }
    }

    /// Fast tier: one fused sweep produces both stage coefficients; the
    /// calculus correction reuses `g_out` as σ (it *is* σ) so only σ′
    /// needs a second pass. Exact tier: the default drift-then-diffusion
    /// order, bit for bit.
    fn drift_and_diffusion(&mut self, t: f64, y: &[f64], f_out: &mut [f64], g_out: &mut [f64]) {
        match self.tier {
            KernelTier::Exact => {
                self.drift(t, y, f_out);
                self.diffusion(t, y, g_out);
            }
            KernelTier::Fast => {
                self.nfe_f += 1;
                self.nfe_g += 1;
                self.sde.drift_diffusion_batch_fast(t, y, self.theta, f_out, g_out);
                let native = self.sde.calculus();
                if native != self.target {
                    self.sde.diffusion_dz_diag_batch_fast(t, y, self.theta, &mut self.dsig);
                    let sign = match (native, self.target) {
                        (Calculus::Ito, Calculus::Stratonovich) => -0.5,
                        (Calculus::Stratonovich, Calculus::Ito) => 0.5,
                        _ => unreachable!(),
                    };
                    for ((o, s), ds) in f_out.iter_mut().zip(g_out.iter()).zip(&self.dsig) {
                        *o += sign * s * ds;
                    }
                }
            }
        }
    }

    fn nfe_drift(&self) -> u64 {
        self.nfe_f
    }

    fn nfe_diffusion(&self) -> u64 {
        self.nfe_g
    }
}

/// Preallocated step scratch: six `[B×d]` stage buffers plus the
/// increment buffer. Sized once per solve; the stepping loop performs no
/// heap allocation.
pub struct Workspace {
    f0: Vec<f64>,
    g0: Vec<f64>,
    f1: Vec<f64>,
    g1: Vec<f64>,
    ytmp: Vec<f64>,
    gp: Vec<f64>,
    /// Brownian increments of the current step (`[B×d]`).
    pub dw: Vec<f64>,
}

impl Workspace {
    pub fn new(dim: usize, batch: usize) -> Self {
        let n = dim * batch;
        Workspace {
            f0: vec![0.0; n],
            g0: vec![0.0; n],
            f1: vec![0.0; n],
            g1: vec![0.0; n],
            ytmp: vec![0.0; n],
            gp: vec![0.0; n],
            dw: vec![0.0; n],
        }
    }

    /// A workspace from the calling thread's recycle pool (pool workers
    /// are persistent, so the same buffers serve every chunk a worker
    /// ever runs). All seven buffers are re-zeroed, making the lease
    /// observationally identical to [`Workspace::new`] — recycling can
    /// never change a computed float.
    pub(crate) fn recycled(dim: usize, batch: usize) -> WorkspaceLease {
        let n = dim * batch;
        let ws = WS_POOL.with(|p| {
            let mut pool = p.borrow_mut();
            let hit = pool.iter().position(|w| w.f0.len() == n);
            hit.map(|i| pool.swap_remove(i))
        });
        ws_counters().record(ws.is_some());
        let ws = match ws {
            Some(mut ws) => {
                for buf in
                    [&mut ws.f0, &mut ws.g0, &mut ws.f1, &mut ws.g1, &mut ws.ytmp, &mut ws.gp,
                     &mut ws.dw]
                {
                    buf.fill(0.0);
                }
                ws
            }
            None => Workspace::new(dim, batch),
        };
        WorkspaceLease { ws: Some(ws) }
    }
}

thread_local! {
    static WS_POOL: std::cell::RefCell<Vec<Workspace>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Registry counters for [`Workspace`] recycling (one relaxed add per
/// lease — a per-solve-call event, not per step).
struct WsCounters {
    recycled: crate::obs::Counter,
    fresh: crate::obs::Counter,
}

impl WsCounters {
    fn record(&self, hit: bool) {
        if hit {
            self.recycled.inc();
        } else {
            self.fresh.inc();
        }
    }
}

fn ws_counters() -> &'static WsCounters {
    static COUNTERS: std::sync::OnceLock<WsCounters> = std::sync::OnceLock::new();
    COUNTERS.get_or_init(|| WsCounters {
        recycled: crate::obs::counter("solve.workspace_recycled"),
        fresh: crate::obs::counter("solve.workspace_fresh"),
    })
}

/// Workspaces kept per thread; excess drops fall back to the allocator.
const WS_POOL_MAX: usize = 8;

/// RAII handle from [`Workspace::recycled`]: dereferences to the
/// workspace, returns it to the thread-local pool on drop.
pub(crate) struct WorkspaceLease {
    ws: Option<Workspace>,
}

impl std::ops::Deref for WorkspaceLease {
    type Target = Workspace;
    fn deref(&self) -> &Workspace {
        self.ws.as_ref().expect("workspace leased")
    }
}

impl std::ops::DerefMut for WorkspaceLease {
    fn deref_mut(&mut self) -> &mut Workspace {
        self.ws.as_mut().expect("workspace leased")
    }
}

impl Drop for WorkspaceLease {
    fn drop(&mut self) {
        if let Some(ws) = self.ws.take() {
            WS_POOL.with(|p| {
                let mut pool = p.borrow_mut();
                if pool.len() < WS_POOL_MAX {
                    pool.push(ws);
                }
            });
        }
    }
}

/// Batched single-step schemes over a [`Workspace`]. Same update formulas
/// as [`super::methods::Stepper`], applied elementwise to `[B×d]` rows.
pub struct BatchStepper {
    method: Method,
}

impl BatchStepper {
    pub fn new(method: Method) -> Self {
        BatchStepper { method }
    }

    pub fn method(&self) -> Method {
        self.method
    }

    /// Advance all paths at time `t` by a signed step `h` with signed
    /// per-path increments `ws.dw`. Writes the new states into `out` (may
    /// not alias `y`).
    pub fn step<S: BatchSdeFunc>(
        &self,
        sys: &mut S,
        t: f64,
        h: f64,
        y: &[f64],
        ws: &mut Workspace,
        out: &mut [f64],
    ) {
        let n = y.len();
        debug_assert_eq!(ws.dw.len(), n);
        debug_assert_eq!(out.len(), n);
        match self.method {
            Method::EulerMaruyama => {
                sys.drift_and_diffusion(t, y, &mut ws.f0, &mut ws.g0);
                for i in 0..n {
                    out[i] = y[i] + ws.f0[i] * h + ws.g0[i] * ws.dw[i];
                }
            }
            Method::Heun => {
                sys.drift_and_diffusion(t, y, &mut ws.f0, &mut ws.g0);
                for i in 0..n {
                    ws.ytmp[i] = y[i] + ws.f0[i] * h + ws.g0[i] * ws.dw[i];
                }
                let t1 = t + h;
                sys.drift_and_diffusion(t1, &ws.ytmp, &mut ws.f1, &mut ws.g1);
                for i in 0..n {
                    out[i] = y[i]
                        + 0.5 * (ws.f0[i] + ws.f1[i]) * h
                        + 0.5 * (ws.g0[i] + ws.g1[i]) * ws.dw[i];
                }
            }
            Method::MilsteinIto | Method::MilsteinStrat => {
                assert!(
                    sys.has_diffusion_jacobian(),
                    "Milstein requires diffusion_dy_diag; use Heun instead"
                );
                sys.drift_and_diffusion(t, y, &mut ws.f0, &mut ws.g0);
                sys.diffusion_dy_diag(t, y, &mut ws.gp);
                let ito = self.method == Method::MilsteinIto;
                for i in 0..n {
                    let dw = ws.dw[i];
                    let corr = if ito { dw * dw - h } else { dw * dw };
                    out[i] =
                        y[i] + ws.f0[i] * h + ws.g0[i] * dw + 0.5 * ws.g0[i] * ws.gp[i] * corr;
                }
            }
        }
    }
}

/// Batched fixed-grid integration core: advance all of `y0` (`[B×d]`)
/// along `times` (monotone, either direction), one batched step per grid
/// interval, writing terminal states into `y_out`. Returns per-path solve
/// statistics (identical for every path — uniform grid, shared scheme).
pub(crate) fn batch_grid_core<S: BatchSdeFunc, B: BrownianMotion>(
    sys: &mut S,
    method: Method,
    y0: &[f64],
    times: &[f64],
    bm: &mut BatchBrownian<B>,
    y_out: &mut [f64],
) -> SolveStats {
    let n = sys.dim() * sys.batch();
    assert_eq!(y0.len(), n, "batch_grid_core: y0 length mismatch");
    assert_eq!(y_out.len(), n, "batch_grid_core: y_out length mismatch");
    assert!(times.len() >= 2, "batch_grid_core: need at least two time points");
    debug_assert_eq!(bm.dim(), sys.dim(), "batch_grid_core: Brownian dim mismatch");
    debug_assert_eq!(bm.batch(), sys.batch(), "batch_grid_core: Brownian batch mismatch");

    let _span = crate::obs::span!("solve.batch.grid");
    let stepper = BatchStepper::new(method);
    let mut ws = Workspace::recycled(sys.dim(), sys.batch());
    let mut y = crate::runtime::arena::lease(n);
    y.copy_from_slice(y0);
    let mut ynext = crate::runtime::arena::lease(n);

    let f0 = sys.nfe_drift();
    let g0 = sys.nfe_diffusion();
    let mut steps = 0u64;

    bm.begin_sweep(times[0]);
    for k in 0..times.len() - 1 {
        let (t, tn) = (times[k], times[k + 1]);
        bm.sweep_increments(tn, &mut ws.dw);
        stepper.step(sys, t, tn - t, &y, &mut ws, &mut ynext);
        std::mem::swap(&mut y, &mut ynext);
        steps += 1;
    }
    y_out.copy_from_slice(&y);
    SolveStats {
        steps,
        rejected: 0,
        nfe_drift: sys.nfe_drift() - f0,
        nfe_diffusion: sys.nfe_diffusion() - g0,
    }
}

/// Like [`batch_grid_core`] but records every path's state at every grid
/// point. Returns the trajectories as one flat `(times.len(), B, d)`
/// buffer — grid point `k`, path `b` at `[(k*B + b)*d .. (k*B + b + 1)*d]`
/// — plus per-path statistics.
pub(crate) fn batch_grid_saving_core<S: BatchSdeFunc, B: BrownianMotion>(
    sys: &mut S,
    method: Method,
    y0: &[f64],
    times: &[f64],
    bm: &mut BatchBrownian<B>,
) -> (Vec<f64>, SolveStats) {
    let _span = crate::obs::span!("solve.batch.grid_saving");
    let n = sys.dim() * sys.batch();
    let mut traj = vec![0.0; times.len() * n];
    traj[..n].copy_from_slice(y0);

    let stepper = BatchStepper::new(method);
    let mut ws = Workspace::recycled(sys.dim(), sys.batch());
    let mut y = crate::runtime::arena::lease(n);
    y.copy_from_slice(y0);
    let mut ynext = crate::runtime::arena::lease(n);

    let f0 = sys.nfe_drift();
    let g0 = sys.nfe_diffusion();

    bm.begin_sweep(times[0]);
    for k in 0..times.len() - 1 {
        let (t, tn) = (times[k], times[k + 1]);
        bm.sweep_increments(tn, &mut ws.dw);
        stepper.step(sys, t, tn - t, &y, &mut ws, &mut ynext);
        std::mem::swap(&mut y, &mut ynext);
        traj[(k + 1) * n..(k + 2) * n].copy_from_slice(&y);
    }
    let stats = SolveStats {
        steps: (times.len() - 1) as u64,
        rejected: 0,
        nfe_drift: sys.nfe_drift() - f0,
        nfe_diffusion: sys.nfe_diffusion() - g0,
    };
    (traj, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::BrownianPath;
    use crate::prng::PrngKey;
    use crate::sde::problems::{sample_experiment_setup, Example1};
    use crate::sde::{ForwardFunc, ReplicatedSde};
    use crate::solvers::{grid_core, uniform_grid};

    /// The batched kernel must reproduce B scalar solves bit-for-bit for
    /// every scheme (the integration-level pin; the API-level one lives in
    /// tests/batch_engine.rs).
    #[test]
    fn batch_kernel_equals_scalar_kernel_per_path() {
        let dim = 3;
        let bsz = 4;
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(88);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let grid = uniform_grid(0.0, 1.0, 64);

        for method in [Method::EulerMaruyama, Method::MilsteinIto, Method::Heun] {
            let mk = |b: u64| BrownianPath::new(key.fold_in(b), dim, 0.0, 1.0);
            let mut bm = BatchBrownian::new((0..bsz as u64).map(mk).collect());
            let mut sys = BatchForwardFunc::for_method(&sde, &theta, bsz, method);
            let y0: Vec<f64> = (0..bsz).flat_map(|_| x0.clone()).collect();
            let mut y_batch = vec![0.0; bsz * dim];
            let stats_b = batch_grid_core(&mut sys, method, &y0, &grid, &mut bm, &mut y_batch);

            for b in 0..bsz {
                let mut single = mk(b as u64);
                let mut ssys = ForwardFunc::for_method(&sde, &theta, method);
                let mut y = vec![0.0; dim];
                let stats_s = grid_core(&mut ssys, method, &x0, &grid, &mut single, &mut y);
                assert_eq!(&y_batch[b * dim..(b + 1) * dim], &y[..], "{} path {b}", method.name());
                assert_eq!(stats_b, stats_s, "{} stats", method.name());
            }
        }
    }

    /// Saving variant: per-path trajectories equal the scalar saving
    /// driver's, and the terminal row equals the non-saving kernel.
    #[test]
    fn batch_saving_matches_scalar_saving() {
        use crate::solvers::grid::grid_saving_core;
        let dim = 2;
        let bsz = 3;
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(99);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let grid = uniform_grid(0.0, 1.0, 16);
        let mk = |b: u64| BrownianPath::new(key.fold_in(100 + b), dim, 0.0, 1.0);

        let mut bm = BatchBrownian::new((0..bsz as u64).map(mk).collect());
        let mut sys = BatchForwardFunc::for_method(&sde, &theta, bsz, Method::Heun);
        let y0: Vec<f64> = (0..bsz).flat_map(|_| x0.clone()).collect();
        let (traj, _) = batch_grid_saving_core(&mut sys, Method::Heun, &y0, &grid, &mut bm);

        for b in 0..bsz {
            let mut single = mk(b as u64);
            let mut ssys = ForwardFunc::for_method(&sde, &theta, Method::Heun);
            let (straj, _) = grid_saving_core(&mut ssys, Method::Heun, &x0, &grid, &mut single);
            for k in 0..grid.len() {
                assert_eq!(
                    &traj[(k * bsz + b) * dim..(k * bsz + b + 1) * dim],
                    &straj[k * dim..(k + 1) * dim],
                    "grid point {k} path {b}"
                );
            }
        }
    }
}
