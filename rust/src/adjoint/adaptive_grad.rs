//! Adaptive-solver stochastic adjoint for the replicated scalar problems
//! (Fig 5b: gradient MSE vs NFE as `atol` shrinks, `rtol = 0`).
//!
//! For a [`ReplicatedSde`] the augmented backward system is *fully
//! diagonal* — dimension `i`'s state, adjoint, and parameter block are all
//! driven by channel `i` alone — so it fits the generic diagonal-noise
//! integrator and hence [`crate::solvers::adaptive`] directly.
//! (The general cross-channel case needs the bespoke driver in
//! [`super::stochastic`]; adaptivity there is future work, as in the
//! paper, whose adaptive experiments are exactly these scalar problems.)
//!
//! The flat augmented state is `[z (d) | a (d) | a_θ (d·k)]`, and
//! [`ChannelMappedBrownian`] replicates the d physical channels into that
//! layout for the solver's per-slot `dw` interface.

use crate::brownian::{BrownianMotion, BrownianPath};
use crate::prng::PrngKey;
use crate::sde::{Calculus, ReplicatedSde, ScalarSde, SdeFunc};
use crate::solvers::{adaptive_core, AdaptiveConfig, Method, SolveStats};

/// Expands a d-channel Brownian source to `n` slots via a slot→channel
/// map (consistency is inherited from the inner source).
pub struct ChannelMappedBrownian<'a, B: BrownianMotion> {
    inner: &'a mut B,
    map: Vec<usize>,
    buf: Vec<f64>,
}

impl<'a, B: BrownianMotion> ChannelMappedBrownian<'a, B> {
    pub fn new(inner: &'a mut B, map: Vec<usize>) -> Self {
        let d = inner.dim();
        assert!(map.iter().all(|&c| c < d), "channel map out of range");
        let buf = vec![0.0; d];
        ChannelMappedBrownian { inner, map, buf }
    }
}

impl<'a, B: BrownianMotion> BrownianMotion for ChannelMappedBrownian<'a, B> {
    fn dim(&self) -> usize {
        self.map.len()
    }
    fn span(&self) -> (f64, f64) {
        self.inner.span()
    }
    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        self.inner.sample_into(t, &mut self.buf);
        for (slot, &ch) in self.map.iter().enumerate() {
            out[slot] = self.buf[ch];
        }
    }
    fn memory_footprint(&self) -> usize {
        self.inner.memory_footprint()
    }
}

/// The fully-diagonal augmented backward system of a replicated scalar
/// problem, in Stratonovich form with analytic derivatives.
pub struct ReplicatedAugmentedFunc<'a, P: ScalarSde> {
    sde: &'a ReplicatedSde<P>,
    theta: &'a [f64],
    d: usize,
    k: usize,
    nfe_f: u64,
    nfe_g: u64,
    dth: Vec<f64>,
    dth2: Vec<f64>,
}

impl<'a, P: ScalarSde> ReplicatedAugmentedFunc<'a, P> {
    pub fn new(sde: &'a ReplicatedSde<P>, theta: &'a [f64]) -> Self {
        let d = crate::sde::Sde::state_dim(sde);
        let k = sde.problem().nparams();
        ReplicatedAugmentedFunc {
            sde,
            theta,
            d,
            k,
            nfe_f: 0,
            nfe_g: 0,
            dth: vec![0.0; k],
            dth2: vec![0.0; k],
        }
    }

    /// Slot→channel map for [`ChannelMappedBrownian`].
    pub fn channel_map(&self) -> Vec<usize> {
        let (d, k) = (self.d, self.k);
        let mut map = Vec::with_capacity(2 * d + d * k);
        map.extend(0..d); // z block
        map.extend(0..d); // a block
        for i in 0..d {
            map.extend(std::iter::repeat(i).take(k)); // θ block of dim i
        }
        map
    }

    /// Pack the initial backward state `(z_T, ∂L/∂z_T = 1, 0)`.
    pub fn pack_terminal(&self, z_t: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; 2 * self.d + self.d * self.k];
        y[..self.d].copy_from_slice(z_t);
        for i in 0..self.d {
            y[self.d + i] = 1.0;
        }
        y
    }

    /// Extract `(grad_z0, grad_theta)` from the terminal backward state.
    pub fn unpack_gradients(&self, y: &[f64]) -> (Vec<f64>, Vec<f64>) {
        (y[self.d..2 * self.d].to_vec(), y[2 * self.d..].to_vec())
    }
}

impl<'a, P: ScalarSde> SdeFunc for ReplicatedAugmentedFunc<'a, P> {
    fn dim(&self) -> usize {
        2 * self.d + self.d * self.k
    }

    fn calculus(&self) -> Calculus {
        Calculus::Stratonovich
    }

    fn drift(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_f += 1;
        let (d, k) = (self.d, self.k);
        let p = self.sde.problem();
        let ito = p.calculus() == Calculus::Ito;
        for i in 0..d {
            let th = &self.theta[i * k..(i + 1) * k];
            let (x, a) = (y[i], y[d + i]);
            let b = p.drift(t, x, th);
            let b_x = p.drift_dx(t, x, th);
            p.drift_dtheta(t, x, th, &mut self.dth);
            let (bt, bt_x) = if ito {
                // Stratonovich conversion: b̃ = b − ½σσ'.
                let s = p.diffusion(t, x, th);
                let s_x = p.diffusion_dx(t, x, th);
                let s_xx = p.diffusion_dxx(t, x, th);
                p.diffusion_dtheta(t, x, th, &mut self.dth2);
                let mut dsx_dth = vec![0.0; k];
                p.diffusion_dx_dtheta(t, x, th, &mut dsx_dth);
                for j in 0..k {
                    self.dth[j] -= 0.5 * (self.dth2[j] * s_x + s * dsx_dth[j]);
                }
                (b - 0.5 * s * s_x, b_x - 0.5 * (s_x * s_x + s * s_xx))
            } else {
                (b, b_x)
            };
            out[i] = bt;
            out[d + i] = -a * bt_x;
            for j in 0..k {
                out[2 * d + i * k + j] = -a * self.dth[j];
            }
        }
    }

    fn diffusion(&mut self, t: f64, y: &[f64], out: &mut [f64]) {
        self.nfe_g += 1;
        let (d, k) = (self.d, self.k);
        let p = self.sde.problem();
        for i in 0..d {
            let th = &self.theta[i * k..(i + 1) * k];
            let (x, a) = (y[i], y[d + i]);
            out[i] = p.diffusion(t, x, th);
            out[d + i] = -a * p.diffusion_dx(t, x, th);
            p.diffusion_dtheta(t, x, th, &mut self.dth);
            for j in 0..k {
                out[2 * d + i * k + j] = -a * self.dth[j];
            }
        }
    }

    fn nfe_drift(&self) -> u64 {
        self.nfe_f
    }

    fn nfe_diffusion(&self) -> u64 {
        self.nfe_g
    }
}

/// Output of an adaptive adjoint gradient computation.
#[derive(Clone, Debug)]
pub struct AdaptiveGradOutput {
    pub z_terminal: Vec<f64>,
    pub grad_z0: Vec<f64>,
    pub grad_theta: Vec<f64>,
    pub w_terminal: Vec<f64>,
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
    pub hit_h_min: bool,
}

/// Adaptive-adjoint engine behind
/// [`crate::api::SdeProblem::sensitivity_adaptive`]: gradient of
/// `L = Σ z_T` for a replicated scalar problem using adaptive
/// time-stepping in BOTH passes (Fig 5b's setting: vary `atol`, rtol=0).
pub(crate) fn adaptive_adjoint_core<P: ScalarSde>(
    sde: &ReplicatedSde<P>,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    key: PrngKey,
    cfg: &AdaptiveConfig,
) -> AdaptiveGradOutput {
    let d = crate::sde::Sde::state_dim(sde);
    let mut bm = BrownianPath::new(key, d, t0, t1);

    // Forward adaptive (Milstein — strong order 1.0, as in the paper).
    let mut fsys = crate::sde::ForwardFunc::for_method(sde, theta, Method::MilsteinIto);
    let fres = adaptive_core(&mut fsys, Method::MilsteinIto, z0, t0, t1, &mut bm, cfg);
    let w_terminal = bm.sample(t1);

    // Backward adaptive on the augmented diagonal system (Heun —
    // Stratonovich, equals commutative Milstein).
    let mut aug = ReplicatedAugmentedFunc::new(sde, theta);
    let map = aug.channel_map();
    let y_t = aug.pack_terminal(&fres.y);
    let mut mapped = ChannelMappedBrownian::new(&mut bm, map);
    let bres = adaptive_core(&mut aug, Method::Heun, &y_t, t1, t0, &mut mapped, cfg);
    let (grad_z0, grad_theta) = aug.unpack_gradients(&bres.y);

    AdaptiveGradOutput {
        z_terminal: fres.y,
        grad_z0,
        grad_theta,
        w_terminal,
        forward_stats: fres.stats,
        backward_stats: bres.stats,
        hit_h_min: fres.hit_h_min || bres.hit_h_min,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};

    #[test]
    fn channel_map_layout() {
        let sde = ReplicatedSde::new(Example1, 3);
        let theta = vec![0.5; 6];
        let aug = ReplicatedAugmentedFunc::new(&sde, &theta);
        let map = aug.channel_map();
        assert_eq!(map.len(), 3 + 3 + 6);
        assert_eq!(&map[..3], &[0, 1, 2]);
        assert_eq!(&map[3..6], &[0, 1, 2]);
        assert_eq!(&map[6..], &[0, 0, 1, 1, 2, 2]);
    }

    #[test]
    fn mapped_brownian_replicates_channels() {
        let mut bm = BrownianPath::new(PrngKey::from_seed(1), 2, 0.0, 1.0);
        let mut mapped = ChannelMappedBrownian::new(&mut bm, vec![0, 1, 0, 1, 1]);
        let w = mapped.sample(0.5);
        assert_eq!(w[0], w[2]);
        assert_eq!(w[1], w[3]);
        assert_eq!(w[1], w[4]);
        assert_ne!(w[0], w[1]);
    }

    fn adaptive_vs_analytic<P: ScalarSde + Copy>(problem: P, atol: f64, seed: u64) -> (f64, u64) {
        let dim = 3;
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let cfg = AdaptiveConfig { atol, rtol: 0.0, h0: 1e-3, ..Default::default() };
        let out = adaptive_adjoint_core(&sde, &theta, &x0, 0.0, 1.0, key, &cfg);
        let mut g_x0 = vec![0.0; dim];
        let mut g_th = vec![0.0; theta.len()];
        sde.analytic_loss_gradients(1.0, &x0, &theta, &out.w_terminal, &mut g_x0, &mut g_th);
        let mse = g_th
            .iter()
            .zip(&out.grad_theta)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            / g_th.len() as f64;
        (mse, out.forward_stats.nfe() + out.backward_stats.nfe())
    }

    #[test]
    fn tighter_atol_improves_gradient_mse() {
        // Average across a few paths (single-path errors are noisy).
        let reps = 6;
        let mut mse_loose = 0.0;
        let mut mse_tight = 0.0;
        let mut nfe_loose = 0;
        let mut nfe_tight = 0;
        for r in 0..reps {
            let (m, n) = adaptive_vs_analytic(Example1, 1e-2, 300 + r);
            mse_loose += m;
            nfe_loose += n;
            let (m, n) = adaptive_vs_analytic(Example1, 1e-5, 300 + r);
            mse_tight += m;
            nfe_tight += n;
        }
        assert!(
            mse_tight < mse_loose,
            "tight atol should reduce gradient MSE: {mse_tight} vs {mse_loose}"
        );
        assert!(nfe_tight > nfe_loose, "tight atol should cost more NFE");
    }

    #[test]
    fn example2_adaptive_gradients_converge() {
        let (mse, _) = adaptive_vs_analytic(Example2, 1e-5, 42);
        assert!(mse < 1e-3, "gradient MSE too large: {mse}");
    }
}
