//! Baseline: forward pathwise sensitivity (Yang & Kushner 1991; Gobet &
//! Munos 2005; Table 1 row 1).
//!
//! Propagates the full sensitivity matrix `S_t = ∂z_t/∂(z_0, θ) ∈
//! R^{d×(d+p)}` alongside the state:
//!
//! ```text
//! dS = (∂b/∂z · S + [0 | ∂b/∂θ]) dt + (∂σ/∂z · S + [0 | ∂σ/∂θ]) dW
//! ```
//!
//! (diagonal noise: row i of the diffusion part is driven by `dW_i`).
//! The Jacobian rows are materialized from the SDE's VJPs — one unit-vector
//! VJP per state dimension per step — which is precisely why this method
//! costs O(L·D) time while staying O(1)-memory in L. For neural drift
//! functions with 10⁴⁺ parameters this is the "prohibitive" cost the paper
//! replaces (§2.3/§6); it is implemented here as an honest baseline for
//! Table 1.

use super::stochastic::{GradientOutput, Noise, NoiseMode};
use crate::brownian::BrownianMotion;
use crate::prng::PrngKey;
use crate::sde::{Calculus, SdeVjp};
use crate::solvers::{uniform_grid, SolveStats};

/// Forward-sensitivity engine behind
/// [`crate::api::SdeProblem::sensitivity`] with `SensAlg::ForwardPathwise`
/// — Euler–Maruyama stepping of the augmented `(z, S)` system against any
/// replayable noise source (stored path, virtual tree, mirrored either
/// way). `loss_grad` maps the realized terminal state to `∂L/∂z_T`, which
/// is contracted against the propagated sensitivity matrix.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pathwise_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    noise_mode: NoiseMode,
    mirror: bool,
    loss_grad: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    assert_eq!(
        sde.calculus(),
        Calculus::Ito,
        "pathwise baseline integrates the native Itô form"
    );
    let d = sde.state_dim();
    let p = sde.param_dim();
    let cols = d + p;
    let grid = uniform_grid(t0, t1, n_steps);
    let mut bm = Noise::new(noise_mode, key, d, t0, t1, mirror);

    let mut z = z0.to_vec();
    let mut z_next = vec![0.0; d];
    // S row-major d×(d+p); S_0 = [I | 0].
    let mut s_mat = vec![0.0; d * cols];
    for i in 0..d {
        s_mat[i * cols + i] = 1.0;
    }
    let mut s_next = vec![0.0; d * cols];

    let mut b = vec![0.0; d];
    let mut sig = vec![0.0; d];
    let mut dsig = vec![0.0; d];
    let mut jb_row_z = vec![0.0; d]; // e_iᵀ ∂b/∂z
    let mut jb_row_th = vec![0.0; p]; // e_iᵀ ∂b/∂θ
    let mut js_row_th = vec![0.0; p]; // e_iᵀ ∂σ/∂θ
    let mut e_i = vec![0.0; d];
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];
    let mut dw = vec![0.0; d];
    let mut nfe_f = 0u64;
    let mut nfe_g = 0u64;

    bm.sample_into(grid[0], &mut wa);
    for k in 0..n_steps {
        let (t, tn) = (grid[k], grid[k + 1]);
        let h = tn - t;
        bm.sample_into(tn, &mut wb);
        for i in 0..d {
            dw[i] = wb[i] - wa[i];
        }

        sde.drift(t, &z, theta, &mut b);
        sde.diffusion(t, &z, theta, &mut sig);
        sde.diffusion_dz_diag(t, &z, theta, &mut dsig);
        nfe_f += 1;
        nfe_g += 1;

        // State update (Euler–Maruyama).
        for i in 0..d {
            z_next[i] = z[i] + b[i] * h + sig[i] * dw[i];
        }

        // Sensitivity update, row by row.
        for i in 0..d {
            // Row i of ∂b/∂z and ∂b/∂θ via a unit-vector VJP (this loop is
            // the O(D) factor in Table 1's time column).
            e_i.fill(0.0);
            e_i[i] = 1.0;
            jb_row_z.fill(0.0);
            jb_row_th.fill(0.0);
            sde.drift_vjp(t, &z, theta, &e_i, &mut jb_row_z, &mut jb_row_th);
            js_row_th.fill(0.0);
            let mut js_row_z_scratch = [0.0; 0];
            let _ = &mut js_row_z_scratch;
            let mut tmp_z = vec![0.0; d];
            sde.diffusion_vjp(t, &z, theta, &e_i, &mut tmp_z, &mut js_row_th);
            nfe_f += 1; // one VJP pair per row ~ one extra (f,g) eval pair
            nfe_g += 1;

            let s_row = &s_mat[i * cols..(i + 1) * cols];
            let out_row = &mut s_next[i * cols..(i + 1) * cols];
            for c in 0..cols {
                // drift: Σ_k (∂b_i/∂z_k) S_{k,c}
                let mut drift_term = 0.0;
                for kk in 0..d {
                    drift_term += jb_row_z[kk] * s_mat[kk * cols + c];
                }
                if c >= d {
                    drift_term += jb_row_th[c - d];
                }
                // diffusion (diagonal): ∂σ_i/∂z_i S_{i,c} (+ ∂σ_i/∂θ_c)
                let mut diff_term = dsig[i] * s_row[c];
                if c >= d {
                    diff_term += js_row_th[c - d];
                }
                out_row[c] = s_row[c] + drift_term * h + diff_term * dw[i];
            }
        }

        std::mem::swap(&mut z, &mut z_next);
        std::mem::swap(&mut s_mat, &mut s_next);
        wa.copy_from_slice(&wb);
    }

    // ∇L · S.
    let grad_l = loss_grad(&z);
    assert_eq!(grad_l.len(), d, "loss gradient has wrong dimension");
    let mut grad_z0 = vec![0.0; d];
    let mut grad_theta = vec![0.0; p];
    for i in 0..d {
        let gl = grad_l[i];
        for c in 0..d {
            grad_z0[c] += gl * s_mat[i * cols + c];
        }
        for c in 0..p {
            grad_theta[c] += gl * s_mat[i * cols + d + c];
        }
    }

    GradientOutput {
        z_terminal: z,
        grad_z0,
        grad_theta,
        z0_reconstructed: z0.to_vec(),
        forward_stats: SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: nfe_f,
            nfe_diffusion: nfe_g,
        },
        backward_stats: SolveStats::default(),
        // Live memory: sensitivity matrix + state (O(1) in L; O(d·D) in
        // problem size), plus the stored noise.
        noise_memory: s_mat.len() + d + bm.memory_footprint(),
        // The sensitivity matrix is this estimator's tape analogue.
        peak_tape_bytes: (s_mat.len() + d) * 8,
        recompute_nfe: 0,
        w_terminal: bm.sample(t1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::backprop::backprop_core;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};
    use crate::sde::ReplicatedSde;
    use crate::solvers::Method;

    fn pathwise_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n: usize,
        key: PrngKey,
    ) -> GradientOutput {
        pathwise_core(sde, theta, z0, 0.0, 1.0, n, key, NoiseMode::StoredPath, false, |z| {
            vec![1.0; z.len()]
        })
    }

    fn backprop_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n: usize,
        key: PrngKey,
        method: Method,
    ) -> GradientOutput {
        backprop_core(sde, theta, z0, 0.0, 1.0, n, key, method, |z| vec![1.0; z.len()])
    }

    #[test]
    fn pathwise_matches_backprop_euler_exactly() {
        // Both differentiate the same Euler–Maruyama discretization on the
        // same Brownian path: gradients must agree to machine-ish accuracy
        // (pathwise is forward-mode, backprop is reverse-mode of the SAME
        // computational graph).
        for (seed, dim) in [(21u64, 2usize), (22, 4)] {
            let sde = ReplicatedSde::new(Example1, dim);
            let key = PrngKey::from_seed(seed);
            let (theta, x0) = sample_experiment_setup(key, dim, 2);
            let n = 128;
            let fw = pathwise_sum(&sde, &theta, &x0, n, key);
            let bp =
                backprop_sum(&sde, &theta, &x0, n, key, Method::EulerMaruyama);
            for j in 0..theta.len() {
                assert!(
                    (fw.grad_theta[j] - bp.grad_theta[j]).abs() < 1e-10,
                    "θ[{j}]: fw {} vs bp {}",
                    fw.grad_theta[j],
                    bp.grad_theta[j]
                );
            }
            for i in 0..dim {
                assert!(
                    (fw.grad_z0[i] - bp.grad_z0[i]).abs() < 1e-10,
                    "z0[{i}]: fw {} vs bp {}",
                    fw.grad_z0[i],
                    bp.grad_z0[i]
                );
            }
        }
    }

    #[test]
    fn pathwise_nonlinear_problem() {
        let sde = ReplicatedSde::new(Example2, 3);
        let key = PrngKey::from_seed(23);
        let (theta, x0) = sample_experiment_setup(key, 3, 1);
        let n = 128;
        let fw = pathwise_sum(&sde, &theta, &x0, n, key);
        let bp =
            backprop_sum(&sde, &theta, &x0, n, key, Method::EulerMaruyama);
        for j in 0..theta.len() {
            assert!(
                (fw.grad_theta[j] - bp.grad_theta[j]).abs() < 1e-9,
                "θ[{j}]: fw {} vs bp {}",
                fw.grad_theta[j],
                bp.grad_theta[j]
            );
        }
    }

    #[test]
    fn nfe_scales_with_dimension() {
        // Table 1: time O(L·D). NFE per step grows with d.
        let key = PrngKey::from_seed(24);
        let mut nfes = Vec::new();
        for dim in [2usize, 8] {
            let sde = ReplicatedSde::new(Example1, dim);
            let (theta, x0) = sample_experiment_setup(key, dim, 2);
            let out = pathwise_sum(&sde, &theta, &x0, 32, key);
            nfes.push(out.forward_stats.nfe());
        }
        assert!(nfes[1] >= 3 * nfes[0], "NFE should grow ~linearly with d: {nfes:?}");
    }
}
