//! The stochastic adjoint sensitivity method (Algorithm 2).
//!
//! Forward pass: integrate the SDE from `z_0` to `z_T`, keeping only the
//! terminal state. Backward pass: integrate the augmented backward
//! Stratonovich system `(z, a_z, a_θ)` from `T` down to `0` against the
//! *same* Brownian sample path, starting from
//! `(z_T, ∂L/∂z_T, 0)`; on arrival, `a_z = ∂L/∂z_0` and `a_θ = ∂L/∂θ`.
//!
//! No intermediate state is stored — memory is O(1) in the number of steps
//! when noise comes from a [`VirtualBrownianTree`], or O(L) when it comes
//! from a stored [`BrownianPath`] (the paper's Table 1 rows 3 and 4).
//!
//! The backward integrator is a Stratonovich Heun scheme hand-unrolled over
//! the three blocks (see [`super::augmented`] for why that is strong order
//! 1.0 here and how the cross-channel θ-quadrature is handled exactly).

use super::augmented::AdjointOps;
use crate::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
use crate::prng::PrngKey;
use crate::sde::{ForwardFunc, SdeVjp};
use crate::solvers::{grid_core, uniform_grid, Method, SolveStats};

/// Where the Brownian sample path comes from.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NoiseMode {
    /// Store every queried value (O(L) memory; the paper's experiments).
    StoredPath,
    /// Virtual Brownian tree with the given bisection tolerance
    /// (O(1) memory, O(log 1/ε) per query; paper §4).
    VirtualTree { tol: f64 },
}

/// Configuration of an adjoint gradient computation.
#[derive(Clone, Copy, Debug)]
pub struct AdjointConfig {
    /// Scheme for the forward pass. Itô schemes integrate the native Itô
    /// form; Stratonovich schemes integrate the converted form. Default:
    /// Milstein (Itô) — strong order 1.0, as in the paper's Fig 5.
    pub forward_method: Method,
    /// Noise source shared by both passes.
    pub noise: NoiseMode,
    /// Drive the solve with the mirrored path `−W` (antithetic coupling,
    /// §8 / [`super::antithetic`]). `−W` is itself a standard Wiener
    /// process, so everything else is unchanged.
    pub mirror: bool,
}

impl Default for AdjointConfig {
    fn default() -> Self {
        AdjointConfig {
            forward_method: Method::MilsteinIto,
            noise: NoiseMode::StoredPath,
            mirror: false,
        }
    }
}

/// Result of an adjoint gradient computation.
#[derive(Clone, Debug)]
pub struct GradientOutput {
    /// Terminal state `z_T` of the forward solve.
    pub z_terminal: Vec<f64>,
    /// `∂L/∂z_0`.
    pub grad_z0: Vec<f64>,
    /// `∂L/∂θ`.
    pub grad_theta: Vec<f64>,
    /// The backward pass's reconstruction of `z_0` (diagnostic: should
    /// match the true `z_0` up to discretization error — Fig 2).
    pub z0_reconstructed: Vec<f64>,
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
    /// Live f64s held by the noise source at the end, plus — for the
    /// taped family — the peak live tape/checkpoint f64s (Table 1 memory).
    pub noise_memory: usize,
    /// Peak bytes of live tape + checkpoint storage. Zero for the
    /// adjoint family (no tape); for taped estimators this is the
    /// quantity the checkpoint schedules bound.
    pub peak_tape_bytes: usize,
    /// Drift + diffusion evaluations spent *re*-integrating segments
    /// during the backward pass (zero for the full tape and the adjoint
    /// family) — the recompute side of the memory/recompute tradeoff.
    pub recompute_nfe: u64,
    /// The realized Brownian value `W(t1)` of the path that drove the
    /// solve. Exposed because closed-form solutions/gradients of the §7.1
    /// problems are functions of `W_T`, and a stored [`BrownianPath`] is
    /// query-order dependent — re-creating it from the seed and asking for
    /// `W(T)` first would reveal a different path.
    pub w_terminal: Vec<f64>,
}

pub(crate) enum NoiseInner {
    Path(BrownianPath),
    Tree(VirtualBrownianTree),
}

/// Noise source assembled from a [`NoiseMode`]: a stored path or a virtual
/// tree, optionally mirrored (−W). Shared by the adjoint engines and the
/// problem API ([`crate::api::SdeProblem`]), whose solutions hand it back
/// as the replay handle.
pub(crate) struct Noise {
    inner: NoiseInner,
    /// Negate every sample (antithetic path −W).
    mirror: bool,
}

impl Noise {
    pub(crate) fn new(
        mode: NoiseMode,
        key: PrngKey,
        d: usize,
        t0: f64,
        t1: f64,
        mirror: bool,
    ) -> Noise {
        Self::with_cache(mode, key, d, t0, t1, mirror, crate::brownian::DEFAULT_NODE_CACHE)
    }

    /// [`Noise::new`] with an explicit virtual-tree ancestor-cache
    /// capacity (ignored for stored paths). `0` disables the cache; every
    /// capacity yields bit-identical samples — the knob trades bridge
    /// draws for O(capacity·d) memory. The problem API threads
    /// [`crate::api::SdeProblem::tree_cache`] through here.
    pub(crate) fn with_cache(
        mode: NoiseMode,
        key: PrngKey,
        d: usize,
        t0: f64,
        t1: f64,
        mirror: bool,
        tree_cache: usize,
    ) -> Noise {
        let inner = match mode {
            NoiseMode::StoredPath => NoiseInner::Path(BrownianPath::new(key, d, t0, t1)),
            NoiseMode::VirtualTree { tol } => NoiseInner::Tree(
                VirtualBrownianTree::with_cache_capacity(key, d, t0, t1, tol, tree_cache),
            ),
        };
        Noise { inner, mirror }
    }

    /// Bridge draws performed by the underlying virtual tree over its
    /// lifetime (0 for stored paths) — the node cache's before/after
    /// perf counter.
    pub(crate) fn bridge_calls(&self) -> u64 {
        match &self.inner {
            NoiseInner::Path(_) => 0,
            NoiseInner::Tree(t) => t.bridge_calls(),
        }
    }
}

impl BrownianMotion for Noise {
    fn dim(&self) -> usize {
        match &self.inner {
            NoiseInner::Path(p) => p.dim(),
            NoiseInner::Tree(t) => t.dim(),
        }
    }
    fn span(&self) -> (f64, f64) {
        match &self.inner {
            NoiseInner::Path(p) => p.span(),
            NoiseInner::Tree(t) => t.span(),
        }
    }
    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        match &mut self.inner {
            NoiseInner::Path(p) => p.sample_into(t, out),
            NoiseInner::Tree(tr) => tr.sample_into(t, out),
        }
        if self.mirror {
            for v in out.iter_mut() {
                *v = -*v;
            }
        }
    }
    fn memory_footprint(&self) -> usize {
        match &self.inner {
            NoiseInner::Path(p) => p.memory_footprint(),
            NoiseInner::Tree(t) => t.memory_footprint(),
        }
    }
}

/// Scratch buffers for the hand-unrolled backward Heun step.
struct BackwardScratch {
    b0: Vec<f64>,
    s0: Vec<f64>,
    fa0: Vec<f64>,
    ga0: Vec<f64>,
    fth0: Vec<f64>,
    gth0: Vec<f64>,
    b1: Vec<f64>,
    s1: Vec<f64>,
    fa1: Vec<f64>,
    ga1: Vec<f64>,
    fth1: Vec<f64>,
    gth1: Vec<f64>,
    zp: Vec<f64>,
    ap: Vec<f64>,
    dw: Vec<f64>,
    wa: Vec<f64>,
    wb: Vec<f64>,
}

impl BackwardScratch {
    fn new(d: usize, p: usize) -> Self {
        BackwardScratch {
            b0: vec![0.0; d],
            s0: vec![0.0; d],
            fa0: vec![0.0; d],
            ga0: vec![0.0; d],
            fth0: vec![0.0; p],
            gth0: vec![0.0; p],
            b1: vec![0.0; d],
            s1: vec![0.0; d],
            fa1: vec![0.0; d],
            ga1: vec![0.0; d],
            fth1: vec![0.0; p],
            gth1: vec![0.0; p],
            zp: vec![0.0; d],
            ap: vec![0.0; d],
            dw: vec![0.0; d],
            wa: vec![0.0; d],
            wb: vec![0.0; d],
        }
    }
}

/// One backward Heun step from `t` to `tn` (`tn < t`), updating `(z, a,
/// ath)` in place. `dw = W(tn) − W(t)` must already be in `sc.dw`.
fn backward_heun_step<S: SdeVjp + ?Sized>(
    ops: &mut AdjointOps<S>,
    t: f64,
    tn: f64,
    z: &mut [f64],
    a: &mut [f64],
    ath: &mut [f64],
    sc: &mut BackwardScratch,
) {
    let d = z.len();
    let p = ath.len();
    let h = tn - t; // signed (negative)

    // Evaluate at the left (later-time) point.
    ops.eval_drift(t, z, a, &mut sc.b0, &mut sc.fa0, &mut sc.fth0);
    ops.eval_diffusion(t, z, a, &sc.dw, &mut sc.s0, &mut sc.ga0, &mut sc.gth0);

    // Euler predictor for (z, a).
    for i in 0..d {
        sc.zp[i] = z[i] + sc.b0[i] * h + sc.s0[i] * sc.dw[i];
        sc.ap[i] = a[i] + sc.fa0[i] * h + sc.ga0[i] * sc.dw[i];
    }

    // Evaluate at the predicted (earlier-time) point.
    ops.eval_drift(tn, &sc.zp, &sc.ap, &mut sc.b1, &mut sc.fa1, &mut sc.fth1);
    ops.eval_diffusion(tn, &sc.zp, &sc.ap, &sc.dw, &mut sc.s1, &mut sc.ga1, &mut sc.gth1);

    // Trapezoid corrector.
    for i in 0..d {
        z[i] += 0.5 * (sc.b0[i] + sc.b1[i]) * h + 0.5 * (sc.s0[i] + sc.s1[i]) * sc.dw[i];
        a[i] += 0.5 * (sc.fa0[i] + sc.fa1[i]) * h + 0.5 * (sc.ga0[i] + sc.ga1[i]) * sc.dw[i];
    }
    for j in 0..p {
        // gth already carries the ΔW contraction (see AdjointOps).
        ath[j] += 0.5 * (sc.fth0[j] + sc.fth1[j]) * h + 0.5 * (sc.gth0[j] + sc.gth1[j]);
    }
}

/// Reusable backward-pass driver for callers that orchestrate their own
/// forward pass and loss structure (the latent-SDE trainer integrates
/// interval-by-interval with per-interval context parameters).
///
/// Holds the scratch buffers; `solve_interval` walks one descending grid,
/// updating `(z, a, ath)` in place against any Brownian source.
pub struct BackwardSolver<'a, S: SdeVjp + ?Sized> {
    ops: AdjointOps<'a, S>,
    sc: BackwardScratch,
}

impl<'a, S: SdeVjp + ?Sized> BackwardSolver<'a, S> {
    pub fn new(sde: &'a S, theta: &[f64]) -> Self {
        let d = sde.state_dim();
        let p = sde.param_dim();
        BackwardSolver { ops: AdjointOps::new(sde, theta), sc: BackwardScratch::new(d, p) }
    }

    /// Swap the parameter vector (e.g. the per-interval context tail)
    /// without reallocating scratch — the latent trainer calls this once
    /// per observation interval.
    pub fn set_theta(&mut self, theta: &[f64]) {
        self.ops.set_theta(theta);
    }

    /// Integrate the augmented backward system along `grid` (descending),
    /// updating `z` (path reconstruction), `a` (state adjoint) and `ath`
    /// (parameter adjoint, accumulated) in place.
    pub fn solve_interval<B: BrownianMotion>(
        &mut self,
        grid: &[f64],
        z: &mut [f64],
        a: &mut [f64],
        ath: &mut [f64],
        bm: &mut B,
        stats: &mut SolveStats,
    ) {
        assert!(grid.len() >= 2 && grid.windows(2).all(|w| w[1] < w[0]),
            "BackwardSolver: grid must be descending");
        let d = z.len();
        let nf0 = self.ops.nfe_drift;
        let ng0 = self.ops.nfe_diffusion;
        bm.sample_into(grid[0], &mut self.sc.wa);
        for k in 0..grid.len() - 1 {
            let (t, tn) = (grid[k], grid[k + 1]);
            bm.sample_into(tn, &mut self.sc.wb);
            for i in 0..d {
                self.sc.dw[i] = self.sc.wb[i] - self.sc.wa[i];
            }
            backward_heun_step(&mut self.ops, t, tn, z, a, ath, &mut self.sc);
            self.sc.wa.copy_from_slice(&self.sc.wb);
            stats.steps += 1;
        }
        stats.nfe_drift += self.ops.nfe_drift - nf0;
        stats.nfe_diffusion += self.ops.nfe_diffusion - ng0;
    }
}

/// Stochastic-adjoint engine (Algorithm 2) behind
/// [`crate::api::SdeProblem::sensitivity`]: gradient of an arbitrary
/// scalar loss `L(z_T)`, with `loss_grad` mapping the realized terminal
/// state to `∂L/∂z_T`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn adjoint_with_loss_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    cfg: &AdjointConfig,
    loss_grad: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    let d = sde.state_dim();
    let grid = uniform_grid(t0, t1, n_steps);
    let mut noise = Noise::new(cfg.noise, key, d, t0, t1, cfg.mirror);

    // Forward pass: terminal state only.
    let mut z_t = vec![0.0; d];
    let forward_stats = {
        let mut sys = ForwardFunc::for_method(sde, theta, cfg.forward_method);
        grid_core(&mut sys, cfg.forward_method, z0, &grid, &mut noise, &mut z_t)
    };

    let w_terminal = noise.sample(t1);

    // Backward pass over the reversed grid.
    let grad_l = loss_grad(&z_t);
    assert_eq!(grad_l.len(), d, "loss gradient has wrong dimension");
    let (z0_rec, grad_z0, grad_theta, backward_stats) =
        backward_pass(sde, theta, &z_t, &grad_l, &grid, &mut noise);

    GradientOutput {
        z_terminal: z_t,
        grad_z0,
        grad_theta,
        z0_reconstructed: z0_rec,
        forward_stats,
        backward_stats,
        noise_memory: noise.memory_footprint(),
        peak_tape_bytes: 0,
        recompute_nfe: 0,
        w_terminal,
    }
}

/// Multi-observation adjoint engine (App. 9.12's loop) behind
/// [`crate::api::SdeProblem::sensitivity_at`]: the loss is
/// `L = Σ_k ℓ_k(z_{t_k})` over observation times `obs_times` (ascending,
/// all in `(t0, t1]`, last one = t1). `loss_grads` receives the forward
/// states at all observation times (row-major `n_obs × d`) and returns all
/// `∂L/∂z_{t_k}` in the same layout. The backward pass injects each
/// gradient when it crosses the corresponding time.
#[allow(clippy::too_many_arguments)]
pub(crate) fn adjoint_multi_obs_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    obs_times: &[f64],
    steps_per_interval: usize,
    key: PrngKey,
    cfg: &AdjointConfig,
    loss_grads: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    let d = sde.state_dim();
    let n_obs = obs_times.len();
    assert!(n_obs > 0, "need at least one observation time");
    assert!(
        obs_times.windows(2).all(|w| w[1] > w[0]) && obs_times[0] > t0,
        "obs_times must be ascending and after t0"
    );
    let t1 = obs_times[n_obs - 1];
    let mut noise = Noise::new(cfg.noise, key, d, t0, t1, cfg.mirror);

    // Forward: integrate interval by interval, saving states at obs times.
    let mut z_obs = vec![0.0; n_obs * d];
    let mut z = z0.to_vec();
    let mut forward_stats = SolveStats::default();
    let mut t_lo = t0;
    for (k, &t_hi) in obs_times.iter().enumerate() {
        let grid = uniform_grid(t_lo, t_hi, steps_per_interval);
        let mut sys = ForwardFunc::for_method(sde, theta, cfg.forward_method);
        let mut z_next = vec![0.0; d];
        let st = grid_core(&mut sys, cfg.forward_method, &z, &grid, &mut noise, &mut z_next);
        accumulate_stats(&mut forward_stats, &st);
        z.copy_from_slice(&z_next);
        z_obs[k * d..(k + 1) * d].copy_from_slice(&z);
        t_lo = t_hi;
    }

    let w_terminal = noise.sample(t1);

    // Loss gradients at every observation.
    let grads = loss_grads(&z_obs);
    assert_eq!(grads.len(), n_obs * d, "loss_grads returned wrong layout");

    // Backward: start at the last obs with its gradient; add each earlier
    // obs gradient as the solve crosses it.
    let p = sde.param_dim();
    let mut ops = AdjointOps::new(sde, theta);
    let mut sc = BackwardScratch::new(d, p);
    let mut a = grads[(n_obs - 1) * d..].to_vec();
    let mut ath = vec![0.0; p];
    let mut zb = z_obs[(n_obs - 1) * d..].to_vec();
    let mut backward_stats = SolveStats::default();

    for k in (0..n_obs).rev() {
        let t_hi = obs_times[k];
        let t_lo = if k == 0 { t0 } else { obs_times[k - 1] };
        let grid = uniform_grid(t_hi, t_lo, steps_per_interval); // descending
        run_backward_grid(&mut ops, &grid, &mut zb, &mut a, &mut ath, &mut sc, &mut noise, &mut backward_stats);
        if k > 0 {
            for i in 0..d {
                a[i] += grads[(k - 1) * d + i];
            }
            // Re-anchor the path reconstruction at the stored state to
            // avoid compounding reconstruction drift across intervals.
            zb.copy_from_slice(&z_obs[(k - 1) * d..k * d]);
        }
    }

    GradientOutput {
        z_terminal: z_obs[(n_obs - 1) * d..].to_vec(),
        grad_z0: a,
        grad_theta: ath,
        z0_reconstructed: zb,
        forward_stats,
        backward_stats,
        noise_memory: noise.memory_footprint(),
        peak_tape_bytes: 0,
        recompute_nfe: 0,
        w_terminal,
    }
}

/// The backward pass over a descending grid; returns
/// `(z0_reconstructed, grad_z0, grad_theta, stats)`.
fn backward_pass<S: SdeVjp + ?Sized>(
    sde: &S,
    theta: &[f64],
    z_t: &[f64],
    grad_l: &[f64],
    forward_grid: &[f64],
    noise: &mut Noise,
) -> (Vec<f64>, Vec<f64>, Vec<f64>, SolveStats) {
    let d = sde.state_dim();
    let p = sde.param_dim();
    let mut ops = AdjointOps::new(sde, theta);
    let mut sc = BackwardScratch::new(d, p);

    let mut z = z_t.to_vec();
    let mut a = grad_l.to_vec();
    let mut ath = vec![0.0; p];

    let grid: Vec<f64> = forward_grid.iter().rev().copied().collect();
    let mut stats = SolveStats::default();
    run_backward_grid(&mut ops, &grid, &mut z, &mut a, &mut ath, &mut sc, noise, &mut stats);
    (z, a, ath, stats)
}

/// Walk a descending grid with the backward Heun stepper.
#[allow(clippy::too_many_arguments)]
fn run_backward_grid<S: SdeVjp + ?Sized>(
    ops: &mut AdjointOps<S>,
    grid: &[f64],
    z: &mut [f64],
    a: &mut [f64],
    ath: &mut [f64],
    sc: &mut BackwardScratch,
    noise: &mut Noise,
    stats: &mut SolveStats,
) {
    let d = z.len();
    let nf0 = ops.nfe_drift;
    let ng0 = ops.nfe_diffusion;
    noise.sample_into(grid[0], &mut sc.wa);
    for k in 0..grid.len() - 1 {
        let (t, tn) = (grid[k], grid[k + 1]);
        noise.sample_into(tn, &mut sc.wb);
        for i in 0..d {
            sc.dw[i] = sc.wb[i] - sc.wa[i];
        }
        backward_heun_step(ops, t, tn, z, a, ath, sc);
        sc.wa.copy_from_slice(&sc.wb);
        stats.steps += 1;
    }
    stats.nfe_drift += ops.nfe_drift - nf0;
    stats.nfe_diffusion += ops.nfe_diffusion - ng0;
}

fn accumulate_stats(total: &mut SolveStats, one: &SolveStats) {
    total.steps += one.steps;
    total.rejected += one.rejected;
    total.nfe_drift += one.nfe_drift;
    total.nfe_diffusion += one.nfe_diffusion;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{SdeProblem, SensAlg, StepControl};
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2, Example3};
    use crate::sde::{ReplicatedSde, ScalarSde};

    /// Shared harness: adjoint gradient vs analytic pathwise gradient for a
    /// replicated scalar problem, driven through the problem API. Returns
    /// (max_rel_err_x0, max_rel_err_th).
    fn adjoint_vs_analytic<P: ScalarSde + Copy>(
        problem: P,
        dim: usize,
        n_steps: usize,
        seed: u64,
        cfg: &AdjointConfig,
    ) -> (f64, f64) {
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .key(key)
            .noise(cfg.noise)
            .mirror(cfg.mirror)
            .sensitivity_sum(&SensAlg::StochasticAdjoint(*cfg), StepControl::Steps(n_steps))
            .expect("valid adjoint problem");

        // Ground truth from the closed form at the realized W_T.
        let w_t = out.w_terminal.clone();
        let mut g_x0 = vec![0.0; dim];
        let mut g_th = vec![0.0; theta.len()];
        sde.analytic_loss_gradients(1.0, &x0, &theta, &w_t, &mut g_x0, &mut g_th);

        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-3);
        let e_x0 = (0..dim).map(|i| rel(out.dz0[i], g_x0[i])).fold(0.0, f64::max);
        let e_th = (0..theta.len()).map(|j| rel(out.dtheta[j], g_th[j])).fold(0.0, f64::max);
        (e_x0, e_th)
    }

    #[test]
    fn example1_gradients_match_analytic() {
        let cfg = AdjointConfig::default();
        let (ex0, eth) = adjoint_vs_analytic(Example1, 4, 4000, 42, &cfg);
        assert!(ex0 < 0.02, "x0 gradient rel err {ex0}");
        assert!(eth < 0.02, "theta gradient rel err {eth}");
    }

    #[test]
    fn example2_gradients_match_analytic() {
        let cfg = AdjointConfig::default();
        let (ex0, eth) = adjoint_vs_analytic(Example2, 4, 4000, 43, &cfg);
        assert!(ex0 < 0.02, "x0 gradient rel err {ex0}");
        assert!(eth < 0.02, "theta gradient rel err {eth}");
    }

    #[test]
    fn example3_gradients_match_analytic() {
        let cfg = AdjointConfig::default();
        let (ex0, eth) = adjoint_vs_analytic(Example3, 4, 4000, 44, &cfg);
        assert!(ex0 < 0.02, "x0 gradient rel err {ex0}");
        assert!(eth < 0.02, "theta gradient rel err {eth}");
    }

    #[test]
    fn virtual_tree_matches_stored_path_gradients() {
        // With a tight tree tolerance both noise sources realize (almost)
        // the same sample path law; gradients from the same seed won't be
        // equal (different path realizations), but each must individually
        // converge to its own analytic value — covered above. Here we
        // check the tree path gives finite, consistent results and O(1)
        // memory.
        let cfg_tree = AdjointConfig {
            noise: NoiseMode::VirtualTree { tol: 1e-8 },
            ..Default::default()
        };
        let (ex0, eth) = adjoint_vs_analytic(Example1, 3, 3000, 45, &cfg_tree);
        assert!(ex0 < 0.03, "x0 gradient rel err {ex0}");
        assert!(eth < 0.03, "theta gradient rel err {eth}");
    }

    #[test]
    fn tree_memory_constant_path_memory_linear() {
        let sde = ReplicatedSde::new(Example1, 2);
        let key = PrngKey::from_seed(9);
        let (theta, x0) = sample_experiment_setup(key, 2, 2);
        let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);
        let out_tree = prob
            .clone()
            .noise(NoiseMode::VirtualTree { tol: 1e-7 })
            .sensitivity_sum(
                &SensAlg::StochasticAdjoint(AdjointConfig::default()),
                StepControl::Steps(512),
            )
            .unwrap();
        let out_path = prob
            .sensitivity_sum(
                &SensAlg::StochasticAdjoint(AdjointConfig::default()),
                StepControl::Steps(512),
            )
            .unwrap();
        // Tree memory is bounded by the ancestor cache (base + capacity
        // nodes of O(d)), constant in the step count; the stored path
        // scales with the 512-step grid.
        let tree_bound = 4 * 2 + 2 + crate::brownian::DEFAULT_NODE_CACHE * (2 + 4);
        assert!(
            out_tree.stats.noise_memory <= tree_bound,
            "tree memory {} > bound {tree_bound}",
            out_tree.stats.noise_memory
        );
        assert!(out_path.stats.noise_memory > 512, "path memory {}", out_path.stats.noise_memory);
        assert!(out_tree.stats.noise_memory < out_path.stats.noise_memory / 2);
    }

    #[test]
    fn backward_pass_reconstructs_initial_state() {
        // The z-block of the backward solve retraces the forward path
        // (Theorem 2.1b); with Stratonovich stepping both ways the
        // reconstruction error is small (this is Fig 2's "right" curve).
        let sde = ReplicatedSde::new(Example1, 3);
        let key = PrngKey::from_seed(50);
        let (theta, x0) = sample_experiment_setup(key, 3, 2);
        let cfg = AdjointConfig { forward_method: Method::Heun, ..Default::default() };
        let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .key(key)
            .sensitivity_sum(&SensAlg::StochasticAdjoint(cfg), StepControl::Steps(2000))
            .unwrap();
        for i in 0..3 {
            assert!(
                (out.z0_reconstructed[i] - x0[i]).abs() < 0.01,
                "dim {i}: reconstructed {} vs {}",
                out.z0_reconstructed[i],
                x0[i]
            );
        }
    }

    #[test]
    fn gradient_error_decreases_with_step_size() {
        // Fig 5(a): error vs fixed step size, averaged over Brownian paths
        // (the figure repeats with 64 sample paths; 16 suffices here).
        let mut errs = Vec::new();
        for &n in &[64usize, 512, 4096] {
            let mut acc = 0.0;
            for rep in 0..16 {
                let (_, eth) =
                    adjoint_vs_analytic(Example2, 2, n, 77 + rep, &AdjointConfig::default());
                acc += eth;
            }
            errs.push(acc / 16.0);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "errors not decreasing: {errs:?}");
    }

    #[test]
    fn multi_obs_matches_sum_of_single_obs() {
        // L = Σ z(t_a) + Σ z(t_b): θ-gradient must equal the sum of two
        // single-terminal-time adjoint computations on the same path.
        let sde = ReplicatedSde::new(Example3, 2);
        let key = PrngKey::from_seed(60);
        let (theta, x0) = sample_experiment_setup(key, 2, 2);
        let cfg = AdjointConfig::default();
        let steps = 1500;
        let prob = SdeProblem::new(&sde, &x0, (0.0, 1.0)).params(&theta).key(key);

        let multi = prob
            .sensitivity_at(&[0.5, 1.0], steps, &cfg, |z_obs| vec![1.0; z_obs.len()])
            .unwrap();

        // Single obs at 1.0 on the same noise: grid differs (one interval
        // of 2*steps vs two of steps). Use matching per-interval grids so
        // the Brownian queries align: emulate by multi_obs with zero grad
        // at 0.5.
        let only_end = prob
            .sensitivity_at(&[0.5, 1.0], steps, &cfg, |z_obs| {
                let mut g = vec![0.0; z_obs.len()];
                for v in g.iter_mut().skip(z_obs.len() / 2) {
                    *v = 1.0;
                }
                g
            })
            .unwrap();
        let only_mid = prob
            .sensitivity_at(&[0.5, 1.0], steps, &cfg, |z_obs| {
                let mut g = vec![0.0; z_obs.len()];
                for v in g.iter_mut().take(z_obs.len() / 2) {
                    *v = 1.0;
                }
                g
            })
            .unwrap();
        for j in 0..theta.len() {
            let sum = only_end.dtheta[j] + only_mid.dtheta[j];
            assert!(
                (multi.dtheta[j] - sum).abs() < 1e-9,
                "θ[{j}]: multi {} vs sum {}",
                multi.dtheta[j],
                sum
            );
        }
        for i in 0..2 {
            let sum = only_end.dz0[i] + only_mid.dz0[i];
            assert!((multi.dz0[i] - sum).abs() < 1e-9, "z0[{i}]");
        }
    }

    #[test]
    fn multi_obs_gradient_matches_analytic() {
        // Terminal-only loss expressed through the multi-obs API must match
        // the closed form too.
        let dim = 3;
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(61);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
            .params(&theta)
            .key(key)
            .sensitivity_at(
                &[0.25, 0.5, 0.75, 1.0],
                800,
                &AdjointConfig::default(),
                |z_obs| {
                    let mut g = vec![0.0; z_obs.len()];
                    let n = z_obs.len();
                    for v in g.iter_mut().skip(n - dim) {
                        *v = 1.0;
                    }
                    g
                },
            )
            .unwrap();
        let w_t = out.w_terminal.clone();
        let mut g_x0 = vec![0.0; dim];
        let mut g_th = vec![0.0; theta.len()];
        sde.analytic_loss_gradients(1.0, &x0, &theta, &w_t, &mut g_x0, &mut g_th);
        for j in 0..theta.len() {
            let rel = (out.dtheta[j] - g_th[j]).abs() / g_th[j].abs().max(1e-3);
            assert!(rel < 0.02, "θ[{j}] rel err {rel}");
        }
    }
}
