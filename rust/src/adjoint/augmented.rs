//! Evaluation bundle for the augmented backward system of Algorithm 2.
//!
//! Augmented state: `(z, a_z, a_θ)` with the backward Stratonovich dynamics
//! (Eq. 7 extended to parameters per §3.3 / App. 9.4), written in the
//! *signed-step* convention (`dt = t_next − t < 0`,
//! `dW = W(t_next) − W(t)`):
//!
//! ```text
//! dz   =  b̃ dt           + σ ∘ dW              (retrace the path)
//! da_z = −a_zᵀ∂b̃/∂z dt   − a_zᵀ∂σ/∂z ∘ dW     (state adjoint)
//! da_θ = −a_zᵀ∂b̃/∂θ dt   − a_zᵀ∂σ/∂θ ∘ dW     (parameter adjoint)
//! ```
//!
//! with `b̃` the Stratonovich-form drift. In this convention the sign
//! bookkeeping of the paper's pseudocode (negate coefficients, negate
//! noise, flip the clock) cancels into plain signed steps — see
//! `adjoint::stochastic` for the integration loop.
//!
//! The `a_θ` block is a pure quadrature (nothing feeds back on it), but its
//! noise term `a_zᵀ∂σ/∂θ ∘ dW` contracts *across* noise channels:
//! `(a_zᵀ∂σ/∂θ)_j · dW = Σ_i a_i (∂σ_i/∂θ_j) dW_i`. [`AdjointOps`]
//! therefore exposes the θ-diffusion VJP pre-weighted by the channel
//! increments (`a ⊙ ΔW` fed through the accumulating VJP), which keeps the
//! estimator exact even when a single parameter drives several channels
//! (e.g. a shared diffusion scale).
//!
//! Per App. 9.4 the augmented system has commutative noise whenever the
//! original SDE has diagonal noise, so the Heun (trapezoid) scheme used by
//! the driver retains strong order 1.0 — it reproduces every term of the
//! commutative Milstein update without second derivatives.

use crate::sde::{Calculus, SdeVjp};

/// One evaluation point of the augmented backward dynamics.
///
/// Buffers are owned by [`AdjointOps`] and reused; each `eval_*` call
/// overwrites the corresponding slice.
pub struct AdjointOps<'a, S: SdeVjp + ?Sized> {
    sde: &'a S,
    theta: Vec<f64>,
    d: usize,
    p: usize,
    neg_a: Vec<f64>,
    weighted_a: Vec<f64>,
    scratch_z: Vec<f64>,
    scratch_p: Vec<f64>,
    /// σ/σ′ staging for the Stratonovich drift conversion (len 2d).
    strat: Vec<f64>,
    /// Combined (drift+VJP) evaluations — NFE accounting in the paper's
    /// "one drift + one diffusion evaluation" units.
    pub nfe_drift: u64,
    pub nfe_diffusion: u64,
}

impl<'a, S: SdeVjp + ?Sized> AdjointOps<'a, S> {
    pub fn new(sde: &'a S, theta: &[f64]) -> Self {
        let d = sde.state_dim();
        let p = sde.param_dim();
        assert_eq!(theta.len(), p, "AdjointOps: theta length mismatch");
        AdjointOps {
            sde,
            theta: theta.to_vec(),
            d,
            p,
            neg_a: vec![0.0; d],
            weighted_a: vec![0.0; d],
            scratch_z: vec![0.0; d],
            scratch_p: vec![0.0; p],
            strat: vec![0.0; 2 * d],
            nfe_drift: 0,
            nfe_diffusion: 0,
        }
    }

    /// Replace the parameter vector in place (e.g. a new per-interval
    /// context block) without reallocating any scratch.
    pub fn set_theta(&mut self, theta: &[f64]) {
        assert_eq!(theta.len(), self.p, "set_theta: length mismatch");
        self.theta.copy_from_slice(theta);
    }

    pub fn state_dim(&self) -> usize {
        self.d
    }

    pub fn par_dim(&self) -> usize {
        self.p
    }

    /// The original SDE must be treated in Stratonovich form on the
    /// backward pass; this reports what conversion (if any) happens.
    pub fn native_calculus(&self) -> Calculus {
        self.sde.calculus()
    }

    /// Drift-side evaluation at `(t, z, a)`:
    /// * `b_out ← b̃(z,t)` (Stratonovich drift),
    /// * `fa_out ← −aᵀ∂b̃/∂z`,
    /// * `fth_out ← −aᵀ∂b̃/∂θ` (overwritten, not accumulated).
    pub fn eval_drift(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        b_out: &mut [f64],
        fa_out: &mut [f64],
        fth_out: &mut [f64],
    ) {
        self.nfe_drift += 1;
        self.sde.drift_stratonovich(t, z, &self.theta, b_out, &mut self.strat);
        for i in 0..self.d {
            self.neg_a[i] = -a[i];
        }
        fa_out.fill(0.0);
        fth_out.fill(0.0);
        // scratch_z is free here (only eval_diffusion uses it), so it
        // doubles as the VJP's sign-flip staging buffer.
        self.sde.drift_vjp_stratonovich(
            t,
            z,
            &self.theta,
            &self.neg_a,
            fa_out,
            fth_out,
            &mut self.scratch_z,
        );
    }

    /// Diffusion-side evaluation at `(t, z, a)` with channel increments
    /// `dw` (length d):
    /// * `s_out ← σ(z,t)`,
    /// * `ga_out ← −aᵀ∂σ/∂z` (componentwise `−a_i ∂σ_i/∂z_i`),
    /// * `gth_out ← −Σ_i a_i dw_i ∂σ_i/∂θ` (ΔW already folded in).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_diffusion(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        dw: &[f64],
        s_out: &mut [f64],
        ga_out: &mut [f64],
        gth_out: &mut [f64],
    ) {
        self.nfe_diffusion += 1;
        self.sde.diffusion(t, z, &self.theta, s_out);
        for i in 0..self.d {
            self.neg_a[i] = -a[i];
            self.weighted_a[i] = -a[i] * dw[i];
        }
        ga_out.fill(0.0);
        gth_out.fill(0.0);
        // z-VJP with −a (unweighted: the driver multiplies by ΔW itself);
        // θ-VJP with −a⊙ΔW (pre-weighted: cross-channel contraction).
        // θ/z side-outputs of each call land in scratch and are discarded.
        self.scratch_p.fill(0.0);
        self.sde
            .diffusion_vjp(t, z, &self.theta, &self.neg_a, ga_out, &mut self.scratch_p);
        self.scratch_z.fill(0.0);
        self.sde
            .diffusion_vjp(t, z, &self.theta, &self.weighted_a, &mut self.scratch_z, gth_out);
    }
}
