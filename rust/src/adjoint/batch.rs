//! Batched stochastic adjoint: B augmented backward solves per step.
//!
//! The scalar engine ([`super::stochastic`]) integrates one augmented
//! state `(z, a_z, a_θ)` backward per path. Here all B paths advance
//! together: the augmented batch state lives in **one contiguous
//! `[B×(2d+p+1)]` buffer**, partitioned structure-of-arrays so each block
//! is itself a dense row-major matrix the batched VJP kernels can sweep:
//!
//! ```text
//! ┌──────────┬──────────┬──────────┬─────┐
//! │ z  [B×d] │ a_z [B×d]│ a_θ [B×p]│ L[B]│   one allocation
//! └──────────┴──────────┴──────────┴─────┘
//! ```
//!
//! `L` is the per-path terminal loss `L_b = Σ_i z_T^{(i,b)}` — constant
//! through the backward pass (the loss of a realized path does not change
//! while we differentiate it) and returned per path, so a batched
//! gradient call also yields the Monte Carlo loss estimate for free.
//!
//! Every per-path float follows the exact evaluation order of the scalar
//! backward Heun step ([`super::stochastic`]'s `backward_heun_step`), so
//! a batch of B adjoint solves equals B scalar adjoint solves bit for bit
//! (pinned by `tests/batch_engine.rs`). Noise comes from one
//! [`BatchBrownian`] whose per-path sources carry the problem keys (and
//! per-path mirror flags), shared between the forward and backward sweeps
//! exactly as in the scalar engine.

use super::stochastic::Noise;
use crate::brownian::{BatchBrownian, BrownianMotion};
use crate::runtime::ExecConfig;
use crate::sde::{BatchSdeVjp, KernelTier};
use crate::solvers::{batch_grid_core, uniform_grid, BatchForwardFunc, Method, SolveStats};

/// Evaluation interface of the batched augmented backward dynamics: what
/// the batched backward Heun stepper needs per stage, abstracted over
/// *how* the per-path coefficients/VJPs are produced.
///
/// Two implementors: [`BatchAdjointOps`] (one shared θ across the batch —
/// the Monte Carlo replicate engine behind
/// [`crate::api::sensitivity_batch`]) and the latent trainer's
/// per-path-context ops (`latent::posterior`), where a small per-path
/// parameter tail — the encoder context of each path's sequence — varies
/// across the batch while the model weights are shared.
///
/// Contract (mirrors the scalar [`super::augmented::AdjointOps`], which
/// defines the float-for-float reference): `eval_drift` writes the
/// Stratonovich drift `b̃(z_b,t)` plus `−a_bᵀ∂b̃/∂z` and `−a_bᵀ∂b̃/∂θ`
/// (overwritten); `eval_diffusion` writes `σ(z_b,t)`, `−a_bᵀ∂σ/∂z`, and
/// the ΔW-contracted `−Σ_i a_{b,i} dw_{b,i} ∂σ_i/∂θ`.
pub(crate) trait BatchAugmentedOps {
    fn state_dim(&self) -> usize;
    fn param_dim(&self) -> usize;
    fn batch(&self) -> usize;
    fn eval_drift(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        b_out: &mut [f64],
        fa_out: &mut [f64],
        fth_out: &mut [f64],
    );
    #[allow(clippy::too_many_arguments)]
    fn eval_diffusion(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        dw: &[f64],
        s_out: &mut [f64],
        ga_out: &mut [f64],
        gth_out: &mut [f64],
    );
    fn nfe(&self) -> (u64, u64);
}

/// Evaluation bundle for the batched augmented backward dynamics —
/// [`super::augmented::AdjointOps`] lifted to `[B×d]`/`[B×p]` buffers.
pub struct BatchAdjointOps<'a, S: BatchSdeVjp + ?Sized> {
    sde: &'a S,
    theta: Vec<f64>,
    d: usize,
    batch: usize,
    tier: KernelTier,
    neg_a: Vec<f64>,
    weighted_a: Vec<f64>,
    scratch_z: Vec<f64>,
    scratch_p: Vec<f64>,
    /// Row-level σ/σ′ staging for the Stratonovich drift (len 2d).
    strat: Vec<f64>,
    /// Row-level sign-flip staging for the Stratonovich drift VJP (len d).
    vjp_scratch: Vec<f64>,
    /// Per-path-unit NFE accounting (one batched call = one evaluation).
    pub nfe_drift: u64,
    pub nfe_diffusion: u64,
}

impl<'a, S: BatchSdeVjp + ?Sized> BatchAdjointOps<'a, S> {
    /// `exec.tier == Fast` routes the coefficient evaluations and VJP
    /// sweeps through the `*_fast` kernels of [`BatchSdeVjp`]; the other
    /// [`ExecConfig`] knobs do not apply at this level (threads and tree
    /// caching belong to the callers).
    pub fn new(sde: &'a S, theta: &[f64], batch: usize, exec: ExecConfig) -> Self {
        let tier = exec.tier;
        let d = sde.state_dim();
        let p = sde.param_dim();
        assert_eq!(theta.len(), p, "BatchAdjointOps: theta length mismatch");
        assert!(batch > 0, "BatchAdjointOps: empty batch");
        BatchAdjointOps {
            sde,
            theta: theta.to_vec(),
            d,
            batch,
            tier,
            neg_a: vec![0.0; batch * d],
            weighted_a: vec![0.0; batch * d],
            scratch_z: vec![0.0; batch * d],
            scratch_p: vec![0.0; batch * p],
            strat: vec![0.0; 2 * d],
            vjp_scratch: vec![0.0; d],
            nfe_drift: 0,
            nfe_diffusion: 0,
        }
    }

    /// Deprecated spelling of [`BatchAdjointOps::new`] from before
    /// [`ExecConfig`] unified the execution knobs; bit-identical to the
    /// base constructor (pinned in `tests/exec_config.rs`).
    #[deprecated(
        since = "0.2.0",
        note = "use `BatchAdjointOps::new(sde, theta, batch, ExecConfig::new().tier(tier))`"
    )]
    pub fn new_tier(sde: &'a S, theta: &[f64], batch: usize, tier: KernelTier) -> Self {
        Self::new(sde, theta, batch, ExecConfig::new().tier(tier))
    }

    /// Drift-side evaluation at `(t, z, a)` for all paths (see the scalar
    /// [`super::augmented::AdjointOps::eval_drift`]):
    /// `b_out[b] ← b̃(z_b,t)`, `fa_out[b] ← −a_bᵀ∂b̃/∂z`,
    /// `fth_out[b] ← −a_bᵀ∂b̃/∂θ` (overwritten).
    pub fn eval_drift(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        b_out: &mut [f64],
        fa_out: &mut [f64],
        fth_out: &mut [f64],
    ) {
        self.nfe_drift += 1;
        match self.tier {
            KernelTier::Exact => {
                self.sde.drift_stratonovich_batch(t, z, &self.theta, b_out, &mut self.strat)
            }
            KernelTier::Fast => {
                self.sde.drift_stratonovich_batch_fast(t, z, &self.theta, b_out, &mut self.strat)
            }
        }
        for (n, v) in self.neg_a.iter_mut().zip(a) {
            *n = -v;
        }
        fa_out.fill(0.0);
        fth_out.fill(0.0);
        match self.tier {
            KernelTier::Exact => self.sde.drift_vjp_stratonovich_batch(
                t,
                z,
                &self.theta,
                &self.neg_a,
                fa_out,
                fth_out,
                &mut self.vjp_scratch,
            ),
            KernelTier::Fast => self.sde.drift_vjp_stratonovich_batch_fast(
                t,
                z,
                &self.theta,
                &self.neg_a,
                fa_out,
                fth_out,
                &mut self.vjp_scratch,
            ),
        }
    }

    /// Diffusion-side evaluation at `(t, z, a)` with per-path channel
    /// increments `dw` (`[B×d]`): `s_out[b] ← σ(z_b,t)`,
    /// `ga_out[b] ← −a_bᵀ∂σ/∂z`, `gth_out[b] ← −Σ_i a_{b,i} dw_{b,i}
    /// ∂σ_i/∂θ` (ΔW folded in, as in the scalar engine).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_diffusion(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        dw: &[f64],
        s_out: &mut [f64],
        ga_out: &mut [f64],
        gth_out: &mut [f64],
    ) {
        self.nfe_diffusion += 1;
        match self.tier {
            KernelTier::Exact => self.sde.diffusion_batch(t, z, &self.theta, s_out),
            KernelTier::Fast => self.sde.diffusion_batch_fast(t, z, &self.theta, s_out),
        }
        for i in 0..self.batch * self.d {
            self.neg_a[i] = -a[i];
            self.weighted_a[i] = -a[i] * dw[i];
        }
        ga_out.fill(0.0);
        gth_out.fill(0.0);
        // z-VJP with −a (unweighted); θ-VJP with −a⊙ΔW. Side outputs of
        // each call land in scratch and are discarded — same two-call
        // structure as the scalar AdjointOps.
        self.scratch_p.fill(0.0);
        self.scratch_z.fill(0.0);
        match self.tier {
            KernelTier::Exact => {
                self.sde.diffusion_vjp_batch(
                    t,
                    z,
                    &self.theta,
                    &self.neg_a,
                    ga_out,
                    &mut self.scratch_p,
                );
                self.sde.diffusion_vjp_batch(
                    t,
                    z,
                    &self.theta,
                    &self.weighted_a,
                    &mut self.scratch_z,
                    gth_out,
                );
            }
            KernelTier::Fast => {
                self.sde.diffusion_vjp_batch_fast(
                    t,
                    z,
                    &self.theta,
                    &self.neg_a,
                    ga_out,
                    &mut self.scratch_p,
                );
                self.sde.diffusion_vjp_batch_fast(
                    t,
                    z,
                    &self.theta,
                    &self.weighted_a,
                    &mut self.scratch_z,
                    gth_out,
                );
            }
        }
    }
}

impl<'a, S: BatchSdeVjp + ?Sized> BatchAugmentedOps for BatchAdjointOps<'a, S> {
    fn state_dim(&self) -> usize {
        self.d
    }
    fn param_dim(&self) -> usize {
        self.sde.param_dim()
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn eval_drift(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        b_out: &mut [f64],
        fa_out: &mut [f64],
        fth_out: &mut [f64],
    ) {
        BatchAdjointOps::eval_drift(self, t, z, a, b_out, fa_out, fth_out);
    }
    fn eval_diffusion(
        &mut self,
        t: f64,
        z: &[f64],
        a: &[f64],
        dw: &[f64],
        s_out: &mut [f64],
        ga_out: &mut [f64],
        gth_out: &mut [f64],
    ) {
        BatchAdjointOps::eval_diffusion(self, t, z, a, dw, s_out, ga_out, gth_out);
    }
    fn nfe(&self) -> (u64, u64) {
        (self.nfe_drift, self.nfe_diffusion)
    }
}

/// Stage buffers of the batched backward Heun step (`[B×d]`/`[B×p]`).
struct BatchBackwardScratch {
    b0: Vec<f64>,
    s0: Vec<f64>,
    fa0: Vec<f64>,
    ga0: Vec<f64>,
    fth0: Vec<f64>,
    gth0: Vec<f64>,
    b1: Vec<f64>,
    s1: Vec<f64>,
    fa1: Vec<f64>,
    ga1: Vec<f64>,
    fth1: Vec<f64>,
    gth1: Vec<f64>,
    zp: Vec<f64>,
    ap: Vec<f64>,
    dw: Vec<f64>,
}

impl BatchBackwardScratch {
    fn new(d: usize, p: usize, batch: usize) -> Self {
        let n = batch * d;
        let np = batch * p;
        BatchBackwardScratch {
            b0: vec![0.0; n],
            s0: vec![0.0; n],
            fa0: vec![0.0; n],
            ga0: vec![0.0; n],
            fth0: vec![0.0; np],
            gth0: vec![0.0; np],
            b1: vec![0.0; n],
            s1: vec![0.0; n],
            fa1: vec![0.0; n],
            ga1: vec![0.0; n],
            fth1: vec![0.0; np],
            gth1: vec![0.0; np],
            zp: vec![0.0; n],
            ap: vec![0.0; n],
            dw: vec![0.0; n],
        }
    }
}

/// One batched backward Heun step from `t` to `tn` (`tn < t`), updating
/// the `(z, a, ath)` blocks in place. `sc.dw` must hold
/// `W_b(tn) − W_b(t)` for every path.
fn batch_backward_heun_step<O: BatchAugmentedOps + ?Sized>(
    ops: &mut O,
    t: f64,
    tn: f64,
    z: &mut [f64],
    a: &mut [f64],
    ath: &mut [f64],
    sc: &mut BatchBackwardScratch,
) {
    let n = z.len();
    let np = ath.len();
    let h = tn - t; // signed (negative)

    ops.eval_drift(t, z, a, &mut sc.b0, &mut sc.fa0, &mut sc.fth0);
    ops.eval_diffusion(t, z, a, &sc.dw, &mut sc.s0, &mut sc.ga0, &mut sc.gth0);

    for i in 0..n {
        sc.zp[i] = z[i] + sc.b0[i] * h + sc.s0[i] * sc.dw[i];
        sc.ap[i] = a[i] + sc.fa0[i] * h + sc.ga0[i] * sc.dw[i];
    }

    ops.eval_drift(tn, &sc.zp, &sc.ap, &mut sc.b1, &mut sc.fa1, &mut sc.fth1);
    ops.eval_diffusion(tn, &sc.zp, &sc.ap, &sc.dw, &mut sc.s1, &mut sc.ga1, &mut sc.gth1);

    for i in 0..n {
        z[i] += 0.5 * (sc.b0[i] + sc.b1[i]) * h + 0.5 * (sc.s0[i] + sc.s1[i]) * sc.dw[i];
        a[i] += 0.5 * (sc.fa0[i] + sc.fa1[i]) * h + 0.5 * (sc.ga0[i] + sc.ga1[i]) * sc.dw[i];
    }
    for j in 0..np {
        // gth already carries the ΔW contraction (see BatchAdjointOps).
        ath[j] += 0.5 * (sc.fth0[j] + sc.fth1[j]) * h + 0.5 * (sc.gth0[j] + sc.gth1[j]);
    }
}

/// Reusable batched backward-pass driver — the batch analogue of
/// [`super::stochastic::BackwardSolver`], for callers that orchestrate
/// their own forward pass and loss structure (the latent-SDE trainer
/// integrates interval-by-interval with per-interval, per-path context
/// parameters).
///
/// Holds the stage scratch; `solve_interval` walks one descending grid,
/// updating the `[B×d]`/`[B×p]` blocks `(z, a, ath)` in place against one
/// [`BatchBrownian`] (whose per-path sources replay the forward noise).
/// Per-path floats follow the scalar `BackwardSolver` exactly, so a batch
/// of B interval solves equals B scalar interval solves bit for bit.
pub(crate) struct BatchBackwardSolver<O: BatchAugmentedOps> {
    ops: O,
    sc: BatchBackwardScratch,
}

impl<O: BatchAugmentedOps> BatchBackwardSolver<O> {
    pub(crate) fn new(ops: O) -> Self {
        let sc = BatchBackwardScratch::new(ops.state_dim(), ops.param_dim(), ops.batch());
        BatchBackwardSolver { ops, sc }
    }

    /// Mutable access to the ops (e.g. to swap the per-interval context
    /// rows) without reallocating scratch.
    pub(crate) fn ops_mut(&mut self) -> &mut O {
        &mut self.ops
    }

    /// Integrate the augmented backward system along `grid` (descending),
    /// updating `z` (path reconstruction), `a` (state adjoint) and `ath`
    /// (parameter adjoint, accumulated) in place. Statistics accumulate
    /// in per-path units (one batched stage = one evaluation per path).
    pub(crate) fn solve_interval<B: BrownianMotion>(
        &mut self,
        grid: &[f64],
        z: &mut [f64],
        a: &mut [f64],
        ath: &mut [f64],
        bm: &mut BatchBrownian<B>,
        stats: &mut SolveStats,
    ) {
        assert!(
            grid.len() >= 2 && grid.windows(2).all(|w| w[1] < w[0]),
            "BatchBackwardSolver: grid must be descending"
        );
        let _span = crate::obs::span!("adjoint.backward");
        let (nf0, ng0) = self.ops.nfe();
        bm.begin_sweep(grid[0]);
        for k in 0..grid.len() - 1 {
            let (t, tn) = (grid[k], grid[k + 1]);
            bm.sweep_increments(tn, &mut self.sc.dw);
            batch_backward_heun_step(&mut self.ops, t, tn, z, a, ath, &mut self.sc);
            stats.steps += 1;
        }
        let (nf1, ng1) = self.ops.nfe();
        stats.nfe_drift += nf1 - nf0;
        stats.nfe_diffusion += ng1 - ng0;
    }
}

/// Result of a batched adjoint computation: per-path rows of everything
/// the scalar [`super::stochastic::GradientOutput`] reports, plus the
/// per-path loss carried in the augmented buffer's final block.
pub(crate) struct BatchGradientOutput {
    /// Terminal states `[B×d]`.
    pub z_terminal: Vec<f64>,
    /// `∂L/∂z_0` per path, `[B×d]`.
    pub grad_z0: Vec<f64>,
    /// `∂L/∂θ` per path, `[B×p]`.
    pub grad_theta: Vec<f64>,
    /// Backward path reconstructions `[B×d]`.
    pub z0_reconstructed: Vec<f64>,
    /// Realized `W_b(t1)` per path, `[B×d]`.
    pub w_terminal: Vec<f64>,
    /// Per-path terminal loss `L_b = Σ_i z_T^{(i,b)}` (length B).
    pub loss: Vec<f64>,
    /// Per-path solve statistics (uniform across the batch).
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
}

/// Batched Algorithm 2 for the summed loss `L = Σ_i z_T^{(i)}`: forward
/// batched solve keeping only terminal states, then one batched augmented
/// backward sweep against the same per-path noise. `z0` is `[B×d]`
/// (per-path initial states); `noise` carries one source per path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn batch_adjoint_sum_core<S: BatchSdeVjp + ?Sized>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    noise: &mut BatchBrownian<Noise>,
    forward_method: Method,
    tier: KernelTier,
) -> BatchGradientOutput {
    let d = sde.state_dim();
    let p = sde.param_dim();
    let batch = noise.batch();
    assert_eq!(z0.len(), batch * d, "batch_adjoint_sum_core: z0 layout mismatch");
    let grid = uniform_grid(t0, t1, n_steps);

    // Forward pass: terminal states only.
    let mut z_t = vec![0.0; batch * d];
    let forward_stats = {
        let mut sys = BatchForwardFunc::for_method_tier(sde, theta, batch, forward_method, tier);
        batch_grid_core(&mut sys, forward_method, z0, &grid, noise, &mut z_t)
    };

    let mut w_terminal = vec![0.0; batch * d];
    noise.sample_all(t1, &mut w_terminal);

    // The augmented batch state: one [B×(2d+p+1)] allocation partitioned
    // SoA into (z | a_z | a_θ | L) blocks.
    let mut aug = vec![0.0; batch * (2 * d + p + 1)];
    let (z_blk, rest) = aug.split_at_mut(batch * d);
    let (a_blk, rest) = rest.split_at_mut(batch * d);
    let (ath_blk, loss_blk) = rest.split_at_mut(batch * p);
    z_blk.copy_from_slice(&z_t);
    a_blk.fill(1.0); // ∂(Σ z_T)/∂z_T is the ones vector, per path.
    for (lb, zr) in loss_blk.iter_mut().zip(z_t.chunks_exact(d)) {
        *lb = zr.iter().sum();
    }

    // Backward pass over the reversed grid.
    let mut ops = BatchAdjointOps::new(sde, theta, batch, ExecConfig::new().tier(tier));
    let mut sc = BatchBackwardScratch::new(d, p, batch);
    let rgrid: Vec<f64> = grid.iter().rev().copied().collect();
    let mut backward_stats = SolveStats::default();
    noise.begin_sweep(rgrid[0]);
    for k in 0..rgrid.len() - 1 {
        let (t, tn) = (rgrid[k], rgrid[k + 1]);
        noise.sweep_increments(tn, &mut sc.dw);
        batch_backward_heun_step(&mut ops, t, tn, z_blk, a_blk, ath_blk, &mut sc);
        backward_stats.steps += 1;
    }
    backward_stats.nfe_drift = ops.nfe_drift;
    backward_stats.nfe_diffusion = ops.nfe_diffusion;

    BatchGradientOutput {
        z_terminal: z_t,
        grad_z0: a_blk.to_vec(),
        grad_theta: ath_blk.to_vec(),
        z0_reconstructed: z_blk.to_vec(),
        w_terminal,
        loss: loss_blk.to_vec(),
        forward_stats,
        backward_stats,
    }
}
