//! Antithetic-path gradient estimation (§8: "we may adopt techniques such
//! as control variates or antithetic paths" — implemented here as the
//! paper's named future-work extension).
//!
//! For a Monte-Carlo objective `E_W[L(Z_T(W))]`, the antithetic estimator
//! averages the pathwise gradient over a Brownian path and its mirror
//! `−W`. Both are valid samples of the Wiener measure, and for losses with
//! approximately odd dependence on the noise their gradient errors
//! anticorrelate, cutting estimator variance at zero extra variance cost
//! (two correlated samples for the price of two independent ones, minus
//! the shared-seed bookkeeping).

use super::stochastic::{adjoint_with_loss_core, AdjointConfig, GradientOutput};
use crate::prng::PrngKey;
use crate::sde::SdeVjp;

/// Result of one antithetic pair.
#[derive(Clone, Debug)]
pub struct AntitheticOutput {
    /// Gradient averaged over the (W, −W) pair.
    pub grad_theta: Vec<f64>,
    pub grad_z0: Vec<f64>,
    /// The two raw outputs (plus-path first).
    pub plus: GradientOutput,
    pub minus: GradientOutput,
}

/// Antithetic-pair engine behind [`crate::api::SdeProblem::sensitivity`]
/// with `SensAlg::Antithetic`. The loss-gradient closure is evaluated once
/// per branch (each branch realizes its own terminal state).
#[allow(clippy::too_many_arguments)]
pub(crate) fn antithetic_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    cfg: &AdjointConfig,
    mut loss_grad: F,
) -> AntitheticOutput
where
    S: SdeVjp + ?Sized,
    F: FnMut(&[f64]) -> Vec<f64>,
{
    let plus = adjoint_with_loss_core(sde, theta, z0, t0, t1, n_steps, key, cfg, &mut loss_grad);
    let minus_cfg = AdjointConfig { mirror: !cfg.mirror, ..*cfg };
    let minus =
        adjoint_with_loss_core(sde, theta, z0, t0, t1, n_steps, key, &minus_cfg, &mut loss_grad);
    let grad_theta = plus
        .grad_theta
        .iter()
        .zip(&minus.grad_theta)
        .map(|(a, b)| 0.5 * (a + b))
        .collect();
    let grad_z0 = plus
        .grad_z0
        .iter()
        .zip(&minus.grad_z0)
        .map(|(a, b)| 0.5 * (a + b))
        .collect();
    AntitheticOutput { grad_theta, grad_z0, plus, minus }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::stochastic::adjoint_with_loss_core;
    use crate::sde::problems::{sample_experiment_setup, Example1};
    use crate::sde::ReplicatedSde;

    fn antithetic_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n: usize,
        key: PrngKey,
        cfg: &AdjointConfig,
    ) -> AntitheticOutput {
        antithetic_core(sde, theta, z0, 0.0, 1.0, n, key, cfg, |z: &[f64]| vec![1.0; z.len()])
    }

    fn adjoint_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n: usize,
        key: PrngKey,
        cfg: &AdjointConfig,
    ) -> crate::adjoint::GradientOutput {
        adjoint_with_loss_core(sde, theta, z0, 0.0, 1.0, n, key, cfg, |z| vec![1.0; z.len()])
    }

    #[test]
    fn mirror_pair_uses_mirrored_noise() {
        let sde = ReplicatedSde::new(Example1, 2);
        let key = PrngKey::from_seed(3);
        let (theta, x0) = sample_experiment_setup(key, 2, 2);
        let out = antithetic_sum(&sde, &theta, &x0, 200, key, &AdjointConfig::default());
        for i in 0..2 {
            assert!(
                (out.plus.w_terminal[i] + out.minus.w_terminal[i]).abs() < 1e-12,
                "minus path must be the mirror of plus"
            );
        }
        assert_ne!(out.plus.grad_theta, out.minus.grad_theta);
    }

    #[test]
    fn antithetic_estimator_reduces_variance() {
        // Compare the variance of the θ-gradient estimator across seeds:
        // mean of 2 independent paths vs one antithetic pair (same total
        // number of solves). GBM's gradient has a strong odd component in
        // W_T, so antithetic coupling should shrink variance noticeably.
        let dim = 1;
        let sde = ReplicatedSde::new(Example1, dim);
        let base = PrngKey::from_seed(4);
        let (theta, x0) = sample_experiment_setup(base, dim, 2);
        let cfg = AdjointConfig::default();
        let n = 200;
        let reps = 60;

        let mut var = |antithetic: bool| -> f64 {
            let mut samples = Vec::new();
            for r in 0..reps {
                let g = if antithetic {
                    antithetic_sum(&sde, &theta, &x0, n, base.fold_in(r), &cfg).grad_theta[0]
                } else {
                    let a = adjoint_sum(&sde, &theta, &x0, n, base.fold_in(10_000 + 2 * r), &cfg);
                    let b = adjoint_sum(&sde, &theta, &x0, n, base.fold_in(10_001 + 2 * r), &cfg);
                    0.5 * (a.grad_theta[0] + b.grad_theta[0])
                };
                samples.push(g);
            }
            let m = samples.iter().sum::<f64>() / reps as f64;
            samples.iter().map(|s| (s - m) * (s - m)).sum::<f64>() / (reps - 1) as f64
        };

        let v_indep = var(false);
        let v_anti = var(true);
        // ∂L/∂α = t·X_T is strictly monotone in W, the textbook case for
        // antithetic coupling; require a clear (≥25%) variance cut.
        assert!(
            v_anti < 0.75 * v_indep,
            "antithetic variance {v_anti:.3e} not < 0.75× independent {v_indep:.3e}"
        );
    }
}
