//! Baseline: backpropagation through the operations of the solver
//! (Giles & Glasserman 2006; "adjoint approach" in the finance literature;
//! Table 1 row 2, the Fig 5(c) comparators).
//!
//! Forward: run a fixed-grid Euler–Maruyama or Milstein (Itô) solve,
//! *storing the full state trajectory and every Brownian increment* —
//! O(L·d) memory, the cost this paper's method removes. Backward: walk the
//! tape in reverse, pulling the loss gradient through each step map with
//! the SDE's VJPs:
//!
//! ```text
//! EM step      z' = z + b·h + σ ⊙ ΔW
//! pullback     āᵀ∂z'/∂z = ā + h·(āᵀ∂b/∂z) + (ā⊙ΔW)ᵀ∂σ/∂z
//!              āᵀ∂z'/∂θ =      h·(āᵀ∂b/∂θ) + (ā⊙ΔW)ᵀ∂σ/∂θ
//! Milstein adds the ½σσ'(ΔW²−h) term, whose pullback needs second
//! derivatives of σ — supplied by `SdeVjp::ito_correction_vjp` (this is
//! the "backpropagating through the Milstein solve requires evaluating
//! high-order derivatives" cost the paper mentions in §7.1).
//! ```

use super::stochastic::GradientOutput;
use crate::brownian::{BrownianMotion, BrownianPath};
use crate::prng::PrngKey;
use crate::sde::{Calculus, SdeVjp};
use crate::solvers::{uniform_grid, Method, SolveStats};

/// Backprop-through-the-solver engine behind
/// [`crate::api::SdeProblem::sensitivity`] with `SensAlg::Backprop`.
/// `method` must be `EulerMaruyama` or `MilsteinIto` (the two schemes the
/// paper backpropagates through in Fig 5c); `loss_grad` maps the realized
/// terminal state to `∂L/∂z_T`. Returns the same [`GradientOutput`] as
/// the stochastic adjoint; `noise_memory` reports the tape size
/// (trajectory + increments), the honest analogue of Table 1's O(L)
/// memory row.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backprop_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    method: Method,
    loss_grad: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    assert!(
        matches!(method, Method::EulerMaruyama | Method::MilsteinIto),
        "backprop baseline supports Euler–Maruyama and Milstein (Itô); got {}",
        method.name()
    );
    assert_eq!(
        sde.calculus(),
        Calculus::Ito,
        "backprop baseline integrates the native Itô form"
    );
    let d = sde.state_dim();
    let p = sde.param_dim();
    let grid = uniform_grid(t0, t1, n_steps);
    let mut bm = BrownianPath::new(key, d, t0, t1);

    // ---- Forward pass with a full tape. -----------------------------
    let mut tape_z = vec![0.0; (n_steps + 1) * d]; // states at grid points
    let mut tape_dw = vec![0.0; n_steps * d]; // increments per step
    tape_z[..d].copy_from_slice(z0);

    let mut b = vec![0.0; d];
    let mut s = vec![0.0; d];
    let mut sp = vec![0.0; d];
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];
    let mut nfe_f = 0u64;
    let mut nfe_g = 0u64;

    bm.sample_into(grid[0], &mut wa);
    for k in 0..n_steps {
        let (t, tn) = (grid[k], grid[k + 1]);
        let h = tn - t;
        bm.sample_into(tn, &mut wb);
        let (z_prev, z_rest) = tape_z.split_at_mut((k + 1) * d);
        let z = &z_prev[k * d..];
        let zn = &mut z_rest[..d];
        let dw = &mut tape_dw[k * d..(k + 1) * d];
        for i in 0..d {
            dw[i] = wb[i] - wa[i];
        }
        sde.drift(t, z, theta, &mut b);
        sde.diffusion(t, z, theta, &mut s);
        nfe_f += 1;
        nfe_g += 1;
        match method {
            Method::EulerMaruyama => {
                for i in 0..d {
                    zn[i] = z[i] + b[i] * h + s[i] * dw[i];
                }
            }
            Method::MilsteinIto => {
                sde.diffusion_dz_diag(t, z, theta, &mut sp);
                for i in 0..d {
                    zn[i] = z[i]
                        + b[i] * h
                        + s[i] * dw[i]
                        + 0.5 * s[i] * sp[i] * (dw[i] * dw[i] - h);
                }
            }
            _ => unreachable!(),
        }
        wa.copy_from_slice(&wb);
    }
    let z_t = tape_z[n_steps * d..].to_vec();

    // ---- Backward sweep over the tape. ------------------------------
    let mut a = loss_grad(&z_t); // ∂L/∂z_T
    assert_eq!(a.len(), d, "loss gradient has wrong dimension");
    let mut a_new = vec![0.0; d];
    let mut grad_theta = vec![0.0; p];
    let mut weighted = vec![0.0; d];
    let mut nbp = 0u64;

    for k in (0..n_steps).rev() {
        let t = grid[k];
        let h = grid[k + 1] - grid[k];
        let z = &tape_z[k * d..(k + 1) * d];
        let dw = &tape_dw[k * d..(k + 1) * d];

        // a_new = a + h·(aᵀ∂b/∂z) + (a⊙ΔW)ᵀ∂σ/∂z  (+ Milstein term)
        a_new.copy_from_slice(&a);
        // drift contribution: scale adjoint by h.
        for i in 0..d {
            weighted[i] = a[i] * h;
        }
        sde.drift_vjp(t, z, theta, &weighted, &mut a_new, &mut grad_theta);
        // diffusion contribution: adjoint weighted by ΔW per channel.
        for i in 0..d {
            weighted[i] = a[i] * dw[i];
        }
        sde.diffusion_vjp(t, z, theta, &weighted, &mut a_new, &mut grad_theta);
        if method == Method::MilsteinIto {
            // correction term c = ½σσ' times (ΔW²−h): adjoint weighted by
            // (ΔW²−h) pulled through ∂c/∂(z,θ) — second derivatives of σ.
            for i in 0..d {
                weighted[i] = a[i] * (dw[i] * dw[i] - h);
            }
            sde.ito_correction_vjp(t, z, theta, &weighted, &mut a_new, &mut grad_theta);
        }
        std::mem::swap(&mut a, &mut a_new);
        nbp += 1;
    }

    GradientOutput {
        z_terminal: z_t,
        grad_z0: a,
        grad_theta,
        z0_reconstructed: z0.to_vec(), // tape holds z0 exactly
        forward_stats: SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: nfe_f,
            nfe_diffusion: nfe_g,
        },
        backward_stats: SolveStats {
            steps: nbp,
            rejected: 0,
            nfe_drift: nbp,
            nfe_diffusion: nbp,
        },
        // Tape: (L+1)·d states + L·d increments + stored noise.
        noise_memory: tape_z.len() + tape_dw.len() + bm.memory_footprint(),
        w_terminal: bm.sample(t1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};
    use crate::sde::ReplicatedSde;

    /// Sum-loss convenience over the engine (what `SensAlg::Backprop`
    /// resolves to).
    fn backprop_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n_steps: usize,
        key: PrngKey,
        method: Method,
    ) -> GradientOutput {
        backprop_core(sde, theta, z0, 0.0, 1.0, n_steps, key, method, |z| vec![1.0; z.len()])
    }

    /// Finite-difference check: perturb θ_j, re-run the *same* discrete
    /// solve on the same Brownian path, difference the losses. Backprop
    /// must match the discrete solve's gradient to FD accuracy — this is
    /// exact (same computational graph), unlike the adjoint which matches
    /// only in the h→0 limit.
    fn fd_check<P: crate::sde::ScalarSde + Copy>(problem: P, method: Method, seed: u64) {
        let dim = 3;
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let n_steps = 64;

        let loss = |th: &[f64], x: &[f64]| -> f64 {
            let out = backprop_sum(&sde, th, x, n_steps, key, method);
            out.z_terminal.iter().sum()
        };

        let out = backprop_sum(&sde, &theta, &x0, n_steps, key, method);
        let eps = 1e-6;
        for j in 0..theta.len() {
            let mut tp = theta.clone();
            tp[j] += eps;
            let hi = loss(&tp, &x0);
            tp[j] -= 2.0 * eps;
            let lo = loss(&tp, &x0);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - out.grad_theta[j]).abs() < 1e-4 * fd.abs().max(1.0),
                "θ[{j}]: fd {fd} vs bp {}",
                out.grad_theta[j]
            );
        }
        for i in 0..dim {
            let mut xp = x0.clone();
            xp[i] += eps;
            let hi = loss(&theta, &xp);
            xp[i] -= 2.0 * eps;
            let lo = loss(&theta, &xp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - out.grad_z0[i]).abs() < 1e-4 * fd.abs().max(1.0),
                "z0[{i}]: fd {fd} vs bp {}",
                out.grad_z0[i]
            );
        }
    }

    #[test]
    fn euler_backprop_is_exact_gradient_of_discrete_solve() {
        fd_check(Example1, Method::EulerMaruyama, 3);
        fd_check(Example2, Method::EulerMaruyama, 4);
    }

    #[test]
    fn milstein_backprop_is_exact_gradient_of_discrete_solve() {
        fd_check(Example1, Method::MilsteinIto, 5);
        fd_check(Example2, Method::MilsteinIto, 6);
    }

    #[test]
    fn backprop_agrees_with_stochastic_adjoint_in_the_limit() {
        use crate::adjoint::stochastic::{adjoint_with_loss_core, AdjointConfig};
        let dim = 2;
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(8);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let n = 8000;
        let bp = backprop_sum(&sde, &theta, &x0, n, key, Method::MilsteinIto);
        let adj = adjoint_with_loss_core(
            &sde,
            &theta,
            &x0,
            0.0,
            1.0,
            n,
            key,
            &AdjointConfig::default(),
            |z| vec![1.0; z.len()],
        );
        for j in 0..theta.len() {
            let rel = (bp.grad_theta[j] - adj.grad_theta[j]).abs()
                / adj.grad_theta[j].abs().max(1e-3);
            assert!(rel < 0.02, "θ[{j}]: bp {} vs adj {}", bp.grad_theta[j], adj.grad_theta[j]);
        }
    }

    #[test]
    fn tape_memory_scales_linearly() {
        let sde = ReplicatedSde::new(Example1, 2);
        let key = PrngKey::from_seed(9);
        let (theta, x0) = sample_experiment_setup(key, 2, 2);
        let m64 =
            backprop_sum(&sde, &theta, &x0, 64, key, Method::EulerMaruyama).noise_memory;
        let m512 =
            backprop_sum(&sde, &theta, &x0, 512, key, Method::EulerMaruyama).noise_memory;
        assert!(m512 > 6 * m64, "memory should scale ~linearly: {m64} -> {m512}");
    }
}
