//! Baseline: backpropagation through the operations of the solver
//! (Giles & Glasserman 2006; "adjoint approach" in the finance literature;
//! Table 1 row 2, the Fig 5(c) comparators).
//!
//! Forward: run a fixed-grid Euler–Maruyama or Milstein (Itô) solve,
//! *storing the full state trajectory and every Brownian increment* —
//! O(L·d) memory, the cost this paper's method removes. Backward: walk the
//! tape in reverse, pulling the loss gradient through each step map with
//! the SDE's VJPs:
//!
//! ```text
//! EM step      z' = z + b·h + σ ⊙ ΔW
//! pullback     āᵀ∂z'/∂z = ā + h·(āᵀ∂b/∂z) + (ā⊙ΔW)ᵀ∂σ/∂z
//!              āᵀ∂z'/∂θ =      h·(āᵀ∂b/∂θ) + (ā⊙ΔW)ᵀ∂σ/∂θ
//! Milstein adds the ½σσ'(ΔW²−h) term, whose pullback needs second
//! derivatives of σ — supplied by `SdeVjp::ito_correction_vjp` (this is
//! the "backpropagating through the Milstein solve requires evaluating
//! high-order derivatives" cost the paper mentions in §7.1).
//! ```
//!
//! The engine itself lives in [`super::checkpoint`]: the full tape is the
//! `Checkpointing::Tape` schedule of the checkpointed driver (first
//! forward pass records everything, nothing is recomputed), and every
//! other schedule produces bit-identical gradients with less memory. This
//! module keeps the historical entry point for the classic configuration
//! (stored path, unmirrored, full tape).

use super::checkpoint::{checkpointed_backprop_core, Checkpointing};
use super::stochastic::{GradientOutput, NoiseMode};
use crate::prng::PrngKey;
use crate::sde::SdeVjp;
use crate::solvers::Method;

/// Full-tape backprop-through-the-solver: the `Checkpointing::Tape`
/// configuration of [`super::checkpoint`] on a stored, unmirrored path.
/// `method` must be `EulerMaruyama`, `MilsteinIto` (the two schemes the
/// paper backpropagates through in Fig 5c) or `Heun`; `loss_grad` maps
/// the realized terminal state to `∂L/∂z_T`. Returns the same
/// [`GradientOutput`] as the stochastic adjoint; `noise_memory` reports
/// the tape size (trajectory + increments), the honest analogue of
/// Table 1's O(L) memory row.
#[allow(clippy::too_many_arguments)]
pub(crate) fn backprop_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    method: Method,
    loss_grad: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    checkpointed_backprop_core(
        sde,
        theta,
        z0,
        t0,
        t1,
        n_steps,
        key,
        method,
        NoiseMode::StoredPath,
        false,
        crate::brownian::DEFAULT_NODE_CACHE,
        Checkpointing::Tape,
        loss_grad,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sde::problems::{sample_experiment_setup, Example1, Example2};
    use crate::sde::ReplicatedSde;

    /// Sum-loss convenience over the engine (what `SensAlg::Backprop`
    /// resolves to).
    fn backprop_sum<S: SdeVjp + ?Sized>(
        sde: &S,
        theta: &[f64],
        z0: &[f64],
        n_steps: usize,
        key: PrngKey,
        method: Method,
    ) -> GradientOutput {
        backprop_core(sde, theta, z0, 0.0, 1.0, n_steps, key, method, |z| vec![1.0; z.len()])
    }

    /// Finite-difference check: perturb θ_j, re-run the *same* discrete
    /// solve on the same Brownian path, difference the losses. Backprop
    /// must match the discrete solve's gradient to FD accuracy — this is
    /// exact (same computational graph), unlike the adjoint which matches
    /// only in the h→0 limit.
    fn fd_check<P: crate::sde::ScalarSde + Copy>(problem: P, method: Method, seed: u64) {
        let dim = 3;
        let sde = ReplicatedSde::new(problem, dim);
        let key = PrngKey::from_seed(seed);
        let (theta, x0) = sample_experiment_setup(key, dim, problem.nparams());
        let n_steps = 64;

        let loss = |th: &[f64], x: &[f64]| -> f64 {
            let out = backprop_sum(&sde, th, x, n_steps, key, method);
            out.z_terminal.iter().sum()
        };

        let out = backprop_sum(&sde, &theta, &x0, n_steps, key, method);
        let eps = 1e-6;
        for j in 0..theta.len() {
            let mut tp = theta.clone();
            tp[j] += eps;
            let hi = loss(&tp, &x0);
            tp[j] -= 2.0 * eps;
            let lo = loss(&tp, &x0);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - out.grad_theta[j]).abs() < 1e-4 * fd.abs().max(1.0),
                "θ[{j}]: fd {fd} vs bp {}",
                out.grad_theta[j]
            );
        }
        for i in 0..dim {
            let mut xp = x0.clone();
            xp[i] += eps;
            let hi = loss(&theta, &xp);
            xp[i] -= 2.0 * eps;
            let lo = loss(&theta, &xp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - out.grad_z0[i]).abs() < 1e-4 * fd.abs().max(1.0),
                "z0[{i}]: fd {fd} vs bp {}",
                out.grad_z0[i]
            );
        }
    }

    #[test]
    fn euler_backprop_is_exact_gradient_of_discrete_solve() {
        fd_check(Example1, Method::EulerMaruyama, 3);
        fd_check(Example2, Method::EulerMaruyama, 4);
    }

    #[test]
    fn milstein_backprop_is_exact_gradient_of_discrete_solve() {
        fd_check(Example1, Method::MilsteinIto, 5);
        fd_check(Example2, Method::MilsteinIto, 6);
    }

    #[test]
    fn heun_backprop_is_exact_gradient_of_discrete_solve() {
        // New with the checkpoint subsystem: the predictor-corrector map
        // is differentiated stage by stage (Stratonovich drift form).
        fd_check(Example1, Method::Heun, 7);
        fd_check(Example2, Method::Heun, 11);
    }

    #[test]
    fn backprop_agrees_with_stochastic_adjoint_in_the_limit() {
        use crate::adjoint::stochastic::{adjoint_with_loss_core, AdjointConfig};
        let dim = 2;
        let sde = ReplicatedSde::new(Example1, dim);
        let key = PrngKey::from_seed(8);
        let (theta, x0) = sample_experiment_setup(key, dim, 2);
        let n = 8000;
        let bp = backprop_sum(&sde, &theta, &x0, n, key, Method::MilsteinIto);
        let adj = adjoint_with_loss_core(
            &sde,
            &theta,
            &x0,
            0.0,
            1.0,
            n,
            key,
            &AdjointConfig::default(),
            |z| vec![1.0; z.len()],
        );
        for j in 0..theta.len() {
            let rel = (bp.grad_theta[j] - adj.grad_theta[j]).abs()
                / adj.grad_theta[j].abs().max(1e-3);
            assert!(rel < 0.02, "θ[{j}]: bp {} vs adj {}", bp.grad_theta[j], adj.grad_theta[j]);
        }
    }

    #[test]
    fn tape_memory_scales_linearly() {
        let sde = ReplicatedSde::new(Example1, 2);
        let key = PrngKey::from_seed(9);
        let (theta, x0) = sample_experiment_setup(key, 2, 2);
        let m64 =
            backprop_sum(&sde, &theta, &x0, 64, key, Method::EulerMaruyama).noise_memory;
        let m512 =
            backprop_sum(&sde, &theta, &x0, 512, key, Method::EulerMaruyama).noise_memory;
        assert!(m512 > 6 * m64, "memory should scale ~linearly: {m64} -> {m512}");
    }
}
