//! Backward path reconstruction (Figure 2).
//!
//! "Negating the drift and diffusion functions for an Itô SDE and
//! simulating backwards from the end state gives the wrong reconstruction.
//! Negating the drift and diffusion functions for the converted
//! Stratonovich SDE gives the same path when simulated backwards."
//!
//! Both variants are mechanically identical in the signed-step convention —
//! walk the grid in reverse with `h < 0` and `ΔW = W(t_k) − W(t_{k+1})` —
//! the only difference is which *form* of the coefficients is stepped:
//!
//! * Itô-naive: Euler–Maruyama on the raw Itô coefficients. Each backward
//!   step mis-handles the Itô correction twice (once per direction), so the
//!   reconstruction drifts by O(σσ'·T) regardless of step size.
//! * Stratonovich: Heun on the converted coefficients. The trapezoid rule
//!   is symmetric under time reversal, so the reconstruction error vanishes
//!   as h → 0.

use crate::brownian::BrownianPath;
use crate::prng::PrngKey;
use crate::sde::{Calculus, ForwardFunc, Sde};
use crate::solvers::{grid_saving_core, uniform_grid, Method};

/// Outcome of a forward-then-backward reconstruction experiment.
#[derive(Clone, Debug)]
pub struct ReconstructionResult {
    /// Times of the saved forward trajectory.
    pub times: Vec<f64>,
    /// Forward trajectory, row-major `(len(times), d)`.
    pub forward: Vec<f64>,
    /// Backward-reconstructed trajectory on the same grid (same layout,
    /// time-ascending so rows align with `forward`).
    pub backward: Vec<f64>,
    /// Max-abs reconstruction error over all grid points and dimensions.
    pub max_error: f64,
    /// Reconstruction error at t0 only.
    pub initial_error: f64,
}

/// Simulate forward on a uniform grid, then backward from the end state on
/// the same grid and Brownian path, with the given scheme. The scheme's
/// calculus decides the coefficient form: `EulerMaruyama`/`MilsteinIto`
/// step the raw Itô form (Fig 2's "wrong" reconstruction);
/// `Heun`/`MilsteinStrat` step the converted Stratonovich form (the
/// "right" one).
pub fn reconstruction_experiment<S: Sde + ?Sized>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    method: Method,
) -> ReconstructionResult {
    assert_eq!(sde.calculus(), Calculus::Ito, "experiment expects an Itô-native SDE");
    let d = sde.state_dim();
    let grid = uniform_grid(t0, t1, n_steps);
    let mut bm = BrownianPath::new(key, d, t0, t1);

    // Forward.
    let mut sys = ForwardFunc::for_method(sde, theta, method);
    let (fwd, _) = grid_saving_core(&mut sys, method, z0, &grid, &mut bm);

    // Backward from the terminal state over the reversed grid.
    let rgrid: Vec<f64> = grid.iter().rev().copied().collect();
    let z_t = &fwd[n_steps * d..];
    let mut sys_b = ForwardFunc::for_method(sde, theta, method);
    let (bwd_rev, _) = grid_saving_core(&mut sys_b, method, z_t, &rgrid, &mut bm);

    // Re-order backward trajectory to ascending time.
    let n_pts = grid.len();
    let mut bwd = vec![0.0; n_pts * d];
    for k in 0..n_pts {
        bwd[k * d..(k + 1) * d].copy_from_slice(&bwd_rev[(n_pts - 1 - k) * d..(n_pts - k) * d]);
    }

    let mut max_error: f64 = 0.0;
    for i in 0..fwd.len() {
        max_error = max_error.max((fwd[i] - bwd[i]).abs());
    }
    let initial_error = (0..d)
        .map(|i| (fwd[i] - bwd[i]).abs())
        .fold(0.0f64, f64::max);

    ReconstructionResult { times: grid, forward: fwd, backward: bwd, max_error, initial_error }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sde::problems::Example1;
    use crate::sde::ReplicatedSde;

    /// Fig 2, quantified: on GBM (multiplicative noise), Stratonovich-Heun
    /// reconstruction error → 0 with step size, Itô-naive error does not.
    #[test]
    fn stratonovich_reconstructs_ito_does_not() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [1.0, 0.8]; // strong noise so the Itô defect is visible
        let z0 = [1.0];
        let key = PrngKey::from_seed(2020);

        let strat =
            reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, 2048, key, Method::Heun);
        let ito = reconstruction_experiment(
            &sde,
            &theta,
            &z0,
            0.0,
            1.0,
            2048,
            key,
            Method::EulerMaruyama,
        );
        assert!(
            strat.initial_error < 1e-2,
            "Stratonovich reconstruction should succeed: {}",
            strat.initial_error
        );
        assert!(
            ito.initial_error > 10.0 * strat.initial_error,
            "Itô-naive reconstruction should fail: ito {} vs strat {}",
            ito.initial_error,
            strat.initial_error
        );
    }

    #[test]
    fn stratonovich_error_decreases_with_refinement() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [1.0, 0.8];
        let z0 = [1.0];
        let key = PrngKey::from_seed(2021);
        let coarse =
            reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, 128, key, Method::Heun);
        let fine =
            reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, 4096, key, Method::Heun);
        assert!(
            fine.max_error < 0.5 * coarse.max_error,
            "refinement should shrink error: coarse {} fine {}",
            coarse.max_error,
            fine.max_error
        );
    }

    #[test]
    fn trajectories_are_aligned() {
        let sde = ReplicatedSde::new(Example1, 2);
        let theta = [0.5, 0.3, 0.6, 0.4];
        let z0 = [1.0, 2.0];
        let key = PrngKey::from_seed(2022);
        let res = reconstruction_experiment(&sde, &theta, &z0, 0.0, 1.0, 64, key, Method::Heun);
        // Endpoint rows must agree exactly: backward starts from forward's
        // terminal state.
        let d = 2;
        let n = res.times.len();
        for i in 0..d {
            assert_eq!(res.forward[(n - 1) * d + i], res.backward[(n - 1) * d + i]);
        }
    }
}
