//! Batched checkpointed backprop: the scalar [`super::driver`] lifted to
//! `[B×d]` structure-of-arrays buffers, one schedule per chunk.
//!
//! Every per-path float follows the scalar [`super::replay::StepKernel`]
//! exactly — the batched SDE kernels default to row loops over the scalar
//! VJPs, the [`BatchBrownian`] sweeps query each path's source in the
//! scalar order, and each path's `grad_theta` row sees the same
//! accumulation sequence — so a batch of B checkpointed backprops equals
//! B scalar runs bit for bit, for every schedule (pinned by
//! `tests/checkpoint_backprop.rs`). Memory accounting is reported in
//! per-path units so the batched and scalar engines expose identical
//! `Gradients.stats`.

use super::driver::MemMeter;
use super::schedule::Checkpointing;
use crate::adjoint::stochastic::Noise;
use crate::brownian::{BatchBrownian, BrownianMotion};
use crate::sde::{BatchSdeVjp, Calculus};
use crate::solvers::{uniform_grid, Method, SolveStats};

/// Batched forward/backward step kernel — [`super::replay::StepKernel`]
/// over `[B×d]`/`[B×p]` buffers, NFE counters in per-path units (one
/// batched call = one evaluation per path).
struct BatchStepKernel<'a, S: BatchSdeVjp + ?Sized> {
    sde: &'a S,
    theta: &'a [f64],
    method: Method,
    n: usize, // batch * d
    b: Vec<f64>,
    sig: Vec<f64>,
    sigp: Vec<f64>,
    b1: Vec<f64>,
    sig1: Vec<f64>,
    zp: Vec<f64>,
    weighted: Vec<f64>,
    v1: Vec<f64>,
    scr: Vec<f64>,
    nfe_f: u64,
    nfe_g: u64,
    bnf: u64,
    bng: u64,
}

impl<'a, S: BatchSdeVjp + ?Sized> BatchStepKernel<'a, S> {
    fn new(sde: &'a S, theta: &'a [f64], method: Method, batch: usize) -> Self {
        assert!(
            matches!(method, Method::EulerMaruyama | Method::MilsteinIto | Method::Heun),
            "backprop kernel supports Euler-Maruyama, Milstein (Ito) and Heun, got {:?}",
            method
        );
        if !matches!(method, Method::Heun) {
            assert!(
                matches!(sde.calculus(), Calculus::Ito),
                "Euler/Milstein backprop differentiates the Ito discretization; \
                 system is Stratonovich-native"
            );
        }
        assert!(batch > 0, "BatchStepKernel: empty batch");
        let d = sde.state_dim();
        let n = batch * d;
        BatchStepKernel {
            sde,
            theta,
            method,
            n,
            b: vec![0.0; n],
            sig: vec![0.0; n],
            sigp: vec![0.0; n],
            b1: vec![0.0; n],
            sig1: vec![0.0; n],
            zp: vec![0.0; n],
            weighted: vec![0.0; n],
            v1: vec![0.0; n],
            scr: vec![0.0; 2 * d],
            nfe_f: 0,
            nfe_g: 0,
            bnf: 0,
            bng: 0,
        }
    }

    fn forward_step(&mut self, t: f64, tn: f64, z: &[f64], dw: &[f64], zn: &mut [f64]) {
        let h = tn - t;
        match self.method {
            Method::EulerMaruyama => {
                self.sde.drift_batch(t, z, self.theta, &mut self.b);
                self.sde.diffusion_batch(t, z, self.theta, &mut self.sig);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.n {
                    zn[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
            }
            Method::MilsteinIto => {
                self.sde.drift_batch(t, z, self.theta, &mut self.b);
                self.sde.diffusion_batch(t, z, self.theta, &mut self.sig);
                self.sde.diffusion_dz_diag_batch(t, z, self.theta, &mut self.sigp);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.n {
                    zn[i] = z[i]
                        + self.b[i] * h
                        + self.sig[i] * dw[i]
                        + 0.5 * self.sig[i] * self.sigp[i] * (dw[i] * dw[i] - h);
                }
            }
            Method::Heun => {
                self.sde.drift_stratonovich_batch(t, z, self.theta, &mut self.b, &mut self.scr);
                self.sde.diffusion_batch(t, z, self.theta, &mut self.sig);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.n {
                    self.zp[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
                self.sde.drift_stratonovich_batch(
                    tn,
                    &self.zp,
                    self.theta,
                    &mut self.b1,
                    &mut self.scr,
                );
                self.sde.diffusion_batch(tn, &self.zp, self.theta, &mut self.sig1);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.n {
                    zn[i] = z[i]
                        + 0.5 * (self.b[i] + self.b1[i]) * h
                        + 0.5 * (self.sig[i] + self.sig1[i]) * dw[i];
                }
            }
            _ => unreachable!("validated in BatchStepKernel::new"),
        }
    }

    fn backward_step(
        &mut self,
        t: f64,
        tn: f64,
        z: &[f64],
        dw: &[f64],
        a: &[f64],
        a_new: &mut [f64],
        grad_theta: &mut [f64],
    ) {
        let h = tn - t;
        match self.method {
            Method::EulerMaruyama | Method::MilsteinIto => {
                a_new.copy_from_slice(a);
                for i in 0..self.n {
                    self.weighted[i] = a[i] * h;
                }
                self.sde.drift_vjp_batch(t, z, self.theta, &self.weighted, a_new, grad_theta);
                for i in 0..self.n {
                    self.weighted[i] = a[i] * dw[i];
                }
                self.sde.diffusion_vjp_batch(t, z, self.theta, &self.weighted, a_new, grad_theta);
                if matches!(self.method, Method::MilsteinIto) {
                    for i in 0..self.n {
                        self.weighted[i] = a[i] * (dw[i] * dw[i] - h);
                    }
                    self.sde.ito_correction_vjp_batch(
                        t,
                        z,
                        self.theta,
                        &self.weighted,
                        a_new,
                        grad_theta,
                    );
                }
                self.bnf += 1;
                self.bng += 1;
            }
            Method::Heun => {
                self.sde.drift_stratonovich_batch(t, z, self.theta, &mut self.b, &mut self.scr);
                self.sde.diffusion_batch(t, z, self.theta, &mut self.sig);
                for i in 0..self.n {
                    self.zp[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
                self.v1.fill(0.0);
                for i in 0..self.n {
                    self.weighted[i] = 0.5 * h * a[i];
                }
                self.sde.drift_vjp_stratonovich_batch(
                    tn,
                    &self.zp,
                    self.theta,
                    &self.weighted,
                    &mut self.v1,
                    grad_theta,
                    &mut self.scr,
                );
                for i in 0..self.n {
                    self.weighted[i] = 0.5 * dw[i] * a[i];
                }
                self.sde.diffusion_vjp_batch(
                    tn,
                    &self.zp,
                    self.theta,
                    &self.weighted,
                    &mut self.v1,
                    grad_theta,
                );
                for i in 0..self.n {
                    a_new[i] = a[i] + self.v1[i];
                }
                for i in 0..self.n {
                    self.weighted[i] = 0.5 * h * a[i] + h * self.v1[i];
                }
                self.sde.drift_vjp_stratonovich_batch(
                    t,
                    z,
                    self.theta,
                    &self.weighted,
                    a_new,
                    grad_theta,
                    &mut self.scr,
                );
                for i in 0..self.n {
                    self.weighted[i] = 0.5 * dw[i] * a[i] + dw[i] * self.v1[i];
                }
                self.sde.diffusion_vjp_batch(t, z, self.theta, &self.weighted, a_new, grad_theta);
                self.bnf += 3;
                self.bng += 3;
            }
            _ => unreachable!("validated in BatchStepKernel::new"),
        }
    }
}

/// Local batch tape of one segment: `len+1` batch states and `len` batch
/// increment rows.
struct BatchLeafTape {
    n: usize, // batch * d
    len: usize,
    z: Vec<f64>,
    dw: Vec<f64>,
}

impl BatchLeafTape {
    fn new(n: usize, len: usize) -> Self {
        BatchLeafTape { n, len, z: vec![0.0; (len + 1) * n], dw: vec![0.0; len * n] }
    }

    /// Tape size in f64s *per path* (the metered unit).
    fn f64s_per_path(&self, batch: usize) -> usize {
        (self.z.len() + self.dw.len()) / batch
    }

    fn state(&self, k: usize) -> &[f64] {
        &self.z[k * self.n..(k + 1) * self.n]
    }

    fn dw(&self, k: usize) -> &[f64] {
        &self.dw[k * self.n..(k + 1) * self.n]
    }

    fn record_forward<S: BatchSdeVjp + ?Sized>(
        &mut self,
        kern: &mut BatchStepKernel<'_, S>,
        grid: &[f64],
        lo: usize,
        z_lo: &[f64],
        bm: &mut BatchBrownian<Noise>,
    ) {
        let n = self.n;
        self.z[..n].copy_from_slice(z_lo);
        bm.begin_sweep(grid[lo]);
        for k in 0..self.len {
            bm.sweep_increments(grid[lo + k + 1], &mut self.dw[k * n..(k + 1) * n]);
            let (prev, next) = self.z.split_at_mut((k + 1) * n);
            kern.forward_step(
                grid[lo + k],
                grid[lo + k + 1],
                &prev[k * n..],
                &self.dw[k * n..(k + 1) * n],
                &mut next[..n],
            );
        }
    }
}

fn integrate_state_only_batch<S: BatchSdeVjp + ?Sized>(
    kern: &mut BatchStepKernel<'_, S>,
    grid: &[f64],
    lo: usize,
    hi: usize,
    z_lo: &[f64],
    bm: &mut BatchBrownian<Noise>,
    z_out: &mut [f64],
) {
    let n = z_lo.len();
    let mut z = z_lo.to_vec();
    let mut zn = vec![0.0; n];
    let mut dw = vec![0.0; n];
    bm.begin_sweep(grid[lo]);
    for k in lo..hi {
        bm.sweep_increments(grid[k + 1], &mut dw);
        kern.forward_step(grid[k], grid[k + 1], &z, &dw, &mut zn);
        std::mem::swap(&mut z, &mut zn);
    }
    z_out.copy_from_slice(&z);
}

/// Per-path rows of everything the scalar checkpointed driver reports.
pub(crate) struct BatchCheckpointOutput {
    /// Terminal states `[B×d]`.
    pub z_terminal: Vec<f64>,
    /// `∂(Σ_i z_T^{(i,b)})/∂z_0` per path, `[B×d]`.
    pub grad_z0: Vec<f64>,
    /// `∂(Σ_i z_T^{(i,b)})/∂θ` per path, `[B×p]`.
    pub grad_theta: Vec<f64>,
    /// Realized `W_b(t1)` per path, `[B×d]`.
    pub w_terminal: Vec<f64>,
    /// Per-path solve statistics (uniform across the batch).
    pub forward_stats: SolveStats,
    pub backward_stats: SolveStats,
    /// Peak live tape/checkpoint f64s per path.
    pub peak_tape_f64s: usize,
    /// Replay/recompute evaluations per path (beyond the first pass).
    pub recompute_nfe: u64,
}

/// Batched checkpointed backprop for the summed per-path loss
/// `L_b = Σ_i z_T^{(i,b)}` — the chunk engine behind
/// [`crate::api::sensitivity_batch`] with `SensAlg::Backprop`. `z0` is
/// `[B×d]`; `noise` carries one replayable source per path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn batch_checkpoint_backprop_core<S: BatchSdeVjp + ?Sized>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    noise: &mut BatchBrownian<Noise>,
    method: Method,
    checkpointing: Checkpointing,
) -> BatchCheckpointOutput {
    let d = sde.state_dim();
    let p = sde.param_dim();
    let batch = noise.batch();
    assert_eq!(z0.len(), batch * d, "batch_checkpoint_backprop_core: z0 layout mismatch");
    let n = batch * d;
    let grid = uniform_grid(t0, t1, n_steps);
    let schedule = checkpointing.schedule(n_steps);
    let mut kern = BatchStepKernel::new(sde, theta, method, batch);
    let mut meter = MemMeter::default(); // per-path units

    let (z_t, ckpts, bnds);
    if schedule.is_tape() {
        let mut tape = BatchLeafTape::new(n, n_steps);
        meter.alloc(tape.f64s_per_path(batch));
        {
            let _span = crate::obs::span!("ckpt.forward");
            tape.record_forward(&mut kern, &grid, 0, z0, noise);
        }
        let forward_stats = SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: kern.nfe_f,
            nfe_diffusion: kern.nfe_g,
        };
        let z_term = tape.state(n_steps).to_vec();
        let mut w_terminal = vec![0.0; n];
        noise.sample_all(t1, &mut w_terminal);

        let mut a = vec![1.0; n]; // ∂(Σ z_T)/∂z_T per path
        let mut a_new = vec![0.0; n];
        let mut grad_theta = vec![0.0; batch * p];
        {
            let _span = crate::obs::span!("ckpt.backward");
            for k in (0..n_steps).rev() {
                kern.backward_step(
                    grid[k],
                    grid[k + 1],
                    tape.state(k),
                    tape.dw(k),
                    &a,
                    &mut a_new,
                    &mut grad_theta,
                );
                std::mem::swap(&mut a, &mut a_new);
            }
        }
        super::driver::publish_ckpt_gauges(meter.peak * 8, 0);
        return BatchCheckpointOutput {
            z_terminal: z_term,
            grad_z0: a,
            grad_theta,
            w_terminal,
            forward_stats,
            backward_stats: SolveStats {
                steps: n_steps as u64,
                rejected: 0,
                nfe_drift: kern.bnf,
                nfe_diffusion: kern.bng,
            },
            peak_tape_f64s: meter.peak,
            recompute_nfe: 0,
        };
    } else {
        bnds = schedule.boundaries().to_vec();
        let nseg = bnds.len() - 1;
        let mut ck = vec![0.0; nseg * n];
        meter.alloc(nseg * d);
        let _span = crate::obs::span!("ckpt.forward");
        let mut z = z0.to_vec();
        let mut zn = vec![0.0; n];
        let mut dw = vec![0.0; n];
        let mut seg = 0usize;
        noise.begin_sweep(grid[0]);
        for k in 0..n_steps {
            if seg < nseg && k == bnds[seg] {
                ck[seg * n..(seg + 1) * n].copy_from_slice(&z);
                seg += 1;
            }
            noise.sweep_increments(grid[k + 1], &mut dw);
            kern.forward_step(grid[k], grid[k + 1], &z, &dw, &mut zn);
            std::mem::swap(&mut z, &mut zn);
        }
        z_t = z;
        ckpts = ck;
    }
    let forward_stats = SolveStats {
        steps: n_steps as u64,
        rejected: 0,
        nfe_drift: kern.nfe_f,
        nfe_diffusion: kern.nfe_g,
    };
    let (rf0, rg0) = (kern.nfe_f, kern.nfe_g);
    let mut w_terminal = vec![0.0; n];
    noise.sample_all(t1, &mut w_terminal);

    let mut a = vec![1.0; n];
    let mut a_new = vec![0.0; n];
    let mut grad_theta = vec![0.0; batch * p];
    let nseg = bnds.len() - 1;
    {
        let _span = crate::obs::span!("ckpt.backward");
        for j in (0..nseg).rev() {
            backward_span_batch(
                &mut kern,
                &grid,
                bnds[j],
                bnds[j + 1],
                &ckpts[j * n..(j + 1) * n],
                schedule.leaf_cap(),
                noise,
                &mut a,
                &mut a_new,
                &mut grad_theta,
                &mut meter,
                batch,
            );
        }
    }
    let recompute_nfe = (kern.nfe_f - rf0) + (kern.nfe_g - rg0);
    super::driver::publish_ckpt_gauges(meter.peak * 8, recompute_nfe);

    BatchCheckpointOutput {
        z_terminal: z_t,
        grad_z0: a,
        grad_theta,
        w_terminal,
        forward_stats,
        backward_stats: SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: kern.bnf,
            nfe_diffusion: kern.bng,
        },
        peak_tape_f64s: meter.peak,
        recompute_nfe,
    }
}

#[allow(clippy::too_many_arguments)]
fn backward_span_batch<S: BatchSdeVjp + ?Sized>(
    kern: &mut BatchStepKernel<'_, S>,
    grid: &[f64],
    lo: usize,
    hi: usize,
    z_lo: &[f64],
    leaf_cap: usize,
    noise: &mut BatchBrownian<Noise>,
    a: &mut Vec<f64>,
    a_new: &mut Vec<f64>,
    grad_theta: &mut [f64],
    meter: &mut MemMeter,
    batch: usize,
) {
    let n = z_lo.len();
    let d = n / batch;
    let len = hi - lo;
    if len <= leaf_cap {
        let mut tape = BatchLeafTape::new(n, len);
        let units = tape.f64s_per_path(batch);
        meter.alloc(units);
        {
            let _span = crate::obs::span!("ckpt.replay");
            tape.record_forward(kern, grid, lo, z_lo, noise);
        }
        for k in (0..len).rev() {
            kern.backward_step(
                grid[lo + k],
                grid[lo + k + 1],
                tape.state(k),
                tape.dw(k),
                a,
                a_new,
                grad_theta,
            );
            std::mem::swap(a, a_new);
        }
        meter.free(units);
    } else {
        let mid = lo + len / 2;
        let mut z_mid = vec![0.0; n];
        meter.alloc(d);
        {
            let _span = crate::obs::span!("ckpt.replay");
            integrate_state_only_batch(kern, grid, lo, mid, z_lo, noise, &mut z_mid);
        }
        backward_span_batch(
            kern, grid, mid, hi, &z_mid, leaf_cap, noise, a, a_new, grad_theta, meter, batch,
        );
        drop(z_mid);
        meter.free(d);
        backward_span_batch(
            kern, grid, lo, mid, z_lo, leaf_cap, noise, a, a_new, grad_theta, meter, batch,
        );
    }
}
