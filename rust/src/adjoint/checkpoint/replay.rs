//! Segment replay: the shared forward/backward step kernel and the local
//! tape a leaf segment is materialized into.
//!
//! Both the classic full-tape backprop and every checkpointed schedule
//! run the *same* [`StepKernel`] functions over the *same* grid times and
//! the *same* Brownian increments (every in-tree noise source replays
//! bit-identically: `BrownianPath` caches each queried time, the virtual
//! tree is a pure function of `(key, t)`, and mirroring is a
//! deterministic negation). Gradients are therefore exact-f64-identical
//! across schedules by construction — the schedule only changes when a
//! step's inputs are recomputed, never what is computed.

use crate::brownian::BrownianMotion;
use crate::sde::{Calculus, SdeVjp};
use crate::solvers::Method;

/// Forward/backward step kernel for the taped family (EM, Milstein-Itô,
/// Heun), with scratch buffers and NFE counters.
///
/// Expressions are kept bitwise-identical to the historical
/// `backprop_core` (EM/Milstein) and to `Stepper` (Heun), so swapping the
/// engine underneath `SensAlg::Backprop` changes no result.
pub(crate) struct StepKernel<'a, S: SdeVjp + ?Sized> {
    sde: &'a S,
    theta: &'a [f64],
    method: Method,
    d: usize,
    // forward scratch
    b: Vec<f64>,
    sig: Vec<f64>,
    sigp: Vec<f64>,
    b1: Vec<f64>,
    sig1: Vec<f64>,
    zp: Vec<f64>,
    // backward scratch
    weighted: Vec<f64>,
    v1: Vec<f64>,
    scr: Vec<f64>,
    /// Forward drift / diffusion evaluations (first pass + replays).
    pub nfe_f: u64,
    pub nfe_g: u64,
    /// Backward (VJP-side) evaluation counters, in historical units:
    /// one per drift-side and one per diffusion-side visit of a step.
    pub bnf: u64,
    pub bng: u64,
}

impl<'a, S: SdeVjp + ?Sized> StepKernel<'a, S> {
    pub fn new(sde: &'a S, theta: &'a [f64], method: Method) -> Self {
        assert!(
            matches!(method, Method::EulerMaruyama | Method::MilsteinIto | Method::Heun),
            "backprop kernel supports Euler-Maruyama, Milstein (Ito) and Heun, got {:?}",
            method
        );
        if !matches!(method, Method::Heun) {
            assert!(
                matches!(sde.calculus(), Calculus::Ito),
                "Euler/Milstein backprop differentiates the Ito discretization; \
                 system is Stratonovich-native"
            );
        }
        let d = sde.state_dim();
        StepKernel {
            sde,
            theta,
            method,
            d,
            b: vec![0.0; d],
            sig: vec![0.0; d],
            sigp: vec![0.0; d],
            b1: vec![0.0; d],
            sig1: vec![0.0; d],
            zp: vec![0.0; d],
            weighted: vec![0.0; d],
            v1: vec![0.0; d],
            scr: vec![0.0; 2 * d],
            nfe_f: 0,
            nfe_g: 0,
            bnf: 0,
            bng: 0,
        }
    }

    pub fn state_dim(&self) -> usize {
        self.d
    }

    /// One forward step of the discrete map: `z` at `t` → `zn` at `tn`
    /// under increment `dw`.
    pub fn forward_step(&mut self, t: f64, tn: f64, z: &[f64], dw: &[f64], zn: &mut [f64]) {
        let h = tn - t;
        match self.method {
            Method::EulerMaruyama => {
                self.sde.drift(t, z, self.theta, &mut self.b);
                self.sde.diffusion(t, z, self.theta, &mut self.sig);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.d {
                    zn[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
            }
            Method::MilsteinIto => {
                self.sde.drift(t, z, self.theta, &mut self.b);
                self.sde.diffusion(t, z, self.theta, &mut self.sig);
                self.sde.diffusion_dz_diag(t, z, self.theta, &mut self.sigp);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.d {
                    zn[i] = z[i]
                        + self.b[i] * h
                        + self.sig[i] * dw[i]
                        + 0.5 * self.sig[i] * self.sigp[i] * (dw[i] * dw[i] - h);
                }
            }
            Method::Heun => {
                // Predictor at (t, z), corrector averaging with (tn, zp);
                // drift in Stratonovich form, matching `Stepper`.
                self.sde.drift_stratonovich(t, z, self.theta, &mut self.b, &mut self.scr);
                self.sde.diffusion(t, z, self.theta, &mut self.sig);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.d {
                    self.zp[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
                self.sde.drift_stratonovich(tn, &self.zp, self.theta, &mut self.b1, &mut self.scr);
                self.sde.diffusion(tn, &self.zp, self.theta, &mut self.sig1);
                self.nfe_f += 1;
                self.nfe_g += 1;
                for i in 0..self.d {
                    zn[i] = z[i]
                        + 0.5 * (self.b[i] + self.b1[i]) * h
                        + 0.5 * (self.sig[i] + self.sig1[i]) * dw[i];
                }
            }
            _ => unreachable!("validated in StepKernel::new"),
        }
    }

    /// One backward (VJP) step: pulls the adjoint `a` at `tn` back to
    /// `a_new` at `t` through the step's discrete map, accumulating the
    /// parameter gradient into `grad_theta`. `z` is the taped state at
    /// `t`, `dw` the taped increment.
    pub fn backward_step(
        &mut self,
        t: f64,
        tn: f64,
        z: &[f64],
        dw: &[f64],
        a: &[f64],
        a_new: &mut [f64],
        grad_theta: &mut [f64],
    ) {
        let h = tn - t;
        match self.method {
            Method::EulerMaruyama | Method::MilsteinIto => {
                a_new.copy_from_slice(a);
                for i in 0..self.d {
                    self.weighted[i] = a[i] * h;
                }
                self.sde.drift_vjp(t, z, self.theta, &self.weighted, a_new, grad_theta);
                for i in 0..self.d {
                    self.weighted[i] = a[i] * dw[i];
                }
                self.sde.diffusion_vjp(t, z, self.theta, &self.weighted, a_new, grad_theta);
                if matches!(self.method, Method::MilsteinIto) {
                    for i in 0..self.d {
                        self.weighted[i] = a[i] * (dw[i] * dw[i] - h);
                    }
                    self.sde.ito_correction_vjp(
                        t,
                        z,
                        self.theta,
                        &self.weighted,
                        a_new,
                        grad_theta,
                    );
                }
                self.bnf += 1;
                self.bng += 1;
            }
            Method::Heun => {
                // Recompute the predictor state from the taped (z, dw).
                self.sde.drift_stratonovich(t, z, self.theta, &mut self.b, &mut self.scr);
                self.sde.diffusion(t, z, self.theta, &mut self.sig);
                for i in 0..self.d {
                    self.zp[i] = z[i] + self.b[i] * h + self.sig[i] * dw[i];
                }
                // u := adjoint on zp, from the corrector's (tn, zp) half.
                self.v1.fill(0.0);
                for i in 0..self.d {
                    self.weighted[i] = 0.5 * h * a[i];
                }
                self.sde.drift_vjp_stratonovich(
                    tn,
                    &self.zp,
                    self.theta,
                    &self.weighted,
                    &mut self.v1,
                    grad_theta,
                    &mut self.scr,
                );
                for i in 0..self.d {
                    self.weighted[i] = 0.5 * dw[i] * a[i];
                }
                self.sde.diffusion_vjp(
                    tn,
                    &self.zp,
                    self.theta,
                    &self.weighted,
                    &mut self.v1,
                    grad_theta,
                );
                // Pull everything back through the (t, z) stage: the
                // direct corrector half plus u through the predictor.
                for i in 0..self.d {
                    a_new[i] = a[i] + self.v1[i];
                }
                for i in 0..self.d {
                    self.weighted[i] = 0.5 * h * a[i] + h * self.v1[i];
                }
                self.sde.drift_vjp_stratonovich(
                    t,
                    z,
                    self.theta,
                    &self.weighted,
                    a_new,
                    grad_theta,
                    &mut self.scr,
                );
                for i in 0..self.d {
                    self.weighted[i] = 0.5 * dw[i] * a[i] + dw[i] * self.v1[i];
                }
                self.sde.diffusion_vjp(t, z, self.theta, &self.weighted, a_new, grad_theta);
                self.bnf += 3;
                self.bng += 3;
            }
            _ => unreachable!("validated in StepKernel::new"),
        }
    }
}

/// Local tape of one segment: `len+1` states and `len` increments, plus
/// the rolling noise-sample buffers used while recording.
pub(crate) struct LeafTape {
    d: usize,
    len: usize,
    z: Vec<f64>,
    dw: Vec<f64>,
    wa: Vec<f64>,
    wb: Vec<f64>,
}

impl LeafTape {
    pub fn new(d: usize, len: usize) -> Self {
        LeafTape {
            d,
            len,
            z: vec![0.0; (len + 1) * d],
            dw: vec![0.0; len * d],
            wa: vec![0.0; d],
            wb: vec![0.0; d],
        }
    }

    /// Tape size in f64s (states + increments; the O(d) noise buffers are
    /// working memory, not tape).
    pub fn f64s(&self) -> usize {
        self.z.len() + self.dw.len()
    }

    pub fn state(&self, k: usize) -> &[f64] {
        &self.z[k * self.d..(k + 1) * self.d]
    }

    pub fn dw(&self, k: usize) -> &[f64] {
        &self.dw[k * self.d..(k + 1) * self.d]
    }

    /// Integrate `grid[lo]..grid[hi]` forward from `z_lo`, recording
    /// every state and increment. Queries noise at the exact grid times
    /// in ascending order, so a replay over a cached path re-reads the
    /// first pass's values bit-for-bit.
    pub fn record_forward<S: SdeVjp + ?Sized, B: BrownianMotion + ?Sized>(
        &mut self,
        kern: &mut StepKernel<'_, S>,
        grid: &[f64],
        lo: usize,
        z_lo: &[f64],
        noise: &mut B,
    ) {
        let d = self.d;
        self.z[..d].copy_from_slice(z_lo);
        noise.sample_into(grid[lo], &mut self.wa);
        for k in 0..self.len {
            noise.sample_into(grid[lo + k + 1], &mut self.wb);
            for i in 0..d {
                self.dw[k * d + i] = self.wb[i] - self.wa[i];
            }
            let (prev, next) = self.z.split_at_mut((k + 1) * d);
            kern.forward_step(
                grid[lo + k],
                grid[lo + k + 1],
                &prev[k * d..],
                &self.dw[k * d..(k + 1) * d],
                &mut next[..d],
            );
            self.wa.copy_from_slice(&self.wb);
        }
    }
}

/// Integrate `grid[lo]..grid[hi]` forward from `z_lo`, keeping only the
/// final state (written into `z_out`). Used to reach a bisection midpoint
/// without taping the left half.
pub(crate) fn integrate_state_only<S: SdeVjp + ?Sized, B: BrownianMotion + ?Sized>(
    kern: &mut StepKernel<'_, S>,
    grid: &[f64],
    lo: usize,
    hi: usize,
    z_lo: &[f64],
    noise: &mut B,
    z_out: &mut [f64],
) {
    let d = z_lo.len();
    let mut z = z_lo.to_vec();
    let mut zn = vec![0.0; d];
    let mut wa = vec![0.0; d];
    let mut wb = vec![0.0; d];
    let mut dw = vec![0.0; d];
    noise.sample_into(grid[lo], &mut wa);
    for k in lo..hi {
        noise.sample_into(grid[k + 1], &mut wb);
        for i in 0..d {
            dw[i] = wb[i] - wa[i];
        }
        kern.forward_step(grid[k], grid[k + 1], &z, &dw, &mut zn);
        std::mem::swap(&mut z, &mut zn);
        wa.copy_from_slice(&wb);
    }
    z_out.copy_from_slice(&z);
}
