//! The checkpointed-backprop driver: walks a [`Schedule`]'s segments in
//! reverse, re-integrating each from its stored checkpoint (bisecting
//! long segments recursively), materializing leaf tapes, and chaining
//! the adjoint across boundaries with the shared [`StepKernel`].
//!
//! With the `Tape` schedule the driver degenerates to the classic
//! full-tape backprop: the first forward pass records the whole
//! trajectory and nothing is recomputed. For every other schedule the
//! *step order of the backward walk is identical* (steps are processed
//! in strictly descending grid order, each via the same kernel call on
//! the same `(t, z, ΔW)` triple), so gradients — including the order of
//! `grad_theta` accumulations — are exact-f64-identical to the tape.

use super::replay::{integrate_state_only, LeafTape, StepKernel};
use super::schedule::Checkpointing;
use crate::adjoint::stochastic::{GradientOutput, Noise, NoiseMode};
use crate::brownian::BrownianMotion;
use crate::prng::PrngKey;
use crate::sde::SdeVjp;
use crate::solvers::{uniform_grid, SolveStats};

/// Running peak of live tape/checkpoint f64s. Counts checkpoint states,
/// bisection-stack midpoint states, and materialized leaf tapes; the
/// O(d) working buffers and the noise source's own cache are excluded
/// (the latter is reported separately via `noise_memory`).
#[derive(Default)]
pub(crate) struct MemMeter {
    live: usize,
    pub peak: usize,
}

impl MemMeter {
    pub fn alloc(&mut self, n: usize) {
        self.live += n;
        self.peak = self.peak.max(self.live);
    }
    pub fn free(&mut self, n: usize) {
        self.live -= n;
    }
}

/// Publish a finished run's memory/recompute stats as registry gauges
/// (`adjoint.peak_tape_bytes` / `adjoint.recompute_nfe`, last-run-wins;
/// the per-call numbers stay on [`GradientOutput`]).
pub(crate) fn publish_ckpt_gauges(peak_tape_bytes: usize, recompute_nfe: u64) {
    use std::sync::OnceLock;
    static PEAK: OnceLock<crate::obs::Gauge> = OnceLock::new();
    static NFE: OnceLock<crate::obs::Gauge> = OnceLock::new();
    PEAK.get_or_init(|| crate::obs::gauge("adjoint.peak_tape_bytes"))
        .set(peak_tape_bytes as u64);
    NFE.get_or_init(|| crate::obs::gauge("adjoint.recompute_nfe")).set(recompute_nfe);
}

/// Checkpointed backprop-through-the-solver engine behind
/// [`crate::api::SensAlg::Backprop`]. Supports every replayable in-tree
/// noise source (stored path, virtual tree, mirrored either way) and the
/// EM / Milstein-Itô / Heun schemes. `checkpointing` selects the
/// memory/recompute tradeoff; results are identical for every choice.
/// `tree_cache` is the virtual tree's ancestor-cache capacity (the
/// segment-replay passes re-query long monotone runs, which the cache
/// collapses to amortized O(1) bridge draws per step); every capacity —
/// including 0 — yields bit-identical gradients.
#[allow(clippy::too_many_arguments)]
pub(crate) fn checkpointed_backprop_core<S, F>(
    sde: &S,
    theta: &[f64],
    z0: &[f64],
    t0: f64,
    t1: f64,
    n_steps: usize,
    key: PrngKey,
    method: crate::solvers::Method,
    noise_mode: NoiseMode,
    mirror: bool,
    tree_cache: usize,
    checkpointing: Checkpointing,
    loss_grad: F,
) -> GradientOutput
where
    S: SdeVjp + ?Sized,
    F: FnOnce(&[f64]) -> Vec<f64>,
{
    let d = sde.state_dim();
    let p = sde.param_dim();
    let grid = uniform_grid(t0, t1, n_steps);
    let schedule = checkpointing.schedule(n_steps);
    let mut noise = Noise::with_cache(noise_mode, key, d, t0, t1, mirror, tree_cache);
    let mut kern = StepKernel::new(sde, theta, method);
    let mut meter = MemMeter::default();

    if schedule.is_tape() {
        // ---- Classic full tape: record everything on the first pass. --
        let mut tape = LeafTape::new(d, n_steps);
        meter.alloc(tape.f64s());
        {
            let _span = crate::obs::span!("ckpt.forward");
            tape.record_forward(&mut kern, &grid, 0, z0, &mut noise);
        }
        let forward_stats = SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: kern.nfe_f,
            nfe_diffusion: kern.nfe_g,
        };
        let z_t = tape.state(n_steps).to_vec();
        let w_terminal = noise.sample(t1);

        let mut a = loss_grad(&z_t);
        assert_eq!(a.len(), d, "loss gradient has wrong dimension");
        let mut a_new = vec![0.0; d];
        let mut grad_theta = vec![0.0; p];
        {
            let _span = crate::obs::span!("ckpt.backward");
            for k in (0..n_steps).rev() {
                kern.backward_step(
                    grid[k],
                    grid[k + 1],
                    tape.state(k),
                    tape.dw(k),
                    &a,
                    &mut a_new,
                    &mut grad_theta,
                );
                std::mem::swap(&mut a, &mut a_new);
            }
        }
        publish_ckpt_gauges(meter.peak * 8, 0);
        return GradientOutput {
            z_terminal: z_t,
            grad_z0: a,
            grad_theta,
            z0_reconstructed: z0.to_vec(), // tape holds z0 exactly
            forward_stats,
            backward_stats: SolveStats {
                steps: n_steps as u64,
                rejected: 0,
                nfe_drift: kern.bnf,
                nfe_diffusion: kern.bng,
            },
            // Tape: (L+1)·d states + L·d increments + stored noise.
            noise_memory: meter.peak + noise.memory_footprint(),
            peak_tape_bytes: meter.peak * 8,
            recompute_nfe: 0,
            w_terminal,
        };
    }

    // ---- First pass: state-only, checkpoint each segment start. -------
    let bnds = schedule.boundaries().to_vec();
    let nseg = bnds.len() - 1;
    let mut ckpts = vec![0.0; nseg * d];
    meter.alloc(nseg * d);
    let z_t = {
        let _span = crate::obs::span!("ckpt.forward");
        let mut z = z0.to_vec();
        let mut zn = vec![0.0; d];
        let mut wa = vec![0.0; d];
        let mut wb = vec![0.0; d];
        let mut dw = vec![0.0; d];
        let mut seg = 0usize;
        noise.sample_into(grid[0], &mut wa);
        for k in 0..n_steps {
            if seg < nseg && k == bnds[seg] {
                ckpts[seg * d..(seg + 1) * d].copy_from_slice(&z);
                seg += 1;
            }
            noise.sample_into(grid[k + 1], &mut wb);
            for i in 0..d {
                dw[i] = wb[i] - wa[i];
            }
            kern.forward_step(grid[k], grid[k + 1], &z, &dw, &mut zn);
            std::mem::swap(&mut z, &mut zn);
            wa.copy_from_slice(&wb);
        }
        z
    };
    let forward_stats = SolveStats {
        steps: n_steps as u64,
        rejected: 0,
        nfe_drift: kern.nfe_f,
        nfe_diffusion: kern.nfe_g,
    };
    let (rf0, rg0) = (kern.nfe_f, kern.nfe_g);
    let w_terminal = noise.sample(t1);

    // ---- Backward: segments in reverse, recursing inside each. --------
    let mut a = loss_grad(&z_t);
    assert_eq!(a.len(), d, "loss gradient has wrong dimension");
    let mut a_new = vec![0.0; d];
    let mut grad_theta = vec![0.0; p];
    {
        let _span = crate::obs::span!("ckpt.backward");
        for j in (0..nseg).rev() {
            backward_span(
                &mut kern,
                &grid,
                bnds[j],
                bnds[j + 1],
                &ckpts[j * d..(j + 1) * d],
                schedule.leaf_cap(),
                &mut noise,
                &mut a,
                &mut a_new,
                &mut grad_theta,
                &mut meter,
            );
        }
    }
    let recompute_nfe = (kern.nfe_f - rf0) + (kern.nfe_g - rg0);
    publish_ckpt_gauges(meter.peak * 8, recompute_nfe);

    GradientOutput {
        z_terminal: z_t,
        grad_z0: a,
        grad_theta,
        z0_reconstructed: z0.to_vec(), // first checkpoint holds z0 exactly
        forward_stats,
        backward_stats: SolveStats {
            steps: n_steps as u64,
            rejected: 0,
            nfe_drift: kern.bnf,
            nfe_diffusion: kern.bng,
        },
        noise_memory: meter.peak + noise.memory_footprint(),
        peak_tape_bytes: meter.peak * 8,
        recompute_nfe,
        w_terminal,
    }
}

/// Walk `grid[lo]..grid[hi]` backward given the state at `lo`. Leaves
/// (≤ `leaf_cap` steps) replay into a local tape and sweep it; longer
/// spans bisect, integrating state-only to the midpoint and processing
/// the right half first (keeping the global backward order strictly
/// descending in step index), then releasing the midpoint and recursing
/// left.
#[allow(clippy::too_many_arguments)]
fn backward_span<S: SdeVjp + ?Sized>(
    kern: &mut StepKernel<'_, S>,
    grid: &[f64],
    lo: usize,
    hi: usize,
    z_lo: &[f64],
    leaf_cap: usize,
    noise: &mut Noise,
    a: &mut Vec<f64>,
    a_new: &mut Vec<f64>,
    grad_theta: &mut [f64],
    meter: &mut MemMeter,
) {
    let d = z_lo.len();
    let len = hi - lo;
    if len <= leaf_cap {
        let mut tape = LeafTape::new(d, len);
        meter.alloc(tape.f64s());
        {
            let _span = crate::obs::span!("ckpt.replay");
            tape.record_forward(kern, grid, lo, z_lo, noise);
        }
        for k in (0..len).rev() {
            kern.backward_step(
                grid[lo + k],
                grid[lo + k + 1],
                tape.state(k),
                tape.dw(k),
                a,
                a_new,
                grad_theta,
            );
            std::mem::swap(a, a_new);
        }
        meter.free(tape.f64s());
    } else {
        let mid = lo + len / 2;
        let mut z_mid = vec![0.0; d];
        meter.alloc(d);
        {
            let _span = crate::obs::span!("ckpt.replay");
            integrate_state_only(kern, grid, lo, mid, z_lo, noise, &mut z_mid);
        }
        backward_span(kern, grid, mid, hi, &z_mid, leaf_cap, noise, a, a_new, grad_theta, meter);
        drop(z_mid);
        meter.free(d);
        backward_span(kern, grid, lo, mid, z_lo, leaf_cap, noise, a, a_new, grad_theta, meter);
    }
}
