//! Checkpoint schedules: how a fixed grid of `n` solver steps is split
//! into segments for the recomputation-based backward pass.
//!
//! A [`Schedule`] has two knobs:
//!
//! * `boundaries` — the top-level segment edges. The forward pass stores
//!   the state at every segment *start* (the checkpoints); the backward
//!   pass walks segments in reverse, re-integrating each one from its
//!   checkpoint.
//! * `leaf_cap` — the longest span the backward pass may materialize as a
//!   local tape. Segments longer than `leaf_cap` are bisected
//!   recursively (storing the midpoint state while the right half is
//!   processed), so a single long segment costs `O(log len)` live states
//!   and `O(len · log len)` recomputation instead of `O(len)` memory.
//!
//! The presets trade memory for recomputation (`n` steps, state dim `d`,
//! all counts in "live steps" — one step ≈ one state + one increment):
//!
//! | preset | live peak | extra forward steps |
//! |---|---|---|
//! | [`Schedule::tape`] | `n` | 0 |
//! | [`Schedule::sqrt`] | `~2·√n` | `n` |
//! | [`Schedule::log`] | `~log₂(n)` | `~n·log₂(n)` |
//! | [`Schedule::budget`] | `≤ max_live_steps`* | schedule-dependent |
//!
//! *Budgets below `~log₂(n)+2` cannot be met by any recursive
//! single-pass schedule; they degrade gracefully to the `log` preset's
//! footprint (single-step leaves, bisection stack), which is the
//! best-effort minimum.
//!
//! Every schedule yields **bit-identical gradients** — the schedule only
//! decides *when* a step's inputs are recomputed, never *what* is
//! computed (noise replay is exact for every in-tree source; see
//! [`super::driver`]).

/// Checkpointing policy selected on [`crate::api::SensAlg::Backprop`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Checkpointing {
    /// Store the full trajectory + increments (the classic backprop tape):
    /// O(n) memory, zero recomputation. The default — fully
    /// backward-compatible with the pre-checkpointing engine.
    #[default]
    Tape,
    /// `√n` flat segmentation: `~2√n` live steps, one extra forward pass.
    Sqrt,
    /// Recursive bisection down to short leaves: `~log₂(n)` live steps,
    /// `~log₂(n)` extra forward passes.
    Log,
    /// Explicit cap on live steps (checkpoint states + bisection stack +
    /// materialized leaf tape). Honored exactly whenever
    /// `max_live_steps ≥ ~log₂(n)+2`; smaller budgets degrade to the
    /// minimal (log-like) footprint. Gradients are exact for any value,
    /// including the degenerate `1` and `n`.
    Budget { max_live_steps: usize },
}

impl Checkpointing {
    /// Materialize the concrete plan for an `n_steps`-step grid.
    pub fn schedule(&self, n_steps: usize) -> Schedule {
        match *self {
            Checkpointing::Tape => Schedule::tape(n_steps),
            Checkpointing::Sqrt => Schedule::sqrt(n_steps),
            Checkpointing::Log => Schedule::log(n_steps),
            Checkpointing::Budget { max_live_steps } => {
                Schedule::budget(n_steps, max_live_steps)
            }
        }
    }

    /// Stable identifier for bench rows and harness output.
    pub fn name(&self) -> &'static str {
        match self {
            Checkpointing::Tape => "tape",
            Checkpointing::Sqrt => "sqrt",
            Checkpointing::Log => "log",
            Checkpointing::Budget { .. } => "budget",
        }
    }
}

/// Leaf length of the [`Schedule::log`] preset: small enough that the
/// live tape is negligible next to the bisection stack, large enough
/// that leaf bookkeeping does not dominate the backward walk.
const LOG_LEAF: usize = 16;

/// A concrete checkpoint plan over a fixed grid of `n_steps` steps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schedule {
    n_steps: usize,
    /// Ascending segment edges; first is `0`, last is `n_steps`.
    boundaries: Vec<usize>,
    /// Longest span materialized as a local tape; longer spans bisect.
    leaf_cap: usize,
}

impl Schedule {
    /// Full-tape plan: one segment, never bisected.
    pub fn tape(n_steps: usize) -> Schedule {
        assert!(n_steps > 0, "Schedule: need at least one step");
        Schedule { n_steps, boundaries: vec![0, n_steps], leaf_cap: n_steps }
    }

    /// `√n` flat plan: segments of `⌈√n⌉` steps, each a single leaf.
    pub fn sqrt(n_steps: usize) -> Schedule {
        assert!(n_steps > 0, "Schedule: need at least one step");
        let c = (n_steps as f64).sqrt().ceil() as usize;
        let c = c.max(1);
        Schedule { n_steps, boundaries: flat_boundaries(n_steps, c), leaf_cap: c }
    }

    /// Logarithmic plan: one segment, bisected down to short leaves.
    pub fn log(n_steps: usize) -> Schedule {
        assert!(n_steps > 0, "Schedule: need at least one step");
        Schedule { n_steps, boundaries: vec![0, n_steps], leaf_cap: LOG_LEAF.min(n_steps) }
    }

    /// Plan honoring an explicit live-step budget where possible.
    ///
    /// Prefers (in order): the full tape when it fits (`n+1 ≤ m`, zero
    /// recomputation); the flat segmentation minimizing peak live steps
    /// subject to `⌈n/L⌉ + L ≤ m` (one extra forward pass); otherwise a
    /// single bisected segment with the leaf shrunk so stack + leaf
    /// stays within `m` when `m ≥ ~log₂(n)+2`, degrading to single-step
    /// leaves below that.
    pub fn budget(n_steps: usize, max_live_steps: usize) -> Schedule {
        assert!(n_steps > 0, "Schedule: need at least one step");
        let m = max_live_steps.max(1);
        if n_steps + 1 <= m {
            return Schedule::tape(n_steps);
        }
        // Flat feasibility: k = ⌈n/L⌉ checkpoints + an L-step leaf tape.
        let mut best: Option<(usize, usize)> = None; // (peak, L)
        for l in 1..m {
            let peak = n_steps.div_ceil(l) + l;
            let better = match best {
                None => true,
                Some((bp, _)) => peak < bp,
            };
            if peak <= m && better {
                best = Some((peak, l));
            }
        }
        if let Some((_, l)) = best {
            return Schedule {
                n_steps,
                boundaries: flat_boundaries(n_steps, l),
                leaf_cap: l,
            };
        }
        // Recursive fallback: bisection stack costs ~⌈log₂ n⌉ live
        // states; give whatever remains of the budget to the leaf.
        let stack = ceil_log2(n_steps) + 1;
        let leaf = m.saturating_sub(stack).max(1);
        Schedule { n_steps, boundaries: vec![0, n_steps], leaf_cap: leaf }
    }

    /// Number of solver steps the plan covers.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Ascending segment edges (`boundaries[0] == 0`, last `== n_steps`).
    pub fn boundaries(&self) -> &[usize] {
        &self.boundaries
    }

    /// Longest span materialized as a local tape.
    pub fn leaf_cap(&self) -> usize {
        self.leaf_cap
    }

    /// True when the plan is the classic full tape (single never-bisected
    /// segment): the driver then tapes during the first forward pass and
    /// recomputes nothing.
    pub fn is_tape(&self) -> bool {
        self.boundaries.len() == 2 && self.leaf_cap >= self.n_steps
    }

    /// Analytic peak of live steps (checkpoint states + bisection stack +
    /// leaf tape), in step units. The driver's byte-level meter agrees
    /// with this up to the `+1` state per materialized leaf.
    pub fn max_live_steps(&self) -> usize {
        let ckpts = self.boundaries.len() - 1;
        let seg_peak = self
            .boundaries
            .windows(2)
            .map(|w| span_live(w[1] - w[0], self.leaf_cap))
            .max()
            .unwrap_or(0);
        ckpts + seg_peak
    }

    /// Total forward steps integrated beyond the first pass (the
    /// recomputation cost of the plan, in steps).
    pub fn recompute_steps(&self) -> usize {
        if self.is_tape() {
            return 0;
        }
        self.boundaries.windows(2).map(|w| span_recompute(w[1] - w[0], self.leaf_cap)).sum()
    }
}

/// Segment edges `0, c, 2c, …, n` (last segment possibly shorter).
fn flat_boundaries(n: usize, c: usize) -> Vec<usize> {
    let mut b: Vec<usize> = (0..n).step_by(c).collect();
    b.push(n);
    b
}

/// Live steps while walking one span backward: a leaf holds its whole
/// tape; a bisected span holds the midpoint state while the right half
/// is processed, then releases it for the left half.
fn span_live(len: usize, cap: usize) -> usize {
    if len <= cap {
        len
    } else {
        let left = len / 2;
        let right = len - left;
        (1 + span_live(right, cap)).max(span_live(left, cap))
    }
}

/// Forward steps re-integrated while walking one span backward (the span
/// itself was already integrated once by the caller / first pass).
fn span_recompute(len: usize, cap: usize) -> usize {
    if len <= cap {
        len // one replay into the leaf tape
    } else {
        let left = len / 2;
        let right = len - left;
        // state-only lo→mid walk, then both halves recurse.
        left + span_recompute(right, cap) + span_recompute(left, cap)
    }
}

/// `⌈log₂ n⌉` for `n ≥ 1`.
fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tape_is_single_uncut_segment() {
        let s = Schedule::tape(1000);
        assert!(s.is_tape());
        assert_eq!(s.boundaries(), &[0, 1000]);
        assert_eq!(s.max_live_steps(), 1001);
        assert_eq!(s.recompute_steps(), 0);
    }

    #[test]
    fn sqrt_peak_scales_as_root_n() {
        for &n in &[16usize, 256, 4096, 65536] {
            let s = Schedule::sqrt(n);
            assert!(!s.is_tape());
            let root = (n as f64).sqrt();
            let peak = s.max_live_steps() as f64;
            assert!(peak <= 2.0 * root + 2.0, "n={n}: peak {peak} vs 2√n {}", 2.0 * root);
            // One extra forward pass, not more.
            assert_eq!(s.recompute_steps(), n);
        }
    }

    #[test]
    fn log_peak_scales_logarithmically() {
        for &n in &[64usize, 1024, 1 << 20] {
            let s = Schedule::log(n);
            let peak = s.max_live_steps();
            let bound = 2 * LOG_LEAF + ceil_log2(n) + 2;
            assert!(peak <= bound, "n={n}: peak {peak} > bound {bound}");
        }
    }

    #[test]
    fn budget_honored_when_feasible() {
        for &n in &[100usize, 1000, 100_000] {
            for &m in &[32usize, 64, 700, 2 * n] {
                let s = Schedule::budget(n, m);
                let need = ceil_log2(n) + 2;
                if m >= need {
                    assert!(
                        s.max_live_steps() <= m,
                        "n={n} m={m}: peak {} exceeds budget",
                        s.max_live_steps()
                    );
                }
            }
        }
    }

    #[test]
    fn budget_degenerate_extremes() {
        // budget=1: degrades to single-step leaves, still a valid plan.
        let s = Schedule::budget(64, 1);
        assert_eq!(s.leaf_cap(), 1);
        assert_eq!(s.boundaries(), &[0, 64]);
        // budget ≥ n+1: the full tape fits, zero recomputation.
        let s = Schedule::budget(64, 65);
        assert!(s.is_tape());
        // budget = n: flat segmentation under the cap.
        let s = Schedule::budget(64, 64);
        assert!(!s.is_tape());
        assert!(s.max_live_steps() <= 64);
    }

    #[test]
    fn boundaries_partition_the_grid() {
        for s in [
            Schedule::sqrt(1),
            Schedule::sqrt(7),
            Schedule::sqrt(1000),
            Schedule::log(37),
            Schedule::budget(123, 30),
        ] {
            let b = s.boundaries();
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), s.n_steps());
            assert!(b.windows(2).all(|w| w[1] > w[0]), "strictly ascending: {b:?}");
        }
    }

    #[test]
    fn preset_names_are_stable() {
        assert_eq!(Checkpointing::Tape.name(), "tape");
        assert_eq!(Checkpointing::Sqrt.name(), "sqrt");
        assert_eq!(Checkpointing::Log.name(), "log");
        assert_eq!(Checkpointing::Budget { max_live_steps: 9 }.name(), "budget");
        assert_eq!(Checkpointing::default(), Checkpointing::Tape);
    }
}
