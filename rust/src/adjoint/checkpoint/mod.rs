//! Constant-memory checkpointed backprop-through-the-solver.
//!
//! The classic taped backprop ([`super::backprop`]) stores the full
//! trajectory and every Brownian increment — O(steps) memory, which caps
//! the horizon long before the paper's 10⁶-step regime. This subsystem
//! removes the cap without changing a single output bit:
//!
//! * `schedule` — checkpoint plans over the fixed grid: the full
//!   [`Checkpointing::Tape`] (default, backward-compatible), the √n flat
//!   plan, recursive-bisection O(log n), and an explicit
//!   [`Checkpointing::Budget`] cap on live steps.
//! * `replay` — segment replay: any `[t_i, t_j]` span is
//!   re-integrated forward from its stored checkpoint, drawing noise
//!   from the original source. Replay is bit-identical to the first
//!   pass for *every* in-tree source: `BrownianPath` caches each
//!   queried time, [`crate::brownian::VirtualBrownianTree`] is a pure
//!   function of `(key, t)` (the paper's "memory-efficient algorithm
//!   for caching noise"), and mirroring is a deterministic negation —
//!   which is also why the taped family no longer rejects tree/mirror
//!   noise specs.
//! * `driver` — walks segments in reverse, materializes each
//!   leaf's local tape, runs the shared per-step VJP kernel, and chains
//!   the adjoint across boundaries in strictly descending step order —
//!   so gradients (including `grad_theta` accumulation order) are
//!   **exact-f64-identical** to the full tape for every scheme
//!   (EM/Milstein-Itô/Heun) and every budget.
//!
//! Select via [`crate::api::SensAlg::Backprop`]`{ method, checkpointing }`;
//! `Gradients.stats` reports the measured `peak_tape_bytes` and
//! `recompute_nfe` so the memory/recompute tradeoff is observable.

mod batch;
mod driver;
mod replay;
mod schedule;

pub use schedule::{Checkpointing, Schedule};

pub(crate) use batch::batch_checkpoint_backprop_core;
pub(crate) use driver::checkpointed_backprop_core;
