//! Gradient computation for SDE solutions (paper §3, Algorithm 2).
//!
//! Three estimators of `∂L(Z_T)/∂(z_0, θ)` at matched Brownian paths:
//!
//! * [`stochastic`] — **the paper's contribution**: the stochastic adjoint
//!   sensitivity method. Solves the augmented backward Stratonovich SDE
//!   over `(z, a_z, a_θ)` whose coefficients are vector-Jacobian products.
//!   O(1) memory (with a [`crate::brownian::VirtualBrownianTree`]) or
//!   O(L) (with a stored path), O(L) time.
//! * [`backprop`] — baseline: reverse-mode differentiation through the
//!   operations of the solver (Giles & Glasserman's "smoking adjoints").
//!   O(L) memory, O(L) time.
//! * [`pathwise`] — baseline: forward sensitivity analysis, propagating the
//!   full Jacobian `∂z_t/∂(z_0, θ)` alongside the state. O(1) memory in L
//!   but O(L·D) time (Jacobian rows are materialized from VJPs).
//!
//! [`reconstruct`] demonstrates the Figure 2 phenomenon: backward-in-time
//! simulation reconstructs the forward path only in Stratonovich form.

pub mod adaptive_grad;
pub mod antithetic;
pub mod augmented;
pub mod backprop;
pub mod pathwise;
pub mod reconstruct;
pub mod stochastic;

#[allow(deprecated)]
pub use adaptive_grad::adaptive_adjoint_gradients;
pub use adaptive_grad::{AdaptiveGradOutput, ChannelMappedBrownian};
#[allow(deprecated)]
pub use antithetic::antithetic_adjoint_gradients;
pub use antithetic::AntitheticOutput;
pub use augmented::AdjointOps;
#[allow(deprecated)]
pub use backprop::backprop_through_solver;
#[allow(deprecated)]
pub use pathwise::forward_pathwise_gradients;
#[allow(deprecated)]
pub use stochastic::{stochastic_adjoint_gradients, stochastic_adjoint_multi_obs};
pub use stochastic::{AdjointConfig, BackwardSolver, GradientOutput, NoiseMode};
