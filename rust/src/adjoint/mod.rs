//! Gradient computation for SDE solutions (paper §3, Algorithm 2).
//!
//! Three estimators of `∂L(Z_T)/∂(z_0, θ)` at matched Brownian paths:
//!
//! * [`stochastic`] — **the paper's contribution**: the stochastic adjoint
//!   sensitivity method. Solves the augmented backward Stratonovich SDE
//!   over `(z, a_z, a_θ)` whose coefficients are vector-Jacobian products.
//!   O(1) memory (with a [`crate::brownian::VirtualBrownianTree`]) or
//!   O(L) (with a stored path), O(L) time.
//! * [`backprop`] — baseline: reverse-mode differentiation through the
//!   operations of the solver (Giles & Glasserman's "smoking adjoints").
//!   O(L) memory, O(L) time.
//! * [`pathwise`] — baseline: forward sensitivity analysis, propagating the
//!   full Jacobian `∂z_t/∂(z_0, θ)` alongside the state. O(1) memory in L
//!   but O(L·D) time (Jacobian rows are materialized from VJPs).
//!
//! [`checkpoint`] removes the backprop tape's O(L) memory cap without
//! changing a bit: recursive checkpoint schedules (√n / log / explicit
//! budget) replay segments from stored states — noise replay is exact for
//! every in-tree source — and the backward walk is exact-f64-identical to
//! the full tape for every scheme and budget.
//!
//! [`reconstruct`] demonstrates the Figure 2 phenomenon: backward-in-time
//! simulation reconstructs the forward path only in Stratonovich form.
//!
//! [`batch`] lifts the stochastic adjoint to the batched SoA engine: B
//! augmented backward solves advance together in one `[B×(2d+p+1)]`
//! buffer, bit-identical per path to B scalar solves — this is what
//! [`crate::api::sensitivity_batch`] runs on.

pub mod adaptive_grad;
pub mod antithetic;
pub mod augmented;
pub mod backprop;
pub mod batch;
pub mod checkpoint;
pub mod pathwise;
pub mod reconstruct;
pub mod stochastic;

pub use adaptive_grad::{AdaptiveGradOutput, ChannelMappedBrownian};
pub use antithetic::AntitheticOutput;
pub use augmented::AdjointOps;
pub use batch::BatchAdjointOps;
pub use checkpoint::{Checkpointing, Schedule};
pub use stochastic::{AdjointConfig, BackwardSolver, GradientOutput, NoiseMode};
