//! Gradient convergence-order measurement (the §5 claim the paper's
//! adjoint rests on): how fast does `∂L/∂(z0, θ)` from each
//! [`SensAlg`] approach the closed-form pathwise gradient as the step
//! size shrinks?
//!
//! Noise handling is per estimator family:
//!
//! * **Adjoint family** (`StochasticAdjoint`, `Antithetic`) honors the
//!   problem's noise spec, so the runner pins a fine-tolerance virtual
//!   tree — the oracle and *every rung* then share one pure-function
//!   path, and the per-path error decays smoothly in `h` (this is what
//!   makes the acceptance criterion's monotone decrease measurable with
//!   few paths). The antithetic truth is the average of the closed-form
//!   gradient over the `(W, −W)` pair.
//! * **Taped family** (`Backprop`, `ForwardPathwise`) only supports its
//!   own stored path, so the runner replays that path query-for-query
//!   (same key, same ascending grid sweep) before handing it to the
//!   oracle. Rungs then realize different paths, but each rung's error is
//!   still measured against *its own* path's exact gradient.

use super::{bootstrap_order, DtLadder, ErrorAggregate, OrderEstimate, DEFAULT_TREE_TOL};
use crate::adjoint::stochastic::Noise;
use crate::api::solve::par_map;
use crate::api::{sensitivity_batch, NoiseSpec, ProblemError, SdeProblem, SensAlg, StepControl};
use crate::brownian::{BrownianMotion, BrownianPath, VirtualBrownianTree};
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;
use crate::sde::{BatchSdeVjp, ExactSolution, SdeVjp};
use crate::solvers::uniform_grid;

/// One rung of a gradient ladder.
#[derive(Clone, Copy, Debug)]
pub struct GradientRung {
    pub steps: usize,
    pub h: f64,
    /// Mean |component error| of `(∂L/∂z0, ∂L/∂θ)` vs the closed form,
    /// averaged over components and paths.
    pub mean_abs_err: f64,
}

/// Result of [`gradient_orders`].
#[derive(Clone, Debug)]
pub struct GradientLadderResult {
    /// [`SensAlg::name`] of the measured estimator.
    pub alg: &'static str,
    pub n_paths: usize,
    pub rungs: Vec<GradientRung>,
    pub fit: OrderEstimate,
    /// Per-path mean-abs errors, rung-major.
    pub per_path: Vec<Vec<f64>>,
}

impl GradientLadderResult {
    /// Mean error strictly decreases rung over rung (the acceptance
    /// criterion for the stochastic adjoint).
    pub fn monotone(&self) -> bool {
        self.rungs.windows(2).all(|w| w[1].mean_abs_err < w[0].mean_abs_err)
    }
}

fn truth_from<S>(
    sde: &S,
    span: (f64, f64),
    z0: &[f64],
    theta: &[f64],
    bm: &mut dyn BrownianMotion,
) -> (Vec<f64>, Vec<f64>)
where
    S: SdeVjp + ExactSolution + ?Sized,
{
    let mut gz0 = vec![0.0; sde.state_dim()];
    let mut gth = vec![0.0; sde.param_dim()];
    sde.exact_sum_gradients(span, z0, theta, bm, &mut gz0, &mut gth);
    (gz0, gth)
}

/// Closed-form gradient target for one path of `alg`. For the taped
/// family, `steps` is the rung's grid (replayed before the oracle reads
/// the path); the tree-backed adjoint family ignores it.
#[allow(clippy::too_many_arguments)]
fn gradient_truth<S>(
    sde: &S,
    span: (f64, f64),
    z0: &[f64],
    theta: &[f64],
    key: PrngKey,
    alg: &SensAlg,
    tol: f64,
    steps: usize,
) -> (Vec<f64>, Vec<f64>)
where
    S: SdeVjp + ExactSolution + ?Sized,
{
    let d = sde.state_dim();
    let (t0, t1) = span;
    match alg {
        SensAlg::StochasticAdjoint(_) => {
            let mut bm = VirtualBrownianTree::new(key, d, t0, t1, tol);
            truth_from(sde, span, z0, theta, &mut bm)
        }
        SensAlg::Antithetic { .. } => {
            // ½(g(W) + g(−W)): the estimator averages the pair, so its
            // target is the averaged closed form. The mirrored branch
            // reuses the estimator's own `Noise` wrapper, so the truth
            // mirrors exactly as the minus-branch solve does.
            let plus = {
                let mut bm = VirtualBrownianTree::new(key, d, t0, t1, tol);
                truth_from(sde, span, z0, theta, &mut bm)
            };
            let minus = {
                let spec = NoiseSpec::VirtualTree { tol };
                let mut bm = Noise::new(spec, key, d, t0, t1, true);
                truth_from(sde, span, z0, theta, &mut bm)
            };
            let avg = |a: &[f64], b: &[f64]| -> Vec<f64> {
                a.iter().zip(b).map(|(x, y)| 0.5 * (x + y)).collect()
            };
            (avg(&plus.0, &minus.0), avg(&plus.1, &minus.1))
        }
        SensAlg::Backprop { .. } | SensAlg::ForwardPathwise => {
            // Replay the engine's taped path exactly: same key, same
            // ascending sweep over the rung's grid, before any oracle
            // query touches the source.
            let mut bm = BrownianPath::new(key, d, t0, t1);
            let mut scratch = vec![0.0; d];
            for &t in &uniform_grid(t0, t1, steps) {
                bm.sample_into(t, &mut scratch);
            }
            truth_from(sde, span, z0, theta, &mut bm)
        }
    }
}

/// Measure the empirical convergence order of `alg`'s gradient on `prob`
/// over `ladder`, against the [`ExactSolution`] closed form, with a
/// paired bootstrap CI (`n_boot` resamples). The problem's key is the
/// root: path `i` (including path 0) uses `key.fold_in(i)`, exactly as
/// [`SdeProblem::replicates`] derives batch keys.
pub fn gradient_orders<S>(
    prob: &SdeProblem<'_, S>,
    alg: &SensAlg,
    ladder: &DtLadder,
    n_paths: usize,
    n_boot: usize,
) -> Result<GradientLadderResult, ProblemError>
where
    S: BatchSdeVjp + ExactSolution + Sync + ?Sized,
{
    assert!(n_paths > 0, "gradient_orders: need at least one path");
    let (t0, t1) = prob.span();
    assert!(t1 > t0, "gradient_orders: ladder needs an ascending horizon");
    let d = prob.dim();
    let p = prob.sde().param_dim();
    let tol = match prob.noise_spec() {
        NoiseSpec::VirtualTree { tol } => tol,
        NoiseSpec::StoredPath => DEFAULT_TREE_TOL,
    };
    // Adjoint family: pin the tree so oracle + all rungs share one path.
    // Taped family: pin the stored path (the estimator would honor a tree
    // spec too, but the oracle replays the realized path per rung).
    let spec = match alg {
        SensAlg::StochasticAdjoint(_) | SensAlg::Antithetic { .. } => {
            NoiseSpec::VirtualTree { tol }
        }
        SensAlg::Backprop { .. } | SensAlg::ForwardPathwise => NoiseSpec::StoredPath,
    };
    let base = prob.clone().noise(spec).mirror(false);
    let probs = base.replicates(base.prng_key(), n_paths);

    let sde = prob.sde();
    let z0 = prob.initial_state();
    let theta = prob.theta();
    let span = (t0, t1);
    let tree_truth = matches!(
        alg,
        SensAlg::StochasticAdjoint(_) | SensAlg::Antithetic { .. }
    );
    // Rung-independent truths (tree family) are computed once up front.
    let shared_truth = tree_truth.then(|| {
        par_map(n_paths, |i| {
            gradient_truth(sde, span, z0, theta, probs[i].prng_key(), alg, tol, 0)
        })
    });

    let hs = ladder.step_sizes(span);
    let mut rungs = Vec::with_capacity(ladder.rungs);
    let mut per_path: Vec<Vec<f64>> = Vec::with_capacity(ladder.rungs);
    for (r, &steps) in ladder.step_counts().iter().enumerate() {
        let grads =
            sensitivity_batch(&probs, alg, StepControl::Steps(steps), ExecConfig::default());
        let mut errs = Vec::with_capacity(n_paths);
        for (i, g) in grads.into_iter().enumerate() {
            let g = g?;
            let owned;
            let (gz0, gth) = match &shared_truth {
                Some(t) => (&t[i].0, &t[i].1),
                None => {
                    owned = gradient_truth(
                        sde,
                        span,
                        z0,
                        theta,
                        probs[i].prng_key(),
                        alg,
                        tol,
                        steps,
                    );
                    (&owned.0, &owned.1)
                }
            };
            let mut sum = 0.0;
            for (a, b) in g.dz0.iter().zip(gz0.iter()) {
                sum += (a - b).abs();
            }
            for (a, b) in g.dtheta.iter().zip(gth.iter()) {
                sum += (a - b).abs();
            }
            errs.push(sum / (d + p) as f64);
        }
        rungs.push(GradientRung {
            steps,
            h: hs[r],
            mean_abs_err: ErrorAggregate::MeanAbs.apply(errs.iter().copied()),
        });
        per_path.push(errs);
    }

    let fit = bootstrap_order(
        &hs,
        &per_path,
        ErrorAggregate::MeanAbs,
        n_boot,
        base.prng_key().fold_in(0x6AD),
    );
    Ok(GradientLadderResult { alg: alg.name(), n_paths, rungs, fit, per_path })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::AdjointConfig;
    use crate::sde::problems::Example1;
    use crate::sde::ReplicatedSde;
    use crate::solvers::Method;

    /// Small-scale smoke: the adjoint's gradient error on GBM decreases
    /// monotonically over a shared-path ladder. Full statistical pins
    /// live in tests/convergence.rs.
    #[test]
    fn adjoint_gbm_gradient_ladder_smoke() {
        let sde = ReplicatedSde::new(Example1, 2);
        let theta = [0.4, 0.5, 0.6, 0.3];
        let z0 = [1.0, 0.8];
        let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
            .params(&theta)
            .key(PrngKey::from_seed(321));
        let ladder = DtLadder::new(32, 4);
        let res = gradient_orders(
            &prob,
            &SensAlg::StochasticAdjoint(AdjointConfig::default()),
            &ladder,
            12,
            100,
        )
        .expect("adjoint-compatible problem");
        assert_eq!(res.alg, "StochasticAdjoint");
        assert!(res.monotone(), "rungs: {:?}", res.rungs);
        assert!(
            res.fit.order > 0.5,
            "order {} (CI [{}, {}])",
            res.fit.order,
            res.fit.ci_lo,
            res.fit.ci_hi
        );
    }

    /// The taped family replays its stored path for the oracle: the
    /// backprop-through-Milstein gradient must converge against the
    /// replayed path's closed form.
    #[test]
    fn backprop_milstein_gbm_gradient_ladder_smoke() {
        let sde = ReplicatedSde::new(Example1, 2);
        let theta = [0.4, 0.5, 0.6, 0.3];
        let z0 = [1.0, 0.8];
        let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
            .params(&theta)
            .key(PrngKey::from_seed(654));
        let ladder = DtLadder::new(32, 3);
        let res = gradient_orders(
            &prob,
            &SensAlg::backprop(Method::MilsteinIto),
            &ladder,
            16,
            50,
        )
        .unwrap();
        // Independent paths per rung: no monotonicity guarantee, but the
        // fitted order must be clearly positive.
        assert!(res.fit.order > 0.4, "order {}", res.fit.order);
        assert!(res.rungs.iter().all(|r| r.mean_abs_err > 0.0));
    }

    /// A virtual-tree problem spec on the input is fine for every family:
    /// the ladder re-pins the spec per family before running.
    #[test]
    fn taped_family_cannot_honor_tree_spec_is_handled() {
        // gradient_orders resets the spec per family (tree for the
        // adjoint, stored path for the taped baselines, which replay it
        // for the oracle), so both families succeed even when the input
        // problem asks for a tree.
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.4, 0.5];
        let z0 = [1.0];
        let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
            .params(&theta)
            .key(PrngKey::from_seed(9))
            .noise(NoiseSpec::VirtualTree { tol: 1e-10 });
        let ladder = DtLadder::new(16, 2);
        assert!(gradient_orders(
            &prob,
            &SensAlg::backprop(Method::EulerMaruyama),
            &ladder,
            4,
            20,
        )
        .is_ok());
    }
}
