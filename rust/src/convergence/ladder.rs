//! Strong/weak order measurement over a dt ladder.
//!
//! Drives [`crate::api::SdeProblem::solve`] via [`crate::api::solve_batch`]
//! across a halving grid of step sizes and compares every rung against the
//! [`ExactSolution`] oracle evaluated on the *same* realized Brownian
//! path. See the module docs of [`crate::convergence`] for the coupling
//! argument.

use super::{bootstrap_order, DtLadder, ErrorAggregate, OrderEstimate, DEFAULT_TREE_TOL};
use crate::api::solve::par_map;
use crate::api::{solve_batch, NoiseSpec, SdeProblem, SolveOptions};
use crate::brownian::VirtualBrownianTree;
use crate::sde::{BatchSde, ExactSolution};
use crate::solvers::Method;

/// One rung of a measured ladder.
#[derive(Clone, Copy, Debug)]
pub struct RungMeasurement {
    /// Solver steps across the horizon.
    pub steps: usize,
    /// Step size `|t1 − t0| / steps`.
    pub h: f64,
    /// Strong error `E‖X_T^num − X_T^exact‖`: per-path RMS over
    /// dimensions, averaged across paths. (The path-mean is markedly
    /// less noisy than a cross-path RMS under GBM's lognormal error
    /// tails, at the same convergence order.)
    pub strong: f64,
    /// |mean coupled difference| averaged over dimensions (weak, first
    /// moment).
    pub weak: f64,
}

/// Result of [`strong_weak_orders`].
#[derive(Clone, Debug)]
pub struct StrongWeakResult {
    pub method: Method,
    pub n_paths: usize,
    pub rungs: Vec<RungMeasurement>,
    pub strong_fit: OrderEstimate,
    pub weak_fit: OrderEstimate,
    /// Per-path strong errors, rung-major (for external re-analysis).
    pub strong_per_path: Vec<Vec<f64>>,
    /// Per-path signed mean differences, rung-major.
    pub weak_per_path: Vec<Vec<f64>>,
}

impl StrongWeakResult {
    /// Strong errors strictly decrease rung over rung.
    pub fn strong_monotone(&self) -> bool {
        self.rungs.windows(2).all(|w| w[1].strong < w[0].strong)
    }
}

/// Measure empirical strong and weak orders of `method` on `prob` over
/// `ladder`, using `n_paths` independent Brownian paths and a
/// paired bootstrap with `n_boot` resamples for the CIs.
///
/// The problem's noise spec is overridden with a fine-tolerance
/// [`NoiseSpec::VirtualTree`] (keeping the tolerance if the problem
/// already specifies a tree): the tree realizes the path as a pure
/// function of `(key, t)`, which is what lets every rung *and* the oracle
/// share one path. The problem's key is the root: path `i` (including
/// path 0) uses `key.fold_in(i)`, exactly as
/// [`SdeProblem::replicates`] derives batch keys.
pub fn strong_weak_orders<S>(
    prob: &SdeProblem<'_, S>,
    method: Method,
    ladder: &DtLadder,
    n_paths: usize,
    n_boot: usize,
) -> StrongWeakResult
where
    S: BatchSde + ExactSolution + Sync + ?Sized,
{
    strong_weak_orders_multi(prob, &[method], ladder, n_paths, n_boot)
        .pop()
        .expect("one method in, one result out")
}

/// [`strong_weak_orders`] for several schemes at once, sharing one oracle
/// pass: the exact solution is method-independent, and for
/// quadrature-based oracles (OU) reconstructing it dominates the cost of
/// the solves. Results are in `methods` order.
pub fn strong_weak_orders_multi<S>(
    prob: &SdeProblem<'_, S>,
    methods: &[Method],
    ladder: &DtLadder,
    n_paths: usize,
    n_boot: usize,
) -> Vec<StrongWeakResult>
where
    S: BatchSde + ExactSolution + Sync + ?Sized,
{
    assert!(n_paths > 0, "strong_weak_orders: need at least one path");
    let (t0, t1) = prob.span();
    assert!(t1 > t0, "strong_weak_orders: ladder needs an ascending horizon");
    let d = prob.dim();
    let tol = match prob.noise_spec() {
        NoiseSpec::VirtualTree { tol } => tol,
        NoiseSpec::StoredPath => DEFAULT_TREE_TOL,
    };
    let base = prob.clone().noise(NoiseSpec::VirtualTree { tol }).mirror(false);
    let probs = base.replicates(base.prng_key(), n_paths);

    // Oracle pass: the exact terminal state per path, computed once for
    // all methods — the tree is order-independent, so a fresh instance
    // with the same key replays the identical path the solver rungs will
    // consume.
    let sde = prob.sde();
    let z0 = prob.initial_state();
    let theta = prob.theta();
    let exact: Vec<Vec<f64>> = par_map(n_paths, |i| {
        let mut bm = VirtualBrownianTree::new(probs[i].prng_key(), d, t0, t1, tol);
        let mut x = vec![0.0; d];
        sde.exact_state((t0, t1), z0, theta, &mut bm, &mut x);
        x
    });

    let hs = ladder.step_sizes((t0, t1));
    let mut results = Vec::with_capacity(methods.len());
    for &method in methods {
        let mut rungs = Vec::with_capacity(ladder.rungs);
        let mut strong_per_path: Vec<Vec<f64>> = Vec::with_capacity(ladder.rungs);
        let mut weak_per_path: Vec<Vec<f64>> = Vec::with_capacity(ladder.rungs);
        for (r, &steps) in ladder.step_counts().iter().enumerate() {
            let sols = solve_batch(&probs, &SolveOptions::fixed(method, steps));
            let mut strong = Vec::with_capacity(n_paths);
            let mut weak = Vec::with_capacity(n_paths);
            for (sol, ex) in sols.iter().zip(&exact) {
                let num = sol.final_state();
                let mut sq = 0.0;
                let mut signed = 0.0;
                for (a, b) in num.iter().zip(ex) {
                    let diff = a - b;
                    sq += diff * diff;
                    signed += diff;
                }
                strong.push((sq / d as f64).sqrt());
                weak.push(signed / d as f64);
            }
            rungs.push(RungMeasurement {
                steps,
                h: hs[r],
                strong: ErrorAggregate::MeanAbs.apply(strong.iter().copied()),
                weak: ErrorAggregate::AbsMean.apply(weak.iter().copied()),
            });
            strong_per_path.push(strong);
            weak_per_path.push(weak);
        }

        let boot_key = base.prng_key().fold_in(0xC0DA);
        let strong_fit =
            bootstrap_order(&hs, &strong_per_path, ErrorAggregate::MeanAbs, n_boot, boot_key);
        let weak_fit = bootstrap_order(
            &hs,
            &weak_per_path,
            ErrorAggregate::AbsMean,
            n_boot,
            boot_key.fold_in(1),
        );
        results.push(StrongWeakResult {
            method,
            n_paths,
            rungs,
            strong_fit,
            weak_fit,
            strong_per_path,
            weak_per_path,
        });
    }
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::PrngKey;
    use crate::sde::problems::Example1;
    use crate::sde::ReplicatedSde;

    /// Smoke test at small scale: errors are positive, rungs coupled
    /// (strong error strictly decreasing on GBM with a shared path), and
    /// the fitted Milstein order is near 1. The full statistical pins
    /// live in tests/convergence.rs.
    #[test]
    fn milstein_gbm_ladder_smoke() {
        let sde = ReplicatedSde::new(Example1, 1);
        let theta = [0.4, 0.5];
        let z0 = [1.0];
        let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
            .params(&theta)
            .key(PrngKey::from_seed(1234));
        let ladder = DtLadder::new(16, 4);
        let res = strong_weak_orders(&prob, Method::MilsteinIto, &ladder, 48, 100);
        assert_eq!(res.rungs.len(), 4);
        assert!(res.rungs.iter().all(|r| r.strong > 0.0));
        assert!(res.strong_monotone(), "rungs: {:?}", res.rungs);
        assert!(
            (res.strong_fit.order - 1.0).abs() < 0.35,
            "strong order {} (CI [{}, {}])",
            res.strong_fit.order,
            res.strong_fit.ci_lo,
            res.strong_fit.ci_hi
        );
        assert!(res.strong_fit.ci_lo <= res.strong_fit.order);
        assert!(res.strong_fit.ci_hi >= res.strong_fit.order);
    }
}
