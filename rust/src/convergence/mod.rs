//! Empirical convergence-order verification (paper §5).
//!
//! The paper's numerical claim is that solutions — and, through the
//! stochastic adjoint, *gradients* — of the discretized SDE converge to
//! the true ones as the step size shrinks, at the scheme's strong order.
//! Elsewhere in this crate that claim lives in unverified constants
//! ([`crate::solvers::Method::strong_order`]) and in loose
//! "error-shrinks" assertions; this subsystem *measures* the orders
//! against analytic oracles and attaches bootstrap confidence intervals,
//! so every future performance PR has a statistical safety net.
//!
//! ## How an order is measured
//!
//! 1. **Shared path.** A problem is replicated over `n_paths` Brownian
//!    paths (one [`crate::prng::PrngKey`] per path). Each path is realized
//!    by a [`crate::brownian::VirtualBrownianTree`], whose value at a time
//!    is a *pure function* of `(key, t)` — so every rung of a step-size
//!    ladder, and the analytic oracle, consume literally the same sample
//!    path. (Estimators that tape their own stored path instead have the
//!    path replayed query-for-query before the oracle reads it.)
//! 2. **dt ladder.** [`DtLadder`] halves the step size rung by rung
//!    (power-of-two step counts, so rung grids are nested bit-exactly and
//!    dyadic queries terminate in the tree without tolerance error).
//! 3. **Errors.** Per rung: the strong error (per-path RMS of
//!    `X^num_T − X^exact_T` over dimensions, averaged across paths), the
//!    weak error (|mean of the coupled difference| — the coupling makes
//!    the Monte-Carlo noise scale with the *strong* error instead of the
//!    solution's standard deviation),
//!    and the gradient error (mean |∂L^num − ∂L^exact| over components)
//!    for any [`crate::api::SensAlg`]. Oracles implement
//!    [`crate::sde::ExactSolution`].
//! 4. **Fit.** The empirical order is the slope of a log-log least-squares
//!    fit ([`crate::metrics::fit_loglog`]); its 95% confidence interval
//!    comes from a paired bootstrap over paths (resampling whole paths
//!    keeps the across-rung coupling intact).
//!
//! Entry points: [`strong_weak_orders`] and [`gradient_orders`]; the
//! `sdegrad repro convergence` harness
//! ([`crate::coordinator::repro::convergence`]) prints the full table and
//! CSVs, and `tests/convergence.rs` pins the measured orders against the
//! nominal ones with seeded tolerances.

pub mod gradient;
pub mod ladder;

pub use gradient::{gradient_orders, GradientLadderResult, GradientRung};
pub use ladder::{
    strong_weak_orders, strong_weak_orders_multi, RungMeasurement, StrongWeakResult,
};

use crate::metrics::{fit_loglog, percentile_of_sorted};
use crate::prng::PrngKey;

/// Tree tolerance used when a problem does not already specify one. Fine
/// enough that non-dyadic queries carry negligible time-jitter; dyadic
/// queries (the normal case: power-of-two ladders on unit horizons)
/// terminate exactly regardless.
pub const DEFAULT_TREE_TOL: f64 = 1e-12;

/// A halving ladder of step counts: `base_steps · 2^r` for
/// `r = 0..rungs`. Power-of-two counts keep rung grids nested
/// bit-exactly (see module docs).
#[derive(Clone, Copy, Debug)]
pub struct DtLadder {
    /// Step count of the coarsest rung.
    pub base_steps: usize,
    /// Number of rungs (≥ 2 to fit a slope; ≥ 4 for the acceptance
    /// criteria of the statistical suite).
    pub rungs: usize,
}

impl DtLadder {
    pub fn new(base_steps: usize, rungs: usize) -> Self {
        assert!(base_steps > 0, "DtLadder: base_steps must be positive");
        assert!(rungs >= 2, "DtLadder: need at least two rungs to fit an order");
        DtLadder { base_steps, rungs }
    }

    /// Step counts, coarse to fine.
    pub fn step_counts(&self) -> Vec<usize> {
        (0..self.rungs).map(|r| self.base_steps << r).collect()
    }

    /// Step sizes `|t1 − t0| / n`, coarse to fine.
    pub fn step_sizes(&self, span: (f64, f64)) -> Vec<f64> {
        let tt = (span.1 - span.0).abs();
        self.step_counts().iter().map(|&n| tt / n as f64).collect()
    }
}

/// How per-path error samples are aggregated into one rung-level error.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorAggregate {
    /// `sqrt(mean(e²))` — quadratic-mean aggregation. Available for
    /// re-analysis, but *not* what the strong ladders use: under GBM's
    /// lognormal error tails the cross-path RMS is ~2× noisier than the
    /// path-mean at the same convergence order.
    Rms,
    /// `mean(|e|)` — strong errors (each sample is already a per-path
    /// RMS over dimensions) and gradient errors (Fig 5's convention).
    MeanAbs,
    /// `|mean(e)|` of *signed* samples — weak (moment) errors.
    AbsMean,
}

impl ErrorAggregate {
    fn apply(&self, vals: impl Iterator<Item = f64>) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        match self {
            ErrorAggregate::Rms => {
                for v in vals {
                    sum += v * v;
                    n += 1;
                }
                (sum / n.max(1) as f64).sqrt()
            }
            ErrorAggregate::MeanAbs => {
                for v in vals {
                    sum += v.abs();
                    n += 1;
                }
                sum / n.max(1) as f64
            }
            ErrorAggregate::AbsMean => {
                for v in vals {
                    sum += v;
                    n += 1;
                }
                (sum / n.max(1) as f64).abs()
            }
        }
    }
}

/// An empirically fitted convergence order with a bootstrap 95% CI.
#[derive(Clone, Copy, Debug)]
pub struct OrderEstimate {
    /// Point estimate (log-log slope over the full path sample).
    pub order: f64,
    /// Fitted `ln C` of `error ≈ C·h^order`.
    pub intercept: f64,
    /// 2.5% / 97.5% bootstrap percentiles of the slope.
    pub ci_lo: f64,
    pub ci_hi: f64,
    /// Bootstrap resamples that produced a usable fit.
    pub n_boot: usize,
}

/// Fit an order from per-path errors and attach a paired-bootstrap CI.
///
/// `per_path[r][i]` is path `i`'s error sample at rung `r` (`hs[r]` its
/// step size). The bootstrap resamples *path indices* — the same resample
/// is applied to every rung, preserving the shared-path coupling that
/// makes the rung errors comparable in the first place. Deterministic in
/// `key`.
pub fn bootstrap_order(
    hs: &[f64],
    per_path: &[Vec<f64>],
    agg: ErrorAggregate,
    n_boot: usize,
    key: PrngKey,
) -> OrderEstimate {
    assert_eq!(hs.len(), per_path.len(), "bootstrap_order: rung count mismatch");
    let n_paths = per_path.first().map_or(0, |v| v.len());
    assert!(n_paths > 0, "bootstrap_order: need at least one path");
    assert!(per_path.iter().all(|v| v.len() == n_paths), "bootstrap_order: ragged samples");

    let point: Vec<f64> = per_path.iter().map(|v| agg.apply(v.iter().copied())).collect();
    let fit = fit_loglog(hs, &point);

    let mut slopes = Vec::with_capacity(n_boot);
    let mut idx = vec![0usize; n_paths];
    let mut errs = vec![0.0; hs.len()];
    for b in 0..n_boot {
        let kb = key.fold_in(b as u64);
        for (j, slot) in idx.iter_mut().enumerate() {
            *slot = ((kb.uniform(j as u64) * n_paths as f64) as usize).min(n_paths - 1);
        }
        for (r, rung) in per_path.iter().enumerate() {
            errs[r] = agg.apply(idx.iter().map(|&i| rung[i]));
        }
        let f = fit_loglog(hs, &errs);
        if f.slope.is_finite() {
            slopes.push(f.slope);
        }
    }
    let (ci_lo, ci_hi) = if slopes.is_empty() {
        (f64::NAN, f64::NAN)
    } else {
        slopes.sort_by(|a, b| a.total_cmp(b));
        (percentile_of_sorted(&slopes, 0.025), percentile_of_sorted(&slopes, 0.975))
    };
    OrderEstimate {
        order: fit.slope,
        intercept: fit.intercept,
        ci_lo,
        ci_hi,
        n_boot: slopes.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_counts_and_sizes() {
        let l = DtLadder::new(16, 4);
        assert_eq!(l.step_counts(), vec![16, 32, 64, 128]);
        let hs = l.step_sizes((0.0, 1.0));
        assert_eq!(hs, vec![1.0 / 16.0, 1.0 / 32.0, 1.0 / 64.0, 1.0 / 128.0]);
    }

    #[test]
    fn aggregates() {
        let v = [3.0, -4.0];
        assert!((ErrorAggregate::Rms.apply(v.iter().copied()) - (12.5f64).sqrt()).abs() < 1e-12);
        assert!((ErrorAggregate::MeanAbs.apply(v.iter().copied()) - 3.5).abs() < 1e-12);
        assert!((ErrorAggregate::AbsMean.apply(v.iter().copied()) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_recovers_noiseless_order() {
        // Per-path errors exactly C_i · h^0.8: slope is 0.8 for every
        // resample, so the CI collapses onto the point estimate.
        let hs = [0.1, 0.05, 0.025];
        let paths = 20;
        let per_path: Vec<Vec<f64>> = hs
            .iter()
            .map(|h| (0..paths).map(|i| (1.0 + i as f64) * h.powf(0.8)).collect())
            .collect();
        let est = bootstrap_order(
            &hs,
            &per_path,
            ErrorAggregate::MeanAbs,
            200,
            PrngKey::from_seed(1),
        );
        assert!((est.order - 0.8).abs() < 1e-10, "order {}", est.order);
        assert!((est.ci_lo - 0.8).abs() < 1e-10 && (est.ci_hi - 0.8).abs() < 1e-10);
        assert_eq!(est.n_boot, 200);
    }

    #[test]
    fn bootstrap_ci_brackets_point_estimate_under_noise() {
        // Heterogeneous constants across paths → nondegenerate CI that
        // still brackets the point estimate.
        let hs = [0.2, 0.1, 0.05, 0.025];
        let key = PrngKey::from_seed(9);
        let paths = 40;
        let per_path: Vec<Vec<f64>> = hs
            .iter()
            .enumerate()
            .map(|(r, h)| {
                (0..paths)
                    .map(|i| {
                        let c = 0.5 + key.uniform((r * paths + i) as u64);
                        c * h
                    })
                    .collect()
            })
            .collect();
        let est =
            bootstrap_order(&hs, &per_path, ErrorAggregate::Rms, 300, PrngKey::from_seed(2));
        assert!(est.ci_lo <= est.order && est.order <= est.ci_hi, "{est:?}");
        assert!(est.ci_hi > est.ci_lo, "CI should have positive width: {est:?}");
        assert!((est.order - 1.0).abs() < 0.2, "order {}", est.order);
    }
}
