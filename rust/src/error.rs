//! Minimal error type for the I/O-facing subsystems (checkpoints, artifact
//! registry, CLI). A string-backed error with `anyhow`-style ergonomics —
//! `err!`, `bail!`, and `.context()` — so those modules stay readable
//! without pulling an external crate into the hermetic build.

use std::fmt;

/// A string-backed error, compatible with `{e}` and `{e:#}` formatting.
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Self {
        Error(msg.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error(s.to_string())
    }
}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::err!($($arg)*));
        }
    };
}

/// `anyhow::Context`-style annotation for `Result`s and `Option`s.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_annotates() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("opening file").unwrap_err();
        let s = format!("{e}");
        assert!(s.contains("opening file"), "{s}");
        assert!(s.contains("missing"), "{s}");
    }

    #[test]
    fn macros_build_errors() {
        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable {}", 1);
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        let e = inner(false).unwrap_err();
        assert!(format!("{e:#}").contains("flag was false"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("empty").is_err());
    }
}
