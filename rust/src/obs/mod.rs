//! Crate-wide observability: spans, a metrics registry, and exporters.
//!
//! This module is the single instrumentation substrate for the whole
//! crate — solver step loops, checkpointed adjoints, the latent-SDE
//! trainer, the work-stealing pool, and the serving plane all report
//! through it. It is std-only and integer-only: **instrumentation never
//! touches the `f64` path**, so every bit-identical/byte-identical pin
//! (batch engine, checkpoint replay, serve oracle bytes) holds with
//! tracing on or off. That determinism contract is pinned by
//! `tests/obs.rs`.
//!
//! Three pieces:
//!
//! * **Spans** ([`span!`] / [`SpanGuard`]) — hierarchical RAII timing
//!   regions with per-thread stacks and a monotonic clock, gated by a
//!   process-wide enable flag ([`set_enabled`]). The disabled path (the
//!   default) is one relaxed atomic load + branch per span site.
//! * **Registry** ([`counter`] / [`gauge`] / [`hist`]) — named monotone
//!   counters, gauges, and power-of-two histograms over relaxed atomics.
//!   Always on; absorbs the crate's former one-off statics (e.g. the
//!   Brownian-tree bridge-call counter, the pool spawn counter).
//! * **Exporters** — Chrome trace-event JSON for spans
//!   ([`export::write_chrome_trace`], the `--trace-out` CLI flag, loads
//!   in `chrome://tracing`/Perfetto) and a strict-JSON registry dump
//!   ([`dump_json`]) merged into serve's `GET /metrics`.
//!
//! Usage:
//!
//! ```
//! sdegrad::obs::set_enabled(true);
//! {
//!     let _span = sdegrad::obs::span!("example.phase");
//!     // ... timed work ...
//! }
//! let trace = sdegrad::obs::export::chrome_trace_json();
//! assert!(trace.contains("example.phase"));
//! sdegrad::obs::set_enabled(false);
//! ```

pub mod export;
pub mod hist;
pub mod registry;
pub mod span;

pub use hist::{bucket_index, bucket_lower_bound, Hist, BUCKETS};
pub use registry::{
    counter, dump_json, gauge, hist, snapshot, Counter, Gauge, HistHandle, MetricValue,
};
pub use span::{clear_events, drain_events, Event, SpanGuard};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span collection enabled? One relaxed load — this is the entire
/// disabled-path cost of a span site (plus a branch).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span collection on or off process-wide. Registry metrics are
/// unaffected (always on). Toggling mid-span is safe: a guard records
/// its end event iff it recorded its begin.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Enter a named span; evaluates to a [`SpanGuard`] that must be bound
/// (`let _span = obs::span!("adjoint.backward");`). The span closes when
/// the guard drops. Names should be `&'static str` literals in
/// `subsystem.phase` form.
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        $crate::obs::SpanGuard::enter($name)
    };
}

pub use crate::obs_span as span;
