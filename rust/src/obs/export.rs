//! Exporters: Chrome trace-event JSON for spans, registry dump for
//! metrics.
//!
//! [`chrome_trace_json`] drains the collected span events and renders
//! them in the Chrome trace-event format — an object with a
//! `"traceEvents"` array of `ph:"B"` / `ph:"E"` duration events carrying
//! `name`, `ts` (µs since the trace epoch), `pid`, and `tid`. The file
//! written by [`write_chrome_trace`] (the `--trace-out` CLI flag) loads
//! directly in `chrome://tracing` or [Perfetto](https://ui.perfetto.dev).
//! Output is emitted through the same strict-JSON grammar the rest of the
//! crate uses (`metrics::json`), and `tests/obs.rs` pins that it parses
//! back under `metrics::json::parse_json` with well-nested begin/end
//! pairs per thread.
//!
//! The registry exporter is [`crate::obs::dump_json`], merged into the
//! serving plane's `GET /metrics` response under the `"registry"` key.

use std::io;
use std::path::Path;

use super::span::{drain_events, Event};
use crate::metrics::json::json_str;

fn push_event(out: &mut String, ev: &Event) {
    let ph = if ev.begin { "B" } else { "E" };
    out.push_str(&format!(
        "{{\"name\":{},\"cat\":\"sdegrad\",\"ph\":\"{}\",\"ts\":{},\"pid\":1,\"tid\":{}}}",
        json_str(ev.name),
        ph,
        ev.ts_us,
        ev.tid
    ));
}

/// Render a slice of events as Chrome trace-event JSON (does not drain).
pub fn chrome_trace_from(events: &[Event]) -> String {
    let mut out = String::from("{\"traceEvents\":[");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_event(&mut out, ev);
    }
    out.push_str("],\"displayTimeUnit\":\"ms\"}");
    out
}

/// Drain all completed span events and render them as Chrome trace-event
/// JSON.
pub fn chrome_trace_json() -> String {
    chrome_trace_from(&drain_events())
}

/// Drain all completed span events and write the Chrome trace JSON to
/// `path` (the `--trace-out` target).
pub fn write_chrome_trace(path: &Path) -> io::Result<()> {
    std::fs::write(path, chrome_trace_json())
}
