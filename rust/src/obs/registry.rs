//! Central registry of named counters, gauges, and histograms.
//!
//! Every metric is registered by a `&'static str` name on first use and
//! lives for the process. Handles ([`Counter`], [`Gauge`], [`HistHandle`])
//! are cheap `Arc` clones over relaxed atomics; hot call sites cache one
//! in a `OnceLock` so the registry lock is taken once per site, not per
//! event. Unlike spans, registry metrics are always on — they are plain
//! integer atomics on paths that already pay far more per call, and the
//! serving plane reports them unconditionally.
//!
//! Naming convention: `subsystem.metric` (e.g. `brownian.bridge_calls`,
//! `runtime.pool.steals`, `serve.queue_wait_us`). [`dump_json`] renders
//! the whole registry as one strict-JSON object (sorted by name) for
//! `GET /metrics` and offline inspection; histogram buckets use the
//! power-of-two layout documented in [`crate::obs::hist`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use super::hist::{Hist, BUCKETS};
use crate::metrics::json::json_str;

/// Handle to a named monotone counter.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add `n` (relaxed; skips the atomic when `n == 0`).
    #[inline]
    pub fn add(&self, n: u64) {
        if n != 0 {
            self.0.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Add 1.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Current value (relaxed).
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a named gauge (last-write-wins instantaneous value).
#[derive(Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Overwrite the gauge (relaxed).
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to at least `v` (relaxed `fetch_max`).
    #[inline]
    pub fn raise(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    /// Current value (relaxed).
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Handle to a named power-of-two histogram.
#[derive(Clone)]
pub struct HistHandle(Arc<Hist>);

impl HistHandle {
    /// Record one observation.
    #[inline]
    pub fn record(&self, v: u64) {
        self.0.record(v);
    }

    /// Snapshot of every bucket.
    pub fn counts(&self) -> [u64; BUCKETS] {
        self.0.counts()
    }

    /// Total observations recorded.
    pub fn total(&self) -> u64 {
        self.0.total()
    }
}

enum Slot {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Hist(Arc<Hist>),
}

fn registry() -> MutexGuard<'static, BTreeMap<&'static str, Slot>> {
    static REGISTRY: OnceLock<Mutex<BTreeMap<&'static str, Slot>>> = OnceLock::new();
    match REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// Get or register the counter named `name`.
///
/// Panics if `name` is already registered as a different metric kind —
/// names are a process-wide namespace and a kind clash is a bug.
pub fn counter(name: &'static str) -> Counter {
    let mut reg = registry();
    match reg
        .entry(name)
        .or_insert_with(|| Slot::Counter(Arc::new(AtomicU64::new(0))))
    {
        Slot::Counter(c) => Counter(Arc::clone(c)),
        _ => panic!("metric `{name}` is already registered with a different kind"),
    }
}

/// Get or register the gauge named `name` (same kind-clash rule as
/// [`counter`]).
pub fn gauge(name: &'static str) -> Gauge {
    let mut reg = registry();
    match reg
        .entry(name)
        .or_insert_with(|| Slot::Gauge(Arc::new(AtomicU64::new(0))))
    {
        Slot::Gauge(g) => Gauge(Arc::clone(g)),
        _ => panic!("metric `{name}` is already registered with a different kind"),
    }
}

/// Get or register the histogram named `name` (same kind-clash rule as
/// [`counter`]).
pub fn hist(name: &'static str) -> HistHandle {
    let mut reg = registry();
    match reg
        .entry(name)
        .or_insert_with(|| Slot::Hist(Arc::new(Hist::new())))
    {
        Slot::Hist(h) => HistHandle(Arc::clone(h)),
        _ => panic!("metric `{name}` is already registered with a different kind"),
    }
}

/// A point-in-time metric value, as returned by [`snapshot`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MetricValue {
    /// Monotone counter value.
    Counter(u64),
    /// Gauge value.
    Gauge(u64),
    /// Histogram bucket counts, trailing zero buckets trimmed.
    Hist(Vec<u64>),
}

/// Relaxed snapshot of every registered metric, sorted by name.
pub fn snapshot() -> Vec<(&'static str, MetricValue)> {
    registry()
        .iter()
        .map(|(&name, slot)| {
            let value = match slot {
                Slot::Counter(c) => MetricValue::Counter(c.load(Ordering::Relaxed)),
                Slot::Gauge(g) => MetricValue::Gauge(g.load(Ordering::Relaxed)),
                Slot::Hist(h) => MetricValue::Hist(h.counts_trimmed()),
            };
            (name, value)
        })
        .collect()
}

/// Render the registry as one strict-JSON object:
/// `{"counters":{..},"gauges":{..},"histograms":{"name":[b0,b1,..],..}}`
/// with names sorted and histogram buckets in the power-of-two layout.
pub fn dump_json() -> String {
    let snap = snapshot();
    let mut counters = String::new();
    let mut gauges = String::new();
    let mut hists = String::new();
    for (name, value) in &snap {
        match value {
            MetricValue::Counter(v) => {
                if !counters.is_empty() {
                    counters.push(',');
                }
                counters.push_str(&format!("{}:{}", json_str(name), v));
            }
            MetricValue::Gauge(v) => {
                if !gauges.is_empty() {
                    gauges.push(',');
                }
                gauges.push_str(&format!("{}:{}", json_str(name), v));
            }
            MetricValue::Hist(buckets) => {
                if !hists.is_empty() {
                    hists.push(',');
                }
                let body: Vec<String> = buckets.iter().map(|b| b.to_string()).collect();
                hists.push_str(&format!("{}:[{}]", json_str(name), body.join(",")));
            }
        }
    }
    format!("{{\"counters\":{{{counters}}},\"gauges\":{{{gauges}}},\"histograms\":{{{hists}}}}}")
}
