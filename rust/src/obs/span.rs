//! Hierarchical RAII spans with per-thread stacks and monotonic timing.
//!
//! A span is entered with [`crate::obs::span!`] (or [`SpanGuard::enter`])
//! and closed when the returned guard drops. Each thread keeps its own
//! event buffer and depth counter, so begin/end events are well-nested
//! per thread by construction (RAII guards drop in LIFO order). When a
//! thread's outermost span closes, its buffer is flushed into a global
//! sink that [`drain_events`] and the Chrome-trace exporter read.
//!
//! Timing uses a process-wide monotonic epoch (`Instant`); timestamps are
//! microseconds since the first span of the process. Thread ids are small
//! dense integers assigned on first use (not OS tids) so traces are
//! stable across runs.
//!
//! The disabled path — the default — is one relaxed atomic load and a
//! branch in [`SpanGuard::enter`]; no timestamp is taken, no allocation
//! happens, and nothing is written. Enabled or not, spans never touch an
//! `f64`: every bit-identical pin in the crate holds with tracing on.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// One begin or end record, as collected by [`drain_events`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Event {
    /// Dense per-process thread id (assigned on the thread's first span).
    pub tid: u64,
    /// Span name (the literal passed to `obs::span!`).
    pub name: &'static str,
    /// `true` for a begin event, `false` for the matching end.
    pub begin: bool,
    /// Microseconds since the process trace epoch.
    pub ts_us: u64,
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Microseconds since the process trace epoch (the first call wins the
/// epoch; it reports 0).
#[inline]
pub(crate) fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());

fn sink() -> MutexGuard<'static, Vec<Event>> {
    match SINK.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

struct ThreadBuf {
    tid: u64,
    depth: usize,
    events: Vec<Event>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if !self.events.is_empty() {
            sink().append(&mut self.events);
        }
    }
}

impl Drop for ThreadBuf {
    // Thread exit with spans still open (e.g. a panicking worker): don't
    // lose what was recorded.
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        depth: 0,
        events: Vec::new(),
    });
}

/// Record one event on the current thread. Returns `false` when the
/// thread-local is gone (thread teardown) so the guard can deactivate.
fn push(name: &'static str, begin: bool) -> bool {
    let ts_us = now_us();
    BUF.try_with(|cell| {
        let mut buf = cell.borrow_mut();
        let tid = buf.tid;
        if begin {
            buf.depth += 1;
        }
        buf.events.push(Event {
            tid,
            name,
            begin,
            ts_us,
        });
        if !begin {
            buf.depth = buf.depth.saturating_sub(1);
            if buf.depth == 0 {
                buf.flush();
            }
        }
    })
    .is_ok()
}

/// RAII guard for one span: records a begin event on creation (when
/// tracing is enabled) and the matching end event on drop.
#[must_use = "a span guard records its end on drop; bind it: `let _span = obs::span!(..)`"]
pub struct SpanGuard {
    name: &'static str,
    active: bool,
}

impl SpanGuard {
    /// Enter a span. When tracing is disabled this is one relaxed load
    /// and a branch; the returned guard is inert.
    #[inline]
    pub fn enter(name: &'static str) -> SpanGuard {
        if !crate::obs::enabled() {
            return SpanGuard {
                name,
                active: false,
            };
        }
        let active = push(name, true);
        SpanGuard { name, active }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        // The end event is recorded iff the begin was, even if tracing
        // was toggled mid-span — per-thread nesting stays well-formed.
        if self.active {
            push(self.name, false);
        }
    }
}

/// Move all completed events out of the global sink (flushing the calling
/// thread's buffer first). Other threads' *open* spans stay in their
/// local buffers until they close or the thread exits.
pub fn drain_events() -> Vec<Event> {
    let _ = BUF.try_with(|cell| cell.borrow_mut().flush());
    std::mem::take(&mut *sink())
}

/// Discard everything collected so far (calling thread's buffer + sink).
pub fn clear_events() {
    drop(drain_events());
}
