//! Log-bucketed (power-of-two) histograms over relaxed atomics.
//!
//! A [`Hist`] is a fixed array of [`BUCKETS`] monotone counters. Bucket 0
//! holds exactly the value `0`; bucket `i` (for `1 ≤ i < BUCKETS`) holds
//! values in `[2^(i-1), 2^i)`, except the last bucket which is open-ended
//! (`[2^(BUCKETS-2), ∞)`). The boundaries are pure integer bit-math
//! ([`bucket_index`] is one `leading_zeros` + clamp) and are pinned by
//! `tests/obs.rs`, so exported bucket counts are comparable across builds
//! and machines.
//!
//! Recording is a single relaxed `fetch_add` on one bucket — safe to call
//! concurrently from any thread, never blocking, and (like everything in
//! `obs`) never touching an `f64`: instrumentation cannot perturb the
//! crate's bit-identical numerical contracts.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets in every [`Hist`]. 64 buckets cover the full `u64`
/// range at power-of-two resolution: microsecond latencies, byte sizes,
/// and counts all fit without configuration.
pub const BUCKETS: usize = 64;

/// Map a value to its bucket index.
///
/// `0 → 0`; otherwise `v → min(BUCKETS-1, 64 - v.leading_zeros())`, i.e.
/// bucket `i` holds `[2^(i-1), 2^i)` with the top bucket open-ended.
#[inline]
pub fn bucket_index(value: u64) -> usize {
    if value == 0 {
        0
    } else {
        usize::min(BUCKETS - 1, 64 - value.leading_zeros() as usize)
    }
}

/// Inclusive lower bound of a bucket: `0 → 0`, `i → 2^(i-1)`.
#[inline]
pub fn bucket_lower_bound(index: usize) -> u64 {
    if index == 0 {
        0
    } else {
        1u64 << (index - 1)
    }
}

/// A concurrent power-of-two histogram. See the module docs for the
/// bucket layout.
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
}

impl Hist {
    /// A histogram with every bucket at zero.
    pub fn new() -> Hist {
        Hist {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one observation (relaxed `fetch_add` on the value's bucket).
    #[inline]
    pub fn record(&self, value: u64) {
        self.buckets[bucket_index(value)].fetch_add(1, Ordering::Relaxed);
    }

    /// Relaxed snapshot of every bucket count.
    pub fn counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Bucket counts with trailing zero buckets dropped (compact form for
    /// JSON export; the index→boundary mapping is unchanged).
    pub fn counts_trimmed(&self) -> Vec<u64> {
        let counts = self.counts();
        let len = BUCKETS - counts.iter().rev().take_while(|&&c| c == 0).count();
        counts[..len].to_vec()
    }

    /// Total number of recorded observations.
    pub fn total(&self) -> u64 {
        self.counts().iter().sum()
    }
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl std::fmt::Debug for Hist {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Hist").field("counts", &self.counts_trimmed()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        for i in 1..BUCKETS {
            // Each bucket's lower bound maps into that bucket, and the
            // value just below it maps into the previous one.
            assert_eq!(bucket_index(bucket_lower_bound(i)), i.min(BUCKETS - 1));
            assert_eq!(bucket_index(bucket_lower_bound(i) - 1), i - 1);
        }
    }

    #[test]
    fn record_and_trim() {
        let h = Hist::new();
        h.record(0);
        h.record(1);
        h.record(1);
        h.record(5);
        let counts = h.counts();
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 2);
        assert_eq!(counts[3], 1);
        assert_eq!(h.total(), 4);
        assert_eq!(h.counts_trimmed(), vec![1, 2, 0, 1]);
        let empty = Hist::new();
        assert!(empty.counts_trimmed().is_empty());
    }
}
