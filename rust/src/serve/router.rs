//! Consistent-hash request routing across dispatcher shards.
//!
//! The sharded batcher ([`super::batcher`]) runs N independent
//! dispatcher threads, each with its own bounded queue. The router picks
//! a shard from the request's **routing key** — `(model fingerprint,
//! endpoint)` — with rendezvous (highest-random-weight) hashing: score
//! every shard against the key, take the argmax. Two properties matter
//! here:
//!
//! * **Affinity**: every request for the same `(model, endpoint)` lands
//!   on the same shard, so compatible requests keep meeting in one queue
//!   and the micro-batcher's cross-request grouping stays as effective
//!   as it was with a single dispatcher. (Grouping compatibility is
//!   strictly finer than the routing key — same endpoint + model plus
//!   bit-equal grids/knobs — so routing never separates two requests
//!   that could have shared an engine call.)
//! * **Minimal disruption**: rendezvous hashing moves only the keys
//!   whose argmax shard disappears when the shard count changes —
//!   there is no ring to rebalance.
//!
//! Routing never changes a response byte: shards share the registry and
//! the same per-request scalar-oracle contract, so WHERE a request runs
//! is invisible in its 200 body (`tests/serve.rs` pins byte-identity
//! across shard counts 1/2/4).

/// FNV-1a 64-bit, the same hash family the model fingerprint and the
/// response cache use — tiny, stable across platforms, no dependency.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

fn fnv1a(h: u64, bytes: &[u8]) -> u64 {
    let mut h = h;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Rendezvous router over a fixed shard count.
#[derive(Clone, Copy, Debug)]
pub struct Router {
    shards: usize,
}

impl Router {
    /// A router over `shards` dispatcher shards (clamped to ≥ 1).
    pub fn new(shards: usize) -> Router {
        Router { shards: shards.max(1) }
    }

    /// The shard count this router spreads keys over.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The highest-scoring shard for `(fingerprint, endpoint)`.
    /// Deterministic: same key → same shard for the lifetime of the
    /// server, on every platform.
    pub fn route(&self, fingerprint: u64, endpoint: &str) -> usize {
        (0..self.shards)
            .max_by_key(|&shard| Self::score(fingerprint, endpoint, shard))
            .expect("at least one shard")
    }

    /// The rendezvous weight of one `(key, shard)` pair.
    fn score(fingerprint: u64, endpoint: &str, shard: usize) -> u64 {
        let h = fnv1a(FNV_OFFSET, &fingerprint.to_le_bytes());
        let h = fnv1a(h, endpoint.as_bytes());
        fnv1a(h, &(shard as u64).to_le_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_are_stable_and_in_range() {
        let r = Router::new(4);
        for fp in [0u64, 1, 0xdead_beef, u64::MAX] {
            for ep in ["/v1/simulate", "/v1/reconstruct", "/v1/elbo"] {
                let s = r.route(fp, ep);
                assert!(s < 4);
                assert_eq!(s, r.route(fp, ep), "routing must be deterministic");
            }
        }
    }

    #[test]
    fn single_shard_takes_everything_and_zero_clamps() {
        let r = Router::new(1);
        assert_eq!(r.route(123, "/v1/elbo"), 0);
        assert_eq!(Router::new(0).shards(), 1, "0 shards clamps to 1");
    }

    /// Enough distinct keys must spread over every shard — a router that
    /// funnels all traffic to one shard silently serializes the server.
    #[test]
    fn many_keys_reach_every_shard() {
        let r = Router::new(4);
        let mut hit = [false; 4];
        for fp in 0..256u64 {
            hit[r.route(fp, "/v1/simulate")] = true;
        }
        assert_eq!(hit, [true; 4], "256 fingerprints must cover all 4 shards");
    }

    /// Rendezvous minimal disruption: growing the shard count only moves
    /// keys whose new argmax IS the new shard — every other key keeps
    /// its old assignment.
    #[test]
    fn growing_shards_only_moves_keys_to_the_new_shard() {
        let small = Router::new(3);
        let big = Router::new(4);
        for fp in 0..512u64 {
            let before = small.route(fp, "/v1/elbo");
            let after = big.route(fp, "/v1/elbo");
            assert!(
                after == before || after == 3,
                "key {fp} moved {before}→{after} without the new shard winning"
            );
        }
    }
}
