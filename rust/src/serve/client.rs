//! Minimal blocking HTTP/1.1 client for the serving protocol (one
//! request per connection, `Connection: close`). One implementation
//! shared by the `sdegrad bench serve` load harness and the end-to-end
//! test suite — and handy for scripting against a running server
//! without curl. Understands both `Content-Length` bodies and the
//! server's `Transfer-Encoding: chunked` streaming responses (the
//! decoded payload is byte-identical either way — framing is transport,
//! not content).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

/// Send one request over a fresh connection; returns
/// `(status, headers, body)` with the chunked framing (if any) already
/// decoded. `headers` is the raw header block (request line included,
/// `\r\n`-separated) for callers that assert on `Retry-After` or
/// `Transfer-Encoding`. A status of 0 means the response head could not
/// be parsed.
pub fn request_with_headers(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, String, Vec<u8>)> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw)?;
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(raw.len());
    let head = String::from_utf8_lossy(&raw[..head_end]).into_owned();
    let status = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let chunked = head
        .to_ascii_lowercase()
        .contains("transfer-encoding: chunked");
    let payload = &raw[head_end..];
    let body = if chunked {
        decode_chunked(payload).unwrap_or_else(|| payload.to_vec())
    } else {
        payload.to_vec()
    };
    Ok((status, head, body))
}

/// Decode an HTTP/1.1 chunked body; `None` on malformed framing (the
/// caller falls back to the raw bytes so a truncated read still
/// surfaces as a comparison failure, not a panic).
fn decode_chunked(mut rest: &[u8]) -> Option<Vec<u8>> {
    let mut out = Vec::with_capacity(rest.len());
    loop {
        let line_end = rest.windows(2).position(|w| w == b"\r\n")?;
        let size_str = std::str::from_utf8(&rest[..line_end]).ok()?;
        // Chunk extensions (";ext=…") are legal; the size is the part
        // before any semicolon.
        let size_hex = size_str.split(';').next()?.trim();
        let size = usize::from_str_radix(size_hex, 16).ok()?;
        rest = &rest[line_end + 2..];
        if size == 0 {
            return Some(out);
        }
        if rest.len() < size + 2 {
            return None;
        }
        out.extend_from_slice(&rest[..size]);
        if &rest[size..size + 2] != b"\r\n" {
            return None;
        }
        rest = &rest[size + 2..];
    }
}

/// Send one request over a fresh connection; returns `(status, body)`
/// (chunked framing decoded). A status of 0 means the response head
/// could not be parsed.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, Vec<u8>)> {
    let (status, _headers, body) = request_with_headers(addr, method, path, body)?;
    Ok((status, body))
}

/// POST a JSON body.
pub fn post(addr: SocketAddr, path: &str, body: &str) -> std::io::Result<(u16, Vec<u8>)> {
    request(addr, "POST", path, body)
}

/// GET (empty body).
pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    request(addr, "GET", path, "")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_chunked_reassembles_frames() {
        let wire = b"4\r\nwiki\r\n5\r\npedia\r\n0\r\n\r\n";
        assert_eq!(decode_chunked(wire).unwrap(), b"wikipedia");
    }

    #[test]
    fn decode_chunked_handles_extensions_and_rejects_truncation() {
        let wire = b"4;name=val\r\nwiki\r\n0\r\n\r\n";
        assert_eq!(decode_chunked(wire).unwrap(), b"wiki");
        assert!(decode_chunked(b"ff\r\nshort\r\n").is_none(), "truncated chunk");
        assert!(decode_chunked(b"zz\r\nwiki\r\n").is_none(), "bad size digits");
    }
}
