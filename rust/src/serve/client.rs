//! Minimal blocking HTTP/1.1 client for the serving protocol (one
//! request per connection, `Connection: close`). One implementation
//! shared by the `sdegrad bench serve` load harness and the end-to-end
//! test suite — and handy for scripting against a running server
//! without curl.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};

/// Send one request over a fresh connection; returns `(status, body)`.
/// A status of 0 means the response head could not be parsed.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> std::io::Result<(u16, Vec<u8>)> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(
        format!(
            "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw)?;
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|p| p + 4)
        .unwrap_or(raw.len());
    let status = std::str::from_utf8(&raw[..head_end])
        .ok()
        .and_then(|h| h.split_whitespace().nth(1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    Ok((status, raw[head_end..].to_vec()))
}

/// POST a JSON body.
pub fn post(addr: SocketAddr, path: &str, body: &str) -> std::io::Result<(u16, Vec<u8>)> {
    request(addr, "POST", path, body)
}

/// GET (empty body).
pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<(u16, Vec<u8>)> {
    request(addr, "GET", path, "")
}
