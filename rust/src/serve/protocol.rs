//! The serving wire protocol: JSON request/response types over the
//! crate's single JSON module ([`crate::metrics::json`]).
//!
//! Every request names a model and carries an explicit `seed`; the seed
//! becomes the request's [`PrngKey`], so a response body is a **pure
//! function of (canonical request, model fingerprint)** — no server-side
//! randomness, no clock. Floats are emitted with shortest-roundtrip
//! formatting ([`json_num`]), so equal floats produce equal bytes and a
//! parsed response recovers the exact `f64`s the engine computed.
//!
//! | endpoint | request fields | response payload |
//! |---|---|---|
//! | `POST /v1/simulate` | `model?, seed, times[], substeps?` | prior latent path + decoded observations |
//! | `POST /v1/reconstruct` | `model?, seed, times[], obs[][], substeps?` | posterior latent path + reconstruction |
//! | `POST /v1/elbo` | `model?, seed, times[], obs[][], substeps?, samples?, kl_weight?` | S-sample ELBO estimate components |
//!
//! Optional fields default to `model="default"`, `substeps=5`,
//! `samples=1`, `kl_weight=1`. Unknown fields are rejected (a typo'd
//! knob silently ignored would change what the client *thinks* the
//! response is a function of). [`ServeRequest::canonical`] re-emits the
//! parsed request with resolved defaults in a fixed field order — the
//! cache key, so spelling differences of the same request share an
//! entry.

use crate::latent::MultiElboOutput;
use crate::metrics::json::{json_num, json_str, parse_json, JsonValue};
use crate::prng::PrngKey;

/// Request-shape guardrails (per request; the HTTP layer separately caps
/// body bytes).
pub const MAX_TIMES: usize = 4096;
pub const MAX_SUBSTEPS: usize = 1024;
pub const MAX_SAMPLES: usize = 256;
/// Combined work cap: `(times − 1) × substeps × samples` solver steps.
/// Each knob alone is within reason at its limit, but their product is
/// ~10⁹ net evaluations — and every engine call runs on the one
/// dispatcher thread, so an unbounded request head-of-line blocks every
/// other client for its whole duration. The cap keeps the worst single
/// request around a million path-steps.
pub const MAX_REQUEST_STEPS: u64 = 1 << 20;

/// Enforce [`MAX_REQUEST_STEPS`] over the parsed solve geometry.
fn check_work(n_obs: usize, substeps: usize, samples: usize) -> Result<(), ApiError> {
    let steps = (n_obs as u64 - 1) * substeps as u64 * samples as u64;
    if steps > MAX_REQUEST_STEPS {
        return Err(ApiError::bad_request(format!(
            "request asks for {steps} solver steps ((times−1)×substeps×samples); \
             the per-request budget is {MAX_REQUEST_STEPS}"
        )));
    }
    Ok(())
}

/// A typed serving error: HTTP status + stable machine code + message.
#[derive(Clone, Debug, PartialEq)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub message: String,
}

impl ApiError {
    pub fn bad_request<M: Into<String>>(message: M) -> Self {
        ApiError { status: 400, code: "bad_request", message: message.into() }
    }

    pub fn bad_json<M: Into<String>>(message: M) -> Self {
        ApiError { status: 400, code: "bad_json", message: message.into() }
    }

    pub fn unknown_model(name: &str) -> Self {
        ApiError {
            status: 404,
            code: "unknown_model",
            message: format!("no model named {name:?} is loaded"),
        }
    }

    pub fn unknown_endpoint(path: &str) -> Self {
        ApiError {
            status: 404,
            code: "unknown_endpoint",
            message: format!("no endpoint at {path:?}"),
        }
    }

    pub fn method_not_allowed(method: &str, path: &str) -> Self {
        ApiError {
            status: 405,
            code: "method_not_allowed",
            message: format!("{method} is not supported on {path}"),
        }
    }

    pub fn body_too_large(limit: usize) -> Self {
        ApiError {
            status: 413,
            code: "body_too_large",
            message: format!("request body exceeds the {limit}-byte limit"),
        }
    }

    /// Load shedding: the routed shard's queue is over its admission
    /// budget ([`super::batcher::BatcherConfig::queue_cells`]). The HTTP
    /// layer adds a `Retry-After` header to 429 responses.
    pub fn overloaded() -> Self {
        ApiError {
            status: 429,
            code: "overloaded",
            message: "the server is shedding load; retry after the Retry-After interval"
                .to_string(),
        }
    }

    pub fn timeout() -> Self {
        ApiError {
            status: 408,
            code: "timeout",
            message: "the connection exceeded the per-request deadline".to_string(),
        }
    }

    pub fn internal<M: Into<String>>(message: M) -> Self {
        ApiError { status: 500, code: "internal", message: message.into() }
    }

    /// The JSON error body.
    pub fn body(&self) -> Vec<u8> {
        format!(
            "{{\"error\":{{\"code\":{},\"message\":{}}}}}",
            json_str(self.code),
            json_str(&self.message)
        )
        .into_bytes()
    }
}

/// `POST /v1/simulate` — sample a prior latent path and decode it.
#[derive(Clone, Debug, PartialEq)]
pub struct SimulateRequest {
    pub model: String,
    pub seed: u64,
    pub times: Vec<f64>,
    pub substeps: usize,
}

/// `POST /v1/reconstruct` — encode observations, sample a posterior
/// latent path, decode the reconstruction.
#[derive(Clone, Debug, PartialEq)]
pub struct ReconstructRequest {
    pub model: String,
    pub seed: u64,
    pub times: Vec<f64>,
    /// Observations, row-major `(K, obs_row)`.
    pub obs: Vec<f64>,
    pub obs_row: usize,
    pub substeps: usize,
}

/// `POST /v1/elbo` — S-sample ELBO estimate of a sequence.
#[derive(Clone, Debug, PartialEq)]
pub struct ElboRequest {
    pub model: String,
    pub seed: u64,
    pub times: Vec<f64>,
    pub obs: Vec<f64>,
    pub obs_row: usize,
    pub substeps: usize,
    pub samples: usize,
    pub kl_weight: f64,
}

/// One parsed, validated serving request.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeRequest {
    Simulate(SimulateRequest),
    Reconstruct(ReconstructRequest),
    Elbo(ElboRequest),
}

impl ServeRequest {
    pub fn model(&self) -> &str {
        match self {
            ServeRequest::Simulate(r) => &r.model,
            ServeRequest::Reconstruct(r) => &r.model,
            ServeRequest::Elbo(r) => &r.model,
        }
    }

    pub fn endpoint(&self) -> &'static str {
        match self {
            ServeRequest::Simulate(_) => "/v1/simulate",
            ServeRequest::Reconstruct(_) => "/v1/reconstruct",
            ServeRequest::Elbo(_) => "/v1/elbo",
        }
    }

    /// The request's PRNG key (every response float derives from it).
    pub fn key(&self) -> PrngKey {
        let seed = match self {
            ServeRequest::Simulate(r) => r.seed,
            ServeRequest::Reconstruct(r) => r.seed,
            ServeRequest::Elbo(r) => r.seed,
        };
        PrngKey::from_seed(seed)
    }

    /// Canonical bytes: the parsed request re-emitted compactly with
    /// resolved defaults in a fixed field order. Two bodies that parse
    /// to the same request have the same canonical form (the cache key).
    pub fn canonical(&self) -> String {
        let mut s = String::new();
        match self {
            ServeRequest::Simulate(r) => {
                s.push_str(&format!(
                    "{{\"model\":{},\"seed\":{},\"times\":",
                    json_str(&r.model),
                    r.seed
                ));
                push_vector(&mut s, &r.times);
                s.push_str(&format!(",\"substeps\":{}}}", r.substeps));
            }
            ServeRequest::Reconstruct(r) => {
                s.push_str(&format!(
                    "{{\"model\":{},\"seed\":{},\"times\":",
                    json_str(&r.model),
                    r.seed
                ));
                push_vector(&mut s, &r.times);
                s.push_str(",\"obs\":");
                push_matrix(&mut s, &r.obs, r.obs_row);
                s.push_str(&format!(",\"substeps\":{}}}", r.substeps));
            }
            ServeRequest::Elbo(r) => {
                s.push_str(&format!(
                    "{{\"model\":{},\"seed\":{},\"times\":",
                    json_str(&r.model),
                    r.seed
                ));
                push_vector(&mut s, &r.times);
                s.push_str(",\"obs\":");
                push_matrix(&mut s, &r.obs, r.obs_row);
                s.push_str(&format!(
                    ",\"substeps\":{},\"samples\":{},\"kl_weight\":{}}}",
                    r.substeps,
                    r.samples,
                    json_num(r.kl_weight)
                ));
            }
        }
        s
    }
}

// ---------------------------------------------------------------------
// Parsing
// ---------------------------------------------------------------------

fn known_fields(v: &JsonValue, allowed: &[&str]) -> Result<(), ApiError> {
    let JsonValue::Obj(pairs) = v else {
        return Err(ApiError::bad_request("request body must be a JSON object"));
    };
    for (k, _) in pairs {
        if !allowed.contains(&k.as_str()) {
            return Err(ApiError::bad_request(format!(
                "unknown field {k:?} (allowed: {allowed:?})"
            )));
        }
    }
    Ok(())
}

fn field_model(v: &JsonValue) -> Result<String, ApiError> {
    match v.get("model") {
        None => Ok("default".to_string()),
        Some(m) => m
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| ApiError::bad_request("\"model\" must be a string")),
    }
}

fn field_seed(v: &JsonValue) -> Result<u64, ApiError> {
    v.get("seed")
        .ok_or_else(|| {
            ApiError::bad_request(
                "\"seed\" is required: responses are a pure function of it",
            )
        })?
        .as_u64()
        .ok_or_else(|| ApiError::bad_request("\"seed\" must be an integer in [0, 2^53)"))
}

fn field_usize(
    v: &JsonValue,
    name: &str,
    default: usize,
    lo: usize,
    hi: usize,
) -> Result<usize, ApiError> {
    let n = match v.get(name) {
        None => default,
        Some(x) => x
            .as_usize()
            .ok_or_else(|| ApiError::bad_request(format!("{name:?} must be an integer")))?,
    };
    if n < lo || n > hi {
        return Err(ApiError::bad_request(format!("{name:?} must be in [{lo}, {hi}]")));
    }
    Ok(n)
}

fn field_times(v: &JsonValue) -> Result<Vec<f64>, ApiError> {
    let arr = v
        .get("times")
        .and_then(JsonValue::as_array)
        .ok_or_else(|| ApiError::bad_request("\"times\" must be an array of numbers"))?;
    if arr.len() < 2 || arr.len() > MAX_TIMES {
        return Err(ApiError::bad_request(format!(
            "\"times\" must have between 2 and {MAX_TIMES} entries"
        )));
    }
    let mut times = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let t = t
            .as_f64()
            .filter(|t| t.is_finite())
            .ok_or_else(|| ApiError::bad_request(format!("times[{i}] must be finite")))?;
        if let Some(&prev) = times.last() {
            if t <= prev {
                return Err(ApiError::bad_request("\"times\" must be strictly ascending"));
            }
        }
        times.push(t);
    }
    Ok(times)
}

/// Parse `obs` as `times.len()` equal-length rows of finite numbers.
/// The row width is validated against the model later
/// ([`validate_for_model`] — the parser does not know the model).
fn field_obs(v: &JsonValue, n_obs: usize) -> Result<(Vec<f64>, usize), ApiError> {
    let arr = v
        .get("obs")
        .and_then(JsonValue::as_array)
        .ok_or_else(|| ApiError::bad_request("\"obs\" must be an array of number rows"))?;
    if arr.len() != n_obs {
        return Err(ApiError::bad_request(format!(
            "\"obs\" must have one row per time ({n_obs}), got {}",
            arr.len()
        )));
    }
    let mut obs = Vec::new();
    let mut row_len = 0usize;
    for (k, row) in arr.iter().enumerate() {
        let row = row
            .as_array()
            .ok_or_else(|| ApiError::bad_request(format!("obs[{k}] must be an array")))?;
        if k == 0 {
            row_len = row.len();
            if row_len == 0 {
                return Err(ApiError::bad_request("obs rows must be non-empty"));
            }
        } else if row.len() != row_len {
            return Err(ApiError::bad_request(format!(
                "obs[{k}] has {} values, expected {row_len}",
                row.len()
            )));
        }
        for (i, x) in row.iter().enumerate() {
            obs.push(
                x.as_f64().filter(|x| x.is_finite()).ok_or_else(|| {
                    ApiError::bad_request(format!("obs[{k}][{i}] must be finite"))
                })?,
            );
        }
    }
    Ok((obs, row_len))
}

fn field_kl_weight(v: &JsonValue) -> Result<f64, ApiError> {
    match v.get("kl_weight") {
        None => Ok(1.0),
        Some(x) => x
            .as_f64()
            .filter(|w| w.is_finite() && *w >= 0.0)
            .ok_or_else(|| ApiError::bad_request("\"kl_weight\" must be a finite number ≥ 0")),
    }
}

/// Parse one request body for an endpoint path. Shape limits are
/// enforced here; model-dependent checks happen in
/// [`validate_for_model`].
pub fn parse_request(path: &str, body: &str) -> Result<ServeRequest, ApiError> {
    let v = parse_json(body).map_err(ApiError::bad_json)?;
    match path {
        "/v1/simulate" => {
            known_fields(&v, &["model", "seed", "times", "substeps"])?;
            let times = field_times(&v)?;
            let substeps = field_usize(&v, "substeps", 5, 1, MAX_SUBSTEPS)?;
            check_work(times.len(), substeps, 1)?;
            Ok(ServeRequest::Simulate(SimulateRequest {
                model: field_model(&v)?,
                seed: field_seed(&v)?,
                times,
                substeps,
            }))
        }
        "/v1/reconstruct" => {
            known_fields(&v, &["model", "seed", "times", "obs", "substeps"])?;
            let times = field_times(&v)?;
            let (obs, obs_row) = field_obs(&v, times.len())?;
            let substeps = field_usize(&v, "substeps", 5, 1, MAX_SUBSTEPS)?;
            check_work(times.len(), substeps, 1)?;
            Ok(ServeRequest::Reconstruct(ReconstructRequest {
                model: field_model(&v)?,
                seed: field_seed(&v)?,
                times,
                obs,
                obs_row,
                substeps,
            }))
        }
        "/v1/elbo" => {
            known_fields(
                &v,
                &["model", "seed", "times", "obs", "substeps", "samples", "kl_weight"],
            )?;
            let times = field_times(&v)?;
            let (obs, obs_row) = field_obs(&v, times.len())?;
            let substeps = field_usize(&v, "substeps", 5, 1, MAX_SUBSTEPS)?;
            let samples = field_usize(&v, "samples", 1, 1, MAX_SAMPLES)?;
            check_work(times.len(), substeps, samples)?;
            Ok(ServeRequest::Elbo(ElboRequest {
                model: field_model(&v)?,
                seed: field_seed(&v)?,
                times,
                obs,
                obs_row,
                substeps,
                samples,
                kl_weight: field_kl_weight(&v)?,
            }))
        }
        _ => Err(ApiError::unknown_endpoint(path)),
    }
}

/// Model-dependent validation: the observation row width must equal the
/// model's observation dimension.
pub fn validate_for_model(req: &ServeRequest, obs_dim: usize) -> Result<(), ApiError> {
    let row = match req {
        ServeRequest::Simulate(_) => return Ok(()),
        ServeRequest::Reconstruct(r) => r.obs_row,
        ServeRequest::Elbo(r) => r.obs_row,
    };
    if row != obs_dim {
        return Err(ApiError::bad_request(format!(
            "obs rows have {row} values but the model observes {obs_dim} dimensions"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// Response emission
// ---------------------------------------------------------------------

fn push_vector(s: &mut String, data: &[f64]) {
    s.push('[');
    for (i, v) in data.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&json_num(*v));
    }
    s.push(']');
}

fn push_matrix(s: &mut String, data: &[f64], row: usize) {
    s.push('[');
    for (k, chunk) in data.chunks_exact(row).enumerate() {
        if k > 0 {
            s.push(',');
        }
        push_vector(s, chunk);
    }
    s.push(']');
}

fn response_head(s: &mut String, model: &str, fingerprint: u64, seed: u64) {
    s.push_str(&format!(
        "{{\"model\":{},\"fingerprint\":\"{fingerprint:016x}\",\"seed\":{seed}",
        json_str(model)
    ));
}

/// `/v1/simulate` response: prior latent path `(K, dz)` + decoded
/// observation-space path `(K, dx)`.
pub fn simulate_response(
    req: &SimulateRequest,
    fingerprint: u64,
    latent: &[f64],
    dz: usize,
    decoded: &[f64],
    dx: usize,
) -> Vec<u8> {
    let mut s = String::new();
    response_head(&mut s, &req.model, fingerprint, req.seed);
    s.push_str(",\"latent\":");
    push_matrix(&mut s, latent, dz);
    s.push_str(",\"obs\":");
    push_matrix(&mut s, decoded, dx);
    s.push('}');
    s.into_bytes()
}

/// `/v1/reconstruct` response: posterior latent path + reconstruction.
pub fn reconstruct_response(
    req: &ReconstructRequest,
    fingerprint: u64,
    latent: &[f64],
    dz: usize,
    recon: &[f64],
    dx: usize,
) -> Vec<u8> {
    let mut s = String::new();
    response_head(&mut s, &req.model, fingerprint, req.seed);
    s.push_str(",\"latent\":");
    push_matrix(&mut s, latent, dz);
    s.push_str(",\"recon\":");
    push_matrix(&mut s, recon, dx);
    s.push('}');
    s.into_bytes()
}

/// `/v1/elbo` response: the S-sample estimate's components.
pub fn elbo_response(req: &ElboRequest, fingerprint: u64, out: &MultiElboOutput) -> Vec<u8> {
    let mut s = String::new();
    response_head(&mut s, &req.model, fingerprint, req.seed);
    s.push_str(&format!(
        ",\"loss\":{},\"log_px\":{},\"kl_path\":{},\"kl_z0\":{},\"recon_mse\":{},\
         \"per_sample_loss\":",
        json_num(out.loss),
        json_num(out.log_px),
        json_num(out.kl_path),
        json_num(out.kl_z0),
        json_num(out.recon_mse)
    ));
    push_vector(&mut s, &out.per_sample_loss);
    s.push('}');
    s.into_bytes()
}

/// `GET /healthz` response: status + the loaded models.
pub fn healthz_response(models: &[(String, u64)]) -> Vec<u8> {
    let mut s = String::from("{\"status\":\"ok\",\"models\":[");
    for (i, (name, fp)) in models.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "{{\"name\":{},\"fingerprint\":\"{fp:016x}\"}}",
            json_str(name)
        ));
    }
    s.push_str("]}");
    s.into_bytes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simulate_parses_with_defaults_and_canonicalizes() {
        let body = r#"{ "seed": 7, "times": [0, 0.5, 1.0] }"#;
        let req = parse_request("/v1/simulate", body).unwrap();
        let ServeRequest::Simulate(r) = &req else { panic!("wrong variant") };
        assert_eq!(r.model, "default");
        assert_eq!(r.seed, 7);
        assert_eq!(r.substeps, 5);
        // Spelling differences collapse to one canonical form.
        let body2 =
            r#"{"times": [0.0, 5e-1, 1], "substeps": 5, "seed": 7, "model": "default"}"#;
        let req2 = parse_request("/v1/simulate", body2).unwrap();
        assert_eq!(req.canonical(), req2.canonical());
        assert!(req.canonical().contains("\"seed\":7"));
    }

    #[test]
    fn reconstruct_and_elbo_parse_obs_rows() {
        let body = r#"{"seed": 1, "times": [0, 0.1], "obs": [[1, 2], [3, 4]]}"#;
        let ServeRequest::Reconstruct(r) = parse_request("/v1/reconstruct", body).unwrap()
        else {
            panic!("wrong variant")
        };
        assert_eq!(r.obs, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.obs_row, 2);

        let body = r#"{"seed": 1, "times": [0, 0.1], "obs": [[1], [2]],
                       "samples": 3, "kl_weight": 0.5}"#;
        let ServeRequest::Elbo(r) = parse_request("/v1/elbo", body).unwrap() else {
            panic!("wrong variant")
        };
        assert_eq!(r.samples, 3);
        assert_eq!(r.kl_weight, 0.5);
        assert_eq!(r.obs_row, 1);
        assert!(validate_for_model(&ServeRequest::Elbo(r.clone()), 1).is_ok());
        assert_eq!(
            validate_for_model(&ServeRequest::Elbo(r), 3).unwrap_err().status,
            400
        );
    }

    #[test]
    fn rejects_bad_requests_with_the_right_codes() {
        let cases: &[(&str, &str, &str)] = &[
            ("/v1/simulate", "not json at all", "bad_json"),
            ("/v1/simulate", r#"{"times": [0, 1]}"#, "bad_request"), // no seed
            ("/v1/simulate", r#"{"seed": 1, "times": [0]}"#, "bad_request"),
            ("/v1/simulate", r#"{"seed": 1, "times": [1, 0]}"#, "bad_request"),
            ("/v1/simulate", r#"{"seed": 1, "times": [0, 1], "typo": 2}"#, "bad_request"),
            ("/v1/simulate", r#"{"seed": -3, "times": [0, 1]}"#, "bad_request"),
            ("/v1/simulate", r#"{"seed": 1, "times": [0, 1], "substeps": 0}"#, "bad_request"),
            (
                "/v1/reconstruct",
                r#"{"seed": 1, "times": [0, 1], "obs": [[1, 2], [3]]}"#,
                "bad_request",
            ),
            ("/v1/elbo", r#"{"seed": 1, "times": [0, 1], "obs": [[1], [2]], "samples": 0}"#,
             "bad_request"),
            ("/v1/nope", r#"{"seed": 1}"#, "unknown_endpoint"),
            // Each knob within its own limit, product over the combined
            // solver-step budget: rejected so one request cannot
            // head-of-line block the dispatcher for minutes.
            ("/v1/elbo",
             r#"{"seed": 1, "times": [0, 1, 2, 3, 4, 5, 6, 7, 8, 9],
                 "obs": [[1],[1],[1],[1],[1],[1],[1],[1],[1],[1]],
                 "substeps": 1024, "samples": 256}"#,
             "bad_request"),
        ];
        for (path, body, code) in cases {
            let err = parse_request(path, body).unwrap_err();
            assert_eq!(&err.code, code, "{path} {body}");
        }
    }

    #[test]
    fn error_bodies_are_json() {
        let e = ApiError::unknown_model("nope");
        let body = String::from_utf8(e.body()).unwrap();
        let v = parse_json(&body).unwrap();
        assert_eq!(v.get("error").unwrap().get("code").unwrap().as_str(), Some("unknown_model"));
    }

    #[test]
    fn responses_emit_exact_floats() {
        let req = SimulateRequest {
            model: "m".into(),
            seed: 3,
            times: vec![0.0, 1.0],
            substeps: 2,
        };
        let latent = [0.1, -2.5e-7, 1.0 / 3.0, 4.0];
        let decoded = [1.5, -0.25];
        let body =
            String::from_utf8(simulate_response(&req, 0xabcd, &latent, 2, &decoded, 1)).unwrap();
        let v = parse_json(&body).unwrap();
        assert_eq!(v.get("fingerprint").unwrap().as_str(), Some("000000000000abcd"));
        let lat = v.get("latent").unwrap().as_array().unwrap();
        let back = lat[1].as_array().unwrap()[0].as_f64().unwrap();
        assert_eq!(back.to_bits(), (1.0f64 / 3.0).to_bits());
    }
}
