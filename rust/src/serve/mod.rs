//! Inference serving: `sdegrad serve` — a std-only HTTP server that
//! answers simulation / reconstruction / scoring requests from trained
//! latent-SDE checkpoints, with **dynamic micro-batching onto the
//! batched SoA engine**.
//!
//! ## Architecture
//!
//! ```text
//!  TCP accept thread ──► connection queue ──► N worker threads
//!                                               │  parse HTTP + JSON,
//!                                               │  validate, cache probe
//!                                               ▼
//!                                     micro-batch queue (mpsc)
//!                                               │
//!                                    dispatcher thread (batcher):
//!                                    drain ≤ max_batch within
//!                                    max_wait_us, group compatible
//!                                    requests, ONE batched engine
//!                                    call per group
//!                                               │
//!                       ┌───────────────────────┼───────────────────────┐
//!         sample_prior_paths_batch  sample_posterior_paths_batch   elbo_value_multi_batch
//!             (prior fleet)         (batched encoder + ctx solve)  (R requests × S samples)
//! ```
//!
//! * [`server`] — TCP listener + minimal HTTP/1.1 parsing on a
//!   worker-thread pool; endpoints `GET /healthz`, `POST /v1/simulate`,
//!   `POST /v1/reconstruct`, `POST /v1/elbo`.
//! * [`protocol`] — JSON request/response types over the crate's single
//!   JSON module ([`crate::metrics::json`]); every request carries a
//!   `seed`, so a response is a **pure function of the request and the
//!   model fingerprint**.
//! * [`batcher`] — the dynamic micro-batcher. Because the batched
//!   engine computes each path's floats independently of its batch
//!   neighbours (PR 3/4's bit-identical-batching guarantee), a
//!   response is pinned bit-identical to a per-request scalar engine
//!   call for ANY arrival order, batch size, and group layout — which
//!   is exactly what makes cross-request batching safe to ship.
//! * [`registry`] — loads one or more checkpoints (`SDEGRAD1`/`2`),
//!   fingerprints them, serves multiple named models.
//! * [`cache`] — LRU response cache keyed on model fingerprint +
//!   canonical request bytes; hits are byte-identical to misses (the
//!   cached value IS the previously computed response bytes).
//!
//! ## Determinism contract
//!
//! For a fixed model checkpoint, every `/v1/*` response body is a pure
//! function of the canonicalized request: per-request `seed` →
//! [`crate::prng::PrngKey`], engine floats independent of batching,
//! shortest-roundtrip float formatting. `tests/serve.rs` pins exact
//! byte equality across micro-batch layouts (`max_batch` 1 vs 16),
//! worker counts, concurrent-client arrival orders, and cache state.
//!
//! `sdegrad bench serve` is the in-process load harness (concurrent
//! clients over localhost → req/sec + p50/p99 → `BENCH_serve.json`,
//! gated by `sdegrad bench compare`).

pub mod batcher;
pub mod cache;
pub mod client;
pub mod protocol;
pub mod registry;
pub mod server;

pub use batcher::{Batcher, BatcherConfig};
pub use cache::ResponseCache;
pub use protocol::{ApiError, ServeRequest};
pub use registry::{dataset_model_config, ModelEntry, ModelRegistry};
pub use server::{Server, ServeConfig};
