//! Inference serving: `sdegrad serve` — a std-only HTTP server that
//! answers simulation / reconstruction / scoring requests from trained
//! latent-SDE checkpoints, with **dynamic micro-batching onto the
//! batched SoA engine** across N dispatcher shards.
//!
//! ## Architecture
//!
//! ```text
//!  TCP accept thread ──► connection queue ──► W worker threads
//!                                               │  parse HTTP + JSON,
//!                                               │  validate, cache probe
//!                                               ▼
//!                            consistent-hash router (rendezvous over
//!                            (model fingerprint, endpoint))
//!                                │
//!              ┌─────────────────┼─────────────────┐
//!              ▼                 ▼                 ▼
//!        shard 0 queue     shard 1 queue  …  shard N−1 queue
//!        (bounded: cell    admission control sheds over-budget
//!         budget)          requests with 429 + Retry-After
//!              │                 │                 │
//!        dispatcher 0      dispatcher 1      dispatcher N−1
//!        drain ≤ max_batch within max_wait_us, group compatible
//!        requests, ONE batched engine call per group
//!              │
//!   ┌──────────┼──────────────────────┬──────────────────────────┐
//!   ▼          ▼                      ▼                          ▼
//!  sample_prior_paths_batch  sample_posterior_paths_batch  elbo_value_multi_batch
//!      (prior fleet)         (batched encoder + ctx solve)  (R requests × S samples)
//! ```
//!
//! * [`server`] — TCP listener + minimal HTTP/1.1 parsing on a
//!   worker-thread pool; endpoints `GET /healthz`, `GET /metrics`,
//!   `POST /v1/simulate`, `POST /v1/reconstruct`, `POST /v1/elbo`. Long
//!   `/v1/simulate` bodies stream with `Transfer-Encoding: chunked`.
//! * [`router`] — rendezvous hashing of `(model fingerprint, endpoint)`
//!   onto shards: affine (compatible requests keep meeting in one
//!   queue, so cross-request batching stays effective) and minimally
//!   disruptive under shard-count changes.
//! * [`protocol`] — JSON request/response types over the crate's single
//!   JSON module ([`crate::metrics::json`]); every request carries a
//!   `seed`, so a response is a **pure function of the request and the
//!   model fingerprint**.
//! * [`batcher`] — the sharded dynamic micro-batcher: per-shard bounded
//!   queues + dispatcher threads, admission control (429 `overloaded`
//!   when a shard's cell budget is exceeded), per-shard monotone
//!   counters for `GET /metrics`. Because the batched engine computes
//!   each path's floats independently of its batch neighbours (PR 3/4's
//!   bit-identical-batching guarantee), a response is pinned
//!   bit-identical to a per-request scalar engine call for ANY arrival
//!   order, batch size, shard count, and group layout — which is
//!   exactly what makes cross-request batching safe to ship.
//! * [`registry`] — loads one or more checkpoints (`SDEGRAD1`/`2`),
//!   fingerprints them, serves multiple named models.
//! * [`cache`] — LRU response cache keyed on model fingerprint +
//!   canonical request bytes; hits are byte-identical to misses (the
//!   cached value IS the previously computed response bytes).
//!
//! ## Determinism contract
//!
//! For a fixed model checkpoint, every 200 `/v1/*` response body is a
//! pure function of the canonicalized request: per-request `seed` →
//! [`crate::prng::PrngKey`], engine floats independent of batching,
//! shortest-roundtrip float formatting. `tests/serve.rs` pins exact
//! byte equality across micro-batch layouts (`max_batch` 1 vs 16),
//! shard counts (1/2/4), worker counts, concurrent-client arrival
//! orders, queue states, and cache states. Load shedding changes WHICH
//! requests get a 429 — never a success byte.
//!
//! `sdegrad bench serve` is the in-process load harness: closed-loop
//! concurrent clients (req/sec + p50/p99) plus an open-loop traffic
//! simulator with heavy-tail request sizes, bursty arrivals, and a
//! deliberate overload episode (p99 + shed-rate, gated by
//! `sdegrad bench compare`). Artifacts land in `BENCH_serve.json`.
//!
//! ## `GET /metrics` fields
//!
//! Strict JSON, integers only (no floats anywhere in the body). Latency
//! histograms are arrays of power-of-two bucket counts — index `i ≥ 1`
//! holds values in `[2^(i-1), 2^i)` microseconds, index 0 holds exactly
//! 0, trailing zero buckets are dropped (see [`crate::obs::hist`]).
//!
//! | field | meaning |
//! |---|---|
//! | `shards[].depth` | jobs currently queued on the shard (gauge) |
//! | `shards[].queued_cells` | queued request cells — the admission meter (gauge) |
//! | `shards[].submitted` | jobs admitted to the shard queue (counter) |
//! | `shards[].shed` | jobs rejected 429 at admission (counter) |
//! | `shards[].batches` | queue drains processed (counter) |
//! | `shards[].jobs` | jobs answered through batches (counter) |
//! | `shards[].occupancy` | drain-size histogram; bounds in `occupancy_le` |
//! | `shards[].assembly_us` | total µs assembling batches (counter) |
//! | `shards[].queue_wait_us` | per-request enqueue→drain wait histogram (µs) |
//! | `shards[].engine_us` | per-drain engine-call time histogram (µs) |
//! | `occupancy_le` | inclusive upper bounds for `occupancy` (`null` = ∞) |
//! | `totals` | `submitted`/`shed`/`batches`/`jobs` summed over shards |
//! | `cache` | response-cache `hits`/`misses`/`entries` |
//! | `engine` | process-wide `bridge_calls`/`pool_workers`/`pool_spawned` |
//! | `registry` | full [`crate::obs`] registry dump: `counters`, `gauges`, `histograms` |

pub mod batcher;
pub mod cache;
pub mod client;
pub mod protocol;
pub mod registry;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle, ShardSnapshot};
pub use cache::ResponseCache;
pub use protocol::{ApiError, ServeRequest};
pub use registry::{dataset_model_config, ModelEntry, ModelRegistry};
pub use router::Router;
pub use server::{Server, ServeConfig};
