//! LRU response cache.
//!
//! Keyed on `(endpoint, model fingerprint, canonical request bytes)` —
//! the exact triple a response is a pure function of — and valued with
//! the **previously computed response bytes**, so a cache hit is
//! byte-identical to the miss that populated it by construction (pinned
//! end-to-end in `tests/serve.rs`). The fingerprint in the key means a
//! checkpoint swap can never serve a stale answer: the new model has a
//! new fingerprint and misses.
//!
//! Recency is a monotonic touch counter per entry; eviction scans for
//! the minimum (O(capacity), and serving caches are small — the probe
//! itself is one hash lookup). A capacity of 0 disables caching.

use std::collections::HashMap;

/// A bounded LRU map from request key bytes to response bytes.
pub struct ResponseCache {
    capacity: usize,
    map: HashMap<Vec<u8>, (Vec<u8>, u64)>,
    clock: u64,
    hits: u64,
    misses: u64,
}

/// Build the cache key for a request.
pub fn cache_key(endpoint: &str, fingerprint: u64, canonical: &str) -> Vec<u8> {
    let mut key = Vec::with_capacity(endpoint.len() + 17 + canonical.len());
    key.extend_from_slice(endpoint.as_bytes());
    key.push(0);
    key.extend_from_slice(&fingerprint.to_le_bytes());
    key.push(0);
    key.extend_from_slice(canonical.as_bytes());
    key
}

impl ResponseCache {
    pub fn new(capacity: usize) -> Self {
        ResponseCache { capacity, map: HashMap::new(), clock: 0, hits: 0, misses: 0 }
    }

    /// Look up a response, refreshing its recency on a hit.
    pub fn get(&mut self, key: &[u8]) -> Option<Vec<u8>> {
        if self.capacity == 0 {
            return None;
        }
        self.clock += 1;
        match self.map.get_mut(key) {
            Some((bytes, stamp)) => {
                *stamp = self.clock;
                self.hits += 1;
                Some(bytes.clone())
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert a computed response, evicting the least-recently-used
    /// entry when full. Responses are deterministic per key, so a racing
    /// double-insert of the same key writes the same bytes.
    pub fn put(&mut self, key: Vec<u8>, value: Vec<u8>) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        if !self.map.contains_key(&key) && self.map.len() >= self.capacity {
            if let Some(oldest) = self
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&oldest);
            }
        }
        self.map.insert(key, (value, self.clock));
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// `(hits, misses)` since construction.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(s: &str) -> Vec<u8> {
        cache_key("/v1/simulate", 0xfeed, s)
    }

    #[test]
    fn hit_returns_the_exact_inserted_bytes() {
        let mut c = ResponseCache::new(4);
        assert_eq!(c.get(&k("a")), None);
        c.put(k("a"), b"response-a".to_vec());
        assert_eq!(c.get(&k("a")).as_deref(), Some(b"response-a".as_ref()));
        assert_eq!(c.stats(), (1, 1));
    }

    #[test]
    fn distinct_fingerprints_do_not_collide() {
        let mut c = ResponseCache::new(4);
        c.put(cache_key("/v1/simulate", 1, "req"), b"m1".to_vec());
        c.put(cache_key("/v1/simulate", 2, "req"), b"m2".to_vec());
        c.put(cache_key("/v1/elbo", 1, "req"), b"e1".to_vec());
        assert_eq!(c.get(&cache_key("/v1/simulate", 1, "req")).as_deref(), Some(b"m1".as_ref()));
        assert_eq!(c.get(&cache_key("/v1/simulate", 2, "req")).as_deref(), Some(b"m2".as_ref()));
        assert_eq!(c.get(&cache_key("/v1/elbo", 1, "req")).as_deref(), Some(b"e1".as_ref()));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = ResponseCache::new(2);
        c.put(k("a"), b"a".to_vec());
        c.put(k("b"), b"b".to_vec());
        assert!(c.get(&k("a")).is_some()); // refresh a; b is now LRU
        c.put(k("c"), b"c".to_vec());
        assert_eq!(c.len(), 2);
        assert!(c.get(&k("a")).is_some());
        assert!(c.get(&k("b")).is_none(), "b should have been evicted");
        assert!(c.get(&k("c")).is_some());
    }

    #[test]
    fn reinserting_existing_key_does_not_evict() {
        let mut c = ResponseCache::new(2);
        c.put(k("a"), b"a".to_vec());
        c.put(k("b"), b"b".to_vec());
        c.put(k("a"), b"a2".to_vec());
        assert_eq!(c.len(), 2);
        assert!(c.get(&k("b")).is_some());
        assert_eq!(c.get(&k("a")).as_deref(), Some(b"a2".as_ref()));
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = ResponseCache::new(0);
        c.put(k("a"), b"a".to_vec());
        assert_eq!(c.get(&k("a")), None);
        assert!(c.is_empty());
    }
}
