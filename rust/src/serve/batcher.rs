//! The sharded dynamic micro-batcher: N dispatcher shards behind
//! consistent-hash routing ([`super::router`]), each with its own
//! **bounded** queue and dispatcher thread. A shard drains its queue (up
//! to `max_batch` jobs or `max_wait_us` after the first, whichever
//! first), partitions the drained jobs into **compatibility groups**
//! (same endpoint, model, time grid, and solve knobs — bit-compared),
//! and issues **one batched engine call per group**:
//!
//! * `/v1/simulate`    → [`sample_prior_paths_batch`] (batched piecewise prior fleet)
//! * `/v1/reconstruct` → [`sample_posterior_paths_batch`] (batched encoder +
//!   per-path-context posterior solve + decoder)
//! * `/v1/elbo`        → [`elbo_value_multi_batch`] (R requests × S samples)
//!
//! ## Sharding and admission control
//!
//! Requests route to a shard by rendezvous hash of `(model fingerprint,
//! endpoint)` — affine, so compatible requests keep meeting in one queue
//! and cross-request grouping stays effective. Each shard's queue is
//! bounded by a **cell budget** ([`BatcherConfig::queue_cells`], in the
//! same `times × samples` units as [`request_cells`]): when admitting a
//! request would exceed the budget, [`BatcherHandle::submit`] sheds it
//! with [`ApiError::overloaded`] (HTTP 429 + `Retry-After`) instead of
//! queueing unbounded work. Shedding changes WHICH requests get a 429 —
//! never a success byte: every 200 is still the scalar oracle's bytes.
//!
//! ## Why cross-request batching is safe
//!
//! Every batched kernel computes each path's floats **independently of
//! its batch neighbours** (the PR 3/4 bit-identical-batching guarantee,
//! re-pinned for these kernels in `latent/{sample,elbo}.rs`), and every
//! per-request float stream derives from the request's own `seed`. So a
//! response is bit-identical to [`scalar_response`] — the per-request
//! scalar engine call — for ANY arrival order, queue depth, `max_batch`,
//! shard count, and group layout. `tests/serve.rs` pins this end-to-end
//! over HTTP.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::protocol::{self, ApiError, ServeRequest};
use super::registry::{ModelEntry, ModelRegistry};
use super::router::Router;
use crate::latent::{
    decode_path, elbo_value_multi, elbo_value_multi_batch, sample_posterior_path,
    sample_posterior_paths_batch, sample_prior_path, sample_prior_paths_batch, ElboConfig,
};
use crate::prng::PrngKey;
use crate::runtime::ExecConfig;
use crate::sde::KernelTier;

/// Default per-shard admission budget, in request cells. Generous — a
/// maximal request ([`protocol::MAX_REQUEST_STEPS`]) is ~2²⁰ cells, so
/// the default holds several of those or thousands of typical requests;
/// overload tests shrink it to force shedding deterministically.
pub const DEFAULT_QUEUE_CELLS: usize = 1 << 22;

/// Micro-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum jobs per drain (1 = no cross-request batching).
    pub max_batch: usize,
    /// How long a dispatcher waits for more jobs after the first one.
    pub max_wait_us: u64,
    /// Dispatcher shards (clamped to ≥ 1). Each shard is an independent
    /// bounded queue + dispatcher thread; requests route by rendezvous
    /// hash of `(model fingerprint, endpoint)`.
    pub shards: usize,
    /// Per-shard admission budget in request cells
    /// ([`request_cells`]); a request that would push a shard's queued
    /// cells past this is shed with a 429 (the queue's head-of-line job
    /// is always admitted so progress is guaranteed).
    pub queue_cells: usize,
    /// Execution configuration for the engine calls
    /// ([`ExecConfig`]): `exec.tier` picks the kernel tier for the
    /// ELBO-scoring engine (`--tier exact|fast` on `sdegrad serve`; the
    /// batched-equals-scalar byte contract holds *within* a tier — the
    /// scalar oracle takes the same tier; simulate / reconstruct solves
    /// stay on the exact engine regardless).
    pub exec: ExecConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 16,
            max_wait_us: 500,
            shards: 1,
            queue_cells: DEFAULT_QUEUE_CELLS,
            exec: ExecConfig::default(),
        }
    }
}

impl BatcherConfig {
    /// Set the kernel tier (delegates to `exec.tier` — the pre-0.2
    /// `tier` field's replacement).
    pub fn tier(mut self, tier: KernelTier) -> Self {
        self.exec.tier = tier;
        self
    }

    /// Replace the whole execution configuration.
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }
}

/// One queued request plus its reply channel.
pub struct Job {
    pub request: ServeRequest,
    pub resp: mpsc::Sender<Result<Vec<u8>, ApiError>>,
    /// When the job entered its shard queue — the dispatcher records
    /// `enqueued → drain` into the shard's queue-wait histogram.
    pub enqueued: Instant,
}

impl Job {
    /// A job stamped with the current instant as its enqueue time.
    pub fn new(request: ServeRequest, resp: mpsc::Sender<Result<Vec<u8>, ApiError>>) -> Job {
        Job { request, resp, enqueued: Instant::now() }
    }
}

/// Queue state behind one shard's mutex.
struct ShardState {
    queue: VecDeque<Job>,
    /// Sum of [`request_cells`] over `queue` (the admission meter).
    queued_cells: usize,
    /// False once the batcher is shutting down: submits fail fast, the
    /// dispatcher exits after draining what is already queued.
    open: bool,
}

/// Monotone per-shard counters (relaxed atomics — statistics, not
/// synchronization). `GET /metrics` reports these via
/// [`BatcherHandle::snapshots`].
#[derive(Debug, Default)]
pub struct ShardStats {
    /// Jobs admitted to the queue.
    pub submitted: AtomicU64,
    /// Jobs rejected with a 429 at admission.
    pub shed: AtomicU64,
    /// Queue drains processed (each drain = one batch of 1..=max_batch
    /// jobs, possibly split into several engine-call groups).
    pub batches: AtomicU64,
    /// Jobs answered through batch processing.
    pub jobs: AtomicU64,
    /// Batch-occupancy histogram over drain sizes; bucket upper bounds
    /// are [`OCCUPANCY_BUCKETS`].
    pub occupancy: [AtomicU64; OCCUPANCY_BUCKETS.len()],
    /// Per-request queue wait (enqueue → drain) in microseconds, as a
    /// power-of-two histogram ([`crate::obs::hist`] bucket layout).
    pub queue_wait_us: crate::obs::Hist,
    /// Per-drain engine time (grouping + batched engine calls + replies)
    /// in microseconds, same bucket layout.
    pub engine_us: crate::obs::Hist,
    /// Total microseconds spent assembling batches (first job available
    /// → drain handed to the engine), a monotone counter.
    pub assembly_us: AtomicU64,
}

/// Inclusive upper bounds of the batch-occupancy histogram buckets
/// (the last bucket is open-ended: drains larger than 16).
pub const OCCUPANCY_BUCKETS: [usize; 6] = [1, 2, 4, 8, 16, usize::MAX];

fn occupancy_bucket(n: usize) -> usize {
    OCCUPANCY_BUCKETS
        .iter()
        .position(|&hi| n <= hi)
        .expect("last bucket is open-ended")
}

/// One dispatcher shard: bounded queue + wakeup + counters.
struct Shard {
    state: Mutex<ShardState>,
    cv: Condvar,
    stats: ShardStats,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            state: Mutex::new(ShardState {
                queue: VecDeque::new(),
                queued_cells: 0,
                open: true,
            }),
            cv: Condvar::new(),
            stats: ShardStats::default(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, ShardState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// A point-in-time reading of one shard, for `GET /metrics`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSnapshot {
    /// Jobs currently queued (gauge).
    pub depth: usize,
    /// Cells currently queued (gauge, the admission meter).
    pub queued_cells: usize,
    /// Monotone counters — see [`ShardStats`].
    pub submitted: u64,
    pub shed: u64,
    pub batches: u64,
    pub jobs: u64,
    pub occupancy: [u64; OCCUPANCY_BUCKETS.len()],
    /// Queue-wait histogram bucket counts (microseconds, power-of-two
    /// buckets — see [`crate::obs::hist`] for the index→bound mapping).
    pub queue_wait_us: [u64; crate::obs::BUCKETS],
    /// Engine-time-per-drain histogram bucket counts (microseconds).
    pub engine_us: [u64; crate::obs::BUCKETS],
    /// Total microseconds spent assembling batches.
    pub assembly_us: u64,
}

struct HandleInner {
    shards: Vec<Arc<Shard>>,
    router: Router,
    registry: Arc<ModelRegistry>,
    queue_cells: usize,
}

/// A cloneable enqueue handle — each HTTP worker holds one. Routing,
/// admission control, and the blocking wait for the computed bytes all
/// live here; the dispatcher threads belong to [`Batcher`].
#[derive(Clone)]
pub struct BatcherHandle {
    inner: Arc<HandleInner>,
}

impl BatcherHandle {
    /// Route `request`, admit it (or shed with a 429), and block for its
    /// response bytes.
    pub fn submit(&self, request: ServeRequest) -> Result<Vec<u8>, ApiError> {
        let shard = &self.inner.shards[self.route(&request)];
        let cells = request_cells(&request);
        let (rtx, rrx) = mpsc::channel();
        {
            let mut st = shard.lock();
            if !st.open {
                return Err(ApiError::internal("the batcher has stopped"));
            }
            // Admission control: shed when the queue's cell meter would
            // blow the budget — EXCEPT into an empty queue, so a request
            // larger than the whole budget can still make progress once
            // the shard drains (a retry after the 429's Retry-After).
            if !st.queue.is_empty() && st.queued_cells + cells > self.inner.queue_cells {
                shard.stats.shed.fetch_add(1, Ordering::Relaxed);
                return Err(ApiError::overloaded());
            }
            st.queue.push_back(Job::new(request, rtx));
            st.queued_cells += cells;
            shard.stats.submitted.fetch_add(1, Ordering::Relaxed);
        }
        shard.cv.notify_one();
        rrx.recv()
            .unwrap_or_else(|_| Err(ApiError::internal("the batcher dropped the request")))
    }

    /// The shard `request` routes to.
    pub fn route(&self, request: &ServeRequest) -> usize {
        // Unknown models still need a shard (the dispatcher answers the
        // 404); fingerprint 0 routes them consistently.
        let fingerprint = self
            .inner
            .registry
            .get(request.model())
            .map_or(0, |e| e.fingerprint);
        self.inner.router.route(fingerprint, request.endpoint())
    }

    /// The shard count.
    pub fn shards(&self) -> usize {
        self.inner.shards.len()
    }

    /// Point-in-time per-shard readings, in shard order.
    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        self.inner
            .shards
            .iter()
            .map(|shard| {
                let (depth, queued_cells) = {
                    let st = shard.lock();
                    (st.queue.len(), st.queued_cells)
                };
                let s = &shard.stats;
                ShardSnapshot {
                    depth,
                    queued_cells,
                    submitted: s.submitted.load(Ordering::Relaxed),
                    shed: s.shed.load(Ordering::Relaxed),
                    batches: s.batches.load(Ordering::Relaxed),
                    jobs: s.jobs.load(Ordering::Relaxed),
                    occupancy: std::array::from_fn(|i| s.occupancy[i].load(Ordering::Relaxed)),
                    queue_wait_us: s.queue_wait_us.counts(),
                    engine_us: s.engine_us.counts(),
                    assembly_us: s.assembly_us.load(Ordering::Relaxed),
                }
            })
            .collect()
    }
}

/// The sharded dispatcher: owns the shard threads; hand out enqueue
/// handles with [`Batcher::handle`].
pub struct Batcher {
    handle: BatcherHandle,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(registry: Arc<ModelRegistry>, cfg: BatcherConfig) -> Batcher {
        let n_shards = cfg.shards.max(1);
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let shards: Vec<Arc<Shard>> = (0..n_shards).map(|_| Arc::new(Shard::new())).collect();
        let mut threads = Vec::with_capacity(n_shards);
        for (i, shard) in shards.iter().enumerate() {
            let shard = shard.clone();
            let registry = registry.clone();
            let exec = cfg.exec;
            let handle = std::thread::Builder::new()
                .name(format!("sdegrad-batcher-{i}"))
                .spawn(move || dispatcher_loop(&shard, &registry, max_batch, max_wait, exec))
                .expect("spawning batcher shard thread");
            threads.push(handle);
        }
        Batcher {
            handle: BatcherHandle {
                inner: Arc::new(HandleInner {
                    shards,
                    router: Router::new(n_shards),
                    registry,
                    queue_cells: cfg.queue_cells.max(1),
                }),
            },
            threads,
        }
    }

    /// A cloneable enqueue handle for a worker thread.
    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }

    /// Enqueue one request and block for its response (test/bench
    /// convenience; the HTTP workers each hold a [`BatcherHandle`]).
    pub fn submit(&self, request: ServeRequest) -> Result<Vec<u8>, ApiError> {
        self.handle.submit(request)
    }

    /// Close every shard, let the dispatchers drain what is already
    /// queued, and join them. Subsequent submits fail with a 500.
    pub fn shutdown(self) {
        for shard in self.handle.inner.shards.iter() {
            shard.lock().open = false;
            shard.cv.notify_all();
        }
        for h in self.threads {
            let _ = h.join();
        }
    }
}

/// One shard's dispatcher: block for the first job, drain
/// opportunistically up to `max_batch` within `max_wait`, process, and
/// repeat; exits once the shard is closed AND its queue is empty (queued
/// work is always answered).
fn dispatcher_loop(
    shard: &Shard,
    registry: &ModelRegistry,
    max_batch: usize,
    max_wait: Duration,
    exec: ExecConfig,
) {
    loop {
        let mut jobs = Vec::new();
        let assembly_us;
        {
            let mut st = shard.lock();
            loop {
                if !st.queue.is_empty() {
                    break;
                }
                if !st.open {
                    return;
                }
                st = shard.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            // Batch assembly starts once the first job is available; the
            // span covers the drain plus the opportunistic wait window.
            let _span = crate::obs::span!("serve.assembly");
            let assembly_start = Instant::now();
            take_queued(&mut st, &mut jobs, max_batch, &shard.stats);
            if max_batch > 1 && jobs.len() < max_batch {
                let deadline = assembly_start + max_wait;
                loop {
                    let now = Instant::now();
                    if now >= deadline || !st.open {
                        break;
                    }
                    let (guard, timeout) = shard
                        .cv
                        .wait_timeout(st, deadline - now)
                        .unwrap_or_else(|e| e.into_inner());
                    st = guard;
                    take_queued(&mut st, &mut jobs, max_batch, &shard.stats);
                    if jobs.len() >= max_batch || timeout.timed_out() {
                        break;
                    }
                }
            }
            assembly_us = assembly_start.elapsed().as_micros() as u64;
        }
        shard.stats.assembly_us.fetch_add(assembly_us, Ordering::Relaxed);
        shard.stats.batches.fetch_add(1, Ordering::Relaxed);
        shard.stats.jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        shard.stats.occupancy[occupancy_bucket(jobs.len())].fetch_add(1, Ordering::Relaxed);
        let engine_start = Instant::now();
        {
            let _span = crate::obs::span!("serve.engine");
            process_batch(registry, jobs, exec);
        }
        shard.stats.engine_us.record(engine_start.elapsed().as_micros() as u64);
    }
}

/// Move queued jobs into `jobs` until it holds `max_batch`, keeping the
/// shard's cell meter in sync and recording each job's queue wait
/// (enqueue → this drain) into the shard's histogram.
fn take_queued(st: &mut ShardState, jobs: &mut Vec<Job>, max_batch: usize, stats: &ShardStats) {
    while jobs.len() < max_batch {
        let Some(job) = st.queue.pop_front() else { break };
        st.queued_cells = st.queued_cells.saturating_sub(request_cells(&job.request));
        stats.queue_wait_us.record(job.enqueued.elapsed().as_micros() as u64);
        jobs.push(job);
    }
}

/// Bit-level equality for the grouping key: `==` would conflate 0.0 and
/// −0.0, which are different inputs to the engine.
fn same_bits(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Can these two requests share one batched engine call? Everything the
/// engine call shares across the batch must match: endpoint, model, the
/// time grid, and the solve knobs. Per-request data (seed, observations)
/// varies freely — that is what the batch dimensions carry.
fn compatible(a: &ServeRequest, b: &ServeRequest) -> bool {
    match (a, b) {
        (ServeRequest::Simulate(x), ServeRequest::Simulate(y)) => {
            x.model == y.model && x.substeps == y.substeps && same_bits(&x.times, &y.times)
        }
        (ServeRequest::Reconstruct(x), ServeRequest::Reconstruct(y)) => {
            x.model == y.model && x.substeps == y.substeps && same_bits(&x.times, &y.times)
        }
        (ServeRequest::Elbo(x), ServeRequest::Elbo(y)) => {
            x.model == y.model
                && x.substeps == y.substeps
                && x.samples == y.samples
                && x.kl_weight.to_bits() == y.kl_weight.to_bits()
                && same_bits(&x.times, &y.times)
        }
        _ => false,
    }
}

/// Aggregate size cap for one batched engine call, in "path-observation
/// cells" (`times × samples` summed over the group — the y_obs state the
/// batched solves keep is proportional to this × the latent dimension).
/// [`protocol::MAX_REQUEST_STEPS`] bounds one request's *compute*;
/// without this, max_batch maximal requests grouped together could
/// transiently allocate ~1 GB in a single engine call. Splitting a
/// compatibility group never changes a response byte (batch composition
/// independence), only how many engine calls serve the drain.
const MAX_GROUP_CELLS: usize = 1 << 21;

/// A request's contribution to [`MAX_GROUP_CELLS`] and the shard
/// admission budget ([`BatcherConfig::queue_cells`]).
pub fn request_cells(r: &ServeRequest) -> usize {
    match r {
        ServeRequest::Simulate(x) => x.times.len(),
        ServeRequest::Reconstruct(x) => x.times.len(),
        ServeRequest::Elbo(x) => x.times.len() * x.samples,
    }
}

/// Partition one drained queue into compatibility groups (arrival order
/// preserved within each group — not that order matters: every response
/// is independent of its neighbours), each capped at
/// [`MAX_GROUP_CELLS`], and run each group as one batched engine call.
fn process_batch(registry: &ModelRegistry, jobs: Vec<Job>, exec: ExecConfig) {
    let mut groups: Vec<Vec<Job>> = Vec::new();
    let mut group_cells: Vec<usize> = Vec::new();
    'outer: for job in jobs {
        let cells = request_cells(&job.request);
        for (g, used) in groups.iter_mut().zip(group_cells.iter_mut()) {
            if compatible(&g[0].request, &job.request) && *used + cells <= MAX_GROUP_CELLS {
                g.push(job);
                *used += cells;
                continue 'outer;
            }
        }
        groups.push(vec![job]);
        group_cells.push(cells);
    }
    for group in groups {
        run_group(registry, group, exec);
    }
}

/// Execute one compatibility group with a single batched engine call and
/// answer every job. The engine call runs under `catch_unwind`: a panic
/// (engine invariant violation on some adversarial input) must answer
/// the group with 500s, not kill the dispatcher thread and brick every
/// future request on its shard into "the batcher has stopped".
fn run_group(registry: &ModelRegistry, jobs: Vec<Job>, exec: ExecConfig) {
    let name = jobs[0].request.model().to_string();
    let Some(entry) = registry.get(&name) else {
        let err = ApiError::unknown_model(&name);
        for j in &jobs {
            let _ = j.resp.send(Err(err.clone()));
        }
        return;
    };
    // Defense in depth for EVERY job — the HTTP worker validates before
    // enqueueing, but direct `Batcher::submit` callers may not have, and
    // obs shape is not part of the grouping key. Malformed jobs are
    // answered individually; the rest proceed as one batch.
    let (valid, invalid): (Vec<Job>, Vec<Job>) = jobs.into_iter().partition(|j| {
        protocol::validate_for_model(&j.request, entry.model.cfg.obs_dim).is_ok()
    });
    for j in &invalid {
        let err = protocol::validate_for_model(&j.request, entry.model.cfg.obs_dim)
            .expect_err("partitioned as invalid");
        let _ = j.resp.send(Err(err));
    }
    if valid.is_empty() {
        return;
    }

    let requests: Vec<&ServeRequest> = valid.iter().map(|j| &j.request).collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // Nothing outlives the closure on panic: the engine works on
        // per-call state and reads the registry immutably.
        compute_group(entry, &requests, exec)
    }));
    match outcome {
        Ok(bodies) => {
            for (j, body) in valid.iter().zip(bodies) {
                let _ = j.resp.send(Ok(body));
            }
        }
        Err(_) => {
            let err = ApiError::internal("engine call failed for this batch");
            for j in &valid {
                let _ = j.resp.send(Err(err.clone()));
            }
        }
    }
}

/// The one-batched-engine-call body of [`run_group`]: responses for a
/// validated compatibility group, in job order.
fn compute_group(
    entry: &ModelEntry,
    requests: &[&ServeRequest],
    exec: ExecConfig,
) -> Vec<Vec<u8>> {
    let dz = entry.model.cfg.latent_dim;
    let dx = entry.model.cfg.obs_dim;
    let keys: Vec<PrngKey> = requests.iter().map(|r| r.key()).collect();

    match requests[0] {
        ServeRequest::Simulate(first) => {
            let latents = sample_prior_paths_batch(
                &entry.model,
                &entry.params,
                &first.times,
                first.substeps,
                &keys,
            );
            requests
                .iter()
                .zip(&latents)
                .map(|(req, latent)| {
                    let ServeRequest::Simulate(r) = req else { unreachable!() };
                    let decoded = decode_path(&entry.model, &entry.params, latent);
                    protocol::simulate_response(r, entry.fingerprint, latent, dz, &decoded, dx)
                })
                .collect()
        }
        ServeRequest::Reconstruct(first) => {
            let rows: Vec<&[f64]> = requests
                .iter()
                .map(|req| {
                    let ServeRequest::Reconstruct(r) = req else { unreachable!() };
                    r.obs.as_slice()
                })
                .collect();
            let latents = sample_posterior_paths_batch(
                &entry.model,
                &entry.params,
                &first.times,
                &rows,
                first.substeps,
                &keys,
            );
            requests
                .iter()
                .zip(&latents)
                .map(|(req, latent)| {
                    let ServeRequest::Reconstruct(r) = req else { unreachable!() };
                    let recon = decode_path(&entry.model, &entry.params, latent);
                    protocol::reconstruct_response(r, entry.fingerprint, latent, dz, &recon, dx)
                })
                .collect()
        }
        ServeRequest::Elbo(first) => {
            let rows: Vec<&[f64]> = requests
                .iter()
                .map(|req| {
                    let ServeRequest::Elbo(r) = req else { unreachable!() };
                    r.obs.as_slice()
                })
                .collect();
            let cfg =
                ElboConfig { substeps: first.substeps, kl_weight: first.kl_weight, exec };
            let outs = elbo_value_multi_batch(
                &entry.model,
                &entry.params,
                &first.times,
                &rows,
                &keys,
                &cfg,
                first.samples,
            );
            requests
                .iter()
                .zip(&outs)
                .map(|(req, out)| {
                    let ServeRequest::Elbo(r) = req else { unreachable!() };
                    protocol::elbo_response(r, entry.fingerprint, out)
                })
                .collect()
        }
    }
}

/// The per-request **scalar oracle**: the same response computed with
/// one-request scalar engine calls (no batching anywhere). The serving
/// determinism contract is that every batched response byte-equals this
/// — `tests/serve.rs` and `sdegrad bench serve` assert it, across shard
/// counts and queue states. The contract is per-tier: the oracle must
/// score the ELBO under the same kernel tier the batcher runs.
pub fn scalar_response(
    entry: &ModelEntry,
    req: &ServeRequest,
    tier: KernelTier,
) -> Result<Vec<u8>, ApiError> {
    protocol::validate_for_model(req, entry.model.cfg.obs_dim)?;
    let dz = entry.model.cfg.latent_dim;
    let dx = entry.model.cfg.obs_dim;
    match req {
        ServeRequest::Simulate(r) => {
            let latent = sample_prior_path(
                &entry.model,
                &entry.params,
                &r.times,
                r.substeps,
                req.key(),
                None,
            );
            let decoded = decode_path(&entry.model, &entry.params, &latent);
            Ok(protocol::simulate_response(r, entry.fingerprint, &latent, dz, &decoded, dx))
        }
        ServeRequest::Reconstruct(r) => {
            let latent = sample_posterior_path(
                &entry.model,
                &entry.params,
                &r.times,
                &r.obs,
                r.substeps,
                req.key(),
            );
            let recon = decode_path(&entry.model, &entry.params, &latent);
            Ok(protocol::reconstruct_response(r, entry.fingerprint, &latent, dz, &recon, dx))
        }
        ServeRequest::Elbo(r) => {
            let cfg = ElboConfig {
                substeps: r.substeps,
                kl_weight: r.kl_weight,
                exec: ExecConfig::new().tier(tier),
            };
            let out = elbo_value_multi(
                &entry.model,
                &entry.params,
                &r.times,
                &r.obs,
                req.key(),
                &cfg,
                r.samples,
            );
            Ok(protocol::elbo_response(r, entry.fingerprint, &out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latent::{LatentSdeConfig, LatentSdeModel};

    fn tiny_registry() -> Arc<ModelRegistry> {
        let cfg = LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            obs_noise_std: 0.1,
            ..Default::default()
        };
        let mut reg = ModelRegistry::new();
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(1));
        reg.insert("default", model, params).unwrap();
        Arc::new(reg)
    }

    fn times() -> Vec<f64> {
        (0..5).map(|k| 0.1 * k as f64).collect()
    }

    fn obs(seed: u64) -> Vec<f64> {
        let mut o = vec![0.0; 5 * 2];
        PrngKey::from_seed(seed).fill_normal(0, &mut o);
        o
    }

    fn sim(seed: u64) -> ServeRequest {
        ServeRequest::Simulate(protocol::SimulateRequest {
            model: "default".into(),
            seed,
            times: times(),
            substeps: 3,
        })
    }

    fn rec(seed: u64) -> ServeRequest {
        ServeRequest::Reconstruct(protocol::ReconstructRequest {
            model: "default".into(),
            seed,
            times: times(),
            obs: obs(seed + 1000),
            obs_row: 2,
            substeps: 3,
        })
    }

    fn elbo(seed: u64, samples: usize) -> ServeRequest {
        ServeRequest::Elbo(protocol::ElboRequest {
            model: "default".into(),
            seed,
            times: times(),
            obs: obs(seed + 2000),
            obs_row: 2,
            substeps: 3,
            samples,
            kl_weight: 0.4,
        })
    }

    #[test]
    fn compatibility_grouping_rules() {
        assert!(compatible(&sim(1), &sim(2)));
        assert!(compatible(&rec(1), &rec(2)));
        assert!(compatible(&elbo(1, 2), &elbo(9, 2)));
        assert!(!compatible(&sim(1), &rec(1)));
        assert!(!compatible(&elbo(1, 2), &elbo(1, 3)), "sample counts differ");
        let mut other = sim(1);
        if let ServeRequest::Simulate(r) = &mut other {
            r.substeps = 4;
        }
        assert!(!compatible(&sim(1), &other), "substeps differ");
        let mut neg_zero = sim(1);
        if let ServeRequest::Simulate(r) = &mut neg_zero {
            r.times[0] = -0.0;
        }
        assert!(!compatible(&sim(1), &neg_zero), "-0.0 and 0.0 must not group");
    }

    /// A mixed drained queue, processed as groups of batched engine
    /// calls, must answer every request byte-identically to the scalar
    /// oracle — the micro-batcher's core contract.
    #[test]
    fn mixed_batch_responses_equal_scalar_oracle_bytes() {
        let registry = tiny_registry();
        let requests: Vec<ServeRequest> = vec![
            sim(1),
            elbo(2, 2),
            sim(3),
            rec(4),
            elbo(5, 2),
            rec(6),
            sim(7),
            elbo(8, 3), // different sample count: its own group
        ];
        let entry = registry.get("default").unwrap();
        let expected: Vec<Vec<u8>> = requests
            .iter()
            .map(|r| scalar_response(entry, r, KernelTier::Exact).unwrap())
            .collect();

        let mut rxs = Vec::new();
        let mut jobs = Vec::new();
        for r in &requests {
            let (tx, rx) = mpsc::channel();
            jobs.push(Job::new(r.clone(), tx));
            rxs.push(rx);
        }
        process_batch(&registry, jobs, ExecConfig::default());
        for (i, rx) in rxs.iter().enumerate() {
            let got = rx.recv().expect("no response").expect("error response");
            assert_eq!(got, expected[i], "request {i} diverged from the scalar oracle");
        }
    }

    /// Obs shape is not part of the grouping key, so a malformed request
    /// can land in a group with valid ones: it must get its own 400 while
    /// the valid request still gets its oracle-identical answer (and the
    /// dispatcher survives — no engine assert fires).
    #[test]
    fn invalid_job_in_group_gets_400_without_poisoning_the_batch() {
        let registry = tiny_registry();
        let good = rec(1);
        let mut bad = rec(2);
        if let ServeRequest::Reconstruct(r) = &mut bad {
            r.obs = vec![0.0; 5 * 3]; // 3-wide rows on a 2-dim model
            r.obs_row = 3;
        }
        let expected = {
            let entry = registry.get("default").unwrap();
            scalar_response(entry, &good, KernelTier::Exact).unwrap()
        };
        let (tx1, rx1) = mpsc::channel();
        let (tx2, rx2) = mpsc::channel();
        process_batch(
            &registry,
            vec![Job::new(good, tx1), Job::new(bad, tx2)],
            ExecConfig::default(),
        );
        assert_eq!(rx1.recv().unwrap().unwrap(), expected);
        let err = rx2.recv().unwrap().unwrap_err();
        assert_eq!((err.status, err.code), (400, "bad_request"));
    }

    #[test]
    fn unknown_model_answers_every_job_in_the_group() {
        let registry = tiny_registry();
        let mut bad = sim(1);
        if let ServeRequest::Simulate(r) = &mut bad {
            r.model = "missing".into();
        }
        let (tx, rx) = mpsc::channel();
        process_batch(&registry, vec![Job::new(bad, tx)], ExecConfig::default());
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 404);
        assert_eq!(err.code, "unknown_model");
    }

    #[test]
    fn batcher_thread_round_trips_and_shuts_down() {
        let registry = tiny_registry();
        let entry_bytes = {
            let entry = registry.get("default").unwrap();
            scalar_response(entry, &sim(42), KernelTier::Exact).unwrap()
        };
        let cfg = BatcherConfig { max_batch: 4, max_wait_us: 100, ..Default::default() };
        let batcher = Batcher::start(registry, cfg);
        let got = batcher.submit(sim(42)).unwrap();
        assert_eq!(got, entry_bytes);
        batcher.shutdown();
    }

    /// Shard count is invisible in response bytes: the same requests
    /// answered through 1, 2, and 4 shards all byte-equal the scalar
    /// oracle.
    #[test]
    fn responses_are_identical_across_shard_counts() {
        let registry = tiny_registry();
        let requests: Vec<ServeRequest> =
            vec![sim(1), rec(2), elbo(3, 2), sim(4), elbo(5, 1), rec(6)];
        let expected: Vec<Vec<u8>> = {
            let entry = registry.get("default").unwrap();
            requests
                .iter()
                .map(|r| scalar_response(entry, r, KernelTier::Exact).unwrap())
                .collect()
        };
        for shards in [1usize, 2, 4] {
            let cfg = BatcherConfig { shards, max_batch: 4, ..Default::default() };
            let batcher = Batcher::start(registry.clone(), cfg);
            for (r, want) in requests.iter().zip(&expected) {
                let got = batcher.submit(r.clone()).expect("success response");
                assert_eq!(&got, want, "{shards}-shard response diverged from the oracle");
            }
            batcher.shutdown();
        }
    }

    /// Routing is a pure function of (model fingerprint, endpoint): every
    /// simulate request lands on one shard, and the per-shard counters
    /// account for exactly the submitted jobs.
    #[test]
    fn routing_is_affine_and_counters_add_up() {
        let registry = tiny_registry();
        let batcher =
            Batcher::start(registry, BatcherConfig { shards: 4, ..Default::default() });
        let handle = batcher.handle();
        let home = handle.route(&sim(0));
        for seed in 1..10 {
            assert_eq!(handle.route(&sim(seed)), home, "same (model, endpoint) must co-route");
        }
        for seed in 0..6 {
            batcher.submit(sim(seed)).unwrap();
        }
        let snaps = handle.snapshots();
        assert_eq!(snaps.len(), 4);
        assert_eq!(snaps.iter().map(|s| s.submitted).sum::<u64>(), 6);
        assert_eq!(snaps.iter().map(|s| s.jobs).sum::<u64>(), 6, "every job answered");
        assert_eq!(snaps.iter().map(|s| s.shed).sum::<u64>(), 0);
        assert_eq!(snaps[home].submitted, 6, "all simulate traffic on the home shard");
        assert!(
            snaps.iter().all(|s| s.depth == 0 && s.queued_cells == 0),
            "queues drained after blocking submits"
        );
        batcher.shutdown();
    }

    /// A queue past its cell budget sheds with 429/overloaded. Uses a
    /// handle with NO dispatcher threads so the queue occupancy is fully
    /// deterministic (a live dispatcher could drain it mid-test).
    #[test]
    fn admission_control_sheds_when_the_queue_is_over_budget() {
        let registry = tiny_registry();
        let handle = BatcherHandle {
            inner: Arc::new(HandleInner {
                shards: vec![Arc::new(Shard::new())],
                router: Router::new(1),
                registry,
                queue_cells: 1, // any request into a non-empty queue sheds
            }),
        };
        // Occupy the queue by hand (no dispatcher will drain it).
        let (tx, _sentinel) = mpsc::channel();
        {
            let mut st = handle.inner.shards[0].lock();
            st.queue.push_back(Job::new(sim(7), tx));
            st.queued_cells += request_cells(&sim(7));
        }
        // 5 queued cells > budget 1: the next submit sheds with 429.
        let err = handle.submit(sim(8)).unwrap_err();
        assert_eq!((err.status, err.code), (429, "overloaded"));
        let snap = handle.snapshots()[0];
        assert_eq!((snap.shed, snap.submitted), (1, 0));
        assert_eq!((snap.depth, snap.queued_cells), (1, 5), "shed job never queued");
    }

    /// The empty-queue admission exception: a request larger than the
    /// whole budget still succeeds once the shard drains, so shedding
    /// sheds load — it never starves a request class. And the bytes a
    /// post-shed retry gets are the oracle's, unchanged by queue history.
    #[test]
    fn over_budget_requests_recover_once_the_queue_drains() {
        let registry = tiny_registry();
        let cfg = BatcherConfig { shards: 1, queue_cells: 1, ..Default::default() };
        let batcher = Batcher::start(registry.clone(), cfg);
        // submit() blocks until the response, so each request meets an
        // empty queue — every one exceeds the 1-cell budget, every one
        // is admitted via the empty-queue exception.
        for seed in [7u64, 8] {
            let got = batcher.submit(sim(seed)).expect("empty queue admits");
            let entry = registry.get("default").unwrap();
            let want = scalar_response(entry, &sim(seed), KernelTier::Exact).unwrap();
            assert_eq!(got, want, "queue budget must not change success bytes");
        }
        batcher.shutdown();
    }

    #[test]
    fn occupancy_buckets_partition_batch_sizes() {
        assert_eq!(occupancy_bucket(1), 0);
        assert_eq!(occupancy_bucket(2), 1);
        assert_eq!(occupancy_bucket(3), 2);
        assert_eq!(occupancy_bucket(4), 2);
        assert_eq!(occupancy_bucket(8), 3);
        assert_eq!(occupancy_bucket(16), 4);
        assert_eq!(occupancy_bucket(17), 5);
        assert_eq!(occupancy_bucket(10_000), 5);
    }
}
