//! The dynamic micro-batcher: a dispatcher thread drains the request
//! queue (up to `max_batch` jobs or `max_wait_us`, whichever first),
//! partitions the drained jobs into **compatibility groups** (same
//! endpoint, model, time grid, and solve knobs — bit-compared), and
//! issues **one batched engine call per group**:
//!
//! * `/v1/simulate`    → [`sample_prior_paths_batch`] (batched piecewise prior fleet)
//! * `/v1/reconstruct` → [`sample_posterior_paths_batch`] (batched encoder +
//!   per-path-context posterior solve + decoder)
//! * `/v1/elbo`        → [`elbo_value_multi_batch`] (R requests × S samples)
//!
//! ## Why cross-request batching is safe
//!
//! Every batched kernel computes each path's floats **independently of
//! its batch neighbours** (the PR 3/4 bit-identical-batching guarantee,
//! re-pinned for these kernels in `latent/{sample,elbo}.rs`), and every
//! per-request float stream derives from the request's own `seed`. So a
//! response is bit-identical to [`scalar_response`] — the per-request
//! scalar engine call — for ANY arrival order, queue depth, `max_batch`,
//! and group layout. `tests/serve.rs` pins this end-to-end over HTTP.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::protocol::{self, ApiError, ServeRequest};
use super::registry::{ModelEntry, ModelRegistry};
use crate::latent::{
    decode_path, elbo_value_multi, elbo_value_multi_batch, sample_posterior_path,
    sample_posterior_paths_batch, sample_prior_path, sample_prior_paths_batch, ElboConfig,
};
use crate::prng::PrngKey;
use crate::sde::KernelTier;

/// Micro-batching knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum jobs per drain (1 = no cross-request batching).
    pub max_batch: usize,
    /// How long the dispatcher waits for more jobs after the first one.
    pub max_wait_us: u64,
    /// Kernel tier for the ELBO-scoring engine calls (`--tier exact|fast`
    /// on `sdegrad serve`). The batched-equals-scalar byte contract holds
    /// *within* a tier: the scalar oracle takes the same tier. Simulate /
    /// reconstruct solves stay on the exact engine regardless.
    pub tier: KernelTier,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 16, max_wait_us: 500, tier: KernelTier::Exact }
    }
}

/// One queued request plus its reply channel.
pub struct Job {
    pub request: ServeRequest,
    pub resp: mpsc::Sender<Result<Vec<u8>, ApiError>>,
}

/// Handle to the dispatcher thread. Cloning [`Batcher::sender`] gives
/// each server worker its own enqueue handle; the dispatcher exits when
/// every sender is dropped.
pub struct Batcher {
    tx: mpsc::Sender<Job>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn start(registry: Arc<ModelRegistry>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = mpsc::channel::<Job>();
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let tier = cfg.tier;
        let handle = std::thread::Builder::new()
            .name("sdegrad-batcher".into())
            .spawn(move || dispatcher_loop(rx, &registry, max_batch, max_wait, tier))
            .expect("spawning batcher thread");
        Batcher { tx, handle: Some(handle) }
    }

    /// An enqueue handle for a worker thread.
    pub fn sender(&self) -> mpsc::Sender<Job> {
        self.tx.clone()
    }

    /// Enqueue one request and block for its response (test/bench
    /// convenience; the HTTP workers use [`Batcher::sender`] + [`submit_via`]).
    pub fn submit(&self, request: ServeRequest) -> Result<Vec<u8>, ApiError> {
        submit_via(&self.tx, request)
    }

    /// Drop the enqueue side and join the dispatcher. Callers must drop
    /// every cloned sender first or this blocks until they do. (Merely
    /// dropping the `Batcher` also stops the dispatcher — once all
    /// senders are gone — but detaches its thread instead of joining.)
    pub fn shutdown(self) {
        let Batcher { tx, handle } = self;
        drop(tx);
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

/// Enqueue on a cloned sender and block for the response.
pub fn submit_via(
    tx: &mpsc::Sender<Job>,
    request: ServeRequest,
) -> Result<Vec<u8>, ApiError> {
    let (rtx, rrx) = mpsc::channel();
    tx.send(Job { request, resp: rtx })
        .map_err(|_| ApiError::internal("the batcher has stopped"))?;
    rrx.recv()
        .unwrap_or_else(|_| Err(ApiError::internal("the batcher dropped the request")))
}

fn dispatcher_loop(
    rx: mpsc::Receiver<Job>,
    registry: &ModelRegistry,
    max_batch: usize,
    max_wait: Duration,
    tier: KernelTier,
) {
    loop {
        // Block for the first job; drain opportunistically after it.
        let first = match rx.recv() {
            Ok(j) => j,
            Err(_) => return, // every sender dropped: clean shutdown
        };
        let mut jobs = vec![first];
        if max_batch > 1 {
            let deadline = Instant::now() + max_wait;
            while jobs.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => jobs.push(j),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }
        process_batch(registry, jobs, tier);
    }
}

/// Bit-level equality for the grouping key: `==` would conflate 0.0 and
/// −0.0, which are different inputs to the engine.
fn same_bits(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Can these two requests share one batched engine call? Everything the
/// engine call shares across the batch must match: endpoint, model, the
/// time grid, and the solve knobs. Per-request data (seed, observations)
/// varies freely — that is what the batch dimensions carry.
fn compatible(a: &ServeRequest, b: &ServeRequest) -> bool {
    match (a, b) {
        (ServeRequest::Simulate(x), ServeRequest::Simulate(y)) => {
            x.model == y.model && x.substeps == y.substeps && same_bits(&x.times, &y.times)
        }
        (ServeRequest::Reconstruct(x), ServeRequest::Reconstruct(y)) => {
            x.model == y.model && x.substeps == y.substeps && same_bits(&x.times, &y.times)
        }
        (ServeRequest::Elbo(x), ServeRequest::Elbo(y)) => {
            x.model == y.model
                && x.substeps == y.substeps
                && x.samples == y.samples
                && x.kl_weight.to_bits() == y.kl_weight.to_bits()
                && same_bits(&x.times, &y.times)
        }
        _ => false,
    }
}

/// Aggregate size cap for one batched engine call, in "path-observation
/// cells" (`times × samples` summed over the group — the y_obs state the
/// batched solves keep is proportional to this × the latent dimension).
/// [`protocol::MAX_REQUEST_STEPS`] bounds one request's *compute*;
/// without this, max_batch maximal requests grouped together could
/// transiently allocate ~1 GB in a single engine call. Splitting a
/// compatibility group never changes a response byte (batch composition
/// independence), only how many engine calls serve the drain.
const MAX_GROUP_CELLS: usize = 1 << 21;

/// A request's contribution to [`MAX_GROUP_CELLS`].
fn request_cells(r: &ServeRequest) -> usize {
    match r {
        ServeRequest::Simulate(x) => x.times.len(),
        ServeRequest::Reconstruct(x) => x.times.len(),
        ServeRequest::Elbo(x) => x.times.len() * x.samples,
    }
}

/// Partition one drained queue into compatibility groups (arrival order
/// preserved within each group — not that order matters: every response
/// is independent of its neighbours), each capped at
/// [`MAX_GROUP_CELLS`], and run each group as one batched engine call.
fn process_batch(registry: &ModelRegistry, jobs: Vec<Job>, tier: KernelTier) {
    let mut groups: Vec<Vec<Job>> = Vec::new();
    let mut group_cells: Vec<usize> = Vec::new();
    'outer: for job in jobs {
        let cells = request_cells(&job.request);
        for (g, used) in groups.iter_mut().zip(group_cells.iter_mut()) {
            if compatible(&g[0].request, &job.request) && *used + cells <= MAX_GROUP_CELLS {
                g.push(job);
                *used += cells;
                continue 'outer;
            }
        }
        groups.push(vec![job]);
        group_cells.push(cells);
    }
    for group in groups {
        run_group(registry, group, tier);
    }
}

/// Execute one compatibility group with a single batched engine call and
/// answer every job. The engine call runs under `catch_unwind`: a panic
/// (engine invariant violation on some adversarial input) must answer
/// the group with 500s, not kill the dispatcher thread and brick every
/// future request into "the batcher has stopped".
fn run_group(registry: &ModelRegistry, jobs: Vec<Job>, tier: KernelTier) {
    let name = jobs[0].request.model().to_string();
    let Some(entry) = registry.get(&name) else {
        let err = ApiError::unknown_model(&name);
        for j in &jobs {
            let _ = j.resp.send(Err(err.clone()));
        }
        return;
    };
    // Defense in depth for EVERY job — the HTTP worker validates before
    // enqueueing, but direct `Batcher::submit` callers may not have, and
    // obs shape is not part of the grouping key. Malformed jobs are
    // answered individually; the rest proceed as one batch.
    let (valid, invalid): (Vec<Job>, Vec<Job>) = jobs.into_iter().partition(|j| {
        protocol::validate_for_model(&j.request, entry.model.cfg.obs_dim).is_ok()
    });
    for j in &invalid {
        let err = protocol::validate_for_model(&j.request, entry.model.cfg.obs_dim)
            .expect_err("partitioned as invalid");
        let _ = j.resp.send(Err(err));
    }
    if valid.is_empty() {
        return;
    }

    let requests: Vec<&ServeRequest> = valid.iter().map(|j| &j.request).collect();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // Nothing outlives the closure on panic: the engine works on
        // per-call state and reads the registry immutably.
        compute_group(entry, &requests, tier)
    }));
    match outcome {
        Ok(bodies) => {
            for (j, body) in valid.iter().zip(bodies) {
                let _ = j.resp.send(Ok(body));
            }
        }
        Err(_) => {
            let err = ApiError::internal("engine call failed for this batch");
            for j in &valid {
                let _ = j.resp.send(Err(err.clone()));
            }
        }
    }
}

/// The one-batched-engine-call body of [`run_group`]: responses for a
/// validated compatibility group, in job order.
fn compute_group(entry: &ModelEntry, requests: &[&ServeRequest], tier: KernelTier) -> Vec<Vec<u8>> {
    let dz = entry.model.cfg.latent_dim;
    let dx = entry.model.cfg.obs_dim;
    let keys: Vec<PrngKey> = requests.iter().map(|r| r.key()).collect();

    match requests[0] {
        ServeRequest::Simulate(first) => {
            let latents = sample_prior_paths_batch(
                &entry.model,
                &entry.params,
                &first.times,
                first.substeps,
                &keys,
            );
            requests
                .iter()
                .zip(&latents)
                .map(|(req, latent)| {
                    let ServeRequest::Simulate(r) = req else { unreachable!() };
                    let decoded = decode_path(&entry.model, &entry.params, latent);
                    protocol::simulate_response(r, entry.fingerprint, latent, dz, &decoded, dx)
                })
                .collect()
        }
        ServeRequest::Reconstruct(first) => {
            let rows: Vec<&[f64]> = requests
                .iter()
                .map(|req| {
                    let ServeRequest::Reconstruct(r) = req else { unreachable!() };
                    r.obs.as_slice()
                })
                .collect();
            let latents = sample_posterior_paths_batch(
                &entry.model,
                &entry.params,
                &first.times,
                &rows,
                first.substeps,
                &keys,
            );
            requests
                .iter()
                .zip(&latents)
                .map(|(req, latent)| {
                    let ServeRequest::Reconstruct(r) = req else { unreachable!() };
                    let recon = decode_path(&entry.model, &entry.params, latent);
                    protocol::reconstruct_response(r, entry.fingerprint, latent, dz, &recon, dx)
                })
                .collect()
        }
        ServeRequest::Elbo(first) => {
            let rows: Vec<&[f64]> = requests
                .iter()
                .map(|req| {
                    let ServeRequest::Elbo(r) = req else { unreachable!() };
                    r.obs.as_slice()
                })
                .collect();
            let cfg = ElboConfig { substeps: first.substeps, kl_weight: first.kl_weight, tier };
            let outs = elbo_value_multi_batch(
                &entry.model,
                &entry.params,
                &first.times,
                &rows,
                &keys,
                &cfg,
                first.samples,
            );
            requests
                .iter()
                .zip(&outs)
                .map(|(req, out)| {
                    let ServeRequest::Elbo(r) = req else { unreachable!() };
                    protocol::elbo_response(r, entry.fingerprint, out)
                })
                .collect()
        }
    }
}

/// The per-request **scalar oracle**: the same response computed with
/// one-request scalar engine calls (no batching anywhere). The serving
/// determinism contract is that every batched response byte-equals this
/// — `tests/serve.rs` and `sdegrad bench serve` assert it. The contract
/// is per-tier: the oracle must score the ELBO under the same kernel
/// tier the batcher runs.
pub fn scalar_response(
    entry: &ModelEntry,
    req: &ServeRequest,
    tier: KernelTier,
) -> Result<Vec<u8>, ApiError> {
    protocol::validate_for_model(req, entry.model.cfg.obs_dim)?;
    let dz = entry.model.cfg.latent_dim;
    let dx = entry.model.cfg.obs_dim;
    match req {
        ServeRequest::Simulate(r) => {
            let latent = sample_prior_path(
                &entry.model,
                &entry.params,
                &r.times,
                r.substeps,
                req.key(),
                None,
            );
            let decoded = decode_path(&entry.model, &entry.params, &latent);
            Ok(protocol::simulate_response(r, entry.fingerprint, &latent, dz, &decoded, dx))
        }
        ServeRequest::Reconstruct(r) => {
            let latent = sample_posterior_path(
                &entry.model,
                &entry.params,
                &r.times,
                &r.obs,
                r.substeps,
                req.key(),
            );
            let recon = decode_path(&entry.model, &entry.params, &latent);
            Ok(protocol::reconstruct_response(r, entry.fingerprint, &latent, dz, &recon, dx))
        }
        ServeRequest::Elbo(r) => {
            let cfg = ElboConfig { substeps: r.substeps, kl_weight: r.kl_weight, tier };
            let out = elbo_value_multi(
                &entry.model,
                &entry.params,
                &r.times,
                &r.obs,
                req.key(),
                &cfg,
                r.samples,
            );
            Ok(protocol::elbo_response(r, entry.fingerprint, &out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::latent::{LatentSdeConfig, LatentSdeModel};

    fn tiny_registry() -> Arc<ModelRegistry> {
        let cfg = LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            obs_noise_std: 0.1,
            ..Default::default()
        };
        let mut reg = ModelRegistry::new();
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(1));
        reg.insert("default", model, params).unwrap();
        Arc::new(reg)
    }

    fn times() -> Vec<f64> {
        (0..5).map(|k| 0.1 * k as f64).collect()
    }

    fn obs(seed: u64) -> Vec<f64> {
        let mut o = vec![0.0; 5 * 2];
        PrngKey::from_seed(seed).fill_normal(0, &mut o);
        o
    }

    fn sim(seed: u64) -> ServeRequest {
        ServeRequest::Simulate(protocol::SimulateRequest {
            model: "default".into(),
            seed,
            times: times(),
            substeps: 3,
        })
    }

    fn rec(seed: u64) -> ServeRequest {
        ServeRequest::Reconstruct(protocol::ReconstructRequest {
            model: "default".into(),
            seed,
            times: times(),
            obs: obs(seed + 1000),
            obs_row: 2,
            substeps: 3,
        })
    }

    fn elbo(seed: u64, samples: usize) -> ServeRequest {
        ServeRequest::Elbo(protocol::ElboRequest {
            model: "default".into(),
            seed,
            times: times(),
            obs: obs(seed + 2000),
            obs_row: 2,
            substeps: 3,
            samples,
            kl_weight: 0.4,
        })
    }

    #[test]
    fn compatibility_grouping_rules() {
        assert!(compatible(&sim(1), &sim(2)));
        assert!(compatible(&rec(1), &rec(2)));
        assert!(compatible(&elbo(1, 2), &elbo(9, 2)));
        assert!(!compatible(&sim(1), &rec(1)));
        assert!(!compatible(&elbo(1, 2), &elbo(1, 3)), "sample counts differ");
        let mut other = sim(1);
        if let ServeRequest::Simulate(r) = &mut other {
            r.substeps = 4;
        }
        assert!(!compatible(&sim(1), &other), "substeps differ");
        let mut neg_zero = sim(1);
        if let ServeRequest::Simulate(r) = &mut neg_zero {
            r.times[0] = -0.0;
        }
        assert!(!compatible(&sim(1), &neg_zero), "-0.0 and 0.0 must not group");
    }

    /// A mixed drained queue, processed as groups of batched engine
    /// calls, must answer every request byte-identically to the scalar
    /// oracle — the micro-batcher's core contract.
    #[test]
    fn mixed_batch_responses_equal_scalar_oracle_bytes() {
        let registry = tiny_registry();
        let requests: Vec<ServeRequest> = vec![
            sim(1),
            elbo(2, 2),
            sim(3),
            rec(4),
            elbo(5, 2),
            rec(6),
            sim(7),
            elbo(8, 3), // different sample count: its own group
        ];
        let entry = registry.get("default").unwrap();
        let expected: Vec<Vec<u8>> = requests
            .iter()
            .map(|r| scalar_response(entry, r, KernelTier::Exact).unwrap())
            .collect();

        let mut rxs = Vec::new();
        let mut jobs = Vec::new();
        for r in &requests {
            let (tx, rx) = mpsc::channel();
            jobs.push(Job { request: r.clone(), resp: tx });
            rxs.push(rx);
        }
        process_batch(&registry, jobs, KernelTier::Exact);
        for (i, rx) in rxs.iter().enumerate() {
            let got = rx.recv().expect("no response").expect("error response");
            assert_eq!(got, expected[i], "request {i} diverged from the scalar oracle");
        }
    }

    /// Obs shape is not part of the grouping key, so a malformed request
    /// can land in a group with valid ones: it must get its own 400 while
    /// the valid request still gets its oracle-identical answer (and the
    /// dispatcher survives — no engine assert fires).
    #[test]
    fn invalid_job_in_group_gets_400_without_poisoning_the_batch() {
        let registry = tiny_registry();
        let good = rec(1);
        let mut bad = rec(2);
        if let ServeRequest::Reconstruct(r) = &mut bad {
            r.obs = vec![0.0; 5 * 3]; // 3-wide rows on a 2-dim model
            r.obs_row = 3;
        }
        let expected = {
            let entry = registry.get("default").unwrap();
            scalar_response(entry, &good, KernelTier::Exact).unwrap()
        };
        let (tx1, rx1) = mpsc::channel();
        let (tx2, rx2) = mpsc::channel();
        process_batch(
            &registry,
            vec![Job { request: good, resp: tx1 }, Job { request: bad, resp: tx2 }],
            KernelTier::Exact,
        );
        assert_eq!(rx1.recv().unwrap().unwrap(), expected);
        let err = rx2.recv().unwrap().unwrap_err();
        assert_eq!((err.status, err.code), (400, "bad_request"));
    }

    #[test]
    fn unknown_model_answers_every_job_in_the_group() {
        let registry = tiny_registry();
        let mut bad = sim(1);
        if let ServeRequest::Simulate(r) = &mut bad {
            r.model = "missing".into();
        }
        let (tx, rx) = mpsc::channel();
        process_batch(&registry, vec![Job { request: bad, resp: tx }], KernelTier::Exact);
        let err = rx.recv().unwrap().unwrap_err();
        assert_eq!(err.status, 404);
        assert_eq!(err.code, "unknown_model");
    }

    #[test]
    fn batcher_thread_round_trips_and_shuts_down() {
        let registry = tiny_registry();
        let entry_bytes = {
            let entry = registry.get("default").unwrap();
            scalar_response(entry, &sim(42), KernelTier::Exact).unwrap()
        };
        let cfg = BatcherConfig { max_batch: 4, max_wait_us: 100, ..Default::default() };
        let batcher = Batcher::start(registry, cfg);
        let got = batcher.submit(sim(42)).unwrap();
        assert_eq!(got, entry_bytes);
        batcher.shutdown();
    }
}
