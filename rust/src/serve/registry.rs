//! The model registry: named [`LatentSdeModel`]s + parameter vectors
//! loaded from checkpoints, each with a **fingerprint** — an FNV-1a hash
//! over the architecture and every parameter bit. The fingerprint is
//! echoed in every response and keyed into the response cache, so a
//! cached answer can never be served across a checkpoint swap or model
//! mismatch.

use crate::coordinator::checkpoint::load_any_params;
use crate::error::Result;
use crate::latent::{DiffusionMode, EncoderKind, LatentSdeConfig, LatentSdeModel};
use crate::{bail, ensure};

/// One served model.
pub struct ModelEntry {
    pub name: String,
    pub model: LatentSdeModel,
    pub params: Vec<f64>,
    pub fingerprint: u64,
}

/// Named models available to the server.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<ModelEntry>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        ModelRegistry { entries: Vec::new() }
    }

    /// Register a model under `name` with an in-memory parameter vector
    /// (tests and the bench harness; checkpoint files go through
    /// [`ModelRegistry::load_checkpoint`]).
    pub fn insert(&mut self, name: &str, model: LatentSdeModel, params: Vec<f64>) -> Result<()> {
        ensure!(!name.is_empty(), "model name must be non-empty");
        ensure!(
            self.get(name).is_none(),
            "a model named {name:?} is already registered"
        );
        ensure!(
            params.len() == model.n_params,
            "checkpoint has {} parameters but the {name:?} architecture needs {} — \
             wrong --dataset/--mode for this checkpoint?",
            params.len(),
            model.n_params
        );
        ensure!(
            params.iter().all(|p| p.is_finite()),
            "checkpoint for {name:?} contains non-finite parameters"
        );
        let fingerprint = fingerprint_model(&model.cfg, &params);
        self.entries.push(ModelEntry { name: name.to_string(), model, params, fingerprint });
        Ok(())
    }

    /// Load a checkpoint file (either `SDEGRAD1` params or `SDEGRAD2`
    /// training state) and register it under `name` with the given
    /// architecture. A corrupt/truncated file or a parameter-count
    /// mismatch surfaces as a clean `Err` — the `sdegrad serve` startup
    /// error path.
    pub fn load_checkpoint(
        &mut self,
        name: &str,
        cfg: LatentSdeConfig,
        path: &str,
    ) -> Result<()> {
        let params = load_any_params(path)?;
        self.insert(name, LatentSdeModel::new(cfg), params)
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// `(name, fingerprint)` pairs for `/healthz`.
    pub fn models(&self) -> Vec<(String, u64)> {
        self.entries.iter().map(|e| (e.name.clone(), e.fingerprint)).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The model architecture each built-in dataset's training run uses —
/// one source of truth shared by `sdegrad train` and `sdegrad serve`, so
/// a checkpoint trained with `--dataset X` is served with `--dataset X`
/// and the architectures cannot drift apart.
pub fn dataset_model_config(dataset: &str) -> Option<LatentSdeConfig> {
    match dataset {
        "gbm" => Some(LatentSdeConfig {
            obs_dim: 1,
            latent_dim: 4,
            context_dim: 1,
            hidden: 64,
            enc_hidden: 64,
            obs_noise_std: 0.05,
            ..Default::default()
        }),
        "lorenz" => Some(LatentSdeConfig {
            obs_dim: 3,
            latent_dim: 4,
            context_dim: 1,
            hidden: 64,
            enc_hidden: 64,
            obs_noise_std: 0.05,
            ..Default::default()
        }),
        "mocap" => Some(LatentSdeConfig {
            obs_dim: 50,
            latent_dim: 6,
            context_dim: 3,
            hidden: 30,
            enc_hidden: 30,
            encoder: EncoderKind::FirstFramesMlp { n_frames: 3 },
            obs_noise_std: 0.1,
            ..Default::default()
        }),
        _ => None,
    }
}

/// Apply a `--mode sde|ode` flag to a dataset architecture.
pub fn apply_mode(cfg: LatentSdeConfig, mode: &str) -> Result<LatentSdeConfig> {
    match mode {
        "sde" => Ok(cfg),
        "ode" => Ok(LatentSdeConfig { diffusion: DiffusionMode::Off, ..cfg }),
        other => bail!("unknown mode {other:?} (expected sde or ode)"),
    }
}

/// FNV-1a over the architecture hyperparameters and every parameter bit:
/// two entries share a fingerprint iff they would produce identical
/// responses.
pub fn fingerprint_model(cfg: &LatentSdeConfig, params: &[f64]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01B3;
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(PRIME);
    };
    mix(cfg.obs_dim as u64);
    mix(cfg.latent_dim as u64);
    mix(cfg.context_dim as u64);
    mix(cfg.hidden as u64);
    mix(cfg.diff_hidden as u64);
    mix(cfg.enc_hidden as u64);
    match cfg.encoder {
        EncoderKind::GruBackward => mix(1),
        EncoderKind::FirstFramesMlp { n_frames } => {
            mix(2);
            mix(n_frames as u64);
        }
    }
    match cfg.diffusion {
        DiffusionMode::PerDimNets { floor, scale } => {
            mix(1);
            mix(floor.to_bits());
            mix(scale.to_bits());
        }
        DiffusionMode::Off => mix(2),
    }
    mix(cfg.obs_noise_std.to_bits());
    mix(params.len() as u64);
    for p in params {
        mix(p.to_bits());
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::checkpoint::{save_params, save_state, TrainState};
    use crate::prng::PrngKey;

    fn tiny_cfg() -> LatentSdeConfig {
        LatentSdeConfig {
            obs_dim: 2,
            latent_dim: 3,
            context_dim: 2,
            hidden: 8,
            diff_hidden: 4,
            enc_hidden: 6,
            ..Default::default()
        }
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let cfg = tiny_cfg();
        let model = LatentSdeModel::new(cfg);
        let params = model.init_params(PrngKey::from_seed(1));
        let a = fingerprint_model(&cfg, &params);
        assert_eq!(a, fingerprint_model(&cfg, &params), "fingerprint not deterministic");
        let mut bumped = params.clone();
        bumped[7] += 1e-12;
        assert_ne!(a, fingerprint_model(&cfg, &bumped), "parameter bit flip unseen");
        let other_cfg = LatentSdeConfig { diffusion: DiffusionMode::Off, ..cfg };
        let ode = LatentSdeModel::new(other_cfg);
        let p_ode = ode.init_params(PrngKey::from_seed(1));
        assert_ne!(a, fingerprint_model(&other_cfg, &p_ode), "architecture change unseen");
    }

    #[test]
    fn registry_serves_multiple_named_models_and_rejects_mismatches() {
        let mut reg = ModelRegistry::new();
        let m1 = LatentSdeModel::new(tiny_cfg());
        let p1 = m1.init_params(PrngKey::from_seed(2));
        reg.insert("alpha", m1, p1).unwrap();
        let m2 = LatentSdeModel::new(tiny_cfg());
        let p2 = m2.init_params(PrngKey::from_seed(3));
        reg.insert("beta", m2, p2).unwrap();

        assert!(reg.get("alpha").is_some());
        assert!(reg.get("beta").is_some());
        assert!(reg.get("gamma").is_none());
        assert_ne!(
            reg.get("alpha").unwrap().fingerprint,
            reg.get("beta").unwrap().fingerprint
        );
        assert_eq!(reg.models().len(), 2);

        // Duplicate name.
        let m3 = LatentSdeModel::new(tiny_cfg());
        let p3 = m3.init_params(PrngKey::from_seed(4));
        assert!(reg.insert("alpha", m3, p3).is_err());

        // Wrong parameter count.
        let m4 = LatentSdeModel::new(tiny_cfg());
        assert!(reg.insert("short", m4, vec![1.0; 3]).unwrap_err().to_string().contains("param"));
    }

    #[test]
    fn loads_both_checkpoint_formats_and_reports_corruption() {
        let dir = std::env::temp_dir().join("sdegrad_serve_registry");
        let model = LatentSdeModel::new(tiny_cfg());
        let params = model.init_params(PrngKey::from_seed(5));

        let p_params = dir.join("params.bin");
        save_params(&p_params, &params).unwrap();
        let p_state = dir.join("state.bin");
        save_state(
            &p_state,
            &TrainState {
                params: params.clone(),
                adam_m: vec![0.0; params.len()],
                adam_v: vec![0.0; params.len()],
                adam_t: 1,
                iter: 1,
                fingerprint: 0,
            },
        )
        .unwrap();

        let mut reg = ModelRegistry::new();
        reg.load_checkpoint("from-params", tiny_cfg(), p_params.to_str().unwrap()).unwrap();
        reg.load_checkpoint("from-state", tiny_cfg(), p_state.to_str().unwrap()).unwrap();
        // Identical params + architecture ⇒ identical fingerprints.
        assert_eq!(
            reg.get("from-params").unwrap().fingerprint,
            reg.get("from-state").unwrap().fingerprint
        );

        // Truncated checkpoint → clean startup error, not a panic.
        let full = std::fs::read(&p_state).unwrap();
        let p_cut = dir.join("cut.bin");
        std::fs::write(&p_cut, &full[..full.len() / 2]).unwrap();
        let err = reg
            .load_checkpoint("corrupt", tiny_cfg(), p_cut.to_str().unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("corrupt") || err.contains("truncated"), "{err}");
    }

    #[test]
    fn dataset_configs_cover_the_training_datasets() {
        for ds in ["gbm", "lorenz", "mocap"] {
            let cfg = dataset_model_config(ds).expect(ds);
            // Each config must build a valid model.
            let _ = LatentSdeModel::new(apply_mode(cfg, "ode").unwrap());
            let _ = LatentSdeModel::new(cfg);
        }
        assert!(dataset_model_config("nope").is_none());
        assert!(apply_mode(dataset_model_config("gbm").unwrap(), "weird").is_err());
    }
}
