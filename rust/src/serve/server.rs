//! The HTTP front end: a TCP listener, a minimal HTTP/1.1 request
//! parser, and a worker-thread pool, all std-only (the hermetic crate
//! set has no async runtime — connections are cheap threads + blocking
//! I/O, the same model as the rest of the crate's parallelism).
//!
//! Request flow per connection (one request per connection,
//! `Connection: close`): worker reads + parses HTTP, parses + validates
//! the JSON body ([`super::protocol`]), probes the response cache, and
//! otherwise routes the request to its dispatcher shard through the
//! sharded micro-batcher ([`super::batcher`]) and blocks for the
//! computed bytes. Admission control lives in the batcher: a shard over
//! its queue budget sheds with 429 + `Retry-After` instead of queueing
//! unbounded work. Long `/v1/simulate` bodies stream back with
//! `Transfer-Encoding: chunked` (same bytes, framed incrementally).
//! `GET /metrics` reports per-shard queue counters, the batch-occupancy
//! histogram, per-shard queue-wait / engine-time latency histograms,
//! cache hit rates, process-wide engine counters, and the whole
//! [`crate::obs`] metrics registry as strict JSON (field table in the
//! [`super`] module docs). The request lifecycle is traced with spans
//! (`serve.parse` → `serve.assembly` → `serve.engine` →
//! `serve.serialize`) when span collection is on. Errors at every layer
//! map to JSON error bodies with stable codes:
//!
//! | status | code | trigger |
//! |---|---|---|
//! | 400 | `bad_json` / `bad_request` | malformed JSON / bad fields or shapes |
//! | 404 | `unknown_endpoint` / `unknown_model` | no such path / no such model |
//! | 405 | `method_not_allowed` | e.g. GET on a `/v1/*` endpoint |
//! | 408 | `timeout` | the connection exceeded the per-request deadline |
//! | 413 | `body_too_large` | body exceeds `max_body_bytes` |
//! | 429 | `overloaded` | shard queue over budget — retry per `Retry-After` |
//! | 500 | `internal` | batcher unavailable / engine call failed |

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use super::batcher::{Batcher, BatcherConfig, BatcherHandle, OCCUPANCY_BUCKETS};
use super::cache::{cache_key, ResponseCache};
use super::protocol::{self, ApiError};
use super::registry::ModelRegistry;
use crate::ensure;
use crate::error::{Context, Result};
use crate::runtime::ExecConfig;

/// Maximum bytes of request line + headers.
const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Per-`read()` socket timeout.
const IO_TIMEOUT: Duration = Duration::from_secs(10);
/// Whole-connection deadline for receiving one request. `IO_TIMEOUT`
/// bounds each read, but a client trickling one byte per read could
/// otherwise pin a worker for MAX_HEAD_BYTES reads; this bounds the
/// total (checked between reads in [`read_request`] and the post-error
/// drain).
const CONN_DEADLINE: Duration = Duration::from_secs(30);
/// Chunk size for `Transfer-Encoding: chunked` streaming.
const STREAM_CHUNK_BYTES: usize = 4096;

/// Server configuration (`sdegrad serve` flags map 1:1 onto these).
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Interface to bind. Defaults to loopback — `sdegrad serve` is not
    /// reachable from other hosts unless `--bind 0.0.0.0` (or a specific
    /// interface address) is passed explicitly.
    pub host: std::net::IpAddr,
    /// Listen port (0 = OS-assigned ephemeral port, reported by
    /// [`Server::addr`] — how the tests and the load harness bind).
    pub port: u16,
    /// HTTP worker threads (concurrent connections in flight).
    pub workers: usize,
    /// Micro-batcher: maximum requests per batched engine call.
    pub max_batch: usize,
    /// Micro-batcher: how long to wait for more requests after the
    /// first, in microseconds.
    pub max_wait_us: u64,
    /// Dispatcher shards (`--shards`); forwarded to
    /// [`BatcherConfig::shards`].
    pub shards: usize,
    /// Per-shard admission budget in request cells (`--queue-cells`);
    /// forwarded to [`BatcherConfig::queue_cells`]. Over-budget requests
    /// get 429 + `Retry-After`.
    pub queue_cells: usize,
    /// 200 responses on `/v1/simulate` at least this many bytes long
    /// stream back with `Transfer-Encoding: chunked`
    /// (`--stream-threshold`). `usize::MAX` disables streaming.
    pub stream_threshold_bytes: usize,
    /// LRU response-cache entries (0 disables caching).
    pub cache_capacity: usize,
    /// Maximum request-body bytes.
    pub max_body_bytes: usize,
    /// Execution configuration for the engine calls (`--tier
    /// exact|fast`); forwarded to [`BatcherConfig::exec`]. Replaces the
    /// pre-0.2 `tier` field — [`ServeConfig::tier`] delegates.
    pub exec: ExecConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            host: std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
            port: 7878,
            // Same capped available-parallelism default as the trainer.
            workers: crate::coordinator::config::num_threads(),
            max_batch: 16,
            max_wait_us: 500,
            shards: 1,
            queue_cells: super::batcher::DEFAULT_QUEUE_CELLS,
            stream_threshold_bytes: 64 * 1024,
            cache_capacity: 1024,
            max_body_bytes: 1 << 20,
            exec: ExecConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Set the kernel tier (delegates to `exec.tier` — the pre-0.2
    /// `tier` field's replacement).
    pub fn tier(mut self, tier: crate::sde::KernelTier) -> Self {
        self.exec.tier = tier;
        self
    }

    /// Replace the whole execution configuration.
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }
}

/// A running server: accept thread + worker pool + sharded batcher.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
    batcher: Option<Batcher>,
}

impl Server {
    /// Bind, spawn the accept/worker/batcher threads, and return
    /// immediately. The server answers until [`Server::shutdown`] (or
    /// process exit; [`Server::run`] blocks for the CLI).
    pub fn start(registry: ModelRegistry, cfg: ServeConfig) -> Result<Server> {
        ensure!(cfg.workers > 0, "need at least one worker thread");
        ensure!(!registry.is_empty(), "no models loaded — nothing to serve");
        let registry = Arc::new(registry);
        let listener = TcpListener::bind((cfg.host, cfg.port))
            .with_context(|| format!("binding {}:{}", cfg.host, cfg.port))?;
        let addr = listener.local_addr().context("reading bound address")?;

        let batcher = Batcher::start(
            registry.clone(),
            BatcherConfig {
                max_batch: cfg.max_batch,
                max_wait_us: cfg.max_wait_us,
                shards: cfg.shards,
                queue_cells: cfg.queue_cells,
                exec: cfg.exec,
            },
        );
        // None when disabled, so the hot path skips canonicalization, the
        // shared lock, and the response clone entirely.
        let cache = (cfg.cache_capacity > 0)
            .then(|| Arc::new(Mutex::new(ResponseCache::new(cfg.cache_capacity))));
        let stop = Arc::new(AtomicBool::new(false));

        // Bounded handoff queue: when every worker is busy and the queue
        // is full, the accept thread blocks in send(), pushing
        // backpressure into the OS listen backlog instead of buffering
        // an unbounded pile of open sockets (fd exhaustion under a
        // connection flood).
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.workers * 4);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut worker_handles = Vec::with_capacity(cfg.workers);
        for w in 0..cfg.workers {
            let conn_rx = conn_rx.clone();
            let registry = registry.clone();
            let cache = cache.clone();
            let handle = batcher.handle();
            let max_body = cfg.max_body_bytes;
            let stream_threshold = cfg.stream_threshold_bytes;
            let worker = std::thread::Builder::new()
                .name(format!("sdegrad-serve-{w}"))
                .spawn(move || loop {
                    // Take one connection; exit when the accept thread is
                    // gone and the queue is drained.
                    let stream = {
                        let rx = conn_rx.lock().unwrap_or_else(|e| e.into_inner());
                        rx.recv()
                    };
                    match stream {
                        Ok(s) => handle_connection(
                            s,
                            &registry,
                            cache.as_deref(),
                            &handle,
                            max_body,
                            stream_threshold,
                        ),
                        Err(_) => break,
                    }
                })
                .expect("spawning serve worker");
            worker_handles.push(worker);
        }

        let accept_stop = stop.clone();
        let accept_handle = std::thread::Builder::new()
            .name("sdegrad-serve-accept".into())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_stop.load(Ordering::SeqCst) {
                        break;
                    }
                    match stream {
                        Ok(s) => {
                            if conn_tx.send(s).is_err() {
                                break;
                            }
                        }
                        // Transient accept errors (EMFILE under load,
                        // aborted handshakes): back off briefly instead
                        // of spinning a hot error loop.
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
                // conn_tx drops here: workers drain and exit.
            })
            .expect("spawning serve accept thread");

        Ok(Server {
            addr,
            stop,
            accept_handle: Some(accept_handle),
            worker_handles,
            batcher: Some(batcher),
        })
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block on the accept thread — the CLI's serve-forever mode.
    pub fn run(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, drain in-flight connections, and join every
    /// thread (accept → workers → batcher shards).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
        if let Some(b) = self.batcher.take() {
            // Workers (and their blocking submits) are done; the shards
            // drain whatever is left and join cleanly.
            b.shutdown();
        }
    }
}

fn find_subsequence(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Classify a socket read error: the per-read `IO_TIMEOUT` firing is a
/// timeout (408, matching the documented error table), not a client
/// protocol error.
fn read_error(e: std::io::Error, what: &str) -> ApiError {
    match e.kind() {
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => ApiError::timeout(),
        _ => ApiError::bad_request(format!("reading {what}: {e}")),
    }
}

/// Read, route, and answer one request; always closes the connection.
fn handle_connection(
    mut stream: TcpStream,
    registry: &ModelRegistry,
    cache: Option<&Mutex<ResponseCache>>,
    handle: &BatcherHandle,
    max_body: usize,
    stream_threshold: usize,
) {
    let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
    let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
    let deadline = std::time::Instant::now() + CONN_DEADLINE;
    let (status, body, streamable, unread_input) =
        match read_request(&mut stream, max_body, deadline) {
            Ok(Some((method, path, body))) => {
                let (status, body) = route(&method, &path, &body, registry, cache, handle);
                // Only successful simulate payloads stream: they carry
                // whole decoded paths and dominate long-response traffic.
                (status, body, path == "/v1/simulate", false)
            }
            Ok(None) => return, // client closed before sending a request
            Err(e) => (e.status, e.body(), false, true),
        };
    {
        let _span = crate::obs::span!("serve.serialize");
        if streamable && status == 200 && body.len() >= stream_threshold {
            write_chunked_response(&mut stream, status, &body);
        } else {
            write_response(&mut stream, status, &body);
        }
    }
    if unread_input {
        // An early error reply (e.g. 413) can leave request bytes unread;
        // closing then would RST the connection and could destroy the
        // response before the client reads it. Half-close our side and
        // drain what the client already sent so the close is clean. The
        // drain gets its OWN short grace deadline — for a 408 the request
        // deadline has by definition already passed, and reusing it would
        // skip the drain exactly when it was needed.
        let drain_deadline = std::time::Instant::now() + Duration::from_secs(5);
        let _ = stream.shutdown(std::net::Shutdown::Write);
        let mut sink = [0u8; 4096];
        let mut budget: usize = 4 * 1024 * 1024;
        while budget > 0 && std::time::Instant::now() < drain_deadline {
            match stream.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(n) => budget -= n.min(budget),
            }
        }
    }
}

/// Parse one HTTP/1.1 request; returns `(method, path, body)`.
#[allow(clippy::type_complexity)]
fn read_request(
    stream: &mut TcpStream,
    max_body: usize,
    deadline: std::time::Instant,
) -> std::result::Result<Option<(String, String, Vec<u8>)>, ApiError> {
    let mut buf = Vec::new();
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_subsequence(&buf, b"\r\n\r\n") {
            break pos + 4;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ApiError::bad_request("request head exceeds 16 KiB"));
        }
        if std::time::Instant::now() >= deadline {
            return Err(ApiError::timeout());
        }
        let n = stream.read(&mut tmp).map_err(|e| read_error(e, "request"))?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(ApiError::bad_request("connection closed mid-request"));
        }
        buf.extend_from_slice(&tmp[..n]);
    };

    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path)) = (parts.next(), parts.next()) else {
        return Err(ApiError::bad_request("malformed request line"));
    };
    let method = method.to_string();
    let path = path.to_string();

    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            if name.trim().eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse()
                    .map_err(|_| ApiError::bad_request("bad Content-Length"))?;
            }
        }
    }
    if content_length > max_body {
        return Err(ApiError::body_too_large(max_body));
    }

    let mut body = buf[head_end..].to_vec();
    while body.len() < content_length {
        if std::time::Instant::now() >= deadline {
            return Err(ApiError::timeout());
        }
        let n = stream.read(&mut tmp).map_err(|e| read_error(e, "body"))?;
        if n == 0 {
            return Err(ApiError::bad_request("connection closed mid-body"));
        }
        body.extend_from_slice(&tmp[..n]);
        if body.len() > max_body {
            return Err(ApiError::body_too_large(max_body));
        }
    }
    body.truncate(content_length);
    Ok(Some((method, path, body)))
}

const API_ENDPOINTS: [&str; 3] = ["/v1/simulate", "/v1/reconstruct", "/v1/elbo"];

fn route(
    method: &str,
    path: &str,
    body: &[u8],
    registry: &ModelRegistry,
    cache: Option<&Mutex<ResponseCache>>,
    handle: &BatcherHandle,
) -> (u16, Vec<u8>) {
    match (method, path) {
        ("GET", "/healthz") => (200, protocol::healthz_response(&registry.models())),
        ("GET", "/metrics") => (200, metrics_response(handle, cache)),
        ("POST", p) if API_ENDPOINTS.contains(&p) => {
            let Ok(body) = std::str::from_utf8(body) else {
                let e = ApiError::bad_json("request body is not UTF-8");
                return (e.status, e.body());
            };
            match answer_api(p, body, registry, cache, handle) {
                Ok(bytes) => (200, bytes),
                Err(e) => (e.status, e.body()),
            }
        }
        (_, p) if p == "/healthz" || p == "/metrics" || API_ENDPOINTS.contains(&p) => {
            let e = ApiError::method_not_allowed(method, p);
            (e.status, e.body())
        }
        (_, p) => {
            let e = ApiError::unknown_endpoint(p);
            (e.status, e.body())
        }
    }
}

/// The `GET /metrics` body: per-shard queue/batch counters and latency
/// histograms, totals, cache hit statistics, process-wide engine
/// counters, and the full [`crate::obs`] metrics registry. Built by
/// hand from integers only (no floats), so the output is strict JSON
/// by construction and byte-stable for a given counter state. The
/// field-by-field table lives in the [`super`] module docs.
fn metrics_response(handle: &BatcherHandle, cache: Option<&Mutex<ResponseCache>>) -> Vec<u8> {
    let snaps = handle.snapshots();
    let mut out = String::with_capacity(256 + 160 * snaps.len());
    out.push_str("{\"shards\":[");
    for (i, s) in snaps.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"shard\":{i},\"depth\":{},\"queued_cells\":{},\"submitted\":{},\
             \"shed\":{},\"batches\":{},\"jobs\":{},\"occupancy\":[",
            s.depth, s.queued_cells, s.submitted, s.shed, s.batches, s.jobs
        ));
        for (j, c) in s.occupancy.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&c.to_string());
        }
        out.push_str(&format!("],\"assembly_us\":{},\"queue_wait_us\":", s.assembly_us));
        push_bucket_counts(&mut out, &s.queue_wait_us);
        out.push_str(",\"engine_us\":");
        push_bucket_counts(&mut out, &s.engine_us);
        out.push('}');
    }
    // Bucket upper bounds so a scraper can label the histogram without
    // hardcoding them (the last bucket is open-ended).
    out.push_str("],\"occupancy_le\":[");
    for (j, &hi) in OCCUPANCY_BUCKETS.iter().enumerate() {
        if j > 0 {
            out.push(',');
        }
        if hi == usize::MAX {
            out.push_str("null");
        } else {
            out.push_str(&hi.to_string());
        }
    }
    let totals = |f: fn(&super::batcher::ShardSnapshot) -> u64| -> u64 {
        snaps.iter().map(f).sum()
    };
    out.push_str(&format!(
        "],\"totals\":{{\"submitted\":{},\"shed\":{},\"batches\":{},\"jobs\":{}}}",
        totals(|s| s.submitted),
        totals(|s| s.shed),
        totals(|s| s.batches),
        totals(|s| s.jobs),
    ));
    let (hits, misses, entries) = cache
        .map(|c| {
            let c = c.lock().unwrap_or_else(|e| e.into_inner());
            let (h, m) = c.stats();
            (h, m, c.len() as u64)
        })
        .unwrap_or((0, 0, 0));
    out.push_str(&format!(
        ",\"cache\":{{\"hits\":{hits},\"misses\":{misses},\"entries\":{entries}}}"
    ));
    out.push_str(&format!(
        ",\"engine\":{{\"bridge_calls\":{},\"pool_workers\":{},\"pool_spawned\":{}}}",
        crate::metrics::counters::bridge_calls_total(),
        crate::runtime::worker_count(),
        crate::runtime::spawned_workers(),
    ));
    // The whole metrics registry (counters/gauges/histograms from every
    // subsystem — see [`crate::obs`]), as one nested object.
    out.push_str(",\"registry\":");
    out.push_str(&crate::obs::dump_json());
    out.push('}');
    out.into_bytes()
}

/// Append histogram bucket counts as a JSON array, trailing zero buckets
/// dropped (the power-of-two index→bound mapping is unchanged — see
/// [`crate::obs::hist`]).
fn push_bucket_counts(out: &mut String, counts: &[u64]) {
    let len = counts.len() - counts.iter().rev().take_while(|&&c| c == 0).count();
    out.push('[');
    for (j, c) in counts[..len].iter().enumerate() {
        if j > 0 {
            out.push(',');
        }
        out.push_str(&c.to_string());
    }
    out.push(']');
}

/// Parse → validate → cache probe → sharded micro-batcher → cache fill.
fn answer_api(
    path: &str,
    body: &str,
    registry: &ModelRegistry,
    cache: Option<&Mutex<ResponseCache>>,
    handle: &BatcherHandle,
) -> std::result::Result<Vec<u8>, ApiError> {
    let span_parse = crate::obs::span!("serve.parse");
    let req = protocol::parse_request(path, body)?;
    let entry = registry
        .get(req.model())
        .ok_or_else(|| ApiError::unknown_model(req.model()))?;
    protocol::validate_for_model(&req, entry.model.cfg.obs_dim)?;
    drop(span_parse);

    let key =
        cache.map(|_| cache_key(req.endpoint(), entry.fingerprint, &req.canonical()));
    if let (Some(c), Some(k)) = (cache, &key) {
        if let Some(hit) = c.lock().unwrap_or_else(|e| e.into_inner()).get(k) {
            // Byte-identical to the miss that filled it: the cached value
            // IS those bytes.
            return Ok(hit);
        }
    }
    let bytes = handle.submit(req)?;
    if let (Some(c), Some(k)) = (cache, key) {
        c.lock().unwrap_or_else(|e| e.into_inner()).put(k, bytes.clone());
    }
    Ok(bytes)
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "Error",
    }
}

/// Headers every response shares. 429s carry `Retry-After: 1` — the
/// admission budget is sized in sub-second queue drains, so "one second"
/// is an honest earliest-retry hint.
fn common_headers(status: u16) -> &'static str {
    if status == 429 {
        "Content-Type: application/json\r\nRetry-After: 1\r\nConnection: close\r\n"
    } else {
        "Content-Type: application/json\r\nConnection: close\r\n"
    }
}

fn write_response(stream: &mut TcpStream, status: u16, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {status} {}\r\n{}Content-Length: {}\r\n\r\n",
        status_reason(status),
        common_headers(status),
        body.len()
    );
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body);
    let _ = stream.flush();
}

/// Stream `body` with `Transfer-Encoding: chunked` in
/// [`STREAM_CHUNK_BYTES`] frames. The de-chunked payload is the exact
/// same byte sequence `write_response` would have sent — framing is
/// transport, not content, so the scalar-oracle byte contract is
/// unchanged ([`super::client::request`] decodes and the tests compare
/// the decoded bytes).
fn write_chunked_response(stream: &mut TcpStream, status: u16, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {status} {}\r\n{}Transfer-Encoding: chunked\r\n\r\n",
        status_reason(status),
        common_headers(status),
    );
    let _ = stream.write_all(head.as_bytes());
    for chunk in body.chunks(STREAM_CHUNK_BYTES) {
        let _ = stream.write_all(format!("{:x}\r\n", chunk.len()).as_bytes());
        let _ = stream.write_all(chunk);
        let _ = stream.write_all(b"\r\n");
    }
    let _ = stream.write_all(b"0\r\n\r\n");
    let _ = stream.flush();
}

#[cfg(test)]
mod tests {
    // The end-to-end suite (concurrent clients over a real socket,
    // response invariance across batch layouts / shard counts / cache
    // states, the full error table, /metrics, overload shedding) lives
    // in `tests/serve.rs`; here we only pin the HTTP head parser and the
    // chunked writer via loopback socket pairs.
    use super::*;

    #[test]
    fn read_request_parses_method_path_and_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            read_request(&mut s, 1024, deadline)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(
            b"POST /v1/simulate HTTP/1.1\r\nHost: x\r\ncontent-length: 4\r\n\r\nbody",
        )
        .unwrap();
        let (method, path, body) = t.join().unwrap().unwrap().unwrap();
        assert_eq!(method, "POST");
        assert_eq!(path, "/v1/simulate");
        assert_eq!(body, b"body");
    }

    #[test]
    fn read_request_rejects_oversized_declared_body() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let deadline = std::time::Instant::now() + Duration::from_secs(5);
            read_request(&mut s, 16, deadline)
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"POST /v1/elbo HTTP/1.1\r\nContent-Length: 99\r\n\r\n").unwrap();
        let err = t.join().unwrap().unwrap_err();
        assert_eq!(err.status, 413);
        assert_eq!(err.code, "body_too_large");
    }

    /// The chunked writer's framing must decode back to the exact input
    /// bytes, with a `Retry-After`-free 200 head and chunk sizes capped
    /// at [`STREAM_CHUNK_BYTES`].
    #[test]
    fn chunked_writer_round_trips_exact_bytes() {
        let body: Vec<u8> = (0..10_000u32).map(|i| (i % 251) as u8).collect();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let body_clone = body.clone();
        let t = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            write_chunked_response(&mut s, 200, &body_clone);
        });
        let mut c = TcpStream::connect(addr).unwrap();
        let mut raw = Vec::new();
        c.read_to_end(&mut raw).unwrap();
        t.join().unwrap();

        let head_end = raw.windows(4).position(|w| w == b"\r\n\r\n").unwrap() + 4;
        let head = std::str::from_utf8(&raw[..head_end]).unwrap();
        assert!(head.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(head.to_ascii_lowercase().contains("transfer-encoding: chunked"));
        assert!(!head.contains("Content-Length"));

        // Decode the chunk framing by hand.
        let mut decoded = Vec::new();
        let mut rest = &raw[head_end..];
        loop {
            let line_end = rest.windows(2).position(|w| w == b"\r\n").unwrap();
            let size =
                usize::from_str_radix(std::str::from_utf8(&rest[..line_end]).unwrap(), 16)
                    .unwrap();
            rest = &rest[line_end + 2..];
            if size == 0 {
                break;
            }
            assert!(size <= STREAM_CHUNK_BYTES, "chunk larger than the frame cap");
            decoded.extend_from_slice(&rest[..size]);
            assert_eq!(&rest[size..size + 2], b"\r\n");
            rest = &rest[size + 2..];
        }
        assert_eq!(decoded, body, "de-chunked payload must be the exact body bytes");
    }
}
