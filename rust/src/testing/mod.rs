//! Mini property-testing harness (proptest substitute — the vendored
//! crate set has no proptest; DESIGN.md §3).
//!
//! [`forall`] runs a property over `n` randomly generated cases from a
//! seeded [`Gen`]; on failure it reports the case index and seed so the
//! exact case is reproducible, and re-runs the property on progressively
//! "smaller" regenerated cases (halved magnitude) to report a simpler
//! counterexample when one exists.

use crate::prng::PrngKey;

/// Seeded random-case generator.
#[derive(Clone, Copy, Debug)]
pub struct Gen {
    key: PrngKey,
    ctr: u64,
    /// Magnitude multiplier used by shrinking.
    pub scale: f64,
}

impl Gen {
    pub fn new(seed: u64, case: u64) -> Self {
        Gen { key: PrngKey::from_seed(seed).fold_in(case), ctr: 0, scale: 1.0 }
    }

    fn next_u(&mut self) -> f64 {
        let v = self.key.uniform(self.ctr);
        self.ctr += 1;
        v
    }

    /// Uniform f64 in [lo, hi), scaled toward the midpoint by `scale`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let raw = lo + self.next_u() * (hi - lo);
        mid + (raw - mid) * self.scale
    }

    /// Standard normal draw (scaled by `scale`).
    pub fn normal(&mut self) -> f64 {
        let v = self.key.normal(self.ctr);
        self.ctr += 1;
        v * self.scale
    }

    /// Vector of normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// usize in [lo, hi).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u() * (hi - lo) as f64) as usize
    }
}

/// Run `prop` over `n_cases` generated cases. Panics with a reproducible
/// report on the first failure (after attempting shrink).
pub fn forall<P>(name: &str, seed: u64, n_cases: u64, mut prop: P)
where
    P: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..n_cases {
        let mut g = Gen::new(seed, case);
        if let Err(msg) = prop(&mut g) {
            // Shrink: re-run with smaller magnitudes; keep the smallest
            // failing scale's message.
            let mut final_msg = msg;
            let mut final_scale = 1.0;
            for k in 1..=4 {
                let scale = 0.5f64.powi(k);
                let mut gs = Gen::new(seed, case);
                gs.scale = scale;
                match prop(&mut gs) {
                    Err(m) => {
                        final_msg = m;
                        final_scale = scale;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (seed {seed}, case {case}, scale {final_scale}):\n{final_msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("abs-nonneg", 1, 50, |g| {
            let x = g.normal();
            if x.abs() >= 0.0 {
                Ok(())
            } else {
                Err(format!("abs({x}) < 0"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn forall_reports_failures() {
        forall("always-fails", 2, 10, |g| {
            let x = g.f64_in(0.0, 1.0);
            Err(format!("x = {x}"))
        });
    }

    #[test]
    fn gen_is_deterministic() {
        let mut a = Gen::new(3, 7);
        let mut b = Gen::new(3, 7);
        assert_eq!(a.normal_vec(5), b.normal_vec(5));
        assert_eq!(a.usize_in(0, 100), b.usize_in(0, 100));
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(4, 0);
        for _ in 0..100 {
            let v = g.f64_in(2.0, 5.0);
            assert!((2.0..5.0).contains(&v));
            let u = g.usize_in(3, 9);
            assert!((3..9).contains(&u));
        }
    }
}
