//! Artifact manifest parsing and compiled-executable registry.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::{bail, err};

/// One exported entry point.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    /// Input shapes; each inner vec is the dims of one f32 argument
    /// (empty = scalar).
    pub input_shapes: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.txt` (line-oriented `key=value`; see
/// `python/compile/aot.py` for the writer).
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Model dimensions recorded by the exporter.
    pub cfg: HashMap<String, String>,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load and parse `dir/manifest.txt`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let mut lines = text.lines();
        let first = lines.next().ok_or_else(|| err!("empty manifest"))?;
        if first.trim() != "format=sdegrad-artifacts-v1" {
            bail!("unknown manifest format line: {first}");
        }
        let mut cfg = HashMap::new();
        let mut entries = Vec::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("cfg ") {
                for tok in rest.split_whitespace() {
                    let (k, v) =
                        tok.split_once('=').ok_or_else(|| err!("bad cfg token {tok}"))?;
                    cfg.insert(k.to_string(), v.to_string());
                }
            } else if let Some(rest) = line.strip_prefix("entry ") {
                let mut toks = rest.split_whitespace();
                let name = toks.next().ok_or_else(|| err!("entry without name"))?.to_string();
                let mut file = String::new();
                let mut input_shapes = Vec::new();
                for tok in toks {
                    if let Some(v) = tok.strip_prefix("file=") {
                        file = v.to_string();
                    } else if let Some(v) = tok.strip_prefix("inputs=") {
                        for spec in v.split(';') {
                            if spec == "scalar" {
                                input_shapes.push(Vec::new());
                            } else {
                                let dims: Result<Vec<usize>, _> =
                                    spec.split('x').map(|d| d.parse::<usize>()).collect();
                                input_shapes.push(dims.context("bad shape in manifest")?);
                            }
                        }
                    }
                }
                if file.is_empty() {
                    bail!("entry {name} has no file=");
                }
                entries.push(ManifestEntry { name, file, input_shapes });
            }
        }
        Ok(Manifest { dir, cfg, entries })
    }

    /// A cfg value parsed as usize.
    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.cfg
            .get(key)
            .ok_or_else(|| err!("manifest cfg missing {key}"))?
            .parse()
            .with_context(|| format!("parsing cfg {key}"))
    }

    /// A cfg value parsed as f64.
    pub fn cfg_f64(&self, key: &str) -> Result<f64> {
        self.cfg
            .get(key)
            .ok_or_else(|| err!("manifest cfg missing {key}"))?
            .parse()
            .with_context(|| format!("parsing cfg {key}"))
    }
}

/// A compiled entry point, callable with f32 buffers.
///
/// With the `xla` cargo feature the entry is compiled through PJRT;
/// without it the manifest metadata is still inspectable but
/// [`Executable::call_f32`] returns a descriptive error.
pub struct Executable {
    pub entry: ManifestEntry,
    #[cfg(feature = "xla")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with flat f32 inputs (one slice per argument, shaped per
    /// the manifest). Returns the flat f32 outputs (tuple elements in
    /// order).
    #[cfg(feature = "xla")]
    pub fn call_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.entry.input_shapes.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.entry.input_shapes) {
            let expect: usize = shape.iter().product::<usize>().max(1);
            if buf.len() != expect {
                bail!(
                    "{}: input length {} != shape {:?} ({} elements)",
                    self.entry.name,
                    buf.len(),
                    shape,
                    expect
                );
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(lit.reshape(&dims).map_err(|e| err!("reshape: {e:?}"))?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err!("execute {}: {e:?}", self.entry.name))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("to_literal: {e:?}"))?;
        let parts = root.to_tuple().map_err(|e| err!("to_tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| err!("to_vec: {e:?}")))
            .collect()
    }

    /// Stub when the `xla` feature is off: execution is unavailable.
    #[cfg(not(feature = "xla"))]
    pub fn call_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        bail!(
            "{}: sdegrad was built without the `xla` feature — artifact \
             execution is disabled (rebuild with `--features xla` after \
             adding the xla crate)",
            self.entry.name
        )
    }
}

/// Loads and compiles artifacts on demand, caching executables by name.
pub struct ArtifactRegistry {
    pub manifest: Manifest,
    compiled: HashMap<String, Executable>,
}

impl ArtifactRegistry {
    /// Open the registry over an artifact directory (default
    /// `artifacts/`).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<ArtifactRegistry> {
        Ok(ArtifactRegistry { manifest: Manifest::load(dir)?, compiled: HashMap::new() })
    }

    /// Compile (or fetch the cached) entry point by name.
    pub fn get(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let entry = self
                .manifest
                .entries
                .iter()
                .find(|e| e.name == name)
                .ok_or_else(|| err!("no artifact entry named {name}"))?
                .clone();
            let exe = Self::compile_entry(&self.manifest.dir, entry)?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    #[cfg(feature = "xla")]
    fn compile_entry(dir: &Path, entry: ManifestEntry) -> Result<Executable> {
        use super::client::pjrt_client;
        let path = dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
        )
        .map_err(|e| err!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let client = pjrt_client()?;
        let exe = client.compile(&comp).map_err(|e| err!("compiling {}: {e:?}", entry.name))?;
        Ok(Executable { entry, exe })
    }

    /// Without the `xla` feature, `get` succeeds (so shapes stay
    /// inspectable) and execution fails in [`Executable::call_f32`].
    #[cfg(not(feature = "xla"))]
    fn compile_entry(_dir: &Path, entry: ManifestEntry) -> Result<Executable> {
        Ok(Executable { entry })
    }

    /// Names of all exported entries.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.iter().map(|e| e.name.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("manifest.txt").exists()
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(artifacts_dir()).expect("manifest");
        assert!(m.entries.len() >= 5, "entries: {:?}", m.entries.len());
        assert!(m.cfg_usize("n_params").unwrap() > 1000);
        let post = m.entries.iter().find(|e| e.name == "post_drift_fwd").unwrap();
        assert_eq!(post.input_shapes.len(), 2);
        assert_eq!(post.input_shapes[0].len(), 1); // flat params
    }

    #[test]
    #[cfg(feature = "xla")]
    fn post_drift_artifact_executes() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut reg = ArtifactRegistry::open(artifacts_dir()).expect("registry");
        let p = reg.manifest.cfg_usize("n_params").unwrap();
        let batch = reg.manifest.cfg_usize("batch").unwrap();
        let dz = reg.manifest.cfg_usize("latent_dim").unwrap();
        let dc = reg.manifest.cfg_usize("context_dim").unwrap();
        let exe = reg.get("post_drift_fwd").expect("compile");
        let params = vec![0.01f32; p];
        let zin = vec![0.1f32; batch * (dz + 1 + dc)];
        let out = exe.call_f32(&[&params, &zin]).expect("execute");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), batch * dz);
        assert!(out[0].iter().all(|v| v.is_finite()));
    }

    /// Cross-language consistency: the XLA artifact evaluated on the Rust
    /// model's parameter vector must match the Rust NN forward (both are
    /// the posterior drift MLP; layouts must agree byte-for-byte).
    #[test]
    #[cfg(feature = "xla")]
    fn xla_post_drift_matches_rust_nn() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        use crate::latent::{LatentSdeConfig, LatentSdeModel};
        use crate::prng::PrngKey;

        let mut reg = ArtifactRegistry::open(artifacts_dir()).expect("registry");
        let m = &reg.manifest;
        let cfg = LatentSdeConfig {
            obs_dim: m.cfg_usize("obs_dim").unwrap(),
            latent_dim: m.cfg_usize("latent_dim").unwrap(),
            context_dim: m.cfg_usize("context_dim").unwrap(),
            hidden: m.cfg_usize("hidden").unwrap(),
            diff_hidden: m.cfg_usize("diff_hidden").unwrap(),
            enc_hidden: m.cfg_usize("enc_hidden").unwrap(),
            ..Default::default()
        };
        let batch = m.cfg_usize("batch").unwrap();
        let model = LatentSdeModel::new(cfg);
        assert_eq!(
            model.n_params,
            m.cfg_usize("n_params").unwrap(),
            "Rust/Python parameter layouts diverged"
        );

        let params = model.init_params(PrngKey::from_seed(99));
        let params_f32: Vec<f32> = params.iter().map(|&v| v as f32).collect();
        let d_in = cfg.latent_dim + 1 + cfg.context_dim;
        let mut zin = vec![0.0f64; batch * d_in];
        PrngKey::from_seed(100).fill_normal(0, &mut zin);
        let zin_f32: Vec<f32> = zin.iter().map(|&v| v as f32).collect();

        let exe = reg.get("post_drift_fwd").expect("compile");
        let out = exe.call_f32(&[&params_f32, &zin_f32]).expect("execute");

        // Rust reference: same MLP on each row.
        let mut cache = model.post_drift.cache();
        for b in 0..batch {
            let mut want = vec![0.0f64; cfg.latent_dim];
            model.post_drift.forward(
                &params,
                &zin[b * d_in..(b + 1) * d_in],
                &mut cache,
                &mut want,
            );
            for i in 0..cfg.latent_dim {
                let got = out[0][b * cfg.latent_dim + i] as f64;
                assert!(
                    (got - want[i]).abs() < 1e-4 * want[i].abs().max(1.0),
                    "row {b} dim {i}: xla {got} vs rust {}",
                    want[i]
                );
            }
        }
    }
}
