//! Persistent work-stealing executor for the batched hot path.
//!
//! Before this module every [`scoped_map`] call site spawned and joined
//! fresh OS threads (`std::thread::scope`) — the trainer paid
//! thread-creation latency every iteration and `sdegrad serve` every
//! request group. Here, one lazily-initialized process-wide pool of
//! parked workers serves every call:
//!
//! * **Jobs, not threads.** A call packages its closure as a job: the
//!   index range `0..n` pre-split into per-participant stealable queues
//!   (packed `hi<<32|lo` atomics: owners pop the front with CAS, thieves
//!   take half from the back), an erased `unsafe fn(*const (), usize)`
//!   task shim, and a completion latch. The job is pushed on the global
//!   injector; parked workers wake, claim a participant slot, and drain.
//! * **The caller participates.** The calling thread runs tasks like any
//!   worker and blocks only on the completion latch. This makes borrowed
//!   closures sound (the closure and result buffer outlive the job: the
//!   caller cannot return before `remaining == 0`, and workers touch the
//!   job's context only while executing a claimed task) and makes nested
//!   calls deadlock-free (an inner call always makes progress on its own
//!   thread even if every pool worker is busy).
//! * **Workers are reused, never respawned.** The pool grows lazily up to
//!   the requested participant count and then parks idle workers on a
//!   condvar — two consecutive batched calls reuse the same threads
//!   (pinned by `tests/executor.rs`).
//!
//! ## Determinism contract
//!
//! Each task writes its result into its own index's slot; the caller
//! reassembles results in index order. Scheduling decides only *who*
//! computes an index, never *what* is computed or how results reduce —
//! results are **bit-identical for any pool size** (including 1) and any
//! steal interleaving, preserving the repo-wide contract.
//!
//! ## Worker-count knob
//!
//! [`worker_count`] unifies what used to be two knobs (`par_map` read
//! `available_parallelism` directly; `coordinator::config::num_threads`
//! capped its own default at 8): an explicit [`set_worker_count`] (the
//! `--threads` CLI flag) wins, then the `SDEGRAD_THREADS` env var, then
//! `available_parallelism`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Explicit worker-count override (0 = unset). Set by [`set_worker_count`].
static WORKER_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Process-wide worker-count knob: explicit [`set_worker_count`] value if
/// set, else the `SDEGRAD_THREADS` env var, else `available_parallelism`.
/// Every parallel surface (the pool, serve workers, trainer defaults,
/// bench harnesses) derives from this.
pub fn worker_count() -> usize {
    let explicit = WORKER_OVERRIDE.load(Ordering::Relaxed);
    if explicit > 0 {
        return explicit;
    }
    if let Ok(v) = std::env::var("SDEGRAD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Set the process-wide worker count (the `--threads` flag). `0` clears
/// the override, falling back to `SDEGRAD_THREADS` /
/// `available_parallelism`. Takes effect for subsequent jobs; already
/// spawned pool workers are never killed (they just idle).
pub fn set_worker_count(n: usize) {
    WORKER_OVERRIDE.store(n, Ordering::Relaxed);
}

/// One participant's index range, packed `end << 32 | start` in a single
/// atomic so pop/steal race safely. The owner pops the front; thieves
/// steal half from the back.
struct PackedRange(AtomicU64);

const LO_MASK: u64 = 0xffff_ffff;

impl PackedRange {
    fn new(lo: u32, hi: u32) -> Self {
        PackedRange(AtomicU64::new(((hi as u64) << 32) | lo as u64))
    }

    /// Owner path: claim the front index, or `None` when empty.
    fn pop_front(&self) -> Option<usize> {
        let mut cur = self.0.load(Ordering::Acquire);
        loop {
            let (lo, hi) = ((cur & LO_MASK) as u32, (cur >> 32) as u32);
            if lo >= hi {
                return None;
            }
            match self.0.compare_exchange_weak(
                cur,
                ((hi as u64) << 32) | (lo + 1) as u64,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(lo as usize),
                Err(next) => cur = next,
            }
        }
    }

    /// Thief path: take the back half (at least one index), or `None`
    /// when empty. The stolen sub-range is returned for local draining.
    fn steal_half(&self) -> Option<(usize, usize)> {
        let mut cur = self.0.load(Ordering::Acquire);
        loop {
            let (lo, hi) = ((cur & LO_MASK) as u32, (cur >> 32) as u32);
            if lo >= hi {
                return None;
            }
            let take = ((hi - lo) as usize).div_ceil(2) as u32;
            let new_hi = hi - take;
            match self.0.compare_exchange_weak(
                cur,
                ((new_hi as u64) << 32) | lo as u64,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some((new_hi as usize, hi as usize)),
                Err(next) => cur = next,
            }
        }
    }
}

/// A scoped job: lifetime-erased task shim + stealable index queues +
/// completion latch. Lives in an `Arc` shared by the caller and any pool
/// workers that joined; the raw context pointers are only dereferenced
/// while executing a claimed task, and every task completes before the
/// caller's stack frame (which owns the referents) unwinds.
struct JobCore {
    /// Monomorphized shim: `call(ctx, i)` runs task `i` and stores its
    /// result in slot `i`.
    call: unsafe fn(*const (), usize),
    ctx: *const (),
    /// Pre-split per-participant queues (slot 0 = caller).
    ranges: Vec<PackedRange>,
    /// Pool workers that joined (caller holds one share implicitly);
    /// bounded by `ranges.len()` so a job never oversubscribes its
    /// requested width.
    joined: AtomicUsize,
    /// Tasks not yet *completed* (claimed-but-running tasks count).
    remaining: AtomicUsize,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// Safety: `ctx` points at a `RawJob` on the caller's stack. The caller
// blocks until `remaining == 0`, and `remaining` reaches 0 only after the
// last task's shim call has returned, so no worker dereferences `ctx`
// after the referents die. Result slots are disjoint per index.
unsafe impl Send for JobCore {}
unsafe impl Sync for JobCore {}

impl JobCore {
    /// Run tasks until no index is claimable anywhere in the job:
    /// drain the preferred queue, then steal from the others.
    fn drain(&self, slot: usize) {
        let w = self.ranges.len();
        loop {
            while let Some(i) = self.ranges[slot].pop_front() {
                self.run_task(i);
            }
            // Own queue empty: steal the back half of the fullest-looking
            // victim (scan in slot order — determinism is unaffected).
            let mut stole = false;
            for v in 0..w {
                if v == slot {
                    continue;
                }
                if let Some((lo, hi)) = self.ranges[v].steal_half() {
                    for i in lo..hi {
                        self.run_task(i);
                    }
                    stole = true;
                    break;
                }
            }
            if !stole {
                return;
            }
        }
    }

    fn run_task(&self, i: usize) {
        // Safety: `i` was claimed exactly once (CAS pop/steal), so slot
        // `i` is written once; `ctx` is alive because `remaining > 0`.
        unsafe { (self.call)(self.ctx, i) };
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
            *done = true;
            self.done_cv.notify_all();
        }
    }

    fn has_work(&self) -> bool {
        self.ranges.iter().any(|r| {
            let v = r.0.load(Ordering::Acquire);
            (v & LO_MASK) < (v >> 32)
        })
    }
}

/// The process-wide pool: an injector of active jobs and a set of parked
/// workers. Workers never exit; the pool only grows (lazily, up to the
/// largest participant count ever requested).
struct Pool {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct PoolState {
    /// Active jobs with potentially claimable work (callers push on
    /// submit, remove on completion).
    jobs: Vec<Arc<JobCore>>,
    /// Total workers ever spawned.
    spawned: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { jobs: Vec::new(), spawned: 0 }),
        work_cv: Condvar::new(),
    })
}

/// Number of pool workers spawned so far over the process lifetime
/// (monotone; the pool-reuse test pins that consecutive batched calls do
/// not grow it).
pub fn spawned_workers() -> usize {
    pool().state.lock().unwrap_or_else(|e| e.into_inner()).spawned
}

/// Body of a pool worker: park until a job with claimable work appears,
/// join it (bounded by its participant width), drain, repeat. Never
/// returns.
fn worker_loop() {
    let p = pool();
    loop {
        let job: Arc<JobCore> = {
            let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                // A worker may join a job if it has claimable work and a
                // free participant slot (slot 0 is the caller's).
                let candidate = st.jobs.iter().find(|j| {
                    j.has_work() && j.joined.load(Ordering::Relaxed) + 1 < j.ranges.len()
                });
                if let Some(j) = candidate {
                    j.joined.fetch_add(1, Ordering::Relaxed);
                    break j.clone();
                }
                st = p.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Steal-only participant: its "own" slot is chosen as the first
        // non-empty queue it finds.
        job.drain(0);
        job.joined.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Order-preserving parallel map over `0..n` on the persistent pool,
/// using at most `max_workers` participants (the calling thread is one of
/// them). Results are bit-identical for any pool size and any steal
/// schedule: task `i` always computes `f(i)` into slot `i`.
///
/// Runs inline when `n <= 1` or the effective width is 1 — sequential
/// execution is the same computation.
pub fn scoped_map<T, F>(n: usize, max_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let width = worker_count().min(max_workers).min(n);
    if width <= 1 || n == 1 {
        return (0..n).map(f).collect();
    }
    assert!(n <= u32::MAX as usize, "scoped_map: task count exceeds u32 range");

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();

    struct RawJob<'f, T, F> {
        f: &'f F,
        slots: *mut Option<T>,
    }
    /// Monomorphized task shim behind `JobCore::call`.
    unsafe fn run_one<T, F: Fn(usize) -> T>(ctx: *const (), i: usize) {
        let job = unsafe { &*(ctx as *const RawJob<'_, T, F>) };
        let v = (job.f)(i);
        unsafe { *job.slots.add(i) = Some(v) };
    }

    {
        let raw = RawJob { f: &f, slots: slots.as_mut_ptr() };
        // Split 0..n into `width` contiguous queues (slot 0 = caller).
        let per = n.div_ceil(width);
        let ranges = (0..width)
            .map(|w| PackedRange::new((w * per).min(n) as u32, ((w + 1) * per).min(n) as u32))
            .collect();
        let job = Arc::new(JobCore {
            call: run_one::<T, F>,
            ctx: (&raw as *const RawJob<'_, T, F>).cast(),
            ranges,
            joined: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });

        // Publish the job and make sure enough workers exist to fill its
        // participant slots, then wake them.
        let p = pool();
        {
            let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
            st.jobs.push(job.clone());
            while st.spawned + 1 < width {
                st.spawned += 1;
                let name = format!("sdegrad-pool-{}", st.spawned);
                std::thread::Builder::new()
                    .name(name)
                    .spawn(worker_loop)
                    .expect("spawning pool worker");
            }
        }
        p.work_cv.notify_all();

        // The caller is participant 0.
        job.drain(0);

        // Wait for stragglers still executing claimed tasks.
        {
            let mut done = job.done.lock().unwrap_or_else(|e| e.into_inner());
            while !*done {
                done = job.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
            }
        }

        // Retire the job.
        let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
        st.jobs.retain(|j| !Arc::ptr_eq(j, &job));
        // `raw` (and the borrow of `slots`) dies here; every task has
        // completed, so no worker will touch `ctx` again.
    }

    slots.into_iter().map(|s| s.expect("pool covered every index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that mutate the process-wide worker count.
    static KNOB: Mutex<()> = Mutex::new(());

    #[test]
    fn maps_in_order_and_covers_every_index() {
        let out = scoped_map(100, usize::MAX, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(scoped_map(0, usize::MAX, |i| i), Vec::<usize>::new());
        assert_eq!(scoped_map(1, usize::MAX, |i| i + 7), vec![7]);
    }

    #[test]
    fn respects_max_workers_inline_path() {
        // max_workers = 1 must run inline (no pool interaction at all).
        let before = spawned_workers();
        let out = scoped_map(64, 1, |i| i as f64 * 0.5);
        assert_eq!(out.len(), 64);
        assert_eq!(spawned_workers(), before);
    }

    #[test]
    fn identical_results_across_widths() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        let f = |i: usize| (i as f64).sqrt().sin();
        let reference: Vec<f64> = (0..257).map(f).collect();
        for width in [1usize, 2, 3, 8] {
            set_worker_count(width);
            assert_eq!(scoped_map(257, usize::MAX, f), reference, "width {width}");
        }
        set_worker_count(0);
    }

    #[test]
    fn nested_maps_do_not_deadlock() {
        let out = scoped_map(8, usize::MAX, |i| {
            scoped_map(8, usize::MAX, move |j| i * 8 + j).iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..8).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn consecutive_calls_reuse_workers() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        set_worker_count(4);
        let _ = scoped_map(64, usize::MAX, |i| i + 1);
        let after_first = spawned_workers();
        for _ in 0..5 {
            let _ = scoped_map(64, usize::MAX, |i| i + 1);
        }
        assert_eq!(spawned_workers(), after_first, "pool must not grow across calls");
        set_worker_count(0);
    }

    #[test]
    fn packed_range_pop_and_steal() {
        let r = PackedRange::new(0, 10);
        assert_eq!(r.pop_front(), Some(0));
        assert_eq!(r.steal_half(), Some((5, 10))); // ceil((10-1)/2)=5 → [5,10)
        assert_eq!(r.steal_half(), Some((3, 5)));
        assert_eq!(r.pop_front(), Some(1));
        assert_eq!(r.pop_front(), Some(2));
        assert_eq!(r.pop_front(), None);
        assert_eq!(r.steal_half(), None);
    }
}
