//! Persistent work-stealing executor for the batched hot path.
//!
//! Before this module every [`scoped_map`] call site spawned and joined
//! fresh OS threads (`std::thread::scope`) — the trainer paid
//! thread-creation latency every iteration and `sdegrad serve` every
//! request group. Here, one lazily-initialized process-wide pool of
//! parked workers serves every call:
//!
//! * **Jobs, not threads.** A call packages its closure as a job: the
//!   index range `0..n` pre-split into per-participant stealable queues
//!   (packed `hi<<32|lo` atomics: owners pop the front with CAS, thieves
//!   take half from the back), an erased `unsafe fn(*const (), usize)`
//!   task shim, and a completion latch. The job is pushed on the global
//!   injector; parked workers wake, claim a participant slot, and drain
//!   starting from that slot's queue.
//! * **The caller participates.** The calling thread runs tasks like any
//!   worker and blocks only on the completion latch. This makes borrowed
//!   closures sound (the closure and result buffer outlive the job: the
//!   caller cannot return before `remaining == 0`, and workers touch the
//!   job's context only while executing a claimed task) and makes nested
//!   calls deadlock-free (an inner call always makes progress on its own
//!   thread even if every pool worker is busy).
//! * **Workers are reused, never respawned.** The pool grows lazily up to
//!   the requested participant count and then parks idle workers on a
//!   condvar — two consecutive batched calls reuse the same threads
//!   (pinned by `tests/executor.rs`).
//!
//! ## Determinism contract
//!
//! Each task writes its result into its own index's slot; the caller
//! reassembles results in index order. Scheduling decides only *who*
//! computes an index, never *what* is computed or how results reduce —
//! results are **bit-identical for any pool size** (including 1) and any
//! steal interleaving, preserving the repo-wide contract.
//!
//! ## Panic containment
//!
//! A panicking task closure must not kill a pool worker (the worker
//! would die with the job's `remaining` latch undecremented and the
//! caller would block forever) and must not let the caller unwind while
//! the job is still published (workers could then execute tasks whose
//! context points into the dead stack frame). So task execution is
//! wrapped in `catch_unwind`: the first payload is stashed on the job,
//! every subsequent task of that job is retired without running (the
//! job is doomed — its results are never read), and the caller re-throws
//! the payload with `resume_unwind` only *after* the completion latch
//! has dropped and the job has been retired from the injector. A drop
//! guard performs that drain/wait/retire sequence even if the caller's
//! own frame unwinds for some other reason (e.g. worker spawn failure),
//! so no unwinding path can leak a live job. Workers survive task panics
//! and keep serving later jobs.
//!
//! ## Worker-count knob
//!
//! [`worker_count`] unifies what used to be two knobs (`par_map` read
//! `available_parallelism` directly; `coordinator::config::num_threads`
//! capped its own default at 8): an explicit [`set_worker_count`] (the
//! `--threads` CLI flag) wins, then the `SDEGRAD_THREADS` env var, then
//! `available_parallelism`.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use crate::obs;

/// Registry counters for the pool's dispatch/steal/park events (always
/// on — one relaxed add per *event*, never per task index). `spawned`
/// mirrors `PoolState::spawned` so [`spawned_workers`] can delegate to
/// the registry while the pool keeps its lock-guarded field for sizing.
struct PoolCounters {
    spawned: obs::Counter,
    dispatches: obs::Counter,
    steals: obs::Counter,
    parks: obs::Counter,
}

fn pool_counters() -> &'static PoolCounters {
    static COUNTERS: OnceLock<PoolCounters> = OnceLock::new();
    COUNTERS.get_or_init(|| PoolCounters {
        spawned: obs::counter("runtime.pool.spawned"),
        dispatches: obs::counter("runtime.pool.dispatches"),
        steals: obs::counter("runtime.pool.steals"),
        parks: obs::counter("runtime.pool.parks"),
    })
}

/// Explicit worker-count override (0 = unset). Set by [`set_worker_count`].
static WORKER_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Process-wide worker-count knob: explicit [`set_worker_count`] value if
/// set, else the `SDEGRAD_THREADS` env var, else `available_parallelism`.
/// Every parallel surface (the pool, serve workers, trainer defaults,
/// bench harnesses) derives from this.
pub fn worker_count() -> usize {
    let explicit = WORKER_OVERRIDE.load(Ordering::Relaxed);
    if explicit > 0 {
        return explicit;
    }
    if let Ok(v) = std::env::var("SDEGRAD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

/// Set the process-wide worker count (the `--threads` flag). `0` clears
/// the override, falling back to `SDEGRAD_THREADS` /
/// `available_parallelism`. Takes effect for subsequent jobs; already
/// spawned pool workers are never killed (they just idle).
pub fn set_worker_count(n: usize) {
    WORKER_OVERRIDE.store(n, Ordering::Relaxed);
}

/// One participant's index range, packed `end << 32 | start` in a single
/// atomic so pop/steal race safely. The owner pops the front; thieves
/// steal half from the back.
struct PackedRange(AtomicU64);

const LO_MASK: u64 = 0xffff_ffff;

impl PackedRange {
    fn new(lo: u32, hi: u32) -> Self {
        PackedRange(AtomicU64::new(((hi as u64) << 32) | lo as u64))
    }

    /// Owner path: claim the front index, or `None` when empty.
    fn pop_front(&self) -> Option<usize> {
        let mut cur = self.0.load(Ordering::Acquire);
        loop {
            let (lo, hi) = ((cur & LO_MASK) as u32, (cur >> 32) as u32);
            if lo >= hi {
                return None;
            }
            match self.0.compare_exchange_weak(
                cur,
                ((hi as u64) << 32) | (lo + 1) as u64,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(lo as usize),
                Err(next) => cur = next,
            }
        }
    }

    /// Thief path: take the back half (at least one index), or `None`
    /// when empty. The stolen sub-range is returned for local draining.
    fn steal_half(&self) -> Option<(usize, usize)> {
        let mut cur = self.0.load(Ordering::Acquire);
        loop {
            let (lo, hi) = ((cur & LO_MASK) as u32, (cur >> 32) as u32);
            if lo >= hi {
                return None;
            }
            let take = ((hi - lo) as usize).div_ceil(2) as u32;
            let new_hi = hi - take;
            match self.0.compare_exchange_weak(
                cur,
                ((new_hi as u64) << 32) | lo as u64,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some((new_hi as usize, hi as usize)),
                Err(next) => cur = next,
            }
        }
    }
}

/// A scoped job: lifetime-erased task shim + stealable index queues +
/// completion latch. Lives in an `Arc` shared by the caller and any pool
/// workers that joined; the raw context pointers are only dereferenced
/// while executing a claimed task, and every task completes before the
/// caller's stack frame (which owns the referents) unwinds.
struct JobCore {
    /// Monomorphized shim: `call(ctx, i)` runs task `i` and stores its
    /// result in slot `i`.
    call: unsafe fn(*const (), usize),
    ctx: *const (),
    /// Pre-split per-participant queues (slot 0 = caller).
    ranges: Vec<PackedRange>,
    /// Pool workers that joined (caller holds one share implicitly);
    /// bounded by `ranges.len()` so a job never oversubscribes its
    /// requested width. Also allocates each joiner's starting queue.
    joined: AtomicUsize,
    /// Tasks not yet *retired* (claimed-but-running tasks count). Every
    /// claimed task is retired exactly once — run, panicked, or skipped
    /// because the job is already doomed — so this always reaches 0.
    remaining: AtomicUsize,
    /// Fast flag: some task panicked, the job is doomed; remaining tasks
    /// are retired without running.
    panicked: AtomicBool,
    /// First panic payload; the caller re-throws it after the job has
    /// fully retired.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done: Mutex<bool>,
    done_cv: Condvar,
}

// Safety: `ctx` points at a `RawJob` on the caller's stack. The caller
// blocks until `remaining == 0` (the `JobGuard` enforces this on every
// exit path, including unwinds), and `remaining` reaches 0 only after
// the last task's shim call has returned, so no worker dereferences
// `ctx` after the referents die. Result slots are disjoint per index.
unsafe impl Send for JobCore {}
unsafe impl Sync for JobCore {}

impl JobCore {
    /// Run tasks until no index is claimable anywhere in the job:
    /// drain the preferred queue, then steal from the others. Never
    /// unwinds — task panics are contained by [`JobCore::run_task`].
    fn drain(&self, slot: usize) {
        let w = self.ranges.len();
        loop {
            while let Some(i) = self.ranges[slot].pop_front() {
                self.run_task(i);
            }
            // Own queue empty: steal the back half of the first victim
            // with work (scan in slot order — determinism is unaffected).
            let mut stole = false;
            for v in 0..w {
                if v == slot {
                    continue;
                }
                if let Some((lo, hi)) = self.ranges[v].steal_half() {
                    pool_counters().steals.inc();
                    for i in lo..hi {
                        self.run_task(i);
                    }
                    stole = true;
                    break;
                }
            }
            if !stole {
                return;
            }
        }
    }

    /// Execute task `i` (unless the job is already doomed) and retire it.
    /// Panics are caught here so they can neither kill a pool worker nor
    /// unwind the caller while the job is live; the first payload is
    /// kept for the caller to re-throw after the job retires.
    fn run_task(&self, i: usize) {
        if !self.panicked.load(Ordering::Acquire) {
            // Safety: `i` was claimed exactly once (CAS pop/steal), so
            // slot `i` is written once; `ctx` is alive because
            // `remaining > 0`. AssertUnwindSafe: a panicked task leaves
            // its own slot untouched and every other slot is written by
            // exactly one task, so no broken invariant is observable —
            // the payload is re-thrown before the slots are consumed.
            let result = catch_unwind(AssertUnwindSafe(|| unsafe { (self.call)(self.ctx, i) }));
            if let Err(payload) = result {
                let mut first = self.panic.lock().unwrap_or_else(|e| e.into_inner());
                if first.is_none() {
                    *first = Some(payload);
                }
                self.panicked.store(true, Ordering::Release);
            }
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let mut done = self.done.lock().unwrap_or_else(|e| e.into_inner());
            *done = true;
            self.done_cv.notify_all();
        }
    }

    fn has_work(&self) -> bool {
        self.ranges.iter().any(|r| {
            let v = r.0.load(Ordering::Acquire);
            (v & LO_MASK) < (v >> 32)
        })
    }
}

/// The process-wide pool: an injector of active jobs and a set of parked
/// workers. Workers never exit; the pool only grows (lazily, up to the
/// largest participant count ever requested).
struct Pool {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

struct PoolState {
    /// Active jobs with potentially claimable work (callers push on
    /// submit, remove on completion).
    jobs: Vec<Arc<JobCore>>,
    /// Total workers ever spawned.
    spawned: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

fn pool() -> &'static Pool {
    POOL.get_or_init(|| Pool {
        state: Mutex::new(PoolState { jobs: Vec::new(), spawned: 0 }),
        work_cv: Condvar::new(),
    })
}

/// Number of pool workers spawned so far over the process lifetime
/// (monotone). Process-global: in a multi-threaded test binary, prefer
/// [`spawned_by_this_thread`] for assertions — concurrent tests share
/// this one pool and race a global count.
///
/// Since the observability registry landed this is a thin shim over the
/// `runtime.pool.spawned` counter, which the spawn loop increments in
/// lockstep with the pool's internal sizing field.
pub fn spawned_workers() -> usize {
    pool_counters().spawned.get() as usize
}

thread_local! {
    /// Pool workers spawned by `scoped_map` calls made from this thread
    /// (spawning happens on the calling thread, so attribution is exact).
    static SPAWNED_HERE: Cell<usize> = const { Cell::new(0) };
}

/// Number of pool workers spawned by `scoped_map` calls made from the
/// *current* thread. The race-free counterpart of [`spawned_workers`]
/// for tests: sibling tests running concurrently spawn on their own
/// threads and cannot perturb this count, so "consecutive calls reuse
/// workers" pins stay exact under a parallel test harness.
pub fn spawned_by_this_thread() -> usize {
    SPAWNED_HERE.with(|c| c.get())
}

/// Body of a pool worker: park until a job with claimable work appears,
/// join it (bounded by its participant width), drain, repeat. Never
/// returns; task panics are contained inside `drain`, so a panicking
/// closure cannot kill the worker.
fn worker_loop() {
    let p = pool();
    loop {
        let (job, slot): (Arc<JobCore>, usize) = {
            let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                // A worker may join a job if it has claimable work and a
                // free participant slot (slot 0 is the caller's).
                let candidate = st.jobs.iter().find(|j| {
                    j.has_work() && j.joined.load(Ordering::Relaxed) + 1 < j.ranges.len()
                });
                if let Some(j) = candidate {
                    // Claim a distinct starting queue (joins are
                    // serialized by the pool lock, so `old + 1` is in
                    // range). After leave/join churn two live workers
                    // can transiently share a slot — that only skews
                    // which queue they drain first; claims stay
                    // CAS-protected.
                    let slot = j.joined.fetch_add(1, Ordering::Relaxed) + 1;
                    break (j.clone(), slot);
                }
                pool_counters().parks.inc();
                st = p.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        job.drain(slot);
        job.joined.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Caller-side cleanup that must run on *every* exit path of
/// [`scoped_map`] — normal return or unwind — while the job is
/// published: participate (drain as slot 0), wait out stragglers still
/// executing claimed tasks, and retire the job from the injector. Only
/// after this may the caller's stack frame (which owns the closure and
/// result slots the job's `ctx` points into) die.
struct JobGuard<'a> {
    job: &'a Arc<JobCore>,
    pool: &'static Pool,
}

impl Drop for JobGuard<'_> {
    fn drop(&mut self) {
        // `drain` never unwinds (task panics are caught in `run_task`),
        // so this cleanup always completes even when invoked mid-unwind.
        self.job.drain(0);
        {
            let mut done = self.job.done.lock().unwrap_or_else(|e| e.into_inner());
            while !*done {
                done = self.job.done_cv.wait(done).unwrap_or_else(|e| e.into_inner());
            }
        }
        let mut st = self.pool.state.lock().unwrap_or_else(|e| e.into_inner());
        st.jobs.retain(|j| !Arc::ptr_eq(j, self.job));
    }
}

/// Order-preserving parallel map over `0..n` on the persistent pool,
/// using at most `max_workers` participants (the calling thread is one of
/// them). Results are bit-identical for any pool size and any steal
/// schedule: task `i` always computes `f(i)` into slot `i`.
///
/// Runs inline when `n <= 1` or the effective width is 1 — sequential
/// execution is the same computation.
///
/// If `f` panics, the panic is contained until every claimed task has
/// retired and the job has been withdrawn from the pool, then re-thrown
/// on the calling thread (first payload wins when several tasks panic).
/// Pool workers survive and keep serving later jobs.
pub fn scoped_map<T, F>(n: usize, max_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let width = worker_count().min(max_workers).min(n);
    if width <= 1 || n == 1 {
        return (0..n).map(f).collect();
    }
    assert!(n <= u32::MAX as usize, "scoped_map: task count exceeds u32 range");

    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();

    struct RawJob<'f, T, F> {
        f: &'f F,
        slots: *mut Option<T>,
    }
    /// Monomorphized task shim behind `JobCore::call`.
    unsafe fn run_one<T, F: Fn(usize) -> T>(ctx: *const (), i: usize) {
        let job = unsafe { &*(ctx as *const RawJob<'_, T, F>) };
        let v = (job.f)(i);
        unsafe { *job.slots.add(i) = Some(v) };
    }

    {
        let raw = RawJob { f: &f, slots: slots.as_mut_ptr() };
        // Split 0..n into `width` contiguous queues (slot 0 = caller).
        let per = n.div_ceil(width);
        let ranges = (0..width)
            .map(|w| PackedRange::new((w * per).min(n) as u32, ((w + 1) * per).min(n) as u32))
            .collect();
        let job = Arc::new(JobCore {
            call: run_one::<T, F>,
            ctx: (&raw as *const RawJob<'_, T, F>).cast(),
            ranges,
            joined: AtomicUsize::new(0),
            remaining: AtomicUsize::new(n),
            panicked: AtomicBool::new(false),
            panic: Mutex::new(None),
            done: Mutex::new(false),
            done_cv: Condvar::new(),
        });

        // Publish the job, then arm the guard: from this point the job
        // is visible to workers, and no path — including an unwind from
        // the spawn loop below — may leave this frame before the guard
        // has drained, waited, and retired it.
        let p = pool();
        pool_counters().dispatches.inc();
        {
            let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
            st.jobs.push(job.clone());
        }
        let guard = JobGuard { job: &job, pool: p };

        // Make sure enough workers exist to fill the job's participant
        // slots, then wake them.
        {
            let mut st = p.state.lock().unwrap_or_else(|e| e.into_inner());
            while st.spawned + 1 < width {
                st.spawned += 1;
                let name = format!("sdegrad-pool-{}", st.spawned);
                std::thread::Builder::new()
                    .name(name)
                    .spawn(worker_loop)
                    .expect("spawning pool worker");
                // Registry mirror + thread-local attribution, both under
                // the pool lock so `spawned_workers()` tracks exactly.
                pool_counters().spawned.inc();
                SPAWNED_HERE.with(|c| c.set(c.get() + 1));
            }
        }
        p.work_cv.notify_all();

        // The caller is participant 0: drain, wait for stragglers,
        // retire the job.
        drop(guard);

        // `raw` (and the borrow of `slots`) is only now allowed to die:
        // every task has retired, so no worker will touch `ctx` again.
        // A contained task panic resumes on this thread, after cleanup.
        if let Some(payload) = job.panic.lock().unwrap_or_else(|e| e.into_inner()).take() {
            resume_unwind(payload);
        }
    }

    slots.into_iter().map(|s| s.expect("pool covered every index")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that mutate the process-wide worker count.
    /// (Spawn-count assertions don't need it — they use the
    /// thread-attributed [`spawned_by_this_thread`], which sibling tests
    /// cannot perturb.)
    static KNOB: Mutex<()> = Mutex::new(());

    #[test]
    fn maps_in_order_and_covers_every_index() {
        let out = scoped_map(100, usize::MAX, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(scoped_map(0, usize::MAX, |i| i), Vec::<usize>::new());
        assert_eq!(scoped_map(1, usize::MAX, |i| i + 7), vec![7]);
    }

    #[test]
    fn respects_max_workers_inline_path() {
        // max_workers = 1 must run inline: this thread spawns nothing.
        let before = spawned_by_this_thread();
        let out = scoped_map(64, 1, |i| i as f64 * 0.5);
        assert_eq!(out.len(), 64);
        assert_eq!(spawned_by_this_thread(), before);
    }

    #[test]
    fn identical_results_across_widths() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        let f = |i: usize| (i as f64).sqrt().sin();
        let reference: Vec<f64> = (0..257).map(f).collect();
        for width in [1usize, 2, 3, 8] {
            set_worker_count(width);
            assert_eq!(scoped_map(257, usize::MAX, f), reference, "width {width}");
        }
        set_worker_count(0);
    }

    #[test]
    fn nested_maps_do_not_deadlock() {
        let out = scoped_map(8, usize::MAX, |i| {
            scoped_map(8, usize::MAX, move |j| i * 8 + j).iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..8).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn consecutive_calls_reuse_workers() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        set_worker_count(4);
        let _ = scoped_map(64, usize::MAX, |i| i + 1);
        let after_first = spawned_by_this_thread();
        for _ in 0..5 {
            let _ = scoped_map(64, usize::MAX, |i| i + 1);
        }
        assert_eq!(
            spawned_by_this_thread(),
            after_first,
            "pool must not grow across calls"
        );
        set_worker_count(0);
    }

    /// A panicking task must propagate to the caller (not hang it) and
    /// must not kill pool workers: the pool keeps serving afterwards.
    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let _g = KNOB.lock().unwrap_or_else(|e| e.into_inner());
        set_worker_count(4);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            scoped_map(64, usize::MAX, |i| {
                if i == 17 {
                    panic!("task 17 failed");
                }
                i
            })
        }));
        let payload = caught.expect_err("task panic must reach the caller");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .map(str::to_owned)
            .or_else(|| payload.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("task 17 failed"), "wrong payload: {msg:?}");
        // Workers contained the panic and live on: the pool still works
        // and produces correct results.
        let out = scoped_map(64, usize::MAX, |i| i + 1);
        assert_eq!(out, (1..=64).collect::<Vec<_>>());
        set_worker_count(0);
    }

    #[test]
    fn packed_range_pop_and_steal() {
        let r = PackedRange::new(0, 10);
        assert_eq!(r.pop_front(), Some(0));
        assert_eq!(r.steal_half(), Some((5, 10))); // ceil((10-1)/2)=5 → [5,10)
        assert_eq!(r.steal_half(), Some((3, 5)));
        assert_eq!(r.pop_front(), Some(1));
        assert_eq!(r.pop_front(), Some(2));
        assert_eq!(r.pop_front(), None);
        assert_eq!(r.steal_half(), None);
    }
}
