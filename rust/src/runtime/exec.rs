//! One execution-config vocabulary for the whole crate.
//!
//! PRs 7–8 grew three independent knobs — the kernel tier, the worker
//! count, and the Brownian-tree node-cache capacity — and re-declared
//! them field-by-field on [`crate::api::SolveOptions`],
//! [`crate::latent::ElboConfig`], [`crate::coordinator::config::TrainConfig`],
//! [`crate::serve::BatcherConfig`], and [`crate::serve::ServeConfig`].
//! [`ExecConfig`] defines the knob set once; every entry point now embeds
//! it (the old per-struct fields survive one release as delegating
//! builders, pinned bit-identical in `tests/exec_config.rs`).
//!
//! None of the knobs changes a float in the exact tier: the tier selects
//! *which* kernels run (`Fast` is tolerance-equal, not bit-equal), the
//! thread count only partitions work across the pool, and the tree cache
//! only memoizes Brownian bridge draws that are pure functions of
//! `(key, t)`.

use crate::brownian::DEFAULT_NODE_CACHE;
use crate::sde::KernelTier;

/// Execution configuration shared by every batched entry point: kernel
/// tier, worker count, and Brownian-tree node-cache capacity.
///
/// * `tier` — [`KernelTier::Exact`] (default) keeps the bit-identical
///   contract with the per-path scalar engine; [`KernelTier::Fast`]
///   routes through the reassociated fast kernels (tolerance-equal).
/// * `threads` — per-call worker count. `None` (default) defers to the
///   process-wide precedence chain: the `--threads` CLI flag >
///   `SDEGRAD_THREADS` > `std::thread::available_parallelism` (see
///   [`crate::runtime::worker_count`]).
/// * `tree_cache` — node-cache capacity for virtual Brownian trees
///   created by entry points that own their noise (0 disables). Entry
///   points taking an [`crate::api::SdeProblem`] keep the problem's own
///   `tree_cache` field authoritative, since it is per-problem state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecConfig {
    pub tier: KernelTier,
    pub threads: Option<usize>,
    pub tree_cache: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig { tier: KernelTier::Exact, threads: None, tree_cache: DEFAULT_NODE_CACHE }
    }
}

impl ExecConfig {
    /// The default configuration (exact tier, global thread precedence,
    /// default tree cache).
    pub fn new() -> Self {
        Self::default()
    }

    /// Select the kernel tier.
    pub fn tier(mut self, tier: KernelTier) -> Self {
        self.tier = tier;
        self
    }

    /// Pin the worker count for calls under this config (`None` defers
    /// to the global precedence chain).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Set the Brownian-tree node-cache capacity (0 disables caching).
    pub fn tree_cache(mut self, capacity: usize) -> Self {
        self.tree_cache = capacity;
        self
    }

    /// The effective worker count: `threads` if pinned, otherwise the
    /// process-wide [`crate::runtime::worker_count`].
    pub fn worker_count(&self) -> usize {
        self.threads.unwrap_or_else(super::worker_count).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_exact_with_global_threads_and_default_cache() {
        let cfg = ExecConfig::default();
        assert_eq!(cfg.tier, KernelTier::Exact);
        assert_eq!(cfg.threads, None);
        assert_eq!(cfg.tree_cache, DEFAULT_NODE_CACHE);
        assert_eq!(cfg, ExecConfig::new());
    }

    #[test]
    fn builders_set_each_knob() {
        let cfg = ExecConfig::new().tier(KernelTier::Fast).threads(3).tree_cache(7);
        assert_eq!(cfg.tier, KernelTier::Fast);
        assert_eq!(cfg.threads, Some(3));
        assert_eq!(cfg.tree_cache, 7);
        assert_eq!(cfg.worker_count(), 3);
    }

    #[test]
    fn unpinned_worker_count_follows_the_global_chain() {
        let cfg = ExecConfig::default();
        assert_eq!(cfg.worker_count(), crate::runtime::worker_count().max(1));
        assert!(cfg.worker_count() >= 1);
    }
}
