//! Per-thread buffer arena: recycled `Vec<f64>` scratch for the batched
//! hot path.
//!
//! Pool workers are persistent (see [`super::pool`]), so a thread-local
//! free list turns per-chunk staging allocations — which used to hit the
//! global allocator once per chunk per call — into pointer pops that
//! reuse the same warm buffers across jobs.
//!
//! [`lease`] hands out a zero-filled buffer of exactly the requested
//! length, *identical in observable state* to a fresh `vec![0.0; n]` —
//! recycling can never change a computed float, so the crate's
//! bit-identical determinism contract is unaffected. Dropping the
//! [`Lease`] returns the buffer to the calling thread's free list (the
//! list is bounded; excess buffers fall back to the allocator).

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};

/// Buffers kept per thread. Beyond this the oldest lease simply frees —
/// a cap, not a correctness boundary. The batched hot path needs ~a
/// dozen staging buffers live per worker at peak.
const MAX_FREE: usize = 32;

thread_local! {
    static FREE: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };
}

/// A recycled `Vec<f64>` scratch buffer, zero-filled to the leased
/// length. Dereferences to the underlying vector; returns it to the
/// thread-local free list on drop.
pub struct Lease {
    buf: Vec<f64>,
}

/// Lease a zero-filled buffer of length `n` from the calling thread's
/// free list (allocating only when the list is empty). Observationally
/// identical to `vec![0.0; n]`.
pub fn lease(n: usize) -> Lease {
    let mut buf = FREE
        .with(|f| f.borrow_mut().pop())
        .unwrap_or_default();
    buf.clear();
    buf.resize(n, 0.0);
    Lease { buf }
}

impl Deref for Lease {
    type Target = Vec<f64>;
    fn deref(&self) -> &Vec<f64> {
        &self.buf
    }
}

impl DerefMut for Lease {
    fn deref_mut(&mut self) -> &mut Vec<f64> {
        &mut self.buf
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        let buf = std::mem::take(&mut self.buf);
        FREE.with(|f| {
            let mut free = f.borrow_mut();
            if free.len() < MAX_FREE {
                free.push(buf);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_is_zero_filled_like_a_fresh_vec() {
        {
            let mut a = lease(8);
            a.iter_mut().for_each(|x| *x = 7.0);
        } // returned dirty
        let b = lease(8);
        assert_eq!(&**b, &vec![0.0; 8], "recycled buffer must be re-zeroed");
        let c = lease(16);
        assert_eq!(c.len(), 16);
        assert!(c.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn buffers_are_reused_on_the_same_thread() {
        let ptr = {
            let a = lease(64);
            a.as_ptr()
        };
        let b = lease(64);
        assert_eq!(b.as_ptr(), ptr, "same-thread same-size lease should recycle");
    }
}
