//! PJRT runtime: load AOT-compiled HLO artifacts and execute them from the
//! Rust hot path (Python never runs at train/serve time).
//!
//! Pipeline: `artifacts/manifest.txt` → [`Manifest`] →
//! [`ArtifactRegistry`] (compiles each `*.hlo.txt` once on the shared
//! [`xla::PjRtClient`] CPU client) → [`Executable::call_f32`].
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py` — the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids).

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactRegistry, Executable, Manifest, ManifestEntry};
#[cfg(feature = "xla")]
pub use client::pjrt_client;
