//! Process-wide execution runtime: the persistent work-stealing thread
//! pool that runs every parallel hot path ([`pool`]), the shared
//! execution-config vocabulary ([`exec::ExecConfig`]), and the PJRT
//! loader for AOT-compiled HLO artifacts ([`artifact`] / [`client`]).
//!
//! ## Thread pool
//!
//! [`scoped_map`] is the single parallel-map primitive for the crate
//! (batch solves, batched sensitivities, the latent-SDE ELBO, serve
//! engine calls). Workers are spawned once and parked between jobs —
//! no per-call thread churn — and [`worker_count`] is the one knob
//! (`--threads` flag via [`set_worker_count`], then `SDEGRAD_THREADS`,
//! then `available_parallelism`). Results are bit-identical for any
//! pool size; see [`pool`] for the determinism contract. Task panics
//! are contained: workers survive, and the caller re-throws the payload
//! only after the job has fully retired (see "Panic containment" in
//! [`pool`]).
//!
//! ## PJRT artifacts
//!
//! Pipeline: `artifacts/manifest.txt` → [`Manifest`] →
//! [`ArtifactRegistry`] (compiles each `*.hlo.txt` once on the shared
//! [`xla::PjRtClient`] CPU client) → [`Executable::call_f32`].
//!
//! Interchange format is **HLO text** (see `python/compile/aot.py` — the
//! image's xla_extension 0.5.1 rejects jax≥0.5's 64-bit-id serialized
//! protos; the text parser reassigns ids).

pub mod arena;
pub mod artifact;
pub mod client;
pub mod exec;
pub mod pool;

pub use artifact::{ArtifactRegistry, Executable, Manifest, ManifestEntry};
pub use exec::ExecConfig;
#[cfg(feature = "xla")]
pub use client::pjrt_client;
pub use pool::{
    scoped_map, set_worker_count, spawned_by_this_thread, spawned_workers, worker_count,
};
