//! Per-thread PJRT CPU client (requires the `xla` cargo feature).
//!
//! The `xla` crate's `PjRtClient` wraps an `Rc`, so it cannot be shared
//! across threads; each worker thread that executes artifacts initializes
//! its own client lazily and reuses it for the thread's lifetime (client
//! construction is the expensive part; `Clone` is an `Rc` bump).

#[cfg(feature = "xla")]
use std::cell::RefCell;

#[cfg(feature = "xla")]
use crate::err;
#[cfg(feature = "xla")]
use crate::error::Result;

#[cfg(feature = "xla")]
thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// This thread's PJRT CPU client (lazily constructed, cheaply cloned).
#[cfg(feature = "xla")]
pub fn pjrt_client() -> Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot =
                Some(xla::PjRtClient::cpu().map_err(|e| err!("PjRtClient::cpu: {e:?}"))?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

#[cfg(all(test, feature = "xla"))]
mod tests {
    use super::*;

    #[test]
    fn client_initializes() {
        let c = pjrt_client().expect("client");
        assert!(c.device_count() >= 1);
        assert!(!c.platform_name().is_empty());
    }

    #[test]
    fn per_thread_clients_work() {
        let handle = std::thread::spawn(|| {
            let c = pjrt_client().expect("client in worker thread");
            c.device_count()
        });
        assert!(handle.join().unwrap() >= 1);
        assert!(pjrt_client().is_ok());
    }
}
