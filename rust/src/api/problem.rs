//! [`SdeProblem`]: the problem half of the problem–solver–solution API.
//!
//! A problem bundles everything that *defines* a stochastic initial-value
//! problem — the SDE, the initial state, the horizon, the parameter
//! vector, and the Brownian source specification — and leaves everything
//! about *how* to solve it to [`super::SolveOptions`] /
//! [`super::SensAlg`]. One problem value can therefore be solved forward,
//! differentiated with any sensitivity algorithm, or replicated into a
//! batch, always against the same defining data.

use crate::prng::PrngKey;
use crate::sde::{Calculus, Sde};
use crate::solvers::Method;
use std::fmt;

/// Where the Brownian sample path comes from (the API-level name for
/// [`crate::adjoint::NoiseMode`]; the two are the same type, so a problem
/// spec can be dropped directly into an
/// [`crate::adjoint::AdjointConfig`]).
pub use crate::adjoint::NoiseMode as NoiseSpec;

/// Validation failure surfaced *before* any integration starts.
///
/// The legacy free functions panicked mid-solve on these conditions (most
/// notoriously `SdeVjp::ito_correction_vjp`'s unimplemented default);
/// [`SdeProblem`] checks them up front and returns an error instead.
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemError {
    /// The SDE is Itô-native but does not implement
    /// `SdeVjp::ito_correction_vjp`, which the requested algorithm needs.
    MissingItoCorrectionVjp { algorithm: &'static str },
    /// The requested algorithm does not support this stepping scheme.
    UnsupportedMethod { algorithm: &'static str, method: Method },
    /// The requested algorithm requires the SDE's native calculus to be
    /// `required`.
    CalculusMismatch { algorithm: &'static str, required: Calculus },
    /// Adaptive stepping is only available for forward solves and (via
    /// `SdeProblem::sensitivity_adaptive`) replicated scalar problems.
    AdaptiveSensitivityUnsupported,
    /// The requested algorithm cannot replay the problem's noise source
    /// deterministically. Every in-tree spec (stored path, virtual tree,
    /// mirrored either way) *is* replayable, so no current combination
    /// returns this; it is reserved for genuinely unreplayable sources
    /// (e.g. externally streamed increments).
    UnsupportedNoise { algorithm: &'static str },
}

impl fmt::Display for ProblemError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProblemError::MissingItoCorrectionVjp { algorithm } => write!(
                f,
                "{algorithm}: SDE is Itô-native but does not provide \
                 ito_correction_vjp — express it in Stratonovich form or \
                 implement the correction VJP (and override \
                 has_ito_correction_vjp)"
            ),
            ProblemError::UnsupportedMethod { algorithm, method } => {
                write!(f, "{algorithm}: stepping scheme {} is not supported", method.name())
            }
            ProblemError::CalculusMismatch { algorithm, required } => {
                write!(f, "{algorithm}: requires a {required:?}-native SDE")
            }
            ProblemError::AdaptiveSensitivityUnsupported => write!(
                f,
                "adaptive step control is not supported by generic sensitivity \
                 algorithms; use fixed steps, or sensitivity_adaptive on a \
                 replicated scalar problem"
            ),
            ProblemError::UnsupportedNoise { algorithm } => write!(
                f,
                "{algorithm}: the problem's noise source cannot be \
                 replayed deterministically by this estimator"
            ),
        }
    }
}

impl std::error::Error for ProblemError {}

/// A stochastic initial-value problem `dZ = b dt + σ dW`, `Z(t0) = z0`,
/// on the horizon `(t0, t1)`.
///
/// Built with a chained constructor and consumed by
/// [`SdeProblem::solve`], [`SdeProblem::sensitivity`] (and friends), or
/// the batch entry points [`super::solve_batch`] /
/// [`super::sensitivity_batch`]:
///
/// ```ignore
/// let sol = SdeProblem::new(&sde, &z0, (0.0, 1.0))
///     .params(&theta)
///     .key(PrngKey::from_seed(7))
///     .noise(NoiseSpec::VirtualTree { tol: 1e-8 })
///     .solve(&SolveOptions::fixed(Method::MilsteinIto, 1000));
/// ```
///
/// The problem owns copies of `z0` and `theta` (cheap relative to any
/// solve) so it can be cloned per batch replicate; the SDE itself is
/// borrowed.
pub struct SdeProblem<'a, S: Sde + ?Sized> {
    pub(crate) sde: &'a S,
    pub(crate) z0: Vec<f64>,
    pub(crate) t0: f64,
    pub(crate) t1: f64,
    pub(crate) theta: Vec<f64>,
    pub(crate) key: PrngKey,
    pub(crate) noise: NoiseSpec,
    pub(crate) mirror: bool,
    pub(crate) tree_cache: usize,
}

impl<'a, S: Sde + ?Sized> Clone for SdeProblem<'a, S> {
    fn clone(&self) -> Self {
        SdeProblem {
            sde: self.sde,
            z0: self.z0.clone(),
            t0: self.t0,
            t1: self.t1,
            theta: self.theta.clone(),
            key: self.key,
            noise: self.noise,
            mirror: self.mirror,
            tree_cache: self.tree_cache,
        }
    }
}

impl<'a, S: Sde + ?Sized> SdeProblem<'a, S> {
    /// A problem with zero parameters-vector default, stored-path noise
    /// from seed 0, and no mirroring. `span` is `(t0, t1)`; a descending
    /// span integrates backward.
    pub fn new(sde: &'a S, z0: &[f64], span: (f64, f64)) -> Self {
        assert_eq!(
            z0.len(),
            sde.state_dim(),
            "SdeProblem: z0 length {} != state_dim {}",
            z0.len(),
            sde.state_dim()
        );
        assert!(span.0 != span.1, "SdeProblem: empty horizon");
        SdeProblem {
            sde,
            z0: z0.to_vec(),
            t0: span.0,
            t1: span.1,
            theta: vec![0.0; sde.param_dim()],
            key: PrngKey::from_seed(0),
            noise: NoiseSpec::StoredPath,
            mirror: false,
            tree_cache: crate::brownian::DEFAULT_NODE_CACHE,
        }
    }

    /// Set the parameter vector θ (length must equal `param_dim`).
    pub fn params(mut self, theta: &[f64]) -> Self {
        assert_eq!(
            theta.len(),
            self.sde.param_dim(),
            "SdeProblem: theta length {} != param_dim {}",
            theta.len(),
            self.sde.param_dim()
        );
        self.theta.clear();
        self.theta.extend_from_slice(theta);
        self
    }

    /// Set the PRNG key that seeds the Brownian source.
    pub fn key(mut self, key: PrngKey) -> Self {
        self.key = key;
        self
    }

    /// Choose the Brownian source (stored path or virtual tree). This is
    /// authoritative for [`SdeProblem::solve`] and every estimator: the
    /// adjoint family honors it directly (it overrides the `noise` field
    /// of any `AdjointConfig` passed via `SensAlg`), and the taped family
    /// (`Backprop`/`ForwardPathwise`) replays it exactly — the virtual
    /// tree is a pure function of `(key, t)`, so a replayed segment is
    /// bit-identical to the first pass by construction.
    pub fn noise(mut self, spec: NoiseSpec) -> Self {
        self.noise = spec;
        self
    }

    /// Drive the solve with the mirrored path `−W` (antithetic coupling).
    pub fn mirror(mut self, mirror: bool) -> Self {
        self.mirror = mirror;
        self
    }

    /// Ancestor-cache capacity for [`NoiseSpec::VirtualTree`] sources
    /// (default [`crate::brownian::DEFAULT_NODE_CACHE`]; ignored for
    /// stored paths). Sequential solver sweeps resume each bisection from
    /// the deepest cached ancestor instead of the root, cutting bridge
    /// draws from O(log n) to amortized O(1) per step at the price of
    /// O(capacity·d) memory. `0` disables the cache. **Results are
    /// bit-identical for every capacity** — each cached node is the same
    /// pure function of `(key, path)` a fresh descent computes — so this
    /// is purely a speed/memory knob.
    pub fn tree_cache(mut self, capacity: usize) -> Self {
        self.tree_cache = capacity;
        self
    }

    /// The virtual-tree ancestor-cache capacity.
    pub fn tree_cache_capacity(&self) -> usize {
        self.tree_cache
    }

    /// The underlying SDE.
    pub fn sde(&self) -> &'a S {
        self.sde
    }

    /// State dimension d.
    pub fn dim(&self) -> usize {
        self.sde.state_dim()
    }

    /// The `(t0, t1)` horizon.
    pub fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    /// Initial state.
    pub fn initial_state(&self) -> &[f64] {
        &self.z0
    }

    /// Parameter vector θ.
    pub fn theta(&self) -> &[f64] {
        &self.theta
    }

    /// PRNG key seeding the Brownian source.
    pub fn prng_key(&self) -> PrngKey {
        self.key
    }

    /// Brownian source specification.
    pub fn noise_spec(&self) -> NoiseSpec {
        self.noise
    }

    /// Whether the path is mirrored.
    pub fn is_mirrored(&self) -> bool {
        self.mirror
    }

    /// `n` clones of this problem with independent Brownian streams
    /// derived from `root` (replicate `i` gets `root.fold_in(i)`), ready
    /// for [`super::solve_batch`] / [`super::sensitivity_batch`].
    pub fn replicates(&self, root: PrngKey, n: usize) -> Vec<Self> {
        (0..n).map(|i| self.clone().key(root.fold_in(i as u64))).collect()
    }
}
