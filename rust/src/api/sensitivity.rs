//! Sensitivity algorithms: [`SensAlg`] → [`Gradients`].
//!
//! Estimator choice is orthogonal to everything else: the same
//! [`SdeProblem`] can be differentiated with the paper's stochastic
//! adjoint, the (checkpointed) backprop-through-solver baseline, forward
//! pathwise sensitivity, or an antithetic adjoint pair. The problem's
//! key, noise spec and mirror flag are authoritative for every family:
//! the taped estimators replay any in-tree source exactly (a stored path
//! caches queried times, the virtual tree is a pure function of
//! `(key, t)`, mirroring is a deterministic negation), so they realize
//! the *same* path the solve APIs would.

use super::problem::{ProblemError, SdeProblem};
use super::solve::{add_stats, StepControl};
use crate::adjoint::adaptive_grad::adaptive_adjoint_core;
use crate::adjoint::antithetic::{antithetic_core, AntitheticOutput};
use crate::adjoint::checkpoint::checkpointed_backprop_core;
use crate::adjoint::pathwise::pathwise_core;
use crate::adjoint::stochastic::{adjoint_multi_obs_core, adjoint_with_loss_core, GradientOutput};
use crate::adjoint::{AdjointConfig, Checkpointing};
use crate::sde::{Calculus, ReplicatedSde, ScalarSde, SdeVjp};
use crate::solvers::{AdaptiveConfig, Method, SolveStats};

/// Which gradient estimator to run (paper §3 / Table 1).
#[derive(Clone, Copy, Debug)]
pub enum SensAlg {
    /// The paper's stochastic adjoint sensitivity method: O(1) memory
    /// with a virtual-tree noise spec, O(L) with a stored path.
    StochasticAdjoint(AdjointConfig),
    /// Reverse-mode differentiation through the solver operations
    /// (`method` must be `EulerMaruyama`, `MilsteinIto` or `Heun`).
    /// `checkpointing` selects the tape's memory/recompute tradeoff —
    /// O(L) memory for the default full [`Checkpointing::Tape`], down to
    /// O(log L) with recursive schedules, with bit-identical gradients
    /// for every choice. See [`crate::adjoint::checkpoint`].
    Backprop { method: Method, checkpointing: Checkpointing },
    /// Forward sensitivity analysis propagating the full Jacobian.
    /// O(L·D) time.
    ForwardPathwise,
    /// The stochastic adjoint averaged over an antithetic pair `(W, −W)`
    /// — two coupled solves, lower-variance estimate.
    Antithetic { base: AdjointConfig },
}

impl SensAlg {
    /// Full-tape backprop with the given scheme — the historical
    /// `Backprop { method }` configuration.
    pub fn backprop(method: Method) -> SensAlg {
        SensAlg::Backprop { method, checkpointing: Checkpointing::Tape }
    }

    /// Stable identifier used in error messages and harness output (the
    /// convergence tables key their gradient-order rows on it).
    pub fn name(&self) -> &'static str {
        match self {
            SensAlg::StochasticAdjoint(_) => "StochasticAdjoint",
            SensAlg::Backprop { .. } => "Backprop",
            SensAlg::ForwardPathwise => "ForwardPathwise",
            SensAlg::Antithetic { .. } => "Antithetic",
        }
    }
}

/// Solver accounting for a gradient computation.
#[derive(Clone, Copy, Debug, Default)]
pub struct GradStats {
    pub forward: SolveStats,
    pub backward: SolveStats,
    /// Live f64s held by the noise source / tape at the end (Table 1's
    /// memory column).
    pub noise_memory: usize,
    /// Peak bytes of live tape + checkpoint storage (zero for the
    /// adjoint family) — the quantity `Checkpointing` schedules bound.
    pub peak_tape_bytes: usize,
    /// Drift + diffusion evaluations spent re-integrating segments
    /// during the backward pass (the recompute side of the
    /// memory/recompute tradeoff; zero for the full tape).
    pub recompute_nfe: u64,
    /// True if an adaptive controller hit `h_min`.
    pub hit_h_min: bool,
}

impl GradStats {
    /// Total function evaluations across both passes.
    pub fn nfe(&self) -> u64 {
        self.forward.nfe() + self.backward.nfe()
    }
}

/// Unified gradient result: `∂L/∂z0`, `∂L/∂θ`, and diagnostics.
#[derive(Clone, Debug)]
pub struct Gradients {
    /// `∂L/∂z_0`.
    pub dz0: Vec<f64>,
    /// `∂L/∂θ`.
    pub dtheta: Vec<f64>,
    /// Terminal state `z_T` of the forward solve.
    pub z_terminal: Vec<f64>,
    /// The backward pass's reconstruction of `z_0` (empty for algorithms
    /// that don't retrace the path).
    pub z0_reconstructed: Vec<f64>,
    /// Realized `W(t1)` of the driving path (closed-form ground truths of
    /// the §7.1 problems are functions of `W_T`).
    pub w_terminal: Vec<f64>,
    pub stats: GradStats,
}

impl From<GradientOutput> for Gradients {
    fn from(o: GradientOutput) -> Gradients {
        Gradients {
            dz0: o.grad_z0,
            dtheta: o.grad_theta,
            z_terminal: o.z_terminal,
            z0_reconstructed: o.z0_reconstructed,
            w_terminal: o.w_terminal,
            stats: GradStats {
                forward: o.forward_stats,
                backward: o.backward_stats,
                noise_memory: o.noise_memory,
                peak_tape_bytes: o.peak_tape_bytes,
                recompute_nfe: o.recompute_nfe,
                hit_h_min: false,
            },
        }
    }
}

fn from_antithetic(pair: AntitheticOutput) -> Gradients {
    let AntitheticOutput { grad_theta, grad_z0, plus, minus } = pair;
    let mut forward = plus.forward_stats;
    let mut backward = plus.backward_stats;
    add_stats(&mut forward, &minus.forward_stats);
    add_stats(&mut backward, &minus.backward_stats);
    Gradients {
        dz0: grad_z0,
        dtheta: grad_theta,
        z_terminal: plus.z_terminal,
        z0_reconstructed: plus.z0_reconstructed,
        w_terminal: plus.w_terminal,
        stats: GradStats {
            forward,
            backward,
            noise_memory: plus.noise_memory + minus.noise_memory,
            peak_tape_bytes: plus.peak_tape_bytes + minus.peak_tape_bytes,
            recompute_nfe: plus.recompute_nfe + minus.recompute_nfe,
            hit_h_min: false,
        },
    }
}

/// Calculus/VJP/noise compatibility check, run before any integration.
/// This is where the old mid-solve `ito_correction_vjp` panic surfaces as
/// a [`ProblemError`] instead. (Shared with [`super::batch`], whose
/// batched kernel validates once for the whole fleet.)
pub(crate) fn validate_alg<S: SdeVjp + ?Sized>(
    prob: &SdeProblem<'_, S>,
    alg: &SensAlg,
) -> Result<(), ProblemError> {
    let sde = prob.sde();
    let name = alg.name();
    match alg {
        SensAlg::StochasticAdjoint(_) | SensAlg::Antithetic { .. } => {
            // The backward Stratonovich dynamics need the correction VJP
            // for Itô-native systems.
            if sde.check_adjoint_compatible().is_err() {
                return Err(ProblemError::MissingItoCorrectionVjp { algorithm: name });
            }
        }
        SensAlg::Backprop { method, .. } => match method {
            Method::EulerMaruyama | Method::MilsteinIto => {
                if sde.calculus() != Calculus::Ito {
                    return Err(ProblemError::CalculusMismatch {
                        algorithm: name,
                        required: Calculus::Ito,
                    });
                }
                // The Milstein correction term's pullback needs second
                // derivatives of σ.
                if *method == Method::MilsteinIto && !sde.has_ito_correction_vjp() {
                    return Err(ProblemError::MissingItoCorrectionVjp { algorithm: name });
                }
            }
            Method::Heun => {
                // Heun steps the Stratonovich drift form; for Itô-native
                // systems the conversion's pullback needs the correction
                // VJP (same requirement as the adjoint family).
                if sde.calculus() == Calculus::Ito && !sde.has_ito_correction_vjp() {
                    return Err(ProblemError::MissingItoCorrectionVjp { algorithm: name });
                }
            }
            _ => {
                return Err(ProblemError::UnsupportedMethod { algorithm: name, method: *method });
            }
        },
        SensAlg::ForwardPathwise => {
            if sde.calculus() != Calculus::Ito {
                return Err(ProblemError::CalculusMismatch {
                    algorithm: name,
                    required: Calculus::Ito,
                });
            }
        }
    }
    // Every in-tree noise spec (stored path, virtual tree, mirrored
    // either way) replays deterministically, so the taped family now
    // honors the problem's spec directly; `ProblemError::UnsupportedNoise`
    // remains reserved for genuinely unreplayable sources.
    Ok(())
}

impl<'a, S: SdeVjp + ?Sized> SdeProblem<'a, S> {
    /// Gradients of an arbitrary scalar terminal loss `L(z_T)`:
    /// `loss_grad` maps the realized terminal state to `∂L/∂z_T`. (For
    /// [`SensAlg::Antithetic`] the closure runs once per branch.)
    ///
    /// The problem's noise spec and mirror flag are honored by every
    /// family (for the adjoint they override the corresponding
    /// `AdjointConfig` fields; the taped estimators replay any in-tree
    /// source exactly).
    pub fn sensitivity<F>(
        &self,
        alg: &SensAlg,
        step: StepControl,
        mut loss_grad: F,
    ) -> Result<Gradients, ProblemError>
    where
        F: FnMut(&[f64]) -> Vec<f64>,
    {
        validate_alg(self, alg)?;
        let n_steps = match step {
            StepControl::Adaptive(_) => return Err(ProblemError::AdaptiveSensitivityUnsupported),
            other => other.resolve_steps(self.t0, self.t1),
        };
        let out = match alg {
            SensAlg::StochasticAdjoint(cfg) => {
                let eff = self.effective_adjoint_config(cfg);
                adjoint_with_loss_core(
                    self.sde,
                    &self.theta,
                    &self.z0,
                    self.t0,
                    self.t1,
                    n_steps,
                    self.key,
                    &eff,
                    &mut loss_grad,
                )
                .into()
            }
            SensAlg::Backprop { method, checkpointing } => checkpointed_backprop_core(
                self.sde,
                &self.theta,
                &self.z0,
                self.t0,
                self.t1,
                n_steps,
                self.key,
                *method,
                self.noise,
                self.mirror,
                self.tree_cache,
                *checkpointing,
                &mut loss_grad,
            )
            .into(),
            SensAlg::ForwardPathwise => pathwise_core(
                self.sde,
                &self.theta,
                &self.z0,
                self.t0,
                self.t1,
                n_steps,
                self.key,
                self.noise,
                self.mirror,
                &mut loss_grad,
            )
            .into(),
            SensAlg::Antithetic { base } => {
                let eff = self.effective_adjoint_config(base);
                from_antithetic(antithetic_core(
                    self.sde,
                    &self.theta,
                    &self.z0,
                    self.t0,
                    self.t1,
                    n_steps,
                    self.key,
                    &eff,
                    &mut loss_grad,
                ))
            }
        };
        Ok(out)
    }

    /// Gradients of the paper's numerical-study loss `L = Σ_i z_T^(i)`
    /// (its terminal gradient is the ones vector).
    pub fn sensitivity_sum(
        &self,
        alg: &SensAlg,
        step: StepControl,
    ) -> Result<Gradients, ProblemError> {
        self.sensitivity(alg, step, |z: &[f64]| vec![1.0; z.len()])
    }

    /// Multi-observation stochastic adjoint (App. 9.12): the loss is
    /// `L = Σ_k ℓ_k(z_{t_k})` over `obs_times` (ascending, last equal to
    /// the problem's `t1`). `loss_grads` receives the forward states at
    /// all observation times (row-major `n_obs × d`) and returns every
    /// `∂L/∂z_{t_k}` in the same layout; the backward pass injects each
    /// gradient as it crosses the corresponding time.
    pub fn sensitivity_at<F>(
        &self,
        obs_times: &[f64],
        steps_per_interval: usize,
        cfg: &AdjointConfig,
        loss_grads: F,
    ) -> Result<Gradients, ProblemError>
    where
        F: FnOnce(&[f64]) -> Vec<f64>,
    {
        validate_alg(self, &SensAlg::StochasticAdjoint(*cfg))?;
        assert!(!obs_times.is_empty(), "sensitivity_at: need at least one observation time");
        assert_eq!(
            obs_times[obs_times.len() - 1],
            self.t1,
            "sensitivity_at: last observation time must equal the problem horizon"
        );
        let eff = self.effective_adjoint_config(cfg);
        Ok(adjoint_multi_obs_core(
            self.sde,
            &self.theta,
            &self.z0,
            self.t0,
            obs_times,
            steps_per_interval,
            self.key,
            &eff,
            loss_grads,
        )
        .into())
    }

    fn effective_adjoint_config(&self, cfg: &AdjointConfig) -> AdjointConfig {
        AdjointConfig { noise: self.noise, mirror: self.mirror, ..*cfg }
    }
}

impl<'a, P: ScalarSde> SdeProblem<'a, ReplicatedSde<P>> {
    /// Stochastic adjoint with adaptive time-stepping in *both* passes
    /// (Fig 5b's setting), available for replicated scalar problems whose
    /// augmented backward system is fully diagonal. Uses a stored-path
    /// noise source regardless of the problem's noise spec (adaptive
    /// solves query at unpredictable times either way).
    pub fn sensitivity_adaptive(&self, cfg: &AdaptiveConfig) -> Gradients {
        let out =
            adaptive_adjoint_core(self.sde, &self.theta, &self.z0, self.t0, self.t1, self.key, cfg);
        Gradients {
            dz0: out.grad_z0,
            dtheta: out.grad_theta,
            z_terminal: out.z_terminal,
            z0_reconstructed: Vec::new(),
            w_terminal: out.w_terminal,
            stats: GradStats {
                forward: out.forward_stats,
                backward: out.backward_stats,
                noise_memory: 0,
                peak_tape_bytes: 0,
                recompute_nfe: 0,
                hit_h_min: out.hit_h_min,
            },
        }
    }
}

