//! Forward solves: [`SolveOptions`] → [`SdeSolution`]. (The batch entry
//! points live in [`super::batch`].)

use super::problem::SdeProblem;
use crate::adjoint::stochastic::Noise;
use crate::brownian::BrownianMotion;
use crate::runtime::ExecConfig;
use crate::sde::{ForwardFunc, KernelTier, Sde};
use crate::solvers::{
    adaptive_core, grid_core, grid_saving_core, uniform_grid, AdaptiveConfig, Method, SolveStats,
};

/// How the solver advances time.
#[derive(Clone, Copy, Debug)]
pub enum StepControl {
    /// Fixed step size `dt`; the horizon is divided into
    /// `round(|t1 − t0| / dt)` uniform steps (at least one).
    Fixed(f64),
    /// Exactly `n` uniform steps across the horizon (per save interval
    /// when combined with [`SaveAt::Grid`]).
    Steps(usize),
    /// Adaptive step-doubling with a PI controller (forward solves only;
    /// saves the final state).
    Adaptive(AdaptiveConfig),
}

impl StepControl {
    /// Number of uniform steps across `(t0, t1)` for the fixed variants.
    pub(crate) fn resolve_steps(&self, t0: f64, t1: f64) -> usize {
        match self {
            StepControl::Fixed(dt) => (((t1 - t0) / dt).abs().round() as usize).max(1),
            StepControl::Steps(n) => (*n).max(1),
            StepControl::Adaptive(_) => {
                unreachable!("resolve_steps called with adaptive step control")
            }
        }
    }
}

/// Which states the solution records.
#[derive(Clone, Copy, Debug, Default)]
pub enum SaveAt<'t> {
    /// Only the state at `t1` (cheapest; the default).
    #[default]
    Final,
    /// The state at each listed time (must start at `t0` and end at `t1`;
    /// the solver steps uniformly *within* each interval, so the listed
    /// times are hit exactly).
    Grid(&'t [f64]),
    /// The state at every solver step.
    Dense,
}

/// Everything about *how* to solve (nothing about *what* — that is the
/// problem's job).
#[derive(Clone, Copy, Debug)]
pub struct SolveOptions<'t> {
    /// Single-step scheme. Itô schemes integrate the Itô reading of the
    /// coefficients, Stratonovich schemes the converted form — either way
    /// the solve targets the process the SDE natively defines.
    pub method: Method,
    pub step: StepControl,
    pub save: SaveAt<'t>,
    /// Execution configuration ([`crate::runtime::ExecConfig`]). The
    /// `exec.tier` knob selects the kernel tier for **batched** execution
    /// ([`super::solve_batch`] and friends): [`KernelTier::Exact`] (the
    /// default) keeps the bit-identical-to-scalar guarantee;
    /// [`KernelTier::Fast`] routes the batch through
    /// autovectorization-friendly fused kernels validated to tolerance.
    /// Scalar (per-path) solves always run the exact engine — the tier is
    /// a property of the batched sweep, so the scalar fallback paths
    /// ignore it. `exec.threads` pins the worker count for the batched
    /// sweep (`None` defers to the global chain).
    pub exec: ExecConfig,
}

impl Default for SolveOptions<'static> {
    fn default() -> Self {
        SolveOptions {
            method: Method::MilsteinIto,
            step: StepControl::Steps(100),
            save: SaveAt::Final,
            exec: ExecConfig::default(),
        }
    }
}

impl SolveOptions<'static> {
    /// Fixed-grid options: `n_steps` uniform steps, final state only.
    pub fn fixed(method: Method, n_steps: usize) -> Self {
        SolveOptions {
            method,
            step: StepControl::Steps(n_steps),
            save: SaveAt::Final,
            exec: ExecConfig::default(),
        }
    }

    /// Adaptive options: PI-controlled stepping, final state only.
    pub fn adaptive(method: Method, cfg: AdaptiveConfig) -> Self {
        SolveOptions {
            method,
            step: StepControl::Adaptive(cfg),
            save: SaveAt::Final,
            exec: ExecConfig::default(),
        }
    }
}

impl<'t> SolveOptions<'t> {
    /// Replace the save specification (changes the lifetime parameter, so
    /// it rebuilds rather than mutates).
    pub fn save<'u>(self, save: SaveAt<'u>) -> SolveOptions<'u> {
        SolveOptions { method: self.method, step: self.step, save, exec: self.exec }
    }

    /// Select the kernel tier for batched execution (shorthand for
    /// setting `exec.tier`).
    pub fn tier(mut self, tier: KernelTier) -> Self {
        self.exec.tier = tier;
        self
    }

    /// Replace the whole execution configuration.
    pub fn exec(mut self, exec: ExecConfig) -> Self {
        self.exec = exec;
        self
    }
}

/// The realized Brownian source of a finished solve, handed back so the
/// *same* sample path can be replayed — e.g. to query `W(t)` for
/// closed-form comparisons, or to drive a backward pass. (A stored
/// [`crate::brownian::BrownianPath`] is query-order dependent, so
/// re-creating it from the seed would reveal a different path; the handle
/// is the only faithful replay mechanism.)
pub struct NoiseHandle {
    pub(crate) inner: Noise,
}

impl NoiseHandle {
    /// Total Brownian-bridge draws performed by the underlying virtual
    /// tree over its lifetime — both passes of a solve/gradient — or 0
    /// for a stored path. The observable behind the tree node cache's
    /// amortized-O(1)-draws-per-step contract (see
    /// [`SdeProblem::tree_cache`]).
    pub fn bridge_calls(&self) -> u64 {
        self.inner.bridge_calls()
    }
}

impl BrownianMotion for NoiseHandle {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn span(&self) -> (f64, f64) {
        self.inner.span()
    }
    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        self.inner.sample_into(t, out)
    }
    fn memory_footprint(&self) -> usize {
        self.inner.memory_footprint()
    }
}

/// The solution half of the API: saved states, solver statistics, and the
/// noise handle needed for replay.
pub struct SdeSolution {
    /// Times at which states were saved (a single entry `t1` for
    /// [`SaveAt::Final`]).
    pub times: Vec<f64>,
    /// Saved states, row-major `(times.len(), d)`.
    pub states: Vec<f64>,
    pub stats: SolveStats,
    /// True if an adaptive controller hit `h_min` (accuracy not
    /// certified).
    pub hit_h_min: bool,
    /// The Brownian source that drove the solve (replayable).
    pub noise: NoiseHandle,
    pub(crate) d: usize,
}

impl SdeSolution {
    /// State dimension d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Saved state at save-index `k`.
    pub fn state(&self, k: usize) -> &[f64] {
        &self.states[k * self.d..(k + 1) * self.d]
    }

    /// The state at the end of the horizon.
    pub fn final_state(&self) -> &[f64] {
        self.state(self.times.len() - 1)
    }

    /// Time of the last saved state.
    pub fn final_time(&self) -> f64 {
        *self.times.last().expect("solution has at least one saved state")
    }

    /// Linear interpolation of the saved trajectory at `t` (clamped to
    /// the saved range; exact at saved times).
    pub fn at(&self, t: f64) -> Vec<f64> {
        let n = self.times.len();
        let d = self.d;
        if n == 1 {
            return self.states[..d].to_vec();
        }
        let ascending = self.times[n - 1] >= self.times[0];
        let (mut lo, mut hi) = (0usize, n - 1);
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let below = if ascending { self.times[mid] <= t } else { self.times[mid] >= t };
            if below {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let (ta, tb) = (self.times[lo], self.times[hi]);
        let w = if tb == ta { 0.0 } else { ((t - ta) / (tb - ta)).clamp(0.0, 1.0) };
        let a = self.state(lo);
        let b = self.state(hi);
        a.iter().zip(b).map(|(x, y)| x + w * (y - x)).collect()
    }

    /// Replay the realized Brownian path at `t`.
    pub fn w(&mut self, t: f64) -> Vec<f64> {
        self.noise.sample(t)
    }
}

impl<'a, S: Sde + ?Sized> SdeProblem<'a, S> {
    /// Solve the problem forward according to `opts`.
    ///
    /// Panics on structurally invalid combinations (adaptive stepping
    /// with non-final saves; a save grid that does not span the horizon);
    /// everything value-dependent was validated at construction.
    pub fn solve(&self, opts: &SolveOptions<'_>) -> SdeSolution {
        let d = self.dim();
        let mut noise =
            Noise::with_cache(self.noise, self.key, d, self.t0, self.t1, self.mirror, self.tree_cache);

        if let StepControl::Adaptive(cfg) = opts.step {
            assert!(
                matches!(opts.save, SaveAt::Final),
                "SdeProblem::solve: adaptive stepping only supports SaveAt::Final"
            );
            let mut sys = ForwardFunc::for_method(self.sde, &self.theta, opts.method);
            let res =
                adaptive_core(&mut sys, opts.method, &self.z0, self.t0, self.t1, &mut noise, &cfg);
            return SdeSolution {
                times: vec![self.t1],
                states: res.y,
                stats: res.stats,
                hit_h_min: res.hit_h_min,
                noise: NoiseHandle { inner: noise },
                d,
            };
        }

        match opts.save {
            SaveAt::Final => {
                let n = opts.step.resolve_steps(self.t0, self.t1);
                let grid = uniform_grid(self.t0, self.t1, n);
                let mut sys = ForwardFunc::for_method(self.sde, &self.theta, opts.method);
                let mut y = vec![0.0; d];
                let stats = grid_core(&mut sys, opts.method, &self.z0, &grid, &mut noise, &mut y);
                SdeSolution {
                    times: vec![self.t1],
                    states: y,
                    stats,
                    hit_h_min: false,
                    noise: NoiseHandle { inner: noise },
                    d,
                }
            }
            SaveAt::Dense => {
                let n = opts.step.resolve_steps(self.t0, self.t1);
                let grid = uniform_grid(self.t0, self.t1, n);
                let mut sys = ForwardFunc::for_method(self.sde, &self.theta, opts.method);
                let (states, stats) =
                    grid_saving_core(&mut sys, opts.method, &self.z0, &grid, &mut noise);
                SdeSolution {
                    times: grid,
                    states,
                    stats,
                    hit_h_min: false,
                    noise: NoiseHandle { inner: noise },
                    d,
                }
            }
            SaveAt::Grid(ts) => {
                assert!(ts.len() >= 2, "SaveAt::Grid: need at least two save times");
                assert_eq!(ts[0], self.t0, "SaveAt::Grid: first save time must be t0");
                assert_eq!(ts[ts.len() - 1], self.t1, "SaveAt::Grid: last save time must be t1");
                let mut y = self.z0.clone();
                let mut states = vec![0.0; ts.len() * d];
                states[..d].copy_from_slice(&y);
                let mut stats = SolveStats::default();
                let mut sys = ForwardFunc::for_method(self.sde, &self.theta, opts.method);
                for k in 1..ts.len() {
                    let n_k = match opts.step {
                        StepControl::Steps(n) => n.max(1),
                        StepControl::Fixed(dt) => {
                            (((ts[k] - ts[k - 1]) / dt).abs().round() as usize).max(1)
                        }
                        StepControl::Adaptive(_) => unreachable!(),
                    };
                    let grid = uniform_grid(ts[k - 1], ts[k], n_k);
                    let mut y_next = vec![0.0; d];
                    let st = grid_core(&mut sys, opts.method, &y, &grid, &mut noise, &mut y_next);
                    add_stats(&mut stats, &st);
                    y = y_next;
                    states[k * d..(k + 1) * d].copy_from_slice(&y);
                }
                SdeSolution {
                    times: ts.to_vec(),
                    states,
                    stats,
                    hit_h_min: false,
                    noise: NoiseHandle { inner: noise },
                    d,
                }
            }
        }
    }

    /// Piecewise solve over the save times `ts` (ascending, spanning the
    /// horizon) with `substeps` uniform solver steps per interval and a
    /// per-interval parameter override: before integrating interval `k`
    /// (from `ts[k]` to `ts[k+1]`), `theta_for` may rewrite the working
    /// parameter vector in place (it starts as the problem's θ).
    ///
    /// This is the primitive behind context-conditioned solves — the
    /// latent-SDE posterior integrates each observation interval with the
    /// encoder context appended to θ — while sharing one Brownian source
    /// across intervals, exactly as a single continuous solve would.
    pub fn solve_intervals<F>(
        &self,
        ts: &[f64],
        substeps: usize,
        method: Method,
        mut theta_for: F,
    ) -> SdeSolution
    where
        F: FnMut(usize, &mut [f64]),
    {
        let d = self.dim();
        assert!(ts.len() >= 2, "solve_intervals: need at least two save times");
        assert_eq!(ts[0], self.t0, "solve_intervals: first save time must be t0");
        assert_eq!(ts[ts.len() - 1], self.t1, "solve_intervals: last save time must be t1");
        let mut noise =
            Noise::with_cache(self.noise, self.key, d, self.t0, self.t1, self.mirror, self.tree_cache);

        let mut theta = self.theta.clone();
        let mut y = self.z0.clone();
        let mut states = vec![0.0; ts.len() * d];
        states[..d].copy_from_slice(&y);
        let mut stats = SolveStats::default();
        for k in 1..ts.len() {
            theta_for(k - 1, &mut theta);
            let grid = uniform_grid(ts[k - 1], ts[k], substeps.max(1));
            let mut sys = ForwardFunc::for_method(self.sde, &theta, method);
            let mut y_next = vec![0.0; d];
            let st = grid_core(&mut sys, method, &y, &grid, &mut noise, &mut y_next);
            add_stats(&mut stats, &st);
            y = y_next;
            states[k * d..(k + 1) * d].copy_from_slice(&y);
        }
        SdeSolution {
            times: ts.to_vec(),
            states,
            stats,
            hit_h_min: false,
            noise: NoiseHandle { inner: noise },
            d,
        }
    }
}

pub(crate) fn add_stats(total: &mut SolveStats, one: &SolveStats) {
    total.steps += one.steps;
    total.rejected += one.rejected;
    total.nfe_drift += one.nfe_drift;
    total.nfe_diffusion += one.nfe_diffusion;
}

/// Order-preserving parallel map over `0..n` on the persistent pool
/// ([`crate::runtime::scoped_map`]; the vendored crate set has no rayon).
/// Used by the batch entry points in [`super::batch`] to fan chunks —
/// and per-path fallbacks — across cores. Width comes from
/// [`crate::runtime::worker_count`]; results are bit-identical for any
/// width.
pub(crate) fn par_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    crate::runtime::scoped_map(n, usize::MAX, f)
}

/// [`par_map`] with an optional per-call worker cap
/// ([`ExecConfig::threads`]); `None` uses the full pool. The cap only
/// changes scheduling, never a float — results stay bit-identical.
pub(crate) fn par_map_with<T, F>(n: usize, threads: Option<usize>, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    crate::runtime::scoped_map(n, threads.map_or(usize::MAX, |t| t.max(1)), f)
}
