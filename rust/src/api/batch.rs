//! Batch entry points: [`solve_batch`] / [`sensitivity_batch`] on the
//! batched SoA execution engine.
//!
//! ## Execution model
//!
//! A slice of problems (typically [`SdeProblem::replicates`] of one
//! problem over independent keys) is split into fixed-size **chunks**;
//! chunks fan out across the persistent work-stealing pool
//! ([`crate::runtime::scoped_map`] — workers are spawned once and parked
//! between calls), and each chunk advances all of its paths *together*
//! through the batched kernels
//! ([`crate::solvers::batch`], [`crate::adjoint::batch`]) over
//! contiguous `[B×d]` buffers. This replaces the pre-0.3 thread-per-path
//! model: the batched kernel pays one dispatch per solver stage instead
//! of per path and keeps coefficients/weights hot in cache across the
//! chunk, while threads still cover the outer batch.
//!
//! ## Determinism and exactness
//!
//! Each path is a pure function of its own key, and the batched kernels
//! compute every per-path float in the scalar engine's exact evaluation
//! order — so results are **bit-identical** to solving each problem
//! sequentially with [`SdeProblem::solve`] /
//! [`SdeProblem::sensitivity_sum`], regardless of thread count or chunk
//! boundaries (pinned by `tests/batch_engine.rs`).
//!
//! ## Batchability
//!
//! The batched kernel requires the problems to share one SDE instance,
//! parameter vector, horizon, and noise-spec kind (per-path initial
//! states, keys, and mirror flags may vary — that is what replicates
//! vary). Mixed batches, adaptive stepping, [`SaveAt::Grid`] saves, and
//! the pathwise/antithetic estimators fall back to the per-path engine
//! ([`solve_batch_per_path`] / [`sensitivity_batch_per_path`]), which
//! remains available directly as the throughput-bench baseline.
//! [`SensAlg::Backprop`] runs batched (each chunk keeps its own
//! checkpoint schedule; per-path gradients still reduce in path order).

use super::problem::{ProblemError, SdeProblem};
use super::sensitivity::{validate_alg, GradStats, Gradients, SensAlg};
use super::solve::{par_map, par_map_with, NoiseHandle, SaveAt, SdeSolution, SolveOptions, StepControl};
use crate::adjoint::batch::batch_adjoint_sum_core;
use crate::adjoint::checkpoint::batch_checkpoint_backprop_core;
use crate::adjoint::stochastic::Noise;
use crate::adjoint::{AdjointConfig, Checkpointing};
use crate::brownian::{BatchBrownian, BrownianMotion};
use crate::runtime::arena::lease;
use crate::runtime::ExecConfig;
use crate::sde::{BatchSde, BatchSdeVjp, KernelTier};
use crate::solvers::{
    batch_grid_core, batch_grid_saving_core, uniform_grid, BatchForwardFunc, Method,
};

/// Paths per batched-kernel chunk. Large enough to amortize per-stage
/// dispatch and keep weight rows hot, small enough that `B×d` stage
/// buffers stay cache-resident and chunks outnumber cores for balance.
/// Chunk boundaries never affect results (each path's floats are
/// independent of its neighbours), only scheduling.
const CHUNK: usize = 32;

/// Can this problem set run on the batched kernel as one fleet?
fn batchable<S: BatchSde + ?Sized>(problems: &[SdeProblem<'_, S>]) -> bool {
    let p0 = &problems[0];
    problems.iter().all(|p| {
        // Same SDE instance (data pointers compared — metadata stripped so
        // trait-object batches don't trip over vtable identity).
        std::ptr::eq((p.sde as *const S).cast::<()>(), (p0.sde as *const S).cast::<()>())
            && p.theta == p0.theta
            && p.t0 == p0.t0
            && p.t1 == p0.t1
            && p.noise == p0.noise
    })
}

/// Chunk index ranges `[start, end)` of `n` items.
fn chunks(n: usize) -> Vec<(usize, usize)> {
    (0..n.div_ceil(CHUNK)).map(|c| (c * CHUNK, ((c + 1) * CHUNK).min(n))).collect()
}

/// Per-path noise sources carrying each problem's key and mirror flag.
fn noise_fleet<S: BatchSde + ?Sized>(
    problems: &[SdeProblem<'_, S>],
    d: usize,
) -> BatchBrownian<Noise> {
    BatchBrownian::new(
        problems
            .iter()
            .map(|p| Noise::with_cache(p.noise, p.key, d, p.t0, p.t1, p.mirror, p.tree_cache))
            .collect(),
    )
}

/// Solve many problems on the batched SoA engine (chunked across scoped
/// threads). Results are in input order and bit-identical to sequential
/// per-problem [`SdeProblem::solve`] calls regardless of thread count.
///
/// Falls back to the per-path engine for non-batchable sets, adaptive
/// stepping, and [`SaveAt::Grid`] saves.
pub fn solve_batch<'a, S>(
    problems: &[SdeProblem<'a, S>],
    opts: &SolveOptions<'_>,
) -> Vec<SdeSolution>
where
    S: BatchSde + Sync + ?Sized,
{
    if problems.is_empty() {
        return Vec::new();
    }
    let fallback = !batchable(problems)
        || matches!(opts.step, StepControl::Adaptive(_))
        || matches!(opts.save, SaveAt::Grid(_));
    if fallback {
        return solve_batch_per_path(problems, opts);
    }
    let ranges = chunks(problems.len());
    par_map_with(ranges.len(), opts.exec.threads, |c| {
        let (lo, hi) = ranges[c];
        solve_chunk(&problems[lo..hi], opts)
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Single-threaded batched solve for SDEs that are not `Sync` (the
/// latent posterior carries interior-mutable scratch): every chunk runs
/// the batched kernel on the calling thread. Results equal
/// [`solve_batch`]'s exactly — only the scheduling differs.
pub fn solve_batch_local<'a, S>(
    problems: &[SdeProblem<'a, S>],
    opts: &SolveOptions<'_>,
) -> Vec<SdeSolution>
where
    S: BatchSde + ?Sized,
{
    if problems.is_empty() {
        return Vec::new();
    }
    let fallback = !batchable(problems)
        || matches!(opts.step, StepControl::Adaptive(_))
        || matches!(opts.save, SaveAt::Grid(_));
    if fallback {
        return problems.iter().map(|p| p.solve(opts)).collect();
    }
    chunks(problems.len())
        .into_iter()
        .flat_map(|(lo, hi)| solve_chunk(&problems[lo..hi], opts))
        .collect()
}

/// The pre-0.3 thread-per-path engine: each problem solved independently
/// on the scalar kernel, fanned across scoped threads. Kept public as the
/// baseline the `sdegrad bench throughput` harness compares against.
pub fn solve_batch_per_path<'a, S>(
    problems: &[SdeProblem<'a, S>],
    opts: &SolveOptions<'_>,
) -> Vec<SdeSolution>
where
    S: BatchSde + Sync + ?Sized,
{
    par_map_with(problems.len(), opts.exec.threads, |i| problems[i].solve(opts))
}

/// One chunk through the batched forward kernel.
fn solve_chunk<S: BatchSde + ?Sized>(
    problems: &[SdeProblem<'_, S>],
    opts: &SolveOptions<'_>,
) -> Vec<SdeSolution> {
    let p0 = &problems[0];
    let d = p0.dim();
    let bsz = problems.len();
    let (t0, t1) = (p0.t0, p0.t1);
    let n = opts.step.resolve_steps(t0, t1);
    let grid = uniform_grid(t0, t1, n);

    // Staging buffers come from the per-thread arena: pool workers are
    // persistent, so consecutive chunks on a worker reuse warm buffers.
    let mut y0 = lease(bsz * d);
    for (row, p) in y0.chunks_exact_mut(d).zip(problems) {
        row.copy_from_slice(&p.z0);
    }
    let mut bm = noise_fleet(problems, d);
    let mut sys =
        BatchForwardFunc::for_method_tier(p0.sde, &p0.theta, bsz, opts.method, opts.exec.tier);

    match opts.save {
        SaveAt::Final => {
            let mut y_out = lease(bsz * d);
            let stats = batch_grid_core(&mut sys, opts.method, &y0, &grid, &mut bm, &mut y_out);
            bm.into_sources()
                .into_iter()
                .enumerate()
                .map(|(b, src)| SdeSolution {
                    times: vec![t1],
                    states: y_out[b * d..(b + 1) * d].to_vec(),
                    stats,
                    hit_h_min: false,
                    noise: NoiseHandle { inner: src },
                    d,
                })
                .collect()
        }
        SaveAt::Dense => {
            let (traj, stats) =
                batch_grid_saving_core(&mut sys, opts.method, &y0, &grid, &mut bm);
            bm.into_sources()
                .into_iter()
                .enumerate()
                .map(|(b, src)| {
                    // Gather path b's rows out of the (times, B, d) buffer.
                    let mut states = vec![0.0; grid.len() * d];
                    for k in 0..grid.len() {
                        states[k * d..(k + 1) * d]
                            .copy_from_slice(&traj[(k * bsz + b) * d..(k * bsz + b + 1) * d]);
                    }
                    SdeSolution {
                        times: grid.clone(),
                        states,
                        stats,
                        hit_h_min: false,
                        noise: NoiseHandle { inner: src },
                        d,
                    }
                })
                .collect()
        }
        SaveAt::Grid(_) => unreachable!("grid saves take the per-path fallback"),
    }
}

/// The batched gradient engines and their per-chunk configuration.
#[derive(Clone, Copy)]
enum BatchedGradAlg {
    Adjoint(AdjointConfig),
    Backprop { method: Method, checkpointing: Checkpointing },
}

/// Differentiate many problems for the summed loss `L = Σ z_T` on the
/// batched SoA engine. [`SensAlg::StochasticAdjoint`] runs the batched
/// augmented adjoint (one `[B×(2d+p+1)]` state per chunk);
/// [`SensAlg::Backprop`] runs the batched checkpointed backprop (each
/// chunk keeps its own schedule); the pathwise and antithetic estimators
/// fall back to the per-path engine. Results are in input order and
/// bit-identical to per-problem [`SdeProblem::sensitivity_sum`] calls
/// regardless of thread count.
///
/// `exec` selects the execution configuration. `exec.tier ==`
/// [`KernelTier::Fast`] routes the forward solve and the augmented
/// backward sweep of the stochastic adjoint through the fused/fast VJP
/// kernels (validated to tolerance in `tests/fast_tier.rs`);
/// [`SensAlg::Backprop`] always runs the exact tier — the checkpointed
/// tape is pinned bit-identical to full-tape backprop and serves as a
/// bit-exactness oracle, so it does not relax float order. The per-path
/// fallback estimators likewise ignore the tier (the fast tier is a
/// property of batched sweeps). `exec.threads` caps the chunk fan-out;
/// each problem's own `tree_cache` field stays authoritative for its
/// noise source (it is per-problem state, not call-level config).
pub fn sensitivity_batch<'a, S>(
    problems: &[SdeProblem<'a, S>],
    alg: &SensAlg,
    step: StepControl,
    exec: ExecConfig,
) -> Vec<Result<Gradients, ProblemError>>
where
    S: BatchSdeVjp + Sync + ?Sized,
{
    let tier = exec.tier;
    if problems.is_empty() {
        return Vec::new();
    }
    let batched = match alg {
        SensAlg::StochasticAdjoint(cfg) if batchable(problems) => BatchedGradAlg::Adjoint(*cfg),
        SensAlg::Backprop { method, checkpointing } if batchable(problems) => {
            BatchedGradAlg::Backprop { method: *method, checkpointing: *checkpointing }
        }
        _ => return sensitivity_batch_per_path(problems, alg, step),
    };
    // Validation depends only on the shared SDE and the algorithm.
    if let Err(e) = validate_alg(&problems[0], alg) {
        return problems.iter().map(|_| Err(e.clone())).collect();
    }
    let n_steps = match step {
        StepControl::Adaptive(_) => {
            return problems
                .iter()
                .map(|_| Err(ProblemError::AdaptiveSensitivityUnsupported))
                .collect()
        }
        other => other.resolve_steps(problems[0].t0, problems[0].t1),
    };

    let ranges = chunks(problems.len());
    par_map_with(ranges.len(), exec.threads, |c| {
        let (lo, hi) = ranges[c];
        match batched {
            BatchedGradAlg::Adjoint(cfg) => {
                sensitivity_chunk(&problems[lo..hi], &cfg, n_steps, tier)
            }
            BatchedGradAlg::Backprop { method, checkpointing } => {
                backprop_chunk(&problems[lo..hi], method, checkpointing, n_steps)
            }
        }
    })
    .into_iter()
    .flatten()
    .map(Ok)
    .collect()
}

/// Deprecated spelling of [`sensitivity_batch`] from before
/// [`ExecConfig`] unified the execution knobs; bit-identical to the base
/// entry point (pinned in `tests/exec_config.rs`).
#[deprecated(
    since = "0.2.0",
    note = "use `sensitivity_batch(problems, alg, step, ExecConfig::new().tier(tier))`"
)]
pub fn sensitivity_batch_tier<'a, S>(
    problems: &[SdeProblem<'a, S>],
    alg: &SensAlg,
    step: StepControl,
    tier: KernelTier,
) -> Vec<Result<Gradients, ProblemError>>
where
    S: BatchSdeVjp + Sync + ?Sized,
{
    sensitivity_batch(problems, alg, step, ExecConfig::new().tier(tier))
}

/// The pre-0.3 thread-per-path gradient engine (scalar adjoint per
/// problem, fanned across threads). Baseline for the throughput bench.
pub fn sensitivity_batch_per_path<'a, S>(
    problems: &[SdeProblem<'a, S>],
    alg: &SensAlg,
    step: StepControl,
) -> Vec<Result<Gradients, ProblemError>>
where
    S: BatchSdeVjp + Sync + ?Sized,
{
    par_map(problems.len(), |i| problems[i].sensitivity_sum(alg, step))
}

/// One chunk through the batched augmented adjoint.
fn sensitivity_chunk<S: BatchSdeVjp + ?Sized>(
    problems: &[SdeProblem<'_, S>],
    cfg: &crate::adjoint::AdjointConfig,
    n_steps: usize,
    tier: KernelTier,
) -> Vec<Gradients> {
    let p0 = &problems[0];
    let d = p0.dim();
    let p = p0.sde.param_dim();
    let bsz = problems.len();

    let mut z0 = lease(bsz * d);
    for (row, pr) in z0.chunks_exact_mut(d).zip(problems) {
        row.copy_from_slice(&pr.z0);
    }
    // The problem's noise spec / mirror flags are authoritative, exactly
    // as in the scalar path's effective_adjoint_config.
    let mut bm = noise_fleet(problems, d);
    let out = batch_adjoint_sum_core(
        p0.sde,
        &p0.theta,
        &z0,
        p0.t0,
        p0.t1,
        n_steps,
        &mut bm,
        cfg.forward_method,
        tier,
    );

    bm.into_sources()
        .into_iter()
        .enumerate()
        .map(|(b, src)| Gradients {
            dz0: out.grad_z0[b * d..(b + 1) * d].to_vec(),
            dtheta: out.grad_theta[b * p..(b + 1) * p].to_vec(),
            z_terminal: out.z_terminal[b * d..(b + 1) * d].to_vec(),
            z0_reconstructed: out.z0_reconstructed[b * d..(b + 1) * d].to_vec(),
            w_terminal: out.w_terminal[b * d..(b + 1) * d].to_vec(),
            stats: GradStats {
                forward: out.forward_stats,
                backward: out.backward_stats,
                noise_memory: src.memory_footprint(),
                peak_tape_bytes: 0,
                recompute_nfe: 0,
                hit_h_min: false,
            },
        })
        .collect()
}

/// One chunk through the batched checkpointed backprop. Stats are in
/// per-path units so each returned [`Gradients`] — including memory and
/// recompute accounting — equals the scalar engine's output exactly.
fn backprop_chunk<S: BatchSdeVjp + ?Sized>(
    problems: &[SdeProblem<'_, S>],
    method: Method,
    checkpointing: Checkpointing,
    n_steps: usize,
) -> Vec<Gradients> {
    let p0 = &problems[0];
    let d = p0.dim();
    let p = p0.sde.param_dim();
    let bsz = problems.len();

    let mut z0 = lease(bsz * d);
    for (row, pr) in z0.chunks_exact_mut(d).zip(problems) {
        row.copy_from_slice(&pr.z0);
    }
    let mut bm = noise_fleet(problems, d);
    let out = batch_checkpoint_backprop_core(
        p0.sde,
        &p0.theta,
        &z0,
        p0.t0,
        p0.t1,
        n_steps,
        &mut bm,
        method,
        checkpointing,
    );

    bm.into_sources()
        .into_iter()
        .enumerate()
        .map(|(b, src)| Gradients {
            dz0: out.grad_z0[b * d..(b + 1) * d].to_vec(),
            dtheta: out.grad_theta[b * p..(b + 1) * p].to_vec(),
            z_terminal: out.z_terminal[b * d..(b + 1) * d].to_vec(),
            // The first checkpoint holds z0 exactly (as in the scalar
            // driver).
            z0_reconstructed: z0[b * d..(b + 1) * d].to_vec(),
            w_terminal: out.w_terminal[b * d..(b + 1) * d].to_vec(),
            stats: GradStats {
                forward: out.forward_stats,
                backward: out.backward_stats,
                noise_memory: out.peak_tape_f64s + src.memory_footprint(),
                peak_tape_bytes: out.peak_tape_f64s * 8,
                recompute_nfe: out.recompute_nfe,
                hit_h_min: false,
            },
        })
        .collect()
}
