//! The unified problem–solver–solution API:
//! [`SdeProblem`] → [`SdeProblem::solve`] → [`SdeSolution`], with
//! pluggable gradient backends via [`SdeProblem::sensitivity`] /
//! [`SensAlg`].
//!
//! The paper's contribution is a *family* of interchangeable gradient
//! estimators over a family of solvers and Brownian sources; this module
//! is the one surface where those choices compose. A problem pins down
//! *what* is being solved (SDE, initial state, horizon, parameters, noise
//! spec, PRNG key); options pin down *how* (scheme, step control, what to
//! save); the sensitivity algorithm is a value, not a different function
//! family — so switching from backprop-through-the-solver to the
//! stochastic adjoint with a virtual Brownian tree is a one-line change:
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! let sde = ReplicatedSde::new(Example1, 10);
//! let theta = vec![0.5; 20];
//! let z0 = vec![1.0; 10];
//!
//! let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
//!     .params(&theta)
//!     .key(PrngKey::from_seed(7))
//!     .noise(NoiseSpec::VirtualTree { tol: 1e-8 });
//!
//! // Forward solve, saving every step; evaluate anywhere by
//! // interpolation and replay the realized Brownian path.
//! let mut sol = prob.solve(
//!     &SolveOptions::fixed(Method::MilsteinIto, 1000).save(SaveAt::Dense),
//! );
//! let z_mid = sol.at(0.5);
//! let w_end = sol.w(1.0);
//!
//! // Gradients of L = Σ z_T via the paper's stochastic adjoint — or any
//! // other estimator, at the same Brownian path.
//! let g = prob
//!     .sensitivity_sum(
//!         &SensAlg::StochasticAdjoint(AdjointConfig::default()),
//!         StepControl::Steps(1000),
//!     )
//!     .unwrap();
//! assert_eq!(g.dtheta.len(), theta.len());
//! # let _ = (z_mid, w_end);
//! ```
//!
//! Batching rides on the same type: [`solve_batch`] /
//! [`sensitivity_batch`] fan a slice of problems (typically
//! [`SdeProblem::replicates`] of one problem with independent keys
//! derived from a root [`crate::prng::PrngKey`]) across a scoped thread
//! pool, with results identical to sequential execution regardless of
//! thread count.
//!
//! The legacy free functions (`integrate_grid`,
//! `stochastic_adjoint_gradients`, …) remain as `#[deprecated]` one-line
//! shims over the same engines, so results are bit-identical across the
//! two surfaces (pinned by `tests/api_equivalence.rs`).

pub mod problem;
pub mod sensitivity;
pub mod solve;

pub use problem::{NoiseSpec, ProblemError, SdeProblem};
pub use sensitivity::{sensitivity_batch, GradStats, Gradients, SensAlg};
pub use solve::{solve_batch, NoiseHandle, SaveAt, SdeSolution, SolveOptions, StepControl};
