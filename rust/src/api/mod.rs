//! The unified problem–solver–solution API:
//! [`SdeProblem`] → [`SdeProblem::solve`] → [`SdeSolution`], with
//! pluggable gradient backends via [`SdeProblem::sensitivity`] /
//! [`SensAlg`].
//!
//! The paper's contribution is a *family* of interchangeable gradient
//! estimators over a family of solvers and Brownian sources; this module
//! is the one surface where those choices compose. A problem pins down
//! *what* is being solved (SDE, initial state, horizon, parameters, noise
//! spec, PRNG key); options pin down *how* (scheme, step control, what to
//! save); the sensitivity algorithm is a value, not a different function
//! family — so switching from backprop-through-the-solver to the
//! stochastic adjoint with a virtual Brownian tree is a one-line change:
//!
//! ```no_run
//! use sdegrad::prelude::*;
//! use sdegrad::sde::problems::Example1;
//! use sdegrad::sde::ReplicatedSde;
//!
//! let sde = ReplicatedSde::new(Example1, 10);
//! let theta = vec![0.5; 20];
//! let z0 = vec![1.0; 10];
//!
//! let prob = SdeProblem::new(&sde, &z0, (0.0, 1.0))
//!     .params(&theta)
//!     .key(PrngKey::from_seed(7))
//!     .noise(NoiseSpec::VirtualTree { tol: 1e-8 });
//!
//! // Forward solve, saving every step; evaluate anywhere by
//! // interpolation and replay the realized Brownian path.
//! let mut sol = prob.solve(
//!     &SolveOptions::fixed(Method::MilsteinIto, 1000).save(SaveAt::Dense),
//! );
//! let z_mid = sol.at(0.5);
//! let w_end = sol.w(1.0);
//!
//! // Gradients of L = Σ z_T via the paper's stochastic adjoint — or any
//! // other estimator, at the same Brownian path.
//! let g = prob
//!     .sensitivity_sum(
//!         &SensAlg::StochasticAdjoint(AdjointConfig::default()),
//!         StepControl::Steps(1000),
//!     )
//!     .unwrap();
//! assert_eq!(g.dtheta.len(), theta.len());
//! # let _ = (z_mid, w_end);
//! ```
//!
//! Batching rides on the same type — and runs on a **batched SoA
//! execution engine**: [`solve_batch`] / [`sensitivity_batch`] take a
//! slice of problems (typically [`SdeProblem::replicates`] of one
//! problem with independent keys derived from a root
//! [`crate::prng::PrngKey`]), split it into chunks across a scoped
//! thread pool, and advance each chunk's paths *together* through the
//! batched solver/adjoint kernels. Results are bit-identical to
//! sequential per-problem execution regardless of thread count (see
//! [`batch`] for the batchability rules and fallbacks).
//!
//! ## Batch buffer layout convention
//!
//! Every batched buffer in this crate is **row-major `[B×d]`**: path
//! `b`'s state occupies `buf[b*d .. (b+1)*d]`, so a batch is B scalar
//! state vectors laid end to end ("structure of arrays" at the fleet
//! level — each quantity (states, adjoints, parameter-gradients) is its
//! own contiguous matrix, rather than per-path structs). Parameter-side
//! batches are `[B×p]` in the same convention, trajectories
//! `(times, B, d)` with the path index in the middle. The batched
//! augmented adjoint state is a single `[B×(2d+p+1)]` allocation
//! partitioned into `(z | a_z | a_θ | L)` blocks — see
//! [`crate::adjoint::batch`].
//!
//! (The pre-0.2 deprecated free-function shims were removed in 0.3; the
//! migration table lives in CHANGES.md.)

pub mod batch;
pub mod problem;
pub mod sensitivity;
pub mod solve;

pub use batch::{
    sensitivity_batch, sensitivity_batch_per_path, solve_batch, solve_batch_local,
    solve_batch_per_path,
};
#[allow(deprecated)]
pub use batch::sensitivity_batch_tier;
pub use crate::adjoint::Checkpointing;
pub use crate::sde::KernelTier;
pub use problem::{NoiseSpec, ProblemError, SdeProblem};
pub use sensitivity::{GradStats, Gradients, SensAlg};
pub use solve::{NoiseHandle, SaveAt, SdeSolution, SolveOptions, StepControl};
