//! SGD with momentum — baseline optimizer for ablations.

/// SGD with classical momentum over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f64,
    pub momentum: f64,
    velocity: Vec<f64>,
}

impl Sgd {
    pub fn new(n_params: usize, lr: f64, momentum: f64) -> Self {
        Sgd { lr, momentum, velocity: vec![0.0; n_params] }
    }

    pub fn step(&mut self, params: &mut [f64], grad: &[f64], lr_scale: f64) {
        let lr = self.lr * lr_scale;
        for i in 0..params.len() {
            self.velocity[i] = self.momentum * self.velocity[i] - lr * grad[i];
            params[i] += self.velocity[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut opt = Sgd::new(2, 0.1, 0.0);
        let mut p = vec![1.0, 1.0];
        opt.step(&mut p, &[1.0, -2.0], 1.0);
        assert_eq!(p, vec![0.9, 1.2]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut opt = Sgd::new(1, 0.1, 0.9);
        let mut p = vec![0.0];
        opt.step(&mut p, &[1.0], 1.0); // v = -0.1
        opt.step(&mut p, &[1.0], 1.0); // v = -0.19
        assert!((p[0] - (-0.29)).abs() < 1e-12);
    }
}
