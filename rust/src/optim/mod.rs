//! Optimizers and schedules for latent-SDE training (§7.3: Adam with
//! exponentially decayed learning rate and linear KL annealing).

pub mod adam;
pub mod schedule;
pub mod sgd;

pub use adam::Adam;
pub use schedule::{ExponentialDecay, KlAnneal};
pub use sgd::Sgd;

/// Clip a gradient vector to a maximum global L2 norm; returns the norm
/// before clipping.
pub fn clip_grad_norm(grad: &mut [f64], max_norm: f64) -> f64 {
    let norm = grad.iter().map(|g| g * g).sum::<f64>().sqrt();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grad.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_leaves_small_gradients_alone() {
        let mut g = vec![0.3, -0.4];
        let norm = clip_grad_norm(&mut g, 10.0);
        assert!((norm - 0.5).abs() < 1e-12);
        assert_eq!(g, vec![0.3, -0.4]);
    }

    #[test]
    fn clip_rescales_large_gradients() {
        let mut g = vec![3.0, 4.0];
        clip_grad_norm(&mut g, 1.0);
        let new_norm = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((new_norm - 1.0).abs() < 1e-12);
        assert!((g[0] / g[1] - 0.75).abs() < 1e-12, "direction preserved");
    }
}
