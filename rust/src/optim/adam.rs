//! Adam (Kingma & Ba 2014) — the optimizer used for every experiment in
//! §7 with its default hyperparameters.

/// Adam state over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    /// Default hyperparameters (β₁=0.9, β₂=0.999, ε=1e-8) at the given
    /// learning rate.
    pub fn new(n_params: usize, lr: f64) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// Apply one update in place. `lr_scale` multiplies the base learning
    /// rate (used by LR-decay schedules).
    pub fn step(&mut self, params: &mut [f64], grad: &[f64], lr_scale: f64) {
        assert_eq!(params.len(), self.m.len(), "Adam: parameter count changed");
        assert_eq!(grad.len(), self.m.len(), "Adam: gradient length mismatch");
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let lr = self.lr * lr_scale;
        for i in 0..params.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let m_hat = self.m[i] / b1t;
            let v_hat = self.v[i] / b2t;
            params[i] -= lr * m_hat / (v_hat.sqrt() + self.eps);
        }
    }

    /// Steps taken.
    pub fn iterations(&self) -> u64 {
        self.t
    }

    /// The optimizer state `(m, v, t)` — what a checkpoint must carry so
    /// a resumed run takes bit-identical steps.
    pub fn state(&self) -> (&[f64], &[f64], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Rebuild an optimizer mid-run from checkpointed state (default
    /// β₁/β₂/ε, like [`Adam::new`]). The next [`Adam::step`] continues
    /// exactly where the saved run left off.
    pub fn from_state(lr: f64, m: Vec<f64>, v: Vec<f64>, t: u64) -> Self {
        assert_eq!(m.len(), v.len(), "Adam::from_state: moment length mismatch");
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, m, v, t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_moves_by_lr() {
        // With zero state, m̂/√v̂ = g/|g| so the first update is ±lr.
        let mut adam = Adam::new(2, 0.1);
        let mut p = vec![1.0, -2.0];
        adam.step(&mut p, &[0.5, -3.0], 1.0);
        assert!((p[0] - (1.0 - 0.1)).abs() < 1e-6, "p0 {}", p[0]);
        assert!((p[1] - (-2.0 + 0.1)).abs() < 1e-6, "p1 {}", p[1]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize ½‖p − c‖².
        let c = [3.0, -1.0, 0.5];
        let mut adam = Adam::new(3, 0.05);
        let mut p = vec![0.0; 3];
        for _ in 0..2000 {
            let g: Vec<f64> = p.iter().zip(&c).map(|(pi, ci)| pi - ci).collect();
            adam.step(&mut p, &g, 1.0);
        }
        for i in 0..3 {
            assert!((p[i] - c[i]).abs() < 1e-3, "p[{i}]={} c[{i}]={}", p[i], c[i]);
        }
    }

    /// Checkpointed state must make a resumed optimizer take bit-identical
    /// steps to the uninterrupted run.
    #[test]
    fn resume_from_state_is_bit_identical() {
        let g = |i: u64| vec![(i as f64 * 0.3).sin(), -(i as f64 * 0.7).cos()];
        let mut full = Adam::new(2, 0.05);
        let mut p_full = vec![1.0, -1.0];
        for i in 0..10 {
            full.step(&mut p_full, &g(i), 1.0);
        }

        let mut head = Adam::new(2, 0.05);
        let mut p = vec![1.0, -1.0];
        for i in 0..5 {
            head.step(&mut p, &g(i), 1.0);
        }
        let (m, v, t) = head.state();
        let mut tail = Adam::from_state(0.05, m.to_vec(), v.to_vec(), t);
        for i in 5..10 {
            tail.step(&mut p, &g(i), 1.0);
        }
        assert_eq!(p, p_full, "resumed Adam diverged from uninterrupted run");
        assert_eq!(tail.iterations(), 10);
    }

    #[test]
    fn lr_scale_scales_step() {
        let mut a1 = Adam::new(1, 0.1);
        let mut a2 = Adam::new(1, 0.1);
        let mut p1 = vec![0.0];
        let mut p2 = vec![0.0];
        a1.step(&mut p1, &[1.0], 1.0);
        a2.step(&mut p2, &[1.0], 0.5);
        assert!((p1[0] - 2.0 * p2[0]).abs() < 1e-12);
    }
}
