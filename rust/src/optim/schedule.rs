//! Learning-rate and KL-weight schedules (§7.3: initial LR 0.01 decayed by
//! 0.999 per iteration; linear KL annealing over the first N iterations).

/// `scale(t) = rate^t` multiplicative learning-rate decay.
#[derive(Clone, Copy, Debug)]
pub struct ExponentialDecay {
    pub rate: f64,
}

impl ExponentialDecay {
    pub fn new(rate: f64) -> Self {
        ExponentialDecay { rate }
    }

    pub fn scale(&self, iteration: u64) -> f64 {
        self.rate.powi(iteration as i32)
    }
}

/// Linear KL annealing: weight ramps 0 → `target` over `warmup` iterations,
/// then stays at `target` (the paper's β in the validation sweep).
#[derive(Clone, Copy, Debug)]
pub struct KlAnneal {
    pub target: f64,
    pub warmup: u64,
}

impl KlAnneal {
    pub fn new(target: f64, warmup: u64) -> Self {
        KlAnneal { target, warmup }
    }

    /// Constant weight (no annealing).
    pub fn constant(target: f64) -> Self {
        KlAnneal { target, warmup: 0 }
    }

    pub fn weight(&self, iteration: u64) -> f64 {
        if self.warmup == 0 || iteration >= self.warmup {
            self.target
        } else {
            self.target * iteration as f64 / self.warmup as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_curve() {
        let d = ExponentialDecay::new(0.999);
        assert_eq!(d.scale(0), 1.0);
        assert!((d.scale(100) - 0.999f64.powi(100)).abs() < 1e-15);
        assert!(d.scale(1000) < d.scale(10));
    }

    #[test]
    fn anneal_ramps_then_holds() {
        let a = KlAnneal::new(0.5, 100);
        assert_eq!(a.weight(0), 0.0);
        assert!((a.weight(50) - 0.25).abs() < 1e-12);
        assert_eq!(a.weight(100), 0.5);
        assert_eq!(a.weight(10_000), 0.5);
        assert_eq!(KlAnneal::constant(0.1).weight(0), 0.1);
    }
}
