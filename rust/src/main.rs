//! `sdegrad` — CLI for the scalable-SDE-gradients framework.
//!
//! Subcommands:
//! * `train`            train a latent SDE on a built-in dataset
//! * `serve`            serve a checkpoint over HTTP (micro-batched inference)
//! * `repro <id>`       regenerate a paper table/figure (`--quick` trims)
//! * `bench <id>`       performance harnesses (`throughput`/`serve` → BENCH_*.json)
//! * `artifacts-check`  compile + smoke-run every AOT artifact
//! * `list`             show datasets / experiments / artifacts
//!
//! Argument syntax is `--key value` (see `coordinator::config`).

use sdegrad::coordinator::config::{arg, parse_args, TrainConfig};
use sdegrad::coordinator::repro;
use sdegrad::coordinator::{load_state, save_params, save_state, train_latent_sde_from};
use sdegrad::data::{gbm, lorenz, mocap};
use sdegrad::latent::LatentSdeModel;
use sdegrad::prng::PrngKey;
use sdegrad::serve::registry::{apply_mode, dataset_model_config};
use sdegrad::serve::{ModelRegistry, ServeConfig, Server};

fn usage() -> ! {
    eprintln!(
        "sdegrad {} — scalable gradients for stochastic differential equations

All subcommands accept a global --threads N (worker count for the
persistent pool; overrides the SDEGRAD_THREADS env var) and a global
--trace-out trace.json (enable span collection and write a Chrome
trace-event file on normal exit — open in chrome://tracing or Perfetto;
`serve` runs until killed, so use it with train/bench/repro).

USAGE:
    sdegrad train --dataset <gbm|lorenz|mocap> [--mode sde|ode] [--iters N]
                  [--batch N] [--samples N] [--lr F] [--kl F] [--substeps N]
                  [--seed N] [--workers N] [--tier exact|fast]
                  [--out checkpoint.bin] [--state state.bin]
                  [--resume state.bin] [--log train.csv] [--smoke-check]
    sdegrad serve --state <ckpt.bin> [--dataset gbm|lorenz|mocap] [--mode sde|ode]
                  [--name default] [--port 7878] [--workers N] [--shards N]
                  [--max-batch 16] [--max-wait-us 500] [--cache 1024]
                  [--queue-cells N] [--stream-threshold BYTES]
                  [--max-body 1048576] [--bind 127.0.0.1] [--tier exact|fast]
                  (loopback-only by default; --bind 0.0.0.0 to expose)
    sdegrad repro <table1|fig2|fig5|fig6|fig9|table2|convergence|all> [--quick]
    sdegrad bench throughput [--quick]     (exact + fast kernel-tier rows)
    sdegrad bench serve [--quick] [--tier exact|fast]
    sdegrad bench baseline [--quick] [--out BENCH_baseline.json]
                  (re-measure and rewrite the regression baseline)
    sdegrad bench compare [--baseline BENCH_baseline.json]
                  [--current BENCH_throughput.json] [--threshold 0.25]
                  [--summary summary.md] [--subset throughput|serve]
    sdegrad artifacts-check [--dir artifacts]
    sdegrad list",
        sdegrad::version()
    );
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else { usage() };
    let rest = &args[1..];
    // Global --threads: sets the process-wide worker count before any
    // subcommand touches the pool (SDEGRAD_THREADS env is the fallback;
    // see runtime::worker_count). Global --trace-out: turn span
    // collection on for the whole run and export the Chrome trace once
    // the subcommand returns.
    let trace_out = {
        let map = parse_args(rest);
        let threads: usize = arg(&map, "threads", 0);
        if threads > 0 {
            sdegrad::runtime::set_worker_count(threads);
        }
        let trace_out = map.get("trace-out").cloned();
        if trace_out.is_some() {
            sdegrad::obs::set_enabled(true);
        }
        trace_out
    };
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "serve" => cmd_serve(rest),
        "repro" => cmd_repro(rest),
        "bench" => cmd_bench(rest),
        "artifacts-check" => cmd_artifacts_check(rest),
        "list" => cmd_list(),
        "--version" | "-V" => println!("sdegrad {}", sdegrad::version()),
        _ => usage(),
    }
    if let Some(path) = trace_out {
        match sdegrad::obs::export::write_chrome_trace(std::path::Path::new(&path)) {
            Ok(()) => eprintln!("wrote Chrome trace to {path} (chrome://tracing / Perfetto)"),
            Err(e) => {
                eprintln!("failed to write trace to {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

fn cmd_train(rest: &[String]) {
    let map = parse_args(rest);
    let dataset_name = map.get("dataset").cloned().unwrap_or_else(|| "gbm".into());
    let mode = map.get("mode").cloned().unwrap_or_else(|| "sde".into());
    let cfg = TrainConfig::from_args(&map);

    // Architecture per dataset: one source of truth shared with
    // `sdegrad serve` (a checkpoint trained here is served with the same
    // --dataset/--mode flags).
    let Some(base_cfg) = dataset_model_config(&dataset_name) else {
        eprintln!("unknown dataset {dataset_name}");
        usage()
    };
    let ds = match dataset_name.as_str() {
        "gbm" => {
            let n: usize = arg(&map, "series", 256);
            gbm::generate(
                PrngKey::from_seed(cfg.seed),
                &gbm::GbmConfig { n_series: n, ..Default::default() },
            )
        }
        "lorenz" => {
            let n: usize = arg(&map, "series", 256);
            lorenz::generate(
                PrngKey::from_seed(cfg.seed),
                &lorenz::LorenzConfig { n_series: n, ..Default::default() },
            )
        }
        "mocap" => mocap::generate(PrngKey::from_seed(cfg.seed), &mocap::MocapConfig::default()),
        other => {
            // dataset_model_config accepted a dataset this match cannot
            // generate: the two lists drifted apart.
            eprintln!("dataset {other} has a model config but no generator in cmd_train");
            std::process::exit(2);
        }
    };
    let model_cfg = match apply_mode(base_cfg, &mode) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            usage()
        }
    };

    let model = LatentSdeModel::new(model_cfg);
    println!(
        "training latent {} on {dataset_name}: {} series × {} obs × {}d, {} params, {} iters, \
         {} samples/seq, {} workers (batched engine)",
        mode.to_uppercase(),
        ds.n_series,
        ds.n_times(),
        ds.dim,
        model.n_params,
        cfg.iters,
        cfg.elbo_samples,
        cfg.n_workers()
    );
    let idx: Vec<usize> = (0..ds.n_series).collect();
    let n_val = (ds.n_series / 8).clamp(1, ds.n_series - 1);
    let (train_idx, val_idx) = idx.split_at(ds.n_series - n_val);
    let log = map.get("log").cloned();
    let resume = map.get("resume").map(|p| {
        let st = load_state(p).expect("loading resume state");
        println!("resuming from {p} at iteration {}", st.iter);
        st
    });
    let log = log.as_deref();
    let report =
        train_latent_sde_from(&model, &ds, train_idx, val_idx, &cfg, log, resume.as_ref());

    for r in report.history.iter().step_by((cfg.iters as usize / 20).max(1)) {
        println!(
            "iter {:>5}  loss {:>12.3}  logp {:>12.3}  kl_path {:>8.3}  kl_z0 {:>7.3}  ({:.2}s)",
            r.iter, r.loss, r.log_px, r.kl_path, r.kl_z0, r.seconds
        );
    }
    for (it, v) in &report.val_history {
        println!("  val @ {it}: loss {:.3}, recon MSE {:.5}", v.loss, v.recon_mse);
    }
    println!("total: {:.1}s", report.total_seconds);
    if let Some(out) = map.get("out") {
        save_params(out, &report.final_params).expect("saving checkpoint");
        println!("saved checkpoint to {out}");
    }
    if let Some(out) = map.get("state") {
        save_state(out, &report.final_state).expect("saving training state");
        println!("saved training state (params + Adam moments) to {out}");
    }
    if map.contains_key("smoke-check") {
        // CI training-smoke gate: the loss must end below where it began.
        let k = (report.history.len() / 4).clamp(1, 5);
        let first: f64 =
            report.history[..k].iter().map(|r| r.loss).sum::<f64>() / k as f64;
        let last: f64 = report.history[report.history.len() - k..]
            .iter()
            .map(|r| r.loss)
            .sum::<f64>()
            / k as f64;
        if last < first {
            println!("SMOKE OK: mean loss first {k} iters {first:.3} -> last {k} iters {last:.3}");
        } else {
            eprintln!(
                "SMOKE FAILED: loss did not improve (first {k} iters {first:.3}, last {k} \
                 iters {last:.3})"
            );
            std::process::exit(1);
        }
    }
}

/// `sdegrad serve`: load checkpoint(s) into a model registry and serve
/// until killed. A corrupt/truncated checkpoint or an
/// architecture/parameter-count mismatch is a clean startup error
/// (exit 1), not a panic.
fn cmd_serve(rest: &[String]) {
    let map = parse_args(rest);
    let Some(state_path) = map.get("state") else {
        eprintln!("serve: --state <checkpoint> is required");
        usage()
    };
    let dataset = map.get("dataset").cloned().unwrap_or_else(|| "gbm".into());
    let mode = map.get("mode").cloned().unwrap_or_else(|| "sde".into());
    let name = map.get("name").cloned().unwrap_or_else(|| "default".into());

    let Some(base_cfg) = dataset_model_config(&dataset) else {
        eprintln!("serve: unknown dataset {dataset}");
        usage()
    };
    let model_cfg = match apply_mode(base_cfg, &mode) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("serve: {e}");
            usage()
        }
    };
    let mut registry = ModelRegistry::new();
    if let Err(e) = registry.load_checkpoint(&name, model_cfg, state_path) {
        eprintln!("serve: cannot load {state_path}: {e}");
        std::process::exit(1);
    }

    let defaults = ServeConfig::default();
    let tier = map
        .get("tier")
        .and_then(|v| sdegrad::sde::KernelTier::parse(v))
        .unwrap_or(defaults.exec.tier);
    let cfg = ServeConfig {
        host: arg(&map, "bind", defaults.host),
        port: arg(&map, "port", defaults.port),
        workers: arg(&map, "workers", defaults.workers),
        max_batch: arg(&map, "max-batch", defaults.max_batch),
        max_wait_us: arg(&map, "max-wait-us", defaults.max_wait_us),
        shards: arg(&map, "shards", defaults.shards),
        queue_cells: arg(&map, "queue-cells", defaults.queue_cells),
        stream_threshold_bytes: arg(&map, "stream-threshold", defaults.stream_threshold_bytes),
        cache_capacity: arg(&map, "cache", defaults.cache_capacity),
        max_body_bytes: arg(&map, "max-body", defaults.max_body_bytes),
        exec: defaults.exec.tier(tier),
    };
    let server = match Server::start(registry, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("serve: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "sdegrad serve: listening on http://{} (model {name:?} from {state_path}; \
         {} workers, {} shards, max-batch {}, max-wait {} µs, cache {}, {} kernels)",
        server.addr(),
        cfg.workers,
        cfg.shards,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.cache_capacity,
        cfg.exec.tier.name()
    );
    println!("endpoints: GET /healthz /metrics, POST /v1/simulate /v1/reconstruct /v1/elbo");
    server.run();
}

fn cmd_repro(rest: &[String]) {
    let map = parse_args(rest);
    let quick = map.contains_key("quick");
    let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "table1" => {
            repro::table1::run(quick);
        }
        "fig2" => {
            repro::fig2::run(quick);
        }
        "fig5" | "fig7" => repro::fig5::run(quick),
        "fig6" | "fig8" => {
            repro::latent_figs::run_lorenz(quick);
        }
        "fig9" => {
            repro::latent_figs::run_gbm(quick);
        }
        "table2" => {
            repro::table2::run(quick);
        }
        "convergence" => {
            repro::convergence::run(quick);
        }
        "all" => {
            repro::table1::run(quick);
            repro::fig2::run(quick);
            repro::fig5::run(quick);
            repro::latent_figs::run_lorenz(quick);
            repro::latent_figs::run_gbm(quick);
            repro::table2::run(quick);
            repro::convergence::run(quick);
        }
        other => {
            eprintln!("unknown experiment {other}");
            usage()
        }
    }
}

fn cmd_bench(rest: &[String]) {
    let map = parse_args(rest);
    let quick = map.contains_key("quick");
    let which = rest.first().map(|s| s.as_str()).unwrap_or("throughput");
    match which {
        "throughput" => {
            sdegrad::coordinator::bench::run_throughput(quick);
        }
        "serve" => {
            let tier = map
                .get("tier")
                .and_then(|v| sdegrad::sde::KernelTier::parse(v))
                .unwrap_or(sdegrad::sde::KernelTier::Exact);
            let exec = sdegrad::runtime::ExecConfig::new().tier(tier);
            sdegrad::coordinator::bench::run_serve_bench(quick, exec);
        }
        "baseline" => {
            let out =
                map.get("out").cloned().unwrap_or_else(|| "BENCH_baseline.json".into());
            sdegrad::coordinator::bench::run_baseline(quick, &out);
        }
        "compare" => {
            let baseline =
                map.get("baseline").cloned().unwrap_or_else(|| "BENCH_baseline.json".into());
            let current =
                map.get("current").cloned().unwrap_or_else(|| "BENCH_throughput.json".into());
            let threshold: f64 = arg(&map, "threshold", 0.25);
            // --summary overrides; otherwise append to the GitHub job
            // summary when running in Actions.
            let summary = map
                .get("summary")
                .cloned()
                .or_else(|| std::env::var("GITHUB_STEP_SUMMARY").ok());
            let subset = map.get("subset").cloned();
            let code = sdegrad::coordinator::bench::run_compare(
                &baseline,
                &current,
                threshold,
                summary.as_deref(),
                subset.as_deref(),
            );
            std::process::exit(code);
        }
        other => {
            eprintln!("unknown bench {other}");
            usage()
        }
    }
}

fn cmd_artifacts_check(rest: &[String]) {
    let map = parse_args(rest);
    let dir = map.get("dir").cloned().unwrap_or_else(|| "artifacts".into());
    let mut reg = match sdegrad::runtime::ArtifactRegistry::open(&dir) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("failed to open artifacts at {dir}: {e:#}");
            std::process::exit(1);
        }
    };
    println!("artifacts at {dir}:");
    let mut cfg_pairs: Vec<(String, String)> =
        reg.manifest.cfg.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
    cfg_pairs.sort();
    for (k, v) in cfg_pairs {
        println!("  cfg {k} = {v}");
    }
    for name in reg.entry_names() {
        let entry_shapes = match reg.get(&name) {
            Ok(e) => e.entry.input_shapes.clone(),
            Err(e) => {
                eprintln!("  {name}: COMPILE FAILED: {e:#}");
                std::process::exit(1);
            }
        };
        // Smoke-run with constant inputs.
        let bufs: Vec<Vec<f32>> = entry_shapes
            .iter()
            .map(|s| vec![0.1f32; s.iter().product::<usize>().max(1)])
            .collect();
        let refs: Vec<&[f32]> = bufs.iter().map(|b| b.as_slice()).collect();
        let exe = reg.get(&name).unwrap();
        match exe.call_f32(&refs) {
            Ok(outs) => {
                let sizes: Vec<usize> = outs.iter().map(|o| o.len()).collect();
                println!("  {name}: OK (outputs {sizes:?})");
            }
            Err(e) => {
                eprintln!("  {name}: EXECUTE FAILED: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn cmd_list() {
    println!("datasets:     gbm, lorenz, mocap (synthetic; see DESIGN.md §3)");
    println!(
        "experiments:  table1, fig2, fig5 (incl. fig7), fig6 (incl. fig8), fig9, table2, \
         convergence"
    );
    println!(
        "benches:      throughput (BENCH_throughput.json, exact+fast tiers), serve \
         (BENCH_serve.json), baseline (rewrites BENCH_baseline.json), compare \
         (regression gate, --subset per harness)"
    );
    println!("serving:      sdegrad serve --state ckpt.bin (healthz/simulate/reconstruct/elbo)");
    println!("artifacts:    see `sdegrad artifacts-check`");
}
