//! Counter-based, splittable pseudorandom number generation.
//!
//! The virtual Brownian tree (paper §4.2) requires a *splittable* PRNG: an
//! operation `split` that deterministically derives two child keys from a
//! parent key, such that streams drawn from distinct keys are independent.
//! Following the paper's implementation notes we use a counter-based
//! generator (Salmon et al., "Parallel random numbers: as easy as 1, 2, 3",
//! SC'11): **Threefry-2x64**. Counter-based PRNGs have no sequential state —
//! the k-th sample is a pure function `random(key, k)` — which makes keys
//! cheap to pass around (two u64s) and splitting a single block-cipher call.
//!
//! This is the same construction JAX uses for `jax.random.split`.

pub mod threefry;
pub mod key;
pub mod normal;

pub use key::PrngKey;
pub use normal::NormalSampler;
pub use threefry::threefry2x64;
