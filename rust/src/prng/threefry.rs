//! Threefry-2x64 block cipher (20 rounds), the core bijection behind the
//! splittable PRNG.
//!
//! Reference: Salmon, Moraes, Dror, Shaw. "Parallel random numbers: as easy
//! as 1, 2, 3." SC'11. Constants match the Random123 reference
//! implementation (and therefore JAX's `threefry2x64`).

/// Rotation constants for Threefry-2x64 (from the Skein/Random123 spec).
const ROTATIONS: [u32; 8] = [16, 42, 12, 31, 16, 32, 24, 21];

/// Key-schedule parity constant for Threefry (Skein's C240).
const PARITY: u64 = 0x1BD1_1BDA_A9FC_1A22;

/// Number of rounds. 20 is the recommended "crush-resistant" setting used by
/// Random123 and JAX.
const ROUNDS: usize = 20;

#[inline(always)]
fn rotl(x: u64, r: u32) -> u64 {
    x.rotate_left(r)
}

/// Apply the Threefry-2x64 bijection to `counter` under `key`.
///
/// Deterministic: the same `(key, counter)` always produces the same output
/// block. Distinct counters under the same key (or the same counter under
/// distinct keys) yield statistically independent 128-bit blocks.
#[inline]
pub fn threefry2x64(key: [u64; 2], counter: [u64; 2]) -> [u64; 2] {
    let ks = [key[0], key[1], key[0] ^ key[1] ^ PARITY];
    let mut x0 = counter[0].wrapping_add(ks[0]);
    let mut x1 = counter[1].wrapping_add(ks[1]);

    // 20 rounds = 5 groups of 4 rounds, with a key injection after each group.
    for group in 0..(ROUNDS / 4) {
        for r in 0..4 {
            x0 = x0.wrapping_add(x1);
            x1 = rotl(x1, ROTATIONS[(group % 2) * 4 + r]);
            x1 ^= x0;
        }
        let inject = group + 1;
        x0 = x0.wrapping_add(ks[inject % 3]);
        x1 = x1.wrapping_add(ks[(inject + 1) % 3]).wrapping_add(inject as u64);
    }
    [x0, x1]
}

/// Convert a u64 to a double uniformly distributed in the half-open interval
/// `(0, 1]` using the top 53 bits. The open lower endpoint means the value is
/// safe to pass to `ln()` (Box–Muller).
#[inline]
pub fn u64_to_open_unit(x: u64) -> f64 {
    // Take the top 53 bits, map {0..2^53-1} -> (0,1] via (v+1)/2^53.
    let v = x >> 11;
    (v as f64 + 1.0) * (1.0 / 9007199254740992.0) // 2^53
}

/// Convert a u64 to a double in `[0, 1)`.
#[inline]
pub fn u64_to_unit(x: u64) -> f64 {
    let v = x >> 11;
    v as f64 * (1.0 / 9007199254740992.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_zero_key_zero_counter() {
        // Deterministic regression anchor: the bijection must never change,
        // or every stored experiment seed silently produces different noise.
        let out = threefry2x64([0, 0], [0, 0]);
        let again = threefry2x64([0, 0], [0, 0]);
        assert_eq!(out, again);
        assert_ne!(out, [0, 0], "bijection should scramble the zero block");
    }

    #[test]
    fn random123_reference_vector() {
        // Known-answer test from the Random123 distribution (threefry2x64,
        // 20 rounds, zero key and counter).
        let out = threefry2x64([0, 0], [0, 0]);
        assert_eq!(out, [0xc2b6e3a8c2c69865, 0x6f81ed42f350084d]);
    }

    #[test]
    fn regression_anchors() {
        // Frozen outputs of this implementation: the bijection must never
        // change across refactors, or stored experiment seeds silently
        // reproduce different noise.
        let out = threefry2x64(
            [0xffffffffffffffff, 0xffffffffffffffff],
            [0xffffffffffffffff, 0xffffffffffffffff],
        );
        assert_eq!(out, [0xe02cb7c4d95d277a, 0xd06633d0893b8b68]);
        let out = threefry2x64(
            [0x452821e638d01377, 0xbe5466cf34e90c6c],
            [0x243f6a8885a308d3, 0x13198a2e03707344],
        );
        assert_eq!(out, [0x830584bde36c471c, 0x1783b99553629002]);
    }

    #[test]
    fn counter_sensitivity() {
        // Flipping one counter bit must change the whole block (avalanche).
        let a = threefry2x64([1, 2], [0, 0]);
        let b = threefry2x64([1, 2], [1, 0]);
        assert_ne!(a[0], b[0]);
        assert_ne!(a[1], b[1]);
        let diff = (a[0] ^ b[0]).count_ones() + (a[1] ^ b[1]).count_ones();
        assert!(diff > 32, "expected avalanche, got {diff} differing bits");
    }

    #[test]
    fn key_sensitivity() {
        let a = threefry2x64([1, 2], [7, 7]);
        let b = threefry2x64([1, 3], [7, 7]);
        let diff = (a[0] ^ b[0]).count_ones() + (a[1] ^ b[1]).count_ones();
        assert!(diff > 32, "expected avalanche, got {diff} differing bits");
    }

    #[test]
    fn unit_conversion_ranges() {
        for &x in &[0u64, 1, u64::MAX, 0x8000_0000_0000_0000, 12345678901234567] {
            let open = u64_to_open_unit(x);
            assert!(open > 0.0 && open <= 1.0, "open-unit out of range: {open}");
            let half = u64_to_unit(x);
            assert!((0.0..1.0).contains(&half), "unit out of range: {half}");
        }
    }

    #[test]
    fn uniform_moments() {
        // Mean ~ 1/2, variance ~ 1/12 over a modest sample.
        let n = 100_000u64;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for i in 0..n {
            let block = threefry2x64([42, 43], [i, 0]);
            let u = u64_to_unit(block[0]);
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }
}
