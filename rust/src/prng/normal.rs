//! Buffered Gaussian sampler over a [`PrngKey`] stream.
//!
//! [`PrngKey::normal_pair`] produces two normals per cipher call;
//! [`NormalSampler`] exposes them as a sequential stream while tracking the
//! counter, which is what solver loops want (one sampler per trajectory,
//! keyed by a per-trajectory child key).

use super::key::PrngKey;

/// Sequential standard-normal stream with an explicit, cloneable position.
#[derive(Clone, Debug)]
pub struct NormalSampler {
    key: PrngKey,
    ctr: u64,
    spare: Option<f64>,
}

impl NormalSampler {
    /// New stream at position zero.
    pub fn new(key: PrngKey) -> Self {
        NormalSampler { key, ctr: 0, spare: None }
    }

    /// Next standard normal.
    pub fn next_normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (a, b) = self.key.normal_pair(self.ctr);
        self.ctr += 1;
        self.spare = Some(b);
        a
    }

    /// Next normal scaled to `N(0, std^2)`.
    pub fn next_scaled(&mut self, std: f64) -> f64 {
        self.next_normal() * std
    }

    /// Fill a slice with independent standard normals.
    pub fn fill(&mut self, out: &mut [f64]) {
        for slot in out.iter_mut() {
            *slot = self.next_normal();
        }
    }

    /// Draws consumed so far (in cipher-call units).
    pub fn position(&self) -> u64 {
        self.ctr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_draws_are_deterministic() {
        let k = PrngKey::from_seed(2);
        let mut s1 = NormalSampler::new(k);
        let mut s2 = NormalSampler::new(k);
        for _ in 0..100 {
            assert_eq!(s1.next_normal(), s2.next_normal());
        }
    }

    #[test]
    fn spare_is_consumed() {
        let k = PrngKey::from_seed(2);
        let mut s = NormalSampler::new(k);
        let (a, b) = k.normal_pair(0);
        assert_eq!(s.next_normal(), a);
        assert_eq!(s.next_normal(), b);
        let (c, _) = k.normal_pair(1);
        assert_eq!(s.next_normal(), c);
    }

    #[test]
    fn fill_moments() {
        let mut s = NormalSampler::new(PrngKey::from_seed(77));
        let mut buf = vec![0.0; 100_000];
        s.fill(&mut buf);
        let mean = buf.iter().sum::<f64>() / buf.len() as f64;
        let var = buf.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.015, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }
}
