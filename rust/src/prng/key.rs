//! Splittable PRNG keys.
//!
//! A [`PrngKey`] is a 128-bit value identifying an independent random
//! stream. Keys support two operations, mirroring JAX's functional PRNG:
//!
//! * [`PrngKey::split`] — derive two statistically independent child keys
//!   (used by the virtual Brownian tree at every interval bisection), and
//! * drawing values — the k-th draw under a key is the pure function
//!   `threefry2x64(key, [k, stream])`, so a key never mutates.
//!
//! Because everything is a pure function of `(key, counter)`, an experiment
//! is bit-reproducible from its root seed, and a tree of 2^40 virtual keys
//! costs nothing to "store": only the root is kept.

use super::threefry::{threefry2x64, u64_to_open_unit, u64_to_unit};

/// A 128-bit splittable PRNG key (Threefry-2x64 based).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct PrngKey {
    k: [u64; 2],
}

impl PrngKey {
    /// Create a key from a single user-facing seed.
    pub fn from_seed(seed: u64) -> Self {
        // Scramble the seed once so nearby seeds give unrelated keys.
        let k = threefry2x64([0x5DEECE66D_u64, 0xB], [seed, !seed]);
        PrngKey { k }
    }

    /// Create a key from raw words (used by tests and serialization).
    pub fn from_raw(k: [u64; 2]) -> Self {
        PrngKey { k }
    }

    /// Raw words of the key.
    pub fn raw(&self) -> [u64; 2] {
        self.k
    }

    /// Deterministically derive two independent child keys.
    pub fn split(&self) -> (PrngKey, PrngKey) {
        // Two cipher calls with distinct counters in a dedicated "split"
        // stream (high bit of the second counter word set so split counters
        // can never collide with draw counters, which use stream ids < 2^63).
        const SPLIT_STREAM: u64 = 1 << 63;
        let left = threefry2x64(self.k, [0, SPLIT_STREAM]);
        let right = threefry2x64(self.k, [1, SPLIT_STREAM]);
        (PrngKey { k: left }, PrngKey { k: right })
    }

    /// Derive `n` independent child keys.
    pub fn split_n(&self, n: usize) -> Vec<PrngKey> {
        const SPLITN_STREAM: u64 = (1 << 63) | 1;
        (0..n)
            .map(|i| PrngKey {
                k: threefry2x64(self.k, [i as u64, SPLITN_STREAM]),
            })
            .collect()
    }

    /// Derive a child key from an integer tag (cheap "fold_in", used to key
    /// per-worker / per-batch-element streams).
    pub fn fold_in(&self, tag: u64) -> PrngKey {
        const FOLD_STREAM: u64 = (1 << 63) | 2;
        PrngKey {
            k: threefry2x64(self.k, [tag, FOLD_STREAM]),
        }
    }

    /// The `i`-th uniform draw in `[0, 1)` from this key's stream.
    pub fn uniform(&self, i: u64) -> f64 {
        let block = threefry2x64(self.k, [i, 0]);
        u64_to_unit(block[0])
    }

    /// The `i`-th pair of independent standard normal draws (Box–Muller).
    ///
    /// One cipher call yields 128 bits = two uniforms = two normals, so
    /// normals come in pairs "for free".
    pub fn normal_pair(&self, i: u64) -> (f64, f64) {
        let block = threefry2x64(self.k, [i, 1]);
        let u1 = u64_to_open_unit(block[0]); // in (0,1]: safe for ln()
        let u2 = u64_to_unit(block[1]);
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        let (s, c) = theta.sin_cos();
        (r * c, r * s)
    }

    /// The `i`-th standard normal draw (discards the Box–Muller partner;
    /// use [`Self::normal_pair`] or [`Self::fill_normal`] in hot paths).
    pub fn normal(&self, i: u64) -> f64 {
        self.normal_pair(i).0
    }

    /// Fill `out` with independent standard normals, using draw indices
    /// `base..base + ceil(len/2)` of the normal stream.
    pub fn fill_normal(&self, base: u64, out: &mut [f64]) {
        let mut i = 0usize;
        let mut ctr = base;
        while i + 1 < out.len() {
            let (a, b) = self.normal_pair(ctr);
            out[i] = a;
            out[i + 1] = b;
            i += 2;
            ctr += 1;
        }
        if i < out.len() {
            out[i] = self.normal_pair(ctr).0;
        }
    }

    /// Fill `out` with uniforms in `[0,1)`.
    pub fn fill_uniform(&self, base: u64, out: &mut [f64]) {
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = self.uniform(base + j as u64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_is_deterministic_and_distinct() {
        let k = PrngKey::from_seed(7);
        let (l1, r1) = k.split();
        let (l2, r2) = k.split();
        assert_eq!(l1, l2);
        assert_eq!(r1, r2);
        assert_ne!(l1, r1);
        assert_ne!(l1, k);
        assert_ne!(r1, k);
    }

    #[test]
    fn split_n_matches_count_and_distinct() {
        let keys = PrngKey::from_seed(3).split_n(16);
        assert_eq!(keys.len(), 16);
        for i in 0..16 {
            for j in (i + 1)..16 {
                assert_ne!(keys[i], keys[j], "children {i} and {j} collide");
            }
        }
    }

    #[test]
    fn fold_in_distinct_tags() {
        let k = PrngKey::from_seed(9);
        assert_ne!(k.fold_in(0), k.fold_in(1));
        assert_eq!(k.fold_in(5), k.fold_in(5));
    }

    #[test]
    fn nearby_seeds_give_unrelated_streams() {
        let a = PrngKey::from_seed(100);
        let b = PrngKey::from_seed(101);
        // First draws should not be close (prob of accidental failure ~ 0
        // for a fixed test — this is a regression canary, not a statistic).
        assert!((a.uniform(0) - b.uniform(0)).abs() > 1e-6);
    }

    #[test]
    fn normal_moments() {
        let k = PrngKey::from_seed(1234);
        let n = 200_000usize;
        let mut buf = vec![0.0; n];
        k.fill_normal(0, &mut buf);
        let mean = buf.iter().sum::<f64>() / n as f64;
        let var = buf.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let skew = buf.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / n as f64;
        let kurt = buf.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!(skew.abs() < 0.05, "skew {skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis {kurt}");
    }

    #[test]
    fn child_streams_uncorrelated() {
        let (l, r) = PrngKey::from_seed(55).split();
        let n = 50_000;
        let mut dot = 0.0;
        for i in 0..n {
            dot += l.normal(i as u64) * r.normal(i as u64);
        }
        let corr = dot / n as f64;
        assert!(corr.abs() < 0.02, "cross-correlation {corr}");
    }

    #[test]
    fn fill_normal_matches_pairwise_draws() {
        let k = PrngKey::from_seed(8);
        let mut buf = vec![0.0; 5];
        k.fill_normal(10, &mut buf);
        let (a, b) = k.normal_pair(10);
        let (c, d) = k.normal_pair(11);
        let (e, _) = k.normal_pair(12);
        assert_eq!(buf, vec![a, b, c, d, e]);
    }
}
