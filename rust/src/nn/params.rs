//! Flat parameter-vector allocation.
//!
//! Every layer's weights live in one flat `Vec<f64>`; layers store only
//! offsets. [`ParamBuilder`] hands out ranges and records initializer
//! specs, so a model definition is a plain struct of layers plus one call
//! to [`ParamBuilder::init`].

use crate::prng::PrngKey;

/// How a parameter range should be initialized.
#[derive(Clone, Copy, Debug)]
pub enum Init {
    /// All zeros (biases).
    Zeros,
    /// Constant value.
    Constant(f64),
    /// Uniform(−limit, +limit) — Xavier/Glorot when limit = √(6/(fan_in+fan_out)).
    Uniform { limit: f64 },
    /// Normal(0, std²).
    Normal { std: f64 },
}

/// Allocator for a model's flat parameter vector.
#[derive(Debug, Default)]
pub struct ParamBuilder {
    size: usize,
    inits: Vec<(usize, usize, Init)>,
}

impl ParamBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Reserve `n` parameters with the given initializer; returns the
    /// starting offset.
    pub fn alloc(&mut self, n: usize, init: Init) -> usize {
        let off = self.size;
        self.size += n;
        self.inits.push((off, n, init));
        off
    }

    /// Total parameter count allocated so far.
    pub fn len(&self) -> usize {
        self.size
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Materialize the initialized parameter vector.
    pub fn init(&self, key: PrngKey) -> Vec<f64> {
        let mut params = vec![0.0; self.size];
        for (idx, &(off, n, init)) in self.inits.iter().enumerate() {
            let k = key.fold_in(idx as u64);
            let slice = &mut params[off..off + n];
            match init {
                Init::Zeros => slice.fill(0.0),
                Init::Constant(c) => slice.fill(c),
                Init::Uniform { limit } => {
                    for (j, v) in slice.iter_mut().enumerate() {
                        *v = (k.uniform(j as u64) * 2.0 - 1.0) * limit;
                    }
                }
                Init::Normal { std } => {
                    k.fill_normal(0, slice);
                    for v in slice.iter_mut() {
                        *v *= std;
                    }
                }
            }
        }
        params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offsets_are_contiguous() {
        let mut b = ParamBuilder::new();
        let a = b.alloc(10, Init::Zeros);
        let c = b.alloc(5, Init::Constant(2.0));
        assert_eq!(a, 0);
        assert_eq!(c, 10);
        assert_eq!(b.len(), 15);
    }

    #[test]
    fn init_respects_specs() {
        let mut b = ParamBuilder::new();
        b.alloc(4, Init::Zeros);
        b.alloc(3, Init::Constant(1.5));
        b.alloc(100, Init::Uniform { limit: 0.2 });
        let p = b.init(PrngKey::from_seed(1));
        assert_eq!(&p[..4], &[0.0; 4]);
        assert_eq!(&p[4..7], &[1.5; 3]);
        assert!(p[7..].iter().all(|v| v.abs() <= 0.2));
        assert!(p[7..].iter().any(|v| v.abs() > 0.01), "uniform init all ~zero?");
    }

    #[test]
    fn init_is_deterministic_per_key() {
        let mut b = ParamBuilder::new();
        b.alloc(50, Init::Normal { std: 0.1 });
        let p1 = b.init(PrngKey::from_seed(7));
        let p2 = b.init(PrngKey::from_seed(7));
        let p3 = b.init(PrngKey::from_seed(8));
        assert_eq!(p1, p2);
        assert_ne!(p1, p3);
    }
}
