//! Multilayer perceptron with a reusable forward cache for VJPs.
//!
//! The paper's drift/decoder nets are 1-hidden-layer MLPs with softplus
//! (App. 9.9); diffusion nets add a sigmoid output. [`Mlp`] supports any
//! depth; [`MlpCache`] stores pre- and post-activation values so a VJP can
//! follow a forward pass without re-allocating — the adjoint hot loop
//! calls forward+vjp at every solver step.

use super::activation::Activation;
use super::linear::Linear;
use super::params::ParamBuilder;

/// A stack of dense layers with a shared hidden activation and a separate
/// output activation.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
    pub hidden_act: Activation,
    pub output_act: Activation,
}

/// Forward-pass cache: pre-activations and activations per layer.
#[derive(Clone, Debug, Default)]
pub struct MlpCache {
    /// `pre[l]` = inputs to activation of layer l (length out_dim of l).
    pre: Vec<Vec<f64>>,
    /// `act[l]` = output of layer l after activation; `act[0]` is the input.
    act: Vec<Vec<f64>>,
    /// Scratch for the backward pass.
    delta: Vec<f64>,
    delta_next: Vec<f64>,
}

impl Mlp {
    /// Build an MLP with the given layer sizes, e.g. `[in, h, out]`.
    pub fn new(
        pb: &mut ParamBuilder,
        sizes: &[usize],
        hidden_act: Activation,
        output_act: Activation,
    ) -> Self {
        assert!(sizes.len() >= 2, "Mlp needs at least input and output sizes");
        let layers = sizes
            .windows(2)
            .map(|w| Linear::new(pb, w[0], w[1]))
            .collect();
        Mlp { layers, hidden_act, output_act }
    }

    pub fn in_dim(&self) -> usize {
        self.layers[0].in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().unwrap().out_dim
    }

    /// Allocate a cache sized for this MLP.
    pub fn cache(&self) -> MlpCache {
        let mut pre = Vec::with_capacity(self.layers.len());
        let mut act = Vec::with_capacity(self.layers.len() + 1);
        act.push(vec![0.0; self.in_dim()]);
        let mut widest = 0;
        for l in &self.layers {
            pre.push(vec![0.0; l.out_dim]);
            act.push(vec![0.0; l.out_dim]);
            widest = widest.max(l.out_dim).max(l.in_dim);
        }
        MlpCache { pre, act, delta: vec![0.0; widest], delta_next: vec![0.0; widest] }
    }

    /// Forward pass; writes the output into `out` and fills `cache`.
    pub fn forward(&self, params: &[f64], x: &[f64], cache: &mut MlpCache, out: &mut [f64]) {
        cache.act[0].copy_from_slice(x);
        let n = self.layers.len();
        for (l, lin) in self.layers.iter().enumerate() {
            // Split act around l so we can read act[l] and write act[l+1].
            let (lo, hi) = cache.act.split_at_mut(l + 1);
            lin.forward(params, &lo[l], &mut cache.pre[l]);
            let act = if l + 1 == n { self.output_act } else { self.hidden_act };
            for (o, (&pre_v, slot)) in cache.pre[l].iter().zip(hi[0].iter_mut()).enumerate() {
                let _ = o;
                *slot = act.apply(pre_v);
            }
        }
        out.copy_from_slice(cache.act.last().unwrap());
    }

    /// Accumulating VJP following a [`Mlp::forward`] with the same inputs:
    /// given `dy = ∂L/∂out`, adds `∂L/∂x` into `dx` and `∂L/∂params` into
    /// `dparams`.
    pub fn vjp(
        &self,
        params: &[f64],
        cache: &mut MlpCache,
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
    ) {
        let n = self.layers.len();
        // delta = dy ⊙ act'(pre) of the output layer.
        {
            let dlt = &mut cache.delta[..self.out_dim()];
            for (i, slot) in dlt.iter_mut().enumerate() {
                let pre = cache.pre[n - 1][i];
                let act = cache.act[n][i];
                *slot = dy[i] * self.output_act.grad(pre, act);
            }
        }
        for l in (0..n).rev() {
            let lin = &self.layers[l];
            let dlt_len = lin.out_dim;
            // dx of this layer goes into delta_next (or the caller's dx for
            // layer 0).
            if l == 0 {
                let (delta, _) = (&cache.delta[..dlt_len], ());
                lin.vjp(params, &cache.act[0], delta, dx, dparams);
            } else {
                let dnext = &mut cache.delta_next[..lin.in_dim];
                dnext.fill(0.0);
                // Borrow juggling: split cache fields.
                let MlpCache { pre, act, delta, delta_next } = cache;
                let dnx = &mut delta_next[..lin.in_dim];
                dnx.fill(0.0);
                lin.vjp(params, &act[l], &delta[..dlt_len], dnx, dparams);
                // delta ← dnext ⊙ act'(pre[l-1])
                for i in 0..lin.in_dim {
                    let p = pre[l - 1][i];
                    let a = act[l][i];
                    delta[i] = dnx[i] * self.hidden_act.grad(p, a);
                }
            }
        }
    }

    /// Fast-tier batched forward: layer matmuls run on
    /// [`Linear::forward_batch_fast`] (reassociated multi-accumulator
    /// dots); activations are elementwise and unchanged. Same cache
    /// contract as [`Mlp::forward_batch`]; agrees with it to relative
    /// tolerance (`tests/fast_tier.rs`).
    pub fn forward_batch_fast(
        &self,
        params: &[f64],
        x: &[f64],
        cache: &mut MlpBatchCache,
        out: &mut [f64],
    ) {
        debug_assert_eq!(x.len(), cache.batch * self.in_dim());
        cache.act[0].copy_from_slice(x);
        let n = self.layers.len();
        for (l, lin) in self.layers.iter().enumerate() {
            let (lo, hi) = cache.act.split_at_mut(l + 1);
            lin.forward_batch_fast(params, &lo[l], &mut cache.pre[l]);
            let act = if l + 1 == n { self.output_act } else { self.hidden_act };
            for (&pre_v, slot) in cache.pre[l].iter().zip(hi[0].iter_mut()) {
                *slot = act.apply(pre_v);
            }
        }
        out.copy_from_slice(cache.act.last().unwrap());
    }

    /// Fast-tier batched VJP following a [`Mlp::forward_batch_fast`]
    /// with the same inputs: layer backward passes run on
    /// [`Linear::vjp_batch_fast`] (branchless, no zero-row skip). Same
    /// cache/per-path-block contract as [`Mlp::vjp_batch`].
    pub fn vjp_batch_fast(
        &self,
        params: &[f64],
        cache: &mut MlpBatchCache,
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let n = self.layers.len();
        let bsz = cache.batch;
        let no = self.out_dim();
        {
            let dlt = &mut cache.delta[..bsz * no];
            for (i, slot) in dlt.iter_mut().enumerate() {
                let pre = cache.pre[n - 1][i];
                let act = cache.act[n][i];
                *slot = dy[i] * self.output_act.grad(pre, act);
            }
        }
        for l in (0..n).rev() {
            let lin = &self.layers[l];
            let dlt_len = bsz * lin.out_dim;
            if l == 0 {
                let delta = &cache.delta[..dlt_len];
                lin.vjp_batch_fast(params, &cache.act[0], delta, dx, dparams, pstride);
            } else {
                let MlpBatchCache { pre, act, delta, delta_next, .. } = cache;
                let dnx = &mut delta_next[..bsz * lin.in_dim];
                dnx.fill(0.0);
                lin.vjp_batch_fast(params, &act[l], &delta[..dlt_len], dnx, dparams, pstride);
                for i in 0..bsz * lin.in_dim {
                    let p = pre[l - 1][i];
                    let a = act[l][i];
                    delta[i] = dnx[i] * self.hidden_act.grad(p, a);
                }
            }
        }
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Allocate a batched cache for B rows.
    pub fn batch_cache(&self, batch: usize) -> MlpBatchCache {
        assert!(batch > 0, "batch_cache: empty batch");
        let mut pre = Vec::with_capacity(self.layers.len());
        let mut act = Vec::with_capacity(self.layers.len() + 1);
        act.push(vec![0.0; batch * self.in_dim()]);
        let mut widest = 0;
        for l in &self.layers {
            pre.push(vec![0.0; batch * l.out_dim]);
            act.push(vec![0.0; batch * l.out_dim]);
            widest = widest.max(l.out_dim).max(l.in_dim);
        }
        MlpBatchCache {
            pre,
            act,
            delta: vec![0.0; batch * widest],
            delta_next: vec![0.0; batch * widest],
            batch,
        }
    }

    /// Batched forward over B input rows (`x: [B×in]`, `out: [B×out]`):
    /// one blocked matrix–matrix pass per layer via
    /// [`Linear::forward_batch`] instead of B matrix–vector passes, with
    /// activations applied elementwise. Per row, bit-identical to
    /// [`Mlp::forward`].
    pub fn forward_batch(
        &self,
        params: &[f64],
        x: &[f64],
        cache: &mut MlpBatchCache,
        out: &mut [f64],
    ) {
        debug_assert_eq!(x.len(), cache.batch * self.in_dim());
        cache.act[0].copy_from_slice(x);
        let n = self.layers.len();
        for (l, lin) in self.layers.iter().enumerate() {
            let (lo, hi) = cache.act.split_at_mut(l + 1);
            lin.forward_batch(params, &lo[l], &mut cache.pre[l]);
            let act = if l + 1 == n { self.output_act } else { self.hidden_act };
            for (&pre_v, slot) in cache.pre[l].iter().zip(hi[0].iter_mut()) {
                *slot = act.apply(pre_v);
            }
        }
        out.copy_from_slice(cache.act.last().unwrap());
    }

    /// Batched accumulating VJP following a [`Mlp::forward_batch`] with
    /// the same inputs: given `dy: [B×out]`, adds `∂L_b/∂x_b` into
    /// `dx[b]` and each path's parameter gradients into
    /// `dparams[b*pstride ..]` (per-path blocks, scalar offsets within).
    /// Per row, bit-identical to [`Mlp::vjp`].
    pub fn vjp_batch(
        &self,
        params: &[f64],
        cache: &mut MlpBatchCache,
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let n = self.layers.len();
        let bsz = cache.batch;
        let no = self.out_dim();
        // delta = dy ⊙ act'(pre) of the output layer, all rows.
        {
            let dlt = &mut cache.delta[..bsz * no];
            for (i, slot) in dlt.iter_mut().enumerate() {
                let pre = cache.pre[n - 1][i];
                let act = cache.act[n][i];
                *slot = dy[i] * self.output_act.grad(pre, act);
            }
        }
        for l in (0..n).rev() {
            let lin = &self.layers[l];
            let dlt_len = bsz * lin.out_dim;
            if l == 0 {
                let delta = &cache.delta[..dlt_len];
                lin.vjp_batch(params, &cache.act[0], delta, dx, dparams, pstride);
            } else {
                let MlpBatchCache { pre, act, delta, delta_next, .. } = cache;
                let dnx = &mut delta_next[..bsz * lin.in_dim];
                dnx.fill(0.0);
                lin.vjp_batch(params, &act[l], &delta[..dlt_len], dnx, dparams, pstride);
                // delta ← dnext ⊙ act'(pre[l-1]), all rows.
                for i in 0..bsz * lin.in_dim {
                    let p = pre[l - 1][i];
                    let a = act[l][i];
                    delta[i] = dnx[i] * self.hidden_act.grad(p, a);
                }
            }
        }
    }
}

/// Batched forward-pass cache: per-layer `[B×width]` pre-activation and
/// activation matrices plus backward-stage scratch — the batch analogue
/// of [`MlpCache`], allocated once per solve and reused every step.
#[derive(Clone, Debug)]
pub struct MlpBatchCache {
    pre: Vec<Vec<f64>>,
    act: Vec<Vec<f64>>,
    delta: Vec<f64>,
    delta_next: Vec<f64>,
    batch: usize,
}

impl MlpBatchCache {
    /// Batch size B this cache was allocated for.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::PrngKey;

    fn fd_check(sizes: &[usize], hidden: Activation, output: Activation, seed: u64) {
        let mut pb = ParamBuilder::new();
        let mlp = Mlp::new(&mut pb, sizes, hidden, output);
        let params = pb.init(PrngKey::from_seed(seed));
        let mut cache = mlp.cache();
        let d_in = sizes[0];
        let d_out = *sizes.last().unwrap();

        let key = PrngKey::from_seed(seed + 1);
        let mut x = vec![0.0; d_in];
        key.fill_normal(0, &mut x);
        let mut dy = vec![0.0; d_out];
        key.fill_normal(100, &mut dy);

        let mut out = vec![0.0; d_out];
        mlp.forward(&params, &x, &mut cache, &mut out);
        let mut dx = vec![0.0; d_in];
        let mut dp = vec![0.0; params.len()];
        mlp.vjp(&params, &mut cache, &dy, &mut dx, &mut dp);

        let loss = |p: &[f64], x: &[f64]| -> f64 {
            let mut c = mlp.cache();
            let mut o = vec![0.0; d_out];
            mlp.forward(p, x, &mut c, &mut o);
            o.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-6;
        for i in 0..d_in {
            let mut xp = x.clone();
            xp[i] += eps;
            let hi = loss(&params, &xp);
            xp[i] -= 2.0 * eps;
            let lo = loss(&params, &xp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 1e-6 * fd.abs().max(1.0),
                "{sizes:?} dx[{i}]: fd {fd} vs {}",
                dx[i]
            );
        }
        for j in (0..params.len()).step_by(7) {
            let mut pp = params.clone();
            pp[j] += eps;
            let hi = loss(&pp, &x);
            pp[j] -= 2.0 * eps;
            let lo = loss(&pp, &x);
            let fd = (hi - lo) / (2.0 * eps);
            assert!(
                (fd - dp[j]).abs() < 1e-6 * fd.abs().max(1.0),
                "{sizes:?} dp[{j}]: fd {fd} vs {}",
                dp[j]
            );
        }
    }

    #[test]
    fn one_hidden_layer_softplus() {
        fd_check(&[3, 16, 2], Activation::Softplus, Activation::Identity, 10);
    }

    #[test]
    fn sigmoid_output_diffusion_style() {
        fd_check(&[1, 8, 1], Activation::Softplus, Activation::Sigmoid, 11);
    }

    #[test]
    fn deep_tanh() {
        fd_check(&[4, 8, 8, 8, 3], Activation::Tanh, Activation::Identity, 12);
    }

    #[test]
    fn linear_model_no_hidden() {
        fd_check(&[5, 2], Activation::Tanh, Activation::Identity, 13);
    }

    /// Batched forward/VJP must equal B scalar passes bit-for-bit — the
    /// guarantee that lets nn-backed SDEs ride the batch engine without
    /// changing any float.
    #[test]
    fn batched_forward_and_vjp_match_scalar_rows_exactly() {
        for (sizes, hidden, output) in [
            (&[3usize, 16, 2][..], Activation::Softplus, Activation::Identity),
            (&[1, 8, 1][..], Activation::Softplus, Activation::Sigmoid),
            (&[4, 8, 8, 3][..], Activation::Tanh, Activation::Identity),
        ] {
            let mut pb = ParamBuilder::new();
            let mlp = Mlp::new(&mut pb, sizes, hidden, output);
            let params = pb.init(PrngKey::from_seed(40));
            let (ni, no) = (mlp.in_dim(), mlp.out_dim());
            let bsz = 5;
            let key = PrngKey::from_seed(41);
            let mut x = vec![0.0; bsz * ni];
            key.fill_normal(0, &mut x);
            let mut dy = vec![0.0; bsz * no];
            key.fill_normal(500, &mut dy);

            let mut bcache = mlp.batch_cache(bsz);
            let mut out_b = vec![0.0; bsz * no];
            mlp.forward_batch(&params, &x, &mut bcache, &mut out_b);
            let mut dx_b = vec![0.0; bsz * ni];
            let mut dp_b = vec![0.0; bsz * params.len()];
            mlp.vjp_batch(&params, &mut bcache, &dy, &mut dx_b, &mut dp_b, params.len());

            for b in 0..bsz {
                let mut cache = mlp.cache();
                let mut out = vec![0.0; no];
                mlp.forward(&params, &x[b * ni..(b + 1) * ni], &mut cache, &mut out);
                assert_eq!(&out_b[b * no..(b + 1) * no], &out[..], "{sizes:?} fwd row {b}");
                let mut dx = vec![0.0; ni];
                let mut dp = vec![0.0; params.len()];
                mlp.vjp(&params, &mut cache, &dy[b * no..(b + 1) * no], &mut dx, &mut dp);
                assert_eq!(&dx_b[b * ni..(b + 1) * ni], &dx[..], "{sizes:?} dx row {b}");
                assert_eq!(
                    &dp_b[b * params.len()..(b + 1) * params.len()],
                    &dp[..],
                    "{sizes:?} dparams row {b}"
                );
            }
        }
    }

    /// Fast-tier forward/VJP agree with the exact batched kernels to
    /// relative tolerance across depths, activations, and odd widths.
    #[test]
    fn fast_batched_kernels_match_exact_to_tolerance() {
        for (sizes, hidden, output) in [
            (&[3usize, 16, 2][..], Activation::Softplus, Activation::Identity),
            (&[1, 9, 1][..], Activation::Softplus, Activation::Sigmoid),
            (&[5, 7, 7, 3][..], Activation::Tanh, Activation::Identity),
        ] {
            let mut pb = ParamBuilder::new();
            let mlp = Mlp::new(&mut pb, sizes, hidden, output);
            let params = pb.init(PrngKey::from_seed(60));
            let (ni, no) = (mlp.in_dim(), mlp.out_dim());
            let bsz = 6;
            let key = PrngKey::from_seed(61);
            let mut x = vec![0.0; bsz * ni];
            key.fill_normal(0, &mut x);
            let mut dy = vec![0.0; bsz * no];
            key.fill_normal(700, &mut dy);
            let tol = |a: f64, b: f64| (a - b).abs() <= 1e-10 * a.abs().max(1.0);

            let mut ce = mlp.batch_cache(bsz);
            let mut out_e = vec![0.0; bsz * no];
            mlp.forward_batch(&params, &x, &mut ce, &mut out_e);
            let mut dx_e = vec![0.0; bsz * ni];
            let mut dp_e = vec![0.0; bsz * params.len()];
            mlp.vjp_batch(&params, &mut ce, &dy, &mut dx_e, &mut dp_e, params.len());

            let mut cf = mlp.batch_cache(bsz);
            let mut out_f = vec![0.0; bsz * no];
            mlp.forward_batch_fast(&params, &x, &mut cf, &mut out_f);
            let mut dx_f = vec![0.0; bsz * ni];
            let mut dp_f = vec![0.0; bsz * params.len()];
            mlp.vjp_batch_fast(&params, &mut cf, &dy, &mut dx_f, &mut dp_f, params.len());

            for (a, b) in out_e.iter().zip(&out_f) {
                assert!(tol(*a, *b), "{sizes:?} fwd {a} vs {b}");
            }
            for (a, b) in dx_e.iter().zip(&dx_f) {
                assert!(tol(*a, *b), "{sizes:?} dx {a} vs {b}");
            }
            for (a, b) in dp_e.iter().zip(&dp_f) {
                assert!(tol(*a, *b), "{sizes:?} dparams {a} vs {b}");
            }
        }
    }

    #[test]
    fn forward_deterministic_across_caches() {
        let mut pb = ParamBuilder::new();
        let mlp = Mlp::new(&mut pb, &[2, 8, 2], Activation::Softplus, Activation::Identity);
        let params = pb.init(PrngKey::from_seed(20));
        let x = [0.3, -0.8];
        let mut c1 = mlp.cache();
        let mut c2 = mlp.cache();
        let mut o1 = [0.0; 2];
        let mut o2 = [0.0; 2];
        mlp.forward(&params, &x, &mut c1, &mut o1);
        mlp.forward(&params, &x, &mut c2, &mut o2);
        assert_eq!(o1, o2);
    }
}
