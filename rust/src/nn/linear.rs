//! Dense layer `y = W x + b` over the flat parameter vector.

use super::params::{Init, ParamBuilder};

/// A dense layer; weights at `w_off` (row-major `out×in`), bias at `b_off`.
#[derive(Clone, Copy, Debug)]
pub struct Linear {
    pub in_dim: usize,
    pub out_dim: usize,
    pub w_off: usize,
    pub b_off: usize,
}

impl Linear {
    /// Allocate a layer with Xavier-uniform weights and zero bias.
    pub fn new(pb: &mut ParamBuilder, in_dim: usize, out_dim: usize) -> Self {
        let limit = (6.0 / (in_dim + out_dim) as f64).sqrt();
        let w_off = pb.alloc(in_dim * out_dim, Init::Uniform { limit });
        let b_off = pb.alloc(out_dim, Init::Zeros);
        Linear { in_dim, out_dim, w_off, b_off }
    }

    /// `out = W x + b`.
    pub fn forward(&self, params: &[f64], x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.in_dim);
        debug_assert_eq!(out.len(), self.out_dim);
        let w = &params[self.w_off..self.w_off + self.in_dim * self.out_dim];
        let b = &params[self.b_off..self.b_off + self.out_dim];
        for o in 0..self.out_dim {
            let row = &w[o * self.in_dim..(o + 1) * self.in_dim];
            let mut acc = b[o];
            for i in 0..self.in_dim {
                acc += row[i] * x[i];
            }
            out[o] = acc;
        }
    }

    /// Accumulate the VJP: given `dy = ∂L/∂out`,
    /// * `dx += Wᵀ dy`,
    /// * `dparams[W] += dy ⊗ x`, `dparams[b] += dy`.
    pub fn vjp(
        &self,
        params: &[f64],
        x: &[f64],
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
    ) {
        debug_assert_eq!(dy.len(), self.out_dim);
        debug_assert_eq!(dx.len(), self.in_dim);
        let w = &params[self.w_off..self.w_off + self.in_dim * self.out_dim];
        for o in 0..self.out_dim {
            let g = dy[o];
            if g == 0.0 {
                continue;
            }
            let row = &w[o * self.in_dim..(o + 1) * self.in_dim];
            let dw_row = &mut dparams[self.w_off + o * self.in_dim..self.w_off + (o + 1) * self.in_dim];
            for i in 0..self.in_dim {
                dx[i] += row[i] * g;
                dw_row[i] += x[i] * g;
            }
            dparams[self.b_off + o] += g;
        }
    }

    /// Parameter count of this layer.
    pub fn param_count(&self) -> usize {
        self.in_dim * self.out_dim + self.out_dim
    }

    /// Batched forward: `out[b] = W x[b] + b` for B rows at once
    /// (`x: [B×in]`, `out: [B×out]`, row-major).
    ///
    /// Blocked matrix–matrix walk: the outer loop is over output rows so
    /// each weight row `W[o,·]` is loaded once and swept across all B
    /// input rows — the cache win batching exists for. Per `(b, o)` cell
    /// the accumulation is the scalar [`Linear::forward`] loop verbatim
    /// (bias first, then `i = 0..in` in order), so batched outputs are
    /// bit-identical to B scalar calls.
    pub fn forward_batch(&self, params: &[f64], x: &[f64], out: &mut [f64]) {
        let (ni, no) = (self.in_dim, self.out_dim);
        let bsz = x.len() / ni;
        debug_assert_eq!(x.len(), bsz * ni);
        debug_assert_eq!(out.len(), bsz * no);
        let w = &params[self.w_off..self.w_off + ni * no];
        let b_vec = &params[self.b_off..self.b_off + no];
        for o in 0..no {
            let row = &w[o * ni..(o + 1) * ni];
            let bias = b_vec[o];
            for b in 0..bsz {
                let xr = &x[b * ni..(b + 1) * ni];
                let mut acc = bias;
                for i in 0..ni {
                    acc += row[i] * xr[i];
                }
                out[b * no + o] = acc;
            }
        }
    }

    /// Batched accumulating VJP over B rows: given `dy: [B×out]`, adds
    /// `Wᵀ dy[b]` into `dx[b]` and the per-path parameter gradients into
    /// `dparams[b*pstride ..]` (each path owns a full parameter-gradient
    /// block of stride `pstride`; offsets within a block match the scalar
    /// layout).
    ///
    /// Same weight-row blocking as [`Linear::forward_batch`]; per path the
    /// update order over `(o, i)` is the scalar [`Linear::vjp`]'s, so
    /// results are bit-identical to B scalar calls.
    pub fn vjp_batch(
        &self,
        params: &[f64],
        x: &[f64],
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let (ni, no) = (self.in_dim, self.out_dim);
        let bsz = x.len() / ni;
        debug_assert_eq!(dy.len(), bsz * no);
        debug_assert_eq!(dx.len(), bsz * ni);
        debug_assert_eq!(dparams.len(), bsz * pstride);
        let w = &params[self.w_off..self.w_off + ni * no];
        for o in 0..no {
            let row = &w[o * ni..(o + 1) * ni];
            for b in 0..bsz {
                let g = dy[b * no + o];
                if g == 0.0 {
                    continue;
                }
                let xr = &x[b * ni..(b + 1) * ni];
                let dxr = &mut dx[b * ni..(b + 1) * ni];
                let blk = &mut dparams[b * pstride..(b + 1) * pstride];
                let dw_row = &mut blk[self.w_off + o * ni..self.w_off + (o + 1) * ni];
                for i in 0..ni {
                    dxr[i] += row[i] * g;
                    dw_row[i] += xr[i] * g;
                }
                blk[self.b_off + o] += g;
            }
        }
    }

    /// Fast-tier batched forward: same weight-row blocking as
    /// [`Linear::forward_batch`], but each dot product runs on four
    /// independent accumulators (reassociated reduction — the float order
    /// the exact kernel pins is deliberately given up here, which is what
    /// lets the compiler keep four FMA chains in flight and vectorize the
    /// stride-1 lanes). Agrees with the exact kernel to relative rounding
    /// tolerance, pinned in `tests/fast_tier.rs`.
    pub fn forward_batch_fast(&self, params: &[f64], x: &[f64], out: &mut [f64]) {
        let (ni, no) = (self.in_dim, self.out_dim);
        let bsz = x.len() / ni;
        debug_assert_eq!(x.len(), bsz * ni);
        debug_assert_eq!(out.len(), bsz * no);
        let w = &params[self.w_off..self.w_off + ni * no];
        let b_vec = &params[self.b_off..self.b_off + no];
        for o in 0..no {
            let row = &w[o * ni..(o + 1) * ni];
            let bias = b_vec[o];
            for b in 0..bsz {
                let xr = &x[b * ni..(b + 1) * ni];
                let mut a0 = 0.0;
                let mut a1 = 0.0;
                let mut a2 = 0.0;
                let mut a3 = 0.0;
                let mut i = 0;
                while i + 4 <= ni {
                    a0 += row[i] * xr[i];
                    a1 += row[i + 1] * xr[i + 1];
                    a2 += row[i + 2] * xr[i + 2];
                    a3 += row[i + 3] * xr[i + 3];
                    i += 4;
                }
                let mut tail = 0.0;
                while i < ni {
                    tail += row[i] * xr[i];
                    i += 1;
                }
                out[b * no + o] = bias + ((a0 + a2) + (a1 + a3)) + tail;
            }
        }
    }

    /// Fast-tier batched VJP: the exact kernel's `g == 0` row skip is
    /// dropped (branchless inner loops vectorize; a multiply by zero is
    /// cheaper than a mispredicted branch at typical densities) and the
    /// two accumulation streams (`dx`, `dW`) stay independent stride-1
    /// sweeps. Gradient values agree with the exact kernel up to the
    /// `±0.0` of skipped rows and rounding-order tolerance.
    pub fn vjp_batch_fast(
        &self,
        params: &[f64],
        x: &[f64],
        dy: &[f64],
        dx: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let (ni, no) = (self.in_dim, self.out_dim);
        let bsz = x.len() / ni;
        debug_assert_eq!(dy.len(), bsz * no);
        debug_assert_eq!(dx.len(), bsz * ni);
        debug_assert_eq!(dparams.len(), bsz * pstride);
        let w = &params[self.w_off..self.w_off + ni * no];
        for o in 0..no {
            let row = &w[o * ni..(o + 1) * ni];
            for b in 0..bsz {
                let g = dy[b * no + o];
                let xr = &x[b * ni..(b + 1) * ni];
                let dxr = &mut dx[b * ni..(b + 1) * ni];
                let blk = &mut dparams[b * pstride..(b + 1) * pstride];
                let dw_row = &mut blk[self.w_off + o * ni..self.w_off + (o + 1) * ni];
                for i in 0..ni {
                    dxr[i] += row[i] * g;
                }
                for i in 0..ni {
                    dw_row[i] += xr[i] * g;
                }
                blk[self.b_off + o] += g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::PrngKey;

    #[test]
    fn forward_matches_manual() {
        let mut pb = ParamBuilder::new();
        let l = Linear::new(&mut pb, 2, 3);
        let mut p = pb.init(PrngKey::from_seed(1));
        // Overwrite with known values: W = [[1,2],[3,4],[5,6]], b=[.1,.2,.3]
        p[l.w_off..l.w_off + 6].copy_from_slice(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        p[l.b_off..l.b_off + 3].copy_from_slice(&[0.1, 0.2, 0.3]);
        let mut y = [0.0; 3];
        l.forward(&p, &[10.0, 20.0], &mut y);
        assert_eq!(y, [50.1, 110.2, 170.3]);
    }

    #[test]
    fn vjp_matches_finite_difference() {
        let mut pb = ParamBuilder::new();
        let l = Linear::new(&mut pb, 3, 2);
        let p = pb.init(PrngKey::from_seed(2));
        let x = [0.5, -1.0, 2.0];
        let dy = [1.0, -0.3];
        let mut dx = vec![0.0; 3];
        let mut dp = vec![0.0; p.len()];
        l.vjp(&p, &x, &dy, &mut dx, &mut dp);

        let loss = |p: &[f64], x: &[f64]| -> f64 {
            let mut y = [0.0; 2];
            l.forward(p, x, &mut y);
            y[0] * dy[0] + y[1] * dy[1]
        };
        let eps = 1e-6;
        for i in 0..3 {
            let mut xp = x;
            xp[i] += eps;
            let hi = loss(&p, &xp);
            xp[i] -= 2.0 * eps;
            let lo = loss(&p, &xp);
            assert!(((hi - lo) / (2.0 * eps) - dx[i]).abs() < 1e-8, "dx[{i}]");
        }
        for j in 0..p.len() {
            let mut pp = p.clone();
            pp[j] += eps;
            let hi = loss(&pp, &x);
            pp[j] -= 2.0 * eps;
            let lo = loss(&pp, &x);
            assert!(((hi - lo) / (2.0 * eps) - dp[j]).abs() < 1e-8, "dp[{j}]");
        }
    }

    #[test]
    fn vjp_accumulates() {
        let mut pb = ParamBuilder::new();
        let l = Linear::new(&mut pb, 2, 2);
        let p = pb.init(PrngKey::from_seed(3));
        let x = [1.0, 2.0];
        let dy = [1.0, 1.0];
        let mut dx = vec![10.0, 20.0];
        let mut dp = vec![0.0; p.len()];
        let mut dx_base = vec![0.0, 0.0];
        l.vjp(&p, &x, &dy, &mut dx_base, &mut dp);
        l.vjp(&p, &x, &dy, &mut dx, &mut dp);
        assert!((dx[0] - (10.0 + dx_base[0])).abs() < 1e-12);
        assert!((dx[1] - (20.0 + dx_base[1])).abs() < 1e-12);
    }

    /// Fast kernels agree with the exact ones to relative rounding
    /// tolerance — including an in-dim that is not a multiple of the
    /// unroll width and a dy row containing exact zeros (the fast VJP
    /// drops the zero-skip).
    #[test]
    fn fast_kernels_match_exact_to_tolerance() {
        let key = PrngKey::from_seed(5);
        let mut pb = ParamBuilder::new();
        let l = Linear::new(&mut pb, 7, 3);
        let p = pb.init(key);
        let bsz = 9;
        let mut x = vec![0.0; bsz * 7];
        key.fill_normal(1, &mut x);
        let mut dy = vec![0.0; bsz * 3];
        key.fill_normal(2, &mut dy);
        dy[4] = 0.0; // exercise the dropped zero-skip
        let tol = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(1.0);

        let mut y_exact = vec![0.0; bsz * 3];
        let mut y_fast = vec![0.0; bsz * 3];
        l.forward_batch(&p, &x, &mut y_exact);
        l.forward_batch_fast(&p, &x, &mut y_fast);
        for (a, b) in y_exact.iter().zip(&y_fast) {
            assert!(tol(*a, *b), "forward {a} vs {b}");
        }

        let pstride = p.len();
        let mut dx_e = vec![0.0; bsz * 7];
        let mut dp_e = vec![0.0; bsz * pstride];
        l.vjp_batch(&p, &x, &dy, &mut dx_e, &mut dp_e, pstride);
        let mut dx_f = vec![0.0; bsz * 7];
        let mut dp_f = vec![0.0; bsz * pstride];
        l.vjp_batch_fast(&p, &x, &dy, &mut dx_f, &mut dp_f, pstride);
        for (a, b) in dx_e.iter().zip(&dx_f) {
            assert!(tol(*a, *b), "dx {a} vs {b}");
        }
        for (a, b) in dp_e.iter().zip(&dp_f) {
            assert!(tol(*a, *b), "dparams {a} vs {b}");
        }
    }
}
