//! Minimal neural-network substrate with hand-written VJPs.
//!
//! The paper's models (App. 9.9/9.11) are small MLPs (drift, diffusion,
//! decoder) and a GRU encoder. The stochastic adjoint only ever needs
//! `vjp(a, net, (x, params))` — never full Jacobians — so this module
//! provides exactly that: every layer implements `forward` and an
//! *accumulating* `vjp`, operating on a single flat `f64` parameter vector
//! shared by the whole model (which is what the optimizer and the
//! XLA-artifact boundary both want).
//!
//! Substitution note (DESIGN.md §3): the paper uses PyTorch autograd; this
//! repo replaces it with these hand-derived VJPs, each verified against
//! central finite differences in the module tests, plus JAX autodiff on the
//! L2 build path.

pub mod activation;
pub mod gru;
pub mod init;
pub mod linear;
pub mod mlp;
pub mod params;

pub use activation::Activation;
pub use gru::{GruBatchCache, GruCell};
pub use linear::Linear;
pub use mlp::{Mlp, MlpBatchCache, MlpCache};
pub use params::ParamBuilder;
