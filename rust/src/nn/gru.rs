//! GRU cell (Cho et al. 2014) with hand-written backward-through-time VJP.
//!
//! Used by the latent-SDE recognition network (App. 9.9: a GRU runs
//! *backward* over the observations and emits a context vector at each
//! time, plus the variational posterior over the initial latent state).
//!
//! Gate equations (PyTorch convention):
//! ```text
//! r  = σ(W_ir x + b_ir + W_hr h + b_hr)
//! u  = σ(W_iu x + b_iu + W_hu h + b_hu)        (update gate, often "z")
//! n  = tanh(W_in x + b_in + r ⊙ (W_hn h + b_hn))
//! h' = (1 − u) ⊙ n + u ⊙ h
//! ```

use super::activation::sigmoid;
use super::linear::Linear;
use super::params::ParamBuilder;

/// A single GRU cell over the flat parameter vector.
#[derive(Clone, Copy, Debug)]
pub struct GruCell {
    pub in_dim: usize,
    pub hidden: usize,
    w_ir: Linear,
    w_iu: Linear,
    w_in: Linear,
    w_hr: Linear,
    w_hu: Linear,
    w_hn: Linear,
}

/// Per-step cache for the VJP. One per unrolled timestep.
#[derive(Clone, Debug, Default)]
pub struct GruStepCache {
    pub x: Vec<f64>,
    pub h: Vec<f64>,
    r: Vec<f64>,
    u: Vec<f64>,
    n: Vec<f64>,
    hn_lin: Vec<f64>,
}

impl GruCell {
    pub fn new(pb: &mut ParamBuilder, in_dim: usize, hidden: usize) -> Self {
        GruCell {
            in_dim,
            hidden,
            w_ir: Linear::new(pb, in_dim, hidden),
            w_iu: Linear::new(pb, in_dim, hidden),
            w_in: Linear::new(pb, in_dim, hidden),
            w_hr: Linear::new(pb, hidden, hidden),
            w_hu: Linear::new(pb, hidden, hidden),
            w_hn: Linear::new(pb, hidden, hidden),
        }
    }

    /// One step: `h_next = GRU(x, h)`. Fills `cache` for the VJP.
    pub fn forward(
        &self,
        params: &[f64],
        x: &[f64],
        h: &[f64],
        cache: &mut GruStepCache,
        h_next: &mut [f64],
    ) {
        let hd = self.hidden;
        cache.x = x.to_vec();
        cache.h = h.to_vec();
        cache.r.resize(hd, 0.0);
        cache.u.resize(hd, 0.0);
        cache.n.resize(hd, 0.0);
        cache.hn_lin.resize(hd, 0.0);

        let mut tmp_i = vec![0.0; hd];
        let mut tmp_h = vec![0.0; hd];
        // r gate
        self.w_ir.forward(params, x, &mut tmp_i);
        self.w_hr.forward(params, h, &mut tmp_h);
        for i in 0..hd {
            cache.r[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // u gate
        self.w_iu.forward(params, x, &mut tmp_i);
        self.w_hu.forward(params, h, &mut tmp_h);
        for i in 0..hd {
            cache.u[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // candidate
        self.w_in.forward(params, x, &mut tmp_i);
        self.w_hn.forward(params, h, &mut cache.hn_lin);
        for i in 0..hd {
            cache.n[i] = (tmp_i[i] + cache.r[i] * cache.hn_lin[i]).tanh();
        }
        for i in 0..hd {
            h_next[i] = (1.0 - cache.u[i]) * cache.n[i] + cache.u[i] * h[i];
        }
    }

    /// Accumulating VJP of one step: given `dh_next`, adds into `dx`, `dh`
    /// (gradient w.r.t. the *incoming* hidden state) and `dparams`.
    pub fn vjp(
        &self,
        params: &[f64],
        cache: &GruStepCache,
        dh_next: &[f64],
        dx: &mut [f64],
        dh: &mut [f64],
        dparams: &mut [f64],
    ) {
        let hd = self.hidden;
        let mut du = vec![0.0; hd];
        let mut dn = vec![0.0; hd];
        let mut dr = vec![0.0; hd];
        let mut dn_pre = vec![0.0; hd];
        let mut dhn_lin = vec![0.0; hd];
        let mut du_pre = vec![0.0; hd];
        let mut dr_pre = vec![0.0; hd];

        for i in 0..hd {
            du[i] = dh_next[i] * (cache.h[i] - cache.n[i]);
            dn[i] = dh_next[i] * (1.0 - cache.u[i]);
            dh[i] += dh_next[i] * cache.u[i];
        }
        for i in 0..hd {
            dn_pre[i] = dn[i] * (1.0 - cache.n[i] * cache.n[i]);
            dr[i] = dn_pre[i] * cache.hn_lin[i];
            dhn_lin[i] = dn_pre[i] * cache.r[i];
            du_pre[i] = du[i] * cache.u[i] * (1.0 - cache.u[i]);
            dr_pre[i] = dr[i] * cache.r[i] * (1.0 - cache.r[i]);
        }
        // Input-side linears.
        self.w_in.vjp(params, &cache.x, &dn_pre, dx, dparams);
        self.w_iu.vjp(params, &cache.x, &du_pre, dx, dparams);
        self.w_ir.vjp(params, &cache.x, &dr_pre, dx, dparams);
        // Hidden-side linears.
        self.w_hn.vjp(params, &cache.h, &dhn_lin, dh, dparams);
        self.w_hu.vjp(params, &cache.h, &du_pre, dh, dparams);
        self.w_hr.vjp(params, &cache.h, &dr_pre, dh, dparams);
    }

    pub fn param_count(&self) -> usize {
        [self.w_ir, self.w_iu, self.w_in, self.w_hr, self.w_hu, self.w_hn]
            .iter()
            .map(|l| l.param_count())
            .sum()
    }

    /// Allocate a batched step cache for B rows.
    pub fn batch_cache(&self, batch: usize) -> GruBatchCache {
        assert!(batch > 0, "GruCell::batch_cache: empty batch");
        let n = batch * self.hidden;
        GruBatchCache {
            x: vec![0.0; batch * self.in_dim],
            h: vec![0.0; n],
            r: vec![0.0; n],
            u: vec![0.0; n],
            n: vec![0.0; n],
            hn_lin: vec![0.0; n],
            tmp_i: vec![0.0; n],
            tmp_h: vec![0.0; n],
            batch,
        }
    }

    /// Batched step over B rows (`x: [B×in]`, `h: [B×hd]`,
    /// `h_next: [B×hd]`): each of the six gate linears becomes one blocked
    /// [`Linear::forward_batch`] pass with the weight rows hot across all
    /// B rows, followed by elementwise gate math. Per row, bit-identical
    /// to [`GruCell::forward`] (same per-cell accumulation and gate
    /// expressions in the same order).
    pub fn forward_batch(
        &self,
        params: &[f64],
        x: &[f64],
        h: &[f64],
        cache: &mut GruBatchCache,
        h_next: &mut [f64],
    ) {
        let n = cache.batch * self.hidden;
        debug_assert_eq!(x.len(), cache.batch * self.in_dim);
        debug_assert_eq!(h.len(), n);
        debug_assert_eq!(h_next.len(), n);
        cache.x.copy_from_slice(x);
        cache.h.copy_from_slice(h);

        let GruBatchCache { r, u, n: cand, hn_lin, tmp_i, tmp_h, .. } = cache;
        // r gate
        self.w_ir.forward_batch(params, x, tmp_i);
        self.w_hr.forward_batch(params, h, tmp_h);
        for i in 0..n {
            r[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // u gate
        self.w_iu.forward_batch(params, x, tmp_i);
        self.w_hu.forward_batch(params, h, tmp_h);
        for i in 0..n {
            u[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // candidate
        self.w_in.forward_batch(params, x, tmp_i);
        self.w_hn.forward_batch(params, h, hn_lin);
        for i in 0..n {
            cand[i] = (tmp_i[i] + r[i] * hn_lin[i]).tanh();
        }
        for i in 0..n {
            h_next[i] = (1.0 - u[i]) * cand[i] + u[i] * h[i];
        }
    }

    /// Fast-tier batched step: the six gate linears run through
    /// [`Linear::forward_batch_fast`] (unrolled multi-accumulator dot
    /// products, so the per-cell sums are reassociated); the elementwise
    /// gate math is identical to [`GruCell::forward_batch`]. Matches the
    /// exact kernel to relative tolerance, not bit-for-bit.
    pub fn forward_batch_fast(
        &self,
        params: &[f64],
        x: &[f64],
        h: &[f64],
        cache: &mut GruBatchCache,
        h_next: &mut [f64],
    ) {
        let n = cache.batch * self.hidden;
        debug_assert_eq!(x.len(), cache.batch * self.in_dim);
        debug_assert_eq!(h.len(), n);
        debug_assert_eq!(h_next.len(), n);
        cache.x.copy_from_slice(x);
        cache.h.copy_from_slice(h);

        let GruBatchCache { r, u, n: cand, hn_lin, tmp_i, tmp_h, .. } = cache;
        // r gate
        self.w_ir.forward_batch_fast(params, x, tmp_i);
        self.w_hr.forward_batch_fast(params, h, tmp_h);
        for i in 0..n {
            r[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // u gate
        self.w_iu.forward_batch_fast(params, x, tmp_i);
        self.w_hu.forward_batch_fast(params, h, tmp_h);
        for i in 0..n {
            u[i] = sigmoid(tmp_i[i] + tmp_h[i]);
        }
        // candidate
        self.w_in.forward_batch_fast(params, x, tmp_i);
        self.w_hn.forward_batch_fast(params, h, hn_lin);
        for i in 0..n {
            cand[i] = (tmp_i[i] + r[i] * hn_lin[i]).tanh();
        }
        for i in 0..n {
            h_next[i] = (1.0 - u[i]) * cand[i] + u[i] * h[i];
        }
    }

    /// Batched accumulating VJP of one step: given `dh_next: [B×hd]`, adds
    /// into `dx: [B×in]`, `dh: [B×hd]` (gradient w.r.t. the *incoming*
    /// hidden state) and each row's parameter-gradient block
    /// `dparams[b*pstride ..]` (scalar offsets within a block). Per row,
    /// bit-identical to [`GruCell::vjp`].
    #[allow(clippy::too_many_arguments)]
    pub fn vjp_batch(
        &self,
        params: &[f64],
        cache: &GruBatchCache,
        dh_next: &[f64],
        dx: &mut [f64],
        dh: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let n = cache.batch * self.hidden;
        debug_assert_eq!(dh_next.len(), n);
        debug_assert_eq!(dh.len(), n);
        debug_assert_eq!(dx.len(), cache.batch * self.in_dim);
        debug_assert_eq!(dparams.len(), cache.batch * pstride);
        let mut du = vec![0.0; n];
        let mut dn = vec![0.0; n];
        let mut dr = vec![0.0; n];
        let mut dn_pre = vec![0.0; n];
        let mut dhn_lin = vec![0.0; n];
        let mut du_pre = vec![0.0; n];
        let mut dr_pre = vec![0.0; n];

        for i in 0..n {
            du[i] = dh_next[i] * (cache.h[i] - cache.n[i]);
            dn[i] = dh_next[i] * (1.0 - cache.u[i]);
            dh[i] += dh_next[i] * cache.u[i];
        }
        for i in 0..n {
            dn_pre[i] = dn[i] * (1.0 - cache.n[i] * cache.n[i]);
            dr[i] = dn_pre[i] * cache.hn_lin[i];
            dhn_lin[i] = dn_pre[i] * cache.r[i];
            du_pre[i] = du[i] * cache.u[i] * (1.0 - cache.u[i]);
            dr_pre[i] = dr[i] * cache.r[i] * (1.0 - cache.r[i]);
        }
        // Input-side linears.
        self.w_in.vjp_batch(params, &cache.x, &dn_pre, dx, dparams, pstride);
        self.w_iu.vjp_batch(params, &cache.x, &du_pre, dx, dparams, pstride);
        self.w_ir.vjp_batch(params, &cache.x, &dr_pre, dx, dparams, pstride);
        // Hidden-side linears.
        self.w_hn.vjp_batch(params, &cache.h, &dhn_lin, dh, dparams, pstride);
        self.w_hu.vjp_batch(params, &cache.h, &du_pre, dh, dparams, pstride);
        self.w_hr.vjp_batch(params, &cache.h, &dr_pre, dh, dparams, pstride);
    }

    /// Fast-tier batched VJP: identical gate backward math, but the six
    /// gate-linear VJPs run through [`Linear::vjp_batch_fast`] (branchless
    /// split dx/dW sweeps). Pairs with [`GruCell::forward_batch_fast`]:
    /// the cache must come from the same tier's forward pass.
    #[allow(clippy::too_many_arguments)]
    pub fn vjp_batch_fast(
        &self,
        params: &[f64],
        cache: &GruBatchCache,
        dh_next: &[f64],
        dx: &mut [f64],
        dh: &mut [f64],
        dparams: &mut [f64],
        pstride: usize,
    ) {
        let n = cache.batch * self.hidden;
        debug_assert_eq!(dh_next.len(), n);
        debug_assert_eq!(dh.len(), n);
        debug_assert_eq!(dx.len(), cache.batch * self.in_dim);
        debug_assert_eq!(dparams.len(), cache.batch * pstride);
        let mut du = vec![0.0; n];
        let mut dn = vec![0.0; n];
        let mut dr = vec![0.0; n];
        let mut dn_pre = vec![0.0; n];
        let mut dhn_lin = vec![0.0; n];
        let mut du_pre = vec![0.0; n];
        let mut dr_pre = vec![0.0; n];

        for i in 0..n {
            du[i] = dh_next[i] * (cache.h[i] - cache.n[i]);
            dn[i] = dh_next[i] * (1.0 - cache.u[i]);
            dh[i] += dh_next[i] * cache.u[i];
        }
        for i in 0..n {
            dn_pre[i] = dn[i] * (1.0 - cache.n[i] * cache.n[i]);
            dr[i] = dn_pre[i] * cache.hn_lin[i];
            dhn_lin[i] = dn_pre[i] * cache.r[i];
            du_pre[i] = du[i] * cache.u[i] * (1.0 - cache.u[i]);
            dr_pre[i] = dr[i] * cache.r[i] * (1.0 - cache.r[i]);
        }
        // Input-side linears.
        self.w_in.vjp_batch_fast(params, &cache.x, &dn_pre, dx, dparams, pstride);
        self.w_iu.vjp_batch_fast(params, &cache.x, &du_pre, dx, dparams, pstride);
        self.w_ir.vjp_batch_fast(params, &cache.x, &dr_pre, dx, dparams, pstride);
        // Hidden-side linears.
        self.w_hn.vjp_batch_fast(params, &cache.h, &dhn_lin, dh, dparams, pstride);
        self.w_hu.vjp_batch_fast(params, &cache.h, &du_pre, dh, dparams, pstride);
        self.w_hr.vjp_batch_fast(params, &cache.h, &dr_pre, dh, dparams, pstride);
    }
}

/// Batched per-step cache: `[B×·]` rows of everything [`GruStepCache`]
/// stores, plus the gate-linear staging buffers — the batch analogue of
/// one unrolled timestep, allocated once per step (or reused).
#[derive(Clone, Debug)]
pub struct GruBatchCache {
    /// Step input rows `[B×in]`.
    pub x: Vec<f64>,
    /// Incoming hidden rows `[B×hd]`.
    pub h: Vec<f64>,
    r: Vec<f64>,
    u: Vec<f64>,
    n: Vec<f64>,
    hn_lin: Vec<f64>,
    tmp_i: Vec<f64>,
    tmp_h: Vec<f64>,
    batch: usize,
}

impl GruBatchCache {
    /// Batch size B this cache was allocated for.
    pub fn batch(&self) -> usize {
        self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::PrngKey;

    #[test]
    fn single_step_vjp_matches_finite_difference() {
        let (in_dim, hd) = (3, 5);
        let mut pb = ParamBuilder::new();
        let cell = GruCell::new(&mut pb, in_dim, hd);
        let params = pb.init(PrngKey::from_seed(30));
        let key = PrngKey::from_seed(31);
        let mut x = vec![0.0; in_dim];
        key.fill_normal(0, &mut x);
        let mut h = vec![0.0; hd];
        key.fill_normal(10, &mut h);
        let mut dy = vec![0.0; hd];
        key.fill_normal(20, &mut dy);

        let mut cache = GruStepCache::default();
        let mut h_next = vec![0.0; hd];
        cell.forward(&params, &x, &h, &mut cache, &mut h_next);
        let mut dx = vec![0.0; in_dim];
        let mut dh = vec![0.0; hd];
        let mut dp = vec![0.0; params.len()];
        cell.vjp(&params, &cache, &dy, &mut dx, &mut dh, &mut dp);

        let loss = |p: &[f64], x: &[f64], h: &[f64]| -> f64 {
            let mut c = GruStepCache::default();
            let mut hn = vec![0.0; hd];
            cell.forward(p, x, h, &mut c, &mut hn);
            hn.iter().zip(&dy).map(|(a, b)| a * b).sum()
        };
        let eps = 1e-6;
        for i in 0..in_dim {
            let mut xp = x.clone();
            xp[i] += eps;
            let hi = loss(&params, &xp, &h);
            xp[i] -= 2.0 * eps;
            let lo = loss(&params, &xp, &h);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - dx[i]).abs() < 1e-7, "dx[{i}]: fd {fd} vs {}", dx[i]);
        }
        for i in 0..hd {
            let mut hp = h.clone();
            hp[i] += eps;
            let hi = loss(&params, &x, &hp);
            hp[i] -= 2.0 * eps;
            let lo = loss(&params, &x, &hp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - dh[i]).abs() < 1e-7, "dh[{i}]: fd {fd} vs {}", dh[i]);
        }
        for j in (0..params.len()).step_by(11) {
            let mut pp = params.clone();
            pp[j] += eps;
            let hi = loss(&pp, &x, &h);
            pp[j] -= 2.0 * eps;
            let lo = loss(&pp, &x, &h);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - dp[j]).abs() < 1e-7, "dp[{j}]: fd {fd} vs {}", dp[j]);
        }
    }

    #[test]
    fn bptt_over_sequence_matches_finite_difference() {
        // Unroll 4 steps, loss = Σ final hidden; check dparams via BPTT.
        let (in_dim, hd, t_len) = (2, 4, 4);
        let mut pb = ParamBuilder::new();
        let cell = GruCell::new(&mut pb, in_dim, hd);
        let params = pb.init(PrngKey::from_seed(40));
        let key = PrngKey::from_seed(41);
        let mut xs = vec![0.0; in_dim * t_len];
        key.fill_normal(0, &mut xs);

        let run = |p: &[f64]| -> (f64, Vec<GruStepCache>) {
            let mut h = vec![0.0; hd];
            let mut caches = Vec::new();
            for t in 0..t_len {
                let mut c = GruStepCache::default();
                let mut hn = vec![0.0; hd];
                cell.forward(p, &xs[t * in_dim..(t + 1) * in_dim], &h, &mut c, &mut hn);
                caches.push(c);
                h = hn;
            }
            (h.iter().sum(), caches)
        };

        let (_, caches) = run(&params);
        // BPTT.
        let mut dh = vec![1.0; hd];
        let mut dp = vec![0.0; params.len()];
        let mut dx = vec![0.0; in_dim];
        for t in (0..t_len).rev() {
            let mut dh_prev = vec![0.0; hd];
            dx.fill(0.0);
            cell.vjp(&params, &caches[t], &dh, &mut dx, &mut dh_prev, &mut dp);
            dh = dh_prev;
        }
        let eps = 1e-6;
        for j in (0..params.len()).step_by(13) {
            let mut pp = params.clone();
            pp[j] += eps;
            let (hi, _) = run(&pp);
            pp[j] -= 2.0 * eps;
            let (lo, _) = run(&pp);
            let fd = (hi - lo) / (2.0 * eps);
            assert!((fd - dp[j]).abs() < 1e-6, "dp[{j}]: fd {fd} vs {}", dp[j]);
        }
    }

    /// Batched step + VJP must equal B scalar passes bit-for-bit — the
    /// guarantee that lets the batched latent-SDE trainer's encoder ride
    /// the batch engine without changing any float.
    #[test]
    fn batched_forward_and_vjp_match_scalar_rows_exactly() {
        let (in_dim, hd, bsz) = (3, 6, 5);
        let mut pb = ParamBuilder::new();
        let cell = GruCell::new(&mut pb, in_dim, hd);
        let params = pb.init(PrngKey::from_seed(50));
        let key = PrngKey::from_seed(51);
        let mut x = vec![0.0; bsz * in_dim];
        key.fill_normal(0, &mut x);
        let mut h = vec![0.0; bsz * hd];
        key.fill_normal(100, &mut h);
        let mut dy = vec![0.0; bsz * hd];
        key.fill_normal(200, &mut dy);

        let mut bcache = cell.batch_cache(bsz);
        let mut hn_b = vec![0.0; bsz * hd];
        cell.forward_batch(&params, &x, &h, &mut bcache, &mut hn_b);
        let mut dx_b = vec![0.0; bsz * in_dim];
        let mut dh_b = vec![0.0; bsz * hd];
        let mut dp_b = vec![0.0; bsz * params.len()];
        cell.vjp_batch(&params, &bcache, &dy, &mut dx_b, &mut dh_b, &mut dp_b, params.len());

        for b in 0..bsz {
            let mut cache = GruStepCache::default();
            let mut hn = vec![0.0; hd];
            cell.forward(
                &params,
                &x[b * in_dim..(b + 1) * in_dim],
                &h[b * hd..(b + 1) * hd],
                &mut cache,
                &mut hn,
            );
            assert_eq!(&hn_b[b * hd..(b + 1) * hd], &hn[..], "fwd row {b}");
            let mut dx = vec![0.0; in_dim];
            let mut dh = vec![0.0; hd];
            let mut dp = vec![0.0; params.len()];
            cell.vjp(&params, &cache, &dy[b * hd..(b + 1) * hd], &mut dx, &mut dh, &mut dp);
            assert_eq!(&dx_b[b * in_dim..(b + 1) * in_dim], &dx[..], "dx row {b}");
            assert_eq!(&dh_b[b * hd..(b + 1) * hd], &dh[..], "dh row {b}");
            assert_eq!(
                &dp_b[b * params.len()..(b + 1) * params.len()],
                &dp[..],
                "dparams row {b}"
            );
        }
    }

    /// The fast-tier step and VJP reassociate the gate-linear dot
    /// products, so they are not bit-identical — but they must agree with
    /// the exact batched kernels to tight relative tolerance.
    #[test]
    fn fast_batched_kernels_match_exact_to_tolerance() {
        let (in_dim, hd, bsz) = (5, 7, 6);
        let mut pb = ParamBuilder::new();
        let cell = GruCell::new(&mut pb, in_dim, hd);
        let params = pb.init(PrngKey::from_seed(60));
        let key = PrngKey::from_seed(61);
        let mut x = vec![0.0; bsz * in_dim];
        key.fill_normal(0, &mut x);
        let mut h = vec![0.0; bsz * hd];
        key.fill_normal(100, &mut h);
        let mut dy = vec![0.0; bsz * hd];
        key.fill_normal(200, &mut dy);

        let mut exact_cache = cell.batch_cache(bsz);
        let mut hn_exact = vec![0.0; bsz * hd];
        cell.forward_batch(&params, &x, &h, &mut exact_cache, &mut hn_exact);
        let mut dx_exact = vec![0.0; bsz * in_dim];
        let mut dh_exact = vec![0.0; bsz * hd];
        let mut dp_exact = vec![0.0; bsz * params.len()];
        cell.vjp_batch(
            &params,
            &exact_cache,
            &dy,
            &mut dx_exact,
            &mut dh_exact,
            &mut dp_exact,
            params.len(),
        );

        let mut fast_cache = cell.batch_cache(bsz);
        let mut hn_fast = vec![0.0; bsz * hd];
        cell.forward_batch_fast(&params, &x, &h, &mut fast_cache, &mut hn_fast);
        let mut dx_fast = vec![0.0; bsz * in_dim];
        let mut dh_fast = vec![0.0; bsz * hd];
        let mut dp_fast = vec![0.0; bsz * params.len()];
        cell.vjp_batch_fast(
            &params,
            &fast_cache,
            &dy,
            &mut dx_fast,
            &mut dh_fast,
            &mut dp_fast,
            params.len(),
        );

        let close = |a: &[f64], b: &[f64], what: &str| {
            for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
                let scale = x.abs().max(y.abs()).max(1.0);
                assert!((x - y).abs() <= 1e-12 * scale, "{what}[{i}]: {x} vs {y}");
            }
        };
        close(&hn_exact, &hn_fast, "h_next");
        close(&dx_exact, &dx_fast, "dx");
        close(&dh_exact, &dh_fast, "dh");
        close(&dp_exact, &dp_fast, "dparams");
    }
}
