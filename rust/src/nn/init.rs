//! Re-exports of initializer specs (kept as a separate module path so model
//! code reads `nn::init::Init::Uniform { .. }`).

pub use super::params::Init;
