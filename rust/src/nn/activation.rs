//! Pointwise activations with derivatives.
//!
//! The paper's architectures use softplus for all drift/decoder
//! nonlinearities (App. 9.9), tanh inside the GRU, and sigmoid at the
//! diffusion output to keep σ bounded and positive.

/// Pointwise activation functions.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Activation {
    Identity,
    Tanh,
    Sigmoid,
    Softplus,
    /// ReLU — not used by the paper's models but handy for ablations.
    Relu,
}

impl Activation {
    /// y = f(x).
    #[inline]
    pub fn apply(&self, x: f64) -> f64 {
        match self {
            Activation::Identity => x,
            Activation::Tanh => x.tanh(),
            Activation::Sigmoid => sigmoid(x),
            Activation::Softplus => softplus(x),
            Activation::Relu => x.max(0.0),
        }
    }

    /// f'(x) expressed via (x, y=f(x)) — using y where cheaper.
    #[inline]
    pub fn grad(&self, x: f64, y: f64) -> f64 {
        match self {
            Activation::Identity => 1.0,
            Activation::Tanh => 1.0 - y * y,
            Activation::Sigmoid => y * (1.0 - y),
            Activation::Softplus => sigmoid(x),
            Activation::Relu => {
                if x > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }

    /// Apply in place over a slice.
    pub fn apply_slice(&self, xs: &mut [f64]) {
        for v in xs.iter_mut() {
            *v = self.apply(*v);
        }
    }
}

/// Numerically-stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable softplus log(1 + e^x).
#[inline]
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradients_match_finite_difference() {
        let eps = 1e-6;
        for act in [
            Activation::Identity,
            Activation::Tanh,
            Activation::Sigmoid,
            Activation::Softplus,
            Activation::Relu,
        ] {
            for &x in &[-3.0f64, -0.7, 0.4, 2.5, 10.0] {
                if act == Activation::Relu && x.abs() < eps {
                    continue;
                }
                let y = act.apply(x);
                let fd = (act.apply(x + eps) - act.apply(x - eps)) / (2.0 * eps);
                let g = act.grad(x, y);
                assert!((fd - g).abs() < 1e-6, "{act:?} at {x}: fd {fd} vs {g}");
            }
        }
    }

    #[test]
    fn stability_at_extremes() {
        assert!(sigmoid(800.0) == 1.0);
        assert!(sigmoid(-800.0) == 0.0);
        assert!(softplus(800.0) == 800.0);
        assert!(softplus(-800.0) >= 0.0);
        assert!(softplus(-800.0) < 1e-300);
    }

    #[test]
    fn softplus_positive() {
        for &x in &[-50.0, -1.0, 0.0, 1.0, 50.0] {
            assert!(softplus(x) > 0.0);
        }
    }
}
