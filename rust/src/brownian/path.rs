//! Stored-query Brownian path ("BrownianPath" in torchsde).
//!
//! Every queried value is cached; a new query time `t` is answered by
//! * extension: if `t` lies outside the currently revealed range, draw the
//!   free increment `N(0, Δt·I)` from the sequential stream, or
//! * interpolation: if `t` falls between two revealed times, sample the
//!   Brownian bridge conditioned on the nearest revealed neighbours.
//!
//! Consistency (same `t` → same value) holds because results are cached;
//! the conditional laws are correct because Brownian motion is Markov, so
//! conditioning on the nearest revealed neighbours equals conditioning on
//! the full revealed set.
//!
//! Memory is O(#distinct queries); this is the paper's "store the noise"
//! baseline in Table 1 and the implementation its experiments use.
//!
//! Performance (EXPERIMENTS.md §Perf): values live in a flat arena
//! (`Vec<f64>`, one slot of `dim` per revealed time) indexed by a
//! `BTreeMap<time, slot>`, so queries never allocate per-point vectors;
//! monotone forward/backward sweeps — the solver access pattern — hit
//! dedicated fast paths that skip the tree search entirely when the
//! queried time matches the last or first revealed time.

use std::collections::BTreeMap;

use super::bridge::bridge_moments;
use super::traits::BrownianMotion;
use crate::prng::{NormalSampler, PrngKey};

/// Total-order wrapper so times can key a BTreeMap.
#[derive(Clone, Copy, PartialEq, Debug)]
struct T(f64);
impl Eq for T {}
impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for T {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// A d-dimensional Brownian path that materializes queried points lazily.
#[derive(Clone, Debug)]
pub struct BrownianPath {
    dim: usize,
    t0: f64,
    t1: f64,
    /// time → arena slot index.
    index: BTreeMap<T, usize>,
    /// Flat value arena; slot i occupies `[i*dim, (i+1)*dim)`.
    arena: Vec<f64>,
    /// Highest / lowest revealed times (fast-path bookkeeping).
    t_max: f64,
    slot_max: usize,
    t_min: f64,
    slot_min: usize,
    sampler: NormalSampler,
    scratch: Vec<f64>,
}

impl BrownianPath {
    /// A path with `W(t0) = 0`, defined (extensibly) on `[t0, t1]`.
    pub fn new(key: PrngKey, dim: usize, t0: f64, t1: f64) -> Self {
        assert!(t1 > t0, "BrownianPath: need t1 > t0 (got [{t0}, {t1}])");
        assert!(dim > 0, "BrownianPath: dim must be positive");
        let mut index = BTreeMap::new();
        index.insert(T(t0), 0);
        BrownianPath {
            dim,
            t0,
            t1,
            index,
            arena: vec![0.0; dim],
            t_max: t0,
            slot_max: 0,
            t_min: t0,
            slot_min: 0,
            sampler: NormalSampler::new(key),
            scratch: vec![0.0; dim],
        }
    }

    /// Number of cached points (Table 1 memory metric).
    pub fn cached_points(&self) -> usize {
        self.index.len()
    }

    #[inline]
    fn slot(&self, i: usize) -> &[f64] {
        &self.arena[i * self.dim..(i + 1) * self.dim]
    }

    /// Reveal `t` (if new) and return its arena slot.
    fn query(&mut self, t: f64) -> usize {
        let d = self.dim;
        // Fast paths: the solver sweeps monotonically, so most queries are
        // at (or beyond) the extremes.
        if t == self.t_max {
            return self.slot_max;
        }
        if t == self.t_min {
            return self.slot_min;
        }
        if t > self.t_max {
            // Extend right: free increment from W(t_max).
            let std = (t - self.t_max).sqrt();
            self.sampler.fill(&mut self.scratch);
            let base = self.slot_max * d;
            let new_slot = self.arena.len() / d;
            for i in 0..d {
                let v = self.arena[base + i] + std * self.scratch[i];
                self.arena.push(v);
            }
            self.index.insert(T(t), new_slot);
            self.t_max = t;
            self.slot_max = new_slot;
            return new_slot;
        }
        if t < self.t_min {
            // Extend left: W(t) = W(t_min) − √(t_min−t)·z.
            let std = (self.t_min - t).sqrt();
            self.sampler.fill(&mut self.scratch);
            let base = self.slot_min * d;
            let new_slot = self.arena.len() / d;
            for i in 0..d {
                let v = self.arena[base + i] - std * self.scratch[i];
                self.arena.push(v);
            }
            self.index.insert(T(t), new_slot);
            self.t_min = t;
            self.slot_min = new_slot;
            return new_slot;
        }
        // Interior: exact hit or bridge interpolation between neighbours.
        if let Some(&slot) = self.index.get(&T(t)) {
            return slot;
        }
        let (ts, lo_slot) = {
            let (k, &v) = self.index.range(..T(t)).next_back().expect("t_min handled above");
            (k.0, v)
        };
        let (te, hi_slot) = {
            let (k, &v) = self.index.range(T(t)..).next().expect("t_max handled above");
            (k.0, v)
        };
        let (wa, wb, std) = bridge_moments(ts, te, t);
        self.sampler.fill(&mut self.scratch);
        let new_slot = self.arena.len() / d;
        let lo = lo_slot * d;
        let hi = hi_slot * d;
        for i in 0..d {
            let v = wa * self.arena[lo + i] + wb * self.arena[hi + i] + std * self.scratch[i];
            self.arena.push(v);
        }
        self.index.insert(T(t), new_slot);
        new_slot
    }
}

impl BrownianMotion for BrownianPath {
    fn dim(&self) -> usize {
        self.dim
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        let slot = self.query(t);
        out.copy_from_slice(self.slot(slot));
    }

    fn memory_footprint(&self) -> usize {
        self.arena.len() + self.index.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::PrngKey;

    fn path(seed: u64, d: usize) -> BrownianPath {
        BrownianPath::new(PrngKey::from_seed(seed), d, 0.0, 1.0)
    }

    #[test]
    fn repeated_queries_identical() {
        let mut p = path(1, 3);
        let a = p.sample(0.37);
        let b = p.sample(0.37);
        assert_eq!(a, b);
    }

    #[test]
    fn starts_at_zero() {
        let mut p = path(2, 4);
        assert_eq!(p.sample(0.0), vec![0.0; 4]);
    }

    #[test]
    fn interpolation_between_cached_points_is_consistent() {
        let mut p = path(3, 1);
        let w_half = p.sample(0.5)[0];
        let w_quarter = p.sample(0.25)[0];
        // Re-query both; cache must return same values.
        assert_eq!(p.sample(0.5)[0], w_half);
        assert_eq!(p.sample(0.25)[0], w_quarter);
        assert_eq!(p.cached_points(), 3); // t0, 0.5, 0.25
    }

    #[test]
    fn monotone_fast_paths_are_consistent_with_interior_queries() {
        // Reveal a grid forward, then re-query in descending order and at
        // midpoints — everything must match the first reveal.
        let mut p = path(4, 2);
        let grid: Vec<f64> = (0..=20).map(|k| k as f64 / 20.0).collect();
        let fwd: Vec<Vec<f64>> = grid.iter().map(|&t| p.sample(t)).collect();
        for (k, &t) in grid.iter().enumerate().rev() {
            assert_eq!(p.sample(t), fwd[k], "mismatch at t={t}");
        }
    }

    #[test]
    fn left_extension_law() {
        // Build a path revealed from 0.5 upward, then query 0.2 (left
        // extension): increments must still have the right variance.
        let n = 30_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for seed in 0..n {
            let mut p = BrownianPath::new(PrngKey::from_seed(seed), 1, 0.0, 1.0);
            // Move the interior pointer to 0.5 first.
            let w_half = p.sample(0.5)[0];
            let w_02 = p.sample(0.2)[0];
            let inc = w_half - w_02;
            sum += inc;
            sumsq += inc * inc;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 0.3).abs() < 0.012, "var {var}");
    }

    #[test]
    fn increments_have_correct_moments() {
        // W(0.6) − W(0.2) over many independent paths ~ N(0, 0.4).
        let n = 40_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for seed in 0..n {
            let mut p = path(seed, 1);
            let inc = p.increment(0.2, 0.6)[0];
            sum += inc;
            sumsq += inc * inc;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 0.4).abs() < 0.01, "var {var}");
    }

    #[test]
    fn nonoverlapping_increments_uncorrelated() {
        let n = 40_000;
        let mut dot = 0.0;
        for seed in 0..n {
            let mut p = path(seed + 1_000_000, 1);
            let a = p.increment(0.0, 0.3)[0];
            let b = p.increment(0.3, 0.9)[0];
            dot += a * b;
        }
        let corr = dot / n as f64;
        assert!(corr.abs() < 0.01, "corr {corr}");
    }

    #[test]
    fn query_order_does_not_change_law() {
        // Variance at 0.5 must be 0.5 whether revealed directly or after
        // finer queries. (Statistical check across seeds.)
        let n = 40_000;
        let mut var_direct = 0.0;
        let mut var_nested = 0.0;
        for seed in 0..n {
            let mut p1 = path(seed + 5_000_000, 1);
            var_direct += p1.sample(0.5)[0].powi(2);
            let mut p2 = path(seed + 9_000_000, 1);
            p2.sample(1.0);
            p2.sample(0.75);
            var_nested += p2.sample(0.5)[0].powi(2);
        }
        var_direct /= n as f64;
        var_nested /= n as f64;
        assert!((var_direct - 0.5).abs() < 0.015, "direct {var_direct}");
        assert!((var_nested - 0.5).abs() < 0.015, "nested {var_nested}");
    }

    #[test]
    fn memory_grows_with_queries() {
        let mut p = path(8, 2);
        let base = p.memory_footprint();
        for i in 1..=50 {
            p.sample(i as f64 / 64.0);
        }
        assert!(p.memory_footprint() >= base + 50 * 2);
    }
}
