//! The interface solvers use to consume noise.

/// A queryable d-dimensional Wiener process sample path on `[t0, t1]`.
///
/// Implementations must be *consistent*: repeated queries at the same time
/// return identical values, and conditioned on any set of previously
/// revealed points, newly revealed points follow the Brownian bridge law.
pub trait BrownianMotion {
    /// Dimensionality of the process.
    fn dim(&self) -> usize;

    /// Time interval on which the path is defined.
    fn span(&self) -> (f64, f64);

    /// Write `W(t)` into `out` (length `dim()`).
    fn sample_into(&mut self, t: f64, out: &mut [f64]);

    /// Convenience: `W(t)` as a fresh vector.
    fn sample(&mut self, t: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.sample_into(t, &mut out);
        out
    }

    /// Write the increment `W(t1) − W(t0)` into `out`.
    fn increment_into(&mut self, t0: f64, t1: f64, out: &mut [f64]) {
        debug_assert!(t0 <= t1, "increment_into: t0={t0} > t1={t1}");
        let d = self.dim();
        let mut a = vec![0.0; d];
        self.sample_into(t0, &mut a);
        self.sample_into(t1, out);
        for i in 0..d {
            out[i] -= a[i];
        }
    }

    /// Convenience: increment as a fresh vector.
    fn increment(&mut self, t0: f64, t1: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.increment_into(t0, t1, &mut out);
        out
    }

    /// Approximate number of f64 values held live by this source. Used by
    /// the Table 1 memory-complexity bench.
    fn memory_footprint(&self) -> usize;
}
