//! Pathwise Riemann quadrature against a realized Brownian path.
//!
//! The exact strong solution of a linear SDE with additive noise (e.g.
//! Ornstein–Uhlenbeck) involves stochastic integrals `∫ f(u) dW_u` with
//! smooth deterministic kernels `f`. Integrating by parts turns each into
//! an ordinary Riemann integral of the *path*,
//!
//! ```text
//! ∫_{t0}^{t1} f(u) dW_u = f(t1)·W̃(t1) − ∫_{t0}^{t1} f'(u)·W̃(u) du,
//! W̃(u) = W(u) − W(t0),
//! ```
//!
//! which [`weighted_path_integrals`] evaluates by composite trapezoid on a
//! fine uniform grid, querying the *same* [`BrownianMotion`] source that
//! drove a numerical solve. Both sources answer off-grid queries with the
//! correct Brownian-bridge law, so the quadrature stays consistent with
//! whatever the solver revealed; its error is `O(δ)` pathwise in the
//! quadrature step `δ` (the trapezoid residual on a Hölder-½ path), with a
//! constant far below any solver rung when `n_intervals` is a few thousand.
//!
//! This is the `brownian/`-side plumbing of the [`crate::convergence`]
//! oracles (see `sde::ou`'s [`crate::sde::ExactSolution`] implementation).

use super::traits::BrownianMotion;

/// Composite-trapezoid evaluation of `∫_{t0}^{t1} f_k(u) · W̃_i(u) du` for
/// every kernel `f_k` in `kernels` and every path dimension `i`, where
/// `W̃(u) = W(u) − W(t0)`.
///
/// `out` is row-major `kernels.len() × bm.dim()` and is overwritten. All
/// kernels share one sweep over the quadrature grid, so the path is
/// queried `n_intervals + 1` times regardless of how many kernels are
/// evaluated.
pub fn weighted_path_integrals(
    bm: &mut dyn BrownianMotion,
    t0: f64,
    t1: f64,
    n_intervals: usize,
    kernels: &[&dyn Fn(f64) -> f64],
    out: &mut [f64],
) {
    let d = bm.dim();
    assert!(n_intervals > 0, "weighted_path_integrals: need at least one interval");
    assert!(t1 > t0, "weighted_path_integrals: need t1 > t0 (got [{t0}, {t1}])");
    assert_eq!(
        out.len(),
        kernels.len() * d,
        "weighted_path_integrals: out must be kernels × dim"
    );
    out.fill(0.0);

    let h = (t1 - t0) / n_intervals as f64;
    let mut w0 = vec![0.0; d];
    let mut w = vec![0.0; d];
    bm.sample_into(t0, &mut w0);
    for j in 0..=n_intervals {
        // Same grid arithmetic as `solvers::uniform_grid`, so dyadic
        // quadrature points coincide bit-exactly with dyadic solver grids.
        let u = if j == n_intervals { t1 } else { t0 + h * j as f64 };
        bm.sample_into(u, &mut w);
        // Trapezoid weights: h/2 at the ends, h in the interior.
        let wt = if j == 0 || j == n_intervals { 0.5 * h } else { h };
        for (k, f) in kernels.iter().enumerate() {
            let c = wt * f(u);
            let row = &mut out[k * d..(k + 1) * d];
            for i in 0..d {
                row[i] += c * (w[i] - w0[i]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::{BrownianPath, VirtualBrownianTree};
    use crate::prng::PrngKey;

    #[test]
    fn zero_kernel_integrates_to_zero() {
        let mut bm = BrownianPath::new(PrngKey::from_seed(1), 2, 0.0, 1.0);
        let mut out = [1.0; 2];
        let zero = |_: f64| 0.0;
        let kernels: [&dyn Fn(f64) -> f64; 1] = [&zero];
        weighted_path_integrals(&mut bm, 0.0, 1.0, 64, &kernels, &mut out);
        assert_eq!(out, [0.0; 2]);
    }

    #[test]
    fn matches_manual_trapezoid_on_revealed_points() {
        // Reveal the quadrature grid first, then compare against a manual
        // trapezoid sum over the same cached values.
        let n = 32;
        let mut bm = BrownianPath::new(PrngKey::from_seed(2), 1, 0.0, 1.0);
        let grid: Vec<f64> = (0..=n).map(|j| j as f64 / n as f64).collect();
        let vals: Vec<f64> = grid.iter().map(|&t| bm.sample(t)[0]).collect();
        let f = |u: f64| (-0.7 * (1.0 - u)).exp();
        let h = 1.0 / n as f64;
        let mut manual = 0.0;
        for (j, (&t, &w)) in grid.iter().zip(&vals).enumerate() {
            let wt = if j == 0 || j == n { 0.5 * h } else { h };
            manual += wt * f(t) * w;
        }
        let mut out = [0.0];
        let kernels: [&dyn Fn(f64) -> f64; 1] = [&f];
        weighted_path_integrals(&mut bm, 0.0, 1.0, n, &kernels, &mut out);
        assert!((out[0] - manual).abs() < 1e-14, "quad {} vs manual {manual}", out[0]);
    }

    #[test]
    fn multiple_kernels_share_one_sweep() {
        // Evaluating [f, g] together must equal evaluating each alone on
        // the same (order-independent) source.
        let f = |u: f64| 1.0 - u;
        let g = |u: f64| (2.0 * u).cos();
        let both: [&dyn Fn(f64) -> f64; 2] = [&f, &g];
        let only_f: [&dyn Fn(f64) -> f64; 1] = [&f];
        let only_g: [&dyn Fn(f64) -> f64; 1] = [&g];
        let mk = || VirtualBrownianTree::new(PrngKey::from_seed(3), 2, 0.0, 1.0, 1e-10);
        let mut joint = [0.0; 4];
        weighted_path_integrals(&mut mk(), 0.0, 1.0, 128, &both, &mut joint);
        let mut alone_f = [0.0; 2];
        weighted_path_integrals(&mut mk(), 0.0, 1.0, 128, &only_f, &mut alone_f);
        let mut alone_g = [0.0; 2];
        weighted_path_integrals(&mut mk(), 0.0, 1.0, 128, &only_g, &mut alone_g);
        for i in 0..2 {
            assert_eq!(joint[i], alone_f[i]);
            assert_eq!(joint[2 + i], alone_g[i]);
        }
    }

    #[test]
    fn integral_of_brownian_path_has_correct_variance() {
        // ∫_0^1 W du ~ N(0, 1/3) — the classic check. Statistical over
        // independent seeds; quadrature bias is O(δ²) and negligible.
        let n_seeds = 4_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        let one = |_: f64| 1.0;
        let kernels: [&dyn Fn(f64) -> f64; 1] = [&one];
        for seed in 0..n_seeds {
            let mut bm = BrownianPath::new(PrngKey::from_seed(90_000 + seed), 1, 0.0, 1.0);
            let mut out = [0.0];
            weighted_path_integrals(&mut bm, 0.0, 1.0, 64, &kernels, &mut out);
            sum += out[0];
            sumsq += out[0] * out[0];
        }
        let mean = sum / n_seeds as f64;
        let var = sumsq / n_seeds as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0 / 3.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn refinement_converges_on_a_fixed_path() {
        // On one order-independent path (tree), doubling the quadrature
        // grid must converge: |I_{2n} − I_{4n}| ≤ |I_n − I_{2n}| + slack.
        let f = |u: f64| (-(1.0 - u)).exp();
        let eval = |n: usize| {
            let mut bm = VirtualBrownianTree::new(PrngKey::from_seed(5), 1, 0.0, 1.0, 1e-12);
            let mut out = [0.0];
            let kernels: [&dyn Fn(f64) -> f64; 1] = [&f];
            weighted_path_integrals(&mut bm, 0.0, 1.0, n, &kernels, &mut out);
            out[0]
        };
        let (a, b, c) = (eval(256), eval(512), eval(1024));
        assert!((b - c).abs() < (a - b).abs() + 1e-4, "not converging: {a} {b} {c}");
    }
}
