//! Batched Brownian sampling: B independent sample paths queried in one
//! call, writing into `[B×d]` row-major buffers.
//!
//! [`BatchBrownian`] wraps one [`BrownianMotion`] source **per path** —
//! each with its own key, cache, and (for [`super::BrownianPath`]) its
//! own sequential RNG stream — and sweeps them together. Per-path query
//! order is exactly the order a scalar solve would issue, so path `i`'s
//! noise is bit-identical to what the scalar engine realizes from the
//! same key (pinned by the property tests below and by
//! `tests/batch_engine.rs`).
//!
//! Two increment APIs, both allocation-free per call:
//! [`BatchBrownian::fill_increments`] answers one arbitrary `(t0, t1)`
//! pair per call, while the [`BatchBrownian::begin_sweep`] /
//! [`BatchBrownian::sweep_increments`] pair serves the solver hot loops —
//! a rolling previous-`W` buffer means each grid time is queried exactly
//! once per source, mirroring the scalar drivers' buffer swap (this
//! matters for the virtual tree, where every query is a bridge descent).

use super::traits::BrownianMotion;

/// B independent Brownian sources swept as one batch.
pub struct BatchBrownian<B: BrownianMotion> {
    sources: Vec<B>,
    dim: usize,
    scratch: Vec<f64>,
    /// Rolling previous-W values (`[B×d]`) for monotone grid sweeps — see
    /// [`BatchBrownian::begin_sweep`].
    wa: Vec<f64>,
}

impl<B: BrownianMotion> BatchBrownian<B> {
    /// Bundle per-path sources (all must share dimension and span).
    pub fn new(sources: Vec<B>) -> Self {
        assert!(!sources.is_empty(), "BatchBrownian: need at least one path");
        let dim = sources[0].dim();
        let span = sources[0].span();
        for s in &sources[1..] {
            assert_eq!(s.dim(), dim, "BatchBrownian: mixed dimensions");
            assert_eq!(s.span(), span, "BatchBrownian: mixed spans");
        }
        let n = sources.len() * dim;
        BatchBrownian { sources, dim, scratch: vec![0.0; dim], wa: vec![0.0; n] }
    }

    /// Per-path dimension d.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Number of paths B.
    pub fn batch(&self) -> usize {
        self.sources.len()
    }

    /// Common time span of all paths.
    pub fn span(&self) -> (f64, f64) {
        self.sources[0].span()
    }

    /// Write `W_b(t)` for every path into `out` (`[B×d]`).
    pub fn sample_all(&mut self, t: f64, out: &mut [f64]) {
        let d = self.dim;
        debug_assert_eq!(out.len(), self.sources.len() * d);
        for (src, row) in self.sources.iter_mut().zip(out.chunks_exact_mut(d)) {
            src.sample_into(t, row);
        }
    }

    /// Write the signed increments `W_b(t1) − W_b(t0)` for every path into
    /// `out` (`[B×d]`) in one call. `t0 > t1` is allowed (backward
    /// sweeps); each source is queried at `t0` then `t1`, the same order a
    /// scalar grid walk reveals times, so cached sources replay
    /// identically.
    pub fn fill_increments(&mut self, t0: f64, t1: f64, out: &mut [f64]) {
        let d = self.dim;
        debug_assert_eq!(out.len(), self.sources.len() * d);
        for (src, row) in self.sources.iter_mut().zip(out.chunks_exact_mut(d)) {
            src.sample_into(t0, &mut self.scratch);
            src.sample_into(t1, row);
            for (r, a) in row.iter_mut().zip(&self.scratch) {
                *r -= a;
            }
        }
    }

    /// Start a monotone grid sweep at `t`: reveals `W_b(t)` for every
    /// path into the rolling buffer. Subsequent
    /// [`BatchBrownian::sweep_increments`] calls then query each grid
    /// time exactly **once** per source — the batch analogue of the
    /// scalar drivers' wa/wb buffer swap. (Plain
    /// [`BatchBrownian::fill_increments`] re-queries its left endpoint;
    /// that is free for cached sources but costs a full bridge descent
    /// per path on a [`super::VirtualBrownianTree`], which the solver hot
    /// loops must not pay twice.)
    pub fn begin_sweep(&mut self, t: f64) {
        let d = self.dim;
        for (src, row) in self.sources.iter_mut().zip(self.wa.chunks_exact_mut(d)) {
            src.sample_into(t, row);
        }
    }

    /// Write the signed increments from the sweep's current position to
    /// `t_next` into `out` (`[B×d]`), advancing the position. Requires a
    /// prior [`BatchBrownian::begin_sweep`].
    pub fn sweep_increments(&mut self, t_next: f64, out: &mut [f64]) {
        let d = self.dim;
        debug_assert_eq!(out.len(), self.sources.len() * d);
        for (src, (row, wa_row)) in self
            .sources
            .iter_mut()
            .zip(out.chunks_exact_mut(d).zip(self.wa.chunks_exact_mut(d)))
        {
            src.sample_into(t_next, row);
            for (r, a) in row.iter_mut().zip(wa_row.iter_mut()) {
                let w = *r;
                *r = w - *a;
                *a = w;
            }
        }
    }

    /// Direct access to one path's source (replay, memory accounting).
    pub fn source_mut(&mut self, b: usize) -> &mut B {
        &mut self.sources[b]
    }

    /// Unbundle into the per-path sources (e.g. to hand each path's
    /// realized noise back as a replay handle).
    pub fn into_sources(self) -> Vec<B> {
        self.sources
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::brownian::{BrownianPath, VirtualBrownianTree};
    use crate::prng::PrngKey;
    use crate::testing::forall;

    /// Property (satellite): `fill_increments` agrees with per-path
    /// queries — on the stored path *and* the virtual tree — for any
    /// query sequence, including descending and repeated times.
    #[test]
    fn fill_increments_matches_per_path_queries_stored_path() {
        forall("fill_increments stored path", 0xB10C, 40, |g| {
            let d = g.usize_in(1, 4);
            let bsz = g.usize_in(1, 6);
            let sources: Vec<BrownianPath> = (0..bsz)
                .map(|b| BrownianPath::new(PrngKey::from_seed(900 + b as u64), d, 0.0, 1.0))
                .collect();
            let clones = sources.clone();
            let mut batch = BatchBrownian::new(sources);
            let mut singles = clones;

            let n_queries = g.usize_in(2, 8);
            let mut t_prev = g.f64_in(0.0, 1.0);
            let mut out = vec![0.0; bsz * d];
            for _ in 0..n_queries {
                let t_next = g.f64_in(0.0, 1.0);
                batch.fill_increments(t_prev, t_next, &mut out);
                for (b, single) in singles.iter_mut().enumerate() {
                    let mut wa = vec![0.0; d];
                    let mut wb = vec![0.0; d];
                    single.sample_into(t_prev, &mut wa);
                    single.sample_into(t_next, &mut wb);
                    for i in 0..d {
                        let want = wb[i] - wa[i];
                        let got = out[b * d + i];
                        if got != want {
                            return Err(format!(
                                "path {b} dim {i}: batch {got} vs scalar {want}"
                            ));
                        }
                    }
                }
                t_prev = t_next;
            }
            Ok(())
        });
    }

    #[test]
    fn fill_increments_matches_per_path_queries_virtual_tree() {
        forall("fill_increments virtual tree", 0x7EE5, 40, |g| {
            let d = g.usize_in(1, 4);
            let bsz = g.usize_in(1, 6);
            let tol = 1e-8;
            let sources: Vec<VirtualBrownianTree> = (0..bsz)
                .map(|b| {
                    VirtualBrownianTree::new(PrngKey::from_seed(40 + b as u64), d, 0.0, 1.0, tol)
                })
                .collect();
            let clones = sources.clone();
            let mut batch = BatchBrownian::new(sources);
            let mut singles = clones;

            for _ in 0..g.usize_in(2, 8) {
                let t0 = g.f64_in(0.0, 1.0);
                let t1 = g.f64_in(0.0, 1.0);
                let mut out = vec![0.0; bsz * d];
                batch.fill_increments(t0, t1, &mut out);
                for (b, single) in singles.iter_mut().enumerate() {
                    let mut wa = vec![0.0; d];
                    let mut wb = vec![0.0; d];
                    single.sample_into(t0, &mut wa);
                    single.sample_into(t1, &mut wb);
                    for i in 0..d {
                        let want = wb[i] - wa[i];
                        if out[b * d + i] != want {
                            return Err(format!("path {b} dim {i} mismatch"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// The sweep API agrees with `fill_increments` exactly on a monotone
    /// grid (same per-source query values, one query per time instead of
    /// two).
    #[test]
    fn sweep_increments_match_fill_increments() {
        let d = 2;
        let bsz = 3;
        let grid: Vec<f64> = (0..=12).map(|k| k as f64 / 12.0).collect();
        let mk = |b: u64| BrownianPath::new(PrngKey::from_seed(300 + b), d, 0.0, 1.0);
        let mut swept = BatchBrownian::new((0..bsz as u64).map(mk).collect());
        let mut filled = BatchBrownian::new((0..bsz as u64).map(mk).collect());
        let mut a = vec![0.0; bsz * d];
        let mut b = vec![0.0; bsz * d];
        swept.begin_sweep(grid[0]);
        for w in grid.windows(2) {
            swept.sweep_increments(w[1], &mut a);
            filled.fill_increments(w[0], w[1], &mut b);
            assert_eq!(a, b, "at ({}, {})", w[0], w[1]);
        }
    }

    /// Monotone grid sweep through the batch reveals the same stored path
    /// per source as an identically-keyed scalar sweep (RNG-stream
    /// equality, not just same-law).
    #[test]
    fn grid_sweep_is_bit_identical_to_scalar_sweep() {
        let d = 2;
        let bsz = 3;
        let grid: Vec<f64> = (0..=16).map(|k| k as f64 / 16.0).collect();
        let mk = |b: u64| BrownianPath::new(PrngKey::from_seed(7000 + b), d, 0.0, 1.0);

        let mut batch = BatchBrownian::new((0..bsz as u64).map(mk).collect());
        let mut dw_batch = Vec::new();
        let mut out = vec![0.0; bsz * d];
        batch.begin_sweep(grid[0]);
        for w in grid.windows(2) {
            batch.sweep_increments(w[1], &mut out);
            dw_batch.push(out.clone());
        }

        for b in 0..bsz {
            let mut single = mk(b as u64);
            let mut wa = vec![0.0; d];
            let mut wb = vec![0.0; d];
            single.sample_into(grid[0], &mut wa);
            for (k, w) in grid.windows(2).enumerate() {
                single.sample_into(w[1], &mut wb);
                for i in 0..d {
                    assert_eq!(
                        dw_batch[k][b * d + i],
                        wb[i] - wa[i],
                        "step {k} path {b} dim {i}"
                    );
                }
                wa.copy_from_slice(&wb);
            }
        }
    }
}
