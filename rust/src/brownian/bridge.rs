//! Lévy's Brownian bridge (paper Eq. 9).
//!
//! Given `W(t_s) = w_s` and `W(t_e) = w_e`, the conditional law of `W(t)`
//! for `t ∈ (t_s, t_e)` is
//!
//! ```text
//! N( ((t_e − t)·w_s + (t − t_s)·w_e) / (t_e − t_s),
//!    (t_e − t)(t − t_s) / (t_e − t_s) · I_d )
//! ```
//!
//! Both the stored-path interpolation and the virtual tree sample from this
//! law; the only difference is where the Gaussian comes from.

use crate::prng::PrngKey;

/// Mean and standard deviation of the bridge marginal at time `t`.
#[inline]
pub fn bridge_moments(ts: f64, te: f64, t: f64) -> (f64, f64, f64) {
    debug_assert!(ts < te, "bridge_moments: degenerate interval [{ts}, {te}]");
    debug_assert!(
        t >= ts && t <= te,
        "bridge_moments: t={t} outside [{ts}, {te}]"
    );
    let span = te - ts;
    let wa = (te - t) / span; // weight on w_s
    let wb = (t - ts) / span; // weight on w_e
    let std = ((te - t) * (t - ts) / span).max(0.0).sqrt();
    (wa, wb, std)
}

/// Sample `W(t) | W(ts)=ws, W(te)=we` into `out`, drawing the Gaussian from
/// `key`'s normal stream (draw indices `0..`). Deterministic in `key`.
pub fn brownian_bridge_sample(
    key: PrngKey,
    ts: f64,
    ws: &[f64],
    te: f64,
    we: &[f64],
    t: f64,
    out: &mut [f64],
) {
    let (wa, wb, std) = bridge_moments(ts, te, t);
    let d = out.len();
    debug_assert_eq!(ws.len(), d);
    debug_assert_eq!(we.len(), d);
    // Draw d normals from the key's dedicated stream.
    let mut i = 0usize;
    let mut ctr = 0u64;
    while i < d {
        let (a, b) = key.normal_pair(ctr);
        out[i] = wa * ws[i] + wb * we[i] + std * a;
        if i + 1 < d {
            out[i + 1] = wa * ws[i + 1] + wb * we[i + 1] + std * b;
        }
        i += 2;
        ctr += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_weights() {
        let (wa, wb, std) = bridge_moments(0.0, 1.0, 0.0);
        assert_eq!((wa, wb, std), (1.0, 0.0, 0.0));
        let (wa, wb, std) = bridge_moments(0.0, 1.0, 1.0);
        assert_eq!((wa, wb, std), (0.0, 1.0, 0.0));
    }

    #[test]
    fn midpoint_variance() {
        // Var at midpoint of [0, h] is h/4.
        let (_, _, std) = bridge_moments(0.0, 0.5, 0.25);
        assert!((std * std - 0.125).abs() < 1e-12);
    }

    #[test]
    fn sample_is_deterministic() {
        let key = PrngKey::from_seed(4);
        let ws = [0.0, 1.0, -1.0];
        let we = [1.0, 1.0, 2.0];
        let mut a = [0.0; 3];
        let mut b = [0.0; 3];
        brownian_bridge_sample(key, 0.0, &ws, 1.0, &we, 0.3, &mut a);
        brownian_bridge_sample(key, 0.0, &ws, 1.0, &we, 0.3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn marginal_statistics() {
        // Empirical mean/variance of the bridge sample at t=0.25 on [0,1]
        // with w_s=0, w_e=0: mean 0, var 0.25*0.75 = 0.1875.
        let ws = [0.0];
        let we = [0.0];
        let n = 100_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for seed in 0..n {
            let key = PrngKey::from_seed(seed);
            let mut out = [0.0];
            brownian_bridge_sample(key, 0.0, &ws, 1.0, &we, 0.25, &mut out);
            sum += out[0];
            sumsq += out[0] * out[0];
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 5e-3, "mean {mean}");
        assert!((var - 0.1875).abs() < 5e-3, "var {var}");
    }

    // -- randomized properties (crate::testing::forall) ---------------------

    use crate::testing::forall;

    /// Property: for any random interval and interior time, the bridge
    /// weights are a convex combination (`wa + wb = 1`, both in [0, 1])
    /// and the variance matches the closed form `wa·wb·(te − ts)`.
    #[test]
    fn property_bridge_moments_identities() {
        forall("bridge-moment-identities", 104, 128, |g| {
            let ts = g.f64_in(-2.0, 2.0);
            let span = g.f64_in(1e-6, 3.0);
            let te = ts + span;
            let t = ts + g.f64_in(0.0, 1.0) * span;
            let (wa, wb, std) = bridge_moments(ts, te, t);
            if (wa + wb - 1.0).abs() > 1e-12 {
                return Err(format!("wa + wb = {} != 1 at t={t} in [{ts}, {te}]", wa + wb));
            }
            if !(-1e-12..=1.0 + 1e-12).contains(&wa) {
                return Err(format!("wa = {wa} outside [0, 1]"));
            }
            let var_closed = wa * wb * span;
            if (std * std - var_closed).abs() > 1e-12 * span.max(1.0) {
                return Err(format!("std² = {} vs wa·wb·span = {var_closed}", std * std));
            }
            Ok(())
        });
    }

    /// Property: sampling at an endpoint reproduces that endpoint exactly
    /// (zero variance), for arbitrary endpoint values; the sample is
    /// deterministic in the key; and an interior sample stays within 8σ
    /// of the bridge mean (a bound the Gaussian violates with
    /// probability ~1e-15 — never over this case count).
    #[test]
    fn property_bridge_sample_endpoints_and_determinism() {
        forall("bridge-sample-endpoints", 105, 64, |g| {
            let seed = g.usize_in(0, 1 << 20) as u64;
            let key = PrngKey::from_seed(seed);
            let ts = g.f64_in(-1.0, 1.0);
            let span = g.f64_in(1e-3, 2.0);
            let te = ts + span;
            let ws = [g.normal(), g.normal()];
            let we = [g.normal(), g.normal()];
            let mut out = [0.0; 2];

            brownian_bridge_sample(key, ts, &ws, te, &we, ts, &mut out);
            if out != ws {
                return Err(format!("sample at ts: {out:?} != {ws:?} (seed {seed})"));
            }
            brownian_bridge_sample(key, ts, &ws, te, &we, te, &mut out);
            if out != we {
                return Err(format!("sample at te: {out:?} != {we:?} (seed {seed})"));
            }

            let t = ts + 0.5 * span;
            let mut a = [0.0; 2];
            let mut b = [0.0; 2];
            brownian_bridge_sample(key, ts, &ws, te, &we, t, &mut a);
            brownian_bridge_sample(key, ts, &ws, te, &we, t, &mut b);
            if a != b {
                return Err(format!("nondeterministic sample (seed {seed})"));
            }
            let (wa, wb, std) = bridge_moments(ts, te, t);
            for i in 0..2 {
                let mean = wa * ws[i] + wb * we[i];
                if (a[i] - mean).abs() > 8.0 * std {
                    return Err(format!(
                        "sample {} is {}σ from bridge mean {mean} (seed {seed})",
                        a[i],
                        (a[i] - mean).abs() / std
                    ));
                }
            }
            Ok(())
        });
    }
}
