//! Virtual Brownian tree (paper §4, Algorithm 3).
//!
//! Reconstructs a Wiener process sample path at arbitrary query times from a
//! *single splittable seed*, in O(1) memory and O(log 1/ε) time per query:
//!
//! 1. The global endpoints `W(t0) = 0` and `W(t1) ~ N(0, (t1−t0)·I)` are
//!    deterministic functions of the seed.
//! 2. To evaluate `W(t)`, bisect the interval. The midpoint value is drawn
//!    from the Brownian bridge (Eq. 9) conditioned on the interval's
//!    endpoints, using a key derived *from the path taken through the tree*
//!    (left/right splits of the parent key). Recurse into the half
//!    containing `t` until the midpoint is within `ε` of `t`.
//!
//! Because the key of every node is a pure function of the root seed and
//! the bisection path, any two queries that touch the same node see the
//! same Gaussian — the tree is consistent without storing anything.
//!
//! ## Node cache
//!
//! Solver sweeps query the tree at monotonically advancing (or, in the
//! adjoint's backward pass, receding) times, so consecutive descents
//! share a long prefix of ancestors. The tree keeps a bounded stack of
//! the nodes visited by the *previous* query (interval, key, midpoint
//! draw); a new query replays the bisection decisions down the cached
//! stack for free and only pays bridge draws from the first divergent
//! level. On a fixed n-step grid a sequential sweep visits each of the
//! ~2n tree nodes once, so the amortized cost drops from O(log n) to
//! O(1) bridge draws per step (`bridge_calls` counts real draws — the
//! before/after metric). Because every cached value is the same pure
//! function of `(key, path)` a fresh descent would compute, results are
//! **bit-identical for any cache capacity**, including 0 (cache off).
//! Memory stays O(1) in the number of queries and steps: at most
//! `capacity` nodes of O(dim) each are live.

use super::bridge::bridge_moments;
use super::traits::BrownianMotion;
use crate::prng::PrngKey;

/// Hard cap on bisection depth: at depth 62 the interval width has shrunk
/// by 2^62, far below f64 resolution of any practical horizon, so deeper
/// recursion cannot make progress.
const MAX_DEPTH: u32 = 62;

/// Default ancestor-node cache capacity: one more than `MAX_DEPTH`, so a
/// full root-to-leaf descent path always fits and sequential sweeps hit
/// the amortized O(1) bridge-draw regime at every tolerance.
pub const DEFAULT_NODE_CACHE: usize = 64;

/// One cached tree node: the bisection interval, the node's key, which
/// side of its parent it hangs off, and the midpoint bridge draw.
#[derive(Clone, Debug)]
struct CachedNode {
    ts: f64,
    te: f64,
    key: PrngKey,
    right: bool,
    wmid: Vec<f64>,
}

/// O(1)-memory virtual Brownian tree over `[t0, t1]`.
#[derive(Debug)]
pub struct VirtualBrownianTree {
    dim: usize,
    t0: f64,
    t1: f64,
    tol: f64,
    key: PrngKey,
    w1: Vec<f64>,
    // Scratch buffers so queries allocate nothing (hot path).
    ws: Vec<f64>,
    we: Vec<f64>,
    wmid: Vec<f64>,
    // Ancestor stack from the previous query: `nodes[..live]` is the
    // prefix of the last descent path, root first. Bounded by
    // `cache_capacity`; slots beyond `live` keep their allocations for
    // reuse.
    cache_capacity: usize,
    nodes: Vec<CachedNode>,
    live: usize,
    // Instrumentation: bridge draws performed (≙ tree levels visited).
    bridge_calls: u64,
    // Draws already booked to the process-wide total
    // ([`crate::metrics::counters`]) — the drop glue flushes
    // `bridge_calls - flushed` so every draw is counted exactly once.
    flushed: u64,
    // Cache effectiveness: levels resumed from a shared ancestor without
    // a draw (hits) vs levels that had to draw and store a fresh node
    // (misses). Flushed to the registry counters
    // `brownian.tree_cache_hits` / `brownian.tree_cache_misses` on drop,
    // with the same delta bookkeeping as `bridge_calls`.
    cache_hits: u64,
    cache_misses: u64,
    hits_flushed: u64,
    misses_flushed: u64,
}

/// Clone keeps the lifetime `bridge_calls` reading but marks those draws
/// as already flushed: the original flushes them on ITS drop, and a
/// derived clone would book the pre-clone draws once per copy.
impl Clone for VirtualBrownianTree {
    fn clone(&self) -> Self {
        VirtualBrownianTree {
            dim: self.dim,
            t0: self.t0,
            t1: self.t1,
            tol: self.tol,
            key: self.key,
            w1: self.w1.clone(),
            ws: self.ws.clone(),
            we: self.we.clone(),
            wmid: self.wmid.clone(),
            cache_capacity: self.cache_capacity,
            nodes: self.nodes.clone(),
            live: self.live,
            bridge_calls: self.bridge_calls,
            flushed: self.bridge_calls,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            hits_flushed: self.cache_hits,
            misses_flushed: self.cache_misses,
        }
    }
}

/// Flush this tree's unflushed bridge draws into the process-wide
/// monotone counter that `GET /metrics` reports.
impl Drop for VirtualBrownianTree {
    fn drop(&mut self) {
        use std::sync::OnceLock;
        crate::metrics::counters::add_bridge_calls(self.bridge_calls - self.flushed);
        self.flushed = self.bridge_calls;
        static HITS: OnceLock<crate::obs::Counter> = OnceLock::new();
        static MISSES: OnceLock<crate::obs::Counter> = OnceLock::new();
        HITS.get_or_init(|| crate::obs::counter("brownian.tree_cache_hits"))
            .add(self.cache_hits - self.hits_flushed);
        MISSES
            .get_or_init(|| crate::obs::counter("brownian.tree_cache_misses"))
            .add(self.cache_misses - self.misses_flushed);
        self.hits_flushed = self.cache_hits;
        self.misses_flushed = self.cache_misses;
    }
}

impl VirtualBrownianTree {
    /// Build a tree with error tolerance `tol` (Algorithm 3's ε) and the
    /// default node-cache capacity ([`DEFAULT_NODE_CACHE`]).
    pub fn new(key: PrngKey, dim: usize, t0: f64, t1: f64, tol: f64) -> Self {
        Self::with_cache_capacity(key, dim, t0, t1, tol, DEFAULT_NODE_CACHE)
    }

    /// Build a tree with an explicit ancestor-cache capacity (`0` turns
    /// the cache off — every query re-descends from the root). Values are
    /// bit-identical for every capacity; only the bridge-draw count and
    /// the O(capacity·dim) memory bound change.
    pub fn with_cache_capacity(
        key: PrngKey,
        dim: usize,
        t0: f64,
        t1: f64,
        tol: f64,
        capacity: usize,
    ) -> Self {
        assert!(t1 > t0, "VirtualBrownianTree: need t1 > t0 (got [{t0}, {t1}])");
        assert!(tol > 0.0, "VirtualBrownianTree: tolerance must be positive");
        assert!(dim > 0, "VirtualBrownianTree: dim must be positive");
        // The terminal value W(t1) gets its own child key; the bridge tree
        // hangs off the other child.
        let (end_key, tree_key) = key.split();
        let mut w1 = vec![0.0; dim];
        end_key.fill_normal(0, &mut w1);
        let scale = (t1 - t0).sqrt();
        for v in w1.iter_mut() {
            *v *= scale;
        }
        VirtualBrownianTree {
            dim,
            t0,
            t1,
            tol,
            key: tree_key,
            w1,
            ws: vec![0.0; dim],
            we: vec![0.0; dim],
            wmid: vec![0.0; dim],
            cache_capacity: capacity,
            nodes: Vec::new(),
            live: 0,
            bridge_calls: 0,
            flushed: 0,
            cache_hits: 0,
            cache_misses: 0,
            hits_flushed: 0,
            misses_flushed: 0,
        }
    }

    /// Error tolerance ε.
    pub fn tolerance(&self) -> f64 {
        self.tol
    }

    /// Ancestor-cache capacity (0 = cache off).
    pub fn cache_capacity(&self) -> usize {
        self.cache_capacity
    }

    /// Total Brownian-bridge draws performed over the tree's lifetime
    /// (per-query cost metric for the Table 1 / perf benches).
    pub fn bridge_calls(&self) -> u64 {
        self.bridge_calls
    }

    /// Levels resumed from a cached shared ancestor without a bridge
    /// draw. High hits on monotone sweeps are the cache paying off.
    pub fn cache_hits(&self) -> u64 {
        self.cache_hits
    }

    /// Levels that had to draw (and store) a fresh node during a cached
    /// descent. Hits + misses ≈ levels visited while the cache is on.
    pub fn cache_misses(&self) -> u64 {
        self.cache_misses
    }

    /// Draw `d` normals from `key`'s stream, scaled by `std`, writing
    /// `wa*ws + wb*we + std*z` into `out`.
    #[inline]
    fn bridge_draw(
        key: PrngKey,
        wa: f64,
        wb: f64,
        std: f64,
        ws: &[f64],
        we: &[f64],
        out: &mut [f64],
    ) {
        let d = out.len();
        let mut i = 0usize;
        let mut ctr = 0u64;
        while i < d {
            let (a, b) = key.normal_pair(ctr);
            out[i] = wa * ws[i] + wb * we[i] + std * a;
            if i + 1 < d {
                out[i + 1] = wa * ws[i + 1] + wb * we[i + 1] + std * b;
            }
            i += 2;
            ctr += 1;
        }
    }

    /// Store `(ts, te, key, right)` + a freshly drawn midpoint at cache
    /// slot `self.live` (reusing the slot's allocation when present).
    /// `self.ws` / `self.we` must hold the node's endpoint values.
    fn draw_into_cache(&mut self, ts: f64, te: f64, tmid: f64, key: PrngKey, right: bool) {
        let (wa, wb, std) = bridge_moments(ts, te, tmid);
        if self.live == self.nodes.len() {
            self.nodes.push(CachedNode { ts, te, key, right, wmid: vec![0.0; self.dim] });
        } else {
            let slot = &mut self.nodes[self.live];
            slot.ts = ts;
            slot.te = te;
            slot.key = key;
            slot.right = right;
        }
        Self::bridge_draw(key, wa, wb, std, &self.ws, &self.we, &mut self.nodes[self.live].wmid);
        self.bridge_calls += 1;
        self.cache_misses += 1;
        self.live += 1;
    }

    /// Algorithm 3's root-to-leaf bisection from an arbitrary starting
    /// node `[ts, te]` (key `key`, depth `depth`, endpoint values in
    /// `self.ws` / `self.we`), with no caching. The cached walk delegates
    /// here when it runs past its capacity; `sample_into` with the cache
    /// off delegates here from the root — both replay the exact float
    /// sequence of the original uncached algorithm.
    fn descend_uncached(
        &mut self,
        t: f64,
        mut ts: f64,
        mut te: f64,
        mut key: PrngKey,
        mut depth: u32,
        out: &mut [f64],
    ) {
        let mut tmid = 0.5 * (ts + te);
        let (wa, wb, std) = bridge_moments(ts, te, tmid);
        let mut wmid = std::mem::take(&mut self.wmid);
        Self::bridge_draw(key, wa, wb, std, &self.ws, &self.we, &mut wmid);
        self.bridge_calls += 1;

        while (t - tmid).abs() > self.tol && depth < MAX_DEPTH {
            let (kl, kr) = key.split();
            if t < tmid {
                te = tmid;
                self.we.copy_from_slice(&wmid);
                key = kl;
            } else {
                ts = tmid;
                self.ws.copy_from_slice(&wmid);
                key = kr;
            }
            tmid = 0.5 * (ts + te);
            if tmid <= ts || tmid >= te {
                break; // interval exhausted at f64 resolution
            }
            let (wa, wb, std) = bridge_moments(ts, te, tmid);
            Self::bridge_draw(key, wa, wb, std, &self.ws, &self.we, &mut wmid);
            self.bridge_calls += 1;
            depth += 1;
        }
        out.copy_from_slice(&wmid);
        self.wmid = wmid;
    }

    /// Cached descent: replay the bisection decision procedure down the
    /// stored ancestor stack (free), truncate at the first divergent
    /// level, and pay bridge draws only for new nodes. Every decision
    /// (termination, side, interval exhaustion) is evaluated on the same
    /// floats as a fresh root descent, so the returned value is
    /// bit-identical to the uncached algorithm.
    fn sample_cached(&mut self, t: f64, out: &mut [f64]) {
        self.ws.fill(0.0);
        self.we.copy_from_slice(&self.w1);
        if self.live == 0 {
            // Root midpoint: always the first draw of any descent.
            let tmid = 0.5 * (self.t0 + self.t1);
            self.draw_into_cache(self.t0, self.t1, tmid, self.key, false);
        }
        let mut i = 0usize;
        loop {
            let (ts, te) = (self.nodes[i].ts, self.nodes[i].te);
            let tmid = 0.5 * (ts + te);
            if (t - tmid).abs() <= self.tol || i as u32 >= MAX_DEPTH {
                out.copy_from_slice(&self.nodes[i].wmid);
                return;
            }
            let right = t >= tmid;
            let (c_ts, c_te) = if right { (tmid, te) } else { (ts, tmid) };
            let c_mid = 0.5 * (c_ts + c_te);
            if c_mid <= c_ts || c_mid >= c_te {
                // Interval exhausted at f64 resolution before the child
                // draw — the uncached loop breaks with the parent value.
                out.copy_from_slice(&self.nodes[i].wmid);
                return;
            }
            // Descend: the child's far endpoint is this node's midpoint.
            if right {
                self.ws.copy_from_slice(&self.nodes[i].wmid);
            } else {
                self.we.copy_from_slice(&self.nodes[i].wmid);
            }
            if i + 1 < self.live && self.nodes[i + 1].right == right {
                self.cache_hits += 1;
                i += 1; // shared ancestor: free descent, no draw
                continue;
            }
            // First divergent level: drop the stale suffix and extend.
            self.live = i + 1;
            let (kl, kr) = self.nodes[i].key.split();
            let c_key = if right { kr } else { kl };
            if self.live < self.cache_capacity {
                self.draw_into_cache(c_ts, c_te, c_mid, c_key, right);
                i += 1;
                continue;
            }
            // Cache full: finish this descent without storing nodes.
            self.descend_uncached(t, c_ts, c_te, c_key, (i + 1) as u32, out);
            return;
        }
    }
}

impl BrownianMotion for VirtualBrownianTree {
    fn dim(&self) -> usize {
        self.dim
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        let t = t.clamp(self.t0, self.t1);
        // Fast paths: global endpoints are known exactly.
        if t == self.t0 {
            out.fill(0.0);
            return;
        }
        if t == self.t1 {
            out.copy_from_slice(&self.w1);
            return;
        }

        // Algorithm 3, through the ancestor cache when enabled.
        if self.cache_capacity == 0 {
            self.ws.fill(0.0);
            self.we.copy_from_slice(&self.w1);
            let key = self.key;
            self.descend_uncached(t, self.t0, self.t1, key, 0, out);
        } else {
            self.sample_cached(t, out);
        }
    }

    fn memory_footprint(&self) -> usize {
        // Endpoint value + three scratch buffers + the key, plus the live
        // ancestor-cache nodes (each an O(dim) midpoint + interval + key):
        // O(dim · cache_capacity), constant in the number of queries and
        // in 1/ε.
        4 * self.dim + 2 + self.live * (self.dim + 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(seed: u64, d: usize, tol: f64) -> VirtualBrownianTree {
        VirtualBrownianTree::new(PrngKey::from_seed(seed), d, 0.0, 1.0, tol)
    }

    #[test]
    fn deterministic_across_queries_and_clones() {
        let mut a = tree(1, 3, 1e-9);
        let mut b = tree(1, 3, 1e-9);
        for &t in &[0.1, 0.5, 0.73, 0.999, 0.1] {
            assert_eq!(a.sample(t), b.sample(t), "mismatch at t={t}");
        }
        // Query order must not matter (nothing is stored).
        let mut c = tree(1, 3, 1e-9);
        let w_73 = c.sample(0.73);
        assert_eq!(a.sample(0.73), w_73);
    }

    #[test]
    fn endpoints() {
        let mut t = tree(2, 2, 1e-9);
        assert_eq!(t.sample(0.0), vec![0.0, 0.0]);
        let w1a = t.sample(1.0);
        let w1b = t.sample(1.0);
        assert_eq!(w1a, w1b);
    }

    #[test]
    fn memory_constant_under_queries() {
        // With the node cache off the footprint is exactly the pre-cache
        // constant; with it on, it is bounded by the capacity — O(1) in
        // the number of queries either way.
        let mut plain =
            VirtualBrownianTree::with_cache_capacity(PrngKey::from_seed(3), 4, 0.0, 1.0, 1e-12, 0);
        let before = plain.memory_footprint();
        for i in 1..1000 {
            plain.sample(i as f64 / 1001.0);
        }
        assert_eq!(plain.memory_footprint(), before);

        let mut cached = tree(3, 4, 1e-12);
        let bound = 4 * 4 + 2 + cached.cache_capacity() * (4 + 4);
        for i in 1..1000 {
            cached.sample(i as f64 / 1001.0);
            assert!(cached.memory_footprint() <= bound, "footprint grew past the cache bound");
        }
    }

    #[test]
    fn cached_values_bitwise_equal_uncached() {
        // Same key, every cache capacity, adversarial query order
        // (forward sweep, backward sweep, repeats, jumps): values must be
        // bit-identical — the cache replays the same pure function.
        let queries: Vec<f64> = (1..64)
            .map(|i| i as f64 / 64.0)
            .chain((1..64).rev().map(|i| i as f64 / 64.0))
            .chain([0.3141, 0.9999, 0.0001, 0.5, 0.3141])
            .collect();
        for d in [1, 3] {
            let mut plain = VirtualBrownianTree::with_cache_capacity(
                PrngKey::from_seed(42),
                d,
                0.0,
                1.0,
                1e-11,
                0,
            );
            for cap in [1, 4, DEFAULT_NODE_CACHE] {
                let mut cached = VirtualBrownianTree::with_cache_capacity(
                    PrngKey::from_seed(42),
                    d,
                    0.0,
                    1.0,
                    1e-11,
                    cap,
                );
                for &t in &queries {
                    assert_eq!(cached.sample(t), plain.sample(t), "t={t} cap={cap} d={d}");
                }
            }
        }
    }

    #[test]
    fn repeated_query_costs_zero_draws() {
        let mut t = tree(7, 2, 1e-11);
        t.sample(0.37);
        let before = t.bridge_calls();
        t.sample(0.37);
        assert_eq!(t.bridge_calls(), before, "identical query must replay the cached path");
    }

    #[test]
    fn monotone_sweep_amortizes_to_two_draws_per_step() {
        // Power-of-2 grid: every grid time is an exact tree midpoint, and
        // a left-to-right sweep visits each of the ~2n distinct nodes on
        // the union of descent paths exactly once. Uncached, every query
        // re-descends ~log2(n) levels from the root.
        let n = 256;
        let mut cached = tree(11, 1, 1e-14);
        for k in 1..n {
            cached.sample(k as f64 / n as f64);
        }
        assert!(
            cached.bridge_calls() <= 2 * n,
            "cached sweep: {} draws for {n} steps (want ≤ {})",
            cached.bridge_calls(),
            2 * n
        );

        let mut plain =
            VirtualBrownianTree::with_cache_capacity(PrngKey::from_seed(11), 1, 0.0, 1.0, 1e-14, 0);
        for k in 1..n {
            plain.sample(k as f64 / n as f64);
        }
        assert!(
            plain.bridge_calls() >= 3 * n,
            "uncached sweep should pay ~log2(n) per step: {} draws",
            plain.bridge_calls()
        );
    }

    #[test]
    fn query_cost_logarithmic_in_tolerance() {
        // Bridge calls per query should grow ~linearly with log2(1/eps).
        let mut costs = Vec::new();
        for &tol in &[1e-3, 1e-6, 1e-9] {
            let mut t = tree(4, 1, tol);
            let before = t.bridge_calls();
            t.sample(0.3141592653589793);
            costs.push(t.bridge_calls() - before);
        }
        assert!(costs[0] < costs[1] && costs[1] < costs[2]);
        // ~10 levels per 1e-3 factor (log2(1000) ≈ 10)
        assert!(costs[2] <= 40, "cost at 1e-9 unexpectedly large: {costs:?}");
    }

    #[test]
    fn marginal_variance_matches_brownian_law() {
        // Var[W(t)] = t at a non-dyadic time, over independent seeds.
        let n = 40_000;
        let t_query = 0.3;
        let mut sumsq = 0.0;
        for seed in 0..n {
            let mut t = tree(seed, 1, 1e-10);
            let w = t.sample(t_query)[0];
            sumsq += w * w;
        }
        let var = sumsq / n as f64;
        assert!((var - t_query).abs() < 0.01, "var {var}");
    }

    #[test]
    fn increment_variance_small_intervals() {
        // Increments over [0.4, 0.6]: variance 0.2.
        let n = 30_000;
        let mut sumsq = 0.0;
        for seed in 0..n {
            let mut t = tree(seed + 77_000, 1, 1e-10);
            let inc = t.increment(0.4, 0.6)[0];
            sumsq += inc * inc;
        }
        let var = sumsq / n as f64;
        assert!((var - 0.2).abs() < 0.01, "var {var}");
    }

    #[test]
    fn dyadic_queries_terminate_fast() {
        let mut t = tree(5, 1, 1e-14);
        let before = t.bridge_calls();
        t.sample(0.5);
        assert_eq!(t.bridge_calls() - before, 1, "0.5 is the first midpoint");
        let before = t.bridge_calls();
        t.sample(0.25);
        // The root is cached from the previous query; only the depth-1
        // node is drawn.
        assert_eq!(t.bridge_calls() - before, 1);

        // Uncached, the same pair re-descends from the root each time.
        let mut u =
            VirtualBrownianTree::with_cache_capacity(PrngKey::from_seed(5), 1, 0.0, 1.0, 1e-14, 0);
        u.sample(0.5);
        let before = u.bridge_calls();
        u.sample(0.25);
        assert_eq!(u.bridge_calls() - before, 2);
    }

    #[test]
    fn tolerance_bounds_time_error() {
        // The returned value is W at a time within eps of the query; for a
        // fine tolerance two adjacent queries differ by a plausible
        // Brownian increment, not by a jump.
        let mut t = tree(6, 1, 1e-12);
        let a = t.sample(0.500000)[0];
        let b = t.sample(0.500001)[0];
        // Brownian increments over 1e-6 have std 1e-3; allow 6 sigma.
        assert!((a - b).abs() < 6e-3, "jump too large: {}", (a - b).abs());
    }

    #[test]
    fn multidim_components_independent() {
        let n = 20_000;
        let mut dot = 0.0;
        for seed in 0..n {
            let mut t = tree(seed + 1_234, 2, 1e-10);
            let w = t.sample(0.7);
            dot += w[0] * w[1];
        }
        let corr = dot / n as f64 / 0.7; // normalize by Var = t
        assert!(corr.abs() < 0.03, "corr {corr}");
    }

    // -- randomized properties (crate::testing::forall) ---------------------

    use crate::brownian::BrownianPath;
    use crate::testing::forall;

    /// Property: querying the same time twice — with arbitrary other
    /// queries interleaved — returns bit-identical values, for both the
    /// virtual tree (a pure function of `(key, t)`) and the stored path
    /// (a cache).
    #[test]
    fn property_same_time_queries_deterministic() {
        forall("same-t-determinism", 101, 64, |g| {
            let seed = g.usize_in(0, 1 << 20) as u64;
            let d = g.usize_in(1, 4);
            let t = g.f64_in(1e-6, 1.0 - 1e-6);
            let interleaved: Vec<f64> = (0..3).map(|_| g.f64_in(0.0, 1.0)).collect();

            let mut tr = VirtualBrownianTree::new(PrngKey::from_seed(seed), d, 0.0, 1.0, 1e-11);
            let mut pa = BrownianPath::new(PrngKey::from_seed(seed), d, 0.0, 1.0);
            let first_tree = tr.sample(t);
            let first_path = pa.sample(t);
            for &u in &interleaved {
                tr.sample(u);
                pa.sample(u);
            }
            if tr.sample(t) != first_tree {
                return Err(format!("tree inconsistent at t={t} (seed {seed})"));
            }
            if pa.sample(t) != first_path {
                return Err(format!("stored path inconsistent at t={t} (seed {seed})"));
            }
            Ok(())
        });
    }

    /// Property: increment additivity `W(s,t) + W(t,u) = W(s,u)` (up to
    /// float cancellation) for random `s < t < u`, on both sources.
    #[test]
    fn property_increment_additivity() {
        forall("increment-additivity", 102, 64, |g| {
            let seed = g.usize_in(0, 1 << 20) as u64;
            let mut ts = [g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0)];
            ts.sort_by(|a, b| a.total_cmp(b));
            let [s, t, u] = ts;
            if t - s < 1e-9 || u - t < 1e-9 {
                return Ok(()); // degenerate case: nothing to check
            }
            let check = |name: &str, bm: &mut dyn BrownianMotion| -> Result<(), String> {
                let a = bm.increment(s, t)[0];
                let b = bm.increment(t, u)[0];
                let c = bm.increment(s, u)[0];
                if (a + b - c).abs() > 1e-12 {
                    Err(format!(
                        "{name}: W({s},{t})+W({t},{u}) = {} != W({s},{u}) = {c} (seed {seed})",
                        a + b
                    ))
                } else {
                    Ok(())
                }
            };
            check(
                "tree",
                &mut VirtualBrownianTree::new(PrngKey::from_seed(seed), 1, 0.0, 1.0, 1e-11),
            )?;
            check("path", &mut BrownianPath::new(PrngKey::from_seed(seed), 1, 0.0, 1.0))
        });
    }

    /// Property: StoredPath ↔ VirtualTree agreement. The two sources
    /// realize different sample paths from the same key (different
    /// algorithms), so agreement is in *law*: over a batch of seeds, the
    /// empirical variance of the increment over a random interval must
    /// match `t − s` for both, and hence each other, within statistical
    /// tolerance.
    #[test]
    fn property_stored_path_and_tree_agree_in_law() {
        forall("path-tree-law-agreement", 103, 12, |g| {
            let s = g.f64_in(0.0, 0.45);
            let t = g.f64_in(0.55, 1.0);
            let span = t - s;
            let n_seeds = 800u64;
            let base = g.usize_in(0, 1 << 20) as u64;
            let mut var = [0.0f64; 2];
            for seed in 0..n_seeds {
                let key = PrngKey::from_seed(base + seed);
                let inc_t =
                    VirtualBrownianTree::new(key, 1, 0.0, 1.0, 1e-11).increment(s, t)[0];
                let inc_p = BrownianPath::new(key, 1, 0.0, 1.0).increment(s, t)[0];
                var[0] += inc_t * inc_t;
                var[1] += inc_p * inc_p;
            }
            for v in var.iter_mut() {
                *v /= n_seeds as f64;
            }
            // √(2/800) ≈ 5% relative noise on a variance estimate; 25%
            // is a ≥5σ band.
            for (name, v) in [("tree", var[0]), ("path", var[1])] {
                if (v - span).abs() > 0.25 * span {
                    return Err(format!("{name}: Var[W({s},{t})] = {v}, expected {span}"));
                }
            }
            if (var[0] - var[1]).abs() > 0.35 * span {
                return Err(format!("sources disagree: tree {} vs path {}", var[0], var[1]));
            }
            Ok(())
        });
    }
}
