//! Virtual Brownian tree (paper §4, Algorithm 3).
//!
//! Reconstructs a Wiener process sample path at arbitrary query times from a
//! *single splittable seed*, in O(1) memory and O(log 1/ε) time per query:
//!
//! 1. The global endpoints `W(t0) = 0` and `W(t1) ~ N(0, (t1−t0)·I)` are
//!    deterministic functions of the seed.
//! 2. To evaluate `W(t)`, bisect the interval. The midpoint value is drawn
//!    from the Brownian bridge (Eq. 9) conditioned on the interval's
//!    endpoints, using a key derived *from the path taken through the tree*
//!    (left/right splits of the parent key). Recurse into the half
//!    containing `t` until the midpoint is within `ε` of `t`.
//!
//! Because the key of every node is a pure function of the root seed and
//! the bisection path, any two queries that touch the same node see the
//! same Gaussian — the tree is consistent without storing anything.

use super::bridge::bridge_moments;
use super::traits::BrownianMotion;
use crate::prng::PrngKey;

/// Hard cap on bisection depth: at depth 62 the interval width has shrunk
/// by 2^62, far below f64 resolution of any practical horizon, so deeper
/// recursion cannot make progress.
const MAX_DEPTH: u32 = 62;

/// O(1)-memory virtual Brownian tree over `[t0, t1]`.
#[derive(Clone, Debug)]
pub struct VirtualBrownianTree {
    dim: usize,
    t0: f64,
    t1: f64,
    tol: f64,
    key: PrngKey,
    w1: Vec<f64>,
    // Scratch buffers so queries allocate nothing (hot path).
    ws: Vec<f64>,
    we: Vec<f64>,
    wmid: Vec<f64>,
    // Instrumentation: bridge draws performed (≙ tree levels visited).
    bridge_calls: u64,
}

impl VirtualBrownianTree {
    /// Build a tree with error tolerance `tol` (Algorithm 3's ε).
    pub fn new(key: PrngKey, dim: usize, t0: f64, t1: f64, tol: f64) -> Self {
        assert!(t1 > t0, "VirtualBrownianTree: need t1 > t0 (got [{t0}, {t1}])");
        assert!(tol > 0.0, "VirtualBrownianTree: tolerance must be positive");
        assert!(dim > 0, "VirtualBrownianTree: dim must be positive");
        // The terminal value W(t1) gets its own child key; the bridge tree
        // hangs off the other child.
        let (end_key, tree_key) = key.split();
        let mut w1 = vec![0.0; dim];
        end_key.fill_normal(0, &mut w1);
        let scale = (t1 - t0).sqrt();
        for v in w1.iter_mut() {
            *v *= scale;
        }
        VirtualBrownianTree {
            dim,
            t0,
            t1,
            tol,
            key: tree_key,
            w1,
            ws: vec![0.0; dim],
            we: vec![0.0; dim],
            wmid: vec![0.0; dim],
            bridge_calls: 0,
        }
    }

    /// Error tolerance ε.
    pub fn tolerance(&self) -> f64 {
        self.tol
    }

    /// Total Brownian-bridge draws performed over the tree's lifetime
    /// (per-query cost metric for the Table 1 / perf benches).
    pub fn bridge_calls(&self) -> u64 {
        self.bridge_calls
    }

    /// Draw `d` normals from `key`'s stream, scaled by `std`, writing
    /// `wa*ws + wb*we + std*z` into `out`.
    #[inline]
    fn bridge_draw(
        key: PrngKey,
        wa: f64,
        wb: f64,
        std: f64,
        ws: &[f64],
        we: &[f64],
        out: &mut [f64],
    ) {
        let d = out.len();
        let mut i = 0usize;
        let mut ctr = 0u64;
        while i < d {
            let (a, b) = key.normal_pair(ctr);
            out[i] = wa * ws[i] + wb * we[i] + std * a;
            if i + 1 < d {
                out[i + 1] = wa * ws[i + 1] + wb * we[i + 1] + std * b;
            }
            i += 2;
            ctr += 1;
        }
    }
}

impl BrownianMotion for VirtualBrownianTree {
    fn dim(&self) -> usize {
        self.dim
    }

    fn span(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    fn sample_into(&mut self, t: f64, out: &mut [f64]) {
        let t = t.clamp(self.t0, self.t1);
        // Fast paths: global endpoints are known exactly.
        if t == self.t0 {
            out.fill(0.0);
            return;
        }
        if t == self.t1 {
            out.copy_from_slice(&self.w1);
            return;
        }

        // Algorithm 3.
        let (mut ts, mut te) = (self.t0, self.t1);
        self.ws.fill(0.0);
        self.we.copy_from_slice(&self.w1);
        let mut key = self.key;

        let mut tmid = 0.5 * (ts + te);
        let (wa, wb, std) = bridge_moments(ts, te, tmid);
        let wmid = std::mem::take(&mut self.wmid);
        let mut wmid = wmid;
        Self::bridge_draw(key, wa, wb, std, &self.ws, &self.we, &mut wmid);
        self.bridge_calls += 1;

        let mut depth = 0u32;
        while (t - tmid).abs() > self.tol && depth < MAX_DEPTH {
            let (kl, kr) = key.split();
            if t < tmid {
                te = tmid;
                self.we.copy_from_slice(&wmid);
                key = kl;
            } else {
                ts = tmid;
                self.ws.copy_from_slice(&wmid);
                key = kr;
            }
            tmid = 0.5 * (ts + te);
            if tmid <= ts || tmid >= te {
                break; // interval exhausted at f64 resolution
            }
            let (wa, wb, std) = bridge_moments(ts, te, tmid);
            Self::bridge_draw(key, wa, wb, std, &self.ws, &self.we, &mut wmid);
            self.bridge_calls += 1;
            depth += 1;
        }
        out.copy_from_slice(&wmid);
        self.wmid = wmid;
    }

    fn memory_footprint(&self) -> usize {
        // Endpoint value + three scratch buffers + the key: O(dim), constant
        // in the number of queries and in 1/ε.
        4 * self.dim + 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree(seed: u64, d: usize, tol: f64) -> VirtualBrownianTree {
        VirtualBrownianTree::new(PrngKey::from_seed(seed), d, 0.0, 1.0, tol)
    }

    #[test]
    fn deterministic_across_queries_and_clones() {
        let mut a = tree(1, 3, 1e-9);
        let mut b = tree(1, 3, 1e-9);
        for &t in &[0.1, 0.5, 0.73, 0.999, 0.1] {
            assert_eq!(a.sample(t), b.sample(t), "mismatch at t={t}");
        }
        // Query order must not matter (nothing is stored).
        let mut c = tree(1, 3, 1e-9);
        let w_73 = c.sample(0.73);
        assert_eq!(a.sample(0.73), w_73);
    }

    #[test]
    fn endpoints() {
        let mut t = tree(2, 2, 1e-9);
        assert_eq!(t.sample(0.0), vec![0.0, 0.0]);
        let w1a = t.sample(1.0);
        let w1b = t.sample(1.0);
        assert_eq!(w1a, w1b);
    }

    #[test]
    fn memory_constant_under_queries() {
        let mut t = tree(3, 4, 1e-12);
        let before = t.memory_footprint();
        for i in 1..1000 {
            t.sample(i as f64 / 1001.0);
        }
        assert_eq!(t.memory_footprint(), before);
    }

    #[test]
    fn query_cost_logarithmic_in_tolerance() {
        // Bridge calls per query should grow ~linearly with log2(1/eps).
        let mut costs = Vec::new();
        for &tol in &[1e-3, 1e-6, 1e-9] {
            let mut t = tree(4, 1, tol);
            let before = t.bridge_calls();
            t.sample(0.3141592653589793);
            costs.push(t.bridge_calls() - before);
        }
        assert!(costs[0] < costs[1] && costs[1] < costs[2]);
        // ~10 levels per 1e-3 factor (log2(1000) ≈ 10)
        assert!(costs[2] <= 40, "cost at 1e-9 unexpectedly large: {costs:?}");
    }

    #[test]
    fn marginal_variance_matches_brownian_law() {
        // Var[W(t)] = t at a non-dyadic time, over independent seeds.
        let n = 40_000;
        let t_query = 0.3;
        let mut sumsq = 0.0;
        for seed in 0..n {
            let mut t = tree(seed, 1, 1e-10);
            let w = t.sample(t_query)[0];
            sumsq += w * w;
        }
        let var = sumsq / n as f64;
        assert!((var - t_query).abs() < 0.01, "var {var}");
    }

    #[test]
    fn increment_variance_small_intervals() {
        // Increments over [0.4, 0.6]: variance 0.2.
        let n = 30_000;
        let mut sumsq = 0.0;
        for seed in 0..n {
            let mut t = tree(seed + 77_000, 1, 1e-10);
            let inc = t.increment(0.4, 0.6)[0];
            sumsq += inc * inc;
        }
        let var = sumsq / n as f64;
        assert!((var - 0.2).abs() < 0.01, "var {var}");
    }

    #[test]
    fn dyadic_queries_terminate_fast() {
        let mut t = tree(5, 1, 1e-14);
        let before = t.bridge_calls();
        t.sample(0.5);
        assert_eq!(t.bridge_calls() - before, 1, "0.5 is the first midpoint");
        let before = t.bridge_calls();
        t.sample(0.25);
        assert_eq!(t.bridge_calls() - before, 2);
    }

    #[test]
    fn tolerance_bounds_time_error() {
        // The returned value is W at a time within eps of the query; for a
        // fine tolerance two adjacent queries differ by a plausible
        // Brownian increment, not by a jump.
        let mut t = tree(6, 1, 1e-12);
        let a = t.sample(0.500000)[0];
        let b = t.sample(0.500001)[0];
        // Brownian increments over 1e-6 have std 1e-3; allow 6 sigma.
        assert!((a - b).abs() < 6e-3, "jump too large: {}", (a - b).abs());
    }

    #[test]
    fn multidim_components_independent() {
        let n = 20_000;
        let mut dot = 0.0;
        for seed in 0..n {
            let mut t = tree(seed + 1_234, 2, 1e-10);
            let w = t.sample(0.7);
            dot += w[0] * w[1];
        }
        let corr = dot / n as f64 / 0.7; // normalize by Var = t
        assert!(corr.abs() < 0.03, "corr {corr}");
    }

    // -- randomized properties (crate::testing::forall) ---------------------

    use crate::brownian::BrownianPath;
    use crate::testing::forall;

    /// Property: querying the same time twice — with arbitrary other
    /// queries interleaved — returns bit-identical values, for both the
    /// virtual tree (a pure function of `(key, t)`) and the stored path
    /// (a cache).
    #[test]
    fn property_same_time_queries_deterministic() {
        forall("same-t-determinism", 101, 64, |g| {
            let seed = g.usize_in(0, 1 << 20) as u64;
            let d = g.usize_in(1, 4);
            let t = g.f64_in(1e-6, 1.0 - 1e-6);
            let interleaved: Vec<f64> = (0..3).map(|_| g.f64_in(0.0, 1.0)).collect();

            let mut tr = VirtualBrownianTree::new(PrngKey::from_seed(seed), d, 0.0, 1.0, 1e-11);
            let mut pa = BrownianPath::new(PrngKey::from_seed(seed), d, 0.0, 1.0);
            let first_tree = tr.sample(t);
            let first_path = pa.sample(t);
            for &u in &interleaved {
                tr.sample(u);
                pa.sample(u);
            }
            if tr.sample(t) != first_tree {
                return Err(format!("tree inconsistent at t={t} (seed {seed})"));
            }
            if pa.sample(t) != first_path {
                return Err(format!("stored path inconsistent at t={t} (seed {seed})"));
            }
            Ok(())
        });
    }

    /// Property: increment additivity `W(s,t) + W(t,u) = W(s,u)` (up to
    /// float cancellation) for random `s < t < u`, on both sources.
    #[test]
    fn property_increment_additivity() {
        forall("increment-additivity", 102, 64, |g| {
            let seed = g.usize_in(0, 1 << 20) as u64;
            let mut ts = [g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0), g.f64_in(0.0, 1.0)];
            ts.sort_by(|a, b| a.total_cmp(b));
            let [s, t, u] = ts;
            if t - s < 1e-9 || u - t < 1e-9 {
                return Ok(()); // degenerate case: nothing to check
            }
            let check = |name: &str, bm: &mut dyn BrownianMotion| -> Result<(), String> {
                let a = bm.increment(s, t)[0];
                let b = bm.increment(t, u)[0];
                let c = bm.increment(s, u)[0];
                if (a + b - c).abs() > 1e-12 {
                    Err(format!(
                        "{name}: W({s},{t})+W({t},{u}) = {} != W({s},{u}) = {c} (seed {seed})",
                        a + b
                    ))
                } else {
                    Ok(())
                }
            };
            check(
                "tree",
                &mut VirtualBrownianTree::new(PrngKey::from_seed(seed), 1, 0.0, 1.0, 1e-11),
            )?;
            check("path", &mut BrownianPath::new(PrngKey::from_seed(seed), 1, 0.0, 1.0))
        });
    }

    /// Property: StoredPath ↔ VirtualTree agreement. The two sources
    /// realize different sample paths from the same key (different
    /// algorithms), so agreement is in *law*: over a batch of seeds, the
    /// empirical variance of the increment over a random interval must
    /// match `t − s` for both, and hence each other, within statistical
    /// tolerance.
    #[test]
    fn property_stored_path_and_tree_agree_in_law() {
        forall("path-tree-law-agreement", 103, 12, |g| {
            let s = g.f64_in(0.0, 0.45);
            let t = g.f64_in(0.55, 1.0);
            let span = t - s;
            let n_seeds = 800u64;
            let base = g.usize_in(0, 1 << 20) as u64;
            let mut var = [0.0f64; 2];
            for seed in 0..n_seeds {
                let key = PrngKey::from_seed(base + seed);
                let inc_t =
                    VirtualBrownianTree::new(key, 1, 0.0, 1.0, 1e-11).increment(s, t)[0];
                let inc_p = BrownianPath::new(key, 1, 0.0, 1.0).increment(s, t)[0];
                var[0] += inc_t * inc_t;
                var[1] += inc_p * inc_p;
            }
            for v in var.iter_mut() {
                *v /= n_seeds as f64;
            }
            // √(2/800) ≈ 5% relative noise on a variance estimate; 25%
            // is a ≥5σ band.
            for (name, v) in [("tree", var[0]), ("path", var[1])] {
                if (v - span).abs() > 0.25 * span {
                    return Err(format!("{name}: Var[W({s},{t})] = {v}, expected {span}"));
                }
            }
            if (var[0] - var[1]).abs() > 0.35 * span {
                return Err(format!("sources disagree: tree {} vs path {}", var[0], var[1]));
            }
            Ok(())
        });
    }
}
