//! Brownian motion sources (paper §4).
//!
//! The stochastic adjoint retraces the forward trajectory backward in time,
//! so the *same* Wiener sample path must be queryable at arbitrary times in
//! both passes. Two implementations of the [`BrownianMotion`] trait:
//!
//! * [`BrownianPath`] — stores every queried value in an ordered map and
//!   interpolates new queries with Brownian bridges conditioned on the
//!   stored neighbours. O(n) memory, O(log n) query. This is the
//!   "store the noise" implementation the paper uses in its experiments.
//! * [`VirtualBrownianTree`] — Algorithm 3: O(1) memory, O(log 1/ε) query.
//!   Reconstructs any node of a Brownian tree from a single splittable seed
//!   by recursively bisecting Brownian bridges.
//!
//! Both are deterministic given their key: querying the same time twice
//! returns the same value, which is precisely what makes the backward solve
//! see the forward pass's noise.
//!
//! [`quadrature`] evaluates kernel-weighted Riemann integrals of a
//! realized path (`∫ f(u)·W(u) du`), the primitive the convergence
//! subsystem's analytic oracles use to reconstruct exact strong solutions
//! of additive-noise SDEs from the same noise source the solver consumed.

pub mod batch;
pub mod bridge;
pub mod path;
pub mod quadrature;
pub mod traits;
pub mod tree;

pub use batch::BatchBrownian;
pub use bridge::brownian_bridge_sample;
pub use path::BrownianPath;
pub use quadrature::weighted_path_integrals;
pub use traits::BrownianMotion;
pub use tree::{VirtualBrownianTree, DEFAULT_NODE_CACHE};
