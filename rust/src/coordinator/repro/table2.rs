//! Table 2: test MSE on future frames of the (synthetic) mocap dataset.
//!
//! Protocol (§7.3 / App. 9.11): 50-d observations, 23 sequences split
//! 16/3/4; the recognition MLP encodes the *first three frames*; the model
//! then predicts the remaining frames; test MSE on those future frames is
//! averaged over 50 posterior samples with a t-statistic 95% CI.
//!
//! Methods (DESIGN.md §3 documents why the external rows of the paper's
//! table are replaced): latent SDE, latent ODE (σ ≡ 0 ablation), and two
//! reference baselines — predict the training mean, and hold the last
//! conditioned frame. The reproduction target is the ordering
//! `latent SDE < latent ODE < hold/mean`.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::train_latent_sde;
use crate::data::mocap::{self, MocapConfig, SPLIT};
use crate::data::TimeSeriesDataset;
use crate::latent::{decode_path, sample_posterior_path, DiffusionMode, EncoderKind,
    LatentSdeConfig, LatentSdeModel};
use crate::metrics::{confidence_interval_95, CsvWriter, OnlineStats};
use crate::prng::PrngKey;

const WARMUP_FRAMES: usize = 3;

/// One Table 2 row.
#[derive(Clone, Debug)]
pub struct Row {
    pub method: String,
    pub test_mse: f64,
    pub ci95: f64,
}

/// Future-frame MSE of a trained model on the test split, averaged over
/// `n_samples` posterior samples.
fn eval_future_mse(
    model: &LatentSdeModel,
    params: &[f64],
    ds: &TimeSeriesDataset,
    test_idx: &[usize],
    substeps: usize,
    n_samples: u64,
) -> OnlineStats {
    let mut stats = OnlineStats::new();
    for &s in test_idx {
        for sample in 0..n_samples {
            let lat = sample_posterior_path(
                model,
                params,
                &ds.times,
                ds.series(s),
                substeps,
                PrngKey::from_seed(40_000 + s as u64 * 1000 + sample),
            );
            let dec = decode_path(model, params, &lat);
            let mut mse = 0.0;
            let mut count = 0;
            for k in WARMUP_FRAMES..ds.n_times() {
                let obs = ds.obs(s, k);
                for d in 0..ds.dim {
                    let e = obs[d] - dec[k * ds.dim + d];
                    mse += e * e;
                    count += 1;
                }
            }
            stats.push(mse / count as f64);
        }
    }
    stats
}

/// MSE of the constant baselines over future frames.
fn baseline_mse(ds: &TimeSeriesDataset, test_idx: &[usize], mode: &str, train_idx: &[usize]) -> OnlineStats {
    // Per-channel training mean.
    let mut mean = vec![0.0; ds.dim];
    let mut n = 0usize;
    for &s in train_idx {
        for k in 0..ds.n_times() {
            for d in 0..ds.dim {
                mean[d] += ds.obs(s, k)[d];
            }
            n += 1;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }

    let mut stats = OnlineStats::new();
    for &s in test_idx {
        let hold = ds.obs(s, WARMUP_FRAMES - 1).to_vec();
        let mut mse = 0.0;
        let mut count = 0;
        for k in WARMUP_FRAMES..ds.n_times() {
            let obs = ds.obs(s, k);
            for d in 0..ds.dim {
                let pred = if mode == "hold" { hold[d] } else { mean[d] };
                let e = obs[d] - pred;
                mse += e * e;
                count += 1;
            }
        }
        stats.push(mse / count as f64);
    }
    stats
}

/// Run the Table 2 experiment. Returns the rows (printed + CSV'd).
pub fn run(quick: bool) -> Vec<Row> {
    super::headline("Table 2: future-frame test MSE on synthetic mocap (50-d)");
    let mcfg = MocapConfig {
        n_frames: if quick { 60 } else { 300 },
        ..Default::default()
    };
    let ds = mocap::generate(PrngKey::from_seed(35), &mcfg);
    let (train_idx, val_idx, test_idx) = ds.split_indices(PrngKey::from_seed(36), SPLIT.0, SPLIT.1, SPLIT.2);

    let base_model_cfg = LatentSdeConfig {
        obs_dim: ds.dim,
        latent_dim: 6,
        context_dim: 3,
        hidden: if quick { 24 } else { 30 },
        diff_hidden: 8,
        enc_hidden: if quick { 24 } else { 30 },
        encoder: EncoderKind::FirstFramesMlp { n_frames: WARMUP_FRAMES },
        obs_noise_std: 0.1,
        ..Default::default()
    };
    let train_cfg = TrainConfig {
        iters: if quick { 30 } else { 400 },
        batch_size: 8,
        lr: 0.01,
        lr_decay: 0.999,
        substeps: 2,
        kl_weight: 0.01,
        kl_anneal_iters: if quick { 10 } else { 200 },
        seed: 37,
        val_every: 0,
        ..Default::default()
    };
    let n_samples = if quick { 8 } else { 50 };
    // §7.3: "We perform validation over the number of training iterations,
    // KL penalty, and KL annealing schedule." We sweep the KL penalty and
    // select by validation future-frame MSE (quick mode: single setting).
    let kl_sweep: &[f64] = if quick { &[0.01] } else { &[0.1, 0.01, 0.001] };

    let mut rows = Vec::new();

    for (label, csv_tag, diffusion) in [
        ("Latent SDE (this work)", "sde", base_model_cfg.diffusion),
        ("Latent ODE", "ode", DiffusionMode::Off),
    ] {
        let model = LatentSdeModel::new(LatentSdeConfig { diffusion, ..base_model_cfg });
        let mut best: Option<(f64, f64, Vec<f64>)> = None; // (val_mse, kl, params)
        for &kl in kl_sweep {
            let cfg_k = TrainConfig { kl_weight: kl, ..train_cfg };
            println!(
                "training {label} ({} params, {} iters, KL {kl}) ...",
                model.n_params, cfg_k.iters
            );
            let report = train_latent_sde(
                &model,
                &ds,
                &train_idx,
                &val_idx,
                &cfg_k,
                Some(
                    super::out_dir()
                        .join(format!("table2_{csv_tag}_kl{kl}_training.csv"))
                        .to_str()
                        .unwrap(),
                ),
            );
            let val_stats = eval_future_mse(
                &model,
                &report.final_params,
                &ds,
                &val_idx,
                cfg_k.substeps,
                (n_samples / 2).max(4),
            );
            println!("  val future-MSE @ KL {kl}: {:.4}", val_stats.mean());
            if best.as_ref().map(|(m, _, _)| val_stats.mean() < *m).unwrap_or(true) {
                best = Some((val_stats.mean(), kl, report.final_params));
            }
        }
        let (_, kl, params) = best.unwrap();
        println!("  selected KL {kl} for {label}");
        let stats = eval_future_mse(&model, &params, &ds, &test_idx, train_cfg.substeps, n_samples);
        rows.push(Row {
            method: label.into(),
            test_mse: stats.mean(),
            ci95: confidence_interval_95(&stats),
        });
    }
    // Constant baselines.
    for (label, mode) in [("Hold last frame", "hold"), ("Train mean", "mean")] {
        let stats = baseline_mse(&ds, &test_idx, mode, &train_idx);
        rows.push(Row {
            method: label.into(),
            test_mse: stats.mean(),
            ci95: confidence_interval_95(&stats),
        });
    }

    let mut csv = CsvWriter::create(
        super::out_dir().join("table2_mocap.csv"),
        &["method", "test_mse", "ci95"],
    )
    .expect("csv");
    println!("\n{:<26} {:>12} {:>10}", "method", "test MSE", "95% CI");
    for r in &rows {
        println!("{:<26} {:>12.4} {:>10.4}", r.method, r.test_mse, r.ci95);
        csv.row(&[r.method.clone(), format!("{}", r.test_mse), format!("{}", r.ci95)]).ok();
    }
    csv.flush().ok();
    rows
}
