//! Reproduction harnesses — one per table/figure of the paper's
//! evaluation (DESIGN.md §5 maps IDs to modules), plus the
//! [`convergence`] verification table (empirical strong/weak/gradient
//! orders vs analytic oracles). Each harness prints its rows/series as an
//! aligned text table and writes the raw data as CSV under `bench_out/`.
//!
//! Shared by the `cargo bench` targets (thin wrappers) and the
//! `sdegrad repro <id>` CLI. `quick: true` shrinks the sweep for CI-speed
//! smoke runs; `false` reproduces the paper-scale setting.

pub mod convergence;
pub mod fig2;
pub mod fig5;
pub mod latent_figs;
pub mod table1;
pub mod table2;

/// Output directory for harness CSVs.
pub fn out_dir() -> std::path::PathBuf {
    std::path::PathBuf::from("bench_out")
}

/// Print a separator headline.
pub fn headline(title: &str) {
    println!("\n=== {title} {}", "=".repeat(70usize.saturating_sub(title.len())));
}
