//! Figures 6/8 (stochastic Lorenz attractor) and Figure 9 (geometric
//! Brownian motion): train a latent SDE on synthetic data and dump
//! posterior reconstructions + prior samples.
//!
//! Qualitative targets (§7.2): the posterior reconstructs the data; the
//! learned prior is *not* deterministic — prior samples spread, and with a
//! shared initial latent state they still diverge (the SDE's path noise),
//! unlike a latent ODE.

use crate::coordinator::config::TrainConfig;
use crate::coordinator::trainer::train_latent_sde;
use crate::data::{gbm, lorenz, TimeSeriesDataset};
use crate::latent::{decode_path, sample_posterior_path, sample_prior_path, LatentSdeConfig,
    LatentSdeModel};
use crate::metrics::{CsvWriter, OnlineStats};
use crate::prng::PrngKey;

/// Summary of a latent-figure run (used by tests and EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub struct Summary {
    pub first_loss: f64,
    pub last_loss: f64,
    pub recon_mse: f64,
    /// Std of decoded prior samples at the terminal time (non-zero ⇒
    /// non-deterministic prior).
    pub prior_spread: f64,
    /// Same, but with all samples started from one shared z0 (isolates
    /// path noise from initial-state noise).
    pub shared_z0_spread: f64,
}

fn run_on(
    name: &str,
    ds: &TimeSeriesDataset,
    model_cfg: LatentSdeConfig,
    train_cfg: TrainConfig,
) -> Summary {
    let model = LatentSdeModel::new(model_cfg);
    let idx: Vec<usize> = (0..ds.n_series).collect();
    let log_path = super::out_dir().join(format!("{name}_training.csv"));
    let report = train_latent_sde(
        &model,
        ds,
        &idx,
        &[],
        &train_cfg,
        Some(log_path.to_str().unwrap()),
    );
    let params = &report.final_params;

    // Posterior reconstructions of the first few series.
    let n_show = 4.min(ds.n_series);
    let mut rec_csv = CsvWriter::create(
        super::out_dir().join(format!("{name}_reconstructions.csv")),
        &["series", "t", "dim", "observed", "reconstructed"],
    )
    .expect("csv");
    let mut mse = OnlineStats::new();
    for s in 0..n_show {
        let lat = sample_posterior_path(
            &model,
            params,
            &ds.times,
            ds.series(s),
            train_cfg.substeps,
            PrngKey::from_seed(9_000 + s as u64),
        );
        let dec = decode_path(&model, params, &lat);
        for (k, &t) in ds.times.iter().enumerate() {
            for d in 0..ds.dim {
                let obs = ds.obs(s, k)[d];
                let hat = dec[k * ds.dim + d];
                mse.push((obs - hat) * (obs - hat));
                rec_csv
                    .row_f64(&[s as f64, t, d as f64, obs, hat])
                    .ok();
            }
        }
    }
    rec_csv.flush().ok();

    // Prior samples: independent z0 (Fig 8 row 2) and shared z0 (row 3).
    let n_samples = 16;
    let mut prior_csv = CsvWriter::create(
        super::out_dir().join(format!("{name}_prior_samples.csv")),
        &["sample", "mode", "t", "dim", "value"],
    )
    .expect("csv");
    let mut terminal_free = OnlineStats::new();
    let mut terminal_shared = OnlineStats::new();
    let dz = model.cfg.latent_dim;
    let shared_z0: Vec<f64> = {
        let mu = &params[model.pz0_mean_off..model.pz0_mean_off + dz];
        mu.to_vec()
    };
    for s in 0..n_samples {
        for (mode, z0) in [("free", None), ("shared", Some(shared_z0.as_slice()))] {
            let lat = sample_prior_path(
                &model,
                params,
                &ds.times,
                train_cfg.substeps,
                PrngKey::from_seed(20_000 + s),
                z0,
            );
            let dec = decode_path(&model, params, &lat);
            for (k, &t) in ds.times.iter().enumerate() {
                for d in 0..ds.dim {
                    prior_csv
                        .row(&[
                            s.to_string(),
                            mode.to_string(),
                            format!("{t}"),
                            d.to_string(),
                            format!("{}", dec[k * ds.dim + d]),
                        ])
                        .ok();
                }
            }
            let last = dec[(ds.n_times() - 1) * ds.dim];
            if mode == "free" {
                terminal_free.push(last);
            } else {
                terminal_shared.push(last);
            }
        }
    }
    prior_csv.flush().ok();

    let summary = Summary {
        first_loss: report.history.first().map(|r| r.loss).unwrap_or(f64::NAN),
        last_loss: report.history.last().map(|r| r.loss).unwrap_or(f64::NAN),
        recon_mse: mse.mean(),
        prior_spread: terminal_free.std(),
        shared_z0_spread: terminal_shared.std(),
    };
    println!(
        "[{name}] loss {:.2} → {:.2} | recon MSE {:.4} | prior spread {:.4} | shared-z0 spread {:.4}",
        summary.first_loss,
        summary.last_loss,
        summary.recon_mse,
        summary.prior_spread,
        summary.shared_z0_spread
    );
    summary
}

/// Figure 6/8: stochastic Lorenz attractor.
pub fn run_lorenz(quick: bool) -> Summary {
    super::headline("Figures 6/8: latent SDE on the stochastic Lorenz attractor");
    let ds = lorenz::generate(
        PrngKey::from_seed(60),
        &lorenz::LorenzConfig {
            n_series: if quick { 48 } else { 512 },
            substeps: if quick { 10 } else { 20 },
            ..Default::default()
        },
    );
    let model_cfg = LatentSdeConfig {
        obs_dim: 3,
        latent_dim: 4,
        context_dim: 1,
        hidden: if quick { 24 } else { 64 },
        diff_hidden: 8,
        enc_hidden: if quick { 24 } else { 64 },
        obs_noise_std: 0.05,
        ..Default::default()
    };
    let train_cfg = TrainConfig {
        iters: if quick { 40 } else { 300 },
        batch_size: 8,
        lr: 0.01,
        substeps: 3,
        kl_weight: 0.01,
        kl_anneal_iters: if quick { 10 } else { 50 },
        seed: 61,
        val_every: 0,
        ..Default::default()
    };
    run_on("fig6_lorenz", &ds, model_cfg, train_cfg)
}

/// Figure 9: geometric Brownian motion.
pub fn run_gbm(quick: bool) -> Summary {
    super::headline("Figure 9: latent SDE on geometric Brownian motion");
    let ds = gbm::generate(
        PrngKey::from_seed(90),
        &gbm::GbmConfig {
            n_series: if quick { 48 } else { 512 },
            dt_obs: if quick { 0.05 } else { 0.02 },
            ..Default::default()
        },
    );
    let model_cfg = LatentSdeConfig {
        obs_dim: 1,
        latent_dim: 4,
        context_dim: 1,
        hidden: if quick { 24 } else { 64 },
        diff_hidden: 8,
        enc_hidden: if quick { 24 } else { 64 },
        obs_noise_std: 0.05,
        ..Default::default()
    };
    let train_cfg = TrainConfig {
        iters: if quick { 40 } else { 300 },
        batch_size: 8,
        lr: 0.01,
        substeps: 3,
        kl_weight: 0.01,
        kl_anneal_iters: if quick { 10 } else { 50 },
        seed: 91,
        val_every: 0,
        ..Default::default()
    };
    run_on("fig9_gbm", &ds, model_cfg, train_cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbm_quick_run_trains_and_prior_is_stochastic() {
        let s = run_gbm(true);
        assert!(s.last_loss < s.first_loss, "loss {:.2} → {:.2}", s.first_loss, s.last_loss);
        assert!(s.prior_spread > 1e-4, "prior looks deterministic: {}", s.prior_spread);
        assert!(
            s.shared_z0_spread > 1e-5,
            "no path-noise spread with shared z0: {}",
            s.shared_z0_spread
        );
    }
}
