//! Convergence-order verification harness (registered alongside the
//! paper's tables/figures as `sdegrad repro convergence`).
//!
//! Measures empirical strong, weak, and gradient convergence orders on
//! the two analytic-oracle problems — geometric Brownian motion
//! (Example 1, multiplicative noise) and Ornstein–Uhlenbeck (additive
//! noise) — across every stepping scheme and sensitivity algorithm, and
//! prints them next to the nominal orders with bootstrap 95% CIs. Raw
//! rung errors and fitted orders land in `bench_out/convergence_*.csv`.
//!
//! Reading the table: `order` is the log-log slope of error vs step size
//! over the halving ladder; it should sit inside a tolerance band around
//! `nominal` (Euler–Maruyama ≈ 0.5 strong on multiplicative noise, 1.0 on
//! additive; Milstein/Heun ≈ 1.0; weak ≈ 1.0; gradient errors shrink at
//! the solver's strong order). `mono` marks a strictly decreasing error
//! ladder — expected whenever the rungs share one virtual-tree path.
//! The seeded tolerance pins live in `rust/tests/convergence.rs`.

use crate::adjoint::AdjointConfig;
use crate::api::{SdeProblem, SensAlg};
use crate::convergence::{gradient_orders, strong_weak_orders_multi, DtLadder};
use crate::metrics::CsvWriter;
use crate::prng::PrngKey;
use crate::sde::ou::OrnsteinUhlenbeck;
use crate::sde::problems::Example1;
use crate::sde::{BatchSde, BatchSdeVjp, ExactSolution, ReplicatedSde};
use crate::solvers::Method;

/// Root seed of the harness (path `i` of a ladder derives
/// `fold_in(i)` from it; tests pin their own seeds).
const SEED: u64 = 2020_0128;

#[allow(clippy::too_many_arguments)]
fn strong_weak_section<S>(
    problem: &str,
    prob: &SdeProblem<'_, S>,
    methods: &[(Method, f64)], // (scheme, nominal strong order)
    ladder: &DtLadder,
    n_paths: usize,
    n_boot: usize,
    csv_rungs: &mut CsvWriter,
    csv_orders: &mut CsvWriter,
) where
    S: BatchSde + ExactSolution + Sync + ?Sized,
{
    println!("\n[{problem}] strong/weak orders ({n_paths} shared-tree paths)");
    println!(
        "{:>16} {:>8} {:>7} {:>22} {:>8} {:>22} {:>5}",
        "method", "kind", "nominal", "order [95% CI]", "", "finest-rung error", "mono"
    );
    let scheme_list: Vec<Method> = methods.iter().map(|&(m, _)| m).collect();
    let results = strong_weak_orders_multi(prob, &scheme_list, ladder, n_paths, n_boot);
    for (&(method, nominal_strong), res) in methods.iter().zip(&results) {
        for r in &res.rungs {
            for (kind, err) in [("strong", r.strong), ("weak", r.weak)] {
                csv_rungs
                    .row(&[
                        problem.to_string(),
                        kind.to_string(),
                        method.name().to_string(),
                        r.steps.to_string(),
                        format!("{}", r.h),
                        format!("{err}"),
                    ])
                    .ok();
            }
        }
        let finest = res.rungs.last().expect("ladder has rungs");
        for (kind, fit, nominal, finest_err, mono) in [
            ("strong", res.strong_fit, nominal_strong, finest.strong, res.strong_monotone()),
            ("weak", res.weak_fit, 1.0, finest.weak, false),
        ] {
            println!(
                "{:>16} {:>8} {:>7.2} {:>10.3} [{:>5.2}, {:>5.2}] {:>8} {:>22.4e} {:>5}",
                method.name(),
                kind,
                nominal,
                fit.order,
                fit.ci_lo,
                fit.ci_hi,
                "",
                finest_err,
                if kind == "strong" {
                    if mono { "yes" } else { "no" }
                } else {
                    "-"
                },
            );
            csv_orders
                .row(&[
                    problem.to_string(),
                    kind.to_string(),
                    method.name().to_string(),
                    format!("{}", fit.order),
                    format!("{}", fit.ci_lo),
                    format!("{}", fit.ci_hi),
                    format!("{nominal}"),
                    (if kind == "strong" { mono } else { false }).to_string(),
                ])
                .ok();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn gradient_section<S>(
    problem: &str,
    prob: &SdeProblem<'_, S>,
    algs: &[(SensAlg, f64)], // (estimator, nominal gradient order)
    ladder: &DtLadder,
    n_paths: usize,
    n_boot: usize,
    csv_rungs: &mut CsvWriter,
    csv_orders: &mut CsvWriter,
) where
    S: BatchSdeVjp + ExactSolution + Sync + ?Sized,
{
    println!("\n[{problem}] gradient orders vs closed form ({n_paths} paths)");
    println!(
        "{:>20} {:>7} {:>22} {:>22} {:>5}",
        "estimator", "nominal", "order [95% CI]", "finest-rung error", "mono"
    );
    for (alg, nominal) in algs {
        let res = match gradient_orders(prob, alg, ladder, n_paths, n_boot) {
            Ok(r) => r,
            Err(e) => {
                println!("{:>20} unsupported here: {e}", alg.name());
                continue;
            }
        };
        for r in &res.rungs {
            csv_rungs
                .row(&[
                    problem.to_string(),
                    "gradient".to_string(),
                    res.alg.to_string(),
                    r.steps.to_string(),
                    format!("{}", r.h),
                    format!("{}", r.mean_abs_err),
                ])
                .ok();
        }
        let finest = res.rungs.last().expect("ladder has rungs");
        println!(
            "{:>20} {:>7.2} {:>10.3} [{:>5.2}, {:>5.2}] {:>22.4e} {:>5}",
            res.alg,
            nominal,
            res.fit.order,
            res.fit.ci_lo,
            res.fit.ci_hi,
            finest.mean_abs_err,
            if res.monotone() { "yes" } else { "no" },
        );
        csv_orders
            .row(&[
                problem.to_string(),
                "gradient".to_string(),
                res.alg.to_string(),
                format!("{}", res.fit.order),
                format!("{}", res.fit.ci_lo),
                format!("{}", res.fit.ci_hi),
                format!("{nominal}"),
                res.monotone().to_string(),
            ])
            .ok();
    }
}

/// Run the full convergence-verification table.
pub fn run(quick: bool) {
    super::headline("Convergence orders: strong / weak / gradient vs analytic oracles");
    let mut csv_rungs = CsvWriter::create(
        super::out_dir().join("convergence_rungs.csv"),
        &["problem", "kind", "series", "steps", "h", "error"],
    )
    .expect("csv");
    let mut csv_orders = CsvWriter::create(
        super::out_dir().join("convergence_orders.csv"),
        &["problem", "kind", "series", "order", "ci_lo", "ci_hi", "nominal", "monotone"],
    )
    .expect("csv");

    let (sw_paths, g_paths, n_boot) = if quick { (64, 8, 100) } else { (256, 24, 400) };
    let sw_ladder = if quick { DtLadder::new(32, 4) } else { DtLadder::new(32, 5) };
    let g_ladder = DtLadder::new(32, 4);

    // Geometric Brownian motion (multiplicative noise): EM drops to
    // strong order ½; every SensAlg is supported.
    let gbm = ReplicatedSde::new(Example1, 2);
    let gbm_theta = [0.4, 0.5, 0.6, 0.3];
    let gbm_z0 = [1.0, 0.8];
    let gbm_prob = SdeProblem::new(&gbm, &gbm_z0, (0.0, 1.0))
        .params(&gbm_theta)
        .key(PrngKey::from_seed(SEED));
    strong_weak_section(
        "gbm",
        &gbm_prob,
        &[
            (Method::EulerMaruyama, 0.5),
            (Method::MilsteinIto, 1.0),
            (Method::Heun, 1.0),
            (Method::MilsteinStrat, 1.0),
        ],
        &sw_ladder,
        sw_paths,
        n_boot,
        &mut csv_rungs,
        &mut csv_orders,
    );
    gradient_section(
        "gbm",
        &gbm_prob,
        &[
            (SensAlg::StochasticAdjoint(AdjointConfig::default()), 1.0),
            (SensAlg::Antithetic { base: AdjointConfig::default() }, 1.0),
            (SensAlg::backprop(Method::MilsteinIto), 1.0),
            (SensAlg::backprop(Method::EulerMaruyama), 0.5),
            (SensAlg::ForwardPathwise, 0.5),
        ],
        &g_ladder,
        g_paths,
        n_boot,
        &mut csv_rungs,
        &mut csv_orders,
    );

    // Ornstein–Uhlenbeck (additive noise): EM ≡ Milstein, both strong
    // order 1; the oracle reconstructs the exact solution by pathwise
    // quadrature (brownian::quadrature).
    let ou = OrnsteinUhlenbeck::new(2);
    let ou_theta = [1.2, 0.3, 0.5];
    let ou_z0 = [0.9, 0.4];
    let ou_prob = SdeProblem::new(&ou, &ou_z0, (0.0, 1.0))
        .params(&ou_theta)
        .key(PrngKey::from_seed(SEED + 1));
    strong_weak_section(
        "ou",
        &ou_prob,
        &[
            (Method::EulerMaruyama, 1.0),
            (Method::MilsteinIto, 1.0),
            (Method::Heun, 1.0),
        ],
        &sw_ladder,
        sw_paths,
        n_boot,
        &mut csv_rungs,
        &mut csv_orders,
    );
    gradient_section(
        "ou",
        &ou_prob,
        &[
            (SensAlg::StochasticAdjoint(AdjointConfig::default()), 1.0),
            (SensAlg::backprop(Method::MilsteinIto), 1.0),
        ],
        &g_ladder,
        g_paths,
        n_boot,
        &mut csv_rungs,
        &mut csv_orders,
    );

    csv_rungs.flush().ok();
    csv_orders.flush().ok();
}
