//! Figures 5 and 7: the numerical studies on the three closed-form test
//! problems.
//!
//! * Panel (a): gradient error vs fixed step size (Milstein-forward +
//!   commutative-Milstein/Heun-backward adjoint), boxplot statistics over
//!   64 Brownian paths.
//! * Panel (b): gradient MSE vs NFE under adaptive stepping as `atol`
//!   varies (rtol = 0).
//! * Panel (c): gradient error vs wall-clock — stochastic adjoint vs
//!   backprop-through-Euler and backprop-through-Milstein, sweeping step
//!   size (the efficiency frontier).
//!
//! Fig 5 shows Example 2; Fig 7 shows Examples 1 and 3. One harness runs
//! all three.

use crate::adjoint::AdjointConfig;
use crate::api::{SdeProblem, SensAlg, StepControl};
use crate::metrics::{CsvWriter, Quartiles, Stopwatch};
use crate::prng::PrngKey;
use crate::sde::problems::{sample_experiment_setup, Example1, Example2, Example3};
use crate::sde::{ReplicatedSde, ScalarSde};
use crate::solvers::{AdaptiveConfig, Method};

const DIM: usize = 10; // §7.1: each equation duplicated 10 times

/// Mean-abs θ-gradient error of one adjoint run vs the closed form.
fn adjoint_error<P: ScalarSde + Copy>(
    problem: P,
    n_steps: usize,
    seed: u64,
) -> f64 {
    let sde = ReplicatedSde::new(problem, DIM);
    let key = PrngKey::from_seed(seed);
    let (theta, x0) = sample_experiment_setup(key, DIM, problem.nparams());
    let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
        .params(&theta)
        .key(key)
        .sensitivity_sum(
            &SensAlg::StochasticAdjoint(AdjointConfig::default()),
            StepControl::Steps(n_steps),
        )
        .expect("adjoint-compatible problem");
    let mut g_x0 = vec![0.0; DIM];
    let mut g_th = vec![0.0; theta.len()];
    sde.analytic_loss_gradients(1.0, &x0, &theta, &out.w_terminal, &mut g_x0, &mut g_th);
    g_th.iter()
        .zip(&out.dtheta)
        .map(|(a, b)| (a - b).abs())
        .sum::<f64>()
        / g_th.len() as f64
}

/// Panel (a) for one problem: error quartiles per step size.
pub fn panel_a<P: ScalarSde + Copy>(problem: P, quick: bool, csv: &mut CsvWriter) {
    let n_paths = if quick { 16 } else { 64 };
    let dts: &[usize] = if quick { &[16, 128, 1024] } else { &[8, 32, 128, 512, 2048, 8192] };
    println!(
        "\n[{} | panel a] gradient error vs step size ({} paths)",
        problem.name(),
        n_paths
    );
    println!("{:>8} {:>12} {:>12} {:>12}", "L", "q1", "median", "q3");
    for &steps in dts {
        let errs: Vec<f64> =
            (0..n_paths).map(|r| adjoint_error(problem, steps, 100 + r)).collect();
        let q = Quartiles::of(&errs);
        println!("{:>8} {:>12.3e} {:>12.3e} {:>12.3e}", steps, q.q1, q.median, q.q3);
        csv.row(&[
            problem.name().to_string(),
            steps.to_string(),
            format!("{}", q.q1),
            format!("{}", q.median),
            format!("{}", q.q3),
            format!("{}", q.min),
            format!("{}", q.max),
        ])
        .ok();
    }
}

/// Panel (b): adaptive solve — gradient MSE and NFE per `atol`.
pub fn panel_b<P: ScalarSde + Copy>(problem: P, quick: bool, csv: &mut CsvWriter) {
    let n_paths = if quick { 6 } else { 24 };
    let atols: &[f64] =
        if quick { &[1e-2, 1e-4] } else { &[1e-1, 1e-2, 1e-3, 1e-4, 1e-5] };
    println!("\n[{} | panel b] adaptive: gradient MSE vs NFE (rtol = 0)", problem.name());
    println!("{:>10} {:>14} {:>10}", "atol", "grad MSE", "mean NFE");
    for &atol in atols {
        let mut mse_acc = 0.0;
        let mut nfe_acc = 0u64;
        for r in 0..n_paths {
            let sde = ReplicatedSde::new(problem, DIM);
            let key = PrngKey::from_seed(900 + r);
            let (theta, x0) = sample_experiment_setup(key, DIM, problem.nparams());
            let cfg = AdaptiveConfig { atol, rtol: 0.0, h0: 1e-2, ..Default::default() };
            let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
                .params(&theta)
                .key(key)
                .sensitivity_adaptive(&cfg);
            let mut g_x0 = vec![0.0; DIM];
            let mut g_th = vec![0.0; theta.len()];
            sde.analytic_loss_gradients(1.0, &x0, &theta, &out.w_terminal, &mut g_x0, &mut g_th);
            mse_acc += g_th
                .iter()
                .zip(&out.dtheta)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                / g_th.len() as f64;
            nfe_acc += out.stats.nfe();
        }
        let mse = mse_acc / n_paths as f64;
        let nfe = nfe_acc as f64 / n_paths as f64;
        println!("{:>10.0e} {:>14.4e} {:>10.0}", atol, mse, nfe);
        csv.row(&[
            problem.name().to_string(),
            format!("{atol}"),
            format!("{mse}"),
            format!("{nfe}"),
        ])
        .ok();
    }
}

/// Panel (c): wall-clock vs gradient error frontier for the adjoint and
/// the two backprop baselines.
pub fn panel_c<P: ScalarSde + Copy>(problem: P, quick: bool, csv: &mut CsvWriter) {
    let n_paths = if quick { 4 } else { 16 };
    let dts: &[usize] = if quick { &[32, 256, 2048] } else { &[16, 64, 256, 1024, 4096] };
    println!("\n[{} | panel c] time vs gradient error", problem.name());
    println!(
        "{:>22} {:>8} {:>12} {:>14}",
        "method", "L", "time (ms)", "mean |err|"
    );
    for &steps in dts {
        let variants: Vec<(&str, SensAlg)> = vec![
            ("adjoint_milstein", SensAlg::StochasticAdjoint(AdjointConfig::default())),
            ("backprop_euler", SensAlg::backprop(Method::EulerMaruyama)),
            ("backprop_milstein", SensAlg::backprop(Method::MilsteinIto)),
        ];
        for (name, alg) in &variants {
            let mut err_acc = 0.0;
            let mut time_acc = 0.0;
            for r in 0..n_paths {
                let sde = ReplicatedSde::new(problem, DIM);
                let key = PrngKey::from_seed(500 + r);
                let (theta, x0) = sample_experiment_setup(key, DIM, problem.nparams());
                let sw = Stopwatch::new();
                let out = SdeProblem::new(&sde, &x0, (0.0, 1.0))
                    .params(&theta)
                    .key(key)
                    .sensitivity_sum(alg, StepControl::Steps(steps))
                    .expect("estimator validated for this SDE");
                time_acc += sw.elapsed_s();
                let mut g_x0 = vec![0.0; DIM];
                let mut g_th = vec![0.0; theta.len()];
                sde.analytic_loss_gradients(
                    1.0,
                    &x0,
                    &theta,
                    &out.w_terminal,
                    &mut g_x0,
                    &mut g_th,
                );
                err_acc += g_th
                    .iter()
                    .zip(&out.dtheta)
                    .map(|(a, b)| (a - b).abs())
                    .sum::<f64>()
                    / g_th.len() as f64;
            }
            let time = time_acc / n_paths as f64;
            let err = err_acc / n_paths as f64;
            println!("{:>22} {:>8} {:>12.3} {:>14.4e}", name, steps, time * 1e3, err);
            csv.row(&[
                problem.name().to_string(),
                name.to_string(),
                steps.to_string(),
                format!("{time}"),
                format!("{err}"),
            ])
            .ok();
        }
    }
}

/// Run all panels for all three examples (Fig 5 = Example 2; Fig 7 =
/// Examples 1 and 3).
pub fn run(quick: bool) {
    super::headline("Figures 5 & 7: numerical studies (Examples 1–3)");
    let mut csv_a = CsvWriter::create(
        super::out_dir().join("fig5a_error_vs_stepsize.csv"),
        &["problem", "steps", "q1", "median", "q3", "min", "max"],
    )
    .expect("csv");
    let mut csv_b = CsvWriter::create(
        super::out_dir().join("fig5b_mse_vs_nfe.csv"),
        &["problem", "atol", "grad_mse", "mean_nfe"],
    )
    .expect("csv");
    let mut csv_c = CsvWriter::create(
        super::out_dir().join("fig5c_time_vs_error.csv"),
        &["problem", "method", "steps", "seconds", "mean_abs_err"],
    )
    .expect("csv");

    panel_a(Example2, quick, &mut csv_a);
    panel_b(Example2, quick, &mut csv_b);
    panel_c(Example2, quick, &mut csv_c);
    panel_a(Example1, quick, &mut csv_a);
    panel_b(Example1, quick, &mut csv_b);
    panel_c(Example1, quick, &mut csv_c);
    panel_a(Example3, quick, &mut csv_a);
    panel_b(Example3, quick, &mut csv_b);
    panel_c(Example3, quick, &mut csv_c);
    csv_a.flush().ok();
    csv_b.flush().ok();
    csv_c.flush().ok();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjoint_error_shrinks_with_steps_example2() {
        // Fig 5a's monotone trend, statistically.
        let reps = 8;
        let coarse: f64 =
            (0..reps).map(|r| adjoint_error(Example2, 16, 700 + r)).sum::<f64>() / reps as f64;
        let fine: f64 =
            (0..reps).map(|r| adjoint_error(Example2, 1024, 700 + r)).sum::<f64>() / reps as f64;
        assert!(fine < coarse, "fine {fine} !< coarse {coarse}");
    }
}
